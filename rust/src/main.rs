//! FengHuang CLI — leader entrypoint.
//!
//! ```text
//! fenghuang simulate [--model M] [--system S] [--remote-tbps X]
//!                    [--batch B] [--prompt P] [--gen G]
//! fenghuang figures  [all|fig1|fig2-model|fig2-hw|table31|speedup|fig41|table43|chapter5]
//! fenghuang speedup
//! fenghuang serve    [--model M] [--requests N] [--max-batch B]
//!                    [--replicas R] [--policy P] [--disaggregate P:D]
//!                    [--sessions S] [--kv-budget-gb G]
//!                    [--prefix-cache [on|off]] [--prefix-cache-gb G]
//!                    [--qps Q] [--pattern P] [--mix M] [--seed S]
//!                    [--slo-ttft-ms X] [--slo-tpot-ms Y]
//!                    [--autoscale [on|off]] [--autoscale-min N]
//!                    [--shed-tokens T]
//!                    [--fabric-contention [off|shared|per-module]]
//!                    [--flash-gb G] [--flash-bw TBPS]
//!                    [--faults SPEC]
//!                    [--tenants SPEC] [--tenant-mode wfq|fifo]
//!                    [--admit-tokens N]
//!                    [--telemetry [on|off]] [--telemetry-interval-ms MS]
//!                    [--trace-out PATH] [--timeseries-out PATH]
//! fenghuang page     [--model M] [--system S] [--local-gb G] [--policy P]
//!                    [--window W] [--steps N] [--nmc on] [--page-kv on]
//!                    [--flash-gb G] [--flash-bw TBPS] [--pool-gb G]
//! fenghuang help
//! ```
//!
//! Flag parsing, the per-subcommand whitelists, and the conflict rules
//! live in [`fenghuang::cli`] so they are unit-tested (the offline build
//! environment has no clap or anyhow — see DESIGN.md §1). Every
//! subcommand validates its flag set: unknown flags and out-of-range
//! values fail with actionable messages instead of silently falling back
//! to defaults.

use fenghuang::cli::{
    check_contention_fabric, check_disaggregate_replicas, cli_err, flag, parse_disaggregate,
    parse_fabric_contention, parse_faults, parse_flags, parse_flash, parse_prefix_cache,
    parse_telemetry, parse_tenants, positive, switch, system_by_name, PAGE_BARE, PAGE_FLAGS,
    SERVE_BARE, SERVE_FLAGS, SIMULATE_FLAGS, TRAFFIC_FLAGS,
};
use fenghuang::coordinator::router::Policy;
use fenghuang::coordinator::PrefixCacheConfig;
use fenghuang::fabric::contention::{ContentionConfig, ContentionMode};
use fenghuang::paging::NmcConfig;
use fenghuang::prelude::*;
use std::collections::HashMap;

const USAGE: &str = "\
fenghuang — FengHuang memory-orchestration reproduction

USAGE:
  fenghuang simulate [--model gpt3|grok1|qwen3|deepseek-v3|gpt2]
                     [--system baseline8|fh4-1.5xm|fh4-2.0xm]
                     [--remote-tbps 4.8] [--batch 8] [--prompt 4096] [--gen 1024]
  fenghuang figures  [all|fig1|fig2-model|fig2-hw|table31|speedup|fig41|table43|chapter5]
  fenghuang figures-csv [fig1|fig2-model|fig2-hw|fig41|speedup]
  fenghuang speedup
  fenghuang serve    [--model gpt3] [--requests 64] [--max-batch 8]
                     [--replicas 1] [--policy round-robin|least-outstanding-tokens|kv-affinity]
                     [--disaggregate P:D] [--sessions 8] [--kv-budget-gb G]
                     [--prefix-cache [on|off]] [--prefix-cache-gb G]
                     [--fabric-contention [off|shared|per-module]]
                     [--flash-gb G] [--flash-bw 1.6]
                     open-loop traffic (any of these flags selects the traffic engine):
                     [--qps 8] [--pattern poisson|bursty|diurnal|replay]
                     [--mix chat|rag|agentic|batch, '+'-combined, e.g. chat+rag]
                     [--slo-ttft-ms 2000] [--slo-tpot-ms 80] [--seed 42]
                     [--autoscale [on|off]] [--autoscale-min 1] [--shed-tokens T]
                     [--faults 'crash@T:rN[:repairX],module@T:hot|mI,degrade@T:xF:dD,
                               random:seed=S:horizon=H[:crash=R][:module=R][:degrade=R]']
                     multi-tenant serving over one shared pool:
                     [--tenants 'name/model[/weight=W][/quota=Q][/slo-scale=S][/mix=M],…']
                     [--tenant-mode wfq|fifo] [--admit-tokens N]
                     telemetry (span traces, stall ledger, time-series):
                     [--telemetry [on|off]] [--telemetry-interval-ms 100]
                     [--trace-out trace.json] [--timeseries-out series.csv]
  fenghuang page     [--model gpt3] [--system fh4-1.5xm|fh4-2.0xm] [--remote-tbps 4.8]
                     [--batch 8] [--phase decode|prefill] [--kv-len 4608] [--prompt 4096]
                     [--local-gb 12|unlimited] [--policy minimal|lru|heat] [--window 10]
                     [--steps 3] [--page-mib 2] [--pin-frac 0.0] [--page-kv on|off]
                     [--nmc on|off] [--fabric-contention [off|shared|per-module]]
                     [--flash-gb G] [--flash-bw 1.6] [--pool-gb G]
  fenghuang help
";

fn run_serve(args: &[String]) -> Result<()> {
    let f = parse_flags("serve", args, SERVE_FLAGS, SERVE_BARE)?;
    let model: String = flag(&f, "model", "gpt3".to_string())?;
    let requests: usize = positive(&f, "requests", 64)?;
    let max_batch: usize = positive(&f, "max-batch", 8)?;
    let replicas: usize = positive(&f, "replicas", 1)?;
    let sessions: usize = positive(&f, "sessions", 8)?;
    let policy_s: String = flag(&f, "policy", "least-outstanding-tokens".to_string())?;
    let policy = Policy::parse(&policy_s).ok_or_else(|| {
        cli_err(format!(
            "unknown policy '{policy_s}' (expected round-robin, \
             least-outstanding-tokens or kv-affinity)"
        ))
    })?;
    let disaggregate = match f.get("disaggregate") {
        Some(v) => Some(parse_disaggregate(v)?),
        None => None,
    };
    if let Some(pools) = disaggregate {
        // Pool sizes define the fleet; an explicit conflicting
        // --replicas would otherwise be silently ignored.
        check_disaggregate_replicas(&f, replicas, pools)?;
    }
    let prefix_cache = parse_prefix_cache(&f)?;
    // The serve rack is always FH4 (TAB), so the flag cannot conflict
    // with the fabric here; `Cluster::new` still enforces the rule.
    let contention = parse_fabric_contention(&f)?;
    let flash = parse_flash(&f)?;
    let fleet = disaggregate.map(|(p, d)| p + d).unwrap_or(replicas);
    let faults = parse_faults(&f, fleet)?;
    let kv_budget = match f.get("kv-budget-gb") {
        Some(v) => {
            let gb: f64 = v
                .parse()
                .map_err(|e| cli_err(format!("--kv-budget-gb: {e}")))?;
            if gb <= 0.0 {
                return Err(cli_err(format!("--kv-budget-gb must be > 0, got {gb}")));
            }
            Some(Bytes::gb(gb))
        }
        None => None,
    };
    let m =
        arch::by_name(&model).ok_or_else(|| cli_err(format!("unknown model '{model}'")))?;
    let tenants = parse_tenants(&f)?;
    if tenants.is_some() {
        // Each tenant names its own model and mix; a fleet-wide --model
        // or --mix would be silently ignored — reject instead.
        for k in ["model", "mix"] {
            if f.contains_key(k) {
                return Err(cli_err(format!(
                    "--{k} conflicts with --tenants (each tenant carries its own \
                     model and mix in the spec)"
                )));
            }
        }
    }
    if tenants.is_some() || TRAFFIC_FLAGS.iter().any(|k| f.contains_key(*k)) {
        // Open-loop traffic engine (DESIGN.md §Traffic); multi-tenant
        // serving always rides it — per-tenant mixes need per-tenant
        // streams (DESIGN.md §Multi-Tenant).
        return run_serve_traffic(
            &f,
            &m,
            requests,
            max_batch,
            replicas,
            policy,
            disaggregate,
            kv_budget,
            prefix_cache,
            contention,
            flash,
            faults,
            tenants,
        );
    }
    if replicas <= 1
        && disaggregate.is_none()
        && !f.contains_key("policy")
        && kv_budget.is_none()
        && prefix_cache.is_none()
        && contention.mode == ContentionMode::Off
        && flash.is_none()
        && faults.is_none()
    {
        // Single node, no routing: the original serving path.
        println!("{}", fenghuang::coordinator::demo_serve(&m, requests, max_batch)?);
    } else {
        println!(
            "{}",
            fenghuang::coordinator::demo_serve_cluster(
                &m,
                requests,
                max_batch,
                replicas,
                policy,
                disaggregate,
                sessions,
                kv_budget,
                prefix_cache,
                contention,
                flash,
                faults,
            )?
        );
    }
    Ok(())
}

/// The `serve` traffic path: build a [`TrafficConfig`] + elastic
/// [`fenghuang::coordinator::ClusterConfig`] from the flags and run the
/// open-loop experiment.
#[allow(clippy::too_many_arguments)]
fn run_serve_traffic(
    f: &HashMap<String, String>,
    m: &ModelArch,
    requests: usize,
    max_batch: usize,
    replicas: usize,
    policy: Policy,
    disaggregate: Option<(usize, usize)>,
    kv_budget: Option<Bytes>,
    prefix_cache: Option<PrefixCacheConfig>,
    contention: ContentionConfig,
    flash: Option<fenghuang::config::FlashConfig>,
    faults: Option<fenghuang::faults::FaultSchedule>,
    tenants: Option<fenghuang::coordinator::TenantsConfig>,
) -> Result<()> {
    use fenghuang::coordinator::{AutoscaleConfig, ClusterConfig, SloTarget};

    if f.contains_key("sessions") {
        return Err(cli_err(
            "--sessions belongs to the legacy fixed-gap workload; the traffic engine's \
             agentic class carries its own session pool (use --mix agentic)"
                .into(),
        ));
    }
    let qps: f64 = flag(f, "qps", 8.0)?;
    if qps <= 0.0 {
        return Err(cli_err(format!("--qps must be > 0, got {qps}")));
    }
    let pattern_s: String = flag(f, "pattern", "poisson".to_string())?;
    let pattern = ArrivalPattern::parse(&pattern_s).ok_or_else(|| {
        cli_err(format!(
            "unknown pattern '{pattern_s}' (expected poisson, bursty, diurnal or replay)"
        ))
    })?;
    let mix_s: String = flag(f, "mix", "chat".to_string())?;
    let mix = WorkloadMix::parse(&mix_s).ok_or_else(|| {
        cli_err(format!(
            "bad mix '{mix_s}' (classes chat|rag|agentic|batch joined by '+', \
             optional :weight — e.g. chat:3+rag:1)"
        ))
    })?;
    let slo_ttft_ms: f64 = flag(f, "slo-ttft-ms", fenghuang::traffic::DEFAULT_SLO_TTFT_MS)?;
    let slo_tpot_ms: f64 = flag(f, "slo-tpot-ms", fenghuang::traffic::DEFAULT_SLO_TPOT_MS)?;
    if slo_ttft_ms <= 0.0 || slo_tpot_ms <= 0.0 {
        return Err(cli_err(format!(
            "SLO targets must be > 0 (got --slo-ttft-ms {slo_ttft_ms}, \
             --slo-tpot-ms {slo_tpot_ms})"
        )));
    }
    let seed: u64 = flag(f, "seed", 42)?;
    let telemetry = parse_telemetry(f)?;
    let autoscale = if switch(f, "autoscale")? {
        let min: usize = positive(f, "autoscale-min", 1)?;
        Some(AutoscaleConfig { min_replicas: min, ..Default::default() })
    } else {
        if f.contains_key("autoscale-min") {
            return Err(cli_err("--autoscale-min needs --autoscale".into()));
        }
        None
    };
    let shed_tokens = match f.get("shed-tokens") {
        Some(v) => {
            let t: u64 = v.parse().map_err(|e| cli_err(format!("--shed-tokens: {e}")))?;
            if t == 0 {
                return Err(cli_err("--shed-tokens must be ≥ 1".into()));
            }
            Some(t)
        }
        None => None,
    };
    // Replay from the legacy fixed-gap cadence: the degenerate recorded
    // trace, kept so `--pattern replay` works without a trace file.
    let replay_gaps = if pattern == ArrivalPattern::Replay {
        vec![Seconds::new(1.0 / qps)]
    } else {
        Vec::new()
    };
    let tc = TrafficConfig {
        arrivals: ArrivalConfig { pattern, qps, replay_gaps, ..Default::default() },
        mix,
        requests,
        seed,
        max_prompt: m.max_seq as usize,
        slo: Some(SloTarget {
            ttft: Seconds::ms(slo_ttft_ms),
            tpot: Seconds::ms(slo_tpot_ms),
        }),
    };
    let cfg = ClusterConfig {
        policy,
        max_batch,
        disaggregate,
        kv_budget,
        shed_tokens,
        autoscale,
        prefix_cache,
        contention,
        flash,
        faults,
        tenants,
        telemetry,
    };
    let total = disaggregate.map(|(p, d)| p + d).unwrap_or(replicas);
    let multi_tenant = cfg.tenants.is_some();
    let (text, report) = if multi_tenant {
        fenghuang::coordinator::demo_serve_tenants_report(total, cfg, &tc)?
    } else {
        fenghuang::coordinator::demo_serve_traffic_report(m, total, cfg, &tc)?
    };
    println!("{text}");
    if let Some(tel) = &report.telemetry {
        if let Some(path) = f.get("trace-out") {
            std::fs::write(path, fenghuang::telemetry::export::chrome_trace(tel))
                .map_err(|e| cli_err(format!("--trace-out {path}: {e}")))?;
            println!("wrote Chrome trace (load in Perfetto / chrome://tracing): {path}");
        }
        if let Some(path) = f.get("timeseries-out") {
            std::fs::write(path, fenghuang::telemetry::export::timeseries_csv(tel))
                .map_err(|e| cli_err(format!("--timeseries-out {path}: {e}")))?;
            println!("wrote telemetry time-series CSV: {path}");
        }
    }
    Ok(())
}

fn run_page(args: &[String]) -> Result<()> {
    let f = parse_flags("page", args, PAGE_FLAGS, PAGE_BARE)?;
    let model: String = flag(&f, "model", "gpt3".to_string())?;
    let system: String = flag(&f, "system", "fh4-1.5xm".to_string())?;
    let remote_tbps: f64 =
        flag(&f, "remote-tbps", fenghuang::config::DEFAULT_REMOTE_TBPS)?;
    if remote_tbps <= 0.0 {
        return Err(cli_err(format!("--remote-tbps must be > 0, got {remote_tbps}")));
    }
    let batch: u64 = positive(&f, "batch", 8)?;
    let phase_s: String = flag(&f, "phase", "decode".to_string())?;
    let phase = match phase_s.to_ascii_lowercase().as_str() {
        "decode" => {
            if f.contains_key("prompt") {
                return Err(cli_err(
                    "--prompt only applies to --phase prefill (use --kv-len for decode)".into(),
                ));
            }
            Phase::Decode { kv_len: positive(&f, "kv-len", 4608)? }
        }
        "prefill" => {
            if f.contains_key("kv-len") {
                return Err(cli_err(
                    "--kv-len only applies to --phase decode (use --prompt for prefill)".into(),
                ));
            }
            Phase::Prefill { prompt_len: positive(&f, "prompt", 4096)? }
        }
        other => {
            return Err(cli_err(format!("--phase wants decode|prefill, got '{other}'")));
        }
    };
    let local_raw: String = flag(&f, "local-gb", "unlimited".to_string())?;
    let local_budget = if local_raw == "unlimited" {
        None
    } else {
        let gb: f64 = local_raw
            .parse()
            .map_err(|e| cli_err(format!("--local-gb: {e} (number of GB or 'unlimited')")))?;
        if gb <= 0.0 {
            return Err(cli_err(format!("--local-gb must be > 0, got {gb}")));
        }
        Some(Bytes::gb(gb))
    };
    let policy_s: String = flag(&f, "policy", "minimal".to_string())?;
    let kind = PolicyKind::parse(&policy_s).ok_or_else(|| {
        cli_err(format!(
            "unknown paging policy '{policy_s}' (expected minimal, lru or heat)"
        ))
    })?;
    let window: usize = positive(&f, "window", 10)?;
    let steps: usize = positive(&f, "steps", 3)?;
    let page_mib: f64 = flag(&f, "page-mib", 2.0)?;
    if page_mib <= 0.0 {
        return Err(cli_err(format!("--page-mib must be > 0, got {page_mib}")));
    }
    let pin_frac: f64 = flag(&f, "pin-frac", 0.0)?;
    if !(0.0..=1.0).contains(&pin_frac) {
        return Err(cli_err(format!("--pin-frac must be in [0, 1], got {pin_frac}")));
    }
    if pin_frac > 0.0 && local_budget.is_none() {
        return Err(cli_err(
            "--pin-frac reserves a fraction of the local budget — give --local-gb too".into(),
        ));
    }
    let page_kv = switch(&f, "page-kv")?;
    let nmc = switch(&f, "nmc")?;
    let contention = parse_fabric_contention(&f)?;
    let flash = parse_flash(&f)?;
    let pool_budget = match f.get("pool-gb") {
        Some(v) => {
            let gb: f64 = v.parse().map_err(|e| cli_err(format!("--pool-gb: {e}")))?;
            if gb <= 0.0 {
                return Err(cli_err(format!("--pool-gb must be > 0, got {gb}")));
            }
            if flash.is_none() {
                return Err(cli_err(
                    "--pool-gb caps the pool's home capacity of the 3-tier hierarchy — \
                     give --flash-gb too"
                        .into(),
                ));
            }
            Some(Bytes::gb(gb))
        }
        None => None,
    };

    let m =
        arch::by_name(&model).ok_or_else(|| cli_err(format!("unknown model '{model}'")))?;
    let mut sys = system_by_name(&system, remote_tbps)?;
    sys.flash = flash;
    check_contention_fabric(&sys, &contention)?;
    let cfg = PagingConfig {
        page_bytes: Bytes::mib(page_mib),
        local_budget,
        pool_budget,
        policy: PlacementPolicy { kind, window, page_kv, pin_frac },
        nmc: NmcConfig { enabled: nmc },
        contention,
        steps,
        ..Default::default()
    };
    let r = fenghuang::paging::simulate_paged(&sys, &m, batch, phase, &cfg)?;
    // Full-residency reference: uncapped LRU reaches the zero-fetch
    // steady state, the "all weights resident" roofline.
    let full_cfg = PagingConfig {
        local_budget: None,
        policy: PlacementPolicy { kind: PolicyKind::Lru, window, page_kv, pin_frac: 0.0 },
        steps: steps.max(2),
        ..cfg
    };
    let full = fenghuang::paging::simulate_paged(&sys, &m, batch, phase, &full_cfg)?;
    let slowdown = if full.steady_step.value() > 0.0 {
        r.steady_step / full.steady_step
    } else {
        1.0
    };

    println!(
        "{} on {} ({:?}, batch {batch}) — policy {}, window {window}, {} steps",
        r.model,
        r.system,
        r.phase,
        r.policy.name(),
        r.steps
    );
    match local_budget {
        Some(b) => println!("  local budget      {:>10.2} GB", b.as_gb()),
        None => println!("  local budget       unlimited"),
    }
    if flash.is_some() {
        println!(
            "  working set       {:>10.2} GB (pool {:.2} GB, flash {:.2} GB, HBM {:.2} GB)",
            r.working_set.as_gb(),
            r.pool_homed.as_gb(),
            r.flash_homed.as_gb(),
            r.local_homed.as_gb()
        );
    } else {
        println!("  working set       {:>10.2} GB (remote pool)", r.working_set.as_gb());
    }
    println!("  cold step         {:>10.3} ms", r.cold_step.as_ms());
    println!("  steady step       {:>10.3} ms", r.steady_step.as_ms());
    println!("  full-residency    {:>10.3} ms  (slowdown {slowdown:.3}x)", full.steady_step.as_ms());
    println!(
        "  exposed stall     {:>10.3} ms ({:.1}% of step)",
        r.exposed.as_ms(),
        100.0 * r.exposure_frac()
    );
    println!("  peak local        {:>10.2} GB", r.peak_local.as_gb());
    println!(
        "  vs Baseline8 HBM  {:>9.1}% capacity reduction (144 GB reference)",
        100.0 * r.capacity_reduction_vs(Bytes::gb(144.0))
    );
    if r.pinned.value() > 0.0 {
        println!("  pinned weights    {:>10.2} GB", r.pinned.as_gb());
    }
    println!(
        "  paged in          {:>10.2} GB in {} pages / {} batches",
        r.migration.bytes_in.as_gb(),
        r.migration.pages_in,
        r.migration.batches
    );
    if r.migration.flash_bytes_in.value() > 0.0 {
        println!(
            "  from flash        {:>10.2} GB in {} pages",
            r.migration.flash_bytes_in.as_gb(),
            r.migration.flash_pages_in
        );
    }
    if r.migration.demotions > 0 || r.migration.promotions > 0 {
        println!(
            "  band moves        {:>10} demotions ({:.2} GB), {} promotions ({:.2} GB)",
            r.migration.demotions,
            r.migration.demoted_bytes.as_gb(),
            r.migration.promotions,
            r.migration.promoted_bytes.as_gb()
        );
    }
    if r.migration.bytes_out.value() > 0.0 {
        println!(
            "  written back      {:>10.2} GB ({} write-backs)",
            r.migration.bytes_out.as_gb(),
            r.migration.writebacks
        );
    }
    if r.evictions > 0 {
        println!("  evictions         {:>10}", r.evictions);
    }
    if nmc {
        println!("  NMC offloads      {:>10} ops executed in-pool", r.nmc_offloads);
    }
    if let Some(fr) = &r.fabric {
        print!("  {}", fr.summary_line());
    }
    Ok(())
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "simulate" => {
            let f = parse_flags("simulate", &args[1..], SIMULATE_FLAGS, &[])?;
            let model: String = flag(&f, "model", "gpt3".to_string())?;
            let system: String = flag(&f, "system", "fh4-1.5xm".to_string())?;
            let remote_tbps: f64 =
        flag(&f, "remote-tbps", fenghuang::config::DEFAULT_REMOTE_TBPS)?;
            let batch: u64 = positive(&f, "batch", 8)?;
            let prompt: u64 = positive(&f, "prompt", 4096)?;
            let gen: u64 = positive(&f, "gen", 1024)?;
            let m = arch::by_name(&model)
                .ok_or_else(|| cli_err(format!("unknown model '{model}'")))?;
            let sys = system_by_name(&system, remote_tbps)?;
            let r = fenghuang::sim::run_workload(&sys, &m, batch, prompt, gen)?;
            println!("{} on {} (batch {batch}, prompt {prompt}, gen {gen})", r.model, r.system);
            println!("  TTFT       {:>10.2} ms", r.ttft.as_ms());
            println!("  TPOT       {:>10.3} ms", r.tpot.as_ms());
            println!("  E2E        {:>10.2} s", r.e2e.value());
            println!("  peak local {:>10.2} GB", r.peak_local.as_gb());
        }
        "figures" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            print!("{}", fenghuang::analysis::render(which)?);
        }
        "figures-csv" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("fig41");
            print!("{}", fenghuang::analysis::render_csv(which)?);
        }
        "speedup" => {
            print!("{}", fenghuang::analysis::render("speedup")?);
        }
        "serve" => run_serve(&args[1..])?,
        "page" => run_page(&args[1..])?,
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
