//! FengHuang CLI — leader entrypoint.
//!
//! ```text
//! fenghuang simulate [--model M] [--system S] [--remote-tbps X]
//!                    [--batch B] [--prompt P] [--gen G]
//! fenghuang figures  [all|fig1|fig2-model|fig2-hw|table31|speedup|fig41|table43|chapter5]
//! fenghuang speedup
//! fenghuang serve    [--model M] [--requests N] [--max-batch B]
//!                    [--replicas R] [--policy P] [--disaggregate P:D]
//!                    [--sessions S]
//! fenghuang help
//! ```
//!
//! (Arg parsing and error plumbing are hand-rolled; the offline build
//! environment has no clap or anyhow — see DESIGN.md §1.)

use fenghuang::coordinator::router::Policy;
use fenghuang::prelude::*;
use fenghuang::units::Bandwidth;
use std::collections::HashMap;

const USAGE: &str = "\
fenghuang — FengHuang memory-orchestration reproduction

USAGE:
  fenghuang simulate [--model gpt3|grok1|qwen3|deepseek-v3|gpt2]
                     [--system baseline8|fh4-1.5xm|fh4-2.0xm]
                     [--remote-tbps 4.8] [--batch 8] [--prompt 4096] [--gen 1024]
  fenghuang figures  [all|fig1|fig2-model|fig2-hw|table31|speedup|fig41|table43|chapter5]
  fenghuang figures-csv [fig1|fig2-model|fig2-hw|fig41|speedup]
  fenghuang speedup
  fenghuang serve    [--model gpt3] [--requests 64] [--max-batch 8]
                     [--replicas 1] [--policy round-robin|least-outstanding-tokens|kv-affinity]
                     [--disaggregate P:D] [--sessions 8]
  fenghuang help
";

fn cli_err(msg: String) -> FhError {
    FhError::Config(msg)
}

/// Parse `--key value` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            return Err(cli_err(format!("unexpected argument '{k}' (flags are --key value)")));
        }
        let v = args
            .get(i + 1)
            .ok_or_else(|| cli_err(format!("flag {k} needs a value")))?;
        flags.insert(k.trim_start_matches("--").to_string(), v.clone());
        i += 2;
    }
    Ok(flags)
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        Some(v) => v.parse().map_err(|e| cli_err(format!("--{key}: {e}"))),
        None => Ok(default),
    }
}

fn system_by_name(name: &str, remote_tbps: f64) -> Result<SystemConfig> {
    let bw = Bandwidth::tbps(remote_tbps);
    match name.to_ascii_lowercase().as_str() {
        "baseline8" => Ok(baseline8()),
        "fh4-1.5xm" | "fh4_15xm" => Ok(fh4_15xm(bw)),
        "fh4-2.0xm" | "fh4_20xm" => Ok(fh4_20xm(bw)),
        other => Err(cli_err(format!("unknown system preset '{other}'"))),
    }
}

/// Parse `--disaggregate P:D` (prefill:decode pool sizes).
fn parse_disaggregate(v: &str) -> Result<(usize, usize)> {
    let (p, d) = v
        .split_once(':')
        .ok_or_else(|| cli_err(format!("--disaggregate wants P:D, got '{v}'")))?;
    let p: usize = p.parse().map_err(|e| cli_err(format!("--disaggregate prefill: {e}")))?;
    let d: usize = d.parse().map_err(|e| cli_err(format!("--disaggregate decode: {e}")))?;
    if p == 0 || d == 0 {
        return Err(cli_err("--disaggregate pools must be non-empty".into()));
    }
    Ok((p, d))
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "simulate" => {
            let f = parse_flags(&args[1..])?;
            let model: String = flag(&f, "model", "gpt3".to_string())?;
            let system: String = flag(&f, "system", "fh4-1.5xm".to_string())?;
            let remote_tbps: f64 = flag(&f, "remote-tbps", 4.8)?;
            let batch: u64 = flag(&f, "batch", 8)?;
            let prompt: u64 = flag(&f, "prompt", 4096)?;
            let gen: u64 = flag(&f, "gen", 1024)?;
            let m = arch::by_name(&model)
                .ok_or_else(|| cli_err(format!("unknown model '{model}'")))?;
            let sys = system_by_name(&system, remote_tbps)?;
            let r = fenghuang::sim::run_workload(&sys, &m, batch, prompt, gen)?;
            println!("{} on {} (batch {batch}, prompt {prompt}, gen {gen})", r.model, r.system);
            println!("  TTFT       {:>10.2} ms", r.ttft.as_ms());
            println!("  TPOT       {:>10.3} ms", r.tpot.as_ms());
            println!("  E2E        {:>10.2} s", r.e2e.value());
            println!("  peak local {:>10.2} GB", r.peak_local.as_gb());
        }
        "figures" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("all");
            print!("{}", fenghuang::analysis::render(which)?);
        }
        "figures-csv" => {
            let which = args.get(1).map(|s| s.as_str()).unwrap_or("fig41");
            print!("{}", fenghuang::analysis::render_csv(which)?);
        }
        "speedup" => {
            print!("{}", fenghuang::analysis::render("speedup")?);
        }
        "serve" => {
            let f = parse_flags(&args[1..])?;
            let model: String = flag(&f, "model", "gpt3".to_string())?;
            let requests: usize = flag(&f, "requests", 64)?;
            let max_batch: usize = flag(&f, "max-batch", 8)?;
            let replicas: usize = flag(&f, "replicas", 1)?;
            let sessions: usize = flag(&f, "sessions", 8)?;
            let policy_s: String = flag(&f, "policy", "least-outstanding-tokens".to_string())?;
            let policy = Policy::parse(&policy_s)
                .ok_or_else(|| cli_err(format!("unknown policy '{policy_s}'")))?;
            let disaggregate = match f.get("disaggregate") {
                Some(v) => Some(parse_disaggregate(v)?),
                None => None,
            };
            if let Some((p, d)) = disaggregate {
                // Pool sizes define the fleet; an explicit conflicting
                // --replicas would otherwise be silently ignored.
                if f.contains_key("replicas") && p + d != replicas {
                    return Err(cli_err(format!(
                        "--replicas {replicas} conflicts with --disaggregate {p}:{d} (= {} replicas)",
                        p + d
                    )));
                }
            }
            let m = arch::by_name(&model)
                .ok_or_else(|| cli_err(format!("unknown model '{model}'")))?;
            if replicas <= 1 && disaggregate.is_none() && !f.contains_key("policy") {
                // Single node, no routing: the original serving path.
                println!("{}", fenghuang::coordinator::demo_serve(&m, requests, max_batch)?);
            } else {
                println!(
                    "{}",
                    fenghuang::coordinator::demo_serve_cluster(
                        &m,
                        requests,
                        max_batch,
                        replicas,
                        policy,
                        disaggregate,
                        sessions,
                    )?
                );
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprint!("unknown command '{other}'\n\n{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
