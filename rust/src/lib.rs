//! # FengHuang — disaggregated shared-memory orchestration for AI inference
//!
//! Reproduction of *FengHuang: Next-Generation Memory Orchestration for AI
//! Inferencing* (Microsoft Research, 2025). The library provides:
//!
//! * [`models`] — analytical LLM architecture library (parameters, KV
//!   cache, FLOPs, communication volumes) for the paper's workloads;
//! * [`hardware`] — xPU / interconnect catalog for the trend figures;
//! * [`fabric`] — the TAB shared-memory pool with write-accumulate and
//!   completion notifications (functional + analytic), NVLink ring
//!   baseline, the §3.3.3 speed-up analysis, and the contention-aware
//!   shared-fabric arbitration layer (windowed per-port / per-module
//!   bandwidth ledger with an Off mode that is bit-identical to the
//!   unloaded charges);
//! * [`trace`] — synthetic operator traces (the Nsight-trace substitute);
//! * [`sim`] — discrete-event simulator with the tensor prefetcher and
//!   paging stream (→ Fig 4.1, Table 4.3);
//! * [`paging`] — active tensor paging: page-granular multi-tier memory
//!   orchestration (page table, eviction policies, batched migration)
//!   with near-memory compute offload (→ Table 4.3 capacity sweep);
//! * [`coordinator`] — serving layer: request router, continuous batcher,
//!   prefill/decode scheduler over simulated FengHuang nodes, and the
//!   rack-scale multi-replica cluster simulator with KV-aware routing,
//!   disaggregated prefill/decode pools, front-door load shedding, an
//!   SLO-driven elastic autoscaler, and a cluster-wide shared prefix-KV
//!   cache in the TAB pool (cross-replica prefill reuse);
//! * [`faults`] — deterministic fault injection and recovery accounting
//!   (replica crash/rejoin, TAB module failure, link degradation) with a
//!   strict bit-identical passthrough when no schedule is armed;
//! * [`telemetry`] — deterministic observability: per-request span
//!   traces with a bitwise TTFT stall-attribution ledger, a windowed
//!   fleet time-series sampler pumped identically by both cluster
//!   cores, and Chrome-trace / CSV exporters (off = bit-identical
//!   passthrough);
//! * [`cli`] — unit-tested flag parsing for the `fenghuang` binary;
//! * [`traffic`] — deterministic open-loop workload engine: seedable
//!   RNG, arrival processes (Poisson / bursty / diurnal / replay), and
//!   workload mixes (chat, RAG, agentic, batch) with per-request
//!   TTFT/TPOT SLO targets;
//! * [`runtime`] — PJRT client wrapper executing AOT-compiled JAX/Pallas
//!   artifacts from the Rust hot path;
//! * [`analysis`] — figure/table generators for every artifact in the
//!   paper's evaluation;
//! * [`config`] — system presets (Table 4.1/4.2) and TOML configuration.
//!
//! See DESIGN.md for the experiment index and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod analysis;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod fabric;
pub mod faults;
pub mod hardware;
pub mod models;
pub mod paging;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod trace;
pub mod traffic;
pub mod units;

pub use error::{FhError, Result};

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::{baseline8, fh4_15xm, fh4_20xm, SystemConfig};
    pub use crate::error::{FhError, Result};
    pub use crate::fabric::{
        Collective, ContentionConfig, ContentionMode, FabricClock, FabricLatencies,
        FabricReport, TabPool,
    };
    pub use crate::models::arch::{self, ModelArch};
    pub use crate::paging::{simulate_paged, PagedReport, PagingConfig, PlacementPolicy, PolicyKind};
    pub use crate::sim::{simulate, SimReport};
    pub use crate::trace::{Phase, TraceConfig};
    pub use crate::traffic::{ArrivalConfig, ArrivalPattern, TrafficConfig, WorkloadMix};
    pub use crate::units::{Bandwidth, Bytes, Dtype, FlopRate, Flops, Seconds};
}
