//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! Python/JAX runs once at build time (`make artifacts`); this module is
//! the only bridge at serve time: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text* is
//! the interchange format (xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-id serialized protos — see /opt/xla-example/README.md).
//!
//! Everything touching the out-of-tree `xla` bindings is gated behind
//! the `pjrt` cargo feature so the default (offline) build stays
//! dependency-free; the [`artifacts`] bundle loader is always available.

pub mod artifacts;

#[cfg(feature = "pjrt")]
use crate::error::{FhError, Result};
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
fn rt_err<E: std::fmt::Display>(ctx: String) -> impl FnOnce(E) -> FhError {
    move |e| FhError::Runtime(format!("{ctx}: {e}"))
}

#[cfg(feature = "pjrt")]
/// A PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(rt_err("PjRtClient::cpu".into()))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it to an executable.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(rt_err(format!("parse {}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(rt_err(format!("compile {}", path.display())))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

#[cfg(feature = "pjrt")]
/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with the given inputs; returns the flattened tuple of
    /// outputs (jax.jit lowering uses `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(rt_err(format!("execute {}", self.name)))?;
        let literal = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| FhError::Runtime(format!("{}: empty result", self.name)))?
            .to_literal_sync()
            .map_err(rt_err("to_literal_sync".into()))?;
        literal.to_tuple().map_err(rt_err("to_tuple".into()))
    }

    /// Execute with borrowed inputs (avoids cloning cached weight
    /// literals on the hot path); returns the flattened output tuple.
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .map_err(rt_err(format!("execute {}", self.name)))?;
        let literal = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| FhError::Runtime(format!("{}: empty result", self.name)))?
            .to_literal_sync()
            .map_err(rt_err("to_literal_sync".into()))?;
        literal.to_tuple().map_err(rt_err("to_tuple".into()))
    }

    /// Execute and return the single output.
    pub fn run1(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let mut outs = self.run(inputs)?;
        if outs.len() != 1 {
            return Err(FhError::Runtime(format!(
                "{}: expected 1 output, got {}",
                self.name,
                outs.len()
            )));
        }
        Ok(outs.pop().unwrap())
    }
}

#[cfg(feature = "pjrt")]
/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    if expected as usize != data.len() {
        return Err(FhError::Runtime(format!(
            "literal shape {dims:?} needs {expected} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(rt_err("reshape".into()))
}

#[cfg(feature = "pjrt")]
/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    if expected as usize != data.len() {
        return Err(FhError::Runtime(format!(
            "literal shape {dims:?} needs {expected} elements, got {}",
            data.len()
        )));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(rt_err("reshape".into()))
}

#[cfg(feature = "pjrt")]
/// Extract a literal's data as `Vec<f32>`.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(rt_err("to_vec::<f32>".into()))
}

#[cfg(feature = "pjrt")]
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0; 5], &[2, 3]).is_err());
        assert!(literal_i32(&[1; 7], &[2, 3]).is_err());
    }

    // PJRT-backed tests live in rust/tests/runtime_e2e.rs (they need the
    // artifacts/ directory built by `make artifacts`).
}
