//! Artifact bundle loader: manifest.txt + params.bin + meta.txt.
//!
//! `python -m compile.aot` writes a flat f32-LE parameter blob and a
//! manifest mapping tensor names to (offset, shape). This loader memory-
//! maps... — reads — the blob once and hands out shaped slices to the
//! serving engine.

use crate::error::{FhError, Result};
use std::collections::HashMap;
use std::io::Read;
use std::path::{Path, PathBuf};

/// One tensor's location in the blob.
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    /// Offset in f32 elements.
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl TensorEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn dims_i64(&self) -> Vec<i64> {
        self.shape.iter().map(|&d| d as i64).collect()
    }
}

/// Model/config scalars from meta.txt.
#[derive(Debug, Clone, Default)]
pub struct Meta {
    pub vocab: usize,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    pub ffn: usize,
    pub batch: usize,
    pub seq: usize,
    pub tp: usize,
    pub writeacc_lanes: usize,
    pub param_count: usize,
}

/// The loaded artifact bundle.
pub struct Bundle {
    pub dir: PathBuf,
    pub meta: Meta,
    blob: Vec<f32>,
    index: HashMap<String, TensorEntry>,
    order: Vec<String>,
}

impl Bundle {
    /// Load `manifest.txt`, `params.bin` and `meta.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))?;
        let mut index = HashMap::new();
        let mut order = Vec::new();
        for (lineno, line) in manifest.lines().enumerate() {
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 2 {
                return Err(FhError::Config(format!(
                    "manifest line {}: expected `name offset shape...`",
                    lineno + 1
                )));
            }
            let entry = TensorEntry {
                name: parts[0].to_string(),
                offset: parts[1]
                    .parse()
                    .map_err(|e| FhError::Config(format!("manifest offset: {e}")))?,
                shape: parts[2..]
                    .iter()
                    .map(|s| s.parse::<usize>())
                    .collect::<std::result::Result<_, _>>()
                    .map_err(|e| FhError::Config(format!("manifest shape: {e}")))?,
            };
            order.push(entry.name.clone());
            index.insert(entry.name.clone(), entry);
        }

        let mut raw = Vec::new();
        std::fs::File::open(dir.join("params.bin"))?.read_to_end(&mut raw)?;
        if raw.len() % 4 != 0 {
            return Err(FhError::Config("params.bin length not a multiple of 4".into()));
        }
        let blob: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        let expected: usize = index.values().map(|e| e.numel()).sum();
        if expected != blob.len() {
            return Err(FhError::Config(format!(
                "params.bin has {} elements, manifest expects {expected}",
                blob.len()
            )));
        }

        let meta_s = std::fs::read_to_string(dir.join("meta.txt"))?;
        let kv: HashMap<&str, &str> =
            meta_s.lines().filter_map(|l| l.split_once(' ')).collect();
        let get = |k: &str| -> Result<usize> {
            kv.get(k)
                .ok_or_else(|| FhError::Config(format!("meta.txt missing '{k}'")))?
                .trim()
                .parse()
                .map_err(|e| FhError::Config(format!("meta {k}: {e}")))
        };
        let meta = Meta {
            vocab: get("vocab")?,
            layers: get("layers")?,
            hidden: get("hidden")?,
            heads: get("heads")?,
            ffn: get("ffn")?,
            batch: get("batch")?,
            seq: get("seq")?,
            tp: get("tp")?,
            writeacc_lanes: get("writeacc_lanes")?,
            param_count: get("param_count")?,
        };

        Ok(Bundle { dir: dir.to_path_buf(), meta, blob, index, order })
    }

    /// Default artifact directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn tensor_names(&self) -> &[String] {
        &self.order
    }

    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.index
            .get(name)
            .ok_or_else(|| FhError::Config(format!("unknown tensor '{name}'")))
    }

    /// Raw f32 view of a tensor.
    pub fn tensor(&self, name: &str) -> Result<&[f32]> {
        let e = self.entry(name)?;
        Ok(&self.blob[e.offset..e.offset + e.numel()])
    }

    /// Tensor as a shaped PJRT literal.
    #[cfg(feature = "pjrt")]
    pub fn literal(&self, name: &str) -> Result<xla::Literal> {
        let e = self.entry(name)?;
        super::literal_f32(self.tensor(name)?, &e.dims_i64())
    }

    /// Path of an HLO artifact in this bundle.
    pub fn hlo_path(&self, stem: &str) -> PathBuf {
        self.dir.join(format!("{stem}.hlo.txt"))
    }

    /// Names of a full layer's tensors in the lowering's argument order.
    pub fn layer_tensor_names(layer: usize) -> Vec<String> {
        ["norm1", "norm2", "wq", "wk", "wv", "wo", "wg", "wu", "wd"]
            .iter()
            .map(|k| format!("layers.{layer}.{k}"))
            .collect()
    }

    /// Names of a shard's tensors in the shard HLO's argument order.
    pub fn shard_tensor_names(layer: usize, rank: usize) -> Vec<String> {
        ["norm1", "norm2", "wq", "wk", "wv", "wo", "wg", "wu", "wd"]
            .iter()
            .map(|k| format!("shard.{layer}.r{rank}.{k}"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_and_shard_name_order() {
        let names = Bundle::layer_tensor_names(2);
        assert_eq!(names[0], "layers.2.norm1");
        assert_eq!(names[8], "layers.2.wd");
        let s = Bundle::shard_tensor_names(0, 3);
        assert_eq!(s[2], "shard.0.r3.wq");
    }

    // Loading tests against the real bundle live in rust/tests/.
}
