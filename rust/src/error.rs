//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline build environment
//! has no thiserror crate (DESIGN.md §1).

use std::fmt;

/// Errors produced by the FengHuang library.
#[derive(Debug)]
pub enum FhError {
    /// A configuration file or preset was invalid.
    Config(String),

    /// A shared-memory operation addressed memory outside an allocation.
    OutOfBounds { offset: usize, len: usize, region: usize },

    /// The shared pool has no room for the requested allocation.
    PoolExhausted { requested: usize, free: usize },

    /// A collective was invoked with inconsistent participants.
    Collective(String),

    /// Local memory capacity exceeded and nothing is evictable.
    LocalMemoryThrash { op: String, need_gb: f64, cap_gb: f64 },

    /// A simulation invariant was violated (bug, not user error).
    Invariant(String),

    /// The PJRT runtime failed to load / compile / execute an artifact.
    Runtime(String),

    /// Serving-layer error (queue closed, request rejected, …).
    Serving(String),

    /// An underlying I/O operation failed.
    Io(std::io::Error),
}

impl fmt::Display for FhError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FhError::Config(s) => write!(f, "config error: {s}"),
            FhError::OutOfBounds { offset, len, region } => write!(
                f,
                "shared memory out of bounds: offset {offset} + len {len} > region {region}"
            ),
            FhError::PoolExhausted { requested, free } => write!(
                f,
                "shared memory pool exhausted: requested {requested} B, free {free} B"
            ),
            FhError::Collective(s) => write!(f, "collective error: {s}"),
            FhError::LocalMemoryThrash { op, need_gb, cap_gb } => write!(
                f,
                "local memory thrash: op {op} needs {need_gb:.2} GB but capacity is {cap_gb:.2} GB"
            ),
            FhError::Invariant(s) => write!(f, "simulation invariant violated: {s}"),
            FhError::Runtime(s) => write!(f, "runtime error: {s}"),
            FhError::Serving(s) => write!(f, "serving error: {s}"),
            FhError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for FhError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FhError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for FhError {
    fn from(e: std::io::Error) -> Self {
        FhError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, FhError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_seed_format() {
        let e = FhError::OutOfBounds { offset: 8, len: 4, region: 10 };
        assert_eq!(e.to_string(), "shared memory out of bounds: offset 8 + len 4 > region 10");
        let e = FhError::Config("bad".into());
        assert_eq!(e.to_string(), "config error: bad");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: FhError = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
