//! Library-wide error type.

use thiserror::Error;

/// Errors produced by the FengHuang library.
#[derive(Debug, Error)]
pub enum FhError {
    /// A configuration file or preset was invalid.
    #[error("config error: {0}")]
    Config(String),

    /// A shared-memory operation addressed memory outside an allocation.
    #[error("shared memory out of bounds: offset {offset} + len {len} > region {region}")]
    OutOfBounds { offset: usize, len: usize, region: usize },

    /// The shared pool has no room for the requested allocation.
    #[error("shared memory pool exhausted: requested {requested} B, free {free} B")]
    PoolExhausted { requested: usize, free: usize },

    /// A collective was invoked with inconsistent participants.
    #[error("collective error: {0}")]
    Collective(String),

    /// Local memory capacity exceeded and nothing is evictable.
    #[error("local memory thrash: op {op} needs {need_gb:.2} GB but capacity is {cap_gb:.2} GB")]
    LocalMemoryThrash { op: String, need_gb: f64, cap_gb: f64 },

    /// A simulation invariant was violated (bug, not user error).
    #[error("simulation invariant violated: {0}")]
    Invariant(String),

    /// The PJRT runtime failed to load / compile / execute an artifact.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Serving-layer error (queue closed, request rejected, …).
    #[error("serving error: {0}")]
    Serving(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, FhError>;
