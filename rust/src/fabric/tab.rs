//! Functional model of the Tensor Addressable Bridge (TAB) shared-memory
//! pool (§3.1–§3.3).
//!
//! This is a *working* substrate, not just a cost model: xPU workers hold an
//! `Arc<TabPool>` and exchange real tensor data through it. It implements
//! the paper's memory semantics:
//!
//! * a single shared address space, **striped evenly across memory
//!   modules** ("uniform data layout, evenly striping tensors across all
//!   memory modules to maximize bandwidth utilization", §3.3.1);
//! * plain `read` / `write`;
//! * **write-accumulate** — commutative in-memory reduction performed by
//!   the TAB, requiring no write ordering (§3.3.1);
//! * **write-completion notifications** — counter-based synchronisation
//!   boards that signal when a group of writes has finished (§3.3.1).
//!
//! Elements are `f32`; striping is by fixed-size granules. Each module is
//! independently locked, so concurrent accumulates to different stripes
//! proceed in parallel — the functional analogue of per-module line-rate
//! reduction hardware.

use crate::error::{FhError, Result};
use crate::units::Bytes;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// A contiguous allocation in the shared (global) address space.
/// Offsets and lengths are in `f32` elements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Region {
    pub offset: usize,
    pub len: usize,
}

impl Region {
    pub fn bytes(&self) -> Bytes {
        Bytes::new(self.len as f64 * 4.0)
    }
}

/// Operation counters (observability; used by tests and the metrics API).
#[derive(Debug, Default)]
pub struct TabStats {
    pub reads: AtomicU64,
    pub writes: AtomicU64,
    pub accumulates: AtomicU64,
    pub bytes_read: AtomicU64,
    pub bytes_written: AtomicU64,
    pub bytes_accumulated: AtomicU64,
    pub notifications: AtomicU64,
}

/// Snapshot of [`TabStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TabStatsSnapshot {
    pub reads: u64,
    pub writes: u64,
    pub accumulates: u64,
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub bytes_accumulated: u64,
    pub notifications: u64,
}

struct Allocator {
    /// Free list of (offset, len), sorted by offset, coalesced.
    free: Vec<(usize, usize)>,
}

impl Allocator {
    fn new(capacity: usize) -> Self {
        Allocator { free: vec![(0, capacity)] }
    }

    fn alloc(&mut self, len: usize) -> Option<usize> {
        // First fit.
        let idx = self.free.iter().position(|&(_, flen)| flen >= len)?;
        let (off, flen) = self.free[idx];
        if flen == len {
            self.free.remove(idx);
        } else {
            self.free[idx] = (off + len, flen - len);
        }
        Some(off)
    }

    fn free(&mut self, offset: usize, len: usize) {
        let pos = self.free.partition_point(|&(o, _)| o < offset);
        self.free.insert(pos, (offset, len));
        // Coalesce neighbours.
        if pos + 1 < self.free.len() {
            let (o, l) = self.free[pos];
            let (no, nl) = self.free[pos + 1];
            if o + l == no {
                self.free[pos] = (o, l + nl);
                self.free.remove(pos + 1);
            }
        }
        if pos > 0 {
            let (po, pl) = self.free[pos - 1];
            let (o, l) = self.free[pos];
            if po + pl == o {
                self.free[pos - 1] = (po, pl + l);
                self.free.remove(pos);
            }
        }
    }

    fn free_total(&self) -> usize {
        self.free.iter().map(|&(_, l)| l).sum()
    }
}

/// A notification board: named counters with blocking waits — the
/// "write completion notification" primitive of §3.3.1.
#[derive(Default)]
struct NotifyBoard {
    counts: Mutex<HashMap<String, u64>>,
    cv: Condvar,
}

impl NotifyBoard {
    fn signal(&self, tag: &str, n: u64) {
        let mut counts = self.counts.lock().unwrap();
        *counts.entry(tag.to_string()).or_insert(0) += n;
        self.cv.notify_all();
    }

    fn wait(&self, tag: &str, target: u64) {
        let mut counts = self.counts.lock().unwrap();
        while counts.get(tag).copied().unwrap_or(0) < target {
            counts = self.cv.wait(counts).unwrap();
        }
    }

    fn reset(&self, tag: &str) {
        self.counts.lock().unwrap().remove(tag);
    }
}

/// The shared TAB memory pool.
pub struct TabPool {
    /// Per-module storage. Global element `e` lives in module
    /// `(e / granule) % modules` at local slot
    /// `(e / (granule*modules)) * granule + e % granule`.
    modules: Vec<Mutex<Vec<f32>>>,
    granule: usize,
    capacity: usize,
    allocator: Mutex<Allocator>,
    board: NotifyBoard,
    pub stats: TabStats,
}

impl TabPool {
    /// Create a pool of `capacity` f32 elements striped over `modules`
    /// memory modules at `granule`-element granularity.
    pub fn new(capacity: usize, modules: usize, granule: usize) -> Self {
        assert!(modules > 0 && granule > 0, "degenerate TAB configuration");
        // Round capacity up so it divides evenly across modules.
        let per_module = capacity.div_ceil(modules * granule) * granule;
        let capacity = per_module * modules;
        TabPool {
            modules: (0..modules).map(|_| Mutex::new(vec![0.0; per_module])).collect(),
            granule,
            capacity,
            allocator: Mutex::new(Allocator::new(capacity)),
            board: NotifyBoard::default(),
            stats: TabStats::default(),
        }
    }

    /// Pool matching the paper's FH configuration: `cap_gb` of remote
    /// memory over `modules` modules (elements are f32).
    pub fn with_gb(cap_gb: f64, modules: usize) -> Self {
        let elems = (cap_gb * 1e9 / 4.0) as usize;
        TabPool::new(elems, modules, 1024)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn num_modules(&self) -> usize {
        self.modules.len()
    }

    /// Allocate a region of `len` elements.
    pub fn alloc(&self, len: usize) -> Result<Region> {
        if len == 0 {
            return Ok(Region { offset: 0, len: 0 });
        }
        let mut a = self.allocator.lock().unwrap();
        match a.alloc(len) {
            Some(offset) => Ok(Region { offset, len }),
            None => Err(FhError::PoolExhausted { requested: len * 4, free: a.free_total() * 4 }),
        }
    }

    /// Return a region to the pool.
    pub fn free(&self, region: Region) {
        if region.len == 0 {
            return;
        }
        self.allocator.lock().unwrap().free(region.offset, region.len);
    }

    /// Free elements remaining (for capacity planning / tests).
    pub fn free_elems(&self) -> usize {
        self.allocator.lock().unwrap().free_total()
    }

    #[inline]
    fn locate(&self, global: usize) -> (usize, usize) {
        let g = self.granule;
        let m = self.modules.len();
        let stripe = global / g;
        let module = stripe % m;
        let local = (stripe / m) * g + global % g;
        (module, local)
    }

    fn check(&self, region: Region, offset: usize, len: usize) -> Result<()> {
        if offset + len > region.len || region.offset + region.len > self.capacity {
            return Err(FhError::OutOfBounds {
                offset: region.offset + offset,
                len,
                region: self.capacity,
            });
        }
        Ok(())
    }

    /// Visit the stripe runs of `[region.offset+offset, +len)`, calling
    /// `f(module, local_start, global_start_rel, run_len)` per contiguous
    /// run inside one module.
    fn for_runs(&self, start: usize, len: usize, mut f: impl FnMut(usize, usize, usize, usize)) {
        let g = self.granule;
        let mut done = 0;
        while done < len {
            let global = start + done;
            let within = global % g;
            let run = (g - within).min(len - done);
            let (module, local) = self.locate(global);
            f(module, local, done, run);
            done += run;
        }
    }

    /// Plain write: `data` into `region` at `offset` elements.
    pub fn write(&self, region: Region, offset: usize, data: &[f32]) -> Result<()> {
        self.check(region, offset, data.len())?;
        self.for_runs(region.offset + offset, data.len(), |m, local, rel, run| {
            let mut module = self.modules[m].lock().unwrap();
            module[local..local + run].copy_from_slice(&data[rel..rel + run]);
        });
        self.stats.writes.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_written.fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        Ok(())
    }

    /// Write-accumulate (§3.3.1): `pool[i] += data[i]`. Commutative, so no
    /// ordering is required between concurrent accumulators; per-module
    /// locks make each stripe-run atomic.
    ///
    /// (Perf note: a stripe-rotation scheme to avoid lock convoys was
    /// tried and reverted — the path is DRAM-bandwidth-bound, and the
    /// rotation's locality loss cost ~12%; see EXPERIMENTS.md §Perf.)
    pub fn write_accumulate(&self, region: Region, offset: usize, data: &[f32]) -> Result<()> {
        self.check(region, offset, data.len())?;
        self.for_runs(region.offset + offset, data.len(), |m, local, rel, run| {
            let mut module = self.modules[m].lock().unwrap();
            let dst = &mut module[local..local + run];
            let src = &data[rel..rel + run];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        });
        self.stats.accumulates.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_accumulated.fetch_add(data.len() as u64 * 4, Ordering::Relaxed);
        Ok(())
    }

    /// Read `out.len()` elements from `region` at `offset`.
    pub fn read_into(&self, region: Region, offset: usize, out: &mut [f32]) -> Result<()> {
        self.check(region, offset, out.len())?;
        self.for_runs(region.offset + offset, out.len(), |m, local, rel, run| {
            let module = self.modules[m].lock().unwrap();
            out[rel..rel + run].copy_from_slice(&module[local..local + run]);
        });
        self.stats.reads.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_read.fetch_add(out.len() as u64 * 4, Ordering::Relaxed);
        Ok(())
    }

    pub fn read(&self, region: Region, offset: usize, len: usize) -> Result<Vec<f32>> {
        let mut out = vec![0.0; len];
        self.read_into(region, offset, &mut out)?;
        Ok(out)
    }

    /// Zero a region (used to reset accumulation buffers between rounds).
    pub fn zero(&self, region: Region) -> Result<()> {
        self.check(region, 0, region.len)?;
        self.for_runs(region.offset, region.len, |m, local, _, run| {
            let mut module = self.modules[m].lock().unwrap();
            module[local..local + run].fill(0.0);
        });
        Ok(())
    }

    // --- Write-completion notifications (§3.3.1) ---

    /// Signal `n` completion events on `tag`.
    pub fn notify(&self, tag: &str, n: u64) {
        self.stats.notifications.fetch_add(n, Ordering::Relaxed);
        self.board.signal(tag, n);
    }

    /// Block until `target` completion events have been signalled on `tag`.
    pub fn wait_notifications(&self, tag: &str, target: u64) {
        self.board.wait(tag, target);
    }

    /// Clear a tag's counter (start of a new collective round).
    pub fn reset_notifications(&self, tag: &str) {
        self.board.reset(tag);
    }

    pub fn stats_snapshot(&self) -> TabStatsSnapshot {
        TabStatsSnapshot {
            reads: self.stats.reads.load(Ordering::Relaxed),
            writes: self.stats.writes.load(Ordering::Relaxed),
            accumulates: self.stats.accumulates.load(Ordering::Relaxed),
            bytes_read: self.stats.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.stats.bytes_written.load(Ordering::Relaxed),
            bytes_accumulated: self.stats.bytes_accumulated.load(Ordering::Relaxed),
            notifications: self.stats.notifications.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn write_then_read_roundtrip() {
        let pool = TabPool::new(1 << 16, 4, 256);
        let r = pool.alloc(1000).unwrap();
        let data: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        pool.write(r, 0, &data).unwrap();
        assert_eq!(pool.read(r, 0, 1000).unwrap(), data);
        // Partial read with offset.
        assert_eq!(pool.read(r, 500, 3).unwrap(), vec![500.0, 501.0, 502.0]);
    }

    #[test]
    fn striping_spans_modules() {
        let pool = TabPool::new(4096, 4, 16);
        let r = pool.alloc(64).unwrap();
        // 64 elements at granule 16 touch 4 stripes → all 4 modules.
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        pool.write(r, 0, &data).unwrap();
        assert_eq!(pool.read(r, 0, 64).unwrap(), data);
    }

    #[test]
    fn write_accumulate_sums() {
        let pool = TabPool::new(4096, 2, 8);
        let r = pool.alloc(100).unwrap();
        pool.zero(r).unwrap();
        for _ in 0..4 {
            pool.write_accumulate(r, 0, &vec![1.5f32; 100]).unwrap();
        }
        assert_eq!(pool.read(r, 0, 100).unwrap(), vec![6.0f32; 100]);
    }

    #[test]
    fn concurrent_accumulate_is_correct_regardless_of_order() {
        // §3.3.1: commutative accumulation needs no write ordering.
        let pool = Arc::new(TabPool::new(1 << 18, 8, 64));
        let r = pool.alloc(10_000).unwrap();
        pool.zero(r).unwrap();
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let pool = Arc::clone(&pool);
                std::thread::spawn(move || {
                    let data: Vec<f32> = (0..10_000).map(|i| (t * i % 7) as f32).collect();
                    pool.write_accumulate(r, 0, &data).unwrap();
                })
            })
            .collect();
        for th in threads {
            th.join().unwrap();
        }
        let got = pool.read(r, 0, 10_000).unwrap();
        for i in 0..10_000usize {
            let want: f32 = (0..8).map(|t| (t * i % 7) as f32).sum();
            assert_eq!(got[i], want, "element {i}");
        }
    }

    #[test]
    fn alloc_free_reuse() {
        let pool = TabPool::new(1024, 2, 8);
        let a = pool.alloc(512).unwrap();
        let b = pool.alloc(512).unwrap();
        assert!(pool.alloc(1).is_err(), "pool should be full");
        pool.free(a);
        pool.free(b);
        assert_eq!(pool.free_elems(), 1024);
        // Coalesced: a full-size alloc must succeed again.
        assert!(pool.alloc(1024).is_ok());
    }

    #[test]
    fn out_of_bounds_rejected() {
        let pool = TabPool::new(1024, 2, 8);
        let r = pool.alloc(10).unwrap();
        assert!(pool.write(r, 5, &[0.0; 10]).is_err());
        assert!(pool.read(r, 0, 11).is_err());
    }

    #[test]
    fn notifications_block_until_target() {
        let pool = Arc::new(TabPool::new(1024, 2, 8));
        let p2 = Arc::clone(&pool);
        let waiter = std::thread::spawn(move || {
            p2.wait_notifications("round0", 4);
        });
        for _ in 0..4 {
            pool.notify("round0", 1);
        }
        waiter.join().unwrap();
        assert_eq!(pool.stats_snapshot().notifications, 4);
    }

    #[test]
    fn pool_exhaustion_reports_free_bytes() {
        let pool = TabPool::new(100, 1, 10);
        let got = pool.alloc(1000);
        assert!(
            matches!(got, Err(FhError::PoolExhausted { requested: 4000, free: 400 })),
            "expected PoolExhausted {{ requested: 4000, free: 400 }}, got {got:?}"
        );
    }
}
