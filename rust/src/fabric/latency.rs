//! Table 3.1 latency constants and Eqs 3.1–3.4.
//!
//! The paper gives a fixed latency breakdown for each TAB operation
//! (measured at 2 KB payloads) plus a `data_size / bandwidth` serialization
//! term. NVLink-side constants come from Table 4.2 ("measured in real
//! systems": ~1000 ns read / ~500 ns write).

use crate::units::{Bandwidth, Bytes, Seconds};

/// One row of Table 3.1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyComponent {
    pub label: &'static str,
    pub ns: f64,
}

/// The read path of Table 3.1 (six components, 220 ns total).
pub const READ_COMPONENTS: [LatencyComponent; 6] = [
    LatencyComponent { label: "Read command from GPU to FengHuang", ns: 40.0 },
    LatencyComponent { label: "Read command processing in FengHuang", ns: 10.0 },
    LatencyComponent { label: "Read command from FengHuang to remote HBM", ns: 40.0 },
    LatencyComponent { label: "Remote HBM read time", ns: 50.0 },
    LatencyComponent { label: "Data from remote HBM to FengHuang", ns: 40.0 },
    LatencyComponent { label: "Data from FengHuang to GPU", ns: 40.0 },
];

/// The write path of Table 3.1 (post-write scheme, 90 ns total).
pub const WRITE_COMPONENTS: [LatencyComponent; 3] = [
    LatencyComponent { label: "Write command and data from GPU to FengHuang", ns: 40.0 },
    LatencyComponent { label: "Write command processing in FengHuang", ns: 10.0 },
    LatencyComponent { label: "Write completion notification from FengHuang to GPU", ns: 40.0 },
];

/// Fixed latencies of the TAB fabric (Table 3.1) and the NVLink baseline
/// (Table 4.2 footnote).
#[derive(Debug, Clone, Copy)]
pub struct FabricLatencies {
    pub tab_read: Seconds,
    pub tab_write: Seconds,
    pub tab_write_accumulate: Seconds,
    pub tab_notification: Seconds,
    pub nvlink_read: Seconds,
    pub nvlink_write: Seconds,
}

impl Default for FabricLatencies {
    fn default() -> Self {
        FabricLatencies {
            tab_read: Seconds::ns(220.0),
            tab_write: Seconds::ns(90.0),
            tab_write_accumulate: Seconds::ns(90.0),
            tab_notification: Seconds::ns(40.0),
            nvlink_read: Seconds::ns(1000.0),
            nvlink_write: Seconds::ns(500.0),
        }
    }
}

impl FabricLatencies {
    /// Eq 3.1: `220 ns + data_size / bandwidth`.
    pub fn read_latency(&self, data: Bytes, bw: Bandwidth) -> Seconds {
        self.tab_read + data.over(bw)
    }

    /// Eq 3.2: `90 ns + data_size / bandwidth`.
    pub fn write_latency(&self, data: Bytes, bw: Bandwidth) -> Seconds {
        self.tab_write + data.over(bw)
    }

    /// Eq 3.3: `90 ns + data_size / bandwidth`.
    pub fn write_accumulate_latency(&self, data: Bytes, bw: Bandwidth) -> Seconds {
        self.tab_write_accumulate + data.over(bw)
    }

    /// Eq 3.4: fixed 40 ns.
    pub fn notification_latency(&self) -> Seconds {
        self.tab_notification
    }

    /// Prefill→decode KV handoff cost in a disaggregated cluster
    /// (DESIGN.md §6).
    ///
    /// * `shared_pool = true` (TAB fabric): the KV pages already live in
    ///   the shared pool, so ownership moves by metadata — one write of
    ///   the page table, a completion notification, and the decode side's
    ///   first read (Eqs 3.2 + 3.4 + 3.1 fixed parts). Independent of KV
    ///   size: this is the paper's memory-orchestration advantage applied
    ///   at cluster scope.
    /// * `shared_pool = false` (shared-nothing link): the full KV cache
    ///   serialises over the inter-node link at `link_bw`, bracketed by
    ///   the NVLink-class write/read latencies.
    pub fn kv_handoff(&self, kv: Bytes, link_bw: Bandwidth, shared_pool: bool) -> Seconds {
        if shared_pool {
            self.tab_write + self.tab_notification + self.tab_read
        } else {
            self.nvlink_write + kv.over(link_bw) + self.nvlink_read
        }
    }
}

/// Verify that the component tables sum to the headline totals.
pub fn component_totals() -> (Seconds, Seconds) {
    let read: f64 = READ_COMPONENTS.iter().map(|c| c.ns).sum();
    let write: f64 = WRITE_COMPONENTS.iter().map(|c| c.ns).sum();
    (Seconds::ns(read), Seconds::ns(write))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table31_totals() {
        let (read, write) = component_totals();
        assert_eq!(read, Seconds::ns(220.0));
        assert_eq!(write, Seconds::ns(90.0));
    }

    #[test]
    fn eq31_read_latency_2kb_at_4tbps() {
        let l = FabricLatencies::default();
        let t = l.read_latency(Bytes::kib(2.0), Bandwidth::tbps(4.0));
        // 220 ns + 2048 B / 4 TB/s = 220 + 0.512 ns
        assert!((t.as_ns() - 220.512).abs() < 1e-9, "t={}", t.as_ns());
    }

    #[test]
    fn eq32_33_write_paths_match() {
        let l = FabricLatencies::default();
        let bw = Bandwidth::tbps(4.0);
        assert_eq!(
            l.write_latency(Bytes::mib(1.0), bw),
            l.write_accumulate_latency(Bytes::mib(1.0), bw)
        );
    }

    #[test]
    fn eq34_notification_fixed() {
        let l = FabricLatencies::default();
        assert_eq!(l.notification_latency(), Seconds::ns(40.0));
    }

    #[test]
    fn kv_handoff_shared_pool_is_size_independent() {
        let l = FabricLatencies::default();
        let bw = Bandwidth::tbps(4.8);
        let small = l.kv_handoff(Bytes::mib(1.0), bw, true);
        let big = l.kv_handoff(Bytes::gb(40.0), bw, true);
        assert_eq!(small, big, "TAB handoff is metadata-only");
        assert!((small.as_ns() - (90.0 + 40.0 + 220.0)).abs() < 1e-9);
        // Shared-nothing pays the full serialization: 40 GB at 4.8 TB/s
        // ≈ 8.3 ms, dwarfing the 350 ns TAB path.
        let link = l.kv_handoff(Bytes::gb(40.0), bw, false);
        assert!(link.as_ms() > 8.0, "link handoff {} ms", link.as_ms());
        assert!(link > big * 1000.0);
    }

    #[test]
    fn enabler2_latency_ratios() {
        // §3.3.3 Enabler 2: 1000/220 and 500/90 are both ≈ 5×.
        let l = FabricLatencies::default();
        let read_ratio = l.nvlink_read / l.tab_read;
        let write_ratio = l.nvlink_write / l.tab_write;
        assert!((4.5..5.6).contains(&read_ratio));
        assert!((5.0..6.0).contains(&write_ratio));
    }
}
