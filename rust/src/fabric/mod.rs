//! Interconnect substrate: the FengHuang TAB shared-memory fabric and the
//! shared-nothing NVLink baseline.
//!
//! Two faces:
//! * **Functional** — [`tab::TabPool`] + [`collectives::TabCommunicator`]
//!   move real `f32` data; [`nvlink::RingCommunicator`] is the
//!   message-passing ring baseline. Used by the serving example and the
//!   numerics cross-checks.
//! * **Analytic** — [`latency`] (Table 3.1 / Eqs 3.1–3.4),
//!   [`collectives::tab_collective_time`], [`nvlink::ring_collective_time`]
//!   and [`analysis`] (§3.3.3) feed the discrete-event simulator, and
//!   [`contention`] arbitrates the shared pool as a finite resource
//!   (windowed per-port / per-module bandwidth ledger,
//!   DESIGN.md §Fabric-Contention).

pub mod analysis;
pub mod collectives;
pub mod contention;
pub mod latency;
pub mod nvlink;
pub mod tab;

pub use collectives::{group, Collective, TabCommunicator};
pub use contention::{
    Booking, ContentionConfig, ContentionMode, FabricClock, FabricReport,
};
pub use latency::FabricLatencies;
pub use tab::{Region, TabPool};
