//! The five communication operations on FengHuang shared memory (§3.3.2),
//! implemented *functionally* against [`TabPool`] — real data moves through
//! the striped pool via write / write-accumulate / notification — plus the
//! analytic cost model used by the simulator.
//!
//! Protocols follow the paper exactly:
//!
//! * **AllReduce / ReduceScatter** — every xPU `write_accumulate`s its
//!   contribution into a shared buffer in parallel; the TAB raises a
//!   completion signal once all have landed; consumers then read either the
//!   whole aggregated tensor (AllReduce) or their own shard
//!   (ReduceScatter).
//! * **AllGather / AllToAll** — every xPU writes its chunk(s); completion
//!   notification; consumers read the whole buffer (AllGather) or their
//!   own column (AllToAll).
//! * **P2P Send/Recv** — sender writes to a designated location; the TAB
//!   notifies the receiver; receiver reads.

use super::latency::FabricLatencies;
use super::tab::{Region, TabPool};
use crate::error::{FhError, Result};
use crate::units::{Bandwidth, Bytes, Seconds};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

/// Which collective (for cost queries and trace ops).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Collective {
    AllReduce,
    ReduceScatter,
    AllGather,
    AllToAll,
    P2p,
}

impl std::fmt::Display for Collective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Collective::AllReduce => "allreduce",
            Collective::ReduceScatter => "reducescatter",
            Collective::AllGather => "allgather",
            Collective::AllToAll => "alltoall",
            Collective::P2p => "p2p",
        };
        f.write_str(s)
    }
}

// ---------------------------------------------------------------------------
// Functional group over a TabPool.
// ---------------------------------------------------------------------------

struct Round {
    region: Region,
    /// Ranks that have finished reading (so the last one can free).
    readers_done: usize,
}

struct GroupShared {
    pool: Arc<TabPool>,
    world: usize,
    rounds: Mutex<HashMap<(Collective, u64), Round>>,
    cv: Condvar,
}

impl GroupShared {
    /// First arriver allocates (and zeroes, for accumulating ops); everyone
    /// gets the same region for `(op, round)`.
    fn round_region(&self, op: Collective, round: u64, elems: usize, zero: bool) -> Result<Region> {
        let mut rounds = self.rounds.lock().unwrap();
        if let Some(r) = rounds.get(&(op, round)) {
            return Ok(r.region);
        }
        let region = self.pool.alloc(elems)?;
        if zero {
            self.pool.zero(region)?;
        }
        rounds.insert((op, round), Round { region, readers_done: 0 });
        Ok(region)
    }

    /// Mark this rank done with the round; last one frees the region and
    /// clears the notification tag.
    fn finish_round(&self, op: Collective, round: u64, tag: &str) {
        let mut rounds = self.rounds.lock().unwrap();
        let entry = rounds.get_mut(&(op, round)).expect("finishing unknown round");
        entry.readers_done += 1;
        if entry.readers_done == self.world {
            let r = rounds.remove(&(op, round)).unwrap();
            self.pool.free(r.region);
            self.pool.reset_notifications(tag);
            self.cv.notify_all();
        }
    }
}

/// A per-rank handle to a collective group over the TAB.
pub struct TabCommunicator {
    shared: Arc<GroupShared>,
    rank: usize,
    /// Per-op local round counters (each rank must call collectives in the
    /// same order — standard communicator semantics).
    round: HashMap<Collective, u64>,
}

/// Create `world` communicator handles over `pool`.
pub fn group(pool: Arc<TabPool>, world: usize) -> Vec<TabCommunicator> {
    assert!(world > 0);
    let shared = Arc::new(GroupShared {
        pool,
        world,
        rounds: Mutex::new(HashMap::new()),
        cv: Condvar::new(),
    });
    (0..world)
        .map(|rank| TabCommunicator { shared: Arc::clone(&shared), rank, round: HashMap::new() })
        .collect()
}

impl TabCommunicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.shared.world
    }

    fn next_round(&mut self, op: Collective) -> u64 {
        let c = self.round.entry(op).or_insert(0);
        let r = *c;
        *c += 1;
        r
    }

    /// AllReduce: sum of every rank's `data`, returned to all ranks.
    pub fn all_reduce(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let op = Collective::AllReduce;
        let round = self.next_round(op);
        let tag = format!("ar:{round}");
        let region = self.shared.round_region(op, round, data.len(), true)?;
        // 1–2. write-accumulate own chunk(s) in parallel with other ranks.
        self.shared.pool.write_accumulate(region, 0, data)?;
        // 3. completion signal from the TAB; wait for all participants.
        self.shared.pool.notify(&tag, 1);
        self.shared.pool.wait_notifications(&tag, self.shared.world as u64);
        let out = self.shared.pool.read(region, 0, data.len())?;
        self.shared.finish_round(op, round, &tag);
        Ok(out)
    }

    /// ReduceScatter: sum of every rank's `data`; rank i gets shard i.
    /// `data.len()` must divide evenly by world size.
    pub fn reduce_scatter(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let w = self.shared.world;
        if data.len() % w != 0 {
            return Err(FhError::Collective(format!(
                "reduce_scatter length {} not divisible by world {w}",
                data.len()
            )));
        }
        let op = Collective::ReduceScatter;
        let round = self.next_round(op);
        let tag = format!("rs:{round}");
        let region = self.shared.round_region(op, round, data.len(), true)?;
        self.shared.pool.write_accumulate(region, 0, data)?;
        self.shared.pool.notify(&tag, 1);
        self.shared.pool.wait_notifications(&tag, w as u64);
        let shard = data.len() / w;
        let out = self.shared.pool.read(region, self.rank * shard, shard)?;
        self.shared.finish_round(op, round, &tag);
        Ok(out)
    }

    /// AllGather: concatenation of every rank's `data`, to all ranks.
    pub fn all_gather(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let w = self.shared.world;
        let op = Collective::AllGather;
        let round = self.next_round(op);
        let tag = format!("ag:{round}");
        let region = self.shared.round_region(op, round, data.len() * w, false)?;
        self.shared.pool.write(region, self.rank * data.len(), data)?;
        self.shared.pool.notify(&tag, 1);
        self.shared.pool.wait_notifications(&tag, w as u64);
        let out = self.shared.pool.read(region, 0, data.len() * w)?;
        self.shared.finish_round(op, round, &tag);
        Ok(out)
    }

    /// AllToAll: `data` is `world` equal chunks; chunk j goes to rank j.
    /// Returns the chunks addressed to this rank, ordered by source.
    pub fn all_to_all(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let w = self.shared.world;
        if data.len() % w != 0 {
            return Err(FhError::Collective(format!(
                "all_to_all length {} not divisible by world {w}",
                data.len()
            )));
        }
        let chunk = data.len() / w;
        let op = Collective::AllToAll;
        let round = self.next_round(op);
        let tag = format!("a2a:{round}");
        // Layout: [dst][src] chunks.
        let region = self.shared.round_region(op, round, chunk * w * w, false)?;
        for dst in 0..w {
            let slot = (dst * w + self.rank) * chunk;
            self.shared.pool.write(region, slot, &data[dst * chunk..(dst + 1) * chunk])?;
        }
        self.shared.pool.notify(&tag, 1);
        self.shared.pool.wait_notifications(&tag, w as u64);
        let out = self.shared.pool.read(region, self.rank * w * chunk, w * chunk)?;
        self.shared.finish_round(op, round, &tag);
        Ok(out)
    }

    /// P2P send: write to a designated location, then the TAB notifies the
    /// receiver (§3.3.2). Pairs with [`TabCommunicator::recv`].
    pub fn send(&mut self, dst: usize, seq: u64, data: &[f32]) -> Result<()> {
        let op = Collective::P2p;
        let tag = format!("p2p:{}:{}:{}", self.rank, dst, seq);
        // Key P2P rounds by a hash of (src, dst, seq) so different pairs
        // don't collide.
        let key = (self.rank as u64) << 40 | (dst as u64) << 20 | seq;
        let region = self.shared.round_region(op, key, data.len(), false)?;
        self.shared.pool.write(region, 0, data)?;
        self.shared.pool.notify(&tag, 1);
        // Sender is immediately done; receiver will finish the round.
        self.shared.finish_round(op, key, &tag);
        Ok(())
    }

    /// P2P recv: wait for the completion notification, then read.
    pub fn recv(&mut self, src: usize, seq: u64, len: usize) -> Result<Vec<f32>> {
        let op = Collective::P2p;
        let tag = format!("p2p:{}:{}:{}", src, self.rank, seq);
        let key = (src as u64) << 40 | (self.rank as u64) << 20 | seq;
        let region = self.shared.round_region(op, key, len, false)?;
        self.shared.pool.wait_notifications(&tag, 1);
        let out = self.shared.pool.read(region, 0, len)?;
        self.shared.finish_round(op, key, &tag);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Cost model (used by the DES and the §3.3.3 analysis).
// ---------------------------------------------------------------------------

/// Analytic completion time of a collective on the TAB, per §3.3.2/§3.3.3.
///
/// `payload` is the per-GPU tensor size (the "T" of §3.3.3); `bw` is the
/// per-GPU crossbar bandwidth **per direction** (the crossbar is
/// bidirectional). All GPUs operate in parallel, and the paper's
/// accounting ("Data Transfer (FengHuang) = T") treats the write stream
/// and the read-back stream as pipelined over the two link directions —
/// the bandwidth term is `max(write_bytes, read_bytes) / bw`, while the
/// fixed latencies (write-accumulate + notification + read, Table 3.1)
/// are paid once.
pub fn tab_collective_time(
    op: Collective,
    payload: Bytes,
    world: usize,
    bw: Bandwidth,
    lat: &FabricLatencies,
) -> Seconds {
    let fixed = lat.tab_write_accumulate + lat.notification_latency() + lat.tab_read;
    fixed + tab_wire_bytes(op, payload, world).over(bw)
}

/// Per-GPU bytes that bound the GPU↔TAB link time for a collective —
/// `max(write stream, read stream)` over the full-duplex link (Enabler 1
/// of §3.3.3: in-memory reduction means one transfer of T, not
/// `2(N−1)/N` ring steps).
pub fn tab_wire_bytes(op: Collective, payload: Bytes, world: usize) -> Bytes {
    let _ = world;
    match op {
        // write T (accumulate), read T → max = T.
        Collective::AllReduce => payload,
        // write T, read T/N → max = T.
        Collective::ReduceScatter => payload,
        // write T/N, read T → max = T.
        Collective::AllGather => payload,
        // write own row T, read own column T → max = T.
        Collective::AllToAll => payload,
        Collective::P2p => payload,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_group<F, R>(world: usize, f: F) -> Vec<R>
    where
        F: Fn(&mut TabCommunicator) -> R + Send + Sync + Copy + 'static,
        R: Send + 'static,
    {
        let pool = Arc::new(TabPool::new(1 << 20, 8, 128));
        let comms = group(pool, world);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| thread::spawn(move || f(&mut c)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        let outs = run_group(4, |c| {
            let data: Vec<f32> = (0..256).map(|i| (c.rank() + 1) as f32 * i as f32).collect();
            c.all_reduce(&data).unwrap()
        });
        // Sum over ranks of (r+1)*i = 10*i.
        for out in outs {
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, 10.0 * i as f32);
            }
        }
    }

    #[test]
    fn reduce_scatter_gives_each_rank_its_shard() {
        let outs = run_group(4, |c| {
            let data = vec![1.0f32; 64];
            (c.rank(), c.reduce_scatter(&data).unwrap())
        });
        for (rank, out) in outs {
            assert_eq!(out.len(), 16, "rank {rank} shard size");
            assert!(out.iter().all(|&v| v == 4.0));
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let outs = run_group(3, |c| {
            let data = vec![c.rank() as f32; 8];
            c.all_gather(&data).unwrap()
        });
        for out in outs {
            assert_eq!(out.len(), 24);
            for r in 0..3 {
                assert!(out[r * 8..(r + 1) * 8].iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn all_to_all_transposes_chunks() {
        let outs = run_group(4, |c| {
            // Rank r sends chunk value 10*r + dst.
            let mut data = Vec::new();
            for dst in 0..4 {
                data.extend(vec![(10 * c.rank() + dst) as f32; 4]);
            }
            (c.rank(), c.all_to_all(&data).unwrap())
        });
        for (rank, out) in outs {
            assert_eq!(out.len(), 16);
            for src in 0..4 {
                let expected = (10 * src + rank) as f32;
                assert!(
                    out[src * 4..(src + 1) * 4].iter().all(|&v| v == expected),
                    "rank {rank} from src {src}: {out:?}"
                );
            }
        }
    }

    #[test]
    fn p2p_send_recv() {
        let pool = Arc::new(TabPool::new(1 << 18, 4, 64));
        let mut comms = group(pool, 2);
        let mut receiver = comms.pop().unwrap();
        let mut sender = comms.pop().unwrap();
        let t = thread::spawn(move || receiver.recv(0, 7, 100).unwrap());
        sender.send(1, 7, &vec![3.25f32; 100]).unwrap();
        assert_eq!(t.join().unwrap(), vec![3.25f32; 100]);
    }

    #[test]
    fn repeated_rounds_reuse_pool() {
        // Regions must be freed between rounds — run many rounds on a pool
        // that only fits a few buffers at once.
        let pool = Arc::new(TabPool::new(4096, 2, 64));
        let comms = group(pool.clone(), 2);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    for round in 0..50 {
                        let data = vec![round as f32; 1024];
                        let out = c.all_reduce(&data).unwrap();
                        assert!(out.iter().all(|&v| v == 2.0 * round as f32));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(pool.free_elems(), pool.capacity());
    }

    #[test]
    fn cost_model_allreduce_matches_hand_calc() {
        // Fixed 90+40+220 ns plus one full-duplex-pipelined transfer of T.
        let lat = FabricLatencies::default();
        let t = tab_collective_time(
            Collective::AllReduce,
            Bytes::mib(1.0),
            8,
            Bandwidth::tbps(4.0),
            &lat,
        );
        let xfer = 1024.0 * 1024.0 / 4e12 * 1e9; // ns, one direction
        let expected = 90.0 + 40.0 + 220.0 + xfer;
        assert!((t.as_ns() - expected).abs() < 1e-6, "t={} exp={}", t.as_ns(), expected);
    }

    #[test]
    fn wire_bytes_single_transfer_property() {
        // Enabler 1: per-GPU wire traffic is O(T), independent of N.
        let b8 = tab_wire_bytes(Collective::AllReduce, Bytes::mib(64.0), 8);
        let b64 = tab_wire_bytes(Collective::AllReduce, Bytes::mib(64.0), 64);
        assert_eq!(b8.value(), b64.value());
    }
}
