//! Shared-nothing NVLink baseline: ring-algorithm collectives.
//!
//! The paper's Baseline8 exchanges data over NVLink 4.0 (450 GB/s per
//! direction per GPU) using ring collectives (§3.3.3 footnote: "the NVLink
//! baseline uses ring-allreduce algorithm"). This module provides
//!
//! * the analytic **cost model** — `2(N−1)` steps of `T/N` for AllReduce,
//!   with the measured fixed latencies of Table 4.2 (~1000 ns read /
//!   ~500 ns write) per step — and
//! * a **functional** message-passing ring over std channels, used to
//!   cross-check that TAB collectives and ring collectives compute the
//!   same numbers.

use super::collectives::Collective;
use super::latency::FabricLatencies;
use crate::units::{Bandwidth, Bytes, Seconds};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread;

/// Analytic completion time of a ring collective over NVLink.
///
/// `payload` is the logical tensor size T per GPU; `bw` is the
/// per-direction per-GPU link bandwidth (450 GB/s for NVLink 4.0).
pub fn ring_collective_time(
    op: Collective,
    payload: Bytes,
    world: usize,
    bw: Bandwidth,
    lat: &FabricLatencies,
) -> Seconds {
    let n = world as f64;
    let step_lat = lat.nvlink_write; // each ring step is a neighbour send
    match op {
        Collective::AllReduce => {
            // 2(N−1) steps, each moving T/N.
            let steps = 2.0 * (n - 1.0);
            (payload / n).over(bw) * steps + step_lat * steps
        }
        Collective::ReduceScatter | Collective::AllGather => {
            let steps = n - 1.0;
            (payload / n).over(bw) * steps + step_lat * steps
        }
        Collective::AllToAll => {
            // Each GPU serialises (N−1) distinct chunks of T/N onto its link.
            let steps = n - 1.0;
            (payload / n).over(bw) * steps + step_lat * steps
        }
        Collective::P2p => payload.over(bw) + lat.nvlink_read,
    }
}

/// Per-GPU bytes crossing NVLink for a collective (Enabler 1 numerator:
/// `2(N−1)·T/N` for AllReduce).
pub fn ring_wire_bytes(op: Collective, payload: Bytes, world: usize) -> Bytes {
    let n = world as f64;
    match op {
        Collective::AllReduce => payload * (2.0 * (n - 1.0) / n),
        Collective::ReduceScatter | Collective::AllGather | Collective::AllToAll => {
            payload * ((n - 1.0) / n)
        }
        Collective::P2p => payload,
    }
}

// ---------------------------------------------------------------------------
// Functional ring (baseline comparator for numerics).
// ---------------------------------------------------------------------------

/// A per-rank handle for a functional ring group.
pub struct RingCommunicator {
    rank: usize,
    world: usize,
    to_next: Sender<Vec<f32>>,
    from_prev: Receiver<Vec<f32>>,
}

/// Build a ring of `world` communicators connected by channels.
pub fn ring_group(world: usize) -> Vec<RingCommunicator> {
    assert!(world > 0);
    let mut senders = Vec::with_capacity(world);
    let mut receivers = Vec::with_capacity(world);
    for _ in 0..world {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    // Rank r sends to rank (r+1) % world; receives from (r−1+world) % world.
    // receivers[i] receives what was sent on senders[i]; give rank r the
    // receiver paired with its predecessor's sender.
    let mut out = Vec::with_capacity(world);
    let mut receivers: Vec<Option<Receiver<Vec<f32>>>> =
        receivers.into_iter().map(Some).collect();
    for rank in 0..world {
        let to_next = senders[(rank + 1) % world].clone();
        let from_prev = receivers[rank].take().unwrap();
        out.push(RingCommunicator { rank, world, to_next, from_prev });
    }
    out
}

impl RingCommunicator {
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Classic ring AllReduce: N−1 reduce-scatter steps followed by N−1
    /// all-gather steps over `world` chunks.
    pub fn all_reduce(&self, data: &[f32]) -> Vec<f32> {
        let w = self.world;
        if w == 1 {
            return data.to_vec();
        }
        let chunk = data.len().div_ceil(w);
        let mut buf = data.to_vec();
        buf.resize(chunk * w, 0.0); // pad
        // Phase 1: reduce-scatter. At step s, send chunk (rank − s) and
        // accumulate into chunk (rank − s − 1).
        for s in 0..w - 1 {
            let send_idx = (self.rank + w - s) % w;
            let recv_idx = (self.rank + w - s - 1) % w;
            self.to_next.send(buf[send_idx * chunk..(send_idx + 1) * chunk].to_vec()).unwrap();
            let incoming = self.from_prev.recv().unwrap();
            for (d, v) in buf[recv_idx * chunk..(recv_idx + 1) * chunk]
                .iter_mut()
                .zip(incoming)
            {
                *d += v;
            }
        }
        // Phase 2: all-gather the reduced chunks around the ring.
        for s in 0..w - 1 {
            let send_idx = (self.rank + 1 + w - s) % w;
            let recv_idx = (self.rank + w - s) % w;
            self.to_next.send(buf[send_idx * chunk..(send_idx + 1) * chunk].to_vec()).unwrap();
            let incoming = self.from_prev.recv().unwrap();
            buf[recv_idx * chunk..(recv_idx + 1) * chunk].copy_from_slice(&incoming);
        }
        buf.truncate(data.len());
        buf
    }

    /// Ring AllGather: N−1 forwarding steps.
    pub fn all_gather(&self, data: &[f32]) -> Vec<f32> {
        let w = self.world;
        let len = data.len();
        let mut out = vec![0.0; len * w];
        out[self.rank * len..(self.rank + 1) * len].copy_from_slice(data);
        let mut current = (self.rank, data.to_vec());
        for _ in 0..w - 1 {
            self.to_next.send(current.1.clone()).unwrap();
            let incoming = self.from_prev.recv().unwrap();
            let src = (current.0 + w - 1) % w;
            out[src * len..(src + 1) * len].copy_from_slice(&incoming);
            current = (src, incoming);
        }
        out
    }
}

/// Run a closure on every rank of a fresh ring group (test helper).
pub fn run_ring<R: Send + 'static>(
    world: usize,
    f: impl Fn(&RingCommunicator) -> R + Send + Sync + Copy + 'static,
) -> Vec<R> {
    let handles: Vec<_> = ring_group(world)
        .into_iter()
        .map(|c| thread::spawn(move || f(&c)))
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::collectives::{group, tab_collective_time};
    use crate::fabric::tab::TabPool;
    use std::sync::Arc;

    #[test]
    fn ring_allreduce_sums() {
        let outs = run_ring(4, |c| {
            let data: Vec<f32> = (0..37).map(|i| (c.rank() * 100 + i) as f32).collect();
            c.all_reduce(&data)
        });
        for out in outs {
            for (i, v) in out.iter().enumerate() {
                let want: f32 = (0..4).map(|r| (r * 100 + i) as f32).sum();
                assert_eq!(*v, want, "element {i}");
            }
        }
    }

    #[test]
    fn ring_allgather_orders_by_rank() {
        let outs = run_ring(5, |c| c.all_gather(&[c.rank() as f32; 3]));
        for out in outs {
            for r in 0..5 {
                assert!(out[r * 3..(r + 1) * 3].iter().all(|&v| v == r as f32));
            }
        }
    }

    #[test]
    fn ring_and_tab_allreduce_agree_numerically() {
        // The two fabrics must compute identical reductions — this is the
        // "baseline comparator implemented too" check.
        let world = 4;
        let len = 513; // deliberately not divisible by world
        let ring_out = run_ring(world, move |c| {
            let data: Vec<f32> = (0..len).map(|i| ((c.rank() + 1) * (i + 1)) as f32).collect();
            c.all_reduce(&data)
        });
        let pool = Arc::new(TabPool::new(1 << 16, 4, 64));
        let tab_out: Vec<Vec<f32>> = {
            let comms = group(pool, world);
            let hs: Vec<_> = comms
                .into_iter()
                .map(|mut c| {
                    thread::spawn(move || {
                        let data: Vec<f32> =
                            (0..len).map(|i| ((c.rank() + 1) * (i + 1)) as f32).collect();
                        c.all_reduce(&data).unwrap()
                    })
                })
                .collect();
            hs.into_iter().map(|h| h.join().unwrap()).collect()
        };
        assert_eq!(ring_out[0], tab_out[0]);
        assert_eq!(ring_out[3], tab_out[3]);
    }

    #[test]
    fn cost_model_allreduce_2n_minus_1_steps() {
        let lat = FabricLatencies::default();
        let t = ring_collective_time(
            Collective::AllReduce,
            Bytes::mib(8.0),
            8,
            Bandwidth::gbps(450.0),
            &lat,
        );
        let step_ns = 8.0 * 1024.0 * 1024.0 / 8.0 / 450e9 * 1e9 + 500.0;
        let expected = 14.0 * step_ns;
        assert!((t.as_ns() - expected).abs() < 1.0, "t={} exp={}", t.as_ns(), expected);
    }

    #[test]
    fn tab_beats_ring_at_all_sizes_for_n8() {
        let lat = FabricLatencies::default();
        for kb in [2.0, 32.0, 1024.0, 65536.0, 1048576.0] {
            let payload = Bytes::kib(kb);
            let ring = ring_collective_time(
                Collective::AllReduce,
                payload,
                8,
                Bandwidth::gbps(450.0),
                &lat,
            );
            let tab = tab_collective_time(
                Collective::AllReduce,
                payload,
                8,
                Bandwidth::tbps(4.0),
                &lat,
            );
            assert!(tab < ring, "TAB must win at {kb} KiB: {tab} vs {ring}");
        }
    }

    #[test]
    fn wire_bytes_match_paper_formulas() {
        let t = Bytes::mib(64.0);
        let ar = ring_wire_bytes(Collective::AllReduce, t, 8);
        assert!((ar.value() - t.value() * 14.0 / 8.0).abs() < 1e-6);
    }
}
