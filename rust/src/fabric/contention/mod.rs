//! Contention-aware shared-fabric arbitration (DESIGN.md §Fabric-Contention).
//!
//! Every fabric charge elsewhere in the simulator — KV handoffs, page
//! migrations, prefix-cache fetches, NMC gathers — historically paid the
//! *unloaded* Table 3.1 latency: N replicas hammering the shared TAB pool
//! cost the same as one. That is exactly the assumption the paper's
//! headline claims (16x–70x faster inter-GPU communication, 50% GPU
//! reduction at equal SLO) lean on, and exactly what a simulator must be
//! able to falsify. This module models the TAB as a *finite, arbitrated*
//! resource:
//!
//! * a [`FabricClock`] books every transfer (bytes, source port, target
//!   module) into discrete time windows against per-port and per-module
//!   bandwidth budgets derived from the node config (port bandwidth =
//!   `SystemConfig::fabric_bw`, pool aggregate = `fabric_bw × num_gpus`
//!   — the crossbar serves one node's worth of ports at line rate, and a
//!   rack sharing the pool shares that aggregate);
//! * the booking returns a congestion-adjusted completion time: queueing
//!   delay (windows where the budgets were exhausted by earlier traffic)
//!   plus serialization at the message-size-efficient bandwidth
//!   ([`crate::models::mfu::link_eff`], Eq 4.1);
//! * [`ContentionMode::Off`] is a strict passthrough — consumers keep
//!   their existing unloaded arithmetic bit-identically (the golden
//!   tests pin this), so contention is a falsifiable overlay, not a
//!   silent recost.
//!
//! Two arbitration granularities:
//!
//! * [`ContentionMode::Shared`] — one aggregate pool budget (the
//!   crossbar as a single shared pipe);
//! * [`ContentionMode::PerModule`] — the pool budget splits evenly over
//!   the memory modules. With `module_interleave` (the paper's §3.3.1
//!   uniform striping) every transfer spreads over all modules and the
//!   per-module ledgers stay exactly balanced; without it, transfers
//!   hash whole to a home module and hot sessions produce hotspots —
//!   the per-module byte imbalance the fleet report surfaces.

mod clock;

pub use clock::{Booking, FabricClock};

use crate::error::{FhError, Result};
use crate::units::{Bytes, Seconds};

/// Canonical module count of the modelled TAB pool (the functional
/// [`crate::fabric::TabPool`] benches and tests stripe over 8 modules).
pub const DEFAULT_TAB_MODULES: usize = 8;

/// Default accounting window of the bandwidth ledger.
pub const DEFAULT_WINDOW: Seconds = Seconds(100.0e-6);

/// Arbitration granularity of the shared fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ContentionMode {
    /// No arbitration: every consumer keeps its unloaded charge
    /// bit-identically (the pre-contention simulator).
    #[default]
    Off,
    /// One aggregate pool bandwidth budget shared by all ports.
    Shared,
    /// The pool budget splits evenly across the memory modules.
    PerModule,
}

impl ContentionMode {
    /// Parse a CLI mode name. A bare `--fabric-contention` switch reads
    /// as `on`, which means [`ContentionMode::Shared`].
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(ContentionMode::Off),
            "on" | "shared" => Some(ContentionMode::Shared),
            "per-module" | "per_module" | "permodule" => Some(ContentionMode::PerModule),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ContentionMode::Off => "off",
            ContentionMode::Shared => "shared",
            ContentionMode::PerModule => "per-module",
        }
    }
}

/// Knobs of the arbitration model
/// ([`crate::coordinator::ClusterConfig::contention`],
/// [`crate::paging::PagingConfig::contention`]).
#[derive(Debug, Clone, Copy)]
pub struct ContentionConfig {
    pub mode: ContentionMode,
    /// Fabric ports contending for the pool. `0` derives from context:
    /// the cluster uses its replica count, the single-node paging path
    /// uses 1.
    pub ports: usize,
    /// Memory modules behind the pool ([`ContentionMode::PerModule`]
    /// granularity).
    pub modules: usize,
    /// Ledger window: bandwidth budgets are granted per window, so the
    /// window sets the arbitration timescale (queueing is resolved at
    /// window granularity).
    pub window: Seconds,
    /// Stripe each transfer evenly over all modules (the paper's §3.3.1
    /// uniform layout). `false` hashes whole transfers to a home module,
    /// exposing hotspots.
    pub module_interleave: bool,
}

impl Default for ContentionConfig {
    fn default() -> Self {
        ContentionConfig {
            mode: ContentionMode::Off,
            ports: 0,
            modules: DEFAULT_TAB_MODULES,
            window: DEFAULT_WINDOW,
            module_interleave: true,
        }
    }
}

impl ContentionConfig {
    /// Fill the derive-from-context default for `ports`.
    pub fn resolved(mut self, default_ports: usize) -> Self {
        if self.ports == 0 {
            self.ports = default_ports;
        }
        self
    }

    /// Validate the knobs (a disabled config is always valid).
    pub fn validate(&self) -> Result<()> {
        if self.mode == ContentionMode::Off {
            return Ok(());
        }
        if self.ports == 0 {
            return Err(FhError::Config(
                "fabric contention needs ≥ 1 port (resolve `ports` before building the clock)"
                    .into(),
            ));
        }
        if self.modules == 0 {
            return Err(FhError::Config("fabric contention needs ≥ 1 module".into()));
        }
        if self.window.value() <= 0.0 {
            return Err(FhError::Config(format!(
                "fabric contention window must be positive, got {}s",
                self.window.value()
            )));
        }
        Ok(())
    }
}

/// Fleet-level observables of the arbitration ledger
/// ([`crate::coordinator::ClusterReport::fabric`],
/// [`crate::paging::PagedReport::fabric`]).
#[derive(Debug, Clone)]
pub struct FabricReport {
    pub mode: ContentionMode,
    pub ports: usize,
    pub modules: usize,
    pub window: Seconds,
    /// Transfers booked through the ledger.
    pub transfers: u64,
    /// Total bytes booked.
    pub bytes: Bytes,
    /// Wire time the booked bytes demand of the pool aggregate.
    pub busy: Seconds,
    /// Latest booked completion (the ledger's horizon).
    pub horizon: Seconds,
    /// Fabric busy fraction: `busy / horizon` (0 when nothing booked).
    pub busy_frac: f64,
    /// Queueing-delay distribution over all bookings.
    pub queue_mean: Seconds,
    pub queue_p50: Seconds,
    pub queue_p95: Seconds,
    pub queue_p99: Seconds,
    pub queue_max: Seconds,
    /// Total queueing delay across all bookings.
    pub queue_total: Seconds,
    /// Total intrinsic serialization across all bookings (Eq 4.1 wire
    /// time, capped at the home module's bandwidth for hashed
    /// transfers — see [`Booking::serialization`]).
    pub serialization: Seconds,
    /// Cumulative bytes landed on each module.
    pub module_bytes: Vec<Bytes>,
    /// Max/mean of `module_bytes` (1.0 when balanced or empty).
    pub module_imbalance: f64,
    /// Module holding the most bytes.
    pub hotspot_module: usize,
}

impl FabricReport {
    /// One summary line for the cluster/paging reports.
    pub fn summary_line(&self) -> String {
        format!(
            "fabric contention ({}, {} ports, {} modules): busy {:.1}% of {:.3}s | \
             queue p50 {:.3} p95 {:.3} p99 {:.3} ms (total {:.3} ms / {} transfers) | \
             module imbalance {:.3} (hotspot m{}) | {:.2} GB booked\n",
            self.mode.name(),
            self.ports,
            self.modules,
            100.0 * self.busy_frac,
            self.horizon.value(),
            self.queue_p50.as_ms(),
            self.queue_p95.as_ms(),
            self.queue_p99.as_ms(),
            self.queue_total.as_ms(),
            self.transfers,
            self.module_imbalance,
            self.hotspot_module,
            self.bytes.as_gb(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parses_cli_names() {
        assert_eq!(ContentionMode::parse("off"), Some(ContentionMode::Off));
        assert_eq!(ContentionMode::parse("on"), Some(ContentionMode::Shared));
        assert_eq!(ContentionMode::parse("shared"), Some(ContentionMode::Shared));
        assert_eq!(ContentionMode::parse("Per-Module"), Some(ContentionMode::PerModule));
        assert_eq!(ContentionMode::parse("per_module"), Some(ContentionMode::PerModule));
        assert_eq!(ContentionMode::parse("sideways"), None);
        assert_eq!(ContentionMode::Shared.name(), "shared");
        assert_eq!(ContentionMode::default(), ContentionMode::Off);
    }

    #[test]
    fn config_resolves_ports_from_context() {
        let cfg = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() };
        assert_eq!(cfg.ports, 0);
        assert_eq!(cfg.resolved(6).ports, 6);
        // An explicit port count wins over the context default.
        let explicit = ContentionConfig { ports: 3, ..cfg };
        assert_eq!(explicit.resolved(6).ports, 3);
    }

    #[test]
    fn config_validation_rejects_degenerate_knobs() {
        let ok = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() }
            .resolved(4);
        ok.validate().unwrap();
        // Off is valid whatever the other knobs say (it is inert).
        ContentionConfig::default().validate().unwrap();
        let bad = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() };
        assert!(bad.validate().is_err(), "unresolved ports must not pass");
        let bad = ContentionConfig { modules: 0, ..ok };
        assert!(bad.validate().is_err());
        let bad = ContentionConfig { window: Seconds::ZERO, ..ok };
        assert!(bad.validate().is_err());
        let bad = ContentionConfig { window: Seconds::new(-1.0), ..ok };
        assert!(bad.validate().is_err());
    }
}
