//! The bandwidth ledger behind the contention model
//! (DESIGN.md §Fabric-Contention).
//!
//! Time is cut into fixed windows. Each window grants every port a byte
//! budget of `port_bw × window` and every module bucket a budget of
//! `(pool_bw / buckets) × window` (one aggregate bucket in
//! [`ContentionMode::Shared`]). A booking drains its bytes window by
//! window at the message's Eq 4.1 effective bandwidth, never taking more
//! than the residual budgets earlier bookings left behind; windows where
//! nothing can move are pure queueing delay. The walk is greedy and
//! order-deterministic: the same booking sequence always produces the
//! same ledger, which is what lets the golden tests pin contended runs.

use super::{ContentionConfig, ContentionMode, FabricReport};
use crate::config::SystemConfig;
use crate::error::{FhError, Result};
use crate::models::mfu;
use crate::traffic::rng::splitmix64;
use crate::units::{Bandwidth, Bytes, Seconds};
use std::collections::BTreeMap;

/// Bytes below this never enter the ledger (sub-microbyte fp dust).
const BYTE_EPS: f64 = 1e-6;

/// Result of booking one transfer.
#[derive(Debug, Clone, Copy)]
pub struct Booking {
    /// When the last byte lands (start + serialization + queueing).
    pub completion: Seconds,
    /// Intrinsic wire time of this message on an *empty* fabric: the
    /// Eq 4.1 effective bandwidth ([`mfu::transfer_time`] at the port
    /// bandwidth), further capped by the home module's bandwidth when
    /// the transfer hashes whole to one module (a hotspotted message
    /// cannot exceed its module's line rate even with no competition —
    /// that excess is serialization at the narrow end, not queueing).
    /// Identical to the unloaded `mfu::transfer_time` in Off, Shared
    /// and interleaved modes.
    pub serialization: Seconds,
    /// Delay attributable purely to arbitration — residual budgets
    /// exhausted by *other* traffic. Zero on an empty fabric in every
    /// mode.
    pub queueing: Seconds,
}

/// Per-window residual ledger.
struct Window {
    /// Bytes each port has booked into this window.
    ports: Vec<f64>,
    /// Bytes each module bucket has absorbed in this window.
    buckets: Vec<f64>,
}

/// The shared-fabric arbitration clock: books transfers against windowed
/// per-port / per-module bandwidth budgets and returns congestion-adjusted
/// completion times.
pub struct FabricClock {
    cfg: ContentionConfig,
    /// Per-port bandwidth (B/s): the unloaded `SystemConfig::fabric_bw`.
    port_bw: f64,
    /// Pool aggregate bandwidth (B/s): `fabric_bw × num_gpus` — the
    /// crossbar serves one node's worth of ports at line rate; a fleet
    /// sharing the pool shares this aggregate.
    pool_bw: f64,
    /// Module buckets (1 in Shared mode, `modules` in PerModule mode).
    nbuckets: usize,
    /// Bandwidth of one bucket (B/s).
    bucket_bw: f64,
    windows: BTreeMap<u64, Window>,
    // --- lifetime stats ---
    port_total: Vec<f64>,
    module_total: Vec<f64>,
    transfers: u64,
    bytes_total: f64,
    ser_total: f64,
    queue_total: f64,
    /// Queueing delay of every booking, seconds (percentiles).
    queue_samples: Vec<f64>,
    horizon: f64,
    /// Link-degradation intervals `(start_s, end_s, factor)` from the
    /// fault layer (DESIGN.md §Faults): windows whose start falls inside
    /// an interval shrink their port and bucket budgets by `factor`.
    /// Registered up-front from the static fault timeline, so bookings
    /// replay identically in both cluster cores; empty on healthy runs —
    /// the budget arithmetic is untouched then (bit-identity).
    degrades: Vec<(f64, f64, f64)>,
}

impl FabricClock {
    /// Build the clock over `sys`'s fabric. `cfg.ports` must already be
    /// resolved ([`ContentionConfig::resolved`]). Active modes require a
    /// FengHuang (TAB) node — shared-nothing fabrics have no shared pool
    /// to arbitrate.
    pub fn for_system(sys: &SystemConfig, cfg: ContentionConfig) -> Result<Self> {
        cfg.validate()?;
        if cfg.mode != ContentionMode::Off && !sys.is_fenghuang() {
            return Err(FhError::Config(
                "fabric contention models the shared TAB pool — shared-nothing \
                 fabrics have no shared fabric to arbitrate (pick a TAB system \
                 or turn contention off)"
                    .into(),
            ));
        }
        if sys.fabric_bw.value() <= 0.0 {
            return Err(FhError::Config(
                "fabric contention needs a positive fabric bandwidth".into(),
            ));
        }
        let ports = cfg.ports.max(1);
        let nbuckets = match cfg.mode {
            ContentionMode::PerModule => cfg.modules.max(1),
            _ => 1,
        };
        let pool_bw = sys.fabric_bw.value() * sys.num_gpus.max(1) as f64;
        Ok(FabricClock {
            cfg,
            port_bw: sys.fabric_bw.value(),
            pool_bw,
            nbuckets,
            bucket_bw: pool_bw / nbuckets as f64,
            windows: BTreeMap::new(),
            port_total: vec![0.0; ports],
            module_total: vec![0.0; nbuckets],
            transfers: 0,
            bytes_total: 0.0,
            ser_total: 0.0,
            queue_total: 0.0,
            queue_samples: Vec::new(),
            horizon: 0.0,
            degrades: Vec::new(),
        })
    }

    pub fn mode(&self) -> ContentionMode {
        self.cfg.mode
    }

    /// Register a link-degradation interval: bandwidth budgets shrink by
    /// `factor` (∈ (0, 1]) for windows starting in `[start, end)`.
    /// Overlapping intervals compound to the tightest factor.
    pub fn degrade(&mut self, start: Seconds, end: Seconds, factor: f64) {
        debug_assert!(factor > 0.0 && factor <= 1.0, "degrade factor out of range");
        self.degrades.push((start.value(), end.value(), factor));
    }

    /// Tightest degrade factor covering a window starting at `wstart`
    /// (1.0 when none applies). Only called when `degrades` is non-empty.
    fn degrade_factor(&self, wstart: f64) -> f64 {
        self.degrades
            .iter()
            .filter(|&&(s, e, _)| wstart >= s && wstart < e)
            .fold(1.0, |acc, &(_, _, f)| acc.min(f))
    }

    /// Home bucket for a hashed (non-interleaved) transfer, `None` when
    /// the transfer stripes over all buckets.
    fn home(&self, key: u64) -> Option<usize> {
        if self.cfg.mode == ContentionMode::PerModule && !self.cfg.module_interleave {
            Some((splitmix64(key) % self.nbuckets as u64) as usize)
        } else {
            None
        }
    }

    /// Book a transfer of `bytes` issued by `port` at virtual time
    /// `start`. `key` is a stable identity (session/tensor id) used only
    /// to pick the home module when interleaving is off. Off mode (and
    /// empty transfers) pass through: completion is `start` plus the
    /// unloaded Eq 4.1 serialization, nothing is recorded.
    pub fn book(&mut self, start: Seconds, bytes: Bytes, port: usize, key: u64) -> Booking {
        let ser = mfu::transfer_time(bytes, Bandwidth(self.port_bw));
        if self.cfg.mode == ContentionMode::Off || bytes.value() <= BYTE_EPS {
            return Booking {
                completion: start + ser,
                serialization: ser,
                queueing: Seconds::ZERO,
            };
        }
        let port = port % self.port_total.len();
        // Effective drain rate of this message (Eq 4.1 shaping folded
        // in). A transfer hashed whole to one module additionally drains
        // at most at that module's bandwidth, *even on an empty fabric*
        // — that cap is intrinsic serialization at the narrow end, not
        // queueing, so it folds into `ser` and the drain rate alike
        // (keeping `completion = start + ser + queueing` exact and
        // `queueing` purely arbitration).
        let eff_bw = bytes.value() / ser.value();
        let home = self.home(key);
        let drain_bw = match home {
            Some(_) => eff_bw.min(self.bucket_bw),
            None => eff_bw,
        };
        let ser = match home {
            Some(_) => Seconds(bytes.value() / drain_bw),
            None => ser,
        };
        let start_s = start.value().max(0.0);
        let win_len = self.cfg.window.value();
        let port_budget_full = self.port_bw * win_len;
        let bucket_budget_full = self.bucket_bw * win_len;
        let mut remaining = bytes.value();
        let mut w = (start_s / win_len) as u64;
        let completion_s;
        loop {
            let wstart = w as f64 * win_len;
            // Degraded links shrink this window's budgets. Healthy runs
            // skip the scaling entirely — same multiplications, same
            // bits as before the fault layer existed.
            let (port_budget, bucket_budget) = if self.degrades.is_empty() {
                (port_budget_full, bucket_budget_full)
            } else {
                let f = self.degrade_factor(wstart);
                (port_budget_full * f, bucket_budget_full * f)
            };
            let t_in = start_s.max(wstart);
            let avail = wstart + win_len - t_in;
            if avail > 0.0 {
                let nports = self.port_total.len();
                let nbuckets = self.nbuckets;
                let win = self.windows.entry(w).or_insert_with(|| Window {
                    ports: vec![0.0; nports],
                    buckets: vec![0.0; nbuckets],
                });
                let port_res = (port_budget - win.ports[port]).max(0.0);
                let bucket_res = match home {
                    Some(m) => (bucket_budget - win.buckets[m]).max(0.0),
                    None => {
                        // Striped: the transfer drains through all buckets
                        // in lockstep, so the tightest bucket gates it.
                        let min_res = win
                            .buckets
                            .iter()
                            .map(|&b| (bucket_budget - b).max(0.0))
                            .fold(f64::INFINITY, f64::min);
                        min_res * nbuckets as f64
                    }
                };
                let take = remaining.min(drain_bw * avail).min(port_res).min(bucket_res);
                if take > BYTE_EPS {
                    win.ports[port] += take;
                    match home {
                        Some(m) => {
                            win.buckets[m] += take;
                            self.module_total[m] += take;
                        }
                        None => {
                            let per = take / nbuckets as f64;
                            for (b, t) in
                                win.buckets.iter_mut().zip(self.module_total.iter_mut())
                            {
                                *b += per;
                                *t += per;
                            }
                        }
                    }
                    self.port_total[port] += take;
                    if remaining - take <= BYTE_EPS {
                        // Final window: the residue drains at the message
                        // rate from the window entry point.
                        completion_s = t_in + remaining / drain_bw;
                        break;
                    }
                    remaining -= take;
                }
            }
            w += 1;
        }
        let completion = Seconds(completion_s);
        let queueing = Seconds((completion_s - start_s - ser.value()).max(0.0));
        self.transfers += 1;
        self.bytes_total += bytes.value();
        self.ser_total += ser.value();
        self.queue_total += queueing.value();
        self.queue_samples.push(queueing.value());
        self.horizon = self.horizon.max(completion_s);
        Booking { completion, serialization: ser, queueing }
    }

    // --- observability (ledger conservation is pinned by
    // rust/tests/fabric_props.rs) ---

    /// Total bytes ever booked.
    pub fn booked_bytes(&self) -> Bytes {
        Bytes(self.bytes_total)
    }

    /// Cumulative pool busy time implied by the booked bytes — the
    /// `busy` field of [`Self::report`] without the percentile sort,
    /// cheap enough for the telemetry sampler to read every tick.
    pub fn busy_time(&self) -> Seconds {
        Seconds(if self.pool_bw > 0.0 { self.bytes_total / self.pool_bw } else { 0.0 })
    }

    pub fn transfers(&self) -> u64 {
        self.transfers
    }

    /// Cumulative bytes per port.
    pub fn port_bytes(&self) -> Vec<Bytes> {
        self.port_total.iter().map(|&b| Bytes(b)).collect()
    }

    /// Cumulative bytes per module bucket.
    pub fn module_bytes(&self) -> Vec<Bytes> {
        self.module_total.iter().map(|&b| Bytes(b)).collect()
    }

    /// Per-window totals (window index, bytes booked in it) — the
    /// conservation ledger: these sum to [`Self::booked_bytes`].
    pub fn window_bytes(&self) -> Vec<(u64, Bytes)> {
        self.windows
            .iter()
            .map(|(&w, win)| (w, Bytes(win.ports.iter().sum())))
            .collect()
    }

    /// Snapshot the fleet-level observables.
    pub fn report(&self) -> FabricReport {
        let mut sorted = self.queue_samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let busy = if self.pool_bw > 0.0 { self.bytes_total / self.pool_bw } else { 0.0 };
        let (hotspot, max_b) = self
            .module_total
            .iter()
            .enumerate()
            .fold((0usize, 0.0f64), |(hi, hb), (i, &b)| if b > hb { (i, b) } else { (hi, hb) });
        let mean_b = self.module_total.iter().sum::<f64>() / self.module_total.len() as f64;
        let imbalance = if mean_b > 0.0 { max_b / mean_b } else { 1.0 };
        FabricReport {
            mode: self.cfg.mode,
            ports: self.port_total.len(),
            modules: self.nbuckets,
            window: self.cfg.window,
            transfers: self.transfers,
            bytes: Bytes(self.bytes_total),
            busy: Seconds(busy),
            horizon: Seconds(self.horizon),
            busy_frac: if self.horizon > 0.0 { busy / self.horizon } else { 0.0 },
            queue_mean: Seconds(if sorted.is_empty() {
                0.0
            } else {
                sorted.iter().sum::<f64>() / sorted.len() as f64
            }),
            queue_p50: Seconds(crate::units::percentile_nearest_rank(&sorted, 50.0)),
            queue_p95: Seconds(crate::units::percentile_nearest_rank(&sorted, 95.0)),
            queue_p99: Seconds(crate::units::percentile_nearest_rank(&sorted, 99.0)),
            queue_max: Seconds(sorted.last().copied().unwrap_or(0.0)),
            queue_total: Seconds(self.queue_total),
            serialization: Seconds(self.ser_total),
            module_bytes: self.module_bytes(),
            module_imbalance: imbalance,
            hotspot_module: hotspot,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm};

    fn sys() -> SystemConfig {
        fh4_15xm(Bandwidth::tbps(4.8))
    }

    fn clock(mode: ContentionMode, ports: usize, interleave: bool) -> FabricClock {
        let cfg = ContentionConfig {
            mode,
            module_interleave: interleave,
            ..Default::default()
        }
        .resolved(ports);
        FabricClock::for_system(&sys(), cfg).unwrap()
    }

    #[test]
    fn shared_nothing_fabric_is_rejected() {
        let cfg =
            ContentionConfig { mode: ContentionMode::Shared, ..Default::default() }.resolved(4);
        assert!(FabricClock::for_system(&baseline8(), cfg).is_err());
        // Off mode is inert and allowed anywhere.
        let off = ContentionConfig::default().resolved(4);
        assert!(FabricClock::for_system(&baseline8(), off).is_ok());
    }

    #[test]
    fn off_mode_is_a_bit_identical_passthrough() {
        let mut c = clock(ContentionMode::Off, 4, true);
        let bytes = Bytes::mib(64.0);
        let start = Seconds::ms(3.0);
        let b = c.book(start, bytes, 0, 1);
        let unloaded = mfu::transfer_time(bytes, sys().fabric_bw);
        assert_eq!(b.serialization, unloaded, "Off must reuse the unloaded Eq 4.1 charge");
        assert_eq!(b.completion, start + unloaded);
        assert_eq!(b.queueing, Seconds::ZERO);
        assert_eq!(c.transfers(), 0, "Off bookings never enter the ledger");
        assert_eq!(c.booked_bytes(), Bytes::ZERO);
    }

    #[test]
    fn lone_transfer_sees_no_queueing() {
        let mut c = clock(ContentionMode::Shared, 4, true);
        let bytes = Bytes::gb(1.0);
        let b = c.book(Seconds::ZERO, bytes, 2, 9);
        assert!(b.queueing < Seconds::ns(1.0), "empty fabric must not queue: {:?}", b);
        let rel = (b.completion.value() - b.serialization.value()).abs()
            / b.serialization.value();
        assert!(rel < 1e-9, "completion {} vs ser {}", b.completion.value(), b.serialization.value());
        assert_eq!(c.transfers(), 1);
        assert!((c.booked_bytes().value() - bytes.value()).abs() < 1.0);
    }

    #[test]
    fn same_port_overlap_queues_the_second_transfer() {
        let mut c = clock(ContentionMode::Shared, 4, true);
        let bytes = Bytes::mib(480.0);
        let first = c.book(Seconds::ZERO, bytes, 1, 1);
        let second = c.book(Seconds::ZERO, bytes, 1, 2);
        assert!(first.queueing < Seconds::ns(1.0));
        assert!(
            second.queueing > Seconds::us(1.0),
            "two simultaneous transfers share one port: {:?}",
            second
        );
        assert!(second.completion > first.completion);
    }

    #[test]
    fn distinct_ports_dodge_each_other_until_the_pool_saturates() {
        // fh4: pool aggregate = 4 ports' worth. Four concurrent ports fit;
        // the eighth must queue behind the pool budget.
        let mut c = clock(ContentionMode::Shared, 8, true);
        let bytes = Bytes::mib(480.0);
        let mut worst = Seconds::ZERO;
        let mut first_four_queue = Seconds::ZERO;
        for p in 0..8 {
            let b = c.book(Seconds::ZERO, bytes, p, p as u64);
            if p < 4 {
                first_four_queue = first_four_queue.max(b.queueing);
            }
            worst = worst.max(b.queueing);
        }
        assert!(first_four_queue < Seconds::ns(1.0), "pool holds 4 ports at line rate");
        assert!(worst > Seconds::us(1.0), "8 ports must overrun a 4-port pool");
    }

    #[test]
    fn hashed_home_module_cap_is_serialization_not_queueing() {
        // fh4: pool 19.2 TB/s over 8 modules → 2.4 TB/s per home module,
        // below a large message's ~4.4 TB/s Eq 4.1 rate. On an EMPTY
        // fabric the module cap must read as intrinsic serialization,
        // never as queueing.
        let mut c = clock(ContentionMode::PerModule, 4, false);
        let bytes = Bytes::mib(512.0);
        let b = c.book(Seconds::ZERO, bytes, 0, 7);
        assert!(b.queueing < Seconds::ns(1.0), "empty fabric must not queue: {b:?}");
        assert!(
            b.serialization > mfu::transfer_time(bytes, sys().fabric_bw),
            "the module cap lengthens the intrinsic wire time"
        );
        let rel = (b.completion.value() - b.serialization.value()).abs()
            / b.serialization.value();
        assert!(rel < 1e-9, "completion {:?} vs ser {:?}", b.completion, b.serialization);
    }

    #[test]
    fn interleaved_striping_is_exactly_balanced_hashed_is_not() {
        let mut striped = clock(ContentionMode::PerModule, 8, true);
        let mut hashed = clock(ContentionMode::PerModule, 8, false);
        for i in 0..40u64 {
            let bytes = Bytes::mib(8.0 + (i % 5) as f64);
            striped.book(Seconds::us(i as f64), bytes, (i % 8) as usize, i * 131);
            hashed.book(Seconds::us(i as f64), bytes, (i % 8) as usize, i * 131);
        }
        let rs = striped.report();
        assert!((rs.module_imbalance - 1.0).abs() < 1e-9, "striping balances exactly");
        let rh = hashed.report();
        assert!(rh.module_imbalance >= rs.module_imbalance);
        assert!(rh.module_imbalance > 1.0, "whole-transfer hashing must skew");
        assert!(rh.hotspot_module < 8);
        // Both ledgers conserve bytes.
        for r in [&rs, &rh] {
            let total: f64 = r.module_bytes.iter().map(|b| b.value()).sum();
            assert!((total - r.bytes.value()).abs() < 1e-3 * r.bytes.value());
        }
    }

    #[test]
    fn degraded_windows_queue_what_healthy_windows_absorb() {
        let mk = |degraded: bool| {
            let mut c = clock(ContentionMode::Shared, 4, true);
            if degraded {
                c.degrade(Seconds::ZERO, Seconds::ms(10.0), 0.25);
            }
            c.book(Seconds::ZERO, Bytes::mib(480.0), 0, 1)
        };
        let healthy = mk(false);
        let degraded = mk(true);
        assert!(
            degraded.completion > healthy.completion,
            "a quartered link must finish later: {degraded:?} vs {healthy:?}"
        );
        assert!(degraded.queueing > healthy.queueing, "the slowdown is arbitration, not wire");
        assert_eq!(
            degraded.serialization, healthy.serialization,
            "degradation never rewrites the intrinsic Eq 4.1 charge"
        );
    }

    #[test]
    fn degrade_recovery_restores_full_budgets() {
        let mut c = clock(ContentionMode::Shared, 4, true);
        c.degrade(Seconds::ZERO, Seconds::ms(1.0), 0.25);
        let mut healthy = clock(ContentionMode::Shared, 4, true);
        // Booked entirely after the interval: bit-identical to a clock
        // that never degraded.
        let after = c.book(Seconds::ms(2.0), Bytes::mib(64.0), 1, 3);
        let want = healthy.book(Seconds::ms(2.0), Bytes::mib(64.0), 1, 3);
        assert_eq!(after.completion.value().to_bits(), want.completion.value().to_bits());
        assert_eq!(after.queueing.value().to_bits(), want.queueing.value().to_bits());
    }

    #[test]
    fn report_percentiles_and_busy_fraction_are_sane() {
        let mut c = clock(ContentionMode::Shared, 2, true);
        for i in 0..16u64 {
            c.book(Seconds::ZERO, Bytes::mib(256.0), (i % 2) as usize, i);
        }
        let r = c.report();
        assert_eq!(r.transfers, 16);
        assert!(r.busy_frac > 0.0 && r.busy_frac <= 1.0 + 1e-9, "busy {}", r.busy_frac);
        assert!(r.queue_p99 >= r.queue_p95);
        assert!(r.queue_p95 >= r.queue_p50);
        assert!(r.queue_max >= r.queue_p99);
        assert!(r.queue_total.value() > 0.0, "16 simultaneous bursts must queue");
        assert!(r.summary_line().contains("fabric contention (shared"));
        // Conservation: window ledger sums to the booked total.
        let windowed: f64 = c.window_bytes().iter().map(|(_, b)| b.value()).sum();
        assert!((windowed - c.booked_bytes().value()).abs() < 1e-3 * windowed);
    }
}
