//! §3.3.3 theoretical speed-up analysis, reproduced exactly.
//!
//! The paper decomposes FengHuang's advantage over NVLink into two
//! multiplicative enablers and evaluates each in a latency-bound and a
//! bandwidth-bound regime:
//!
//! * **Enabler 1 (reduced data movement)** — ring AllReduce needs
//!   `2(N−1)` transfers per GPU vs one in-memory-reduced transfer on the
//!   TAB → `2(N−1)` latency-bound, `2(N−1)/N` bandwidth-bound.
//! * **Enabler 2 (superior link)** — 1000/220 ≈ 5× read (500/90 ≈ 5.6×
//!   write) latency advantage; 4000/450 ≈ 8.89× bandwidth advantage.
//!
//! Overall: 70× latency-bound, ≈15.56× bandwidth-bound for N = 8.

use super::latency::FabricLatencies;
use crate::units::{Bandwidth, Seconds};

/// Inputs of the §3.3.3 analysis.
#[derive(Debug, Clone, Copy)]
pub struct SpeedupConfig {
    pub world: usize,
    /// Effective TAB crossbar bandwidth per GPU (paper uses 4.0 TB/s,
    /// derated from the 4.8 TB/s line rate for "typical hardware
    /// efficiency").
    pub tab_bw: Bandwidth,
    /// NVLink per-direction bandwidth per GPU (450 GB/s).
    pub nvlink_bw: Bandwidth,
    pub latencies: FabricLatencies,
}

impl Default for SpeedupConfig {
    fn default() -> Self {
        SpeedupConfig {
            world: 8,
            tab_bw: Bandwidth::tbps(4.0),
            nvlink_bw: Bandwidth::gbps(450.0),
            latencies: FabricLatencies::default(),
        }
    }
}

/// The full §3.3.3 result set.
#[derive(Debug, Clone, Copy)]
pub struct SpeedupReport {
    pub enabler1_latency: f64,
    pub enabler1_bandwidth: f64,
    pub enabler2_latency_read: f64,
    pub enabler2_latency_write: f64,
    pub enabler2_bandwidth: f64,
    pub overall_latency_bound: f64,
    pub overall_bandwidth_bound: f64,
}

/// Compute the §3.3.3 speed-ups.
pub fn speedup(cfg: &SpeedupConfig) -> SpeedupReport {
    let n = cfg.world as f64;
    // Enabler 1: transfers per GPU — ring 2(N−1) vs 1 (latency-bound);
    // bytes per GPU — 2(N−1)·T/N vs T (bandwidth-bound).
    let e1_lat = 2.0 * (n - 1.0);
    let e1_bw = 2.0 * (n - 1.0) / n;
    // Enabler 2: fixed-latency and line-rate ratios.
    let lat = &cfg.latencies;
    let e2_lat_read = lat.nvlink_read / lat.tab_read;
    let e2_lat_write = lat.nvlink_write / lat.tab_write;
    let e2_bw = cfg.tab_bw / cfg.nvlink_bw;
    // The paper rounds Enabler 2 latency ("1000/220 or 500/90 ≈ 5×") to 5
    // before multiplying; we reproduce that by rounding the mean of the
    // two ratios (4.55 and 5.56 → 5).
    let e2_lat = ((e2_lat_read + e2_lat_write) / 2.0).round();
    SpeedupReport {
        enabler1_latency: e1_lat,
        enabler1_bandwidth: e1_bw,
        enabler2_latency_read: e2_lat_read,
        enabler2_latency_write: e2_lat_write,
        enabler2_bandwidth: e2_bw,
        overall_latency_bound: e1_lat * e2_lat,
        overall_bandwidth_bound: e1_bw * e2_bw,
    }
}

/// End-to-end AllReduce speed-up at a concrete payload size — the
/// simulation-level counterpart of the closed-form analysis (sweeps of this
/// function produce the 16×–70× "up to two orders of magnitude" claim).
pub fn allreduce_speedup_at(payload: crate::units::Bytes, cfg: &SpeedupConfig) -> f64 {
    use super::collectives::{tab_collective_time, Collective};
    use super::nvlink::ring_collective_time;
    let ring = ring_collective_time(
        Collective::AllReduce,
        payload,
        cfg.world,
        cfg.nvlink_bw,
        &cfg.latencies,
    );
    let tab =
        tab_collective_time(Collective::AllReduce, payload, cfg.world, cfg.tab_bw, &cfg.latencies);
    ring / tab
}

/// Latency floor of each fabric (payload → 0): used to report the
/// latency-bound asymptote.
pub fn latency_floors(cfg: &SpeedupConfig) -> (Seconds, Seconds) {
    let n = cfg.world as f64;
    let ring = cfg.latencies.nvlink_write * (2.0 * (n - 1.0));
    let tab = cfg.latencies.tab_write_accumulate
        + cfg.latencies.tab_notification
        + cfg.latencies.tab_read;
    (ring, tab)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Bytes;

    #[test]
    fn paper_headline_numbers() {
        let r = speedup(&SpeedupConfig::default());
        assert_eq!(r.enabler1_latency, 14.0);
        assert!((r.enabler1_bandwidth - 1.75).abs() < 1e-12);
        assert!((r.enabler2_bandwidth - 8.888888888888889).abs() < 1e-9);
        assert!((r.overall_latency_bound - 70.0).abs() < 1e-9, "70× claim");
        assert!((r.overall_bandwidth_bound - 15.555555).abs() < 1e-3, "15.56× claim");
    }

    #[test]
    fn enabler2_latency_components() {
        let r = speedup(&SpeedupConfig::default());
        assert!((r.enabler2_latency_read - 1000.0 / 220.0).abs() < 1e-9);
        assert!((r.enabler2_latency_write - 500.0 / 90.0).abs() < 1e-9);
    }

    #[test]
    fn simulated_speedup_brackets_16x_to_70x() {
        // The abstract's "16× to 70× faster inter-GPU communication".
        let cfg = SpeedupConfig::default();
        let small = allreduce_speedup_at(Bytes::new(64.0), &cfg);
        let large = allreduce_speedup_at(Bytes::gib(1.0), &cfg);
        assert!(small > 15.0, "small-payload speedup {small:.1}");
        assert!(small < 75.0, "small-payload speedup {small:.1}");
        assert!(large > 14.0, "large-payload speedup {large:.1}");
        assert!(large < 17.0, "large-payload speedup {large:.1}");
    }

    #[test]
    fn speedup_decreases_with_payload() {
        // Latency-bound regime benefits most; speedup decays toward the
        // bandwidth-bound asymptote as payloads grow.
        let cfg = SpeedupConfig::default();
        let sizes = [1e3, 1e5, 1e7, 1e9];
        let sp: Vec<f64> =
            sizes.iter().map(|&s| allreduce_speedup_at(Bytes::new(s), &cfg)).collect();
        for w in sp.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "speedup must be non-increasing: {sp:?}");
        }
    }

    #[test]
    fn latency_floor_ratio_is_20x() {
        // 14 steps × 500 ns = 7000 ns vs 90+40+220 = 350 ns → 20×.
        let (ring, tab) = latency_floors(&SpeedupConfig::default());
        assert!((ring.as_ns() - 7000.0).abs() < 1e-9);
        assert!((tab.as_ns() - 350.0).abs() < 1e-9);
        assert!((ring / tab - 20.0).abs() < 1e-9);
    }
}
