//! Request router across engine replicas (vllm-router-style).
//!
//! A FengHuang rack hosts several independent 4-GPU nodes; the router
//! spreads incoming requests across them. Policies: round-robin and
//! least-loaded (by outstanding token estimate).

use super::request::Request;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
}

/// Router state over `n` replicas.
pub struct Router {
    policy: Policy,
    next: usize,
    /// Outstanding work estimate per replica (prompt + max_new tokens).
    load: Vec<u64>,
}

impl Router {
    pub fn new(replicas: usize, policy: Policy) -> Self {
        assert!(replicas > 0);
        Router { policy, next: 0, load: vec![0; replicas] }
    }

    pub fn replicas(&self) -> usize {
        self.load.len()
    }

    /// Choose a replica for `req` and account its load.
    pub fn route(&mut self, req: &Request) -> usize {
        let idx = match self.policy {
            Policy::RoundRobin => {
                let i = self.next;
                self.next = (self.next + 1) % self.load.len();
                i
            }
            Policy::LeastLoaded => self
                .load
                .iter()
                .enumerate()
                .min_by_key(|(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.load[idx] += (req.prompt_len() + req.max_new_tokens) as u64;
        idx
    }

    /// Report completion of a request previously routed to `replica`.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        let w = (req.prompt_len() + req.max_new_tokens) as u64;
        self.load[replica] = self.load[replica].saturating_sub(w);
    }

    pub fn load(&self, replica: usize) -> u64 {
        self.load[replica]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Seconds;

    fn req(id: u64, len: usize) -> Request {
        Request { id, prompt: vec![1; len], max_new_tokens: 8, arrival: Seconds::ZERO }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_unequal_requests() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let a = r.route(&req(0, 1000)); // heavy → replica 0
        let b = r.route(&req(1, 10)); // light → replica 1
        let c = r.route(&req(2, 10)); // replica 1 still lighter
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(c, 1);
    }

    #[test]
    fn completion_releases_load() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let q = req(0, 100);
        let idx = r.route(&q);
        assert!(r.load(idx) > 0);
        r.complete(idx, &q);
        assert_eq!(r.load(idx), 0);
    }
}
