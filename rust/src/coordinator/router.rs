//! Request router across engine replicas (vllm-router-style).
//!
//! A FengHuang rack hosts several independent 4-GPU nodes; the router
//! spreads incoming requests across them. Policies (DESIGN.md §6):
//!
//! * **round-robin** — stateless cycling;
//! * **least-outstanding-tokens** — pick the replica with the smallest
//!   outstanding work estimate (prompt + generation-budget tokens);
//! * **kv-affinity** — requests sharing a prompt prefix
//!   ([`Request::affinity_key`]) stick to one replica so its KV/prefix
//!   cache stays hot, spilling to the least-loaded replica only when the
//!   sticky replica is overloaded.

use super::request::Request;
use std::collections::HashMap;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastLoaded,
    KvAffinity,
}

impl Policy {
    /// Parse a CLI policy name.
    pub fn parse(s: &str) -> Option<Policy> {
        match s.to_ascii_lowercase().as_str() {
            "round-robin" | "rr" => Some(Policy::RoundRobin),
            "least-loaded" | "least-outstanding-tokens" | "lot" => Some(Policy::LeastLoaded),
            "kv-affinity" | "session-affinity" | "kv" => Some(Policy::KvAffinity),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Policy::RoundRobin => "round-robin",
            Policy::LeastLoaded => "least-outstanding-tokens",
            Policy::KvAffinity => "kv-affinity",
        }
    }
}

/// Default overload spill threshold for [`Policy::KvAffinity`], in
/// outstanding tokens above the least-loaded replica: a sticky replica
/// this far ahead of the fleet minimum loses the session to the
/// least-loaded replica (cache locality is worth a bounded, not
/// unbounded, queueing penalty).
pub const DEFAULT_SPILL_TOKENS: u64 = 16 * 1024;

/// Router state over `n` replicas. The *active set* is the prefix
/// `[0, active)`: new work only routes there, so the elastic autoscaler
/// (DESIGN.md §Traffic) can shrink/grow the serving fleet while
/// deactivated replicas drain — `complete_work` still releases their
/// outstanding load.
pub struct Router {
    policy: Policy,
    next: usize,
    /// Outstanding work estimate per replica (prompt + max_new tokens).
    load: Vec<u64>,
    /// Cumulative tokens ever routed per replica (imbalance metric).
    routed: Vec<u64>,
    /// Sticky session → replica map for [`Policy::KvAffinity`].
    affinity: HashMap<u64, usize>,
    spill_tokens: u64,
    /// Replicas currently receiving new work (always ≥ 1, ≤ n).
    active: usize,
    /// Replicas the fault layer took out entirely (DESIGN.md §Faults):
    /// unlike a deactivated replica, a dead one cannot even drain.
    dead: Vec<bool>,
    /// Count of `true` entries in `dead` — the healthy fast paths gate
    /// on zero so fault support never perturbs a healthy run.
    dead_count: usize,
}

impl Router {
    pub fn new(replicas: usize, policy: Policy) -> Self {
        assert!(replicas > 0);
        Router {
            policy,
            next: 0,
            load: vec![0; replicas],
            routed: vec![0; replicas],
            affinity: HashMap::new(),
            spill_tokens: DEFAULT_SPILL_TOKENS,
            active: replicas,
            dead: vec![false; replicas],
            dead_count: 0,
        }
    }

    /// Override the KV-affinity overload spill threshold.
    pub fn with_spill_tokens(mut self, tokens: u64) -> Self {
        self.spill_tokens = tokens;
        self
    }

    pub fn replicas(&self) -> usize {
        self.load.len()
    }

    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// Resize the active set (clamped to `[1, n]`). Shrinking never
    /// cancels outstanding work — deactivated replicas drain naturally.
    pub fn set_active(&mut self, n: usize) {
        self.active = n.clamp(1, self.load.len());
        if self.next >= self.active {
            self.next = 0;
        }
    }

    pub fn active(&self) -> usize {
        self.active
    }

    /// Take `replica` out of routing entirely (crash — DESIGN.md
    /// §Faults). Unlike a deactivated replica it cannot even drain;
    /// its outstanding load is released by the evacuation path, not
    /// here. Idempotent.
    pub fn mark_dead(&mut self, replica: usize) {
        if !self.dead[replica] {
            self.dead[replica] = true;
            self.dead_count += 1;
        }
    }

    /// The repaired replica rejoins routing (cold caches). Idempotent.
    pub fn mark_alive(&mut self, replica: usize) {
        if self.dead[replica] {
            self.dead[replica] = false;
            self.dead_count -= 1;
        }
    }

    pub fn is_dead(&self, replica: usize) -> bool {
        self.dead[replica]
    }

    /// Whether a sticky/warm home may keep receiving work. This is the
    /// ONE re-home predicate shared by both deactivation paths — the
    /// autoscale drain (home left the active prefix) and a crash (home
    /// marked dead): in either case the session must silently re-home
    /// through the policy fallback instead of routing to a replica
    /// that can no longer take work. `min` is the caller's
    /// [`Self::min_active_load`] snapshot (one load read per route).
    fn sticky_home_usable(&self, home: usize, min: u64) -> bool {
        home < self.active && !self.dead[home] && self.load[home] <= min + self.spill_tokens
    }

    fn least_loaded(&self) -> usize {
        if self.dead_count == 0 {
            return self.load[..self.active]
                .iter()
                .enumerate()
                .min_by_key(|(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap();
        }
        self.least_loaded_alive()
    }

    /// Fault path of [`Self::least_loaded`]: smallest-load *alive*
    /// replica, preferring the active prefix, spilling to alive
    /// drain-set replicas when every active replica is dead, and
    /// (defensively — `Cluster::new` rejects all-dead schedules)
    /// falling back to replica 0. First index wins ties, matching the
    /// healthy path.
    fn least_loaded_alive(&self) -> usize {
        let pick = |lo: usize, hi: usize| -> Option<usize> {
            let mut best = None;
            for i in lo..hi {
                if self.dead[i] {
                    continue;
                }
                if best.map(|b: usize| self.load[i] < self.load[b]).unwrap_or(true) {
                    best = Some(i);
                }
            }
            best
        };
        pick(0, self.active).or_else(|| pick(self.active, self.load.len())).unwrap_or(0)
    }

    /// Smallest outstanding load across the active set (the front-door
    /// shed check reads this: if even the emptiest active replica is
    /// over the watermark, the fleet is saturated). Dead replicas do
    /// not count — their (evacuated) load is no signal of capacity.
    pub fn min_active_load(&self) -> u64 {
        if self.dead_count == 0 {
            return *self.load[..self.active].iter().min().unwrap();
        }
        let alive_min = |lo: usize, hi: usize| {
            (lo..hi).filter(|&i| !self.dead[i]).map(|i| self.load[i]).min()
        };
        alive_min(0, self.active).or_else(|| alive_min(0, self.load.len())).unwrap_or(0)
    }

    /// Total outstanding load across the whole fleet, draining replicas
    /// included (the autoscaler's demand signal).
    pub fn total_load(&self) -> u64 {
        self.load.iter().sum()
    }

    /// Choose a replica for `req` and account its load.
    pub fn route(&mut self, req: &Request) -> usize {
        self.route_work(req.affinity_key(), req.work_tokens())
    }

    /// Policy core: choose a replica for a request with session key `key`
    /// and outstanding-work estimate `work` tokens, and account the load.
    pub fn route_work(&mut self, key: u64, work: u64) -> usize {
        let idx = match self.policy {
            Policy::RoundRobin => {
                if self.dead_count == 0 {
                    let i = self.next;
                    self.next = (self.next + 1) % self.active;
                    i
                } else {
                    // Cycle past dead slots; an all-dead active prefix
                    // spills to the least-loaded alive replica.
                    let mut i = self.next;
                    let mut scanned = 0;
                    while scanned < self.active && self.dead[i] {
                        i = (i + 1) % self.active;
                        scanned += 1;
                    }
                    if self.dead[i] {
                        i = self.least_loaded_alive();
                    }
                    self.next = (i + 1) % self.active;
                    i
                }
            }
            Policy::LeastLoaded => self.least_loaded(),
            Policy::KvAffinity => {
                let min = self.min_active_load();
                match self.affinity.get(&key) {
                    // A sticky replica outside the active set re-homes
                    // (it is draining and must not receive new work) —
                    // same predicate as a dead one (crash re-queue).
                    Some(&i) if self.sticky_home_usable(i, min) => i,
                    _ => {
                        let i = self.least_loaded();
                        self.affinity.insert(key, i);
                        i
                    }
                }
            }
        };
        self.load[idx] += work;
        self.routed[idx] += work;
        idx
    }

    /// Warm-page-aware routing (DESIGN.md §Prefix-Cache): the prefix
    /// cache's hit-probe names the replica whose *local* pages are warm
    /// for this prefix. Least-loaded routing prefers it while its load
    /// stays within the spill threshold of the fleet minimum — locality
    /// is worth a bounded queueing penalty, exactly the kv-affinity
    /// trade-off — and otherwise falls back to the shared pool via the
    /// normal policy. Round-robin stays deliberately stateless and
    /// kv-affinity keeps its own (session-sticky) map.
    pub fn route_work_warm(&mut self, key: u64, work: u64, warm: Option<usize>) -> usize {
        if self.policy == Policy::LeastLoaded {
            if let Some(i) = warm {
                if self.sticky_home_usable(i, self.min_active_load()) {
                    self.load[i] += work;
                    self.routed[i] += work;
                    return i;
                }
            }
        }
        self.route_work(key, work)
    }

    /// Direct placement, bypassing the policy: charge `work` to a
    /// replica the caller already picked. The multi-tenant admission
    /// layer uses this after `tenancy::pick_replica` has chosen the
    /// tenant's home (or swap target); the load/routed accounting — and
    /// hence `complete_work`/`unroute` symmetry — stays identical to a
    /// policy route (DESIGN.md §Multi-Tenant).
    pub fn route_to(&mut self, replica: usize, work: u64) {
        self.load[replica] += work;
        self.routed[replica] += work;
    }

    /// Report completion of a request previously routed to `replica`.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        self.complete_work(replica, req.work_tokens());
    }

    /// Release `work` tokens of outstanding load from `replica`.
    pub fn complete_work(&mut self, replica: usize, work: u64) {
        self.load[replica] = self.load[replica].saturating_sub(work);
    }

    /// Revoke a routing decision whose request was refused downstream:
    /// releases the outstanding load *and* removes the tokens from the
    /// cumulative routed count, as if the route never happened.
    pub fn unroute(&mut self, replica: usize, work: u64) {
        self.load[replica] = self.load[replica].saturating_sub(work);
        self.routed[replica] = self.routed[replica].saturating_sub(work);
    }

    pub fn load(&self, replica: usize) -> u64 {
        self.load[replica]
    }

    /// Cumulative tokens routed to each replica.
    pub fn routed(&self) -> &[u64] {
        &self.routed
    }

    /// Load imbalance of the cumulative routing decisions: max/mean of
    /// per-replica routed tokens (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let total: u64 = self.routed.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.routed.len() as f64;
        let max = *self.routed.iter().max().unwrap() as f64;
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Seconds;

    fn req(id: u64, len: usize) -> Request {
        Request {
            id,
            prompt: vec![1; len],
            max_new_tokens: 8,
            arrival: Seconds::ZERO,
            ..Default::default()
        }
    }

    /// Request whose affinity prefix encodes `session`.
    fn session_req(id: u64, session: i32, len: usize) -> Request {
        let mut prompt = vec![session; len.max(1)];
        for (i, t) in prompt.iter_mut().enumerate().skip(32) {
            *t = (i % 100) as i32 + 1000 * id as i32; // tails differ per request
        }
        Request { id, prompt, max_new_tokens: 8, arrival: Seconds::ZERO, ..Default::default() }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10))).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_unequal_requests() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let a = r.route(&req(0, 1000)); // heavy → replica 0
        let b = r.route(&req(1, 10)); // light → replica 1
        let c = r.route(&req(2, 10)); // replica 1 still lighter
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(c, 1);
    }

    #[test]
    fn completion_releases_load() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let q = req(0, 100);
        let idx = r.route(&q);
        assert!(r.load(idx) > 0);
        r.complete(idx, &q);
        assert_eq!(r.load(idx), 0);
        // Releasing more than outstanding saturates at zero.
        r.complete_work(idx, 10_000);
        assert_eq!(r.load(idx), 0);
    }

    #[test]
    fn route_to_charges_like_a_policy_route() {
        let mut r = Router::new(3, Policy::LeastLoaded);
        r.route_to(2, 100);
        assert_eq!(r.load(2), 100);
        assert_eq!(r.routed(), &[0, 0, 100]);
        // Same release/revoke symmetry as route_work.
        r.complete_work(2, 40);
        assert_eq!(r.load(2), 60);
        r.unroute(2, 60);
        assert_eq!(r.load(2), 0);
        assert_eq!(r.routed(), &[0, 0, 40]);
        // Direct placement must not perturb the policy's RR cursor or
        // least-loaded view beyond the charged load itself.
        let next = r.route_work(1, 10);
        assert_ne!(next, 2, "replica 2 still carries routed history but no load");
    }

    #[test]
    fn policy_names_parse() {
        assert_eq!(Policy::parse("round-robin"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("rr"), Some(Policy::RoundRobin));
        assert_eq!(Policy::parse("least-outstanding-tokens"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::parse("least-loaded"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::parse("KV-Affinity"), Some(Policy::KvAffinity));
        assert_eq!(Policy::parse("carrier-pigeon"), None);
        assert_eq!(Policy::KvAffinity.name(), "kv-affinity");
    }

    #[test]
    fn kv_affinity_sticks_across_request_stream() {
        let mut r = Router::new(4, Policy::KvAffinity);
        // Interleaved stream from 4 sessions: each session must always
        // land on the replica it was first assigned.
        // Outstanding load stays far below the default spill threshold,
        // so stickiness is never overridden; least-loaded seeding of the
        // first request per session spreads the four sessions out.
        let mut assigned: HashMap<i32, usize> = HashMap::new();
        for i in 0..40 {
            let session = (i % 4) as i32 + 1;
            let q = session_req(i, session, 200);
            let idx = r.route(&q);
            let expect = *assigned.entry(session).or_insert(idx);
            assert_eq!(idx, expect, "session {session} moved replicas at request {i}");
        }
        // 4 sessions over 4 replicas via least-loaded seeding: all distinct.
        let mut seen: Vec<usize> = assigned.values().copied().collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 4, "sessions should spread over replicas");
    }

    #[test]
    fn kv_affinity_spills_when_replica_overloaded() {
        let mut r = Router::new(2, Policy::KvAffinity).with_spill_tokens(100);
        let q0 = session_req(0, 7, 400); // session 7 → some replica, 408 tokens
        let home = r.route(&q0);
        // Same session while home is >100 tokens above the idle replica:
        // must spill to the other replica (and re-home there).
        let q1 = session_req(1, 7, 40);
        let spill = r.route(&q1);
        assert_ne!(spill, home, "overloaded sticky replica must spill");
        // The session re-homed: with load now balanced-ish it stays put.
        let q2 = session_req(2, 7, 40);
        assert_eq!(r.route(&q2), spill);
    }

    #[test]
    fn warm_probe_bends_least_loaded_within_spill_threshold() {
        let mut r = Router::new(3, Policy::LeastLoaded).with_spill_tokens(100);
        // Replica 2 carries slightly more load than the minimum but holds
        // the warm pages: the probe wins.
        r.route_work(1, 50); // replica picked deterministically: least-loaded = 0
        assert_eq!(r.load(0), 50);
        let warm = r.route_work_warm(2, 40, Some(2));
        assert_eq!(warm, 2, "warm replica within threshold is preferred");
        // Pile load onto the warm replica past the threshold: fall back
        // to least-loaded.
        r.complete_work(2, 40);
        r.route_work_warm(3, 500, Some(2));
        let spill = r.route_work_warm(4, 10, Some(2));
        assert_ne!(spill, 2, "overloaded warm replica must spill");
        // No warm hint behaves exactly like route_work.
        let mut a = Router::new(2, Policy::LeastLoaded);
        let mut b = Router::new(2, Policy::LeastLoaded);
        for i in 0..6 {
            assert_eq!(a.route_work_warm(i, 10 + i, None), b.route_work(i, 10 + i));
        }
        // Round-robin ignores the probe entirely.
        let mut rr = Router::new(3, Policy::RoundRobin);
        let picks: Vec<usize> = (0..3).map(|i| rr.route_work_warm(i, 10, Some(2))).collect();
        assert_eq!(picks, vec![0, 1, 2]);
        // An out-of-active-set warm replica is never used.
        let mut ll = Router::new(3, Policy::LeastLoaded);
        ll.set_active(2);
        assert!(ll.route_work_warm(9, 10, Some(2)) < 2);
    }

    #[test]
    fn active_set_confines_new_work_and_drains_the_rest() {
        let mut r = Router::new(4, Policy::RoundRobin);
        assert_eq!(r.active(), 4);
        r.set_active(2);
        let picks: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10))).collect();
        assert!(picks.iter().all(|&i| i < 2), "{picks:?}");
        // Releasing load on a deactivated replica still works (drain).
        r.complete_work(3, 100);
        // Clamp: never below one, never above the fleet.
        r.set_active(0);
        assert_eq!(r.active(), 1);
        r.set_active(99);
        assert_eq!(r.active(), 4);
    }

    #[test]
    fn least_loaded_ignores_inactive_replicas() {
        let mut r = Router::new(3, Policy::LeastLoaded);
        // Load up replicas 0 and 1, leave 2 empty — then deactivate 2.
        r.route(&req(0, 500));
        r.route(&req(1, 400));
        r.set_active(2);
        let pick = r.route(&req(2, 10));
        assert!(pick < 2, "empty-but-inactive replica 2 must not be picked");
        assert_eq!(r.min_active_load(), r.load(0).min(r.load(1)));
        assert_eq!(r.total_load(), r.load(0) + r.load(1) + r.load(2));
    }

    #[test]
    fn kv_affinity_rehomes_sessions_off_deactivated_replicas() {
        let mut r = Router::new(4, Policy::KvAffinity);
        // Bias replica 0 so the session homes on a later replica, then
        // shrink the active set below that home.
        r.route(&req(100, 2000));
        let home = r.route(&session_req(0, 9, 100));
        assert!(home >= 1, "session must avoid the loaded replica 0");
        r.set_active(1);
        let next = r.route(&session_req(1, 9, 100));
        assert_eq!(next, 0, "session must re-home into the active set");
        // Sticky thereafter (home now inside the active set).
        assert_eq!(r.route(&session_req(2, 9, 100)), 0);
    }

    #[test]
    fn dead_replicas_receive_no_new_work() {
        // Every policy must refuse a dead replica, exactly like the
        // autoscale drain set — the shared re-home predicate.
        for policy in [Policy::RoundRobin, Policy::LeastLoaded, Policy::KvAffinity] {
            let mut r = Router::new(3, policy);
            r.mark_dead(1);
            assert!(r.is_dead(1));
            // Distinct sessions so kv-affinity takes a fresh routing
            // decision per request rather than riding one sticky home.
            for i in 0..9 {
                let pick = r.route(&session_req(i, i as i32 + 100, 50));
                assert_ne!(pick, 1, "{policy:?} routed to a dead replica");
            }
            // Rejoin: the replica is eligible again.
            r.mark_alive(1);
            let picks: Vec<usize> =
                (9..30).map(|i| r.route(&session_req(i, i as i32 + 100, 50))).collect();
            assert!(picks.contains(&1), "{policy:?} never re-used the rejoined replica");
        }
    }

    #[test]
    fn round_robin_skips_dead_and_keeps_cycling() {
        let mut r = Router::new(3, Policy::RoundRobin);
        r.mark_dead(0);
        let picks: Vec<usize> = (0..4).map(|i| r.route(&req(i, 10))).collect();
        assert_eq!(picks, vec![1, 2, 1, 2]);
    }

    #[test]
    fn sticky_sessions_rehome_off_dead_replicas() {
        let mut r = Router::new(4, Policy::KvAffinity);
        // Bias replica 0 so the session homes elsewhere.
        r.route(&req(100, 2000));
        let home = r.route(&session_req(0, 9, 100));
        assert!(home >= 1);
        r.mark_dead(home);
        let next = r.route(&session_req(1, 9, 100));
        assert_ne!(next, home, "session must re-home off the dead replica");
        // Sticky on the new home thereafter — the crash path behaves
        // exactly like the drain path re-home.
        assert_eq!(r.route(&session_req(2, 9, 100)), next);
    }

    #[test]
    fn warm_probe_never_picks_a_dead_replica() {
        let mut r = Router::new(3, Policy::LeastLoaded);
        r.mark_dead(2);
        assert_ne!(r.route_work_warm(7, 10, Some(2)), 2);
    }

    #[test]
    fn min_active_load_ignores_dead_replicas() {
        let mut r = Router::new(2, Policy::LeastLoaded);
        let a = r.route(&req(0, 100));
        let b = r.route(&req(1, 500));
        // Kill the lighter replica: the shed signal must read the
        // surviving one's load, not the dead minimum.
        let (light, heavy) = if r.load(a) < r.load(b) { (a, b) } else { (b, a) };
        r.mark_dead(light);
        assert_eq!(r.min_active_load(), r.load(heavy));
        // An all-dead active prefix falls back to alive replicas
        // beyond it.
        let mut r = Router::new(3, Policy::LeastLoaded);
        r.route(&req(0, 100));
        r.set_active(2);
        r.mark_dead(0);
        r.mark_dead(1);
        assert_eq!(r.min_active_load(), r.load(2));
        assert_eq!(r.route(&req(1, 10)), 2, "work spills to the alive drain-set replica");
    }

    #[test]
    fn mark_dead_and_alive_are_idempotent() {
        let mut r = Router::new(2, Policy::RoundRobin);
        r.mark_dead(0);
        r.mark_dead(0);
        r.mark_alive(0);
        assert!(!r.is_dead(0), "double-kill then one repair must leave the replica alive");
        r.mark_alive(0);
        let picks: Vec<usize> = (0..4).map(|i| r.route(&req(i, 10))).collect();
        assert_eq!(picks, vec![0, 1, 0, 1], "healthy cycling restored");
    }

    #[test]
    fn imbalance_metric_tracks_routed_tokens() {
        let mut r = Router::new(2, Policy::RoundRobin);
        r.route(&req(0, 992)); // 1000 tokens → replica 0
        r.route(&req(1, 92)); // 100 tokens → replica 1
        assert_eq!(r.routed(), &[1000, 100]);
        let exp = 1000.0 / 550.0;
        assert!((r.imbalance() - exp).abs() < 1e-9, "imbalance {}", r.imbalance());
        // A fresh router is "balanced".
        assert_eq!(Router::new(3, Policy::LeastLoaded).imbalance(), 1.0);
    }
}
