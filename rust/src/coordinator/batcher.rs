//! Continuous batcher.
//!
//! Groups queued requests into prefill batches (up to `max_batch`, padded
//! to a common tile length) and maintains the running decode set,
//! admitting new requests between decode rounds — the standard
//! continuous-batching discipline of vLLM/SGLang-class servers, which the
//! paper's software stack plugs into (§3.4).

use super::request::Request;
use std::collections::VecDeque;

/// Batch formed for one prefill pass.
#[derive(Debug, Clone)]
pub struct PrefillBatch {
    pub requests: Vec<Request>,
    /// Common padded prompt length (tile multiple).
    pub padded_len: usize,
}

/// Continuous batcher state.
pub struct Batcher {
    queue: VecDeque<Request>,
    pub max_batch: usize,
    /// Sequence-length tile (attention block size of the L1 kernel).
    pub tile: usize,
    /// Cap on admitted prompt length.
    pub max_prompt: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, tile: usize, max_prompt: usize) -> Self {
        assert!(max_batch >= 1 && tile >= 1);
        Batcher { queue: VecDeque::new(), max_batch, tile, max_prompt }
    }

    /// Whether a request would be accepted: the single admission rule,
    /// also consulted by the cluster front door before routing.
    pub fn admits(&self, req: &Request) -> bool {
        req.prompt_len() <= self.max_prompt && !req.prompt.is_empty()
    }

    /// Enqueue a request. Returns false (rejecting it) if the prompt
    /// exceeds the admissible length.
    pub fn submit(&mut self, req: Request) -> bool {
        if !self.admits(&req) {
            return false;
        }
        self.queue.push_back(req);
        true
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Take the whole queue in FIFO order — the crash-evacuation path
    /// (DESIGN.md §Faults): a dead replica's queued requests leave
    /// through here to be re-routed by the cluster.
    pub fn drain_queue(&mut self) -> Vec<Request> {
        self.queue.drain(..).collect()
    }

    /// Visit every queued request mutably, FIFO order. The fault layer
    /// uses this to revoke cached-prefix grants whose TAB module died
    /// while the request was still waiting.
    pub fn for_each_queued_mut(&mut self, mut f: impl FnMut(&mut Request)) {
        for r in self.queue.iter_mut() {
            f(r);
        }
    }

    /// Form the next prefill batch: up to `room` requests (bounded by
    /// `max_batch`), padded to the longest member rounded up to the tile.
    pub fn next_batch(&mut self, room: usize) -> Option<PrefillBatch> {
        if self.queue.is_empty() || room == 0 {
            return None;
        }
        let n = room.min(self.max_batch).min(self.queue.len());
        let requests: Vec<Request> = self.queue.drain(..n).collect();
        // Padding follows the *prefill* length: tokens served from the
        // shared prefix cache (DESIGN.md §Prefix-Cache) never enter the
        // prefill kernel, so they must not inflate the tile either.
        let longest = requests.iter().map(|r| r.prefill_len()).max().unwrap_or(1);
        let padded_len = longest.div_ceil(self.tile) * self.tile;
        Some(PrefillBatch { requests, padded_len })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Seconds;

    fn req(id: u64, len: usize) -> Request {
        Request {
            id,
            prompt: vec![1; len],
            max_new_tokens: 4,
            arrival: Seconds::ZERO,
            ..Default::default()
        }
    }

    #[test]
    fn admits_boundary_lengths_exactly() {
        let b = Batcher::new(4, 64, 100);
        assert!(!b.admits(&req(0, 0)), "empty prompts are inadmissible");
        assert!(b.admits(&req(1, 1)), "one token is the smallest admissible prompt");
        assert!(b.admits(&req(2, 99)));
        assert!(b.admits(&req(3, 100)), "the cap itself is admissible");
        assert!(!b.admits(&req(4, 101)), "one past the cap is not");
        // A cap of 1 still admits single-token prompts.
        let tight = Batcher::new(1, 1, 1);
        assert!(tight.admits(&req(5, 1)));
        assert!(!tight.admits(&req(6, 2)));
    }

    #[test]
    fn batches_respect_max_batch_and_room() {
        let mut b = Batcher::new(4, 64, 1024);
        for i in 0..10 {
            assert!(b.submit(req(i, 10)));
        }
        let batch = b.next_batch(8).unwrap();
        assert_eq!(batch.requests.len(), 4); // max_batch wins
        let batch = b.next_batch(2).unwrap();
        assert_eq!(batch.requests.len(), 2); // room wins
        assert_eq!(b.queued(), 4);
    }

    #[test]
    fn padding_rounds_to_tile() {
        let mut b = Batcher::new(4, 64, 1024);
        b.submit(req(0, 10));
        b.submit(req(1, 70));
        let batch = b.next_batch(4).unwrap();
        assert_eq!(batch.padded_len, 128);
    }

    #[test]
    fn padding_follows_prefill_length_under_cache_hits() {
        let mut b = Batcher::new(4, 64, 4096);
        let mut hit = req(0, 1000);
        hit.cached_prefix = 960; // 40 tokens left to prefill
        b.submit(hit);
        b.submit(req(1, 50));
        let batch = b.next_batch(4).unwrap();
        assert_eq!(batch.padded_len, 64, "cached tokens must not inflate the tile");
        // Admission still judges the full prompt (KV must fit max_seq).
        let mut long = req(2, 5000);
        long.cached_prefix = 4990;
        assert!(!b.admits(&long));
    }

    #[test]
    fn rejects_oversized_and_empty_prompts() {
        let mut b = Batcher::new(4, 64, 100);
        assert!(!b.submit(req(0, 101)));
        assert!(!b.submit(req(1, 0)));
        assert!(b.submit(req(2, 100)));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(8, 64, 1024);
        for i in 0..5 {
            b.submit(req(i, 8));
        }
        let batch = b.next_batch(3).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn empty_queue_yields_none() {
        let mut b = Batcher::new(4, 64, 1024);
        assert!(b.next_batch(4).is_none());
        b.submit(req(0, 8));
        assert!(b.next_batch(0).is_none());
    }
}
