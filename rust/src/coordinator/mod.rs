//! Serving coordinator — the Layer-3 system.
//!
//! * [`request`] — request/response lifecycle types;
//! * [`batcher`] — continuous batching;
//! * [`scheduler`] — the prefill/decode serving loop (virtual or wall
//!   clock, backend-agnostic);
//! * [`engine`] — backends: mock, simulation (paper-scale models);
//! * `tp` — the PJRT tensor-parallel pipeline over the functional TAB
//!   pool (the end-to-end request path of `examples/serve_e2e.rs`;
//!   requires the `pjrt` feature);
//! * [`router`] — multi-replica request routing (round-robin,
//!   least-outstanding-tokens, KV-affinity) with a warm-page hit-probe;
//! * [`cluster`] — rack-scale co-simulation of N replicas with routed
//!   dispatch and optional disaggregated prefill/decode pools;
//! * [`calendar`] / [`arena`] / [`event_core`] — the event-driven
//!   cluster core (DESIGN.md §Event-Core): a deterministic binary-heap
//!   event calendar, arena-allocated request handles, and lean
//!   per-replica serving loops held bit-identical to the stepping
//!   oracle by a differential test harness;
//! * [`prefix_cache`] — cluster-wide shared prefix-KV cache in the TAB
//!   pool: cross-replica prefill reuse (DESIGN.md §Prefix-Cache);
//! * [`tenancy`] — multi-tenant serving: per-tenant models and QoS,
//!   weighted-fair admission arbitration, cold-start model swaps
//!   (DESIGN.md §Multi-Tenant);
//! * [`metrics`] — latency/throughput accounting, per-replica and
//!   fleet-level.

pub mod arena;
pub mod batcher;
pub mod calendar;
pub mod cluster;
pub mod engine;
pub mod event_core;
pub mod metrics;
pub mod prefix_cache;
pub mod request;
pub mod router;
pub mod scheduler;
pub mod tenancy;
#[cfg(feature = "pjrt")]
pub mod tp;

pub use arena::{ArenaEntry, ReqId, RequestArena};
pub use batcher::Batcher;
pub use calendar::{Event, EventCalendar, EventKind};
pub use cluster::{
    demo_serve_cluster, demo_serve_tenants, demo_serve_tenants_report, demo_serve_traffic,
    demo_serve_traffic_report, session_workload, AutoscaleConfig, Cluster, ClusterConfig,
    ClusterReport,
};
pub use engine::{Backend, SimBackend};
pub use event_core::{EventReplica, LeanHandoff};
pub use prefix_cache::{PoolPlacement, PrefixCache, PrefixCacheConfig, PrefixCacheReport, PrefixHit};
pub use metrics::{LatencyStat, Metrics, STREAMING_THRESHOLD};
pub use request::{Request, Response, SloTarget};
pub use router::{Policy, Router};
pub use scheduler::{SchedMode, Scheduler};
pub use tenancy::{TenantArbitration, TenantConfig, TenantReport, TenantsConfig};

use crate::config::fh4_15xm;
use crate::error::Result;
use crate::models::arch::ModelArch;
use crate::units::{Bandwidth, Seconds};

/// Generate a deterministic synthetic workload: `n` requests with
/// LCG-spaced arrivals and prompt/generation lengths around the paper's
/// Q&A task shape (scaled by `prompt`/`gen`).
pub fn synthetic_workload(n: usize, prompt: usize, gen: usize, mean_gap: Seconds) -> Vec<Request> {
    let mut state: u64 = 0x9E3779B97F4A7C15;
    let mut t = Seconds::ZERO;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let jitter = ((state >> 33) % 1000) as f64 / 1000.0; // [0,1)
        t += mean_gap * (2.0 * jitter); // mean = mean_gap
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let plen = (prompt / 2 + ((state >> 33) as usize % prompt.max(1))).max(1);
        out.push(Request {
            id: id as u64,
            prompt: (0..plen).map(|i| (i % 509) as i32 + 1).collect(),
            max_new_tokens: gen,
            arrival: t,
            ..Default::default()
        });
    }
    out
}

/// `fenghuang serve`: run a synthetic workload on a simulated FH4 node
/// and return the metrics summary.
pub fn demo_serve(model: &ModelArch, requests: usize, max_batch: usize) -> Result<String> {
    let sys = fh4_15xm(Bandwidth::tbps(4.8));
    let backend = SimBackend::new(sys.clone(), model.clone(), max_batch);
    let batcher = Batcher::new(max_batch, 64, model.max_seq as usize);
    let mut sched = Scheduler::new(backend, batcher);
    sched.submit_all(synthetic_workload(requests, 1024, 128, Seconds::ms(50.0)));
    sched.run_to_completion()?;
    Ok(format!(
        "served {} requests of {} on {}\n{}",
        requests,
        model.name,
        sys.name,
        sched.metrics.summary()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::gpt3_175b;

    #[test]
    fn synthetic_workload_is_deterministic_and_sorted() {
        let a = synthetic_workload(20, 512, 64, Seconds::ms(10.0));
        let b = synthetic_workload(20, 512, 64, Seconds::ms(10.0));
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
        for w in a.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn demo_serve_completes() {
        let s = demo_serve(&gpt3_175b(), 12, 4).unwrap();
        assert!(s.contains("completed 12"), "{s}");
    }
}
