//! Binary-heap event calendar for the event-driven cluster core
//! (DESIGN.md §Event-Core).
//!
//! The calendar replaces the tick-scanning loop's O(replicas) sweep per
//! arrival with a min-heap over typed events ordered by virtual time.
//! Determinism is load-bearing: the golden snapshots and the
//! differential equivalence suite (`rust/tests/event_core_equiv.rs`)
//! assert *bit*-identical fleet metrics, so ties cannot be resolved by
//! heap insertion luck. Every event carries a `(time, class, seq)` key:
//!
//! * `time` — virtual seconds (finite; `Seconds` debug-asserts this);
//! * `class` — a fixed per-kind rank so same-instant events replay the
//!   stepping loop's ordering (an `AutoscaleTick` scheduled at exactly
//!   an arrival's timestamp fires *before* the arrival, mirroring the
//!   `while next_scale <= arrival` loop);
//! * `seq` — a monotone push counter, making same-time same-class
//!   events FIFO (arrivals pushed in sorted order pop in sorted order).
//!
//! Scheduling into the past is a logic bug in the driver, not a
//! recoverable condition at runtime — `push` rejects it (returns
//! `false`) and the property suite (`rust/tests/event_props.rs`) pins
//! the behavior. Scheduling *at* the current instant is allowed: a tick
//! rescheduling itself at `t + interval` with a degenerate zero
//! interval would be caught by config validation, not here.

use super::arena::ReqId;
use crate::units::Seconds;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// The event vocabulary of the cluster core.
///
/// Only `Arrival` and `AutoscaleTick` are *global* synchronization
/// points: the stepping loop this core must replay bit-for-bit advances
/// every replica exactly at those instants, and router/autoscaler
/// observations depend on that phasing. Replica-local deadlines
/// (prefill completion, decode rounds, KV migration, disaggregated
/// handoff landing) are declared here as first-class kinds so drivers
/// can schedule them explicitly, but the bit-compatible driver resolves
/// them lazily inside each sync window (see DESIGN.md §Event-Core for
/// why promoting them to global events changes router observations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Fault injection: entry `idx` of the cluster's fault timeline
    /// fires (DESIGN.md §Faults). Ranked before every other kind so a
    /// fault scheduled exactly at a tick or arrival hits the fleet the
    /// tick/arrival then observes — mirroring the stepping loop's
    /// fault-before-tick-before-arrival ordering at equal instants.
    Fault { idx: usize },
    /// Elastic-fleet autoscaler evaluation at a fixed cadence.
    AutoscaleTick,
    /// Multi-tenant admission pump: drain the tenant arbiter's queues
    /// into replicas whose gate has freed (DESIGN.md §Multi-Tenant).
    /// Ranked after `AutoscaleTick` so an admission at a shared instant
    /// sees the tick's fleet resize, and before replica-local
    /// completions/arrivals like the tick itself.
    TenantTick,
    /// Telemetry time-series sampling tick (DESIGN.md §Telemetry):
    /// advance every replica to the instant and record fleet gauges.
    /// Ranked after `TenantTick` so the sample sees the instant's
    /// admissions, and before replica-local completions/arrivals like
    /// the other ticks.
    TelemetryTick,
    /// A disaggregated prefill→decode KV handoff lands on `replica`.
    HandoffDone { replica: usize },
    /// A KV page migration (paging layer) completes on `replica`.
    MigrationDone { replica: usize },
    /// A prefill batch completes on `replica`.
    PrefillDone { replica: usize },
    /// A decode round completes on `replica`.
    DecodeTick { replica: usize },
    /// An open-loop request (arena handle) reaches the front door.
    Arrival { req: ReqId },
}

impl EventKind {
    /// Same-timestamp rank: lower pops first. `AutoscaleTick` precedes
    /// `Arrival` at equal times (the stepping loop fires due ticks
    /// before admitting the arrival that exposed them); replica-local
    /// completions sort between the two so injected work lands before
    /// the next admission reads router state.
    fn class(self) -> u8 {
        match self {
            EventKind::Fault { .. } => 0,
            EventKind::AutoscaleTick => 1,
            EventKind::TenantTick => 2,
            EventKind::TelemetryTick => 3,
            EventKind::HandoffDone { .. } => 4,
            EventKind::MigrationDone { .. } => 5,
            EventKind::PrefillDone { .. } => 6,
            EventKind::DecodeTick { .. } => 7,
            EventKind::Arrival { .. } => 8,
        }
    }
}

/// One scheduled event. `seq` is assigned by the calendar at push time
/// and exposed so tests can assert the FIFO tie-break directly.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time: Seconds,
    pub kind: EventKind,
    pub seq: u64,
}

/// Max-heap entry with reversed ordering, so `BinaryHeap::pop` yields
/// the minimum `(time, class, seq)`. Times are finite (enforced by
/// `Seconds::new`), so `total_cmp` agrees with the naive `<` everywhere
/// it matters while staying a total order.
struct HeapEntry(Event);

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .0
            .time
            .value()
            .total_cmp(&self.0.time.value())
            .then_with(|| other.0.kind.class().cmp(&self.0.kind.class()))
            .then_with(|| other.0.seq.cmp(&self.0.seq))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for HeapEntry {}

/// The event calendar: a deterministic min-heap of [`Event`]s.
#[derive(Default)]
pub struct EventCalendar {
    heap: BinaryHeap<HeapEntry>,
    next_seq: u64,
    /// Time of the last popped event, as a raw f64 so the pre-first-pop
    /// sentinel can be -∞ (Seconds requires finite values).
    now: f64,
    /// `Arrival` events currently scheduled — the driver's cheap "any
    /// admissions left?" check without scanning the heap.
    arrivals: usize,
}

impl EventCalendar {
    pub fn new() -> Self {
        EventCalendar {
            heap: BinaryHeap::new(),
            next_seq: 0,
            now: f64::NEG_INFINITY,
            arrivals: 0,
        }
    }

    pub fn with_capacity(n: usize) -> Self {
        EventCalendar { heap: BinaryHeap::with_capacity(n), ..Self::new() }
    }

    /// Schedule `kind` at `time`. Returns `false` (and schedules
    /// nothing) if `time` precedes the last popped event — an event in
    /// the past can never pop in order. Scheduling exactly at the
    /// current instant is allowed and pops after anything of an equal
    /// or lower class already queued there.
    #[must_use]
    pub fn push(&mut self, time: Seconds, kind: EventKind) -> bool {
        if time.value() < self.now {
            return false;
        }
        if matches!(kind, EventKind::Arrival { .. }) {
            self.arrivals += 1;
        }
        self.heap.push(HeapEntry(Event { time, kind, seq: self.next_seq }));
        self.next_seq += 1;
        true
    }

    /// Pop the earliest event and advance the calendar's notion of now.
    pub fn pop(&mut self) -> Option<Event> {
        let e = self.heap.pop()?.0;
        self.now = e.time.value();
        if matches!(e.kind, EventKind::Arrival { .. }) {
            self.arrivals -= 1;
        }
        Some(e)
    }

    /// Timestamp of the next event without popping it.
    pub fn peek_time(&self) -> Option<Seconds> {
        self.heap.peek().map(|e| e.0.time)
    }

    /// Time of the last popped event (`None` before the first pop).
    pub fn now(&self) -> Option<Seconds> {
        self.now.is_finite().then(|| Seconds::new(self.now))
    }

    /// `Arrival` events still scheduled.
    pub fn arrivals_scheduled(&self) -> usize {
        self.arrivals
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut cal = EventCalendar::new();
        for &t in &[3.0, 1.0, 2.0, 5.0, 4.0] {
            assert!(cal.push(Seconds::new(t), EventKind::AutoscaleTick));
        }
        let times: Vec<f64> = std::iter::from_fn(|| cal.pop())
            .map(|e| e.time.value())
            .collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!(cal.is_empty());
    }

    #[test]
    fn equal_time_orders_by_class_then_seq() {
        let mut cal = EventCalendar::new();
        let t = Seconds::new(1.0);
        assert!(cal.push(t, EventKind::Arrival { req: ReqId(0) }));
        assert!(cal.push(t, EventKind::TelemetryTick));
        assert!(cal.push(t, EventKind::TenantTick));
        assert!(cal.push(t, EventKind::AutoscaleTick));
        assert!(cal.push(t, EventKind::Fault { idx: 0 }));
        assert!(cal.push(t, EventKind::Arrival { req: ReqId(1) }));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::Fault { idx: 0 }));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::AutoscaleTick));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::TenantTick));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::TelemetryTick));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::Arrival { req: ReqId(0) }));
        assert!(matches!(cal.pop().unwrap().kind, EventKind::Arrival { req: ReqId(1) }));
    }

    #[test]
    fn rejects_push_into_the_past_but_allows_now() {
        let mut cal = EventCalendar::new();
        assert!(cal.push(Seconds::new(2.0), EventKind::AutoscaleTick));
        cal.pop();
        assert!(!cal.push(Seconds::new(1.0), EventKind::AutoscaleTick));
        assert!(cal.push(Seconds::new(2.0), EventKind::AutoscaleTick));
        assert_eq!(cal.len(), 1);
    }

    #[test]
    fn tracks_scheduled_arrivals() {
        let mut cal = EventCalendar::new();
        assert!(cal.push(Seconds::new(1.0), EventKind::Arrival { req: ReqId(7) }));
        assert!(cal.push(Seconds::new(1.5), EventKind::AutoscaleTick));
        assert_eq!(cal.arrivals_scheduled(), 1);
        cal.pop();
        assert_eq!(cal.arrivals_scheduled(), 0);
        assert_eq!(cal.len(), 1);
    }

    #[test]
    fn now_is_none_before_first_pop() {
        let mut cal = EventCalendar::new();
        assert!(cal.now().is_none());
        assert!(cal.push(Seconds::new(0.0), EventKind::AutoscaleTick));
        cal.pop();
        assert_eq!(cal.now().unwrap().value(), 0.0);
    }
}
