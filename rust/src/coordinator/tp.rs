//! Tensor-parallel execution over the functional TAB pool + PJRT workers.
//!
//! This is the end-to-end composition of all three layers on the request
//! path:
//!
//! ```text
//!  coordinator (this module)         worker thread r (×TP)
//!  ───────────────────────────       ─────────────────────────────
//!  embed lookup (Rust)
//!  for each layer:
//!    alloc TAB region, zero   ──x──► PJRT: layer_shard_fwd(x, Wᵣ)
//!    wait notifications  ◄─ write-accumulate partialᵣ + notify ──┘
//!    read Σ partials, add residual
//!  final norm + lm head (Rust)
//! ```
//!
//! The inter-worker "AllReduce" is exactly the paper's §3.3.2 protocol:
//! parallel write-accumulate into shared memory, a completion
//! notification, then reads — no ring, no NVLink. The PJRT executable is
//! the HLO text produced by `python -m compile.aot` (Layer 1 Pallas
//! attention inside a Layer 2 JAX block), so numerics flow through the
//! full stack. `verify_against_full_model` cross-checks the sharded
//! pipeline against the single `model_fwd` executable.
//!
//! PJRT handles are `!Send` (`Rc` internally), so every worker owns its
//! own client/executable/weight literals; only `Vec<f32>` activations and
//! `Region` descriptors cross threads.

use super::engine::{Backend, PrefillItem};
use crate::error::{FhError, Result};
use crate::fabric::tab::{Region, TabPool};
use crate::runtime::artifacts::Bundle;
use crate::runtime::{literal_f32, literal_i32, to_vec_f32, Runtime};
use crate::units::Seconds;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// Which half of the block's partials to accumulate.
#[derive(Debug, Clone, Copy)]
pub enum Half {
    Attn,
    Ffn,
}

enum Msg {
    /// Run layer `layer` on input `x`, accumulate the given half into
    /// `region`, then notify `tag`.
    Run { layer: usize, half: Half, x: Arc<Vec<f32>>, region: Region, tag: String },
    Shutdown,
}

/// The TP pipeline: coordinator + worker threads + TAB pool.
pub struct TpPipeline {
    pool: Arc<TabPool>,
    senders: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<Result<()>>>,
    pub meta: crate::runtime::artifacts::Meta,
    embed: Vec<f32>,
    final_norm: Vec<f32>,
    x_dims: [usize; 3],
    round: u64,
}

impl TpPipeline {
    /// Spawn `meta.tp` workers, each compiling the shard HLO on its own
    /// PJRT client and caching its shard weights as literals.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let bundle = Bundle::load(artifact_dir)?;
        let meta = bundle.meta.clone();
        let tp = meta.tp;
        let x_elems = meta.batch * meta.seq * meta.hidden;
        let pool = Arc::new(TabPool::new(x_elems * 8, tp.max(2), 1024));
        let embed = bundle.tensor("embed")?.to_vec();
        let final_norm = bundle.tensor("final_norm")?.to_vec();

        let mut senders = Vec::with_capacity(tp);
        let mut handles = Vec::with_capacity(tp);
        let dir = artifact_dir.to_path_buf();
        for rank in 0..tp {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            let pool_r = Arc::clone(&pool);
            let dir_r = dir.clone();
            handles.push(std::thread::spawn(move || -> Result<()> {
                // Thread-local PJRT state.
                let bundle = Bundle::load(&dir_r)?;
                let rt = Runtime::cpu()?;
                let exe = rt.load_hlo(&bundle.hlo_path("layer_shard_fwd"))?;
                // Cache shard weight literals per layer.
                let mut weights: Vec<Vec<xla::Literal>> = Vec::new();
                for layer in 0..bundle.meta.layers {
                    let names = Bundle::shard_tensor_names(layer, rank);
                    let lits: Result<Vec<_>> =
                        names.iter().map(|n| bundle.literal(n)).collect();
                    weights.push(lits?);
                }
                let b = bundle.meta.batch as i64;
                let s = bundle.meta.seq as i64;
                let h = bundle.meta.hidden as i64;
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Shutdown => break,
                        Msg::Run { layer, half, x, region, tag } => {
                            let x_lit = literal_f32(&x, &[b, s, h])?;
                            let mut inputs = vec![x_lit];
                            // Re-borrowing literals for execute: the xla
                            // crate takes Borrow<Literal>, so pass refs
                            // via clone-free slice construction below.
                            let outs = {
                                let mut all: Vec<&xla::Literal> = Vec::with_capacity(10);
                                all.push(&inputs[0]);
                                for w in &weights[layer] {
                                    all.push(w);
                                }
                                exe_run(&exe, &all)?
                            };
                            inputs.clear();
                            let idx = match half {
                                Half::Attn => 0,
                                Half::Ffn => 1,
                            };
                            let partial = to_vec_f32(&outs[idx])?;
                            pool_r.write_accumulate(region, 0, &partial)?;
                            pool_r.notify(&tag, 1);
                        }
                    }
                }
                Ok(())
            }));
        }
        let x_dims = [meta.batch, meta.seq, meta.hidden];
        Ok(TpPipeline { pool, senders, handles, meta, embed, final_norm, x_dims, round: 0 })
    }

    fn tp(&self) -> usize {
        self.senders.len()
    }

    /// One accumulated half-layer across all workers.
    fn half_layer(&mut self, layer: usize, half: Half, x: &Arc<Vec<f32>>) -> Result<Vec<f32>> {
        let elems = x.len();
        let region = self.pool.alloc(elems)?;
        self.pool.zero(region)?;
        self.round += 1;
        let tag = format!("tp:{}", self.round);
        for tx in &self.senders {
            tx.send(Msg::Run { layer, half, x: Arc::clone(x), region, tag: tag.clone() })
                .map_err(|_| FhError::Serving("worker channel closed".into()))?;
        }
        self.pool.wait_notifications(&tag, self.tp() as u64);
        let sum = self.pool.read(region, 0, elems)?;
        self.pool.free(region);
        self.pool.reset_notifications(&tag);
        Ok(sum)
    }

    /// Full forward through the sharded pipeline: tokens [batch][seq]
    /// (padded to meta.seq) → logits [batch, seq, vocab].
    pub fn forward(&mut self, tokens: &[Vec<i32>]) -> Result<Vec<f32>> {
        let [b, s, h] = self.x_dims;
        if tokens.len() != b || tokens.iter().any(|t| t.len() != s) {
            return Err(FhError::Serving(format!(
                "tp forward needs exactly [{b}, {s}] tokens"
            )));
        }
        // Embed lookup.
        let vocab = self.meta.vocab;
        let mut x = vec![0f32; b * s * h];
        for (bi, row) in tokens.iter().enumerate() {
            for (si, &t) in row.iter().enumerate() {
                let t = (t as usize).min(vocab - 1);
                let src = &self.embed[t * h..(t + 1) * h];
                x[(bi * s + si) * h..(bi * s + si + 1) * h].copy_from_slice(src);
            }
        }
        for layer in 0..self.meta.layers {
            let xa = Arc::new(x.clone());
            let attn = self.half_layer(layer, Half::Attn, &xa)?;
            for (xi, ai) in x.iter_mut().zip(&attn) {
                *xi += ai;
            }
            let xf = Arc::new(x.clone());
            let ffn = self.half_layer(layer, Half::Ffn, &xf)?;
            for (xi, fi) in x.iter_mut().zip(&ffn) {
                *xi += fi;
            }
        }
        // Final RMS norm + tied lm head (coordinator-side epilogue).
        let eps = 1e-6f32;
        let mut logits = vec![0f32; b * s * vocab];
        for row in 0..b * s {
            let xr = &mut x[row * h..(row + 1) * h];
            let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / h as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            for (v, w) in xr.iter_mut().zip(&self.final_norm) {
                *v = *v * inv * w;
            }
            let out = &mut logits[row * vocab..(row + 1) * vocab];
            for (t, o) in out.iter_mut().enumerate() {
                let e = &self.embed[t * h..(t + 1) * h];
                *o = xr.iter().zip(e).map(|(a, b)| a * b).sum();
            }
        }
        Ok(logits)
    }

    /// TAB-pool traffic stats (observability for the example).
    pub fn pool_stats(&self) -> crate::fabric::tab::TabStatsSnapshot {
        self.pool.stats_snapshot()
    }
}

impl Drop for TpPipeline {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn exe_run(exe: &crate::runtime::Executable, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
    // The Executable::run signature takes owned literals via Borrow; we
    // re-expose a reference path here to avoid cloning weight literals.
    exe.run_refs(inputs)
}

// ---------------------------------------------------------------------------
// Full-model PJRT backend (single executable) for the serving loop.
// ---------------------------------------------------------------------------

/// Serving backend running the `model_fwd` artifact on one PJRT client.
pub struct PjrtBackend {
    exe: crate::runtime::Executable,
    params: Vec<xla::Literal>,
    pub meta: crate::runtime::artifacts::Meta,
}

impl PjrtBackend {
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let bundle = Bundle::load(artifact_dir)?;
        let rt = Runtime::cpu()?;
        let exe = rt.load_hlo(&bundle.hlo_path("model_fwd"))?;
        let mut names = vec!["embed".to_string(), "final_norm".to_string()];
        for l in 0..bundle.meta.layers {
            names.extend(Bundle::layer_tensor_names(l));
        }
        let params: Result<Vec<_>> = names.iter().map(|n| bundle.literal(n)).collect();
        Ok(PjrtBackend { exe, params: params?, meta: bundle.meta.clone() })
    }

    /// Run the model on padded tokens [batch][seq]; returns logits flat
    /// [batch*seq*vocab].
    pub fn forward(&self, tokens: &[Vec<i32>]) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let s = self.meta.seq;
        if tokens.len() != b || tokens.iter().any(|t| t.len() != s) {
            return Err(FhError::Serving(format!("model_fwd needs [{b}, {s}] tokens")));
        }
        let flat: Vec<i32> = tokens.iter().flatten().copied().collect();
        let tok_lit = literal_i32(&flat, &[b as i64, s as i64])?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(1 + self.params.len());
        inputs.push(&tok_lit);
        for p in &self.params {
            inputs.push(p);
        }
        let out = self.exe.run_refs(&inputs)?;
        to_vec_f32(&out[0])
    }

    fn argmax_at(&self, logits: &[f32], batch_idx: usize, pos: usize) -> i32 {
        let v = self.meta.vocab;
        let s = self.meta.seq;
        let row = &logits[(batch_idx * s + pos) * v..(batch_idx * s + pos + 1) * v];
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Pad per-request token sequences into the fixed [batch, seq] frame.
    fn pad_frame(&self, seqs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let b = self.meta.batch;
        let s = self.meta.seq;
        if seqs.len() > b {
            return Err(FhError::Serving(format!("batch {} > artifact batch {b}", seqs.len())));
        }
        if let Some(too_long) = seqs.iter().find(|q| q.len() > s) {
            return Err(FhError::Serving(format!(
                "sequence length {} exceeds artifact seq {s}",
                too_long.len()
            )));
        }
        let mut frame = vec![vec![0i32; s]; b];
        for (bi, q) in seqs.iter().enumerate() {
            frame[bi][..q.len()].copy_from_slice(q);
        }
        Ok(frame)
    }
}

impl Backend for PjrtBackend {
    fn max_concurrency(&self) -> usize {
        self.meta.batch
    }

    fn prefill(&mut self, items: &[PrefillItem], _padded: usize) -> Result<(Seconds, Vec<i32>)> {
        let start = Instant::now();
        let seqs: Vec<&[i32]> = items.iter().map(|i| i.tokens.as_slice()).collect();
        let frame = self.pad_frame(&seqs)?;
        let logits = self.forward(&frame)?;
        let toks = items
            .iter()
            .enumerate()
            .map(|(bi, it)| self.argmax_at(&logits, bi, it.tokens.len() - 1))
            .collect();
        Ok((Seconds::new(start.elapsed().as_secs_f64()), toks))
    }

    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)> {
        let start = Instant::now();
        let refs: Vec<&[i32]> = seqs.iter().map(|s| s.as_slice()).collect();
        let frame = self.pad_frame(&refs)?;
        let logits = self.forward(&frame)?;
        let toks = seqs
            .iter()
            .enumerate()
            .map(|(bi, s)| self.argmax_at(&logits, bi, s.len() - 1))
            .collect();
        Ok((Seconds::new(start.elapsed().as_secs_f64()), toks))
    }
}

/// Cross-check: sharded-TAB pipeline ≡ single full-model executable.
/// Returns the max absolute logit difference.
pub fn verify_against_full_model(
    tp: &mut TpPipeline,
    full: &PjrtBackend,
    tokens: &[Vec<i32>],
) -> Result<f32> {
    let a = tp.forward(tokens)?;
    let b = full.forward(tokens)?;
    if a.len() != b.len() {
        return Err(FhError::Serving("logit shape mismatch".into()));
    }
    Ok(a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0f32, f32::max))
}
