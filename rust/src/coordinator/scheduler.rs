//! Prefill/decode scheduler: the serving loop.
//!
//! Continuous batching with prefill priority: whenever queue room exists,
//! waiting requests are prefilled as a batch; otherwise one decode round
//! advances every active sequence by a token. The clock is virtual for the
//! simulation backend (advanced by modelled step times) and real for the
//! PJRT backend (advanced by measured wall time) — the same scheduler
//! drives both, which is what makes the end-to-end example a true test of
//! the coordinator.
//!
//! The loop is exposed at three granularities so a [`cluster`] of
//! replicas can co-simulate on a shared virtual clock:
//!
//! * [`Scheduler::step`] — one scheduling action;
//! * [`Scheduler::run_until`] — advance to a global timestamp;
//! * [`Scheduler::run_to_completion`] — drain everything (single-node
//!   behaviour, unchanged).
//!
//! A replica can also serve a single *role* in a disaggregated pool
//! ([`SchedMode`]): prefill-only replicas emit [`Handoff`]s instead of
//! decoding, and decode-only replicas adopt handed-off sequences via
//! [`Scheduler::inject`] once the KV transfer completes.
//!
//! [`cluster`]: super::cluster

use super::batcher::Batcher;
use super::engine::{Backend, PrefillItem};
use super::metrics::Metrics;
use super::request::{Request, Response};
use crate::error::Result;
use crate::faults::CompletionEvent;
use crate::telemetry::{RequestSpan, SpanKind, SpanStart};
use crate::units::Seconds;
use std::collections::VecDeque;

/// Which phases of the serving loop this scheduler runs (DESIGN.md §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedMode {
    /// Aggregated serving: prefill and decode on the same replica.
    #[default]
    Full,
    /// Disaggregated prefill pool member: prefill batches, then hand the
    /// sequence (KV state) off instead of decoding.
    PrefillOnly,
    /// Disaggregated decode pool member: no local prefill; sequences
    /// arrive via [`Scheduler::inject`].
    DecodeOnly,
}

/// A prefilled sequence leaving a prefill-only replica: everything the
/// decode side needs to continue generation. The KV cache itself moves
/// over the fabric; the transfer cost is charged by the cluster layer
/// ([`FabricLatencies::kv_handoff`]).
///
/// [`FabricLatencies::kv_handoff`]: crate::fabric::FabricLatencies::kv_handoff
#[derive(Debug, Clone)]
pub struct Handoff {
    pub req: Request,
    /// Prompt + first generated token.
    pub tokens: Vec<i32>,
    pub ttft: Seconds,
    pub generated: usize,
    /// Prefill-replica clock when the sequence became ready.
    pub done_at: Seconds,
}

struct Active {
    req: Request,
    tokens: Vec<i32>,
    ttft: Seconds,
    generated: usize,
    /// Prefill attribution captured when the batch ran; `None` for
    /// injected sequences (their prefill happened on another replica).
    start: Option<SpanStart>,
}

/// The serving loop coordinator.
pub struct Scheduler<B: Backend> {
    backend: B,
    batcher: Batcher,
    mode: SchedMode,
    /// Requests not yet arrived (sorted by arrival).
    future: VecDeque<Request>,
    active: Vec<Active>,
    /// Handed-off sequences waiting for their KV transfer: (ready, seq).
    injected: Vec<(Seconds, Handoff)>,
    /// Sequences handed off by a prefill-only replica.
    pub handoffs: Vec<Handoff>,
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    clock: Seconds,
    /// Per-completion trace for windowed recovery analysis (DESIGN.md
    /// §Faults). Off (and never allocated) unless [`Self::with_trace`]
    /// armed it — healthy runs skip the recording branch entirely.
    record_trace: bool,
    trace: Vec<CompletionEvent>,
    /// Per-request lifecycle spans (DESIGN.md §Telemetry). Off (and
    /// never allocated) unless [`Self::with_telemetry`] armed it —
    /// telemetry-off runs skip every recording branch.
    record_spans: bool,
    spans: Vec<RequestSpan>,
}

impl<B: Backend> Scheduler<B> {
    pub fn new(backend: B, batcher: Batcher) -> Self {
        Scheduler {
            backend,
            batcher,
            mode: SchedMode::Full,
            future: VecDeque::new(),
            active: Vec::new(),
            injected: Vec::new(),
            handoffs: Vec::new(),
            metrics: Metrics::default(),
            responses: Vec::new(),
            clock: Seconds::ZERO,
            record_trace: false,
            trace: Vec::new(),
            record_spans: false,
            spans: Vec::new(),
        }
    }

    /// Set the disaggregation role (default [`SchedMode::Full`]).
    pub fn with_mode(mut self, mode: SchedMode) -> Self {
        self.mode = mode;
        self
    }

    /// Record a [`CompletionEvent`] per finished request (the fault
    /// layer's recovery-window input). Default off.
    pub fn with_trace(mut self) -> Self {
        self.record_trace = true;
        self
    }

    /// Completion trace recorded under [`Self::with_trace`].
    pub fn trace(&self) -> &[CompletionEvent] {
        &self.trace
    }

    /// Record a [`RequestSpan`] per completed lifecycle phase and charge
    /// the metrics stall ledger (DESIGN.md §Telemetry). Default off.
    pub fn with_telemetry(mut self) -> Self {
        self.record_spans = true;
        self
    }

    /// Drain the recorded spans (cluster report assembly stamps the
    /// replica index on them).
    pub fn take_spans(&mut self) -> Vec<RequestSpan> {
        std::mem::take(&mut self.spans)
    }

    pub fn mode(&self) -> SchedMode {
        self.mode
    }

    /// Submit a workload (requests may have future arrival times; must be
    /// sorted by arrival).
    pub fn submit_all(&mut self, mut reqs: Vec<Request>) {
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        self.future.extend(reqs);
    }

    /// Adopt a prefilled sequence from another replica; it becomes
    /// decodable once the clock reaches `ready` (KV transfer complete).
    pub fn inject(&mut self, handoff: Handoff, ready: Seconds) {
        self.injected.push((ready, handoff));
    }

    /// Whether this replica's batcher would accept the request (the
    /// cluster consults this before charging the router).
    pub fn admits(&self, req: &Request) -> bool {
        self.batcher.admits(req)
    }

    /// Outstanding work: queued + active + in-flight injected sequences.
    pub fn pending(&self) -> usize {
        self.batcher.queued() + self.active.len() + self.injected.len() + self.future.len()
    }

    fn admit_arrived(&mut self) {
        while let Some(front) = self.future.front() {
            if front.arrival <= self.clock {
                let req = self.future.pop_front().unwrap();
                if !self.batcher.submit(req) {
                    self.metrics.rejected += 1;
                }
            } else {
                break;
            }
        }
    }

    fn admit_injected(&mut self) {
        let clock = self.clock;
        // Earliest-ready first, and never beyond the backend's
        // concurrency cap — a decode-pool replica must queue overflow
        // exactly like an aggregated replica would.
        loop {
            if self.active.len() >= self.backend.max_concurrency() {
                break;
            }
            let mut best: Option<usize> = None;
            for (i, (ready, _)) in self.injected.iter().enumerate() {
                if *ready <= clock && best.map_or(true, |b| *ready < self.injected[b].0) {
                    best = Some(i);
                }
            }
            let Some(i) = best else { break };
            let (_, h) = self.injected.swap_remove(i);
            self.active.push(Active {
                req: h.req,
                tokens: h.tokens,
                ttft: h.ttft,
                generated: h.generated,
                start: None,
            });
        }
        // A handed-off request may already have hit its generation budget
        // (max_new_tokens == 1): complete it without a decode step.
        self.finish_done();
    }

    /// Earliest future event (arrival or injected-ready) strictly ahead
    /// of the clock, if any.
    fn next_event_time(&self) -> Option<Seconds> {
        let arrival = self.future.front().map(|r| r.arrival);
        let ready = self
            .injected
            .iter()
            .map(|(t, _)| *t)
            .min_by(|a, b| a.partial_cmp(b).unwrap());
        match (arrival, ready) {
            (Some(a), Some(r)) => Some(a.min(r)),
            (Some(a), None) => Some(a),
            (None, Some(r)) => Some(r),
            (None, None) => None,
        }
    }

    /// The scheduling core: a prefill batch, a decode round, or an idle
    /// jump to the next event. With a `limit`, work that would start at or
    /// beyond the limit (and idle jumps past it) is deferred instead.
    /// Returns false when nothing was done.
    fn step_bounded(&mut self, limit: Option<Seconds>) -> Result<bool> {
        self.admit_arrived();
        self.admit_injected();
        let past = |t: Seconds| limit.is_some_and(|l| t >= l);
        let room = self.backend.max_concurrency().saturating_sub(self.active.len());
        if self.batcher.queued() > 0 && room > 0 {
            if past(self.clock) {
                return Ok(false);
            }
            self.step_prefill(room)?;
        } else if !self.active.is_empty() {
            if past(self.clock) {
                return Ok(false);
            }
            self.step_decode()?;
        } else if let Some(t) = self.next_event_time() {
            if limit.is_some_and(|l| t > l) {
                return Ok(false);
            }
            // Idle: jump to the next arrival / KV-transfer completion.
            self.clock = self.clock.max(t);
        } else {
            return Ok(false);
        }
        Ok(true)
    }

    /// One scheduling action. Returns false when fully drained.
    pub fn step(&mut self) -> Result<bool> {
        self.step_bounded(None)
    }

    /// Run until the local clock reaches the cluster timestamp `t`: all
    /// work that starts before `t` executes (steps may overshoot `t` —
    /// that batch was already in flight), and an idle replica's clock
    /// advances to `t`.
    pub fn run_until(&mut self, t: Seconds) -> Result<()> {
        while self.clock < t && self.step_bounded(Some(t))? {}
        // Idle until t: catch the clock up so later load observations and
        // idle jumps stay monotone across the fleet.
        if self.clock < t && self.active.is_empty() && self.batcher.queued() == 0 {
            self.clock = t;
        }
        Ok(())
    }

    /// Run until every submitted request completes. Returns the responses.
    pub fn run_to_completion(&mut self) -> Result<&[Response]> {
        while self.step()? {}
        self.metrics.clock = self.clock;
        Ok(&self.responses)
    }

    fn step_prefill(&mut self, room: usize) -> Result<()> {
        let Some(batch) = self.batcher.next_batch(room) else {
            return Ok(());
        };
        let items: Vec<PrefillItem> = batch
            .requests
            .iter()
            .map(|r| PrefillItem { id: r.id, tokens: r.prompt.clone() })
            .collect();
        // Cached prefix tokens (DESIGN.md §Prefix-Cache) skip the compute
        // — `padded_len` already reflects the prefill lengths — but their
        // pooled KV must be fetched before attention can run over the
        // full context: the TAB read is a serial stall on the step.
        let fetch: Seconds = batch.requests.iter().map(|r| r.prefix_fetch).sum();
        // Cold-start model swaps (DESIGN.md §Multi-Tenant) stall the
        // first prefill the same way: weight paging is serial with the
        // step. Zero for every request outside the multi-tenant layer.
        let swap: Seconds = batch.requests.iter().map(|r| r.swap_stall).sum();
        let (compute, first_tokens) = self.backend.prefill(&items, batch.padded_len)?;
        // Span attribution (DESIGN.md §Telemetry) reconstructs the clock
        // advance below bitwise: `SpanStart::prefill_done` replays
        // `queue_end + ((compute + fetch) + swap)` — keep the `elapsed`
        // association in sync with it.
        let queue_end = self.clock;
        let elapsed = compute + fetch + swap;
        self.clock += elapsed;
        self.metrics.busy += elapsed;
        self.metrics.prefix_fetch += fetch;
        self.metrics.swap_stall += swap;
        for (req, first) in batch.requests.into_iter().zip(first_tokens) {
            self.metrics.prefill_tokens += req.prompt_len() as u64;
            self.metrics.prefill_tokens_saved +=
                req.cached_prefix.min(req.prompt_len()) as u64;
            let ttft = self.clock - req.arrival;
            self.metrics.ttft.record(ttft);
            let mut tokens = req.prompt.clone();
            tokens.push(first);
            self.metrics.tokens_generated += 1;
            if self.mode == SchedMode::PrefillOnly {
                // The prefill side of a handoff is this replica's last
                // sight of the request: emit its span now (the decode
                // replica emits the matching `DecodeInjected` span).
                if self.record_spans {
                    let span = RequestSpan {
                        id: req.id,
                        replica: 0,
                        tenant: req.tenant,
                        kind: SpanKind::PrefillHandoff,
                        arrival: req.arrival,
                        queue_end,
                        prefill_compute: compute,
                        prefix_fetch: fetch,
                        swap_stall: swap,
                        prefill_done: self.clock,
                        ttft,
                        finish: self.clock,
                        generated: 1,
                    };
                    self.metrics.ledger.charge(&span);
                    self.spans.push(span);
                }
                self.handoffs.push(Handoff {
                    req,
                    tokens,
                    ttft,
                    generated: 1,
                    done_at: self.clock,
                });
            } else {
                let start = Some(SpanStart { queue_end, compute, fetch, swap });
                self.active.push(Active { req, tokens, ttft, generated: 1, start });
            }
        }
        self.finish_done();
        Ok(())
    }

    fn step_decode(&mut self) -> Result<()> {
        let seqs: Vec<Vec<i32>> = self.active.iter().map(|a| a.tokens.clone()).collect();
        let (elapsed, next_tokens) = self.backend.decode_step(&seqs)?;
        self.clock += elapsed;
        self.metrics.busy += elapsed;
        // KV capacity pressure: the backend folded any paging stall into
        // `elapsed`; attribute it so fleet reports can separate it out.
        self.metrics.paging_stall += self.backend.take_paging_stall();
        let per_tok = elapsed; // one step produced one token per sequence
        for (a, tok) in self.active.iter_mut().zip(next_tokens) {
            a.tokens.push(tok);
            a.generated += 1;
            self.metrics.tokens_generated += 1;
            self.metrics.tpot.record(per_tok);
        }
        self.finish_done();
        Ok(())
    }

    fn finish_done(&mut self) {
        let clock = self.clock;
        let mut kept = Vec::with_capacity(self.active.len());
        for a in self.active.drain(..) {
            if a.generated >= a.req.max_new_tokens {
                let total = clock - a.req.arrival;
                self.metrics.e2e.record(total);
                self.metrics.completed += 1;
                // SLO scoring (DESIGN.md §Traffic): a tagged request is
                // met iff TTFT *and* mean TPOT land under target; only
                // met requests feed the goodput numerator.
                if let Some(slo) = a.req.slo {
                    self.metrics.slo_total += 1;
                    let tpot = if a.generated > 1 {
                        (total - a.ttft) / (a.generated - 1) as f64
                    } else {
                        Seconds::ZERO
                    };
                    if slo.met(a.ttft, tpot) {
                        self.metrics.slo_met += 1;
                        self.metrics.goodput_tokens += a.generated as u64;
                    }
                }
                if self.record_trace {
                    let slo_ok = a.req.slo.map(|slo| {
                        let tpot = if a.generated > 1 {
                            (total - a.ttft) / (a.generated - 1) as f64
                        } else {
                            Seconds::ZERO
                        };
                        slo.met(a.ttft, tpot)
                    });
                    self.trace.push(CompletionEvent {
                        at: clock,
                        tokens: a.generated as u64,
                        slo: slo_ok,
                        tenant: a.req.tenant,
                        ttft: a.ttft,
                    });
                }
                if self.record_spans {
                    let span = match a.start {
                        Some(st) => RequestSpan {
                            id: a.req.id,
                            replica: 0,
                            tenant: a.req.tenant,
                            kind: SpanKind::Full,
                            arrival: a.req.arrival,
                            queue_end: st.queue_end,
                            prefill_compute: st.compute,
                            prefix_fetch: st.fetch,
                            swap_stall: st.swap,
                            prefill_done: st.prefill_done(),
                            ttft: a.ttft,
                            finish: clock,
                            generated: a.generated as u64,
                        },
                        // Injected sequence: prefill was attributed on
                        // the prefill replica's `PrefillHandoff` span.
                        None => RequestSpan {
                            id: a.req.id,
                            replica: 0,
                            tenant: a.req.tenant,
                            kind: SpanKind::DecodeInjected,
                            arrival: a.req.arrival,
                            queue_end: a.req.arrival,
                            prefill_compute: Seconds::ZERO,
                            prefix_fetch: Seconds::ZERO,
                            swap_stall: Seconds::ZERO,
                            prefill_done: a.req.arrival + a.ttft,
                            ttft: a.ttft,
                            finish: clock,
                            generated: a.generated as u64,
                        },
                    };
                    self.metrics.ledger.charge(&span);
                    self.spans.push(span);
                }
                self.responses.push(Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    total,
                    generated: a.generated,
                });
            } else {
                kept.push(a);
            }
        }
        self.active = kept;
    }

    /// Crash evacuation (DESIGN.md §Faults): strip every request still
    /// owned by this replica — batcher queue, unarrived future, then the
    /// active set in batch order — and hand them back for re-routing.
    /// The second return is the generated-token count of the active set:
    /// decode progress lost with the replica's local KV. Metrics already
    /// recorded (TTFT of evacuated prefills) stay recorded, exactly as a
    /// real fleet's monitoring would have seen them.
    pub fn evacuate(&mut self) -> (Vec<Request>, u64) {
        let mut out = self.batcher.drain_queue();
        out.extend(self.future.drain(..));
        let mut lost = 0u64;
        for a in self.active.drain(..) {
            lost += a.generated as u64;
            out.push(a.req);
        }
        (out, lost)
    }

    /// Revoke cached-prefix grants for queued (not yet prefilled)
    /// requests whose home TAB module satisfies `pred` — the module died
    /// before their prefill ran, so the pooled KV no longer exists. The
    /// request re-prefills from scratch. Returns the revocation count.
    pub fn revoke_cached_prefix(&mut self, pred: impl Fn(usize) -> bool) -> usize {
        let mut n = 0usize;
        let mut revoke = |r: &mut Request| {
            if r.cached_prefix > 0 && r.prefix_home.is_some_and(&pred) {
                r.cached_prefix = 0;
                r.prefix_fetch = Seconds::ZERO;
                r.prefix_home = None;
                n += 1;
            }
        };
        self.batcher.for_each_queued_mut(&mut revoke);
        for r in self.future.iter_mut() {
            revoke(r);
        }
        n
    }

    pub fn clock(&self) -> Seconds {
        self.clock
    }

    /// Shared view of the execution backend (the cluster layer reads the
    /// node config off it for KV-handoff costing).
    pub fn backend(&self) -> &B {
        &self.backend
    }
}

impl Scheduler<super::engine::SimBackend> {
    /// Repoint this replica at a different model (multi-tenant cold
    /// start, DESIGN.md §Multi-Tenant). The admission limit follows the
    /// new model's context window; the backend reprices its step caches.
    pub fn set_model(&mut self, model: crate::models::arch::ModelArch) {
        self.batcher.max_prompt = model.max_seq as usize;
        self.backend.set_model(model);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockBackend;

    fn req(id: u64, len: usize, gen: usize, arrival_ms: f64) -> Request {
        Request {
            id,
            prompt: vec![(id % 7) as i32 + 1; len],
            max_new_tokens: gen,
            arrival: Seconds::ms(arrival_ms),
            ..Default::default()
        }
    }

    fn run(reqs: Vec<Request>, max_conc: usize) -> (Vec<Response>, Metrics) {
        let backend = MockBackend::new(max_conc, Seconds::ms(10.0), Seconds::ms(1.0));
        let batcher = Batcher::new(max_conc, 64, 4096);
        let mut s = Scheduler::new(backend, batcher);
        s.submit_all(reqs);
        s.run_to_completion().unwrap();
        (s.responses.clone(), s.metrics.clone())
    }

    #[test]
    fn all_requests_complete() {
        let reqs: Vec<_> = (0..10).map(|i| req(i, 32, 4, 0.0)).collect();
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 10);
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 40);
        // Every response carries prompt + generated tokens.
        for r in &resp {
            assert_eq!(r.tokens.len(), 32 + 4);
        }
    }

    #[test]
    fn ttft_includes_queueing_delay() {
        // 8 same-arrival requests, concurrency 4: the second wave queues
        // behind the first wave's prefill+decode.
        let reqs: Vec<_> = (0..8).map(|i| req(i, 16, 2, 0.0)).collect();
        let (resp, _) = run(reqs, 4);
        let mut ttfts: Vec<f64> = resp.iter().map(|r| r.ttft.as_ms()).collect();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(ttfts[7] > ttfts[0], "queued wave must see larger TTFT");
    }

    #[test]
    fn idle_clock_jumps_to_next_arrival() {
        let reqs = vec![req(0, 16, 1, 0.0), req(1, 16, 1, 500.0)];
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 2);
        // Second request arrives at 500 ms; wall clock must pass it.
        assert!(m.clock.as_ms() >= 500.0);
        // But its TTFT is small (no queueing).
        let r1 = resp.iter().find(|r| r.id == 1).unwrap();
        assert!(r1.ttft.as_ms() < 50.0, "ttft {}", r1.ttft.as_ms());
    }

    #[test]
    fn oversized_prompts_are_rejected_not_hung() {
        let mut reqs = vec![req(0, 16, 2, 0.0)];
        reqs.push(req(1, 100_000, 2, 0.0));
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 1);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn continuous_batching_admits_midstream() {
        // Long-running decode + late arrival: the late request must be
        // prefilled while the first is still decoding (completed count
        // proves no deadlock; TTFT of the late one stays bounded).
        let reqs = vec![req(0, 16, 50, 0.0), req(1, 16, 2, 20.0)];
        let (resp, _) = run(reqs, 4);
        assert_eq!(resp.len(), 2);
        let late = resp.iter().find(|r| r.id == 1).unwrap();
        assert!(late.ttft.as_ms() < 100.0, "late ttft {}", late.ttft.as_ms());
    }

    #[test]
    fn run_until_stops_at_timestamp_and_catches_up_idle_clock() {
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut s = Scheduler::new(backend, Batcher::new(4, 64, 4096));
        s.submit_all(vec![req(0, 16, 4, 0.0), req(1, 16, 4, 900.0)]);
        // Run to t=100 ms: request 0 (prefill 10 + 3 decodes) is done,
        // request 1 has not arrived, and the idle clock sits at t.
        s.run_until(Seconds::ms(100.0)).unwrap();
        assert_eq!(s.metrics.completed, 1);
        assert!((s.clock().as_ms() - 100.0).abs() < 1e-9, "clock {}", s.clock().as_ms());
        assert_eq!(s.pending(), 1);
        // Draining picks up the second request.
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.completed, 2);
    }

    #[test]
    fn busy_time_excludes_idle_gaps() {
        let reqs = vec![req(0, 16, 2, 0.0), req(1, 16, 2, 500.0)];
        let (_, m) = run(reqs, 4);
        // Two prefills (10 ms) + two decode rounds (1 ms) each ≈ 22 ms of
        // busy time against a ≥500 ms clock.
        assert!(m.busy.as_ms() < 30.0, "busy {}", m.busy.as_ms());
        assert!(m.clock.as_ms() >= 500.0);
        assert!(m.utilization() < 0.1);
    }

    #[test]
    fn slo_scoring_counts_met_and_missed_requests() {
        use crate::coordinator::request::SloTarget;
        // MockBackend: prefill 10 ms, decode 1 ms → TTFT ≈ 10 ms,
        // TPOT = 1 ms for a lone request.
        let mut generous = req(0, 16, 4, 0.0);
        generous.slo = Some(SloTarget { ttft: Seconds::ms(50.0), tpot: Seconds::ms(5.0) });
        let mut strict = req(1, 16, 4, 0.0);
        strict.slo = Some(SloTarget { ttft: Seconds::us(1.0), tpot: Seconds::ms(5.0) });
        let untracked = req(2, 16, 4, 0.0);
        let (_, m) = run(vec![generous, strict, untracked], 4);
        assert_eq!(m.completed, 3);
        assert_eq!(m.slo_total, 2, "untracked requests stay out of attainment");
        assert_eq!(m.slo_met, 1);
        assert_eq!(m.goodput_tokens, 4, "only the met request's tokens are goodput");
        assert!((m.slo_attainment() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prefix_fetch_is_charged_and_saved_tokens_counted() {
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut s = Scheduler::new(backend, Batcher::new(4, 64, 4096));
        let mut hit = req(0, 64, 2, 0.0);
        hit.cached_prefix = 48;
        hit.prefix_fetch = Seconds::ms(3.0);
        s.submit_all(vec![hit, req(1, 64, 2, 0.0)]);
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.completed, 2);
        assert_eq!(s.metrics.prefill_tokens, 128);
        assert_eq!(s.metrics.prefill_tokens_saved, 48);
        assert_eq!(s.metrics.prefix_fetch, Seconds::ms(3.0));
        // The fetch stall lands on the batch's TTFT: prefill 10 ms +
        // fetch 3 ms, before any decode round.
        let hit_resp = s.responses.iter().find(|r| r.id == 0).unwrap();
        assert!(hit_resp.ttft.as_ms() >= 13.0 - 1e-9, "ttft {}", hit_resp.ttft.as_ms());
        // An uncached run charges no fetch and saves nothing.
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut plain = Scheduler::new(backend, Batcher::new(4, 64, 4096));
        plain.submit_all(vec![req(2, 64, 2, 0.0)]);
        plain.run_to_completion().unwrap();
        assert_eq!(plain.metrics.prefill_tokens_saved, 0);
        assert_eq!(plain.metrics.prefix_fetch, Seconds::ZERO);
        assert_eq!(plain.metrics.prefill_tokens, 64);
    }

    #[test]
    fn prefill_only_hands_off_instead_of_decoding() {
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut s = Scheduler::new(backend, Batcher::new(4, 64, 4096))
            .with_mode(SchedMode::PrefillOnly);
        s.submit_all((0..6).map(|i| req(i, 16, 8, 0.0)).collect());
        s.run_to_completion().unwrap();
        assert_eq!(s.handoffs.len(), 6);
        assert_eq!(s.metrics.completed, 0, "prefill pool never completes requests");
        assert_eq!(s.metrics.ttft.count(), 6, "TTFT is measured at prefill");
        for h in &s.handoffs {
            assert_eq!(h.generated, 1);
            assert_eq!(h.tokens.len(), 16 + 1);
            assert!(h.done_at > Seconds::ZERO);
        }
    }

    #[test]
    fn decode_only_resumes_injected_sequences() {
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut s =
            Scheduler::new(backend, Batcher::new(4, 64, 4096)).with_mode(SchedMode::DecodeOnly);
        let h = Handoff {
            req: req(7, 16, 4, 0.0),
            tokens: vec![1; 17],
            ttft: Seconds::ms(12.0),
            generated: 1,
            done_at: Seconds::ms(12.0),
        };
        // KV transfer lands at 50 ms; decode must not start earlier.
        s.inject(h, Seconds::ms(50.0));
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.completed, 1);
        let r = &s.responses[0];
        assert_eq!(r.generated, 4);
        assert_eq!(r.tokens.len(), 17 + 3);
        assert_eq!(r.ttft, Seconds::ms(12.0), "handoff TTFT is preserved");
        // 3 decode steps after the 50 ms transfer.
        assert!(r.total.as_ms() >= 53.0 - 1e-9, "total {}", r.total.as_ms());
    }

    #[test]
    fn telemetry_spans_conserve_ttft_and_leave_the_clock_untouched() {
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut s = Scheduler::new(backend, Batcher::new(4, 64, 4096)).with_telemetry();
        s.submit_all((0..6).map(|i| req(i, 16, 3, 0.0)).collect());
        s.run_to_completion().unwrap();
        assert_eq!(s.metrics.ledger.spans, 6);
        let spans = s.take_spans();
        assert_eq!(spans.len(), 6);
        for sp in &spans {
            assert!(sp.conserves_ttft(), "span {} drifted", sp.id);
            assert_eq!(sp.kind, SpanKind::Full);
            assert_eq!(sp.generated, 3);
        }
        // Recording is pure observation: the same run without telemetry
        // lands on a bit-identical clock and records no ledger.
        let backend = MockBackend::new(4, Seconds::ms(10.0), Seconds::ms(1.0));
        let mut off = Scheduler::new(backend, Batcher::new(4, 64, 4096));
        off.submit_all((0..6).map(|i| req(i, 16, 3, 0.0)).collect());
        off.run_to_completion().unwrap();
        assert!(off.metrics.ledger.is_zero());
        assert!(off.take_spans().is_empty());
        assert_eq!(off.clock().value().to_bits(), s.clock().value().to_bits());
        assert_eq!(off.metrics.completed, s.metrics.completed);
    }
}
