//! Prefill/decode scheduler: the serving loop.
//!
//! Continuous batching with prefill priority: whenever queue room exists,
//! waiting requests are prefilled as a batch; otherwise one decode round
//! advances every active sequence by a token. The clock is virtual for the
//! simulation backend (advanced by modelled step times) and real for the
//! PJRT backend (advanced by measured wall time) — the same scheduler
//! drives both, which is what makes the end-to-end example a true test of
//! the coordinator.

use super::batcher::Batcher;
use super::engine::{Backend, PrefillItem};
use super::metrics::Metrics;
use super::request::{Request, Response};
use crate::error::Result;
use crate::units::Seconds;
use std::collections::VecDeque;

struct Active {
    req: Request,
    tokens: Vec<i32>,
    ttft: Seconds,
    generated: usize,
}

/// The serving loop coordinator.
pub struct Scheduler<B: Backend> {
    backend: B,
    batcher: Batcher,
    /// Requests not yet arrived (sorted by arrival).
    future: VecDeque<Request>,
    active: Vec<Active>,
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    clock: Seconds,
}

impl<B: Backend> Scheduler<B> {
    pub fn new(backend: B, batcher: Batcher) -> Self {
        Scheduler {
            backend,
            batcher,
            future: VecDeque::new(),
            active: Vec::new(),
            metrics: Metrics::default(),
            responses: Vec::new(),
            clock: Seconds::ZERO,
        }
    }

    /// Submit a workload (requests may have future arrival times; must be
    /// sorted by arrival).
    pub fn submit_all(&mut self, mut reqs: Vec<Request>) {
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        self.future.extend(reqs);
    }

    fn admit_arrived(&mut self) {
        while let Some(front) = self.future.front() {
            if front.arrival <= self.clock {
                let req = self.future.pop_front().unwrap();
                if !self.batcher.submit(req) {
                    self.metrics.rejected += 1;
                }
            } else {
                break;
            }
        }
    }

    /// Run until every submitted request completes. Returns the responses.
    pub fn run_to_completion(&mut self) -> Result<&[Response]> {
        loop {
            self.admit_arrived();
            let room = self.backend.max_concurrency().saturating_sub(self.active.len());
            if self.batcher.queued() > 0 && room > 0 {
                self.step_prefill(room)?;
            } else if !self.active.is_empty() {
                self.step_decode()?;
            } else if let Some(front) = self.future.front() {
                // Idle: jump to the next arrival.
                self.clock = front.arrival;
            } else {
                break;
            }
        }
        self.metrics.clock = self.clock;
        Ok(&self.responses)
    }

    fn step_prefill(&mut self, room: usize) -> Result<()> {
        let Some(batch) = self.batcher.next_batch(room) else {
            return Ok(());
        };
        let items: Vec<PrefillItem> = batch
            .requests
            .iter()
            .map(|r| PrefillItem { id: r.id, tokens: r.prompt.clone() })
            .collect();
        let (elapsed, first_tokens) = self.backend.prefill(&items, batch.padded_len)?;
        self.clock += elapsed;
        for (req, first) in batch.requests.into_iter().zip(first_tokens) {
            let ttft = self.clock - req.arrival;
            self.metrics.ttft.record(ttft);
            let mut tokens = req.prompt.clone();
            tokens.push(first);
            self.metrics.tokens_generated += 1;
            self.active.push(Active { req, tokens, ttft, generated: 1 });
        }
        self.finish_done();
        Ok(())
    }

    fn step_decode(&mut self) -> Result<()> {
        let seqs: Vec<Vec<i32>> = self.active.iter().map(|a| a.tokens.clone()).collect();
        let (elapsed, next_tokens) = self.backend.decode_step(&seqs)?;
        self.clock += elapsed;
        let per_tok = elapsed; // one step produced one token per sequence
        for (a, tok) in self.active.iter_mut().zip(next_tokens) {
            a.tokens.push(tok);
            a.generated += 1;
            self.metrics.tokens_generated += 1;
            self.metrics.tpot.record(per_tok);
        }
        self.finish_done();
        Ok(())
    }

    fn finish_done(&mut self) {
        let clock = self.clock;
        let mut kept = Vec::with_capacity(self.active.len());
        for a in self.active.drain(..) {
            if a.generated >= a.req.max_new_tokens {
                let total = clock - a.req.arrival;
                self.metrics.e2e.record(total);
                self.metrics.completed += 1;
                self.responses.push(Response {
                    id: a.req.id,
                    tokens: a.tokens,
                    ttft: a.ttft,
                    total,
                    generated: a.generated,
                });
            } else {
                kept.push(a);
            }
        }
        self.active = kept;
    }

    pub fn clock(&self) -> Seconds {
        self.clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockBackend;

    fn req(id: u64, len: usize, gen: usize, arrival_ms: f64) -> Request {
        Request {
            id,
            prompt: vec![(id % 7) as i32 + 1; len],
            max_new_tokens: gen,
            arrival: Seconds::ms(arrival_ms),
        }
    }

    fn run(reqs: Vec<Request>, max_conc: usize) -> (Vec<Response>, Metrics) {
        let backend = MockBackend::new(max_conc, Seconds::ms(10.0), Seconds::ms(1.0));
        let batcher = Batcher::new(max_conc, 64, 4096);
        let mut s = Scheduler::new(backend, batcher);
        s.submit_all(reqs);
        s.run_to_completion().unwrap();
        (s.responses.clone(), s.metrics.clone())
    }

    #[test]
    fn all_requests_complete() {
        let reqs: Vec<_> = (0..10).map(|i| req(i, 32, 4, 0.0)).collect();
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 10);
        assert_eq!(m.completed, 10);
        assert_eq!(m.tokens_generated, 40);
        // Every response carries prompt + generated tokens.
        for r in &resp {
            assert_eq!(r.tokens.len(), 32 + 4);
        }
    }

    #[test]
    fn ttft_includes_queueing_delay() {
        // 8 same-arrival requests, concurrency 4: the second wave queues
        // behind the first wave's prefill+decode.
        let reqs: Vec<_> = (0..8).map(|i| req(i, 16, 2, 0.0)).collect();
        let (resp, _) = run(reqs, 4);
        let mut ttfts: Vec<f64> = resp.iter().map(|r| r.ttft.as_ms()).collect();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(ttfts[7] > ttfts[0], "queued wave must see larger TTFT");
    }

    #[test]
    fn idle_clock_jumps_to_next_arrival() {
        let reqs = vec![req(0, 16, 1, 0.0), req(1, 16, 1, 500.0)];
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 2);
        // Second request arrives at 500 ms; wall clock must pass it.
        assert!(m.clock.as_ms() >= 500.0);
        // But its TTFT is small (no queueing).
        let r1 = resp.iter().find(|r| r.id == 1).unwrap();
        assert!(r1.ttft.as_ms() < 50.0, "ttft {}", r1.ttft.as_ms());
    }

    #[test]
    fn oversized_prompts_are_rejected_not_hung() {
        let mut reqs = vec![req(0, 16, 2, 0.0)];
        reqs.push(req(1, 100_000, 2, 0.0));
        let (resp, m) = run(reqs, 4);
        assert_eq!(resp.len(), 1);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn continuous_batching_admits_midstream() {
        // Long-running decode + late arrival: the late request must be
        // prefilled while the first is still decoding (completed count
        // proves no deadlock; TTFT of the late one stays bounded).
        let reqs = vec![req(0, 16, 50, 0.0), req(1, 16, 2, 20.0)];
        let (resp, _) = run(reqs, 4);
        assert_eq!(resp.len(), 2);
        let late = resp.iter().find(|r| r.id == 1).unwrap();
        assert!(late.ttft.as_ms() < 100.0, "late ttft {}", late.ttft.as_ms());
    }
}
