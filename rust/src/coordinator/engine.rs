//! Execution backends for the scheduler.
//!
//! * [`MockBackend`] — fixed-cost steps (scheduler unit tests).
//! * [`SimBackend`] — paper-scale models on simulated FengHuang/Baseline
//!   nodes: step costs come from the trace-driven simulator (`crate::sim`)
//!   on a virtual clock. This is what `fenghuang serve` uses.
//! * The PJRT tiny-model backend lives in `super::tp` (real compute,
//!   real wall clock, TAB-pool communication; `pjrt` feature) and drives
//!   `examples/serve_e2e.rs`.

use crate::config::SystemConfig;
use crate::error::Result;
use crate::models::arch::ModelArch;
use crate::sim;
use crate::trace::Phase;
use crate::units::Seconds;
use std::collections::HashMap;

/// One request's view handed to a prefill call.
#[derive(Debug, Clone)]
pub struct PrefillItem {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// An execution backend the scheduler can drive.
pub trait Backend {
    /// Max simultaneously active sequences.
    fn max_concurrency(&self) -> usize;
    /// Run one batched prefill at `padded_len`; return (elapsed, first
    /// generated token per item).
    fn prefill(&mut self, items: &[PrefillItem], padded_len: usize) -> Result<(Seconds, Vec<i32>)>;
    /// Advance every sequence by one token; return (elapsed, next tokens).
    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)>;
}

/// Deterministic pseudo-token (the simulation backends don't model real
/// vocabularies; serving correctness for real tokens is proven by the
/// PJRT backend).
fn pseudo_token(seed: u64) -> i32 {
    ((seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407) >> 33) % 512)
        as i32
}

/// Fixed-cost backend for scheduler tests.
pub struct MockBackend {
    conc: usize,
    prefill_cost: Seconds,
    decode_cost: Seconds,
}

impl MockBackend {
    pub fn new(conc: usize, prefill_cost: Seconds, decode_cost: Seconds) -> Self {
        MockBackend { conc, prefill_cost, decode_cost }
    }
}

impl Backend for MockBackend {
    fn max_concurrency(&self) -> usize {
        self.conc
    }

    fn prefill(&mut self, items: &[PrefillItem], _padded: usize) -> Result<(Seconds, Vec<i32>)> {
        Ok((self.prefill_cost, items.iter().map(|i| pseudo_token(i.id)).collect()))
    }

    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)> {
        Ok((self.decode_cost, seqs.iter().map(|s| pseudo_token(s.len() as u64)).collect()))
    }
}

/// Simulation backend: paper-scale model on a configured node; step costs
/// from the discrete-event simulator, memoised per (batch, length) bucket.
pub struct SimBackend {
    pub sys: SystemConfig,
    pub model: ModelArch,
    max_conc: usize,
    prefill_cache: HashMap<(u64, u64), Seconds>,
    decode_cache: HashMap<(u64, u64), Seconds>,
}

impl SimBackend {
    pub fn new(sys: SystemConfig, model: ModelArch, max_conc: usize) -> Self {
        SimBackend { sys, model, max_conc, prefill_cache: HashMap::new(), decode_cache: HashMap::new() }
    }

    fn bucket(len: u64) -> u64 {
        len.next_power_of_two().max(64)
    }
}

impl Backend for SimBackend {
    fn max_concurrency(&self) -> usize {
        self.max_conc
    }

    fn prefill(&mut self, items: &[PrefillItem], padded_len: usize) -> Result<(Seconds, Vec<i32>)> {
        let batch = items.len() as u64;
        let key = (batch, Self::bucket(padded_len as u64));
        let t = match self.prefill_cache.get(&key) {
            Some(t) => *t,
            None => {
                let r = sim::simulate(
                    &self.sys,
                    &self.model,
                    batch,
                    Phase::Prefill { prompt_len: key.1 },
                )?;
                self.prefill_cache.insert(key, r.total);
                r.total
            }
        };
        Ok((t, items.iter().map(|i| pseudo_token(i.id)).collect()))
    }

    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)> {
        let batch = seqs.len() as u64;
        let max_len = seqs.iter().map(|s| s.len()).max().unwrap_or(1) as u64;
        let key = (batch, Self::bucket(max_len));
        let t = match self.decode_cache.get(&key) {
            Some(t) => *t,
            None => {
                let r =
                    sim::simulate(&self.sys, &self.model, batch, Phase::Decode { kv_len: key.1 })?;
                self.decode_cache.insert(key, r.total);
                r.total
            }
        };
        Ok((t, seqs.iter().enumerate().map(|(i, s)| pseudo_token(s.len() as u64 + i as u64)).collect()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::models::arch::gpt3_175b;
    use crate::units::Bandwidth;

    #[test]
    fn sim_backend_costs_scale_with_length() {
        let mut b = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), gpt3_175b(), 8);
        let items: Vec<PrefillItem> =
            (0..4).map(|i| PrefillItem { id: i, tokens: vec![1; 512] }).collect();
        let (short, _) = b.prefill(&items, 512).unwrap();
        let (long, _) = b.prefill(&items, 4096).unwrap();
        assert!(long > short);
    }

    #[test]
    fn sim_backend_memoises() {
        let mut b = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), gpt3_175b(), 8);
        let seqs = vec![vec![1i32; 1000]; 4];
        let (a, _) = b.decode_step(&seqs).unwrap();
        let (c, _) = b.decode_step(&seqs).unwrap();
        assert_eq!(a, c);
        assert_eq!(b.decode_cache.len(), 1);
    }

    #[test]
    fn pseudo_tokens_in_vocab_range() {
        for i in 0..1000 {
            let t = pseudo_token(i);
            assert!((0..512).contains(&t));
        }
    }
}
