//! Execution backends for the scheduler.
//!
//! * [`MockBackend`] — fixed-cost steps (scheduler unit tests).
//! * [`SimBackend`] — paper-scale models on simulated FengHuang/Baseline
//!   nodes: step costs come from the trace-driven simulator (`crate::sim`)
//!   on a virtual clock. This is what `fenghuang serve` uses.
//! * The PJRT tiny-model backend lives in `super::tp` (real compute,
//!   real wall clock, TAB-pool communication; `pjrt` feature) and drives
//!   `examples/serve_e2e.rs`.

use crate::config::SystemConfig;
use crate::error::Result;
use crate::models::arch::ModelArch;
use crate::models::memory;
use crate::paging::KvPressure;
use crate::sim;
use crate::trace::Phase;
use crate::units::{Bytes, Seconds};
use std::collections::HashMap;

/// One request's view handed to a prefill call.
#[derive(Debug, Clone)]
pub struct PrefillItem {
    pub id: u64,
    pub tokens: Vec<i32>,
}

/// An execution backend the scheduler can drive.
pub trait Backend {
    /// Max simultaneously active sequences.
    fn max_concurrency(&self) -> usize;
    /// Run one batched prefill at `padded_len`; return (elapsed, first
    /// generated token per item).
    fn prefill(&mut self, items: &[PrefillItem], padded_len: usize) -> Result<(Seconds, Vec<i32>)>;
    /// Advance every sequence by one token; return (elapsed, next tokens).
    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)>;
    /// Drain the KV-paging stall the backend folded into the last step's
    /// elapsed time (zero for backends without KV capacity pressure; the
    /// scheduler attributes it to [`super::metrics::Metrics`]).
    fn take_paging_stall(&mut self) -> Seconds {
        Seconds::ZERO
    }
}

/// Deterministic pseudo-token (the simulation backends don't model real
/// vocabularies; serving correctness for real tokens is proven by the
/// PJRT backend).
fn pseudo_token(seed: u64) -> i32 {
    ((seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407) >> 33) % 512)
        as i32
}

/// Fixed-cost backend for scheduler tests.
pub struct MockBackend {
    conc: usize,
    prefill_cost: Seconds,
    decode_cost: Seconds,
}

impl MockBackend {
    pub fn new(conc: usize, prefill_cost: Seconds, decode_cost: Seconds) -> Self {
        MockBackend { conc, prefill_cost, decode_cost }
    }
}

impl Backend for MockBackend {
    fn max_concurrency(&self) -> usize {
        self.conc
    }

    fn prefill(&mut self, items: &[PrefillItem], _padded: usize) -> Result<(Seconds, Vec<i32>)> {
        Ok((self.prefill_cost, items.iter().map(|i| pseudo_token(i.id)).collect()))
    }

    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)> {
        Ok((self.decode_cost, seqs.iter().map(|s| pseudo_token(s.len() as u64)).collect()))
    }
}

/// Simulation backend: paper-scale model on a configured node; step costs
/// from the discrete-event simulator, memoised per (batch, length) bucket.
pub struct SimBackend {
    pub sys: SystemConfig,
    pub model: ModelArch,
    max_conc: usize,
    prefill_cache: HashMap<(u64, u64), Seconds>,
    decode_cache: HashMap<(u64, u64), Seconds>,
    /// Per-replica KV capacity pressure (None = infinite local KV, the
    /// pre-paging behaviour).
    kv: Option<KvPressure>,
    pending_stall: Seconds,
}

impl SimBackend {
    pub fn new(sys: SystemConfig, model: ModelArch, max_conc: usize) -> Self {
        SimBackend {
            sys,
            model,
            max_conc,
            prefill_cache: HashMap::new(),
            decode_cache: HashMap::new(),
            kv: None,
            pending_stall: Seconds::ZERO,
        }
    }

    /// Enable KV capacity pressure: active sequences' KV beyond `budget`
    /// (per replica, aggregate across its GPUs) spills to the remote tier
    /// and decode steps are charged the paging stall.
    pub fn with_kv_budget(mut self, budget: Bytes) -> Self {
        self.kv = Some(KvPressure::new(budget, &self.sys));
        self
    }

    /// KV-pressure counters (spilled peak, total stall), when enabled.
    pub fn kv_pressure(&self) -> Option<&KvPressure> {
        self.kv.as_ref()
    }

    /// Swap the served model in place (multi-tenant cold start,
    /// DESIGN.md §Multi-Tenant). Both memo caches are keyed only by
    /// (batch, length-bucket), so stale entries priced for the old
    /// model would corrupt every later step — drop them.
    pub fn set_model(&mut self, model: ModelArch) {
        self.model = model;
        self.prefill_cache.clear();
        self.decode_cache.clear();
    }

    fn bucket(len: u64) -> u64 {
        len.next_power_of_two().max(64)
    }

    /// Modelled cost of one batched prefill at `padded_len`, memoised per
    /// (batch, length-bucket). The length-based entry point: the event
    /// core (`coordinator::event_core`) calls it directly, skipping
    /// token materialisation; the trait path delegates here.
    pub fn prefill_cost(&mut self, batch: u64, padded_len: u64) -> Result<Seconds> {
        let key = (batch, Self::bucket(padded_len));
        if let Some(t) = self.prefill_cache.get(&key) {
            return Ok(*t);
        }
        let r = sim::simulate(&self.sys, &self.model, batch, Phase::Prefill { prompt_len: key.1 })?;
        self.prefill_cache.insert(key, r.total);
        Ok(r.total)
    }

    /// Modelled cost of advancing `batch` sequences one token, with the
    /// longest at `max_len` and `total_tokens` of KV resident across the
    /// batch. The compute term is memoised per (batch, length-bucket);
    /// the KV-pressure stall uses the *exact* resident footprint and is
    /// charged on every call (the pressure state advances per step, memo
    /// hit or not).
    pub fn decode_cost(&mut self, batch: u64, max_len: u64, total_tokens: u64) -> Result<Seconds> {
        let key = (batch, Self::bucket(max_len));
        let mut t = match self.decode_cache.get(&key) {
            Some(t) => *t,
            None => {
                let r =
                    sim::simulate(&self.sys, &self.model, batch, Phase::Decode { kv_len: key.1 })?;
                self.decode_cache.insert(key, r.total);
                r.total
            }
        };
        if let Some(kv) = self.kv.as_mut() {
            let resident = memory::kv_cache_bytes(&self.model, 1, total_tokens);
            let stall = kv.step_stall(resident, resident);
            t += stall;
            self.pending_stall += stall;
        }
        Ok(t)
    }
}

impl Backend for SimBackend {
    fn max_concurrency(&self) -> usize {
        self.max_conc
    }

    fn prefill(&mut self, items: &[PrefillItem], padded_len: usize) -> Result<(Seconds, Vec<i32>)> {
        let t = self.prefill_cost(items.len() as u64, padded_len as u64)?;
        Ok((t, items.iter().map(|i| pseudo_token(i.id)).collect()))
    }

    fn decode_step(&mut self, seqs: &[Vec<i32>]) -> Result<(Seconds, Vec<i32>)> {
        let batch = seqs.len() as u64;
        let max_len = seqs.iter().map(|s| s.len()).max().unwrap_or(1) as u64;
        // Exact resident KV across the batch (not the bucketed cost
        // key): a decode step touches all of it.
        let total_tokens: u64 = seqs.iter().map(|s| s.len() as u64).sum();
        let t = self.decode_cost(batch, max_len, total_tokens)?;
        Ok((t, seqs.iter().enumerate().map(|(i, s)| pseudo_token(s.len() as u64 + i as u64)).collect()))
    }

    fn take_paging_stall(&mut self) -> Seconds {
        std::mem::take(&mut self.pending_stall)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::models::arch::gpt3_175b;
    use crate::units::Bandwidth;

    #[test]
    fn sim_backend_costs_scale_with_length() {
        let mut b = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), gpt3_175b(), 8);
        let items: Vec<PrefillItem> =
            (0..4).map(|i| PrefillItem { id: i, tokens: vec![1; 512] }).collect();
        let (short, _) = b.prefill(&items, 512).unwrap();
        let (long, _) = b.prefill(&items, 4096).unwrap();
        assert!(long > short);
    }

    #[test]
    fn sim_backend_memoises() {
        let mut b = SimBackend::new(fh4_15xm(Bandwidth::tbps(4.8)), gpt3_175b(), 8);
        let seqs = vec![vec![1i32; 1000]; 4];
        let (a, _) = b.decode_step(&seqs).unwrap();
        let (c, _) = b.decode_step(&seqs).unwrap();
        assert_eq!(a, c);
        assert_eq!(b.decode_cache.len(), 1);
    }

    #[test]
    fn kv_budget_charges_decode_stall() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut free = SimBackend::new(sys.clone(), gpt3_175b(), 8);
        let mut capped =
            SimBackend::new(sys, gpt3_175b(), 8).with_kv_budget(Bytes::gb(1.0));
        // 8 × 8K-context sequences: GPT-3 MHA KV is ~4.6 MB/token, far
        // beyond a 1 GB budget — most of it spills.
        let seqs = vec![vec![1i32; 8192]; 8];
        let (a, _) = free.decode_step(&seqs).unwrap();
        let (b, _) = capped.decode_step(&seqs).unwrap();
        assert!(b > a, "capped step {b:?} must exceed free step {a:?}");
        let stall = capped.take_paging_stall();
        assert!(stall > Seconds::ZERO);
        assert_eq!(capped.take_paging_stall(), Seconds::ZERO, "stall drains once");
        let kv = capped.kv_pressure().unwrap();
        assert!(kv.spilled_peak.value() > 0.0);
        assert_eq!(kv.stall_total, stall);
        assert!(free.take_paging_stall() == Seconds::ZERO);
        assert!(free.kv_pressure().is_none());
    }

    #[test]
    fn cost_entry_points_match_trait_path() {
        // The event core calls prefill_cost/decode_cost directly; the
        // equivalence suite depends on them pricing identically to the
        // token-materialising trait path.
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut via_trait = SimBackend::new(sys.clone(), gpt3_175b(), 8);
        let mut via_cost = SimBackend::new(sys, gpt3_175b(), 8);
        let items: Vec<PrefillItem> =
            (0..4).map(|i| PrefillItem { id: i, tokens: vec![1; 700] }).collect();
        let (p, _) = via_trait.prefill(&items, 704).unwrap();
        assert_eq!(p, via_cost.prefill_cost(4, 704).unwrap());
        let seqs = vec![vec![1i32; 1000]; 4];
        let (d, _) = via_trait.decode_step(&seqs).unwrap();
        assert_eq!(d, via_cost.decode_cost(4, 1000, 4000).unwrap());
    }

    #[test]
    fn set_model_drops_stale_cost_caches() {
        use crate::models::arch::gpt2_xl;
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut b = SimBackend::new(sys.clone(), gpt3_175b(), 8);
        let big = b.prefill_cost(4, 704).unwrap();
        let _ = b.decode_cost(4, 1000, 4000).unwrap();
        assert_eq!(b.prefill_cache.len(), 1);
        b.set_model(gpt2_xl());
        assert!(b.prefill_cache.is_empty() && b.decode_cache.is_empty());
        let small = b.prefill_cost(4, 704).unwrap();
        assert!(small < big, "swapped-in model must be re-priced, not memo-served");
        // And the new prices match a backend born with the new model.
        let mut fresh = SimBackend::new(sys, gpt2_xl(), 8);
        assert_eq!(small, fresh.prefill_cost(4, 704).unwrap());
    }

    #[test]
    fn pseudo_tokens_in_vocab_range() {
        for i in 0..1000 {
            let t = pseudo_token(i);
            assert!((0..512).contains(&t));
        }
    }
}
