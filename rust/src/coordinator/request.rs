//! Request and response types for the serving layer.

use crate::units::Seconds;

/// A generation request entering the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (tiny-model vocab) or just a length for the
    /// simulation backend.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Arrival time on the serving clock.
    pub arrival: Seconds,
}

impl Request {
    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }
}

/// Lifecycle state tracked by the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding { generated: usize },
    Finished,
}

/// A completed request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (arrival → first prefill completion).
    pub ttft: Seconds,
    /// Total latency (arrival → last token).
    pub total: Seconds,
    /// Tokens generated.
    pub generated: usize,
}

impl Response {
    /// Mean time per output token after the first.
    pub fn tpot(&self) -> Seconds {
        if self.generated <= 1 {
            Seconds::ZERO
        } else {
            (self.total - self.ttft) / (self.generated - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpot_divides_decode_time() {
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3],
            ttft: Seconds::ms(100.0),
            total: Seconds::ms(300.0),
            generated: 5,
        };
        assert!((r.tpot().as_ms() - 50.0).abs() < 1e-9);
        let single = Response { generated: 1, ..r };
        assert_eq!(single.tpot(), Seconds::ZERO);
    }
}
