//! Request and response types for the serving layer.

use crate::units::Seconds;

/// Per-request latency service-level objective: the request is *SLO-met*
/// iff its TTFT and its mean TPOT both land at or under the targets
/// (scored by the scheduler at completion; fleet attainment and goodput
/// aggregate in [`super::metrics::Metrics`], DESIGN.md §Traffic).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTarget {
    /// Time-to-first-token target.
    pub ttft: Seconds,
    /// Time-per-output-token target (mean over the decode phase).
    pub tpot: Seconds,
}

impl SloTarget {
    /// Whether an observed (ttft, tpot) pair meets this target.
    pub fn met(&self, ttft: Seconds, tpot: Seconds) -> bool {
        ttft <= self.ttft && tpot <= self.tpot
    }
}

/// A generation request entering the coordinator.
#[derive(Debug, Clone, Default)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (tiny-model vocab) or just a length for the
    /// simulation backend.
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Arrival time on the serving clock.
    pub arrival: Seconds,
    /// Latency SLO this request is scored against (`None` = untracked:
    /// legacy workloads and offline batch classes).
    pub slo: Option<SloTarget>,
    /// Leading prompt tokens whose KV was found in the shared prefix
    /// cache (DESIGN.md §Prefix-Cache): they skip prefill compute, and
    /// the fetch of their pooled KV is charged via `prefix_fetch`. Set by
    /// the cluster at admission; 0 everywhere else.
    pub cached_prefix: usize,
    /// Stall charged to this request's prefill step for fetching the
    /// cached prefix KV out of the TAB pool.
    pub prefix_fetch: Seconds,
    /// TAB module the cached prefix is homed on (`Some` only when
    /// `cached_prefix > 0`). The fault layer revokes the hit — resetting
    /// all three prefix fields — when that module dies before the
    /// request prefills (DESIGN.md §Faults).
    pub prefix_home: Option<usize>,
    /// Owning tenant (DESIGN.md §Multi-Tenant); 0 on single-tenant
    /// fleets, where it is never read.
    pub tenant: usize,
    /// Model-swap cold-start stall charged to this request's prefill
    /// step when its admission forced a replica to page a different
    /// tenant's weights in. Zero everywhere else.
    pub swap_stall: Seconds,
}

impl Request {
    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    /// Tokens this request actually runs through prefill compute: the
    /// prompt minus the cached prefix, never below one (the final prompt
    /// token always executes to produce the first logits).
    pub fn prefill_len(&self) -> usize {
        self.prompt.len().saturating_sub(self.cached_prefix).max(1)
    }

    /// Routing work estimate: prompt plus generation budget in tokens.
    pub fn work_tokens(&self) -> u64 {
        (self.prompt.len() + self.max_new_tokens) as u64
    }

    /// Session/prefix key for KV-affinity routing: FNV-1a over the first
    /// [`AFFINITY_PREFIX`] prompt tokens. Requests of the same session
    /// share a prompt prefix (system prompt + conversation head), so they
    /// hash to the same replica and can reuse its KV/prefix cache.
    pub fn affinity_key(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for &t in self.prompt.iter().take(AFFINITY_PREFIX) {
            h ^= t as u32 as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Number of leading prompt tokens hashed by [`Request::affinity_key`].
pub const AFFINITY_PREFIX: usize = 32;

/// Lifecycle state tracked by the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    Queued,
    Prefilling,
    Decoding { generated: usize },
    Finished,
}

/// A completed request with its latency breakdown.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time to first token (arrival → first prefill completion).
    pub ttft: Seconds,
    /// Total latency (arrival → last token).
    pub total: Seconds,
    /// Tokens generated.
    pub generated: usize,
}

impl Response {
    /// Mean time per output token after the first.
    pub fn tpot(&self) -> Seconds {
        if self.generated <= 1 {
            Seconds::ZERO
        } else {
            (self.total - self.ttft) / (self.generated - 1) as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tpot_divides_decode_time() {
        let r = Response {
            id: 1,
            tokens: vec![1, 2, 3],
            ttft: Seconds::ms(100.0),
            total: Seconds::ms(300.0),
            generated: 5,
        };
        assert!((r.tpot().as_ms() - 50.0).abs() < 1e-9);
        let single = Response { generated: 1, ..r };
        assert_eq!(single.tpot(), Seconds::ZERO);
    }

    #[test]
    fn slo_met_requires_both_targets() {
        let slo = SloTarget { ttft: Seconds::ms(100.0), tpot: Seconds::ms(10.0) };
        assert!(slo.met(Seconds::ms(100.0), Seconds::ms(10.0)), "boundaries count as met");
        assert!(!slo.met(Seconds::ms(100.1), Seconds::ms(5.0)));
        assert!(!slo.met(Seconds::ms(50.0), Seconds::ms(10.1)));
    }

    #[test]
    fn prefill_len_subtracts_cached_prefix_but_keeps_one_token() {
        let mut r = Request {
            id: 0,
            prompt: vec![1; 100],
            max_new_tokens: 8,
            ..Default::default()
        };
        assert_eq!(r.prefill_len(), 100, "no cache hit → full prompt prefills");
        r.cached_prefix = 60;
        assert_eq!(r.prefill_len(), 40);
        assert_eq!(r.work_tokens(), 108, "routing estimate stays the full work");
        r.cached_prefix = 99;
        assert_eq!(r.prefill_len(), 1);
        r.cached_prefix = 100;
        assert_eq!(r.prefill_len(), 1, "at least one token always prefills");
    }

    #[test]
    fn affinity_key_depends_on_prefix_only() {
        let base = Request {
            id: 0,
            prompt: (0..100).collect(),
            max_new_tokens: 8,
            ..Default::default()
        };
        // Same prefix, different tail → same key (prefix-cache hit).
        let mut tail = base.clone();
        tail.prompt[AFFINITY_PREFIX + 5] = 999;
        assert_eq!(base.affinity_key(), tail.affinity_key());
        // Different prefix → different key.
        let mut other = base.clone();
        other.prompt[0] = 999;
        assert_ne!(base.affinity_key(), other.affinity_key());
    }
}
