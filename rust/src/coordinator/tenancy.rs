//! Multi-tenant admission arbitration (DESIGN.md §Multi-Tenant; →
//! EXPERIMENTS.md §Tenant-Sweep).
//!
//! The paper's rack-level pitch is one *shared* disaggregated pool
//! multiplexed across workloads. This module supplies the missing
//! control plane: each tenant brings its own [`ModelArch`], QoS class
//! (SLO scale, token quota) and traffic mix, and the cluster arbitrates
//! admissions across tenants at the router with either deficit-round-
//! robin weighted fair queueing ([`TenantArbitration::Wfq`]) or a naive
//! global FIFO ([`TenantArbitration::Fifo`]) — the baseline the
//! tenant-isolation tests show leaking a neighbour's burst into an
//! innocent tenant's tail latency.
//!
//! Everything here is deliberately *pure* bookkeeping over integers so
//! both simulation cores (`Cluster::run` on the event calendar and the
//! `run_stepping` oracle) share byte-identical decisions; the
//! differential harness in `rust/tests/event_core_equiv.rs` pins that.

use std::collections::VecDeque;

use crate::coordinator::metrics::LatencyStat;
use crate::error::{FhError, Result};
use crate::models::arch::{by_name, ModelArch};
use crate::traffic::{ClassKind, WorkloadMix};
use crate::units::{Bytes, Seconds};

/// Default DRR base quantum: admitted tokens a weight-1.0 tenant may
/// release per round. One round fits a typical chat request, so light
/// interactive tenants interleave ahead of a batch tenant's backlog.
pub const DEFAULT_QUANTUM: u64 = 8192;

/// Default cadence of the admission pump between arrivals (only armed
/// when a gate or replica contention can actually defer admissions).
pub const DEFAULT_ADMIT_INTERVAL_MS: f64 = 10.0;

/// Arbitration discipline multiplexing tenants onto the shared fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantArbitration {
    /// Deficit-round-robin weighted fair queueing (default): weights
    /// scale per-round deficit quanta, so a backlogged tenant's
    /// admitted tokens track its weight share to within one request.
    Wfq,
    /// Naive global arrival order — the "no isolation" baseline.
    Fifo,
}

impl TenantArbitration {
    /// Parse a CLI mode name.
    pub fn parse(s: &str) -> Option<TenantArbitration> {
        match s.to_ascii_lowercase().as_str() {
            "wfq" | "drr" | "fair" => Some(TenantArbitration::Wfq),
            "fifo" | "none" => Some(TenantArbitration::Fifo),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TenantArbitration::Wfq => "wfq",
            TenantArbitration::Fifo => "fifo",
        }
    }
}

/// One tenant: its model, QoS class, and traffic shape.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    pub name: String,
    /// Model this tenant is served with; replicas holding a different
    /// model must swap (cold start) before taking the tenant's work.
    pub model: ModelArch,
    /// WFQ weight (scales the per-round deficit quantum). Must be > 0.
    pub weight: f64,
    /// Front-door token quota: total work tokens the tenant may enqueue
    /// over a run. Exhaustion sheds at admission, before routing.
    pub quota_tokens: Option<u64>,
    /// Latency-tier scale on the fleet base SLO (>1 = relaxed tier).
    pub slo_scale: f64,
    /// Workload mix this tenant's traffic is drawn from.
    pub mix: WorkloadMix,
}

impl TenantConfig {
    /// A weight-1.0 chat tenant with no quota at the base latency tier.
    pub fn new(name: &str, model: ModelArch) -> TenantConfig {
        TenantConfig {
            name: name.to_string(),
            model,
            weight: 1.0,
            quota_tokens: None,
            slo_scale: 1.0,
            mix: WorkloadMix::of(ClassKind::Chat),
        }
    }
}

/// Fleet-level tenancy configuration (`ClusterConfig::tenants`).
#[derive(Debug, Clone)]
pub struct TenantsConfig {
    pub tenants: Vec<TenantConfig>,
    pub arbitration: TenantArbitration,
    /// Admission gate: a replica only takes new work while its routed
    /// load is at or below this many tokens. `None` admits eagerly —
    /// the arbiter never queues, so WFQ and FIFO coincide.
    pub admit_tokens: Option<u64>,
    /// DRR base quantum in tokens at weight 1.0.
    pub quantum: u64,
    /// Cadence of the admission pump between arrivals.
    pub admit_interval: Seconds,
}

impl TenantsConfig {
    /// Default arbitration (WFQ, no gate) over the given tenants.
    pub fn new(tenants: Vec<TenantConfig>) -> TenantsConfig {
        TenantsConfig {
            tenants,
            arbitration: TenantArbitration::Wfq,
            admit_tokens: None,
            quantum: DEFAULT_QUANTUM,
            admit_interval: Seconds::ms(DEFAULT_ADMIT_INTERVAL_MS),
        }
    }

    /// One default tenant on `model` — semantically the single-tenant
    /// fleet, pinned bit-identical to tenants-off by the property tests.
    pub fn single(model: ModelArch) -> TenantsConfig {
        let name = model.name.clone();
        TenantsConfig::new(vec![TenantConfig::new(&name, model)])
    }

    /// Whether the run needs admission-pump ticks between arrivals: a
    /// gate can defer admissions, or multiple tenants contend for
    /// replicas (model swaps wait for an idle one). A single ungated
    /// tenant drains fully at each arrival, so no ticks are scheduled —
    /// that keeps the single-tenant config bit-identical to tenants-off.
    pub fn needs_ticks(&self) -> bool {
        self.admit_tokens.is_some() || self.tenants.len() > 1
    }

    pub fn validate(&self) -> Result<()> {
        if self.tenants.is_empty() {
            return Err(FhError::Config("tenants config needs at least one tenant".into()));
        }
        for t in &self.tenants {
            if !t.weight.is_finite() || !(t.weight > 0.0) {
                return Err(FhError::Config(format!(
                    "tenant '{}' weight must be a positive finite number, got {}",
                    t.name, t.weight
                )));
            }
            if !(t.slo_scale > 0.0) {
                return Err(FhError::Config(format!(
                    "tenant '{}' slo-scale must be > 0, got {}",
                    t.name, t.slo_scale
                )));
            }
        }
        if self.quantum == 0 {
            return Err(FhError::Config("tenant quantum must be ≥ 1 token".into()));
        }
        if self.admit_interval.value() <= 0.0 {
            return Err(FhError::Config("tenant admit interval must be positive".into()));
        }
        Ok(())
    }

    /// Parse the `serve --tenants` grammar: tenants separated by `,`,
    /// fields within a tenant by `/`. The first two fields are
    /// `name/model`; the rest are `key=value` with keys `weight`,
    /// `quota`, `slo-scale`, and `mix` (the mix value uses the usual
    /// `chat:3+batch` grammar). Example:
    /// `alpha/gpt3/weight=3/mix=chat,beta/qwen3/quota=500000/mix=batch`.
    pub fn parse(spec: &str) -> Result<TenantsConfig> {
        let mut tenants = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                return Err(FhError::Config("empty tenant entry in --tenants spec".into()));
            }
            let mut fields = part.split('/');
            let name = fields.next().unwrap_or("").trim();
            let model_name = fields.next().unwrap_or("").trim();
            if name.is_empty() || model_name.is_empty() {
                return Err(FhError::Config(format!(
                    "tenant entry '{part}' must start with name/model"
                )));
            }
            let model = by_name(model_name).ok_or_else(|| {
                FhError::Config(format!("unknown model '{model_name}' in --tenants spec"))
            })?;
            let mut t = TenantConfig::new(name, model);
            for field in fields {
                let (key, value) = field.split_once('=').ok_or_else(|| {
                    FhError::Config(format!("tenant option '{field}' must be key=value"))
                })?;
                let value = value.trim();
                match key.trim() {
                    "weight" => {
                        t.weight = value.parse().map_err(|_| {
                            FhError::Config(format!("bad tenant weight '{value}'"))
                        })?;
                    }
                    "quota" => {
                        t.quota_tokens = Some(value.parse().map_err(|_| {
                            FhError::Config(format!("bad tenant quota '{value}'"))
                        })?);
                    }
                    "slo-scale" => {
                        t.slo_scale = value.parse().map_err(|_| {
                            FhError::Config(format!("bad tenant slo-scale '{value}'"))
                        })?;
                    }
                    "mix" => {
                        t.mix = WorkloadMix::parse(value).ok_or_else(|| {
                            FhError::Config(format!("bad tenant mix '{value}'"))
                        })?;
                    }
                    other => {
                        return Err(FhError::Config(format!(
                            "unknown tenant option '{other}' (weight|quota|slo-scale|mix)"
                        )));
                    }
                }
            }
            tenants.push(t);
        }
        let cfg = TenantsConfig::new(tenants);
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One queued admission candidate. `work` is the router charge (prompt
/// plus decode-token work), `payload` the core-specific handle: an
/// owned `Request` on the stepping core, an arena id on the event core.
#[derive(Debug)]
pub struct Queued<T> {
    pub work: u64,
    pub prompt_len: usize,
    pub affinity: u64,
    pub payload: T,
}

/// Verdict from one admission attempt.
pub enum Admit<T> {
    /// Routed and submitted — charge the tenant's deficit.
    Served,
    /// Inadmissible (e.g. prompt over the model context); dropped
    /// without a deficit charge.
    Rejected,
    /// No replica can take it right now — hand it back and stop
    /// draining this tenant until capacity frees.
    Blocked(Queued<T>),
}

/// The admission arbiter: per-tenant FIFO queues drained by either
/// strict global arrival order or deficit round robin. Owns no clock
/// and no floats — callers pump it at arrivals and admission ticks.
#[derive(Debug)]
pub struct TenantArbiter<T> {
    arbitration: TenantArbitration,
    queues: Vec<VecDeque<Queued<T>>>,
    /// FIFO mode only: global arrival order of tenant indices.
    order: VecDeque<usize>,
    deficit: Vec<u64>,
    quantum: Vec<u64>,
    queued_tokens: u64,
}

impl<T> TenantArbiter<T> {
    pub fn new(cfg: &TenantsConfig) -> TenantArbiter<T> {
        let quantum = cfg
            .tenants
            .iter()
            .map(|t| (((cfg.quantum as f64) * t.weight).round() as u64).max(1))
            .collect();
        TenantArbiter {
            arbitration: cfg.arbitration,
            queues: cfg.tenants.iter().map(|_| VecDeque::new()).collect(),
            order: VecDeque::new(),
            deficit: vec![0; cfg.tenants.len()],
            quantum,
            queued_tokens: 0,
        }
    }

    pub fn enqueue(&mut self, tenant: usize, item: Queued<T>) {
        self.queued_tokens += item.work;
        self.queues[tenant].push_back(item);
        if self.arbitration == TenantArbitration::Fifo {
            self.order.push_back(tenant);
        }
    }

    pub fn is_empty(&self) -> bool {
        self.queues.iter().all(|q| q.is_empty())
    }

    /// Total router-charge tokens waiting across all tenants (feeds the
    /// autoscaler's outstanding-work signal).
    pub fn queued_tokens(&self) -> u64 {
        self.queued_tokens
    }

    pub fn queued(&self, tenant: usize) -> usize {
        self.queues[tenant].len()
    }

    /// Drain admissible work through `try_admit`. FIFO pops strict
    /// global arrival order and stops at the first blocked head — one
    /// tenant's backlog stalls everyone behind it. WFQ runs deficit
    /// round robin: each round every unblocked backlogged tenant
    /// accrues its weighted quantum and releases heads while credit
    /// lasts; a head blocked on capacity refunds the round's quantum so
    /// a stall cannot bank an unbounded burst.
    pub fn pump<F>(&mut self, mut try_admit: F)
    where
        F: FnMut(usize, Queued<T>) -> Admit<T>,
    {
        match self.arbitration {
            TenantArbitration::Fifo => self.pump_fifo(&mut try_admit),
            TenantArbitration::Wfq => self.pump_wfq(&mut try_admit),
        }
    }

    fn pump_fifo<F>(&mut self, try_admit: &mut F)
    where
        F: FnMut(usize, Queued<T>) -> Admit<T>,
    {
        while let Some(&t) = self.order.front() {
            let Some(q) = self.queues[t].pop_front() else {
                self.order.pop_front();
                continue;
            };
            let work = q.work;
            match try_admit(t, q) {
                Admit::Served | Admit::Rejected => {
                    self.order.pop_front();
                    self.queued_tokens -= work;
                }
                Admit::Blocked(q) => {
                    self.queues[t].push_front(q);
                    break;
                }
            }
        }
    }

    fn pump_wfq<F>(&mut self, try_admit: &mut F)
    where
        F: FnMut(usize, Queued<T>) -> Admit<T>,
    {
        let n = self.queues.len();
        let mut blocked = vec![false; n];
        loop {
            let mut served = false;
            let mut accruing = false;
            for t in 0..n {
                if self.queues[t].is_empty() {
                    self.deficit[t] = 0;
                    continue;
                }
                if blocked[t] {
                    continue;
                }
                self.deficit[t] = self.deficit[t].saturating_add(self.quantum[t]);
                loop {
                    let Some(head) = self.queues[t].front() else { break };
                    if head.work > self.deficit[t] {
                        // Not enough credit yet: keep the accrual and
                        // return next round — the classic DRR build-up
                        // toward a request larger than one quantum.
                        accruing = true;
                        break;
                    }
                    let q = self.queues[t].pop_front().unwrap();
                    let work = q.work;
                    match try_admit(t, q) {
                        Admit::Served => {
                            self.deficit[t] -= work;
                            self.queued_tokens -= work;
                            served = true;
                        }
                        Admit::Rejected => {
                            self.queued_tokens -= work;
                            served = true;
                        }
                        Admit::Blocked(q) => {
                            self.queues[t].push_front(q);
                            self.deficit[t] =
                                self.deficit[t].saturating_sub(self.quantum[t]);
                            blocked[t] = true;
                            break;
                        }
                    }
                }
                if self.queues[t].is_empty() {
                    self.deficit[t] = 0;
                }
            }
            if !served && !accruing {
                break;
            }
        }
    }
}

/// Replica choice for one queued admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pick {
    /// The whole active fleet already serves this tenant and no gate
    /// binds: defer to the router policy. This is the path single-tenant
    /// runs take, keeping them bit-identical to tenants-off.
    Fleet,
    /// Least-loaded active replica already holding the tenant's model.
    Assigned(usize),
    /// Tenant holds no replica: claim this idle one and swap its model
    /// in (cold start).
    Swap(usize),
    /// Nothing can take the request right now; leave it queued.
    Blocked,
}

/// Decide where one admission goes. Pure so both simulation cores share
/// the byte-identical decision: `tassign` maps replica → tenant,
/// `load`/`pending` are the router charge and in-flight depth per
/// replica, `active` the autoscaler's active prefix, and `gate` the
/// admission watermark (`u64::MAX` when ungated).
pub fn pick_replica(
    tenant: usize,
    tassign: &[usize],
    load: &[u64],
    pending: &[usize],
    active: usize,
    gate: u64,
) -> Pick {
    let n = active.min(tassign.len());
    if gate == u64::MAX && (0..n).all(|i| tassign[i] == tenant) {
        return Pick::Fleet;
    }
    let mut best: Option<usize> = None;
    let mut has_home = false;
    for i in 0..n {
        if tassign[i] != tenant {
            continue;
        }
        has_home = true;
        if load[i] <= gate && best.map_or(true, |b| load[i] < load[b]) {
            best = Some(i);
        }
    }
    if let Some(i) = best {
        return Pick::Assigned(i);
    }
    if !has_home {
        // Only a fully cold tenant swaps; a gated-but-homed tenant
        // waits rather than thrashing models across the fleet.
        for i in 0..n {
            if pending[i] == 0 && load[i] == 0 {
                return Pick::Swap(i);
            }
        }
    }
    Pick::Blocked
}

/// Cluster-side per-tenant accounting, updated identically by both
/// cores at enqueue/admission time.
#[derive(Debug, Clone, Default)]
pub struct TenantStats {
    /// Work tokens accepted past the quota check (charged against
    /// `quota_tokens`).
    pub enqueued_tokens: u64,
    pub admitted_requests: u64,
    pub admitted_tokens: u64,
    /// Requests shed at the front door on quota exhaustion.
    pub shed_quota: u64,
    /// Model swaps performed on behalf of this tenant.
    pub swaps: u64,
    /// Cold-start latency per swap (weight page-in + fabric queueing).
    pub cold_start: LatencyStat,
    pub cold_start_total: Seconds,
}

/// Per-tenant slice of a finished run (`ClusterReport::tenants`).
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub model: String,
    pub weight: f64,
    pub admitted_requests: u64,
    pub admitted_tokens: u64,
    pub enqueued_tokens: u64,
    pub shed_quota: u64,
    pub completed: u64,
    pub tokens_generated: u64,
    pub slo_total: u64,
    pub slo_met: u64,
    /// Tokens from completions that met their SLO.
    pub goodput_tokens: u64,
    pub ttft: LatencyStat,
    pub swaps: u64,
    pub cold_start: LatencyStat,
    pub cold_start_total: Seconds,
    /// Model weights parked in the shared pool because the tenant holds
    /// no replica at end of run (cold model footprint).
    pub pool_bytes_held: Bytes,
    /// Stall attribution folded from this tenant's request spans
    /// (DESIGN.md §Telemetry) — explains *why* a tenant's latency looks
    /// the way it does (queue wait under WFQ vs swap stalls vs decode).
    /// Zero — and silent in the summary — with telemetry off.
    pub ledger: crate::telemetry::StallLedger,
}

impl TenantReport {
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_total == 0 {
            return 1.0;
        }
        self.slo_met as f64 / self.slo_total as f64
    }

    /// One human-readable line for `ClusterReport::summary`.
    pub fn summary_line(&self) -> String {
        let slo = if self.slo_total > 0 {
            format!(
                " | slo {:.1}% | goodput {} tok",
                100.0 * self.slo_attainment(),
                self.goodput_tokens
            )
        } else {
            String::new()
        };
        let swaps = if self.swaps > 0 {
            format!(
                " | swaps {} (cold-start mean {:.1} ms)",
                self.swaps,
                self.cold_start.mean_ms()
            )
        } else {
            String::new()
        };
        let quota = if self.shed_quota > 0 {
            format!(" | quota-shed {}", self.shed_quota)
        } else {
            String::new()
        };
        let parked = if self.pool_bytes_held.value() > 0.0 {
            format!(" | {:.1} GB parked in pool", self.pool_bytes_held.as_gb())
        } else {
            String::new()
        };
        let stalls = if self.ledger.is_zero() {
            String::new()
        } else {
            format!(" | {}", self.ledger.summary_line())
        };
        format!(
            "tenant {} ({}, w {:.1}): admitted {} ({} tok) | completed {} | \
             ttft p99 {:.1} ms{slo}{swaps}{quota}{parked}{stalls}",
            self.name,
            self.model,
            self.weight,
            self.admitted_requests,
            self.admitted_tokens,
            self.completed,
            self.ttft.percentile_ms(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::{gpt2, gpt2_xl};

    fn two_tenant_cfg(w_a: f64, w_b: f64, quantum: u64) -> TenantsConfig {
        let mut cfg = TenantsConfig::new(vec![
            TenantConfig::new("a", gpt2()),
            TenantConfig::new("b", gpt2()),
        ]);
        cfg.tenants[0].weight = w_a;
        cfg.tenants[1].weight = w_b;
        cfg.quantum = quantum;
        cfg
    }

    fn item(work: u64) -> Queued<u64> {
        Queued { work, prompt_len: work as usize, affinity: 0, payload: 0 }
    }

    #[test]
    fn spec_parses_names_models_and_options() {
        let cfg = TenantsConfig::parse(
            "alpha/gpt2/weight=3/mix=chat:2+batch,beta/gpt2-xl/quota=1000/slo-scale=2.5",
        )
        .unwrap();
        assert_eq!(cfg.tenants.len(), 2);
        assert_eq!(cfg.tenants[0].name, "alpha");
        assert_eq!(cfg.tenants[0].weight, 3.0);
        assert_eq!(cfg.tenants[0].mix.name(), "chat+batch");
        assert_eq!(cfg.tenants[1].model.name, gpt2_xl().name);
        assert_eq!(cfg.tenants[1].quota_tokens, Some(1000));
        assert_eq!(cfg.tenants[1].slo_scale, 2.5);
        assert_eq!(cfg.arbitration, TenantArbitration::Wfq);
    }

    #[test]
    fn spec_rejects_bad_entries() {
        assert!(TenantsConfig::parse("").is_err());
        assert!(TenantsConfig::parse("alpha").is_err());
        assert!(TenantsConfig::parse("alpha/not-a-model").is_err());
        assert!(TenantsConfig::parse("alpha/gpt2/weight=-1").is_err());
        assert!(TenantsConfig::parse("alpha/gpt2/bogus=1").is_err());
        assert!(TenantsConfig::parse("alpha/gpt2/mix=nope").is_err());
    }

    #[test]
    fn arbitration_names_roundtrip() {
        for mode in [TenantArbitration::Wfq, TenantArbitration::Fifo] {
            assert_eq!(TenantArbitration::parse(mode.name()), Some(mode));
        }
        assert_eq!(TenantArbitration::parse("drr"), Some(TenantArbitration::Wfq));
        assert_eq!(TenantArbitration::parse("what"), None);
    }

    #[test]
    fn wfq_shares_track_weights_within_one_request() {
        // Both tenants backlogged with equal demand; admission capacity
        // of 1000 tokens per pump. DRR must split it 3:1 by weight to
        // within one quantum + one max request.
        let cfg = two_tenant_cfg(3.0, 1.0, 100);
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        for _ in 0..100 {
            arb.enqueue(0, item(50));
            arb.enqueue(1, item(50));
        }
        let mut capacity = 1000u64;
        let mut admitted = [0u64; 2];
        arb.pump(|t, q| {
            if q.work > capacity {
                return Admit::Blocked(q);
            }
            capacity -= q.work;
            admitted[t] += q.work;
            Admit::Served
        });
        assert_eq!(admitted[0] + admitted[1], 1000);
        let ideal_a = 750i64;
        assert!(
            (admitted[0] as i64 - ideal_a).abs() <= 350,
            "weighted share off: {admitted:?}"
        );
        assert!(admitted[1] > 0, "light tenant starved: {admitted:?}");
    }

    #[test]
    fn wfq_banks_credit_for_a_request_larger_than_one_quantum() {
        let cfg = two_tenant_cfg(1.0, 1.0, 10);
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        arb.enqueue(0, item(95));
        let mut admitted = 0u64;
        arb.pump(|_, q| {
            admitted += q.work;
            Admit::Served
        });
        assert_eq!(admitted, 95, "large request must accrue credit, not starve");
        assert!(arb.is_empty());
    }

    #[test]
    fn wfq_blocked_tenant_does_not_stall_the_other() {
        let cfg = two_tenant_cfg(1.0, 1.0, 100);
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        for _ in 0..5 {
            arb.enqueue(0, item(10));
            arb.enqueue(1, item(10));
        }
        let mut admitted = [0u64; 2];
        arb.pump(|t, q| {
            if t == 0 {
                return Admit::Blocked(q);
            }
            admitted[t] += q.work;
            Admit::Served
        });
        assert_eq!(admitted, [0, 50], "tenant 1 must drain around blocked tenant 0");
        assert_eq!(arb.queued(0), 5);
        assert_eq!(arb.queued_tokens(), 50);
    }

    #[test]
    fn fifo_blocked_head_stalls_everyone_behind_it() {
        let mut cfg = two_tenant_cfg(1.0, 1.0, 100);
        cfg.arbitration = TenantArbitration::Fifo;
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        arb.enqueue(0, item(10)); // blocked head
        arb.enqueue(1, item(10)); // admissible, but behind it
        let mut admitted = [0u64; 2];
        arb.pump(|t, q| {
            if t == 0 {
                return Admit::Blocked(q);
            }
            admitted[t] += q.work;
            Admit::Served
        });
        assert_eq!(admitted, [0, 0], "FIFO must not overtake a blocked head");
        assert_eq!(arb.queued_tokens(), 20);
    }

    #[test]
    fn fifo_preserves_global_arrival_order() {
        let mut cfg = two_tenant_cfg(1.0, 1.0, 100);
        cfg.arbitration = TenantArbitration::Fifo;
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        for (t, w) in [(1usize, 1u64), (0, 2), (1, 3), (0, 4)] {
            arb.enqueue(t, item(w));
        }
        let mut seen = Vec::new();
        arb.pump(|t, q| {
            seen.push((t, q.work));
            Admit::Served
        });
        assert_eq!(seen, vec![(1, 1), (0, 2), (1, 3), (0, 4)]);
        assert!(arb.is_empty());
    }

    #[test]
    fn rejected_items_are_dropped_without_deficit_charge() {
        let cfg = two_tenant_cfg(1.0, 1.0, 100);
        let mut arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        arb.enqueue(0, item(60));
        arb.enqueue(0, item(60));
        let mut calls = 0;
        arb.pump(|_, _| {
            calls += 1;
            Admit::Rejected
        });
        // Both drained despite 120 > one quantum: rejects charge nothing.
        assert_eq!(calls, 2);
        assert!(arb.is_empty());
        assert_eq!(arb.queued_tokens(), 0);
    }

    #[test]
    fn pick_prefers_fleet_when_ungated_and_uncontended() {
        let tassign = [0usize, 0, 0];
        let p = pick_replica(0, &tassign, &[5, 0, 9], &[1, 0, 2], 3, u64::MAX);
        assert_eq!(p, Pick::Fleet);
        // A gate forces explicit least-loaded placement even then.
        let p = pick_replica(0, &tassign, &[5, 0, 9], &[1, 0, 2], 3, 100);
        assert_eq!(p, Pick::Assigned(1));
    }

    #[test]
    fn pick_takes_least_loaded_home_replica_within_gate() {
        let tassign = [0usize, 1, 0, 1];
        let p = pick_replica(1, &tassign, &[0, 80, 0, 40], &[0; 4], 4, 100);
        assert_eq!(p, Pick::Assigned(3));
        // Both homes over the gate: queue rather than swap elsewhere.
        let p = pick_replica(1, &tassign, &[0, 180, 0, 140], &[0; 4], 4, 100);
        assert_eq!(p, Pick::Blocked);
    }

    #[test]
    fn pick_swaps_lowest_idle_replica_for_a_cold_tenant() {
        let tassign = [0usize, 0];
        // Tenant 2 holds nothing; replica 0 busy, replica 1 idle.
        let p = pick_replica(2, &tassign, &[50, 0], &[2, 0], 2, u64::MAX);
        assert_eq!(p, Pick::Swap(1));
        // No idle replica → blocked.
        let p = pick_replica(2, &tassign, &[50, 10], &[2, 1], 2, u64::MAX);
        assert_eq!(p, Pick::Blocked);
    }

    #[test]
    fn pick_ignores_replicas_outside_the_active_prefix() {
        let tassign = [0usize, 1];
        // Replica 1 is tenant 1's home but scaled out of the active set.
        let p = pick_replica(1, &tassign, &[0, 0], &[0, 0], 1, u64::MAX);
        assert_eq!(p, Pick::Swap(0));
    }

    #[test]
    fn weighted_quanta_scale_and_floor_at_one_token() {
        let mut cfg = two_tenant_cfg(3.0, 1.0, 100);
        cfg.tenants.push(TenantConfig::new("c", gpt2()));
        cfg.tenants[2].weight = 1e-9;
        let arb: TenantArbiter<u64> = TenantArbiter::new(&cfg);
        assert_eq!(arb.quantum, vec![300, 100, 1]);
    }

    #[test]
    fn single_helper_builds_one_default_tenant() {
        let cfg = TenantsConfig::single(gpt2());
        assert_eq!(cfg.tenants.len(), 1);
        assert!(!cfg.needs_ticks(), "ungated single tenant must not tick");
        let mut gated = cfg.clone();
        gated.admit_tokens = Some(4096);
        assert!(gated.needs_ticks());
        cfg.validate().unwrap();
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        assert!(TenantsConfig::new(vec![]).validate().is_err());
        let mut cfg = TenantsConfig::single(gpt2());
        cfg.quantum = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::single(gpt2());
        cfg.admit_interval = Seconds::ZERO;
        assert!(cfg.validate().is_err());
        let mut cfg = TenantsConfig::single(gpt2());
        cfg.tenants[0].weight = 0.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tenant_report_summary_gates_optional_segments() {
        let mut r = TenantReport {
            name: "a".into(),
            model: "gpt2".into(),
            weight: 1.0,
            admitted_requests: 4,
            admitted_tokens: 400,
            enqueued_tokens: 400,
            shed_quota: 0,
            completed: 4,
            tokens_generated: 100,
            slo_total: 0,
            slo_met: 0,
            goodput_tokens: 0,
            ttft: LatencyStat::default(),
            swaps: 0,
            cold_start: LatencyStat::default(),
            cold_start_total: Seconds::ZERO,
            pool_bytes_held: Bytes::ZERO,
            ledger: crate::telemetry::StallLedger::default(),
        };
        let line = r.summary_line();
        assert!(!line.contains("slo") && !line.contains("swaps"), "{line}");
        assert!(!line.contains("stalls"), "zero ledger stays silent: {line}");
        r.slo_total = 4;
        r.slo_met = 3;
        r.swaps = 2;
        r.cold_start.record(Seconds::ms(10.0));
        r.shed_quota = 1;
        r.pool_bytes_held = Bytes::gb(2.0);
        r.ledger.spans = 4;
        r.ledger.queue_wait = Seconds::ms(8.0);
        r.ledger.decode = Seconds::ms(40.0);
        let line = r.summary_line();
        assert!(line.contains("slo 75.0%"), "{line}");
        assert!(line.contains("swaps 2"), "{line}");
        assert!(line.contains("quota-shed 1"), "{line}");
        assert!(line.contains("parked in pool"), "{line}");
        assert!(line.contains("stalls (4 spans"), "{line}");
    }
}
