//! Request arena for the event-driven cluster core
//! (DESIGN.md §Event-Core).
//!
//! The stepping loop moves whole [`Request`]s — prompt vectors included
//! — through submit queues, batcher queues and response vectors; at a
//! million requests those moves and the retained token buffers dominate
//! both wall-clock and resident memory. The arena fixes the cost shape:
//! every request is allocated once at workload ingest, all queues carry
//! a 4-byte [`ReqId`] handle, and the prompt buffer is *retired*
//! (freed) as soon as admission routing has consumed it — the serving
//! cost model is length-based, so everything downstream of admission
//! reads only the frozen scalars.
//!
//! Handles never dangle: entries are never removed from the backing
//! vector, so a `ReqId` stays valid for the arena's whole lifetime and
//! the scalar metadata (lengths, arrival, SLO, affinity) survives
//! prompt retirement unchanged. `rust/tests/event_props.rs` pins this.

use super::request::{Request, SloTarget};
use crate::units::Seconds;

/// Index handle into a [`RequestArena`]. `u32` bounds the arena at ~4
/// billion requests — far above the 1M+ sweeps this core targets —
/// while keeping event payloads and queue entries small.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReqId(pub u32);

/// One arena slot: the request's frozen scalar metadata plus its
/// (retirable) prompt buffer.
#[derive(Debug)]
pub struct ArenaEntry {
    /// Original request id (used for fabric booking attribution).
    pub id: u64,
    /// Prompt length, frozen at allocation — valid after retirement.
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub arrival: Seconds,
    pub slo: Option<SloTarget>,
    /// Leading tokens served from the shared prefix cache; set by the
    /// cluster at admission (mirrors `Request::cached_prefix`).
    pub cached_prefix: usize,
    /// TAB fetch stall charged to this request's prefill step.
    pub prefix_fetch: Seconds,
    /// TAB module the cached prefix lives on (mirrors
    /// `Request::prefix_home`; revoked on module failure).
    pub prefix_home: Option<usize>,
    /// Owning tenant (mirrors `Request::tenant`); 0 single-tenant.
    pub tenant: usize,
    /// Model-swap cold-start stall charged at this request's prefill
    /// step (mirrors `Request::swap_stall`); set at admission.
    pub swap_stall: Seconds,
    /// Session-affinity hash, precomputed at allocation so routing
    /// never needs the prompt bytes.
    affinity: u64,
    prompt: Vec<i32>,
    retired: bool,
}

impl ArenaEntry {
    /// Prompt tokens, empty after [`RequestArena::retire_prompt`].
    pub fn prompt(&self) -> &[i32] {
        &self.prompt
    }

    pub fn affinity_key(&self) -> u64 {
        self.affinity
    }

    /// Mirrors [`Request::prefill_len`] on the frozen scalars.
    pub fn prefill_len(&self) -> usize {
        self.prompt_len.saturating_sub(self.cached_prefix).max(1)
    }

    /// Mirrors `Request::work_tokens` on the frozen scalars.
    pub fn work_tokens(&self) -> u64 {
        (self.prompt_len + self.max_new_tokens) as u64
    }
}

/// Append-only arena of [`ArenaEntry`]s indexed by [`ReqId`].
#[derive(Default)]
pub struct RequestArena {
    entries: Vec<ArenaEntry>,
}

impl RequestArena {
    pub fn new() -> Self {
        RequestArena { entries: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        RequestArena { entries: Vec::with_capacity(n) }
    }

    /// Move `req` into the arena, freezing its scalar metadata and
    /// precomputing the affinity hash while the prompt is still here.
    pub fn alloc(&mut self, req: Request) -> ReqId {
        assert!(self.entries.len() < u32::MAX as usize, "arena full");
        let affinity = req.affinity_key();
        let id = ReqId(self.entries.len() as u32);
        self.entries.push(ArenaEntry {
            id: req.id,
            prompt_len: req.prompt.len(),
            max_new_tokens: req.max_new_tokens,
            arrival: req.arrival,
            slo: req.slo,
            cached_prefix: req.cached_prefix,
            prefix_fetch: req.prefix_fetch,
            prefix_home: req.prefix_home,
            tenant: req.tenant,
            swap_stall: req.swap_stall,
            affinity,
            prompt: req.prompt,
            retired: false,
        });
        id
    }

    pub fn get(&self, id: ReqId) -> &ArenaEntry {
        &self.entries[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: ReqId) -> &mut ArenaEntry {
        &mut self.entries[id.0 as usize]
    }

    /// Free the prompt buffer. The scalar metadata (and the handle)
    /// stay valid; only `prompt()` becomes empty. Idempotent.
    pub fn retire_prompt(&mut self, id: ReqId) {
        let e = &mut self.entries[id.0 as usize];
        e.prompt = Vec::new();
        e.retired = true;
    }

    pub fn is_retired(&self, id: ReqId) -> bool {
        self.entries[id.0 as usize].retired
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, gen: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt as i32).map(|t| t % 500 + 1).collect(),
            max_new_tokens: gen,
            arrival: Seconds::ms(id as f64),
            ..Default::default()
        }
    }

    #[test]
    fn alloc_freezes_scalars_and_affinity() {
        let r = req(3, 100, 16);
        let affinity = r.affinity_key();
        let mut arena = RequestArena::new();
        let id = arena.alloc(r);
        let e = arena.get(id);
        assert_eq!(e.id, 3);
        assert_eq!(e.prompt_len, 100);
        assert_eq!(e.work_tokens(), 116);
        assert_eq!(e.affinity_key(), affinity);
        assert_eq!(e.prompt().len(), 100);
    }

    #[test]
    fn retirement_frees_prompt_but_not_metadata() {
        let mut arena = RequestArena::new();
        let id = arena.alloc(req(9, 64, 8));
        arena.retire_prompt(id);
        assert!(arena.is_retired(id));
        let e = arena.get(id);
        assert!(e.prompt().is_empty());
        assert_eq!(e.prompt_len, 64);
        assert_eq!(e.prefill_len(), 64);
        assert_eq!(e.work_tokens(), 72);
    }

    #[test]
    fn prefill_len_mirrors_request_semantics() {
        let mut arena = RequestArena::new();
        let id = arena.alloc(req(1, 50, 4));
        arena.get_mut(id).cached_prefix = 48;
        assert_eq!(arena.get(id).prefill_len(), 2);
        arena.get_mut(id).cached_prefix = 50;
        assert_eq!(arena.get(id).prefill_len(), 1);
        arena.get_mut(id).cached_prefix = 99;
        assert_eq!(arena.get(id).prefill_len(), 1);
    }

    #[test]
    fn handles_stay_stable_across_allocs() {
        let mut arena = RequestArena::with_capacity(4);
        let ids: Vec<ReqId> = (0..100).map(|i| arena.alloc(req(i, 8, 2))).collect();
        arena.retire_prompt(ids[10]);
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(arena.get(*id).id, i as u64);
        }
        assert_eq!(arena.len(), 100);
    }
}
