//! Lean per-replica serving loop for the event-driven cluster core
//! (DESIGN.md §Event-Core).
//!
//! [`EventReplica`] is a step-exact mirror of
//! [`Scheduler<SimBackend>`](super::scheduler::Scheduler) that works on
//! [`ReqId`] arena handles and sequence *lengths* instead of moving
//! `Request`s and materialising token vectors. The simulation backend's
//! costs are length-based, so nothing observable changes: every clock
//! advance, metric record and completion happens in the same order with
//! the same floating-point inputs as the stepping loop — the
//! differential suite (`rust/tests/event_core_equiv.rs`) holds the two
//! cores bit-identical. What *does* change is the cost shape: no token
//! clones per decode round, no response retention, O(active) state per
//! replica — the difference between thousands and millions of requests
//! per run.
//!
//! Mirror discipline: any behavioral edit to `scheduler.rs`'s
//! `step_bounded` / `step_prefill` / `step_decode` / `finish_done` /
//! `admit_injected` / `run_until` must land here too, and vice versa.
//! The equivalence suite exists to catch the drift.

use super::arena::{ReqId, RequestArena};
use super::engine::{Backend, SimBackend};
use super::metrics::Metrics;
use super::scheduler::SchedMode;
use crate::error::Result;
use crate::faults::CompletionEvent;
use crate::telemetry::{RequestSpan, SpanKind, SpanStart};
use crate::units::Seconds;
use std::collections::VecDeque;

/// An active (decoding) sequence: lengths and timestamps only.
#[derive(Debug, Clone, Copy)]
struct ActiveSeq {
    id: ReqId,
    /// Mirror of the stepping loop's `tokens.len()`: prompt + 1 after
    /// prefill, +1 per decode round.
    len: usize,
    generated: usize,
    ttft: Seconds,
    /// Prefill attribution captured when the batch ran; `None` for
    /// injected sequences (mirror of `Active::start`).
    start: Option<SpanStart>,
}

/// Handle-based mirror of [`Handoff`](super::scheduler::Handoff): a
/// prefilled sequence leaving a prefill-pool replica.
#[derive(Debug, Clone, Copy)]
pub struct LeanHandoff {
    pub id: ReqId,
    /// Mirror of `Handoff::tokens.len()`: prompt + first token.
    pub len: usize,
    pub ttft: Seconds,
    pub generated: usize,
    /// Prefill-replica clock when the sequence became ready.
    pub done_at: Seconds,
}

/// One replica of the event-driven cluster core.
pub struct EventReplica {
    backend: SimBackend,
    mode: SchedMode,
    /// Batcher mirror knobs (`Batcher::{max_batch, tile, max_prompt}`).
    max_batch: usize,
    tile: usize,
    max_prompt: usize,
    queue: VecDeque<ReqId>,
    active: Vec<ActiveSeq>,
    /// Handed-off sequences waiting on their KV transfer: (ready, seq).
    injected: Vec<(Seconds, LeanHandoff)>,
    /// Handoffs produced since the cluster last collected them.
    handoffs_out: Vec<LeanHandoff>,
    handoffs_total: u64,
    /// Router work released by completions since the last drain, in
    /// completion order (the stepping loop's `responses[].tokens.len()`).
    completed_work: Vec<u64>,
    pub metrics: Metrics,
    clock: Seconds,
    /// Per-completion trace for windowed recovery analysis (DESIGN.md
    /// §Faults); armed by [`Self::with_trace`], off (and unallocated) on
    /// healthy runs.
    record_trace: bool,
    trace: Vec<CompletionEvent>,
    /// Per-request lifecycle spans (DESIGN.md §Telemetry); armed by
    /// [`Self::with_telemetry`], off (and unallocated) otherwise —
    /// mirror of `Scheduler::{record_spans, spans}`.
    record_spans: bool,
    spans: Vec<RequestSpan>,
}

impl EventReplica {
    pub fn new(
        backend: SimBackend,
        mode: SchedMode,
        max_batch: usize,
        tile: usize,
        max_prompt: usize,
    ) -> Self {
        assert!(max_batch >= 1 && tile >= 1);
        EventReplica {
            backend,
            mode,
            max_batch,
            tile,
            max_prompt,
            queue: VecDeque::new(),
            active: Vec::new(),
            injected: Vec::new(),
            handoffs_out: Vec::new(),
            handoffs_total: 0,
            completed_work: Vec::new(),
            metrics: Metrics::default(),
            clock: Seconds::ZERO,
            record_trace: false,
            trace: Vec::new(),
            record_spans: false,
            spans: Vec::new(),
        }
    }

    /// Record a [`CompletionEvent`] per finished request (mirror of
    /// `Scheduler::with_trace`). Default off.
    pub fn with_trace(mut self) -> Self {
        self.record_trace = true;
        self
    }

    /// Completion trace recorded under [`Self::with_trace`].
    pub fn trace(&self) -> &[CompletionEvent] {
        &self.trace
    }

    /// Record a [`RequestSpan`] per completed lifecycle phase and charge
    /// the metrics stall ledger (mirror of `Scheduler::with_telemetry`).
    /// Default off.
    pub fn with_telemetry(mut self) -> Self {
        self.record_spans = true;
        self
    }

    /// Drain the recorded spans (cluster report assembly stamps the
    /// replica index on them).
    pub fn take_spans(&mut self) -> Vec<RequestSpan> {
        std::mem::take(&mut self.spans)
    }

    /// Admission rule mirror (`Batcher::admits` on the frozen prompt
    /// length): the cluster consults this before charging the router.
    pub fn admits(&self, prompt_len: usize) -> bool {
        prompt_len <= self.max_prompt && prompt_len > 0
    }

    /// Enqueue an admitted request. The cluster submits at the arrival
    /// sync point, when this replica's clock has already reached the
    /// arrival — so the stepping loop's future-queue holding pattern
    /// collapses to a direct queue push.
    pub fn submit(&mut self, id: ReqId) {
        self.queue.push_back(id);
    }

    /// Adopt a prefilled sequence; decodable once the clock reaches
    /// `ready` (KV transfer complete).
    pub fn inject(&mut self, handoff: LeanHandoff, ready: Seconds) {
        self.injected.push((ready, handoff));
    }

    /// Outstanding work: queued + active + in-flight injected sequences.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len() + self.injected.len()
    }

    /// Work released by completions since the last call, in completion
    /// order (the cluster feeds these to the router).
    pub fn take_completed_work(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.completed_work)
    }

    /// Crash evacuation (DESIGN.md §Faults): strip every request this
    /// replica still owns — queue in submission order, then the active
    /// set in batch order — mirroring `Scheduler::evacuate` (whose
    /// `batcher.queue ++ future` is exactly submission order). The
    /// second return is the active set's generated-token count: decode
    /// progress lost with the replica's local KV.
    pub fn evacuate(&mut self) -> (Vec<ReqId>, u64) {
        let mut out: Vec<ReqId> = self.queue.drain(..).collect();
        let mut lost = 0u64;
        for a in self.active.drain(..) {
            lost += a.generated as u64;
            out.push(a.id);
        }
        (out, lost)
    }

    /// Queued (not yet prefilled) requests, FIFO — the fault layer scans
    /// these to revoke cached-prefix grants of a dead TAB module.
    pub fn queued_ids(&self) -> impl Iterator<Item = ReqId> + '_ {
        self.queue.iter().copied()
    }

    /// Handoffs produced since the last call.
    pub fn take_handoffs(&mut self) -> Vec<LeanHandoff> {
        std::mem::take(&mut self.handoffs_out)
    }

    /// Lifetime handoff count (per-replica report line).
    pub fn handoffs_total(&self) -> u64 {
        self.handoffs_total
    }

    pub fn backend(&self) -> &SimBackend {
        &self.backend
    }

    /// Repoint this replica at a different model (multi-tenant cold
    /// start) — mirror of `Scheduler::set_model`.
    pub fn set_model(&mut self, model: crate::models::arch::ModelArch) {
        self.max_prompt = model.max_seq as usize;
        self.backend.set_model(model);
    }

    pub fn clock(&self) -> Seconds {
        self.clock
    }

    pub fn mode(&self) -> SchedMode {
        self.mode
    }

    /// Mirror of `Scheduler::admit_injected`: earliest-ready first,
    /// never beyond the concurrency cap, then complete anything that
    /// arrived already at its generation budget.
    fn admit_injected(&mut self, arena: &RequestArena) {
        let clock = self.clock;
        loop {
            if self.active.len() >= self.backend.max_concurrency() {
                break;
            }
            let mut best: Option<usize> = None;
            for (i, (ready, _)) in self.injected.iter().enumerate() {
                if *ready <= clock && best.map_or(true, |b| *ready < self.injected[b].0) {
                    best = Some(i);
                }
            }
            let Some(i) = best else { break };
            let (_, h) = self.injected.swap_remove(i);
            self.active.push(ActiveSeq {
                id: h.id,
                len: h.len,
                generated: h.generated,
                ttft: h.ttft,
                start: None,
            });
        }
        self.finish_done(arena);
    }

    /// Earliest injected-ready time (the arrival stream lives in the
    /// cluster's calendar, not here).
    fn next_ready_time(&self) -> Option<Seconds> {
        self.injected
            .iter()
            .map(|(t, _)| *t)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }

    /// Mirror of `Scheduler::step_bounded`.
    fn step_bounded(&mut self, arena: &RequestArena, limit: Option<Seconds>) -> Result<bool> {
        self.admit_injected(arena);
        let past = |t: Seconds| limit.is_some_and(|l| t >= l);
        let room = self.backend.max_concurrency().saturating_sub(self.active.len());
        if !self.queue.is_empty() && room > 0 {
            if past(self.clock) {
                return Ok(false);
            }
            self.step_prefill(arena, room)?;
        } else if !self.active.is_empty() {
            if past(self.clock) {
                return Ok(false);
            }
            self.step_decode(arena)?;
        } else if let Some(t) = self.next_ready_time() {
            if limit.is_some_and(|l| t > l) {
                return Ok(false);
            }
            self.clock = self.clock.max(t);
        } else {
            return Ok(false);
        }
        Ok(true)
    }

    /// Mirror of `Scheduler::run_until`, plus a fast path for a fully
    /// idle replica: with nothing queued, active or in flight, the step
    /// loop can only fall through to the idle catch-up, so skip its
    /// bookkeeping — this is what keeps 64-replica fleets O(1) per sync
    /// point on replicas the router isn't feeding.
    pub fn run_until(&mut self, arena: &RequestArena, t: Seconds) -> Result<()> {
        if self.queue.is_empty() && self.active.is_empty() && self.injected.is_empty() {
            if self.clock < t {
                self.clock = t;
            }
            return Ok(());
        }
        while self.clock < t && self.step_bounded(arena, Some(t))? {}
        if self.clock < t && self.active.is_empty() && self.queue.is_empty() {
            self.clock = t;
        }
        Ok(())
    }

    /// Mirror of `Scheduler::run_to_completion` (metrics only — there
    /// are no responses to return).
    pub fn run_to_completion(&mut self, arena: &RequestArena) -> Result<()> {
        while self.step_bounded(arena, None)? {}
        self.metrics.clock = self.clock;
        Ok(())
    }

    /// Mirror of `Batcher::next_batch` + `Scheduler::step_prefill`.
    fn step_prefill(&mut self, arena: &RequestArena, room: usize) -> Result<()> {
        let n = room.min(self.max_batch).min(self.queue.len());
        if n == 0 {
            return Ok(());
        }
        let batch: Vec<ReqId> = self.queue.drain(..n).collect();
        // Padding follows the *prefill* length (cached prefix tokens
        // never enter the kernel), exactly as the batcher computes it.
        let longest = batch.iter().map(|&id| arena.get(id).prefill_len()).max().unwrap_or(1);
        let padded_len = longest.div_ceil(self.tile) * self.tile;
        // Prefix-KV fetch stalls sum in batch order (f64 addition order
        // is part of the bit-identity contract).
        let fetch: Seconds = batch.iter().map(|&id| arena.get(id).prefix_fetch).sum();
        // Cold-start model-swap stalls sum the same way (DESIGN.md
        // §Multi-Tenant); zero outside the multi-tenant layer.
        let swap: Seconds = batch.iter().map(|&id| arena.get(id).swap_stall).sum();
        let compute = self.backend.prefill_cost(n as u64, padded_len as u64)?;
        // Span attribution (DESIGN.md §Telemetry): `queue_end` plus the
        // `elapsed` association below is what `SpanStart::prefill_done`
        // replays bitwise — keep them in sync (and with scheduler.rs).
        let queue_end = self.clock;
        let elapsed = compute + fetch + swap;
        self.clock += elapsed;
        self.metrics.busy += elapsed;
        self.metrics.prefix_fetch += fetch;
        self.metrics.swap_stall += swap;
        for id in batch {
            let e = arena.get(id);
            self.metrics.prefill_tokens += e.prompt_len as u64;
            self.metrics.prefill_tokens_saved += e.cached_prefix.min(e.prompt_len) as u64;
            let ttft = self.clock - e.arrival;
            self.metrics.ttft.record(ttft);
            self.metrics.tokens_generated += 1;
            if self.mode == SchedMode::PrefillOnly {
                // Mirror of the scheduler's handoff-side span emission.
                if self.record_spans {
                    let span = RequestSpan {
                        id: e.id,
                        replica: 0,
                        tenant: e.tenant,
                        kind: SpanKind::PrefillHandoff,
                        arrival: e.arrival,
                        queue_end,
                        prefill_compute: compute,
                        prefix_fetch: fetch,
                        swap_stall: swap,
                        prefill_done: self.clock,
                        ttft,
                        finish: self.clock,
                        generated: 1,
                    };
                    self.metrics.ledger.charge(&span);
                    self.spans.push(span);
                }
                self.handoffs_out.push(LeanHandoff {
                    id,
                    len: e.prompt_len + 1,
                    ttft,
                    generated: 1,
                    done_at: self.clock,
                });
                self.handoffs_total += 1;
            } else {
                let start = Some(SpanStart { queue_end, compute, fetch, swap });
                self.active.push(ActiveSeq {
                    id,
                    len: e.prompt_len + 1,
                    generated: 1,
                    ttft,
                    start,
                });
            }
        }
        self.finish_done(arena);
        Ok(())
    }

    /// Mirror of `Scheduler::step_decode`.
    fn step_decode(&mut self, arena: &RequestArena) -> Result<()> {
        let batch = self.active.len() as u64;
        let max_len = self.active.iter().map(|a| a.len).max().unwrap_or(1) as u64;
        let total_tokens: u64 = self.active.iter().map(|a| a.len as u64).sum();
        let elapsed = self.backend.decode_cost(batch, max_len, total_tokens)?;
        self.clock += elapsed;
        self.metrics.busy += elapsed;
        self.metrics.paging_stall += self.backend.take_paging_stall();
        let per_tok = elapsed; // one step produced one token per sequence
        let metrics = &mut self.metrics;
        for a in &mut self.active {
            a.len += 1;
            a.generated += 1;
            metrics.tokens_generated += 1;
            metrics.tpot.record(per_tok);
        }
        self.finish_done(arena);
        Ok(())
    }

    /// Mirror of `Scheduler::finish_done`: complete sequences at their
    /// generation budget, in active order, releasing their final length
    /// as router work.
    fn finish_done(&mut self, arena: &RequestArena) {
        let clock = self.clock;
        let metrics = &mut self.metrics;
        let completed_work = &mut self.completed_work;
        let record_trace = self.record_trace;
        let trace = &mut self.trace;
        let record_spans = self.record_spans;
        let spans = &mut self.spans;
        self.active.retain(|a| {
            let e = arena.get(a.id);
            if a.generated >= e.max_new_tokens {
                let total = clock - e.arrival;
                metrics.e2e.record(total);
                metrics.completed += 1;
                if let Some(slo) = e.slo {
                    metrics.slo_total += 1;
                    let tpot = if a.generated > 1 {
                        (total - a.ttft) / (a.generated - 1) as f64
                    } else {
                        Seconds::ZERO
                    };
                    if slo.met(a.ttft, tpot) {
                        metrics.slo_met += 1;
                        metrics.goodput_tokens += a.generated as u64;
                    }
                }
                if record_trace {
                    let slo_ok = e.slo.map(|slo| {
                        let tpot = if a.generated > 1 {
                            (total - a.ttft) / (a.generated - 1) as f64
                        } else {
                            Seconds::ZERO
                        };
                        slo.met(a.ttft, tpot)
                    });
                    trace.push(CompletionEvent {
                        at: clock,
                        tokens: a.generated as u64,
                        slo: slo_ok,
                        tenant: e.tenant,
                        ttft: a.ttft,
                    });
                }
                if record_spans {
                    let span = match a.start {
                        Some(st) => RequestSpan {
                            id: e.id,
                            replica: 0,
                            tenant: e.tenant,
                            kind: SpanKind::Full,
                            arrival: e.arrival,
                            queue_end: st.queue_end,
                            prefill_compute: st.compute,
                            prefix_fetch: st.fetch,
                            swap_stall: st.swap,
                            prefill_done: st.prefill_done(),
                            ttft: a.ttft,
                            finish: clock,
                            generated: a.generated as u64,
                        },
                        // Injected sequence: prefill was attributed on
                        // the prefill replica's `PrefillHandoff` span.
                        None => RequestSpan {
                            id: e.id,
                            replica: 0,
                            tenant: e.tenant,
                            kind: SpanKind::DecodeInjected,
                            arrival: e.arrival,
                            queue_end: e.arrival,
                            prefill_compute: Seconds::ZERO,
                            prefix_fetch: Seconds::ZERO,
                            swap_stall: Seconds::ZERO,
                            prefill_done: e.arrival + a.ttft,
                            ttft: a.ttft,
                            finish: clock,
                            generated: a.generated as u64,
                        },
                    };
                    metrics.ledger.charge(&span);
                    spans.push(span);
                }
                completed_work.push(a.len as u64);
                false
            } else {
                true
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::coordinator::batcher::Batcher;
    use crate::coordinator::request::Request;
    use crate::coordinator::scheduler::Scheduler;
    use crate::models::arch::gpt3_175b;
    use crate::units::Bandwidth;

    fn requests() -> Vec<Request> {
        (0..12)
            .map(|i| Request {
                id: i,
                prompt: vec![(i % 7) as i32 + 1; 64 + (i as usize % 5) * 40],
                max_new_tokens: 4 + (i as usize % 3),
                arrival: Seconds::ms(3.0 * i as f64),
                ..Default::default()
            })
            .collect()
    }

    #[test]
    fn lean_replica_matches_scheduler_bit_for_bit() {
        // Single replica, no router: drive the stepping scheduler and
        // the lean mirror over the same stream and demand identical
        // metrics — the unit-scale version of the differential suite.
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let model = gpt3_175b();
        let backend = SimBackend::new(sys.clone(), model.clone(), 4);
        let mut sched = Scheduler::new(backend, Batcher::new(4, 64, model.max_seq as usize));
        sched.submit_all(requests());
        sched.run_to_completion().unwrap();

        let backend = SimBackend::new(sys, model.clone(), 4);
        let mut ev =
            EventReplica::new(backend, SchedMode::Full, 4, 64, model.max_seq as usize);
        let mut arena = RequestArena::new();
        for req in requests() {
            let arrival = req.arrival;
            let rid = arena.alloc(req);
            ev.run_until(&arena, arrival).unwrap();
            ev.submit(rid);
        }
        ev.run_to_completion(&arena).unwrap();

        assert_eq!(ev.metrics.completed, sched.metrics.completed);
        assert_eq!(ev.metrics.tokens_generated, sched.metrics.tokens_generated);
        assert_eq!(ev.metrics.clock.value().to_bits(), sched.metrics.clock.value().to_bits());
        assert_eq!(ev.metrics.busy.value().to_bits(), sched.metrics.busy.value().to_bits());
        assert_eq!(
            ev.metrics.ttft.mean_ms().to_bits(),
            sched.metrics.ttft.mean_ms().to_bits()
        );
        assert_eq!(
            ev.metrics.e2e.percentile_ms(95.0).to_bits(),
            sched.metrics.e2e.percentile_ms(95.0).to_bits()
        );
        // Released router work equals the stepping responses' lengths.
        let work = ev.take_completed_work();
        let want: Vec<u64> = sched.responses.iter().map(|r| r.tokens.len() as u64).collect();
        assert_eq!(work, want);
    }

    #[test]
    fn admits_mirrors_batcher_rule() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let ev = EventReplica::new(
            SimBackend::new(sys, gpt3_175b(), 4),
            SchedMode::Full,
            4,
            64,
            100,
        );
        assert!(!ev.admits(0));
        assert!(ev.admits(1));
        assert!(ev.admits(100));
        assert!(!ev.admits(101));
    }
}
