//! Serving metrics: latency distributions, throughput counters, and
//! fleet-level aggregation across cluster replicas (DESIGN.md §6).

use crate::units::Seconds;

/// Online latency statistics with exact percentiles (stores samples; the
/// serving demos run ≤ thousands of requests).
#[derive(Debug, Default, Clone)]
pub struct LatencyStat {
    samples_ms: Vec<f64>,
}

impl LatencyStat {
    pub fn record(&mut self, v: Seconds) {
        self.samples_ms.push(v.as_ms());
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Exact percentile (nearest-rank).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * s.len() as f64).ceil().max(1.0) as usize - 1;
        s[rank.min(s.len() - 1)]
    }

    pub fn max_ms(&self) -> f64 {
        self.samples_ms.iter().copied().fold(0.0, f64::max)
    }

    /// Absorb another stat's samples (fleet aggregation).
    pub fn merge(&mut self, other: &LatencyStat) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub ttft: LatencyStat,
    pub tpot: LatencyStat,
    pub e2e: LatencyStat,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_generated: u64,
    pub clock: Seconds,
    /// Time the backend actually spent executing prefill/decode steps
    /// (clock minus idle gaps) — per-replica utilization numerator.
    pub busy: Seconds,
    /// Portion of `busy` that was KV-paging stall (decode steps waiting
    /// on spilled KV pages; zero when KV capacity pressure is off).
    pub paging_stall: Seconds,
}

impl Metrics {
    pub fn throughput_tokens_per_s(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.clock.value()
    }

    pub fn requests_per_s(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.clock.value()
    }

    /// Fraction of the serving clock the backend was busy.
    pub fn utilization(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        (self.busy / self.clock).min(1.0)
    }

    /// Fold another replica's metrics into this one. Latency samples
    /// concatenate, counters add, busy time adds (fleet GPU-seconds), and
    /// the clock takes the max (fleet makespan on the shared virtual
    /// clock).
    pub fn merge(&mut self, other: &Metrics) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.tokens_generated += other.tokens_generated;
        self.busy += other.busy;
        self.paging_stall += other.paging_stall;
        self.clock = self.clock.max(other.clock);
    }

    pub fn summary(&self) -> String {
        let stall = if self.paging_stall.value() > 0.0 {
            format!(" | kv-paging stall {:.3}s", self.paging_stall.value())
        } else {
            String::new()
        };
        format!(
            "completed {} | rejected {} | tokens {} | wall {:.3}s{stall}\n\
             TTFT  mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}\n\
             TPOT  mean {:.3} ms  p50 {:.3}  p95 {:.3}  p99 {:.3}\n\
             E2E   mean {:.2} ms  p95 {:.2}\n\
             throughput {:.1} tok/s | {:.2} req/s",
            self.completed,
            self.rejected,
            self.tokens_generated,
            self.clock.value(),
            self.ttft.mean_ms(),
            self.ttft.percentile_ms(50.0),
            self.ttft.percentile_ms(95.0),
            self.ttft.percentile_ms(99.0),
            self.ttft.max_ms(),
            self.tpot.mean_ms(),
            self.tpot.percentile_ms(50.0),
            self.tpot.percentile_ms(95.0),
            self.tpot.percentile_ms(99.0),
            self.e2e.mean_ms(),
            self.e2e.percentile_ms(95.0),
            self.throughput_tokens_per_s(),
            self.requests_per_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = LatencyStat::default();
        for ms in [10.0, 20.0, 30.0, 40.0, 50.0] {
            s.record(Seconds::ms(ms));
        }
        assert_eq!(s.percentile_ms(50.0), 30.0);
        assert_eq!(s.percentile_ms(100.0), 50.0);
        assert_eq!(s.percentile_ms(1.0), 10.0);
        assert_eq!(s.max_ms(), 50.0);
        assert!((s.mean_ms() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStat::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.percentile_ms(95.0), 0.0);
        let m = Metrics::default();
        assert_eq!(m.throughput_tokens_per_s(), 0.0);
        assert_eq!(m.utilization(), 0.0);
    }

    #[test]
    fn throughput_counts_over_clock() {
        let m = Metrics {
            tokens_generated: 500,
            completed: 10,
            clock: Seconds::new(2.0),
            ..Default::default()
        };
        assert_eq!(m.throughput_tokens_per_s(), 250.0);
        assert_eq!(m.requests_per_s(), 5.0);
    }

    #[test]
    fn merge_concatenates_samples_and_takes_max_clock() {
        let mut a = Metrics {
            completed: 3,
            tokens_generated: 30,
            clock: Seconds::new(1.0),
            busy: Seconds::new(0.5),
            ..Default::default()
        };
        a.ttft.record(Seconds::ms(10.0));
        let mut b = Metrics {
            completed: 2,
            tokens_generated: 20,
            clock: Seconds::new(2.0),
            busy: Seconds::new(1.0),
            ..Default::default()
        };
        b.ttft.record(Seconds::ms(30.0));
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.tokens_generated, 50);
        assert_eq!(a.clock, Seconds::new(2.0));
        assert_eq!(a.busy, Seconds::new(1.5));
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.ttft.max_ms(), 30.0);
    }

    #[test]
    fn utilization_is_busy_over_clock() {
        let m = Metrics {
            clock: Seconds::new(4.0),
            busy: Seconds::new(3.0),
            ..Default::default()
        };
        assert!((m.utilization() - 0.75).abs() < 1e-12);
    }
}
