//! Serving metrics: latency distributions, throughput counters, and
//! fleet-level aggregation across cluster replicas (DESIGN.md §6).

use crate::units::Seconds;

/// Samples per stat above which percentile accumulation switches from
/// exact (stored samples, nearest-rank) to a streaming log-spaced
/// histogram. Below the threshold behavior is *bitwise* identical to
/// the historical exact path — the golden snapshots and the
/// differential equivalence suite depend on that.
pub const STREAMING_THRESHOLD: usize = 65_536;

/// Sub-bins per power-of-two octave: 64 gives a worst-case relative
/// error of 1/128 ≈ 0.78 % for any in-range value (the representative
/// is the arithmetic midpoint of a bin whose width is lo/64).
const HIST_SUBS_LOG2: u32 = 6;
const HIST_SUBS: usize = 1 << HIST_SUBS_LOG2;
/// Octave range: 2^-40 ms (≈ 1 fs) … 2^40 ms (≈ 35 years). Values
/// outside land in the under/overflow bins; the overflow bin reports
/// the exact running max.
const HIST_MIN_EXP: i64 = -40;
const HIST_MAX_EXP: i64 = 40;
const HIST_NBINS: usize = ((HIST_MAX_EXP - HIST_MIN_EXP) as usize) * HIST_SUBS + 2;

/// Fixed-bin log-spaced histogram: 64 sub-bins per octave over 80
/// octaves, plus an underflow bin (index 0: zero, negative, non-finite,
/// sub-range) and an overflow bin (last index). Bin index and
/// representative come straight from the f64 bit pattern, so `record`
/// is a shift-and-mask — no branches on magnitude.
#[derive(Debug, Clone)]
struct StreamingHist {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl StreamingHist {
    fn new() -> Self {
        StreamingHist { counts: vec![0; HIST_NBINS], count: 0, sum: 0.0, max: 0.0 }
    }

    fn bin_of(v: f64) -> usize {
        if !(v > 0.0) {
            return 0; // zero, negative, NaN
        }
        let bits = v.to_bits();
        let exp = ((bits >> 52) & 0x7ff) as i64 - 1023;
        if exp < HIST_MIN_EXP {
            return 0; // includes subnormals (biased exponent 0)
        }
        if exp >= HIST_MAX_EXP {
            return HIST_NBINS - 1;
        }
        let sub = ((bits >> (52 - HIST_SUBS_LOG2)) & (HIST_SUBS as u64 - 1)) as usize;
        (exp - HIST_MIN_EXP) as usize * HIST_SUBS + sub + 1
    }

    /// Arithmetic midpoint of the bin's value range — the estimate
    /// reported for any percentile landing in this bin.
    fn representative(bin: usize) -> f64 {
        if bin == 0 {
            return 0.0;
        }
        let i = bin - 1;
        let exp = HIST_MIN_EXP + (i / HIST_SUBS) as i64;
        let sub = (i % HIST_SUBS) as f64;
        let base = 2.0f64.powi(exp as i32);
        let lo = base * (1.0 + sub / HIST_SUBS as f64);
        let hi = base * (1.0 + (sub + 1.0) / HIST_SUBS as f64);
        0.5 * (lo + hi)
    }

    fn record(&mut self, ms: f64) {
        self.counts[Self::bin_of(ms)] += 1;
        self.count += 1;
        self.sum += ms;
        self.max = self.max.max(ms);
    }

    fn absorb(&mut self, other: &StreamingHist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank percentile over the binned counts: walk bins until
    /// the cumulative count reaches the rank, report that bin's
    /// representative. The overflow bin reports the exact max, and the
    /// result is clamped to it (midpoints can overshoot when the max
    /// sits low in its bin).
    fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (((p / 100.0) * self.count as f64).ceil().max(1.0) as u64).min(self.count);
        let mut seen = 0u64;
        for (bin, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                if bin == HIST_NBINS - 1 {
                    return self.max;
                }
                return Self::representative(bin).min(self.max);
            }
        }
        self.max
    }
}

/// Online latency statistics. Exact percentiles (stored samples,
/// nearest-rank) up to [`STREAMING_THRESHOLD`] samples — bitwise
/// identical to the historical behavior, which the golden snapshots
/// pin — then a streaming log-spaced histogram with ≤ 1 % relative
/// error on percentiles and O(1) memory, so million-request event-core
/// sweeps don't retain a sample per token
/// (statistical bounds tested in `rust/tests/stream_stats.rs`).
#[derive(Debug, Default, Clone)]
pub struct LatencyStat {
    samples_ms: Vec<f64>,
    hist: Option<Box<StreamingHist>>,
}

impl LatencyStat {
    pub fn record(&mut self, v: Seconds) {
        let ms = v.as_ms();
        if let Some(h) = self.hist.as_mut() {
            h.record(ms);
            return;
        }
        self.samples_ms.push(ms);
        if self.samples_ms.len() > STREAMING_THRESHOLD {
            self.engage_streaming();
        }
    }

    /// Fold the stored samples into a fresh histogram (in record order,
    /// so the running sum accumulates exactly as the exact path would)
    /// and drop the sample buffer.
    fn engage_streaming(&mut self) {
        let mut h = Box::new(StreamingHist::new());
        for &ms in &self.samples_ms {
            h.record(ms);
        }
        self.hist = Some(h);
        self.samples_ms = Vec::new();
    }

    /// True once this stat has crossed to the streaming histogram.
    pub fn is_streaming(&self) -> bool {
        self.hist.is_some()
    }

    pub fn count(&self) -> usize {
        match &self.hist {
            Some(h) => h.count as usize,
            None => self.samples_ms.len(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        match &self.hist {
            Some(h) => {
                if h.count == 0 {
                    0.0
                } else {
                    h.sum / h.count as f64
                }
            }
            None => {
                if self.samples_ms.is_empty() {
                    return 0.0;
                }
                self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
            }
        }
    }

    /// Percentile: exact nearest-rank below the streaming threshold
    /// (shared definition in [`crate::units::percentile_nearest_rank`]),
    /// histogram estimate (≤ 1 % relative error) above it.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        match &self.hist {
            Some(h) => h.percentile(p),
            None => {
                let mut s = self.samples_ms.clone();
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                crate::units::percentile_nearest_rank(&s, p)
            }
        }
    }

    /// Exact on both paths (the histogram tracks the running max).
    pub fn max_ms(&self) -> f64 {
        match &self.hist {
            Some(h) => h.max,
            None => self.samples_ms.iter().copied().fold(0.0, f64::max),
        }
    }

    /// Absorb another stat (fleet aggregation). Stays exact — sample
    /// concatenation, the historical behavior — while the combined
    /// count fits under the threshold; otherwise the merged stat is
    /// streaming and absorbs the other side bin-wise (or sample-wise if
    /// the other side is still exact).
    pub fn merge(&mut self, other: &LatencyStat) {
        let both_exact = self.hist.is_none() && other.hist.is_none();
        if both_exact && self.samples_ms.len() + other.samples_ms.len() <= STREAMING_THRESHOLD {
            self.samples_ms.extend_from_slice(&other.samples_ms);
            return;
        }
        if self.hist.is_none() {
            self.engage_streaming();
        }
        let h = self.hist.as_mut().expect("engaged above");
        match &other.hist {
            Some(oh) => h.absorb(oh),
            None => {
                for &ms in &other.samples_ms {
                    h.record(ms);
                }
            }
        }
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub ttft: LatencyStat,
    pub tpot: LatencyStat,
    pub e2e: LatencyStat,
    pub completed: u64,
    pub rejected: u64,
    /// Requests dropped by front-door load shedding (admission control;
    /// distinct from `rejected`, which is inadmissible-prompt refusal).
    pub shed: u64,
    pub tokens_generated: u64,
    /// Completed requests that carried an SLO target.
    pub slo_total: u64,
    /// Of those, how many met both their TTFT and TPOT targets.
    pub slo_met: u64,
    /// Tokens generated by SLO-met requests only (goodput numerator).
    pub goodput_tokens: u64,
    /// Prompt tokens demanded by completed prefill passes (the full
    /// prompt lengths, before any prefix-cache discount).
    pub prefill_tokens: u64,
    /// Of those, tokens served from the shared prefix cache instead of
    /// being recomputed (DESIGN.md §Prefix-Cache).
    pub prefill_tokens_saved: u64,
    /// Stall spent fetching cached prefix KV out of the TAB pool.
    pub prefix_fetch: Seconds,
    pub clock: Seconds,
    /// Time the backend actually spent executing prefill/decode steps
    /// (clock minus idle gaps) — per-replica utilization numerator.
    pub busy: Seconds,
    /// Portion of `busy` that was KV-paging stall (decode steps waiting
    /// on spilled KV pages; zero when KV capacity pressure is off).
    pub paging_stall: Seconds,
    /// Fabric queueing delay charged to requests by the shared-fabric
    /// arbitration layer (handoffs + prefix fetches; zero with
    /// contention off — DESIGN.md §Fabric-Contention).
    pub fabric_wait: Seconds,
    /// Model-swap cold-start stall charged to prefill steps when a
    /// multi-tenant admission paged another tenant's weights onto this
    /// replica (zero on single-model fleets — DESIGN.md §Multi-Tenant).
    pub swap_stall: Seconds,
    /// Stall-attribution ledger folded from request spans (DESIGN.md
    /// §Telemetry); stays zero — and silent in the summary — unless the
    /// serving loop was armed with telemetry.
    pub ledger: crate::telemetry::StallLedger,
}

impl Metrics {
    pub fn throughput_tokens_per_s(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.clock.value()
    }

    pub fn requests_per_s(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.clock.value()
    }

    /// Fraction of SLO-carrying completions that met their targets
    /// (1.0 when nothing carried an SLO — there was nothing to miss).
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_total == 0 {
            return 1.0;
        }
        self.slo_met as f64 / self.slo_total as f64
    }

    /// Goodput: tokens per virtual second from SLO-met requests only —
    /// the metric the paper's "maintaining end-user performance" clause
    /// is scored on (DESIGN.md §Traffic).
    pub fn goodput_tokens_per_s(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        self.goodput_tokens as f64 / self.clock.value()
    }

    /// Fraction of demanded prefill tokens that skipped compute via the
    /// shared prefix cache — the token-weighted proxy for the paper's
    /// GPU-compute-offload saving (the wall-clock saving is measured
    /// directly by `benches/prefix_cache.rs` against a no-cache run).
    pub fn prefill_compute_saving(&self) -> f64 {
        if self.prefill_tokens == 0 {
            return 0.0;
        }
        self.prefill_tokens_saved as f64 / self.prefill_tokens as f64
    }

    /// Fraction of the serving clock the backend was busy.
    pub fn utilization(&self) -> f64 {
        if self.clock.value() <= 0.0 {
            return 0.0;
        }
        (self.busy / self.clock).min(1.0)
    }

    /// Fold another replica's metrics into this one. Latency samples
    /// concatenate, counters add, busy time adds (fleet GPU-seconds), and
    /// the clock takes the max (fleet makespan on the shared virtual
    /// clock).
    pub fn merge(&mut self, other: &Metrics) {
        self.ttft.merge(&other.ttft);
        self.tpot.merge(&other.tpot);
        self.e2e.merge(&other.e2e);
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.tokens_generated += other.tokens_generated;
        self.slo_total += other.slo_total;
        self.slo_met += other.slo_met;
        self.goodput_tokens += other.goodput_tokens;
        self.prefill_tokens += other.prefill_tokens;
        self.prefill_tokens_saved += other.prefill_tokens_saved;
        self.prefix_fetch += other.prefix_fetch;
        self.busy += other.busy;
        self.paging_stall += other.paging_stall;
        self.fabric_wait += other.fabric_wait;
        self.swap_stall += other.swap_stall;
        self.ledger.merge(&other.ledger);
        self.clock = self.clock.max(other.clock);
    }

    pub fn summary(&self) -> String {
        let stall = if self.paging_stall.value() > 0.0 {
            format!(" | kv-paging stall {:.3}s", self.paging_stall.value())
        } else {
            String::new()
        };
        let fabric = if self.fabric_wait.value() > 0.0 {
            format!(" | fabric wait {:.3} ms", self.fabric_wait.as_ms())
        } else {
            String::new()
        };
        let swap = if self.swap_stall.value() > 0.0 {
            format!(" | model-swap stall {:.3} ms", self.swap_stall.as_ms())
        } else {
            String::new()
        };
        let shed = if self.shed > 0 { format!(" | shed {}", self.shed) } else { String::new() };
        let prefix = if self.prefill_tokens_saved > 0 {
            format!(
                "prefix-cache saved {} prefill tokens ({:.1}% of demand) | fetch {:.3} ms\n",
                self.prefill_tokens_saved,
                100.0 * self.prefill_compute_saving(),
                self.prefix_fetch.as_ms(),
            )
        } else {
            String::new()
        };
        let stalls = if self.ledger.is_zero() {
            String::new()
        } else {
            format!("{}\n", self.ledger.summary_line())
        };
        let slo = if self.slo_total > 0 {
            format!(
                "SLO   attainment {:.1}% ({}/{}) | goodput {:.1} tok/s\n",
                100.0 * self.slo_attainment(),
                self.slo_met,
                self.slo_total,
                self.goodput_tokens_per_s(),
            )
        } else {
            String::new()
        };
        format!(
            "completed {} | rejected {}{shed} | tokens {} | wall {:.3}s{stall}{fabric}{swap}\n{prefix}{stalls}{slo}\
             TTFT  mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}\n\
             TPOT  mean {:.3} ms  p50 {:.3}  p95 {:.3}  p99 {:.3}\n\
             E2E   mean {:.2} ms  p95 {:.2}\n\
             throughput {:.1} tok/s | {:.2} req/s",
            self.completed,
            self.rejected,
            self.tokens_generated,
            self.clock.value(),
            self.ttft.mean_ms(),
            self.ttft.percentile_ms(50.0),
            self.ttft.percentile_ms(95.0),
            self.ttft.percentile_ms(99.0),
            self.ttft.max_ms(),
            self.tpot.mean_ms(),
            self.tpot.percentile_ms(50.0),
            self.tpot.percentile_ms(95.0),
            self.tpot.percentile_ms(99.0),
            self.e2e.mean_ms(),
            self.e2e.percentile_ms(95.0),
            self.throughput_tokens_per_s(),
            self.requests_per_s(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = LatencyStat::default();
        for ms in [10.0, 20.0, 30.0, 40.0, 50.0] {
            s.record(Seconds::ms(ms));
        }
        assert_eq!(s.percentile_ms(50.0), 30.0);
        assert_eq!(s.percentile_ms(100.0), 50.0);
        assert_eq!(s.percentile_ms(1.0), 10.0);
        assert_eq!(s.max_ms(), 50.0);
        assert!((s.mean_ms() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStat::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.percentile_ms(95.0), 0.0);
        let m = Metrics::default();
        assert_eq!(m.throughput_tokens_per_s(), 0.0);
        assert_eq!(m.utilization(), 0.0);
    }

    #[test]
    fn throughput_counts_over_clock() {
        let m = Metrics {
            tokens_generated: 500,
            completed: 10,
            clock: Seconds::new(2.0),
            ..Default::default()
        };
        assert_eq!(m.throughput_tokens_per_s(), 250.0);
        assert_eq!(m.requests_per_s(), 5.0);
    }

    #[test]
    fn merge_concatenates_samples_and_takes_max_clock() {
        let mut a = Metrics {
            completed: 3,
            tokens_generated: 30,
            clock: Seconds::new(1.0),
            busy: Seconds::new(0.5),
            ..Default::default()
        };
        a.ttft.record(Seconds::ms(10.0));
        let mut b = Metrics {
            completed: 2,
            tokens_generated: 20,
            clock: Seconds::new(2.0),
            busy: Seconds::new(1.0),
            ..Default::default()
        };
        b.ttft.record(Seconds::ms(30.0));
        a.merge(&b);
        assert_eq!(a.completed, 5);
        assert_eq!(a.tokens_generated, 50);
        assert_eq!(a.clock, Seconds::new(2.0));
        assert_eq!(a.busy, Seconds::new(1.5));
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.ttft.max_ms(), 30.0);
    }

    #[test]
    fn percentile_of_single_sample_is_that_sample() {
        let mut s = LatencyStat::default();
        s.record(Seconds::ms(17.0));
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile_ms(p), 17.0, "p{p}");
        }
        assert_eq!(s.max_ms(), 17.0);
        assert_eq!(s.mean_ms(), 17.0);
    }

    #[test]
    fn percentile_handles_ties() {
        let mut s = LatencyStat::default();
        for ms in [5.0, 5.0, 5.0, 5.0, 50.0] {
            s.record(Seconds::ms(ms));
        }
        assert_eq!(s.percentile_ms(50.0), 5.0);
        assert_eq!(s.percentile_ms(80.0), 5.0, "nearest rank lands on the tie block");
        assert_eq!(s.percentile_ms(81.0), 50.0);
        assert_eq!(s.percentile_ms(100.0), 50.0);
    }

    #[test]
    fn merge_of_disjoint_replicas_matches_pooled_distribution() {
        // Replica A holds the low half, replica B the high half; the
        // merged percentile must equal the percentile of the pooled set.
        let mut a = LatencyStat::default();
        let mut b = LatencyStat::default();
        let mut pooled = LatencyStat::default();
        for ms in 1..=50 {
            a.record(Seconds::ms(ms as f64));
            pooled.record(Seconds::ms(ms as f64));
        }
        for ms in 51..=100 {
            b.record(Seconds::ms(ms as f64));
            pooled.record(Seconds::ms(ms as f64));
        }
        a.merge(&b);
        assert_eq!(a.count(), 100);
        for p in [1.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(a.percentile_ms(p), pooled.percentile_ms(p), "p{p}");
        }
        // Merging an empty stat changes nothing.
        let before = a.percentile_ms(95.0);
        a.merge(&LatencyStat::default());
        assert_eq!(a.count(), 100);
        assert_eq!(a.percentile_ms(95.0), before);
    }

    #[test]
    fn slo_and_goodput_counters_merge_and_report() {
        let mut a = Metrics {
            slo_total: 8,
            slo_met: 6,
            goodput_tokens: 600,
            shed: 2,
            clock: Seconds::new(2.0),
            ..Default::default()
        };
        let b = Metrics { slo_total: 2, slo_met: 2, goodput_tokens: 200, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.slo_total, 10);
        assert_eq!(a.slo_met, 8);
        assert!((a.slo_attainment() - 0.8).abs() < 1e-12);
        assert_eq!(a.goodput_tokens_per_s(), 400.0);
        assert_eq!(a.shed, 2);
        let s = a.summary();
        assert!(s.contains("attainment 80.0%"), "{s}");
        assert!(s.contains("shed 2"), "{s}");
        // No SLO-carrying traffic → vacuous attainment, silent summary.
        let empty = Metrics::default();
        assert_eq!(empty.slo_attainment(), 1.0);
        assert_eq!(empty.goodput_tokens_per_s(), 0.0);
        assert!(!empty.summary().contains("attainment"));
        assert!(!empty.summary().contains("shed"));
    }

    #[test]
    fn prefix_counters_merge_and_report() {
        let mut a = Metrics {
            prefill_tokens: 800,
            prefill_tokens_saved: 200,
            prefix_fetch: Seconds::ms(2.0),
            ..Default::default()
        };
        let b = Metrics {
            prefill_tokens: 200,
            prefill_tokens_saved: 50,
            prefix_fetch: Seconds::ms(1.0),
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.prefill_tokens, 1000);
        assert_eq!(a.prefill_tokens_saved, 250);
        assert!((a.prefill_compute_saving() - 0.25).abs() < 1e-12);
        assert_eq!(a.prefix_fetch, Seconds::ms(3.0));
        let s = a.summary();
        assert!(s.contains("prefix-cache saved 250 prefill tokens"), "{s}");
        assert!(s.contains("25.0% of demand"), "{s}");
        // No cache activity → silent summary, zero saving.
        let quiet = Metrics { prefill_tokens: 500, ..Default::default() };
        assert_eq!(quiet.prefill_compute_saving(), 0.0);
        assert!(!quiet.summary().contains("prefix-cache"));
        assert_eq!(Metrics::default().prefill_compute_saving(), 0.0);
    }

    #[test]
    fn fabric_wait_merges_and_reports() {
        let mut a = Metrics { fabric_wait: Seconds::ms(2.0), ..Default::default() };
        let b = Metrics { fabric_wait: Seconds::ms(3.0), ..Default::default() };
        a.merge(&b);
        assert_eq!(a.fabric_wait, Seconds::ms(5.0));
        assert!(a.summary().contains("fabric wait"), "{}", a.summary());
        // Silent when the arbitration layer charged nothing.
        assert!(!Metrics::default().summary().contains("fabric wait"));
    }

    #[test]
    fn swap_stall_merges_and_reports() {
        let mut a = Metrics { swap_stall: Seconds::ms(40.0), ..Default::default() };
        let b = Metrics { swap_stall: Seconds::ms(60.0), ..Default::default() };
        a.merge(&b);
        assert_eq!(a.swap_stall, Seconds::ms(100.0));
        assert!(a.summary().contains("model-swap stall"), "{}", a.summary());
        // Silent on single-model fleets where no swap ever happens.
        assert!(!Metrics::default().summary().contains("model-swap"));
    }

    #[test]
    fn stall_ledger_merges_and_reports() {
        use crate::telemetry::{RequestSpan, SpanKind, StallLedger};
        let span = RequestSpan {
            id: 1,
            replica: 0,
            tenant: 0,
            kind: SpanKind::Full,
            arrival: Seconds::ZERO,
            queue_end: Seconds::ms(2.0),
            prefill_compute: Seconds::ms(8.0),
            prefix_fetch: Seconds::ZERO,
            swap_stall: Seconds::ZERO,
            prefill_done: Seconds::ms(10.0),
            ttft: Seconds::ms(10.0),
            finish: Seconds::ms(20.0),
            generated: 4,
        };
        let mut a = Metrics::default();
        a.ledger.charge(&span);
        let mut b = Metrics::default();
        b.ledger.charge(&span);
        a.merge(&b);
        assert_eq!(a.ledger.spans, 2);
        assert_eq!(a.ledger.ttft_total, Seconds::ms(20.0));
        assert!(a.summary().contains("stalls (2 spans"), "{}", a.summary());
        // Telemetry off → zero ledger, silent summary.
        assert_eq!(Metrics::default().ledger, StallLedger::default());
        assert!(!Metrics::default().summary().contains("stalls"));
    }

    #[test]
    fn utilization_is_busy_over_clock() {
        let m = Metrics {
            clock: Seconds::new(4.0),
            busy: Seconds::new(3.0),
            ..Default::default()
        };
        assert!((m.utilization() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn hist_bins_are_monotone_and_tight() {
        // Bin index must be nondecreasing in the value, and the
        // representative within 1 % of any value mapping to its bin.
        let mut prev_bin = 0usize;
        let mut v = 1e-9f64;
        while v < 1e9 {
            let bin = StreamingHist::bin_of(v);
            assert!(bin >= prev_bin, "bin order broke at {v}");
            prev_bin = bin;
            if bin > 0 && bin < HIST_NBINS - 1 {
                let rep = StreamingHist::representative(bin);
                assert!(
                    (rep - v).abs() / v < 0.01,
                    "representative {rep} off by >1% from {v} (bin {bin})"
                );
            }
            v *= 1.07;
        }
        // Degenerate inputs land in the underflow bin, not a panic.
        assert_eq!(StreamingHist::bin_of(0.0), 0);
        assert_eq!(StreamingHist::bin_of(-5.0), 0);
        assert_eq!(StreamingHist::bin_of(f64::NAN), 0);
        assert_eq!(StreamingHist::bin_of(1e-300), 0);
        assert_eq!(StreamingHist::bin_of(1e300), HIST_NBINS - 1);
    }

    #[test]
    fn streaming_engages_past_threshold_and_preserves_aggregates() {
        let mut s = LatencyStat::default();
        let n = STREAMING_THRESHOLD + 100;
        let mut sum = 0.0;
        for i in 0..n {
            let ms = 1.0 + (i % 997) as f64;
            sum += ms;
            s.record(Seconds::ms(ms));
        }
        assert!(s.is_streaming());
        assert_eq!(s.count(), n);
        assert_eq!(s.max_ms(), 997.0, "max stays exact on the streaming path");
        assert!((s.mean_ms() - sum / n as f64).abs() / (sum / n as f64) < 1e-12);
        let p50 = s.percentile_ms(50.0);
        assert!((p50 - 499.0).abs() / 499.0 < 0.01, "p50 {p50} off exact 499 by >1%");
        // Below the threshold the stat must not have engaged.
        let mut small = LatencyStat::default();
        for _ in 0..STREAMING_THRESHOLD {
            small.record(Seconds::ms(1.0));
        }
        assert!(!small.is_streaming());
    }

    #[test]
    fn merge_crossing_threshold_engages_streaming() {
        let mut a = LatencyStat::default();
        let mut b = LatencyStat::default();
        for i in 0..STREAMING_THRESHOLD / 2 + 100 {
            a.record(Seconds::ms(1.0 + (i % 100) as f64));
            b.record(Seconds::ms(201.0 + (i % 100) as f64));
        }
        assert!(!a.is_streaming() && !b.is_streaming());
        let total = a.count() + b.count();
        a.merge(&b);
        assert!(a.is_streaming(), "merge past the threshold must engage streaming");
        assert_eq!(a.count(), total);
        assert_eq!(a.max_ms(), 300.0);
        // All of b sits above all of a → p75 lands in b's range.
        let p75 = a.percentile_ms(75.0);
        assert!((201.0..=300.0).contains(&p75), "p75 {p75} outside b's band");
    }
}
