//! Multi-replica cluster simulator: rack-scale FengHuang serving
//! (DESIGN.md §6).
//!
//! A [`Cluster`] owns N replicas — each a [`Scheduler`] over its own
//! [`SimBackend`] node — co-simulated on a shared virtual clock. Requests
//! enter through the [`Router`] (round-robin / least-outstanding-tokens /
//! KV-affinity); the event loop processes arrivals in global time order,
//! advancing every replica's local clock to each arrival before the
//! routing decision so the router observes *current* outstanding load,
//! not admission-time guesses.
//!
//! [`Cluster::run`] drives the event-driven core (DESIGN.md
//! §Event-Core): a binary-heap [`EventCalendar`] of typed events, an
//! arena of [`ReqId`] handles, and lean [`EventReplica`] serving loops.
//! The original tick-scanning implementation survives as
//! [`Cluster::run_stepping`] — the oracle the differential suite
//! (`rust/tests/event_core_equiv.rs`) holds the event core bit-identical
//! against.
//!
//! Two topologies:
//!
//! * **Aggregated** — every replica runs the full prefill+decode loop.
//! * **Disaggregated** — replicas split into a prefill pool and a decode
//!   pool. Prefill replicas emit [`Handoff`]s; the cluster charges the
//!   KV transfer ([`FabricLatencies::kv_handoff`]) and injects the
//!   sequence into the least-loaded decode replica. On TAB fabrics the
//!   KV pages already live in shared memory, so the handoff is
//!   metadata-only — the cluster-scope payoff of the paper's memory
//!   orchestration; on shared-nothing fabrics the full KV serialises
//!   over the link.
//!
//! [`FabricLatencies::kv_handoff`]: crate::fabric::FabricLatencies::kv_handoff
//! [`Handoff`]: super::scheduler::Handoff

use super::arena::{ReqId, RequestArena};
use super::batcher::Batcher;
use super::calendar::{EventCalendar, EventKind};
use super::engine::SimBackend;
use super::event_core::EventReplica;
use super::metrics::{LatencyStat, Metrics};
use super::prefix_cache::{PrefixCache, PrefixCacheConfig, PrefixCacheReport};
use super::request::Request;
use super::router::{Policy, Router};
use super::scheduler::{SchedMode, Scheduler};
use super::tenancy::{
    pick_replica, Admit, Pick, Queued, TenantArbiter, TenantReport, TenantStats, TenantsConfig,
};
use crate::config::{fh4_rack, FlashConfig, SystemConfig};
use crate::error::{FhError, Result};
use crate::fabric::contention::{ContentionConfig, ContentionMode, FabricClock, FabricReport};
use crate::faults::{
    attainment_windows, recovery_stats, CompletionEvent, FaultKind, FaultReport, FaultSchedule,
    FaultSpec, ModuleSel,
};
use crate::models::arch::ModelArch;
use crate::models::memory;
use crate::telemetry::{
    RequestSpan, StallLedger, TelemetryConfig, TelemetryReport, TelemetrySample, TelemetrySampler,
};
use crate::units::{Bandwidth, Bytes, Seconds};

/// Metadata payload booked for a TAB KV handoff (the page-table
/// ownership record — the KV itself never moves on a shared pool).
const HANDOFF_META_BYTES: Bytes = Bytes(4096.0);

/// Metadata payload booked when a replica publishes prefix KV to the
/// shared cache (trie/page-table update; the KV was produced in-pool).
const PREFIX_PUBLISH_META_BYTES: Bytes = Bytes(4096.0);

/// Elastic-autoscaler knobs (DESIGN.md §Traffic). Every `interval` of
/// virtual time the controller reads the fleet's outstanding routed
/// tokens and resizes the active set to
/// `ceil(outstanding / target_tokens)`, clamped to
/// `[min_replicas, fleet]`. Scale-*up* jumps straight to the desired
/// size (the SLO pays for lag); scale-*down* steps one replica per
/// decision (hysteresis against flapping). Deactivated replicas drain
/// — the router stops sending them new work but keeps releasing their
/// completions.
#[derive(Debug, Clone, Copy)]
pub struct AutoscaleConfig {
    /// Floor of the active set (also the initial size).
    pub min_replicas: usize,
    /// Decision cadence on the virtual clock.
    pub interval: Seconds,
    /// Outstanding tokens one active replica is provisioned for.
    pub target_tokens: u64,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        AutoscaleConfig {
            min_replicas: 1,
            interval: Seconds::new(1.0),
            target_tokens: 4096,
        }
    }
}

/// Cluster topology and policy knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub policy: Policy,
    /// Per-replica continuous-batching width.
    pub max_batch: usize,
    /// `Some((prefill, decode))` splits the fleet into disaggregated
    /// pools of those sizes; `None` runs every replica aggregated.
    pub disaggregate: Option<(usize, usize)>,
    /// Per-replica local KV budget (`crate::paging::KvPressure`). `None`
    /// keeps the pre-paging assumption of infinite local KV capacity;
    /// `Some(b)` spills session KV beyond `b` to the remote tier and
    /// charges decode steps the paging stall (DESIGN.md §Paging).
    pub kv_budget: Option<Bytes>,
    /// Front-door load shedding: an arrival is dropped (counted in
    /// `Metrics::shed`, never routed) when even the emptiest *active*
    /// replica already holds more than this many outstanding tokens.
    /// `None` admits everything the batcher would accept.
    pub shed_tokens: Option<u64>,
    /// Elastic serving: `Some` lets the fleet breathe with the traffic
    /// curve (aggregated topologies only).
    pub autoscale: Option<AutoscaleConfig>,
    /// Shared prefix-KV cache in the TAB pool (DESIGN.md §Prefix-Cache):
    /// KV produced by any replica becomes reusable by every replica.
    /// Requires a FengHuang (TAB) fabric.
    pub prefix_cache: Option<PrefixCacheConfig>,
    /// Shared-fabric arbitration (DESIGN.md §Fabric-Contention): books
    /// the fleet-level transfers — KV handoffs, prefix-cache fetches and
    /// publications — against per-port / per-module bandwidth budgets and
    /// charges the resulting queueing delay. `ContentionMode::Off` (the
    /// default) keeps every charge bit-identical to the unloaded model.
    /// Active modes require a FengHuang (TAB) fabric; `ports == 0`
    /// resolves to the fleet size. Scope note: the `kv_budget` spill
    /// stream (`paging::KvPressure`) is computed inside each replica's
    /// backend and still pays the *unloaded* fabric bandwidth — a
    /// contended run with a KV budget understates pool load by those
    /// spill bytes (DESIGN.md §Fabric-Contention names this the next
    /// consumer to route through the ledger).
    pub contention: ContentionConfig,
    /// Rack-level high-bandwidth flash tier (DESIGN.md §Tiering):
    /// applied uniformly to every replica's node config, so each
    /// replica's KV pressure spills HBM → pool → flash in order
    /// instead of treating the pool as bottomless. `None` keeps the
    /// 2-tier model bit-identically.
    pub flash: Option<FlashConfig>,
    /// Deterministic fault injection (DESIGN.md §Faults): replica
    /// crashes with re-queue and timed rejoin, TAB-module failures that
    /// invalidate pool-resident prefix KV, and link-degradation windows
    /// on the contention ledger. `None` — and `Some` with an empty
    /// schedule — are strict passthroughs: both cores run the exact
    /// code paths (and floats) of a fault-free build.
    pub faults: Option<FaultSchedule>,
    /// Multi-tenant serving (DESIGN.md §Multi-Tenant): each tenant
    /// brings its own model, QoS class and traffic mix; admissions are
    /// arbitrated across tenants at the router (WFQ or FIFO), cold
    /// tenants page their weights in from the pool/flash tier, and the
    /// report grows per-tenant SLO/goodput/cold-start observables.
    /// `None` is a strict passthrough: both cores run the exact code
    /// paths (and floats) of a single-model build.
    pub tenants: Option<TenantsConfig>,
    /// Deterministic observability (DESIGN.md §Telemetry): per-request
    /// lifecycle spans, a fleet stall-attribution ledger, and a
    /// windowed time-series tick pumped by both cores. `None` is a
    /// strict passthrough: no tick is scheduled, no span is recorded,
    /// and every metric stays bit-identical to a pre-telemetry build.
    pub telemetry: Option<TelemetryConfig>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: None,
            kv_budget: None,
            shed_tokens: None,
            autoscale: None,
            prefix_cache: None,
            contention: ContentionConfig::default(),
            flash: None,
            faults: None,
            tenants: None,
            telemetry: None,
        }
    }
}

/// Per-replica slice of a [`ClusterReport`].
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub name: String,
    pub role: SchedMode,
    pub completed: u64,
    pub handoffs: u64,
    /// Cumulative tokens the router sent this replica.
    pub routed_tokens: u64,
    pub busy: Seconds,
    pub clock: Seconds,
    pub utilization: f64,
    /// KV-paging stall this replica's decode steps absorbed.
    pub paging_stall: Seconds,
    /// High-water mark of KV bytes spilled to the remote tier.
    pub kv_spilled_peak: Bytes,
}

/// Fleet-level result of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub model: String,
    pub policy: Policy,
    /// Merged metrics: latency samples from every replica, counters
    /// summed, clock = fleet makespan.
    pub fleet: Metrics,
    pub per_replica: Vec<ReplicaReport>,
    /// Max/mean of routed tokens across the serving (or prefill) pool.
    pub imbalance: f64,
    /// Disaggregated mode only: handoff count and total KV-transfer time.
    pub handoffs: u64,
    pub handoff_time: Seconds,
    /// Peak KV bytes spilled to the remote tier on any replica (the
    /// fleet stall total lives in `fleet.paging_stall`).
    pub kv_spilled_peak: Bytes,
    /// Peak KV bytes any replica pushed past its pool slice into the
    /// flash tier (zero without a flash tier or when the pool held).
    pub flash_spilled_peak: Bytes,
    /// Per-tenant observables (DESIGN.md §Multi-Tenant): SLO attainment,
    /// goodput, cold-start latency, quota shedding, pool bytes parked.
    /// `None` when multi-tenancy is off.
    pub tenants: Option<Vec<TenantReport>>,
    /// Shared prefix-cache observables (None when the cache is off).
    pub prefix_cache: Option<PrefixCacheReport>,
    /// Shared-fabric arbitration observables: busy fraction, queueing
    /// percentiles, per-module imbalance (None with contention off).
    pub fabric: Option<FabricReport>,
    /// Fault-injection observables — per-class counts, blast radius and
    /// windowed recovery (None when no schedule was configured).
    pub faults: Option<FaultReport>,
    /// Telemetry slice of the run — request spans, interval gauges, and
    /// the rolling-attainment curve (None with telemetry off).
    pub telemetry: Option<TelemetryReport>,
    /// Whether the elastic autoscaler drove this run.
    pub elastic: bool,
    /// Provisioned capacity: ∫ active-replica-count dt over the run —
    /// the GPU-cost denominator of the 50 %-fewer-GPUs claim. A static
    /// fleet burns `replicas × makespan`.
    pub replica_seconds: f64,
    /// `replica_seconds` × GPUs per node (FH4 nodes have 4).
    pub gpu_seconds: f64,
    /// Autoscaler decisions: (virtual time, new active-set size).
    pub scale_events: Vec<(Seconds, usize)>,
}

impl ClusterReport {
    pub fn makespan(&self) -> Seconds {
        self.fleet.clock
    }

    /// Fleet throughput in generated tokens per virtual second.
    pub fn throughput_tokens_per_s(&self) -> f64 {
        self.fleet.throughput_tokens_per_s()
    }

    /// What the same run would have cost fully provisioned.
    pub fn static_replica_seconds(&self) -> f64 {
        self.per_replica.len() as f64 * self.makespan().value()
    }

    /// Fraction of demanded prefill tokens the shared prefix cache kept
    /// off the GPUs (0 without the cache); see
    /// [`Metrics::prefill_compute_saving`].
    pub fn prefill_compute_saving(&self) -> f64 {
        self.fleet.prefill_compute_saving()
    }

    /// Fractional replica-seconds saved vs the static fleet (the
    /// "fewer GPUs at equal SLO" number; 0 for a static run).
    pub fn elastic_saving(&self) -> f64 {
        let stat = self.static_replica_seconds();
        if !self.elastic || stat <= 0.0 {
            return 0.0;
        }
        (1.0 - self.replica_seconds / stat).max(0.0)
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "cluster of {} replicas (policy {}) serving {}\n{}\n",
            self.per_replica.len(),
            self.policy.name(),
            self.model,
            self.fleet.summary()
        );
        for r in &self.per_replica {
            let role = match r.role {
                SchedMode::Full => "serve",
                SchedMode::PrefillOnly => "prefill",
                SchedMode::DecodeOnly => "decode",
            };
            s.push_str(&format!(
                "  {:<14} [{role:^7}] completed {:>4} | handoffs {:>4} | routed {:>9} tok | busy {:>8.3}s | util {:>5.1}%\n",
                r.name,
                r.completed,
                r.handoffs,
                r.routed_tokens,
                r.busy.value(),
                r.utilization * 100.0
            ));
        }
        s.push_str(&format!(
            "load imbalance (max/mean routed tokens): {:.3}\n",
            self.imbalance
        ));
        if self.handoffs > 0 {
            s.push_str(&format!(
                "KV handoffs: {} totalling {:.3} ms of transfer\n",
                self.handoffs,
                self.handoff_time.as_ms()
            ));
        }
        if self.fleet.paging_stall.value() > 0.0 || self.kv_spilled_peak.value() > 0.0 {
            s.push_str(&format!(
                "KV paging: {:.3} ms of decode stall | peak spill {:.2} GB to remote tier\n",
                self.fleet.paging_stall.as_ms(),
                self.kv_spilled_peak.as_gb()
            ));
        }
        if self.flash_spilled_peak.value() > 0.0 {
            s.push_str(&format!(
                "flash tier: peak spill {:.2} GB past the pool slice\n",
                self.flash_spilled_peak.as_gb()
            ));
        }
        if let Some(tenants) = &self.tenants {
            for t in tenants {
                s.push_str(&t.summary_line());
                s.push('\n');
            }
        }
        if let Some(fr) = &self.fabric {
            s.push_str(&fr.summary_line());
        }
        if let Some(pc) = &self.prefix_cache {
            s.push_str(&format!(
                "prefix-cache: hit-rate {:.1}% ({}/{} probes) | {} tokens reused | \
                 prefill compute saving {:.1}% | pool {:.2}/{:.2} GB held (peak {:.2}) | \
                 {} extents, {} evicted\n",
                100.0 * pc.hit_rate,
                pc.hits,
                pc.lookups,
                pc.hit_tokens,
                100.0 * self.prefill_compute_saving(),
                pc.pool_bytes_held.as_gb(),
                pc.capacity.as_gb(),
                pc.pool_bytes_peak.as_gb(),
                pc.entries,
                pc.evicted_tokens,
            ));
        }
        if self.elastic {
            s.push_str(&format!(
                "elastic: {:.1} replica-s provisioned vs {:.1} static ({:.1}% saving, \
                 {:.1} GPU-s) | {} scale events\n",
                self.replica_seconds,
                self.static_replica_seconds(),
                100.0 * self.elastic_saving(),
                self.gpu_seconds,
                self.scale_events.len(),
            ));
        }
        if let Some(fr) = &self.faults {
            s.push_str(&fr.summary_line());
            s.push('\n');
        }
        if let Some(tel) = &self.telemetry {
            s.push_str(&tel.summary_line());
            s.push('\n');
        }
        s
    }
}

/// Per-replica observables a core exposes at report time — the common
/// denominator of a `Scheduler` replica and an [`EventReplica`], so both
/// cores assemble their [`ClusterReport`] through the same code path.
struct ReplicaSnap<'a> {
    metrics: &'a Metrics,
    handoffs: u64,
    spilled: Bytes,
    /// Peak spill past the pool slice into flash (zero without a tier).
    flash: Bytes,
    /// Completion trace for the fault-recovery and per-tenant reports —
    /// empty unless a fault schedule or a tenants config armed trace
    /// recording on the replica.
    trace: &'a [CompletionEvent],
}

/// Mutable fault-injection state of one run (DESIGN.md §Faults): the
/// concrete timeline both cores replay, plus the counters the
/// [`FaultReport`] aggregates at report time.
struct FaultState {
    /// [`FaultSchedule::timeline`] — explicit faults plus the rejoins
    /// derived from crash repair times, in stable time order. Empty
    /// means strict passthrough: no fault code path executes.
    timeline: Vec<FaultSpec>,
    crashes: u64,
    rejoins: u64,
    module_failures: u64,
    link_degrades: u64,
    requeued: u64,
    reprefilled: u64,
    tokens_lost: u64,
    bytes_invalidated: Bytes,
    extents_invalidated: u64,
}

impl FaultState {
    fn new(timeline: Vec<FaultSpec>) -> Self {
        FaultState {
            timeline,
            crashes: 0,
            rejoins: 0,
            module_failures: 0,
            link_degrades: 0,
            requeued: 0,
            reprefilled: 0,
            tokens_lost: 0,
            bytes_invalidated: Bytes::ZERO,
            extents_invalidated: 0,
        }
    }
}

/// The multi-replica cluster simulator.
pub struct Cluster {
    replicas: Vec<Scheduler<SimBackend>>,
    names: Vec<String>,
    roles: Vec<SchedMode>,
    cfg: ClusterConfig,
    model: ModelArch,
    /// Routes arrivals over the serving pool (all replicas when
    /// aggregated, the prefill pool when disaggregated).
    router: Router,
    /// Disaggregated mode: least-outstanding-tokens over the decode pool.
    decode_router: Option<Router>,
    /// First decode-pool index (== prefill pool size).
    decode_base: usize,
    /// Response / handoff high-water marks per replica (for draining).
    resp_seen: Vec<usize>,
    handoff_seen: Vec<usize>,
    handoffs: u64,
    handoff_time: Seconds,
    /// Requests refused at the cluster front door (inadmissible prompts)
    /// — never routed, so they can't leak outstanding load in the router.
    rejected: u64,
    /// Requests dropped by overload shedding (`ClusterConfig::shed_tokens`).
    shed: u64,
    /// Cluster-wide shared prefix-KV cache in the TAB pool — one
    /// instance serving every replica (DESIGN.md §Prefix-Cache).
    prefix_cache: Option<PrefixCache>,
    /// Shared-fabric arbitration ledger (DESIGN.md §Fabric-Contention);
    /// None with contention off, keeping every charge unloaded.
    fabric: Option<FabricClock>,
    /// Total fabric queueing delay charged to requests (handoffs +
    /// prefix fetches) — folded into the fleet metrics at report time.
    fabric_wait: Seconds,
    /// Current active-set size (== fleet size without an autoscaler).
    active: usize,
    /// ∫ active dt accumulator and its last accounting timestamp.
    replica_seconds: f64,
    last_account: Seconds,
    /// Next autoscaler decision time.
    next_scale: Seconds,
    scale_events: Vec<(Seconds, usize)>,
    /// Fault timeline and counters (DESIGN.md §Faults); an empty
    /// timeline keeps every fault code path dormant.
    fstate: FaultState,
    /// Multi-tenant state (DESIGN.md §Multi-Tenant): replica → tenant
    /// model assignment (mutated by cold-start swaps), per-tenant
    /// counters, and the next admission-pump tick. Dormant without a
    /// tenants config.
    tassign: Vec<usize>,
    tstats: Vec<TenantStats>,
    next_admit: Seconds,
    /// Telemetry time-series recorder and the next sampling tick
    /// (DESIGN.md §Telemetry). Dormant without a telemetry config.
    sampler: Option<TelemetrySampler>,
    next_telemetry: Seconds,
}

impl Cluster {
    /// Build a cluster from per-replica node configs (see
    /// [`fh4_rack`] / [`crate::config::baseline_rack`]). With
    /// `cfg.disaggregate = Some((p, d))`, the first `p` systems form the
    /// prefill pool and the next `d` the decode pool; `p + d` must equal
    /// `systems.len()`.
    pub fn new(systems: Vec<SystemConfig>, model: &ModelArch, cfg: ClusterConfig) -> Result<Self> {
        if systems.is_empty() {
            return Err(FhError::Config("cluster needs at least one replica".into()));
        }
        // A rack-level flash tier applies uniformly: every replica's
        // node gains the same backing store below its pool slice.
        let systems: Vec<SystemConfig> = match cfg.flash {
            Some(f) => systems
                .into_iter()
                .map(|mut s| {
                    s.flash = Some(f);
                    s
                })
                .collect(),
            None => systems,
        };
        let (serving_pool, decode_base) = match cfg.disaggregate {
            Some((p, d)) => {
                if p == 0 || d == 0 || p + d != systems.len() {
                    return Err(FhError::Config(format!(
                        "disaggregate {p}:{d} does not cover {} replicas",
                        systems.len()
                    )));
                }
                (p, p)
            }
            None => (systems.len(), systems.len()),
        };
        // The shared cache lives in the pool of the (homogeneous) rack;
        // its geometry comes from the first replica's node config.
        let prefix_cache = match cfg.prefix_cache {
            Some(pc) => Some(PrefixCache::new(pc, &systems[0], model)?),
            None => None,
        };
        // Shared-fabric arbitration: one ledger for the whole rack, one
        // port per replica, budgets from the (homogeneous) node config.
        let mut fabric = match cfg.contention.mode {
            ContentionMode::Off => None,
            _ => Some(FabricClock::for_system(
                &systems[0],
                cfg.contention.resolved(systems.len()),
            )?),
        };
        // Fault schedule: validate against the fleet it will hit, derive
        // the concrete timeline, and register the (static) degrade
        // profile on the contention clock so both cores price every
        // fabric window identically (DESIGN.md §Faults).
        let fault_timeline = match &cfg.faults {
            Some(fs) => {
                fs.validate()?;
                let timeline = fs.timeline();
                let mut down = vec![false; systems.len()];
                for spec in &timeline {
                    match spec.kind {
                        FaultKind::ReplicaCrash { replica, .. } => {
                            if cfg.disaggregate.is_some() {
                                return Err(FhError::Config(
                                    "replica-crash faults drive aggregated fleets only \
                                     (a dead prefill pool has no evacuation target; \
                                     drop --disaggregate)"
                                        .into(),
                                ));
                            }
                            if replica >= systems.len() {
                                return Err(FhError::Config(format!(
                                    "fault schedule crashes replica {replica} but the \
                                     fleet has {}",
                                    systems.len()
                                )));
                            }
                            if down[replica] {
                                return Err(FhError::Config(format!(
                                    "replica {replica} crashes again before its rejoin"
                                )));
                            }
                            down[replica] = true;
                            if down.iter().all(|&d| d) {
                                return Err(FhError::Config(
                                    "fault schedule takes the whole fleet down at once \
                                     — nothing would serve the re-queued requests"
                                        .into(),
                                ));
                            }
                        }
                        FaultKind::ReplicaRejoin { replica } => {
                            debug_assert!(
                                replica < systems.len(),
                                "rejoins derive from bounds-checked crashes"
                            );
                            down[replica] = false;
                        }
                        FaultKind::ModuleFailure { module } => {
                            let Some(pc) = &cfg.prefix_cache else {
                                return Err(FhError::Config(
                                    "module-failure faults kill shared prefix-KV extents \
                                     — enable the prefix cache (--prefix-cache)"
                                        .into(),
                                ));
                            };
                            if let ModuleSel::Index(m) = module {
                                if m >= pc.modules {
                                    return Err(FhError::Config(format!(
                                        "fault schedule fails TAB module {m} but the \
                                         pool spreads over {}",
                                        pc.modules
                                    )));
                                }
                            }
                        }
                        FaultKind::LinkDegrade { factor, duration } => {
                            let Some(clock) = fabric.as_mut() else {
                                return Err(FhError::Config(
                                    "link-degrade faults scale contention budgets — \
                                     enable arbitration (--fabric-contention shared \
                                     or per-module)"
                                        .into(),
                                ));
                            };
                            clock.degrade(spec.at, spec.at + duration, factor);
                        }
                    }
                }
                timeline
            }
            None => Vec::new(),
        };
        // Multi-tenant validation (DESIGN.md §Multi-Tenant): tenancy
        // composes with the gate, shedding and the autoscaler, but not
        // with features whose state is keyed on one fleet-wide model.
        if let Some(tc) = &cfg.tenants {
            tc.validate()?;
            if cfg.disaggregate.is_some() {
                return Err(FhError::Config(
                    "multi-tenant serving drives aggregated fleets only (drop --disaggregate)"
                        .into(),
                ));
            }
            if cfg.prefix_cache.is_some() {
                return Err(FhError::Config(
                    "the shared prefix cache is keyed on a single model — drop \
                     --prefix-cache when serving multiple tenants"
                        .into(),
                ));
            }
            if cfg.faults.is_some() {
                return Err(FhError::Config(
                    "fault injection does not compose with multi-tenancy yet (drop --faults)"
                        .into(),
                ));
            }
        }
        // Telemetry composes with every feature; only the interval needs
        // validating (DESIGN.md §Telemetry).
        if let Some(tel) = &cfg.telemetry {
            tel.validate()?;
        }
        let mut replicas = Vec::with_capacity(systems.len());
        let mut names = Vec::with_capacity(systems.len());
        let mut roles = Vec::with_capacity(systems.len());
        for (i, sys) in systems.into_iter().enumerate() {
            sys.validate()?;
            let role = match cfg.disaggregate {
                Some(_) if i < decode_base => SchedMode::PrefillOnly,
                Some(_) => SchedMode::DecodeOnly,
                None => SchedMode::Full,
            };
            names.push(sys.name.clone());
            // Tenant fleets boot round-robin over the tenant models so
            // every tenant starts with a warm home somewhere; cold-start
            // swaps rebalance the assignment as traffic skews.
            let rmodel = match &cfg.tenants {
                Some(tc) => tc.tenants[i % tc.tenants.len()].model.clone(),
                None => model.clone(),
            };
            let mut backend = SimBackend::new(sys, rmodel.clone(), cfg.max_batch);
            if let Some(budget) = cfg.kv_budget {
                backend = backend.with_kv_budget(budget);
            }
            let batcher = Batcher::new(cfg.max_batch, 64, rmodel.max_seq as usize);
            let mut sched = Scheduler::new(backend, batcher).with_mode(role);
            if !fault_timeline.is_empty() || cfg.tenants.is_some() || cfg.telemetry.is_some() {
                // The recovery, per-tenant and rolling-attainment
                // reports need a completion trace; plain healthy runs
                // record nothing (passthrough).
                sched = sched.with_trace();
            }
            if cfg.telemetry.is_some() {
                sched = sched.with_telemetry();
            }
            replicas.push(sched);
            roles.push(role);
        }
        let mut router = Router::new(serving_pool, cfg.policy);
        let mut active = serving_pool;
        if let Some(a) = cfg.autoscale {
            if cfg.disaggregate.is_some() {
                return Err(FhError::Config(
                    "autoscaling drives aggregated fleets only (drop --disaggregate)".into(),
                ));
            }
            if a.min_replicas == 0 || a.min_replicas > serving_pool {
                return Err(FhError::Config(format!(
                    "autoscale min_replicas {} out of range for a {serving_pool}-replica fleet",
                    a.min_replicas
                )));
            }
            if a.interval.value() <= 0.0 || a.target_tokens == 0 {
                return Err(FhError::Config(
                    "autoscale interval and target_tokens must be positive".into(),
                ));
            }
            active = a.min_replicas;
            router.set_active(active);
        }
        let decode_router = cfg
            .disaggregate
            .map(|(_, d)| Router::new(d, Policy::LeastLoaded));
        let n = replicas.len();
        let next_scale = cfg.autoscale.map(|a| a.interval).unwrap_or(Seconds::ZERO);
        let (tassign, tstats, next_admit) = match &cfg.tenants {
            Some(tc) => (
                (0..n).map(|i| i % tc.tenants.len()).collect(),
                vec![TenantStats::default(); tc.tenants.len()],
                tc.admit_interval,
            ),
            None => (vec![0; n], Vec::new(), Seconds::ZERO),
        };
        let (sampler, next_telemetry) = match &cfg.telemetry {
            Some(tel) => (Some(TelemetrySampler::new(tel.interval)), tel.interval),
            None => (None, Seconds::ZERO),
        };
        Ok(Cluster {
            replicas,
            names,
            roles,
            cfg,
            model: model.clone(),
            router,
            decode_router,
            decode_base,
            resp_seen: vec![0; n],
            handoff_seen: vec![0; n],
            handoffs: 0,
            handoff_time: Seconds::ZERO,
            rejected: 0,
            shed: 0,
            prefix_cache,
            fabric,
            fabric_wait: Seconds::ZERO,
            active,
            replica_seconds: 0.0,
            last_account: Seconds::ZERO,
            next_scale,
            scale_events: Vec::new(),
            fstate: FaultState::new(fault_timeline),
            tassign,
            tstats,
            next_admit,
            sampler,
            next_telemetry,
        })
    }

    /// Convenience: an FH4-1.5xM rack at the default remote bandwidth
    /// ([`crate::config::DEFAULT_REMOTE_TBPS`]).
    pub fn fh4(replicas: usize, model: &ModelArch, cfg: ClusterConfig) -> Result<Self> {
        Cluster::new(
            fh4_rack(replicas, Bandwidth::tbps(crate::config::DEFAULT_REMOTE_TBPS)),
            model,
            cfg,
        )
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Current active-set size (== fleet size when not autoscaling).
    pub fn active(&self) -> usize {
        self.active
    }

    /// Fold the elapsed interval at the current active-set size into the
    /// provisioned-capacity integral.
    fn account(&mut self, t: Seconds) {
        let dt = (t - self.last_account).value();
        if dt > 0.0 {
            self.replica_seconds += self.active as f64 * dt;
            self.last_account = t;
        }
    }

    /// One autoscaler decision at virtual time `t` (DESIGN.md §Traffic):
    /// provision `ceil(outstanding / target_tokens)` active replicas —
    /// up immediately, down one step per tick. `queued_extra` is work
    /// the tenant arbiter holds at the front door (zero without
    /// tenants): demand the router can't see yet but the controller
    /// must still provision for.
    fn autoscale_tick(&mut self, t: Seconds, queued_extra: u64) {
        let Some(a) = self.cfg.autoscale else { return };
        let outstanding = self.router.total_load() + queued_extra;
        let desired = (outstanding.div_ceil(a.target_tokens).max(1) as usize)
            .clamp(a.min_replicas, self.replicas.len());
        let next = if desired > self.active {
            desired
        } else if desired < self.active {
            self.active - 1
        } else {
            self.active
        };
        if next != self.active {
            self.account(t);
            self.active = next;
            self.router.set_active(next);
            self.scale_events.push((t, next));
        }
    }

    /// Record one fleet gauge snapshot at tick instant `t`, stepping
    /// core. Every field is an integer counter or a pure copy of state
    /// both cores hold bit-identically at the tick (a global sync
    /// point), so [`Cluster::sample_event`] reads the same values.
    fn sample_stepping(&mut self, t: Seconds) {
        if self.sampler.is_none() {
            return;
        }
        let mut pending = 0u64;
        let mut completed = 0u64;
        let mut tokens_generated = 0u64;
        let mut slo_total = 0u64;
        let mut slo_met = 0u64;
        for r in &self.replicas {
            pending += r.pending() as u64;
            completed += r.metrics.completed;
            tokens_generated += r.metrics.tokens_generated;
            slo_total += r.metrics.slo_total;
            slo_met += r.metrics.slo_met;
        }
        let sample = TelemetrySample {
            at: t,
            active_replicas: self.active,
            routed_tokens: self.router.total_load(),
            pending,
            completed,
            tokens_generated,
            shed: self.shed,
            rejected: self.rejected,
            slo_total,
            slo_met,
            pool_bytes: self
                .prefix_cache
                .as_ref()
                .map_or(0.0, |pc| pc.held_bytes().value()),
            fabric_busy: self
                .fabric
                .as_ref()
                .map_or(Seconds::ZERO, |c| c.busy_time()),
        };
        self.sampler.as_mut().expect("checked above").record(sample);
    }

    /// Event-core twin of [`Cluster::sample_stepping`].
    fn sample_event(&mut self, evs: &[EventReplica], t: Seconds) {
        if self.sampler.is_none() {
            return;
        }
        let mut pending = 0u64;
        let mut completed = 0u64;
        let mut tokens_generated = 0u64;
        let mut slo_total = 0u64;
        let mut slo_met = 0u64;
        for r in evs {
            pending += r.pending() as u64;
            completed += r.metrics.completed;
            tokens_generated += r.metrics.tokens_generated;
            slo_total += r.metrics.slo_total;
            slo_met += r.metrics.slo_met;
        }
        let sample = TelemetrySample {
            at: t,
            active_replicas: self.active,
            routed_tokens: self.router.total_load(),
            pending,
            completed,
            tokens_generated,
            shed: self.shed,
            rejected: self.rejected,
            slo_total,
            slo_met,
            pool_bytes: self
                .prefix_cache
                .as_ref()
                .map_or(0.0, |pc| pc.held_bytes().value()),
            fabric_busy: self
                .fabric
                .as_ref()
                .map_or(Seconds::ZERO, |c| c.busy_time()),
        };
        self.sampler.as_mut().expect("checked above").record(sample);
    }

    /// Release router load for responses this replica finished since the
    /// last drain. A completed response's token vector is exactly the
    /// work the router charged (prompt + generation budget).
    fn drain_completions(&mut self, idx: usize) {
        let fresh = &self.replicas[idx].responses[self.resp_seen[idx]..];
        let works: Vec<u64> = fresh.iter().map(|r| r.tokens.len() as u64).collect();
        self.resp_seen[idx] = self.replicas[idx].responses.len();
        for w in works {
            match self.roles[idx] {
                SchedMode::DecodeOnly => {
                    if let Some(dr) = self.decode_router.as_mut() {
                        dr.complete_work(idx - self.decode_base, w);
                    }
                }
                _ => self.router.complete_work(idx, w),
            }
        }
    }

    /// Move fresh handoffs from prefill replica `idx` into decode
    /// replicas, charging the KV transfer over the fabric.
    fn transfer_handoffs(&mut self, idx: usize) {
        let fresh: Vec<_> =
            self.replicas[idx].handoffs[self.handoff_seen[idx]..].to_vec();
        self.handoff_seen[idx] = self.replicas[idx].handoffs.len();
        for h in fresh {
            // Prefill work (what route_work charged) leaves the prefill
            // replica once handed off.
            self.router
                .complete_work(idx, (h.req.prompt_len() + 1) as u64);
            let ctx = h.tokens.len() as u64;
            let kv = memory::kv_cache_bytes(&self.model, 1, ctx);
            let sys = &self.replicas[idx].backend().sys;
            let mut cost = sys.latencies.kv_handoff(kv, sys.fabric_bw, sys.is_fenghuang());
            // Arbitrated fabric: the ownership-record write contends for
            // command bandwidth with every other fleet transfer (the KV
            // itself never moves on a shared pool — metadata only). The
            // fixed Table 3.1 latencies above already cover the wire
            // time, so only the queueing delay is added.
            if let Some(clock) = self.fabric.as_mut() {
                let b = clock.book(h.done_at, HANDOFF_META_BYTES, idx, h.req.id);
                cost += b.queueing;
                self.fabric_wait += b.queueing;
            }
            self.handoffs += 1;
            self.handoff_time += cost;
            let dr = self.decode_router.as_mut().expect("disaggregated");
            // Outstanding decode work: context plus remaining generation
            // budget — released as the response's final token count.
            let work = (ctx + h.req.max_new_tokens as u64).saturating_sub(1);
            let di = self.decode_base + dr.route_work(h.req.affinity_key(), work);
            let ready = h.done_at + cost;
            self.replicas[di].inject(h, ready);
        }
    }

    /// Advance every replica's local clock to global time `t`, moving
    /// handoffs and releasing completed load along the way.
    fn advance_to(&mut self, t: Seconds) -> Result<()> {
        for i in 0..self.decode_base {
            self.replicas[i].run_until(t)?;
            self.drain_completions(i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_handoffs(i);
            }
        }
        for i in self.decode_base..self.replicas.len() {
            self.replicas[i].run_until(t)?;
            self.drain_completions(i);
        }
        Ok(())
    }

    /// Serve a workload to completion and produce the fleet report,
    /// driven by the event calendar (DESIGN.md §Event-Core).
    ///
    /// Arrivals and autoscaler ticks are the global synchronization
    /// points; between two of them each [`EventReplica`] resolves its
    /// own prefill/decode/handoff deadlines locally. Every router and
    /// autoscaler observation therefore happens at exactly the instants
    /// — and over exactly the floating-point state — the stepping loop
    /// produces, which is what keeps the two cores bit-identical.
    ///
    /// A `Cluster` is single-shot: run it once (either core).
    pub fn run(&mut self, mut reqs: Vec<Request>) -> Result<ClusterReport> {
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let mut arena = RequestArena::with_capacity(reqs.len());
        let mut cal = EventCalendar::with_capacity(reqs.len() + 1);
        for req in reqs {
            let arrival = req.arrival;
            let rid = arena.alloc(req);
            let ok = cal.push(arrival, EventKind::Arrival { req: rid });
            debug_assert!(ok, "sorted arrivals cannot land in the past");
        }
        let mut evs = self.build_event_replicas();
        // The whole fault timeline is known up front — schedule it all;
        // at equal instants the calendar fires faults before ticks and
        // arrivals (class order), and earlier-listed faults first (seq).
        for (i, spec) in self.fstate.timeline.iter().enumerate() {
            let ok = cal.push(spec.at, EventKind::Fault { idx: i });
            debug_assert!(ok, "fault times are validated non-negative");
        }
        if self.cfg.autoscale.is_some() {
            // Exactly one tick lives in the calendar at a time; each pop
            // reschedules the next (or drops it when the run is over).
            let ok = cal.push(self.next_scale, EventKind::AutoscaleTick);
            debug_assert!(ok);
        }
        // Multi-tenant arbitration state lives on the run's stack: the
        // admission closures borrow the cluster and the arbiter
        // disjointly (DESIGN.md §Multi-Tenant). Pump ticks are only
        // armed when admissions can actually be deferred — a single
        // ungated tenant drains at each arrival, keeping that config
        // bit-identical to a tenants-off run.
        let mut arb: Option<TenantArbiter<ReqId>> =
            self.cfg.tenants.as_ref().map(TenantArbiter::new);
        if self.cfg.tenants.as_ref().is_some_and(|tc| tc.needs_ticks()) {
            let ok = cal.push(self.next_admit, EventKind::TenantTick);
            debug_assert!(ok, "admit interval is validated positive");
        }
        if self.cfg.telemetry.is_some() {
            let ok = cal.push(self.next_telemetry, EventKind::TelemetryTick);
            debug_assert!(ok, "telemetry interval is validated positive");
        }
        while let Some(ev) = cal.pop() {
            match ev.kind {
                EventKind::Fault { idx } => {
                    // Advance-then-apply: bring the fleet to the fault
                    // instant only when something is actually in flight
                    // — a fault landing on an idle fleet (e.g. a rejoin
                    // long after the drain) must not stretch makespan.
                    // The stepping core applies the same rule.
                    let t = ev.time;
                    if evs.iter().any(|r| r.pending() > 0) {
                        self.advance_event_replicas(&arena, &mut evs, t)?;
                    }
                    self.apply_fault_event(&mut arena, &mut evs, idx, t)?;
                }
                EventKind::AutoscaleTick => {
                    let a = self.cfg.autoscale.expect("tick implies autoscale");
                    // Mirror of the stepping drain loop's `any pending`
                    // check: the first tick past the last arrival with
                    // nothing left in flight (and nothing the arbiter is
                    // still holding) is dropped — not ticked — and the
                    // calendar drains to empty.
                    if cal.arrivals_scheduled() == 0
                        && arb.as_ref().map_or(true, |a| a.is_empty())
                        && !evs.iter().any(|r| r.pending() > 0)
                    {
                        continue;
                    }
                    let t = ev.time;
                    self.advance_event_replicas(&arena, &mut evs, t)?;
                    let queued = arb.as_ref().map_or(0, |a| a.queued_tokens());
                    self.autoscale_tick(t, queued);
                    self.next_scale += a.interval;
                    let ok = cal.push(self.next_scale, EventKind::AutoscaleTick);
                    debug_assert!(ok, "tick interval is validated positive");
                }
                EventKind::TenantTick => {
                    let interval = self
                        .cfg
                        .tenants
                        .as_ref()
                        .expect("tick implies tenants")
                        .admit_interval;
                    // Same drop rule as the autoscale tick: once the
                    // arrivals are exhausted with nothing queued at the
                    // door or in flight, the pump retires for good.
                    if cal.arrivals_scheduled() == 0
                        && arb.as_ref().map_or(true, |a| a.is_empty())
                        && !evs.iter().any(|r| r.pending() > 0)
                    {
                        continue;
                    }
                    let t = ev.time;
                    self.advance_event_replicas(&arena, &mut evs, t)?;
                    if let Some(arb) = arb.as_mut() {
                        self.pump_event(&mut arena, &mut evs, arb, t);
                    }
                    self.next_admit += interval;
                    let ok = cal.push(self.next_admit, EventKind::TenantTick);
                    debug_assert!(ok, "admit interval is validated positive");
                }
                EventKind::TelemetryTick => {
                    let interval = self
                        .cfg
                        .telemetry
                        .as_ref()
                        .expect("tick implies telemetry")
                        .interval;
                    // Same drop rule as the other ticks: the first due
                    // sample that observes neither arrivals, queued
                    // admissions nor in-flight work retires the series.
                    if cal.arrivals_scheduled() == 0
                        && arb.as_ref().map_or(true, |a| a.is_empty())
                        && !evs.iter().any(|r| r.pending() > 0)
                    {
                        continue;
                    }
                    let t = ev.time;
                    self.advance_event_replicas(&arena, &mut evs, t)?;
                    self.sample_event(&evs, t);
                    self.next_telemetry += interval;
                    let ok = cal.push(self.next_telemetry, EventKind::TelemetryTick);
                    debug_assert!(ok, "telemetry interval is validated positive");
                }
                EventKind::Arrival { req } => match arb.as_mut() {
                    Some(arb) => self.enqueue_event_arrival(&mut arena, &mut evs, arb, req)?,
                    None => self.admit_event_arrival(&mut arena, &mut evs, req)?,
                },
                // Replica-local deadlines are resolved lazily inside
                // `advance_event_replicas`; the bit-compatible driver
                // never schedules them (DESIGN.md §Event-Core).
                EventKind::PrefillDone { .. }
                | EventKind::DecodeTick { .. }
                | EventKind::MigrationDone { .. }
                | EventKind::HandoffDone { .. } => {}
            }
        }
        // Drain, mirroring the stepping core: prefill/serving pool first
        // (its completion produces the final handoffs), then decode.
        for i in 0..self.decode_base {
            evs[i].run_to_completion(&arena)?;
            self.drain_event_completions(&mut evs, i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_event_handoffs(&arena, &mut evs, i);
            }
        }
        for i in self.decode_base..evs.len() {
            evs[i].run_to_completion(&arena)?;
            self.drain_event_completions(&mut evs, i);
        }
        let makespan = evs
            .iter()
            .map(|r| r.metrics.clock)
            .fold(Seconds::ZERO, Seconds::max);
        if self.cfg.autoscale.is_some() {
            self.account(makespan);
        } else {
            self.replica_seconds = evs.len() as f64 * makespan.value();
        }
        Ok(self.report_event(&mut evs))
    }

    /// Fresh lean replicas mirroring this cluster's fleet: same node
    /// configs, roles and batching knobs as the `Scheduler` replicas.
    fn build_event_replicas(&self) -> Vec<EventReplica> {
        self.replicas
            .iter()
            .zip(&self.roles)
            .enumerate()
            .map(|(i, (r, &role))| {
                // Same boot assignment as the stepping fleet: tenant
                // models round-robin, the fleet model otherwise.
                let rmodel = match &self.cfg.tenants {
                    Some(tc) => tc.tenants[i % tc.tenants.len()].model.clone(),
                    None => self.model.clone(),
                };
                let mut backend = SimBackend::new(
                    r.backend().sys.clone(),
                    rmodel.clone(),
                    self.cfg.max_batch,
                );
                if let Some(budget) = self.cfg.kv_budget {
                    backend = backend.with_kv_budget(budget);
                }
                let ev = EventReplica::new(
                    backend,
                    role,
                    self.cfg.max_batch,
                    64,
                    rmodel.max_seq as usize,
                );
                let ev = if self.cfg.telemetry.is_some() {
                    ev.with_telemetry()
                } else {
                    ev
                };
                if self.fstate.timeline.is_empty()
                    && self.cfg.tenants.is_none()
                    && self.cfg.telemetry.is_none()
                {
                    ev
                } else {
                    ev.with_trace()
                }
            })
            .collect()
    }

    /// Event-core mirror of the arrival body of [`Cluster::run_stepping`]:
    /// advance the fleet to the arrival, shed or route, probe and
    /// publish the prefix cache, submit — then retire the prompt buffer
    /// (nothing downstream of admission reads token bytes).
    fn admit_event_arrival(
        &mut self,
        arena: &mut RequestArena,
        evs: &mut [EventReplica],
        rid: ReqId,
    ) -> Result<()> {
        let arrival = arena.get(rid).arrival;
        self.advance_event_replicas(arena, evs, arrival)?;
        if let Some(cap) = self.cfg.shed_tokens {
            if self.router.min_active_load() > cap {
                self.shed += 1;
                return Ok(());
            }
        }
        let hit = match self.prefix_cache.as_mut() {
            Some(pc) => pc.lookup(arena.get(rid).prompt()),
            None => super::prefix_cache::PrefixHit::MISS,
        };
        let warm = if hit.tokens > 0 { hit.replica } else { None };
        let (prompt_len, affinity, work_tokens) = {
            let e = arena.get(rid);
            (e.prompt_len, e.affinity_key(), e.work_tokens())
        };
        let charged = match self.cfg.disaggregate {
            Some(_) => (prompt_len + 1) as u64,
            None => work_tokens,
        };
        let idx = self.router.route_work_warm(affinity, charged, warm);
        if !evs[idx].admits(prompt_len) {
            self.router.unroute(idx, charged);
            self.rejected += 1;
            return Ok(());
        }
        if let Some(pc) = self.prefix_cache.as_mut() {
            {
                let e = arena.get_mut(rid);
                e.cached_prefix = hit.tokens;
                e.prefix_fetch = hit.fetch;
                e.prefix_home = hit.home;
            }
            let nmc = pc.nmc_gather();
            let inserted = pc.insert(arena.get(rid).prompt(), idx);
            if let Some(clock) = self.fabric.as_mut() {
                let lat = evs[idx].backend().sys.latencies;
                if hit.tokens > 0 {
                    let b = clock.book(arrival, hit.bytes, idx, affinity);
                    arena.get_mut(rid).prefix_fetch = if nmc {
                        lat.tab_read + b.queueing
                    } else {
                        lat.tab_read + (b.completion - arrival)
                    };
                    self.fabric_wait += b.queueing;
                }
                if inserted > 0 {
                    clock.book(arrival, PREFIX_PUBLISH_META_BYTES, idx, affinity);
                }
            }
        }
        evs[idx].submit(rid);
        // Prompt retirement is the event core's memory win, but a fault
        // schedule may need the tokens again — crash evacuees re-probe
        // the cache and re-publish on re-admission — so faulted runs
        // keep them resident. Healthy runs retire as before.
        if self.fstate.timeline.is_empty() {
            arena.retire_prompt(rid);
        }
        Ok(())
    }

    /// Multi-tenant arrival, event core: advance the fleet, shed- and
    /// quota-check at the front door, hand the request to the arbiter,
    /// and pump admissions at the arrival instant. Mirror of the
    /// tenants-on arrival body of [`Cluster::run_stepping`].
    fn enqueue_event_arrival(
        &mut self,
        arena: &mut RequestArena,
        evs: &mut [EventReplica],
        arb: &mut TenantArbiter<ReqId>,
        rid: ReqId,
    ) -> Result<()> {
        let arrival = arena.get(rid).arrival;
        self.advance_event_replicas(arena, evs, arrival)?;
        if let Some(cap) = self.cfg.shed_tokens {
            if self.router.min_active_load() > cap {
                self.shed += 1;
                return Ok(());
            }
        }
        let (tenant, work, prompt_len, affinity) = {
            let e = arena.get(rid);
            (e.tenant, e.work_tokens(), e.prompt_len, e.affinity_key())
        };
        let tc = self.cfg.tenants.as_ref().expect("arbiter implies tenants");
        if let Some(quota) = tc.tenants[tenant].quota_tokens {
            if self.tstats[tenant].enqueued_tokens + work > quota {
                self.tstats[tenant].shed_quota += 1;
                self.shed += 1;
                return Ok(());
            }
        }
        self.tstats[tenant].enqueued_tokens += work;
        arb.enqueue(tenant, Queued { work, prompt_len, affinity, payload: rid });
        // Nothing downstream of admission reads prompt bytes (tenancy
        // forbids the prefix cache and faults), so retire eagerly.
        arena.retire_prompt(rid);
        self.pump_event(arena, evs, arb, arrival);
        Ok(())
    }

    /// Drain the arbiter into the fleet at instant `t`, event core. The
    /// admission closure picks a replica, routes, admission-checks
    /// against the tenant's model, swaps a cold tenant's model in, and
    /// submits; each verdict feeds the arbiter's deficit accounting.
    /// Mirror of [`Cluster::pump_stepping`].
    fn pump_event(
        &mut self,
        arena: &mut RequestArena,
        evs: &mut [EventReplica],
        arb: &mut TenantArbiter<ReqId>,
        t: Seconds,
    ) {
        let tc = self.cfg.tenants.clone().expect("arbiter implies tenants");
        let gate = tc.admit_tokens.unwrap_or(u64::MAX);
        arb.pump(|tenant, q| {
            let load: Vec<u64> = (0..evs.len()).map(|i| self.router.load(i)).collect();
            let pending: Vec<usize> = evs.iter().map(|r| r.pending()).collect();
            let pick =
                pick_replica(tenant, &self.tassign, &load, &pending, self.active, gate);
            let idx = match pick {
                Pick::Fleet => self.router.route_work(q.affinity, q.work),
                Pick::Assigned(i) | Pick::Swap(i) => {
                    self.router.route_to(i, q.work);
                    i
                }
                Pick::Blocked => return Admit::Blocked(q),
            };
            let max_seq = tc.tenants[tenant].model.max_seq as usize;
            if q.prompt_len == 0 || q.prompt_len > max_seq {
                self.router.unroute(idx, q.work);
                self.rejected += 1;
                return Admit::Rejected;
            }
            if matches!(pick, Pick::Swap(_)) {
                let model = &tc.tenants[tenant].model;
                let bytes = memory::param_bytes(model);
                // Weights page in from the flash tier when the rack has
                // one, else over the pool fabric; an arbitrated fabric
                // adds the ledger's queueing delay on top.
                let bw = match self.cfg.flash {
                    Some(f) => f.bandwidth,
                    None => evs[idx].backend().sys.fabric_bw,
                };
                let mut stall = bytes.over(bw);
                if let Some(clock) = self.fabric.as_mut() {
                    let b = clock.book(t, bytes, idx, q.affinity);
                    stall += b.queueing;
                    self.fabric_wait += b.queueing;
                }
                evs[idx].set_model(model.clone());
                self.tassign[idx] = tenant;
                self.tstats[tenant].swaps += 1;
                self.tstats[tenant].cold_start.record(stall);
                self.tstats[tenant].cold_start_total += stall;
                // The triggering request pays the cold start as a serial
                // stall on its prefill step.
                arena.get_mut(q.payload).swap_stall = stall;
            }
            self.tstats[tenant].admitted_requests += 1;
            self.tstats[tenant].admitted_tokens += q.work;
            evs[idx].submit(q.payload);
            Admit::Served
        });
    }

    /// Event-core mirror of [`Cluster::advance_to`].
    fn advance_event_replicas(
        &mut self,
        arena: &RequestArena,
        evs: &mut [EventReplica],
        t: Seconds,
    ) -> Result<()> {
        for i in 0..self.decode_base {
            evs[i].run_until(arena, t)?;
            self.drain_event_completions(evs, i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_event_handoffs(arena, evs, i);
            }
        }
        for i in self.decode_base..evs.len() {
            evs[i].run_until(arena, t)?;
            self.drain_event_completions(evs, i);
        }
        Ok(())
    }

    /// Event-core mirror of [`Cluster::drain_completions`] — the lean
    /// replica hands over released work directly, no response scan.
    fn drain_event_completions(&mut self, evs: &mut [EventReplica], idx: usize) {
        for w in evs[idx].take_completed_work() {
            match self.roles[idx] {
                SchedMode::DecodeOnly => {
                    if let Some(dr) = self.decode_router.as_mut() {
                        dr.complete_work(idx - self.decode_base, w);
                    }
                }
                _ => self.router.complete_work(idx, w),
            }
        }
    }

    /// Event-core mirror of [`Cluster::transfer_handoffs`].
    fn transfer_event_handoffs(
        &mut self,
        arena: &RequestArena,
        evs: &mut [EventReplica],
        idx: usize,
    ) {
        let fresh = evs[idx].take_handoffs();
        if fresh.is_empty() {
            return;
        }
        let (lat, fabric_bw, is_fh) = {
            let sys = &evs[idx].backend().sys;
            (sys.latencies, sys.fabric_bw, sys.is_fenghuang())
        };
        for h in fresh {
            self.router.complete_work(idx, h.len as u64);
            let ctx = h.len as u64;
            let kv = memory::kv_cache_bytes(&self.model, 1, ctx);
            let mut cost = lat.kv_handoff(kv, fabric_bw, is_fh);
            let e = arena.get(h.id);
            if let Some(clock) = self.fabric.as_mut() {
                let b = clock.book(h.done_at, HANDOFF_META_BYTES, idx, e.id);
                cost += b.queueing;
                self.fabric_wait += b.queueing;
            }
            self.handoffs += 1;
            self.handoff_time += cost;
            let dr = self.decode_router.as_mut().expect("disaggregated");
            let work = (ctx + e.max_new_tokens as u64).saturating_sub(1);
            let di = self.decode_base + dr.route_work(e.affinity_key(), work);
            let ready = h.done_at + cost;
            evs[di].inject(h, ready);
        }
    }

    /// Apply fault `idx` of the timeline at instant `t` — event-core
    /// side. The stepping twin is [`Cluster::apply_fault_stepping`];
    /// every router/cache/fabric mutation must match it exactly.
    fn apply_fault_event(
        &mut self,
        arena: &mut RequestArena,
        evs: &mut [EventReplica],
        idx: usize,
        t: Seconds,
    ) -> Result<()> {
        match self.fstate.timeline[idx].kind {
            FaultKind::ReplicaCrash { replica, .. } => {
                self.fstate.crashes += 1;
                let (evacuees, lost) = evs[replica].evacuate();
                // Release every evacuee's routing charge before the
                // replica leaves the pool, then re-route in evacuation
                // order (queue FIFO, then the active set) — the router
                // must observe the dead replica with zero load.
                for &rid in &evacuees {
                    self.router.complete_work(replica, arena.get(rid).work_tokens());
                }
                self.router.mark_dead(replica);
                self.fstate.tokens_lost += lost;
                self.fstate.requeued += evacuees.len() as u64;
                for rid in evacuees {
                    self.readmit_event(arena, evs, rid, t);
                }
            }
            FaultKind::ReplicaRejoin { replica } => {
                // Back in the pool with cold caches: zero outstanding
                // load, no warm pages — the router will refill it.
                self.router.mark_alive(replica);
                self.fstate.rejoins += 1;
            }
            FaultKind::ModuleFailure { module } => {
                let pc = self
                    .prefix_cache
                    .as_mut()
                    .expect("validated: module faults require the prefix cache");
                let m = match module {
                    ModuleSel::Index(i) => i,
                    ModuleSel::Hottest => pc.hottest_module(),
                };
                let (bytes, extents) = pc.fail_module(m);
                self.fstate.module_failures += 1;
                self.fstate.bytes_invalidated += bytes;
                self.fstate.extents_invalidated += extents;
                // Queued requests holding a grant on the dead module
                // must prefill those tokens after all; decodes already
                // running used their local HBM copy and are unaffected.
                let mut revoked = 0u64;
                for ev in evs.iter() {
                    let ids: Vec<ReqId> = ev.queued_ids().collect();
                    for rid in ids {
                        let e = arena.get_mut(rid);
                        if e.cached_prefix > 0 && e.prefix_home == Some(m) {
                            e.cached_prefix = 0;
                            e.prefix_fetch = Seconds::ZERO;
                            e.prefix_home = None;
                            revoked += 1;
                        }
                    }
                }
                self.fstate.reprefilled += revoked;
            }
            FaultKind::LinkDegrade { .. } => {
                // The degrade profile registered on the contention clock
                // at construction (the schedule is static); the event
                // only marks the injection for the report.
                self.fstate.link_degrades += 1;
            }
        }
        Ok(())
    }

    /// Re-route one crash evacuee at fault time `t` — the admission path
    /// minus shedding (an already-admitted request is never dropped at
    /// the door) with the prior prefix grant revoked: the in-flight
    /// fetch died with the replica, so the request re-probes the pool.
    fn readmit_event(&mut self, arena: &mut RequestArena, evs: &mut [EventReplica], rid: ReqId, t: Seconds) {
        {
            let e = arena.get_mut(rid);
            if e.cached_prefix > 0 {
                self.fstate.reprefilled += 1;
            }
            e.cached_prefix = 0;
            e.prefix_fetch = Seconds::ZERO;
            e.prefix_home = None;
        }
        let hit = match self.prefix_cache.as_mut() {
            Some(pc) => pc.lookup(arena.get(rid).prompt()),
            None => super::prefix_cache::PrefixHit::MISS,
        };
        let warm = if hit.tokens > 0 { hit.replica } else { None };
        let (prompt_len, affinity, charged) = {
            let e = arena.get(rid);
            // Crash faults are aggregated-only, so the charge is always
            // the full work estimate.
            (e.prompt_len, e.affinity_key(), e.work_tokens())
        };
        let idx = self.router.route_work_warm(affinity, charged, warm);
        if !evs[idx].admits(prompt_len) {
            self.router.unroute(idx, charged);
            self.rejected += 1;
            return;
        }
        if let Some(pc) = self.prefix_cache.as_mut() {
            {
                let e = arena.get_mut(rid);
                e.cached_prefix = hit.tokens;
                e.prefix_fetch = hit.fetch;
                e.prefix_home = hit.home;
            }
            let nmc = pc.nmc_gather();
            let inserted = pc.insert(arena.get(rid).prompt(), idx);
            if let Some(clock) = self.fabric.as_mut() {
                let lat = evs[idx].backend().sys.latencies;
                if hit.tokens > 0 {
                    let b = clock.book(t, hit.bytes, idx, affinity);
                    arena.get_mut(rid).prefix_fetch = if nmc {
                        lat.tab_read + b.queueing
                    } else {
                        lat.tab_read + (b.completion - t)
                    };
                    self.fabric_wait += b.queueing;
                }
                if inserted > 0 {
                    clock.book(t, PREFIX_PUBLISH_META_BYTES, idx, affinity);
                }
            }
        }
        evs[idx].submit(rid);
    }

    /// Stepping-core twin of [`Cluster::apply_fault_event`].
    fn apply_fault_stepping(&mut self, spec: FaultSpec, t: Seconds) -> Result<()> {
        match spec.kind {
            FaultKind::ReplicaCrash { replica, .. } => {
                self.fstate.crashes += 1;
                let (evacuees, lost) = self.replicas[replica].evacuate();
                for r in &evacuees {
                    self.router.complete_work(replica, r.work_tokens());
                }
                self.router.mark_dead(replica);
                self.fstate.tokens_lost += lost;
                self.fstate.requeued += evacuees.len() as u64;
                for r in evacuees {
                    self.readmit_stepping(r, t);
                }
            }
            FaultKind::ReplicaRejoin { replica } => {
                self.router.mark_alive(replica);
                self.fstate.rejoins += 1;
            }
            FaultKind::ModuleFailure { module } => {
                let pc = self
                    .prefix_cache
                    .as_mut()
                    .expect("validated: module faults require the prefix cache");
                let m = match module {
                    ModuleSel::Index(i) => i,
                    ModuleSel::Hottest => pc.hottest_module(),
                };
                let (bytes, extents) = pc.fail_module(m);
                self.fstate.module_failures += 1;
                self.fstate.bytes_invalidated += bytes;
                self.fstate.extents_invalidated += extents;
                let mut revoked = 0u64;
                for r in self.replicas.iter_mut() {
                    revoked += r.revoke_cached_prefix(|h| h == m) as u64;
                }
                self.fstate.reprefilled += revoked;
            }
            FaultKind::LinkDegrade { .. } => {
                self.fstate.link_degrades += 1;
            }
        }
        Ok(())
    }

    /// Stepping-core twin of [`Cluster::readmit_event`].
    fn readmit_stepping(&mut self, mut req: Request, t: Seconds) {
        if req.cached_prefix > 0 {
            self.fstate.reprefilled += 1;
        }
        req.cached_prefix = 0;
        req.prefix_fetch = Seconds::ZERO;
        req.prefix_home = None;
        let hit = match self.prefix_cache.as_mut() {
            Some(pc) => pc.lookup(&req.prompt),
            None => super::prefix_cache::PrefixHit::MISS,
        };
        let warm = if hit.tokens > 0 { hit.replica } else { None };
        let charged = req.work_tokens();
        let idx = self.router.route_work_warm(req.affinity_key(), charged, warm);
        if !self.replicas[idx].admits(&req) {
            self.router.unroute(idx, charged);
            self.rejected += 1;
            return;
        }
        if let Some(pc) = self.prefix_cache.as_mut() {
            req.cached_prefix = hit.tokens;
            req.prefix_fetch = hit.fetch;
            req.prefix_home = hit.home;
            let nmc = pc.nmc_gather();
            let inserted = pc.insert(&req.prompt, idx);
            if let Some(clock) = self.fabric.as_mut() {
                let lat = self.replicas[idx].backend().sys.latencies;
                if hit.tokens > 0 {
                    let b = clock.book(t, hit.bytes, idx, req.affinity_key());
                    req.prefix_fetch = if nmc {
                        lat.tab_read + b.queueing
                    } else {
                        lat.tab_read + (b.completion - t)
                    };
                    self.fabric_wait += b.queueing;
                }
                if inserted > 0 {
                    clock.book(t, PREFIX_PUBLISH_META_BYTES, idx, req.affinity_key());
                }
            }
        }
        self.replicas[idx].submit_all(vec![req]);
    }

    /// Stepping-core twin of [`Cluster::pump_event`]: drain the tenant
    /// arbiter in weighted-fair order, placing each admitted request on
    /// its tenant's replica (swapping an idle one when the tenant has no
    /// home) and charging cold-start transfers through the fabric clock.
    fn pump_stepping(&mut self, arb: &mut TenantArbiter<Request>, t: Seconds) {
        let tc = self.cfg.tenants.clone().expect("arbiter implies tenants");
        let gate = tc.admit_tokens.unwrap_or(u64::MAX);
        arb.pump(|tenant, mut q| {
            let load: Vec<u64> = (0..self.replicas.len()).map(|i| self.router.load(i)).collect();
            let pending: Vec<usize> = self.replicas.iter().map(|r| r.pending()).collect();
            let pick = pick_replica(tenant, &self.tassign, &load, &pending, self.active, gate);
            let idx = match pick {
                Pick::Fleet => self.router.route_work(q.affinity, q.work),
                Pick::Assigned(i) | Pick::Swap(i) => {
                    self.router.route_to(i, q.work);
                    i
                }
                Pick::Blocked => return Admit::Blocked(q),
            };
            let max_seq = tc.tenants[tenant].model.max_seq as usize;
            if q.prompt_len == 0 || q.prompt_len > max_seq {
                self.router.unroute(idx, q.work);
                self.rejected += 1;
                return Admit::Rejected;
            }
            if matches!(pick, Pick::Swap(_)) {
                let model = &tc.tenants[tenant].model;
                let bytes = memory::param_bytes(model);
                let bw = match self.cfg.flash {
                    Some(f) => f.bandwidth,
                    None => self.replicas[idx].backend().sys.fabric_bw,
                };
                let mut stall = bytes.over(bw);
                if let Some(clock) = self.fabric.as_mut() {
                    let b = clock.book(t, bytes, idx, q.affinity);
                    stall += b.queueing;
                    self.fabric_wait += b.queueing;
                }
                self.replicas[idx].set_model(model.clone());
                self.tassign[idx] = tenant;
                self.tstats[tenant].swaps += 1;
                self.tstats[tenant].cold_start.record(stall);
                self.tstats[tenant].cold_start_total += stall;
                q.payload.swap_stall = stall;
            }
            self.tstats[tenant].admitted_requests += 1;
            self.tstats[tenant].admitted_tokens += q.work;
            self.replicas[idx].submit_all(vec![q.payload]);
            Admit::Served
        });
    }

    /// Serve a workload to completion with the original tick-stepping
    /// core. Kept as the reduced oracle for the differential equivalence
    /// suite — production callers use [`Cluster::run`].
    pub fn run_stepping(&mut self, mut reqs: Vec<Request>) -> Result<ClusterReport> {
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        let timeline: Vec<FaultSpec> = self.fstate.timeline.clone();
        let mut fi = 0usize;
        // Multi-tenant arbitration state (mirror of the stack state in
        // [`Cluster::run`]); pump ticks are only armed when admissions
        // can actually be deferred.
        let mut arb: Option<TenantArbiter<Request>> =
            self.cfg.tenants.as_ref().map(TenantArbiter::new);
        let admit_interval = self
            .cfg
            .tenants
            .as_ref()
            .map(|tc| tc.admit_interval)
            .unwrap_or(Seconds::ZERO);
        let admit_ticking = self.cfg.tenants.as_ref().is_some_and(|tc| tc.needs_ticks());
        let telemetry_on = self.cfg.telemetry.is_some();
        let telemetry_interval = self
            .cfg
            .telemetry
            .as_ref()
            .map(|tel| tel.interval)
            .unwrap_or(Seconds::ZERO);
        for mut req in reqs {
            // Faults, autoscaler decisions, tenant admission pumps and
            // telemetry samples fire on their own cadences, interleaved
            // in virtual-time order with the arrivals. Ties follow the
            // event calendar's class order: fault, then scale tick, then
            // admission pump, then telemetry sample, then the arrival
            // itself.
            loop {
                let mut due: Option<(Seconds, u8)> = None;
                let mut consider = |t: Seconds, class: u8| {
                    if due.map_or(true, |(dt, dc)| t < dt || (t == dt && class < dc)) {
                        due = Some((t, class));
                    }
                };
                if let Some(ft) =
                    timeline.get(fi).map(|s| s.at).filter(|&ft| ft <= req.arrival)
                {
                    consider(ft, 0);
                }
                if self.cfg.autoscale.is_some() && self.next_scale <= req.arrival {
                    consider(self.next_scale, 1);
                }
                if admit_ticking && self.next_admit <= req.arrival {
                    consider(self.next_admit, 2);
                }
                if telemetry_on && self.next_telemetry <= req.arrival {
                    consider(self.next_telemetry, 3);
                }
                match due {
                    Some((ft, 0)) => {
                        // An idle-fleet fault must not stretch the
                        // makespan: only advance when work is in flight.
                        if self.replicas.iter().any(|r| r.pending() > 0) {
                            self.advance_to(ft)?;
                        }
                        let spec = timeline[fi];
                        self.apply_fault_stepping(spec, ft)?;
                        fi += 1;
                    }
                    Some((ts, 1)) => {
                        self.advance_to(ts)?;
                        let queued = arb.as_ref().map_or(0, |a| a.queued_tokens());
                        self.autoscale_tick(ts, queued);
                        self.next_scale +=
                            self.cfg.autoscale.expect("due implies autoscale").interval;
                    }
                    Some((ta, 2)) => {
                        self.advance_to(ta)?;
                        if let Some(arb) = arb.as_mut() {
                            self.pump_stepping(arb, ta);
                        }
                        self.next_admit += admit_interval;
                    }
                    Some((tt, _)) => {
                        self.advance_to(tt)?;
                        self.sample_stepping(tt);
                        self.next_telemetry += telemetry_interval;
                    }
                    None => break,
                }
            }
            self.advance_to(req.arrival)?;
            // Load shedding: when even the emptiest active replica is
            // past the watermark the fleet is saturated — drop at the
            // front door rather than queue into a blown SLO.
            if let Some(cap) = self.cfg.shed_tokens {
                if self.router.min_active_load() > cap {
                    self.shed += 1;
                    continue;
                }
            }
            // Multi-tenant front door: quota-check, hand to the arbiter,
            // pump at the arrival instant (mirror of
            // [`Cluster::enqueue_event_arrival`]).
            if let Some(arb) = arb.as_mut() {
                let tenant = req.tenant;
                let work = req.work_tokens();
                let tc = self.cfg.tenants.as_ref().expect("arbiter implies tenants");
                if let Some(quota) = tc.tenants[tenant].quota_tokens {
                    if self.tstats[tenant].enqueued_tokens + work > quota {
                        self.tstats[tenant].shed_quota += 1;
                        self.shed += 1;
                        continue;
                    }
                }
                self.tstats[tenant].enqueued_tokens += work;
                let arrival = req.arrival;
                let q = Queued {
                    work,
                    prompt_len: req.prompt_len(),
                    affinity: req.affinity_key(),
                    payload: req,
                };
                arb.enqueue(tenant, q);
                self.pump_stepping(arb, arrival);
                continue;
            }
            // Shared prefix-cache probe (DESIGN.md §Prefix-Cache): the
            // longest cached prefix of this prompt skips prefill compute
            // and is fetched from the pool instead. The probe also names
            // the replica with warm local pages so least-loaded routing
            // can prefer it before falling back to the shared pool.
            let hit = match self.prefix_cache.as_mut() {
                Some(pc) => pc.lookup(&req.prompt),
                None => super::prefix_cache::PrefixHit::MISS,
            };
            let warm = if hit.tokens > 0 { hit.replica } else { None };
            // Aggregated replicas own prompt + generation; a prefill pool
            // member only owns the prompt (+1 first token) until handoff.
            let charged = match self.cfg.disaggregate {
                Some(_) => (req.prompt_len() + 1) as u64,
                None => req.work_tokens(),
            };
            let idx = self.router.route_work_warm(req.affinity_key(), charged, warm);
            // Admission control: a request the target replica's batcher
            // would refuse must not keep its routing charge (the load
            // would never be released and would repel least-loaded and
            // kv-affinity decisions from that replica forever).
            if !self.replicas[idx].admits(&req) {
                self.router.unroute(idx, charged);
                self.rejected += 1;
                continue;
            }
            if let Some(pc) = self.prefix_cache.as_mut() {
                req.cached_prefix = hit.tokens;
                req.prefix_fetch = hit.fetch;
                req.prefix_home = hit.home;
                let nmc = pc.nmc_gather();
                // Publish this request's prefix KV: produced into the
                // pool by `idx`, visible to every replica from the next
                // arrival on (publication is metadata-only on TAB).
                let inserted = pc.insert(&req.prompt, idx);
                // Arbitrated fabric: re-price the unloaded fetch through
                // the ledger and book the publication metadata.
                if let Some(clock) = self.fabric.as_mut() {
                    let lat = self.replicas[idx].backend().sys.latencies;
                    if hit.tokens > 0 {
                        let b =
                            clock.book(req.arrival, hit.bytes, idx, req.affinity_key());
                        // NMC gather streams KV in-pool under the
                        // attention pass: only the command latency and
                        // the arbitration delay are exposed. A staged
                        // fetch exposes the whole congestion-adjusted
                        // transfer (queueing + Eq 4.1 serialization).
                        req.prefix_fetch = if nmc {
                            lat.tab_read + b.queueing
                        } else {
                            lat.tab_read + (b.completion - req.arrival)
                        };
                        self.fabric_wait += b.queueing;
                    }
                    // Publication loads the fabric but charges the
                    // request nothing (metadata write, fire-and-forget).
                    // A fully-cached prompt publishes nothing — no
                    // phantom booking for it.
                    if inserted > 0 {
                        clock.book(
                            req.arrival,
                            PREFIX_PUBLISH_META_BYTES,
                            idx,
                            req.affinity_key(),
                        );
                    }
                }
            }
            self.replicas[idx].submit_all(vec![req]);
        }
        // With an autoscaler, keep ticking the controller on its cadence
        // while the backlog drains: a burst that landed inside the first
        // interval must still trigger scale-up, and the integral must
        // charge whatever the controller provisions for the tail rather
        // than freezing at the last arrival's active set. (Autoscale is
        // aggregated-only, so the simple any-pending loop is safe.)
        // Faults past the last arrival interleave here in time order;
        // ticks cease permanently on the first no-backlog check, exactly
        // like the event calendar dropping an AutoscaleTick once the
        // arrivals are exhausted and nothing is pending.
        let mut ticking = self.cfg.autoscale.is_some();
        let mut pumping = admit_ticking;
        let mut sampling = telemetry_on;
        loop {
            // Retirement mirrors the calendar dropping a tick: the first
            // due tick that observes no backlog (fleet idle, arbiter
            // drained) ends that cadence for good.
            let idle = !self.replicas.iter().any(|r| r.pending() > 0)
                && arb.as_ref().map_or(true, |a| a.is_empty());
            let mut due: Option<(Seconds, u8)> = None;
            let mut consider = |t: Seconds, class: u8| {
                if due.map_or(true, |(dt, dc)| t < dt || (t == dt && class < dc)) {
                    due = Some((t, class));
                }
            };
            if let Some(s) = timeline.get(fi) {
                consider(s.at, 0);
            }
            if ticking {
                consider(self.next_scale, 1);
            }
            if pumping {
                consider(self.next_admit, 2);
            }
            if sampling {
                consider(self.next_telemetry, 3);
            }
            match due {
                Some((ft, 0)) => {
                    if self.replicas.iter().any(|r| r.pending() > 0) {
                        self.advance_to(ft)?;
                    }
                    let spec = timeline[fi];
                    self.apply_fault_stepping(spec, ft)?;
                    fi += 1;
                }
                Some((t, 1)) => {
                    if idle {
                        ticking = false;
                        continue;
                    }
                    self.advance_to(t)?;
                    let queued = arb.as_ref().map_or(0, |a| a.queued_tokens());
                    self.autoscale_tick(t, queued);
                    self.next_scale +=
                        self.cfg.autoscale.expect("ticking implies autoscale").interval;
                }
                Some((t, 2)) => {
                    if idle {
                        pumping = false;
                        continue;
                    }
                    self.advance_to(t)?;
                    if let Some(arb) = arb.as_mut() {
                        self.pump_stepping(arb, t);
                    }
                    self.next_admit += admit_interval;
                }
                Some((t, _)) => {
                    if idle {
                        sampling = false;
                        continue;
                    }
                    self.advance_to(t)?;
                    self.sample_stepping(t);
                    self.next_telemetry += telemetry_interval;
                }
                None => break,
            }
        }
        // Drain. Prefill/serving pool first; in disaggregated mode its
        // completion produces the final handoffs, which the decode pool
        // then drains (prefill replicas never depend on decode ones, so
        // running each pool to completion preserves event order).
        for i in 0..self.decode_base {
            self.replicas[i].run_to_completion()?;
            self.drain_completions(i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_handoffs(i);
            }
        }
        for i in self.decode_base..self.replicas.len() {
            self.replicas[i].run_to_completion()?;
            self.drain_completions(i);
        }
        // Close the provisioned-capacity integral at the fleet makespan.
        let makespan = self
            .replicas
            .iter()
            .map(|r| r.metrics.clock)
            .fold(Seconds::ZERO, Seconds::max);
        if self.cfg.autoscale.is_some() {
            self.account(makespan);
        } else {
            self.replica_seconds = self.replicas.len() as f64 * makespan.value();
        }
        Ok(self.report())
    }

    /// Stepping-core report: snapshot the `Scheduler` replicas. Takes
    /// `&mut self` only to drain recorded telemetry spans and samples
    /// (stamping each span with its replica index) before the
    /// immutable snapshot borrow.
    fn report(&mut self) -> ClusterReport {
        let spans = stamp_spans(self.replicas.iter_mut().map(|r| r.take_spans()));
        let samples = self
            .sampler
            .as_mut()
            .map(|s| std::mem::take(&mut s.samples))
            .unwrap_or_default();
        let snaps: Vec<ReplicaSnap<'_>> = self
            .replicas
            .iter()
            .map(|r| ReplicaSnap {
                metrics: &r.metrics,
                handoffs: r.handoffs.len() as u64,
                spilled: r
                    .backend()
                    .kv_pressure()
                    .map(|kv| kv.spilled_peak)
                    .unwrap_or(Bytes::ZERO),
                flash: r
                    .backend()
                    .kv_pressure()
                    .map(|kv| kv.flash_spilled_peak)
                    .unwrap_or(Bytes::ZERO),
                trace: r.trace(),
            })
            .collect();
        let gpus_per_node = self
            .replicas
            .first()
            .map(|r| r.backend().sys.num_gpus)
            .unwrap_or(0) as f64;
        self.assemble_report(&snaps, gpus_per_node, spans, samples)
    }

    /// Event-core report: snapshot the lean replicas. Field-for-field
    /// the same assembly as [`Cluster::report`] — shared below, so the
    /// two cores cannot drift in what they observe.
    fn report_event(&mut self, evs: &mut [EventReplica]) -> ClusterReport {
        let spans = stamp_spans(evs.iter_mut().map(|r| r.take_spans()));
        let samples = self
            .sampler
            .as_mut()
            .map(|s| std::mem::take(&mut s.samples))
            .unwrap_or_default();
        let snaps: Vec<ReplicaSnap<'_>> = evs
            .iter()
            .map(|r| ReplicaSnap {
                metrics: &r.metrics,
                handoffs: r.handoffs_total(),
                spilled: r
                    .backend()
                    .kv_pressure()
                    .map(|kv| kv.spilled_peak)
                    .unwrap_or(Bytes::ZERO),
                flash: r
                    .backend()
                    .kv_pressure()
                    .map(|kv| kv.flash_spilled_peak)
                    .unwrap_or(Bytes::ZERO),
                trace: r.trace(),
            })
            .collect();
        let gpus_per_node = evs
            .first()
            .map(|r| r.backend().sys.num_gpus)
            .unwrap_or(0) as f64;
        self.assemble_report(&snaps, gpus_per_node, spans, samples)
    }

    fn assemble_report(
        &self,
        snaps: &[ReplicaSnap<'_>],
        gpus_per_node: f64,
        spans: Vec<RequestSpan>,
        samples: Vec<TelemetrySample>,
    ) -> ClusterReport {
        let mut fleet = Metrics::default();
        let mut per_replica = Vec::with_capacity(snaps.len());
        let mut kv_spilled_peak = Bytes::ZERO;
        let mut flash_spilled_peak = Bytes::ZERO;
        fleet.rejected = self.rejected;
        fleet.shed = self.shed;
        fleet.fabric_wait = self.fabric_wait;
        for (i, r) in snaps.iter().enumerate() {
            fleet.merge(r.metrics);
            kv_spilled_peak = kv_spilled_peak.max(r.spilled);
            flash_spilled_peak = flash_spilled_peak.max(r.flash);
            let routed_tokens = match self.roles[i] {
                SchedMode::DecodeOnly => self
                    .decode_router
                    .as_ref()
                    .map(|dr| dr.routed()[i - self.decode_base])
                    .unwrap_or(0),
                _ => self.router.routed()[i],
            };
            per_replica.push(ReplicaReport {
                name: self.names[i].clone(),
                role: self.roles[i],
                completed: r.metrics.completed,
                handoffs: r.handoffs,
                routed_tokens,
                busy: r.metrics.busy,
                clock: r.metrics.clock,
                utilization: r.metrics.utilization(),
                paging_stall: r.metrics.paging_stall,
                kv_spilled_peak: r.spilled,
            });
        }
        // Fault accounting: counters from the injection state, recovery
        // statistics from the merged per-replica completion traces
        // (empty schedule ⇒ the all-healthy FaultReport::empty shape).
        let faults = self.cfg.faults.as_ref().map(|fs| {
            let mut fr = FaultReport::empty(fs);
            fr.crashes = self.fstate.crashes;
            fr.rejoins = self.fstate.rejoins;
            fr.module_failures = self.fstate.module_failures;
            fr.link_degrades = self.fstate.link_degrades;
            fr.requests_requeued = self.fstate.requeued;
            fr.requests_reprefilled = self.fstate.reprefilled;
            fr.tokens_lost = self.fstate.tokens_lost;
            fr.bytes_invalidated = self.fstate.bytes_invalidated;
            fr.extents_invalidated = self.fstate.extents_invalidated;
            if let Some(first) = self.fstate.timeline.first().map(|s| s.at) {
                let mut completions: Vec<CompletionEvent> =
                    snaps.iter().flat_map(|s| s.trace.iter().copied()).collect();
                completions.sort_by(|a, b| a.at.value().total_cmp(&b.at.value()));
                let rs = recovery_stats(&completions, first, fleet.clock, fs.window, fs.epsilon);
                fr.first_fault = Some(first);
                fr.baseline_attainment = rs.baseline_attainment;
                fr.dip_attainment = rs.dip_attainment;
                fr.slo_dip = rs.slo_dip;
                fr.recovery_time = rs.recovery_time;
                fr.recovered = rs.recovered;
                fr.goodput_lost_tokens = rs.goodput_lost_tokens;
            }
            fr
        });
        // Per-tenant accounting: front-door counters live in `tstats`;
        // completion-side numbers (TTFT tail, SLO attainment, goodput)
        // come from the merged per-replica traces, which both cores
        // populate in identical order.
        let tenants = self.cfg.tenants.as_ref().map(|tc| {
            tc.tenants
                .iter()
                .enumerate()
                .map(|(ti, t)| {
                    let stats = &self.tstats[ti];
                    let mut ttft = LatencyStat::default();
                    let mut completed = 0u64;
                    let mut tokens_generated = 0u64;
                    let mut slo_total = 0u64;
                    let mut slo_met = 0u64;
                    let mut goodput_tokens = 0u64;
                    for s in snaps {
                        for ev in s.trace.iter().filter(|e| e.tenant == ti) {
                            completed += 1;
                            tokens_generated += ev.tokens;
                            ttft.record(ev.ttft);
                            if let Some(ok) = ev.slo {
                                slo_total += 1;
                                if ok {
                                    slo_met += 1;
                                    goodput_tokens += ev.tokens;
                                }
                            }
                        }
                    }
                    let homed = self.tassign.iter().take(self.active).any(|&a| a == ti);
                    // Per-tenant stall attribution (DESIGN.md
                    // §Telemetry): fold the tenant's spans into its own
                    // ledger. Empty — and silent — with telemetry off.
                    let mut ledger = StallLedger::default();
                    for s in spans.iter().filter(|s| s.tenant == ti) {
                        ledger.charge(s);
                    }
                    TenantReport {
                        name: t.name.clone(),
                        model: t.model.name.clone(),
                        weight: t.weight,
                        admitted_requests: stats.admitted_requests,
                        admitted_tokens: stats.admitted_tokens,
                        enqueued_tokens: stats.enqueued_tokens,
                        shed_quota: stats.shed_quota,
                        completed,
                        tokens_generated,
                        slo_total,
                        slo_met,
                        goodput_tokens,
                        ttft,
                        swaps: stats.swaps,
                        cold_start: stats.cold_start.clone(),
                        cold_start_total: stats.cold_start_total,
                        pool_bytes_held: if homed {
                            Bytes::ZERO
                        } else {
                            memory::param_bytes(&t.model)
                        },
                        ledger,
                    }
                })
                .collect()
        });
        // Telemetry slice: the drained spans and samples, the fleet
        // ledger (already merged through the per-replica metrics), and
        // a rolling-attainment curve cut from the completion trace by
        // the fault layer's window slicer (telemetry arms trace
        // recording precisely so this reuse works).
        let telemetry = self.cfg.telemetry.as_ref().map(|tel| {
            let mut completions: Vec<CompletionEvent> =
                snaps.iter().flat_map(|s| s.trace.iter().copied()).collect();
            completions.sort_by(|a, b| a.at.value().total_cmp(&b.at.value()));
            TelemetryReport {
                interval: tel.interval,
                attainment: attainment_windows(&completions, fleet.clock, tel.interval),
                ledger: fleet.ledger,
                spans,
                samples,
            }
        });
        ClusterReport {
            model: self.model.name.clone(),
            policy: self.cfg.policy,
            kv_spilled_peak,
            flash_spilled_peak,
            tenants,
            prefix_cache: self.prefix_cache.as_ref().map(|pc| pc.report()),
            fabric: self.fabric.as_ref().map(|c| c.report()),
            faults,
            telemetry,
            fleet,
            per_replica,
            imbalance: self.router.imbalance(),
            handoffs: self.handoffs,
            handoff_time: self.handoff_time,
            elastic: self.cfg.autoscale.is_some(),
            replica_seconds: self.replica_seconds,
            gpu_seconds: self.replica_seconds * gpus_per_node,
            scale_events: self.scale_events.clone(),
        }
    }
}

/// Concatenate per-replica telemetry span drains, stamping each span
/// with its replica index — the hot paths record spans with
/// `replica: 0` because a replica-local serving loop has no notion of
/// its fleet position (DESIGN.md §Telemetry).
fn stamp_spans(per_replica: impl Iterator<Item = Vec<RequestSpan>>) -> Vec<RequestSpan> {
    let mut out = Vec::new();
    for (i, mut v) in per_replica.enumerate() {
        for s in &mut v {
            s.replica = i;
        }
        out.append(&mut v);
    }
    out
}

/// Deterministic multi-session workload: `n` requests spread over
/// `sessions` conversations. Requests of one session share a prompt
/// prefix (its "system prompt"), so [`Request::affinity_key`] groups them
/// — the workload KV-affinity routing is built for.
pub fn session_workload(
    n: usize,
    sessions: usize,
    prompt: usize,
    gen: usize,
    mean_gap: Seconds,
) -> Vec<Request> {
    let sessions = sessions.max(1);
    let mut state: u64 = 0x243F6A8885A308D3;
    let mut t = Seconds::ZERO;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let jitter = ((state >> 33) % 1000) as f64 / 1000.0;
        t += mean_gap * (2.0 * jitter);
        let session = id % sessions; // every session sees traffic
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let plen = (prompt / 2 + ((state >> 33) as usize % prompt.max(1))).max(64);
        // Prefix identifies the session; the tail varies per request.
        let mut tokens: Vec<i32> = Vec::with_capacity(plen);
        for i in 0..plen.min(super::request::AFFINITY_PREFIX) {
            tokens.push(((session * 131 + i * 7) % 509) as i32 + 1);
        }
        for i in tokens.len()..plen {
            tokens.push(((id * 31 + i) % 509) as i32 + 1);
        }
        out.push(Request {
            id: id as u64,
            prompt: tokens,
            max_new_tokens: gen,
            arrival: t,
            ..Default::default()
        });
    }
    out
}

/// `fenghuang serve --replicas N`: run a multi-session workload on an
/// FH4 rack and return the fleet summary.
#[allow(clippy::too_many_arguments)]
pub fn demo_serve_cluster(
    model: &ModelArch,
    requests: usize,
    max_batch: usize,
    replicas: usize,
    policy: Policy,
    disaggregate: Option<(usize, usize)>,
    sessions: usize,
    kv_budget: Option<Bytes>,
    prefix_cache: Option<PrefixCacheConfig>,
    contention: ContentionConfig,
    flash: Option<FlashConfig>,
    faults: Option<FaultSchedule>,
) -> Result<String> {
    let total = disaggregate.map(|(p, d)| p + d).unwrap_or(replicas);
    let cfg = ClusterConfig {
        policy,
        max_batch,
        disaggregate,
        kv_budget,
        prefix_cache,
        contention,
        flash,
        faults,
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(total, model, cfg)?;
    // Keep per-replica pressure constant as the fleet grows.
    let gap = Seconds::ms(50.0 / total.max(1) as f64);
    let report = cluster.run(session_workload(requests, sessions, 1024, 128, gap))?;
    Ok(report.summary())
}

/// `fenghuang serve --qps … --pattern … --mix …`: drive an FH4 rack with
/// the open-loop traffic engine (DESIGN.md §Traffic) and return the
/// fleet summary — SLO attainment, goodput, shed count, and (when
/// autoscaling) the provisioned replica-seconds vs the static fleet.
pub fn demo_serve_traffic(
    model: &ModelArch,
    replicas: usize,
    cfg: ClusterConfig,
    tc: &crate::traffic::TrafficConfig,
) -> Result<String> {
    demo_serve_traffic_report(model, replicas, cfg, tc).map(|(s, _)| s)
}

/// [`demo_serve_traffic`] variant that also returns the structured
/// report — `main` drives the telemetry exporters (`--trace-out` /
/// `--timeseries-out`) off the same run instead of re-simulating.
pub fn demo_serve_traffic_report(
    model: &ModelArch,
    replicas: usize,
    cfg: ClusterConfig,
    tc: &crate::traffic::TrafficConfig,
) -> Result<(String, ClusterReport)> {
    let mut cluster = Cluster::fh4(replicas, model, cfg)?;
    let reqs = crate::traffic::generate(tc)?;
    let report = cluster.run(reqs)?;
    let text = format!(
        "open-loop traffic: {} requests, mix {}, pattern {} @ {:.1} qps peak, seed {}\n{}",
        tc.requests,
        tc.mix.name(),
        tc.arrivals.pattern.name(),
        tc.arrivals.qps,
        tc.seed,
        report.summary()
    );
    Ok((text, report))
}

/// `fenghuang serve --tenants …`: multi-tenant multi-model serving over
/// one shared pool (DESIGN.md §Multi-Tenant). `cfg.tenants` must be
/// populated; each tenant drives its share of the open-loop traffic
/// with its own mix and SLO scale.
pub fn demo_serve_tenants(
    replicas: usize,
    cfg: ClusterConfig,
    tc: &crate::traffic::TrafficConfig,
) -> Result<String> {
    demo_serve_tenants_report(replicas, cfg, tc).map(|(s, _)| s)
}

/// [`demo_serve_tenants`] variant that also returns the structured
/// report, for the same exporter plumbing as
/// [`demo_serve_traffic_report`].
pub fn demo_serve_tenants_report(
    replicas: usize,
    cfg: ClusterConfig,
    tc: &crate::traffic::TrafficConfig,
) -> Result<(String, ClusterReport)> {
    let tenants = cfg
        .tenants
        .clone()
        .ok_or_else(|| FhError::Config("demo_serve_tenants requires cfg.tenants".into()))?;
    let reqs = crate::traffic::tenants::generate_tenant_workload(&tenants, tc)?;
    let base = tenants.tenants[0].model.clone();
    let mut cluster = Cluster::fh4(replicas, &base, cfg)?;
    let report = cluster.run(reqs)?;
    let text = format!(
        "multi-tenant serving: {} tenants ({}), {} requests, pattern {} @ {:.1} qps peak, seed {}\n{}",
        tenants.tenants.len(),
        tenants.arbitration.name(),
        tc.requests,
        tc.arrivals.pattern.name(),
        tc.arrivals.qps,
        tc.seed,
        report.summary()
    );
    Ok((text, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::gpt3_175b;

    fn small_workload(n: usize) -> Vec<Request> {
        session_workload(n, 4, 256, 8, Seconds::ms(5.0))
    }

    #[test]
    fn cluster_completes_every_request() {
        let mut c = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r = c.run(small_workload(12)).unwrap();
        assert_eq!(r.fleet.completed, 12);
        assert_eq!(r.fleet.ttft.count(), 12);
        assert_eq!(r.fleet.tokens_generated, 12 * 8);
        assert!(r.makespan() > Seconds::ZERO);
        assert_eq!(r.per_replica.len(), 2);
        let sum: u64 = r.per_replica.iter().map(|p| p.completed).sum();
        assert_eq!(sum, 12);
    }

    #[test]
    fn throughput_scales_with_replica_count() {
        // Same saturating workload on 1 vs 4 replicas: the fleet must
        // finish it in substantially less virtual time.
        let load = || session_workload(32, 8, 512, 16, Seconds::ms(1.0));
        let mut c1 = Cluster::fh4(1, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r1 = c1.run(load()).unwrap();
        let mut c4 = Cluster::fh4(4, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r4 = c4.run(load()).unwrap();
        assert_eq!(r1.fleet.completed, 32);
        assert_eq!(r4.fleet.completed, 32);
        assert!(
            r4.makespan().value() < 0.6 * r1.makespan().value(),
            "4 replicas: {:.3}s vs 1 replica: {:.3}s",
            r4.makespan().value(),
            r1.makespan().value()
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_on_imbalance() {
        // Heterogeneous prompts: round-robin ignores size, LOT equalises.
        let lopsided = || {
            let mut reqs = small_workload(24);
            for (i, r) in reqs.iter_mut().enumerate() {
                let len = if i % 2 == 0 { 2000 } else { 64 };
                r.prompt = vec![(i % 500) as i32 + 1; len];
            }
            reqs
        };
        let run = |policy| {
            let cfg = ClusterConfig { policy, ..Default::default() };
            let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
            c.run(lopsided()).unwrap()
        };
        let rr = run(Policy::RoundRobin);
        let lot = run(Policy::LeastLoaded);
        assert_eq!(rr.fleet.completed, 24);
        assert_eq!(lot.fleet.completed, 24);
        assert!(
            lot.imbalance <= rr.imbalance,
            "LOT imbalance {:.3} vs RR {:.3}",
            lot.imbalance,
            rr.imbalance
        );
    }

    #[test]
    fn kv_affinity_cluster_serves_sessions() {
        let cfg = ClusterConfig { policy: Policy::KvAffinity, ..Default::default() };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(20)).unwrap();
        assert_eq!(r.fleet.completed, 20);
        assert!(r.imbalance >= 1.0);
        assert!(r.summary().contains("kv-affinity"));
    }

    #[test]
    fn disaggregated_cluster_hands_off_and_completes() {
        let cfg = ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: Some((2, 2)),
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(16)).unwrap();
        assert_eq!(r.fleet.completed, 16);
        assert_eq!(r.handoffs, 16, "every request crosses the pools once");
        // TTFT measured on the prefill pool, decode latencies downstream.
        assert_eq!(r.fleet.ttft.count(), 16);
        assert!(r.fleet.tpot.count() > 0);
        // TAB fabric: handoff is metadata-only (≈350 ns each).
        assert!(
            r.handoff_time.as_ms() < 1.0,
            "TAB handoff cost {:.3} ms",
            r.handoff_time.as_ms()
        );
        let prefill_done: u64 = r
            .per_replica
            .iter()
            .filter(|p| p.role == SchedMode::PrefillOnly)
            .map(|p| p.completed)
            .sum();
        assert_eq!(prefill_done, 0, "prefill pool hands off instead of completing");
    }

    #[test]
    fn inadmissible_prompts_rejected_without_charging_router() {
        let mut c = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let mut reqs = small_workload(6);
        // Oversize two prompts beyond the model's max_seq.
        let cap = gpt3_175b().max_seq as usize;
        reqs[1].prompt = vec![1; cap + 1];
        reqs[4].prompt = vec![1; cap * 2];
        let admitted_work: u64 = reqs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != 1 && *i != 4)
            .map(|(_, r)| r.work_tokens())
            .sum();
        let r = c.run(reqs).unwrap();
        assert_eq!(r.fleet.completed, 4);
        assert_eq!(r.fleet.rejected, 2);
        // Rejected requests never touched the router's accounting.
        let routed: u64 = r.per_replica.iter().map(|p| p.routed_tokens).sum();
        assert_eq!(routed, admitted_work);
    }

    #[test]
    fn disaggregate_split_must_cover_fleet() {
        let cfg = ClusterConfig { disaggregate: Some((3, 2)), ..Default::default() };
        assert!(Cluster::fh4(4, &gpt3_175b(), cfg).is_err());
        let cfg = ClusterConfig { disaggregate: Some((0, 4)), ..Default::default() };
        assert!(Cluster::fh4(4, &gpt3_175b(), cfg).is_err());
    }

    #[test]
    fn session_workload_groups_by_prefix() {
        let reqs = session_workload(50, 5, 256, 8, Seconds::ms(1.0));
        assert_eq!(reqs.len(), 50);
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.affinity_key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 5, "one affinity key per session");
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn demo_serve_cluster_reports_fleet_percentiles() {
        let s = demo_serve_cluster(
            &gpt3_175b(),
            12,
            4,
            2,
            Policy::KvAffinity,
            None,
            4,
            None,
            None,
            ContentionConfig::default(),
            None,
            None,
        )
        .unwrap();
        assert!(s.contains("completed 12"), "{s}");
        assert!(s.contains("p99"), "{s}");
        assert!(s.contains("load imbalance"), "{s}");
        assert!(!s.contains("prefix-cache"), "cache off → silent summary\n{s}");
        // With the cache on, sessions share their affinity prefixes and
        // the summary reports reuse.
        let s = demo_serve_cluster(
            &gpt3_175b(),
            12,
            4,
            2,
            Policy::KvAffinity,
            None,
            4,
            None,
            Some(PrefixCacheConfig::default()),
            ContentionConfig::default(),
            None,
            None,
        )
        .unwrap();
        assert!(s.contains("completed 12"), "{s}");
        assert!(s.contains("prefix-cache: hit-rate"), "{s}");
    }

    #[test]
    fn prefix_cache_reuses_session_prefixes_across_replicas() {
        use crate::traffic::{ClassKind, TrafficConfig, WorkloadMix};
        let tc = TrafficConfig {
            mix: WorkloadMix::of(ClassKind::Agentic),
            requests: 40,
            seed: 11,
            max_prompt: gpt3_175b().max_seq as usize,
            slo: None,
            ..Default::default()
        };
        let reqs = || crate::traffic::generate(&tc).unwrap();
        let cached_cfg = || ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            ..Default::default()
        };
        let mut cached = Cluster::fh4(4, &gpt3_175b(), cached_cfg()).unwrap();
        let rc = cached.run(reqs()).unwrap();
        assert_eq!(rc.fleet.completed, 40);
        let pc = rc.prefix_cache.expect("cache report");
        assert!(pc.hits > 0, "agentic sessions must hit the shared prefix");
        assert!(pc.hit_rate > 0.0 && pc.hit_rate <= 1.0);
        assert!(rc.fleet.prefill_tokens_saved > 0);
        assert!(rc.prefill_compute_saving() > 0.0);
        assert!(rc.fleet.prefix_fetch > Seconds::ZERO, "hits pay the TAB fetch");
        assert!(pc.pool_bytes_held.value() > 0.0);
        assert!(pc.pool_bytes_held <= pc.capacity);
        assert!(rc.summary().contains("prefix-cache"), "{}", rc.summary());
        // The cache is shared: sessions are sticky per replica under
        // least-loaded spill, yet total hits exceed what any single
        // replica's private cache could see only if inserts from one
        // replica serve lookups routed elsewhere — asserted indirectly:
        // reuse happened while > 1 replica served traffic.
        let served = rc.per_replica.iter().filter(|r| r.completed > 0).count();
        assert!(served > 1, "traffic must actually spread over replicas");
        // No-cache run: same fleet, no savings, no report.
        let mut plain = Cluster::fh4(4, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let rp = plain.run(reqs()).unwrap();
        assert_eq!(rp.fleet.completed, 40);
        assert!(rp.prefix_cache.is_none());
        assert_eq!(rp.fleet.prefill_tokens_saved, 0);
        assert_eq!(rp.prefill_compute_saving(), 0.0);
        // Cache runs are deterministic: same seed, same savings.
        let mut again = Cluster::fh4(4, &gpt3_175b(), cached_cfg()).unwrap();
        let ra = again.run(reqs()).unwrap();
        assert_eq!(ra.fleet.prefill_tokens_saved, rc.fleet.prefill_tokens_saved);
        assert_eq!(ra.makespan(), rc.makespan());
        let pa = ra.prefix_cache.unwrap();
        assert_eq!(pa.hits, pc.hits);
        assert_eq!(pa.hit_tokens, pc.hit_tokens);
        assert_eq!(pa.evicted_tokens, pc.evicted_tokens);
    }

    #[test]
    fn prefix_cache_requires_tab_fabric() {
        let cfg = ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            ..Default::default()
        };
        let r = Cluster::new(crate::config::baseline_rack(2), &gpt3_175b(), cfg);
        assert!(r.is_err(), "shared-nothing racks have no pool to share");
    }

    #[test]
    fn front_door_sheds_overload_without_charging_router() {
        // Simultaneous burst against a tiny shed watermark: the fleet
        // admits what fits and drops the rest at the door.
        let cfg = ClusterConfig { shed_tokens: Some(600), ..Default::default() };
        let mut c = Cluster::fh4(2, &gpt3_175b(), cfg).unwrap();
        let mut reqs = small_workload(12);
        for r in &mut reqs {
            r.arrival = Seconds::ZERO;
        }
        let r = c.run(reqs).unwrap();
        assert!(r.fleet.shed > 0, "watermark must bind under a burst");
        assert_eq!(r.fleet.completed + r.fleet.shed, 12);
        assert!(r.fleet.summary().contains("shed"), "{}", r.fleet.summary());
        // Shed requests never touched the routed-token accounting.
        let routed: u64 = r.per_replica.iter().map(|p| p.routed_tokens).sum();
        assert!(routed > 0);
        // An uncapped fleet serves everything.
        let mut free = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let mut reqs = small_workload(12);
        for r in &mut reqs {
            r.arrival = Seconds::ZERO;
        }
        let rf = free.run(reqs).unwrap();
        assert_eq!(rf.fleet.completed, 12);
        assert_eq!(rf.fleet.shed, 0);
    }

    #[test]
    fn autoscaler_saves_replica_seconds_and_stays_deterministic() {
        use crate::traffic::{
            ArrivalConfig, ArrivalPattern, ClassKind, TrafficConfig, WorkloadMix,
        };
        let tc = TrafficConfig {
            arrivals: ArrivalConfig {
                pattern: ArrivalPattern::Diurnal,
                qps: 10.0,
                diurnal_period: Seconds::new(8.0),
                diurnal_floor: 0.05,
                ..Default::default()
            },
            mix: WorkloadMix::of(ClassKind::Chat),
            requests: 48,
            seed: 7,
            max_prompt: 4096,
            slo: None,
        };
        let reqs = crate::traffic::generate(&tc).unwrap();
        let mut stat = Cluster::fh4(4, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let rs = stat.run(reqs.clone()).unwrap();
        let auto_cfg = || ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 2048, ..Default::default() }),
            ..Default::default()
        };
        let mut auto1 = Cluster::fh4(4, &gpt3_175b(), auto_cfg()).unwrap();
        let ra = auto1.run(reqs).unwrap();
        assert_eq!(rs.fleet.completed, 48);
        assert_eq!(ra.fleet.completed, 48, "elastic fleet must not lose requests");
        assert!(ra.elastic && !rs.elastic);
        assert!(!ra.scale_events.is_empty(), "the controller must act on a diurnal curve");
        // Static accounting identity: N × makespan.
        assert!((rs.replica_seconds - rs.static_replica_seconds()).abs() < 1e-9);
        assert_eq!(rs.elastic_saving(), 0.0);
        // The trough pays for itself: strictly fewer provisioned
        // replica-seconds than the always-on fleet.
        assert!(
            ra.replica_seconds < rs.replica_seconds,
            "elastic {:.2} vs static {:.2}",
            ra.replica_seconds,
            rs.replica_seconds
        );
        assert!(ra.elastic_saving() > 0.0);
        assert!(ra.gpu_seconds > ra.replica_seconds, "FH4 nodes have 4 GPUs");
        assert!(ra.summary().contains("elastic:"), "{}", ra.summary());
        // Bit-for-bit reproducibility: regenerate the workload from the
        // same seed, rerun, and demand identical aggregates.
        let mut auto2 = Cluster::fh4(4, &gpt3_175b(), auto_cfg()).unwrap();
        let rb = auto2.run(crate::traffic::generate(&tc).unwrap()).unwrap();
        assert_eq!(ra.makespan(), rb.makespan());
        assert_eq!(ra.replica_seconds, rb.replica_seconds);
        assert_eq!(ra.scale_events, rb.scale_events);
    }

    #[test]
    fn autoscaler_reacts_to_a_burst_inside_the_first_interval() {
        // Every arrival lands at t=0, before the first controller tick:
        // the controller must still observe the backlog during the drain
        // (post-arrival ticks) and scale up, not freeze at min_replicas.
        // The backlog is sized to several seconds of single-replica work
        // so it cannot evaporate before the first 1 s tick.
        let reqs = session_workload(48, 8, 1024, 32, Seconds::ZERO);
        let cfg = ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 512, ..Default::default() }),
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(reqs).unwrap();
        assert_eq!(r.fleet.completed, 48);
        assert!(!r.scale_events.is_empty(), "controller must act during the drain");
        assert!(
            r.scale_events.iter().any(|&(_, n)| n > 1),
            "a multi-second backlog must trigger scale-up: {:?}",
            r.scale_events
        );
    }

    #[test]
    fn autoscale_config_is_validated() {
        let bad = ClusterConfig {
            autoscale: Some(AutoscaleConfig::default()),
            disaggregate: Some((2, 2)),
            ..Default::default()
        };
        assert!(Cluster::fh4(4, &gpt3_175b(), bad).is_err());
        let bad = ClusterConfig {
            autoscale: Some(AutoscaleConfig { min_replicas: 0, ..Default::default() }),
            ..Default::default()
        };
        assert!(Cluster::fh4(4, &gpt3_175b(), bad).is_err());
        let bad = ClusterConfig {
            autoscale: Some(AutoscaleConfig { min_replicas: 5, ..Default::default() }),
            ..Default::default()
        };
        assert!(Cluster::fh4(4, &gpt3_175b(), bad).is_err());
        let bad = ClusterConfig {
            autoscale: Some(AutoscaleConfig { target_tokens: 0, ..Default::default() }),
            ..Default::default()
        };
        assert!(Cluster::fh4(4, &gpt3_175b(), bad).is_err());
    }

    #[test]
    fn demo_serve_traffic_reports_slo_attainment() {
        use crate::traffic::{TrafficConfig, WorkloadMix};
        let tc = TrafficConfig {
            mix: WorkloadMix::parse("chat+batch").unwrap(),
            requests: 16,
            seed: 3,
            max_prompt: gpt3_175b().max_seq as usize,
            ..Default::default()
        };
        let s = demo_serve_traffic(&gpt3_175b(), 2, ClusterConfig::default(), &tc).unwrap();
        assert!(s.contains("open-loop traffic"), "{s}");
        assert!(s.contains("attainment"), "{s}");
        assert!(s.contains("goodput"), "{s}");
    }

    #[test]
    fn fabric_contention_requires_tab_and_reports_the_ledger() {
        use crate::traffic::{ClassKind, TrafficConfig, WorkloadMix};
        // Active contention on a shared-nothing rack is rejected.
        let cfg = ClusterConfig {
            contention: ContentionConfig {
                mode: ContentionMode::Shared,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(Cluster::new(crate::config::baseline_rack(2), &gpt3_175b(), cfg).is_err());
        // Agentic traffic through the shared prefix cache drives real
        // fabric bytes; the ledger must see them and report.
        let tc = TrafficConfig {
            mix: WorkloadMix::of(ClassKind::Agentic),
            requests: 32,
            seed: 11,
            max_prompt: gpt3_175b().max_seq as usize,
            slo: None,
            ..Default::default()
        };
        let contended_cfg = || ClusterConfig {
            prefix_cache: Some(PrefixCacheConfig::default()),
            contention: ContentionConfig {
                mode: ContentionMode::Shared,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), contended_cfg()).unwrap();
        let r = c.run(crate::traffic::generate(&tc).unwrap()).unwrap();
        assert_eq!(r.fleet.completed, 32);
        let fr = r.fabric.as_ref().expect("contended run must report the ledger");
        assert!(fr.transfers > 0, "prefix traffic must book transfers");
        assert!(fr.bytes.value() > 0.0);
        assert!(fr.busy_frac >= 0.0);
        assert!(r.summary().contains("fabric contention"), "{}", r.summary());
        // Deterministic: same seed, same ledger.
        let mut again = Cluster::fh4(4, &gpt3_175b(), contended_cfg()).unwrap();
        let r2 = again.run(crate::traffic::generate(&tc).unwrap()).unwrap();
        assert_eq!(r.makespan(), r2.makespan());
        let fr2 = r2.fabric.as_ref().unwrap();
        assert_eq!(fr.transfers, fr2.transfers);
        assert_eq!(fr.bytes.value(), fr2.bytes.value());
        assert_eq!(fr.queue_p99, fr2.queue_p99);
        // Contention can only slow the fleet down vs the unloaded pool,
        // and the Off default stays silent.
        let mut off = Cluster::fh4(
            4,
            &gpt3_175b(),
            ClusterConfig {
                prefix_cache: Some(PrefixCacheConfig::default()),
                ..Default::default()
            },
        )
        .unwrap();
        let ro = off.run(crate::traffic::generate(&tc).unwrap()).unwrap();
        assert!(ro.fabric.is_none());
        assert_eq!(ro.fleet.fabric_wait, Seconds::ZERO);
        assert!(!ro.summary().contains("fabric contention"));
        // The hit set is timing-independent (lookups precede routing), so
        // the congestion-priced fetch stall can only grow vs unloaded.
        assert_eq!(r.fleet.prefill_tokens_saved, ro.fleet.prefill_tokens_saved);
        assert!(
            r.fleet.prefix_fetch >= ro.fleet.prefix_fetch - Seconds::ns(1.0),
            "arbitrated fetches must not undercut the unloaded charge: {:?} vs {:?}",
            r.fleet.prefix_fetch,
            ro.fleet.prefix_fetch
        );
    }

    #[test]
    fn contended_handoffs_complete_on_disaggregated_tab_pools() {
        let cfg = ClusterConfig {
            policy: Policy::LeastLoaded,
            disaggregate: Some((2, 2)),
            contention: ContentionConfig {
                mode: ContentionMode::PerModule,
                module_interleave: false,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(16)).unwrap();
        assert_eq!(r.fleet.completed, 16);
        assert_eq!(r.handoffs, 16);
        let fr = r.fabric.as_ref().expect("ledger on");
        assert_eq!(fr.transfers, 16, "one metadata booking per handoff");
        assert_eq!(fr.modules, 8);
        assert!(fr.module_imbalance >= 1.0);
        // Metadata-only handoffs stay cheap even arbitrated.
        assert!(r.handoff_time.as_ms() < 10.0, "{} ms", r.handoff_time.as_ms());
    }

    #[test]
    fn kv_budget_degrades_gracefully_with_finite_tails() {
        // A deliberately tiny per-replica KV budget: decode steps pay
        // paging stalls, yet every request completes and the fleet tail
        // latencies stay finite — no more infinite-local-KV assumption.
        let capped = ClusterConfig { kv_budget: Some(Bytes::gb(2.0)), ..Default::default() };
        let mut c = Cluster::fh4(2, &gpt3_175b(), capped).unwrap();
        let r = c.run(small_workload(12)).unwrap();
        assert_eq!(r.fleet.completed, 12);
        assert!(r.fleet.paging_stall > Seconds::ZERO, "budget must bind");
        assert!(r.kv_spilled_peak.value() > 0.0);
        let p99 = r.fleet.ttft.percentile_ms(99.0);
        assert!(p99.is_finite() && p99 > 0.0);
        assert!(r.summary().contains("KV paging"), "{}", r.summary());
        // Same workload without pressure is strictly faster.
        let mut free = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let rf = free.run(small_workload(12)).unwrap();
        assert_eq!(rf.fleet.paging_stall, Seconds::ZERO);
        assert!(r.makespan() >= rf.makespan());
        let stalls: Seconds = r.per_replica.iter().map(|p| p.paging_stall).sum();
        assert_eq!(stalls, r.fleet.paging_stall);
    }

    #[test]
    fn rack_flash_tier_prices_kv_spill_past_the_pool() {
        use crate::config::{fh4_rack, FlashConfig};
        use crate::units::Bandwidth;
        // Shrink each replica's pool slice so KV spill punches through
        // it into flash; a slow flash tier can then only add stall
        // relative to the same spill served pool-only.
        let mk = |flash: Option<FlashConfig>| {
            let mut systems = fh4_rack(2, Bandwidth::tbps(4.8));
            for s in &mut systems {
                s.remote_capacity = Bytes::gb(3.0);
            }
            let cfg =
                ClusterConfig { kv_budget: Some(Bytes::gb(2.0)), flash, ..Default::default() };
            let mut c = Cluster::new(systems, &gpt3_175b(), cfg).unwrap();
            c.run(small_workload(12)).unwrap()
        };
        let pool_only = mk(None);
        let slow_flash = mk(Some(FlashConfig {
            capacity: Bytes::gb(2048.0),
            bandwidth: Bandwidth::tbps(0.4),
        }));
        assert_eq!(slow_flash.fleet.completed, 12);
        assert!(pool_only.kv_spilled_peak.value() > 0.0, "budget must bind");
        assert!(slow_flash.kv_spilled_peak.value() > 0.0);
        assert!(
            slow_flash.fleet.paging_stall >= pool_only.fleet.paging_stall,
            "flash {:?} vs pool {:?}",
            slow_flash.fleet.paging_stall,
            pool_only.fleet.paging_stall
        );
        // When the spill actually overflows the 3 GB pool slice, the
        // 0.4 TB/s flash leg is strictly slower than 4.8 TB/s pool.
        if slow_flash.kv_spilled_peak.as_gb() > 3.5 {
            assert!(slow_flash.fleet.paging_stall > pool_only.fleet.paging_stall);
        }
    }

    #[test]
    fn flash_spill_peak_surfaces_in_report_and_summary() {
        use crate::config::{fh4_rack, FlashConfig};
        use crate::units::Bandwidth;
        // A sliver of a pool slice forces nearly all KV spill through to
        // the flash tier, so the fleet report must surface the overflow.
        let mut systems = fh4_rack(2, Bandwidth::tbps(4.8));
        for s in &mut systems {
            s.remote_capacity = Bytes::gb(0.25);
        }
        let cfg = ClusterConfig {
            kv_budget: Some(Bytes::gb(2.0)),
            flash: Some(FlashConfig {
                capacity: Bytes::gb(2048.0),
                bandwidth: Bandwidth::tbps(1.0),
            }),
            ..Default::default()
        };
        let mut c = Cluster::new(systems, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(12)).unwrap();
        assert!(r.kv_spilled_peak.value() > 0.0, "budget must bind");
        assert!(
            r.flash_spilled_peak.value() > 0.0,
            "spill past a 0.25 GB pool slice must reach flash"
        );
        assert!(r.flash_spilled_peak.value() <= r.kv_spilled_peak.value());
        assert!(r.summary().contains("flash tier: peak spill"), "{}", r.summary());
        // Without a flash tier the observable stays zero and silent.
        let mut plain = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let rp = plain.run(small_workload(12)).unwrap();
        assert_eq!(rp.flash_spilled_peak, Bytes::ZERO);
        assert!(!rp.summary().contains("flash tier"));
    }

    fn two_tenant_cfg() -> super::super::tenancy::TenantsConfig {
        use super::super::tenancy::{TenantConfig, TenantsConfig};
        use crate::models::arch::{gpt2, gpt2_xl};
        TenantsConfig::new(vec![
            TenantConfig::new("alpha", gpt2()),
            TenantConfig::new("beta", gpt2_xl()),
        ])
    }

    #[test]
    fn tenancy_rejects_unsupported_compositions() {
        use crate::faults::{FaultKind, FaultSchedule, FaultSpec};
        let tenants = Some(two_tenant_cfg());
        let bad = ClusterConfig {
            tenants: tenants.clone(),
            disaggregate: Some((1, 1)),
            ..Default::default()
        };
        assert!(Cluster::fh4(2, &gpt3_175b(), bad).is_err());
        let bad = ClusterConfig {
            tenants: tenants.clone(),
            prefix_cache: Some(PrefixCacheConfig::default()),
            ..Default::default()
        };
        assert!(Cluster::fh4(2, &gpt3_175b(), bad).is_err());
        let mut faults = FaultSchedule::default();
        faults.events.push(FaultSpec {
            at: Seconds::ms(5.0),
            kind: FaultKind::ReplicaCrash { replica: 0, repair: Seconds::new(1.0) },
        });
        let bad = ClusterConfig { tenants, faults: Some(faults), ..Default::default() };
        assert!(Cluster::fh4(2, &gpt3_175b(), bad).is_err());
    }

    #[test]
    fn two_tenant_run_reports_per_tenant_observables() {
        use crate::traffic::{generate_tenant_workload, TrafficConfig};
        let tenants = two_tenant_cfg();
        let tc = TrafficConfig { requests: 24, seed: 11, ..Default::default() };
        let reqs = generate_tenant_workload(&tenants, &tc).unwrap();
        let cfg = ClusterConfig { tenants: Some(tenants), ..Default::default() };
        let mut c = Cluster::fh4(2, &gpt3_175b(), cfg).unwrap();
        let r = c.run(reqs).unwrap();
        let ts = r.tenants.as_ref().expect("tenants config implies tenant reports");
        assert_eq!(ts.len(), 2);
        // Both tenants were homed at boot (round-robin): no cold starts,
        // every request admitted and completed.
        for t in ts {
            assert!(t.admitted_requests > 0, "{}", t.name);
            assert_eq!(t.completed, t.admitted_requests, "{}", t.name);
            assert_eq!(t.swaps, 0, "{}", t.name);
            assert_eq!(t.pool_bytes_held, Bytes::ZERO, "{}", t.name);
            assert!(t.ttft.count() == t.completed as usize, "{}", t.name);
        }
        let completed: u64 = ts.iter().map(|t| t.completed).sum();
        assert_eq!(completed, r.fleet.completed);
        assert!(r.summary().contains("tenant alpha"), "{}", r.summary());
        assert!(r.summary().contains("tenant beta"), "{}", r.summary());
    }

    #[test]
    fn cold_tenant_swaps_models_and_pays_the_transfer() {
        use crate::traffic::{generate_tenant_workload, TrafficConfig};
        // One replica, two tenants: whoever is not resident must swap
        // the model in through the pool, and the report prices it.
        let tenants = two_tenant_cfg();
        let tc = TrafficConfig { requests: 12, seed: 5, ..Default::default() };
        let reqs = generate_tenant_workload(&tenants, &tc).unwrap();
        let cfg = ClusterConfig { tenants: Some(tenants), ..Default::default() };
        let mut c = Cluster::fh4(1, &gpt3_175b(), cfg).unwrap();
        let r = c.run(reqs).unwrap();
        let ts = r.tenants.as_ref().unwrap();
        let swaps: u64 = ts.iter().map(|t| t.swaps).sum();
        assert!(swaps >= 1, "a single replica cannot host both tenants warm");
        let cold: Seconds = ts.iter().map(|t| t.cold_start_total).sum();
        assert!(cold > Seconds::ZERO, "cold starts must cost transfer time");
        assert_eq!(r.fleet.completed, ts.iter().map(|t| t.completed).sum::<u64>());
        // Exactly one tenant still holds the replica at end of run; the
        // other's weights are parked in the pool.
        let parked = ts.iter().filter(|t| t.pool_bytes_held.value() > 0.0).count();
        assert_eq!(parked, 1);
        assert!(r.fleet.swap_stall > Seconds::ZERO, "swap stalls reach fleet metrics");
    }
}
