//! Multi-replica cluster simulator: rack-scale FengHuang serving
//! (DESIGN.md §6).
//!
//! A [`Cluster`] owns N replicas — each a [`Scheduler`] over its own
//! [`SimBackend`] node — co-simulated on a shared virtual clock. Requests
//! enter through the [`Router`] (round-robin / least-outstanding-tokens /
//! KV-affinity); the event loop processes arrivals in global time order,
//! advancing every replica's local clock to each arrival before the
//! routing decision so the router observes *current* outstanding load,
//! not admission-time guesses.
//!
//! Two topologies:
//!
//! * **Aggregated** — every replica runs the full prefill+decode loop.
//! * **Disaggregated** — replicas split into a prefill pool and a decode
//!   pool. Prefill replicas emit [`Handoff`]s; the cluster charges the
//!   KV transfer ([`FabricLatencies::kv_handoff`]) and injects the
//!   sequence into the least-loaded decode replica. On TAB fabrics the
//!   KV pages already live in shared memory, so the handoff is
//!   metadata-only — the cluster-scope payoff of the paper's memory
//!   orchestration; on shared-nothing fabrics the full KV serialises
//!   over the link.
//!
//! [`FabricLatencies::kv_handoff`]: crate::fabric::FabricLatencies::kv_handoff
//! [`Handoff`]: super::scheduler::Handoff

use super::batcher::Batcher;
use super::engine::SimBackend;
use super::metrics::Metrics;
use super::request::Request;
use super::router::{Policy, Router};
use super::scheduler::{SchedMode, Scheduler};
use crate::config::{fh4_rack, SystemConfig};
use crate::error::{FhError, Result};
use crate::models::arch::ModelArch;
use crate::models::memory;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Cluster topology and policy knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub policy: Policy,
    /// Per-replica continuous-batching width.
    pub max_batch: usize,
    /// `Some((prefill, decode))` splits the fleet into disaggregated
    /// pools of those sizes; `None` runs every replica aggregated.
    pub disaggregate: Option<(usize, usize)>,
    /// Per-replica local KV budget (`crate::paging::KvPressure`). `None`
    /// keeps the pre-paging assumption of infinite local KV capacity;
    /// `Some(b)` spills session KV beyond `b` to the remote tier and
    /// charges decode steps the paging stall (DESIGN.md §Paging).
    pub kv_budget: Option<Bytes>,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: None,
            kv_budget: None,
        }
    }
}

/// Per-replica slice of a [`ClusterReport`].
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    pub name: String,
    pub role: SchedMode,
    pub completed: u64,
    pub handoffs: u64,
    /// Cumulative tokens the router sent this replica.
    pub routed_tokens: u64,
    pub busy: Seconds,
    pub clock: Seconds,
    pub utilization: f64,
    /// KV-paging stall this replica's decode steps absorbed.
    pub paging_stall: Seconds,
    /// High-water mark of KV bytes spilled to the remote tier.
    pub kv_spilled_peak: Bytes,
}

/// Fleet-level result of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub model: String,
    pub policy: Policy,
    /// Merged metrics: latency samples from every replica, counters
    /// summed, clock = fleet makespan.
    pub fleet: Metrics,
    pub per_replica: Vec<ReplicaReport>,
    /// Max/mean of routed tokens across the serving (or prefill) pool.
    pub imbalance: f64,
    /// Disaggregated mode only: handoff count and total KV-transfer time.
    pub handoffs: u64,
    pub handoff_time: Seconds,
    /// Peak KV bytes spilled to the remote tier on any replica (the
    /// fleet stall total lives in `fleet.paging_stall`).
    pub kv_spilled_peak: Bytes,
}

impl ClusterReport {
    pub fn makespan(&self) -> Seconds {
        self.fleet.clock
    }

    /// Fleet throughput in generated tokens per virtual second.
    pub fn throughput_tokens_per_s(&self) -> f64 {
        self.fleet.throughput_tokens_per_s()
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "cluster of {} replicas (policy {}) serving {}\n{}\n",
            self.per_replica.len(),
            self.policy.name(),
            self.model,
            self.fleet.summary()
        );
        for r in &self.per_replica {
            let role = match r.role {
                SchedMode::Full => "serve",
                SchedMode::PrefillOnly => "prefill",
                SchedMode::DecodeOnly => "decode",
            };
            s.push_str(&format!(
                "  {:<14} [{role:^7}] completed {:>4} | handoffs {:>4} | routed {:>9} tok | busy {:>8.3}s | util {:>5.1}%\n",
                r.name,
                r.completed,
                r.handoffs,
                r.routed_tokens,
                r.busy.value(),
                r.utilization * 100.0
            ));
        }
        s.push_str(&format!(
            "load imbalance (max/mean routed tokens): {:.3}\n",
            self.imbalance
        ));
        if self.handoffs > 0 {
            s.push_str(&format!(
                "KV handoffs: {} totalling {:.3} ms of transfer\n",
                self.handoffs,
                self.handoff_time.as_ms()
            ));
        }
        if self.fleet.paging_stall.value() > 0.0 || self.kv_spilled_peak.value() > 0.0 {
            s.push_str(&format!(
                "KV paging: {:.3} ms of decode stall | peak spill {:.2} GB to remote tier\n",
                self.fleet.paging_stall.as_ms(),
                self.kv_spilled_peak.as_gb()
            ));
        }
        s
    }
}

/// The multi-replica cluster simulator.
pub struct Cluster {
    replicas: Vec<Scheduler<SimBackend>>,
    names: Vec<String>,
    roles: Vec<SchedMode>,
    cfg: ClusterConfig,
    model: ModelArch,
    /// Routes arrivals over the serving pool (all replicas when
    /// aggregated, the prefill pool when disaggregated).
    router: Router,
    /// Disaggregated mode: least-outstanding-tokens over the decode pool.
    decode_router: Option<Router>,
    /// First decode-pool index (== prefill pool size).
    decode_base: usize,
    /// Response / handoff high-water marks per replica (for draining).
    resp_seen: Vec<usize>,
    handoff_seen: Vec<usize>,
    handoffs: u64,
    handoff_time: Seconds,
    /// Requests refused at the cluster front door (inadmissible prompts)
    /// — never routed, so they can't leak outstanding load in the router.
    rejected: u64,
}

impl Cluster {
    /// Build a cluster from per-replica node configs (see
    /// [`fh4_rack`] / [`crate::config::baseline_rack`]). With
    /// `cfg.disaggregate = Some((p, d))`, the first `p` systems form the
    /// prefill pool and the next `d` the decode pool; `p + d` must equal
    /// `systems.len()`.
    pub fn new(systems: Vec<SystemConfig>, model: &ModelArch, cfg: ClusterConfig) -> Result<Self> {
        if systems.is_empty() {
            return Err(FhError::Config("cluster needs at least one replica".into()));
        }
        let (serving_pool, decode_base) = match cfg.disaggregate {
            Some((p, d)) => {
                if p == 0 || d == 0 || p + d != systems.len() {
                    return Err(FhError::Config(format!(
                        "disaggregate {p}:{d} does not cover {} replicas",
                        systems.len()
                    )));
                }
                (p, p)
            }
            None => (systems.len(), systems.len()),
        };
        let mut replicas = Vec::with_capacity(systems.len());
        let mut names = Vec::with_capacity(systems.len());
        let mut roles = Vec::with_capacity(systems.len());
        for (i, sys) in systems.into_iter().enumerate() {
            sys.validate()?;
            let role = match cfg.disaggregate {
                Some(_) if i < decode_base => SchedMode::PrefillOnly,
                Some(_) => SchedMode::DecodeOnly,
                None => SchedMode::Full,
            };
            names.push(sys.name.clone());
            let mut backend = SimBackend::new(sys, model.clone(), cfg.max_batch);
            if let Some(budget) = cfg.kv_budget {
                backend = backend.with_kv_budget(budget);
            }
            let batcher = Batcher::new(cfg.max_batch, 64, model.max_seq as usize);
            replicas.push(Scheduler::new(backend, batcher).with_mode(role));
            roles.push(role);
        }
        let router = Router::new(serving_pool, cfg.policy);
        let decode_router = cfg
            .disaggregate
            .map(|(_, d)| Router::new(d, Policy::LeastLoaded));
        let n = replicas.len();
        Ok(Cluster {
            replicas,
            names,
            roles,
            cfg,
            model: model.clone(),
            router,
            decode_router,
            decode_base,
            resp_seen: vec![0; n],
            handoff_seen: vec![0; n],
            handoffs: 0,
            handoff_time: Seconds::ZERO,
            rejected: 0,
        })
    }

    /// Convenience: an FH4-1.5xM rack at 4.8 TB/s remote bandwidth.
    pub fn fh4(replicas: usize, model: &ModelArch, cfg: ClusterConfig) -> Result<Self> {
        Cluster::new(fh4_rack(replicas, Bandwidth::tbps(4.8)), model, cfg)
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Release router load for responses this replica finished since the
    /// last drain. A completed response's token vector is exactly the
    /// work the router charged (prompt + generation budget).
    fn drain_completions(&mut self, idx: usize) {
        let fresh = &self.replicas[idx].responses[self.resp_seen[idx]..];
        let works: Vec<u64> = fresh.iter().map(|r| r.tokens.len() as u64).collect();
        self.resp_seen[idx] = self.replicas[idx].responses.len();
        for w in works {
            match self.roles[idx] {
                SchedMode::DecodeOnly => {
                    if let Some(dr) = self.decode_router.as_mut() {
                        dr.complete_work(idx - self.decode_base, w);
                    }
                }
                _ => self.router.complete_work(idx, w),
            }
        }
    }

    /// Move fresh handoffs from prefill replica `idx` into decode
    /// replicas, charging the KV transfer over the fabric.
    fn transfer_handoffs(&mut self, idx: usize) {
        let fresh: Vec<_> =
            self.replicas[idx].handoffs[self.handoff_seen[idx]..].to_vec();
        self.handoff_seen[idx] = self.replicas[idx].handoffs.len();
        for h in fresh {
            // Prefill work (what route_work charged) leaves the prefill
            // replica once handed off.
            self.router
                .complete_work(idx, (h.req.prompt_len() + 1) as u64);
            let ctx = h.tokens.len() as u64;
            let kv = memory::kv_cache_bytes(&self.model, 1, ctx);
            let sys = &self.replicas[idx].backend().sys;
            let cost = sys.latencies.kv_handoff(kv, sys.fabric_bw, sys.is_fenghuang());
            self.handoffs += 1;
            self.handoff_time += cost;
            let dr = self.decode_router.as_mut().expect("disaggregated");
            // Outstanding decode work: context plus remaining generation
            // budget — released as the response's final token count.
            let work = (ctx + h.req.max_new_tokens as u64).saturating_sub(1);
            let di = self.decode_base + dr.route_work(h.req.affinity_key(), work);
            let ready = h.done_at + cost;
            self.replicas[di].inject(h, ready);
        }
    }

    /// Advance every replica's local clock to global time `t`, moving
    /// handoffs and releasing completed load along the way.
    fn advance_to(&mut self, t: Seconds) -> Result<()> {
        for i in 0..self.decode_base {
            self.replicas[i].run_until(t)?;
            self.drain_completions(i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_handoffs(i);
            }
        }
        for i in self.decode_base..self.replicas.len() {
            self.replicas[i].run_until(t)?;
            self.drain_completions(i);
        }
        Ok(())
    }

    /// Serve a workload to completion and produce the fleet report.
    pub fn run(&mut self, mut reqs: Vec<Request>) -> Result<ClusterReport> {
        reqs.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
        for req in reqs {
            self.advance_to(req.arrival)?;
            // Aggregated replicas own prompt + generation; a prefill pool
            // member only owns the prompt (+1 first token) until handoff.
            let charged = match self.cfg.disaggregate {
                Some(_) => (req.prompt_len() + 1) as u64,
                None => req.work_tokens(),
            };
            let idx = self.router.route_work(req.affinity_key(), charged);
            // Admission control: a request the target replica's batcher
            // would refuse must not keep its routing charge (the load
            // would never be released and would repel least-loaded and
            // kv-affinity decisions from that replica forever).
            if !self.replicas[idx].admits(&req) {
                self.router.unroute(idx, charged);
                self.rejected += 1;
                continue;
            }
            self.replicas[idx].submit_all(vec![req]);
        }
        // Drain. Prefill/serving pool first; in disaggregated mode its
        // completion produces the final handoffs, which the decode pool
        // then drains (prefill replicas never depend on decode ones, so
        // running each pool to completion preserves event order).
        for i in 0..self.decode_base {
            self.replicas[i].run_to_completion()?;
            self.drain_completions(i);
            if self.cfg.disaggregate.is_some() {
                self.transfer_handoffs(i);
            }
        }
        for i in self.decode_base..self.replicas.len() {
            self.replicas[i].run_to_completion()?;
            self.drain_completions(i);
        }
        Ok(self.report())
    }

    fn report(&self) -> ClusterReport {
        let mut fleet = Metrics::default();
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        let mut kv_spilled_peak = Bytes::ZERO;
        fleet.rejected = self.rejected;
        for (i, r) in self.replicas.iter().enumerate() {
            fleet.merge(&r.metrics);
            let spilled = r
                .backend()
                .kv_pressure()
                .map(|kv| kv.spilled_peak)
                .unwrap_or(Bytes::ZERO);
            kv_spilled_peak = kv_spilled_peak.max(spilled);
            let routed_tokens = match self.roles[i] {
                SchedMode::DecodeOnly => self
                    .decode_router
                    .as_ref()
                    .map(|dr| dr.routed()[i - self.decode_base])
                    .unwrap_or(0),
                _ => self.router.routed()[i],
            };
            per_replica.push(ReplicaReport {
                name: self.names[i].clone(),
                role: self.roles[i],
                completed: r.metrics.completed,
                handoffs: r.handoffs.len() as u64,
                routed_tokens,
                busy: r.metrics.busy,
                clock: r.metrics.clock,
                utilization: r.metrics.utilization(),
                paging_stall: r.metrics.paging_stall,
                kv_spilled_peak: spilled,
            });
        }
        ClusterReport {
            model: self.model.name.clone(),
            policy: self.cfg.policy,
            kv_spilled_peak,
            fleet,
            per_replica,
            imbalance: self.router.imbalance(),
            handoffs: self.handoffs,
            handoff_time: self.handoff_time,
        }
    }
}

/// Deterministic multi-session workload: `n` requests spread over
/// `sessions` conversations. Requests of one session share a prompt
/// prefix (its "system prompt"), so [`Request::affinity_key`] groups them
/// — the workload KV-affinity routing is built for.
pub fn session_workload(
    n: usize,
    sessions: usize,
    prompt: usize,
    gen: usize,
    mean_gap: Seconds,
) -> Vec<Request> {
    let sessions = sessions.max(1);
    let mut state: u64 = 0x243F6A8885A308D3;
    let mut t = Seconds::ZERO;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let jitter = ((state >> 33) % 1000) as f64 / 1000.0;
        t += mean_gap * (2.0 * jitter);
        let session = id % sessions; // every session sees traffic
        state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let plen = (prompt / 2 + ((state >> 33) as usize % prompt.max(1))).max(64);
        // Prefix identifies the session; the tail varies per request.
        let mut tokens: Vec<i32> = Vec::with_capacity(plen);
        for i in 0..plen.min(super::request::AFFINITY_PREFIX) {
            tokens.push(((session * 131 + i * 7) % 509) as i32 + 1);
        }
        for i in tokens.len()..plen {
            tokens.push(((id * 31 + i) % 509) as i32 + 1);
        }
        out.push(Request { id: id as u64, prompt: tokens, max_new_tokens: gen, arrival: t });
    }
    out
}

/// `fenghuang serve --replicas N`: run a multi-session workload on an
/// FH4 rack and return the fleet summary.
pub fn demo_serve_cluster(
    model: &ModelArch,
    requests: usize,
    max_batch: usize,
    replicas: usize,
    policy: Policy,
    disaggregate: Option<(usize, usize)>,
    sessions: usize,
    kv_budget: Option<Bytes>,
) -> Result<String> {
    let total = disaggregate.map(|(p, d)| p + d).unwrap_or(replicas);
    let cfg = ClusterConfig { policy, max_batch, disaggregate, kv_budget };
    let mut cluster = Cluster::fh4(total, model, cfg)?;
    // Keep per-replica pressure constant as the fleet grows.
    let gap = Seconds::ms(50.0 / total.max(1) as f64);
    let report = cluster.run(session_workload(requests, sessions, 1024, 128, gap))?;
    Ok(report.summary())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::gpt3_175b;

    fn small_workload(n: usize) -> Vec<Request> {
        session_workload(n, 4, 256, 8, Seconds::ms(5.0))
    }

    #[test]
    fn cluster_completes_every_request() {
        let mut c = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r = c.run(small_workload(12)).unwrap();
        assert_eq!(r.fleet.completed, 12);
        assert_eq!(r.fleet.ttft.count(), 12);
        assert_eq!(r.fleet.tokens_generated, 12 * 8);
        assert!(r.makespan() > Seconds::ZERO);
        assert_eq!(r.per_replica.len(), 2);
        let sum: u64 = r.per_replica.iter().map(|p| p.completed).sum();
        assert_eq!(sum, 12);
    }

    #[test]
    fn throughput_scales_with_replica_count() {
        // Same saturating workload on 1 vs 4 replicas: the fleet must
        // finish it in substantially less virtual time.
        let load = || session_workload(32, 8, 512, 16, Seconds::ms(1.0));
        let mut c1 = Cluster::fh4(1, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r1 = c1.run(load()).unwrap();
        let mut c4 = Cluster::fh4(4, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let r4 = c4.run(load()).unwrap();
        assert_eq!(r1.fleet.completed, 32);
        assert_eq!(r4.fleet.completed, 32);
        assert!(
            r4.makespan().value() < 0.6 * r1.makespan().value(),
            "4 replicas: {:.3}s vs 1 replica: {:.3}s",
            r4.makespan().value(),
            r1.makespan().value()
        );
    }

    #[test]
    fn least_loaded_beats_round_robin_on_imbalance() {
        // Heterogeneous prompts: round-robin ignores size, LOT equalises.
        let lopsided = || {
            let mut reqs = small_workload(24);
            for (i, r) in reqs.iter_mut().enumerate() {
                let len = if i % 2 == 0 { 2000 } else { 64 };
                r.prompt = vec![(i % 500) as i32 + 1; len];
            }
            reqs
        };
        let run = |policy| {
            let cfg = ClusterConfig { policy, ..Default::default() };
            let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
            c.run(lopsided()).unwrap()
        };
        let rr = run(Policy::RoundRobin);
        let lot = run(Policy::LeastLoaded);
        assert_eq!(rr.fleet.completed, 24);
        assert_eq!(lot.fleet.completed, 24);
        assert!(
            lot.imbalance <= rr.imbalance,
            "LOT imbalance {:.3} vs RR {:.3}",
            lot.imbalance,
            rr.imbalance
        );
    }

    #[test]
    fn kv_affinity_cluster_serves_sessions() {
        let cfg = ClusterConfig { policy: Policy::KvAffinity, ..Default::default() };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(20)).unwrap();
        assert_eq!(r.fleet.completed, 20);
        assert!(r.imbalance >= 1.0);
        assert!(r.summary().contains("kv-affinity"));
    }

    #[test]
    fn disaggregated_cluster_hands_off_and_completes() {
        let cfg = ClusterConfig {
            policy: Policy::LeastLoaded,
            max_batch: 8,
            disaggregate: Some((2, 2)),
            ..Default::default()
        };
        let mut c = Cluster::fh4(4, &gpt3_175b(), cfg).unwrap();
        let r = c.run(small_workload(16)).unwrap();
        assert_eq!(r.fleet.completed, 16);
        assert_eq!(r.handoffs, 16, "every request crosses the pools once");
        // TTFT measured on the prefill pool, decode latencies downstream.
        assert_eq!(r.fleet.ttft.count(), 16);
        assert!(r.fleet.tpot.count() > 0);
        // TAB fabric: handoff is metadata-only (≈350 ns each).
        assert!(
            r.handoff_time.as_ms() < 1.0,
            "TAB handoff cost {:.3} ms",
            r.handoff_time.as_ms()
        );
        let prefill_done: u64 = r
            .per_replica
            .iter()
            .filter(|p| p.role == SchedMode::PrefillOnly)
            .map(|p| p.completed)
            .sum();
        assert_eq!(prefill_done, 0, "prefill pool hands off instead of completing");
    }

    #[test]
    fn inadmissible_prompts_rejected_without_charging_router() {
        let mut c = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let mut reqs = small_workload(6);
        // Oversize two prompts beyond the model's max_seq.
        let cap = gpt3_175b().max_seq as usize;
        reqs[1].prompt = vec![1; cap + 1];
        reqs[4].prompt = vec![1; cap * 2];
        let admitted_work: u64 = reqs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != 1 && *i != 4)
            .map(|(_, r)| r.work_tokens())
            .sum();
        let r = c.run(reqs).unwrap();
        assert_eq!(r.fleet.completed, 4);
        assert_eq!(r.fleet.rejected, 2);
        // Rejected requests never touched the router's accounting.
        let routed: u64 = r.per_replica.iter().map(|p| p.routed_tokens).sum();
        assert_eq!(routed, admitted_work);
    }

    #[test]
    fn disaggregate_split_must_cover_fleet() {
        let cfg = ClusterConfig { disaggregate: Some((3, 2)), ..Default::default() };
        assert!(Cluster::fh4(4, &gpt3_175b(), cfg).is_err());
        let cfg = ClusterConfig { disaggregate: Some((0, 4)), ..Default::default() };
        assert!(Cluster::fh4(4, &gpt3_175b(), cfg).is_err());
    }

    #[test]
    fn session_workload_groups_by_prefix() {
        let reqs = session_workload(50, 5, 256, 8, Seconds::ms(1.0));
        assert_eq!(reqs.len(), 50);
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.affinity_key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 5, "one affinity key per session");
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn demo_serve_cluster_reports_fleet_percentiles() {
        let s = demo_serve_cluster(&gpt3_175b(), 12, 4, 2, Policy::KvAffinity, None, 4, None)
            .unwrap();
        assert!(s.contains("completed 12"), "{s}");
        assert!(s.contains("p99"), "{s}");
        assert!(s.contains("load imbalance"), "{s}");
    }

    #[test]
    fn kv_budget_degrades_gracefully_with_finite_tails() {
        // A deliberately tiny per-replica KV budget: decode steps pay
        // paging stalls, yet every request completes and the fleet tail
        // latencies stay finite — no more infinite-local-KV assumption.
        let capped = ClusterConfig { kv_budget: Some(Bytes::gb(2.0)), ..Default::default() };
        let mut c = Cluster::fh4(2, &gpt3_175b(), capped).unwrap();
        let r = c.run(small_workload(12)).unwrap();
        assert_eq!(r.fleet.completed, 12);
        assert!(r.fleet.paging_stall > Seconds::ZERO, "budget must bind");
        assert!(r.kv_spilled_peak.value() > 0.0);
        let p99 = r.fleet.ttft.percentile_ms(99.0);
        assert!(p99.is_finite() && p99 > 0.0);
        assert!(r.summary().contains("KV paging"), "{}", r.summary());
        // Same workload without pressure is strictly faster.
        let mut free = Cluster::fh4(2, &gpt3_175b(), ClusterConfig::default()).unwrap();
        let rf = free.run(small_workload(12)).unwrap();
        assert_eq!(rf.fleet.paging_stall, Seconds::ZERO);
        assert!(r.makespan() >= rf.makespan());
        let stalls: Seconds = r.per_replica.iter().map(|p| p.paging_stall).sum();
        assert_eq!(stalls, r.fleet.paging_stall);
    }
}
