//! Shared prefix-KV cache in the TAB pool (DESIGN.md §Prefix-Cache).
//!
//! FengHuang's central claim is that disaggregated memory is *shared*:
//! KV state produced by one GPU is reachable by every other GPU at
//! fabric latency (§ GPU-compute offload). This module models the
//! serving-layer payoff: a cluster-wide prefix-KV cache living in the
//! TAB pool. Prompts are indexed by their affinity-prefix token chain in
//! a deterministic radix trie; each trie node owns the KV page extent of
//! one prompt token, resident in a reserved share of the remote pool.
//! On admission the cluster looks up the longest cached prefix — hit
//! tokens skip prefill compute entirely and are charged a TAB fetch
//! ([`FabricLatencies::read_latency`]) instead; the NMC gather path
//! elides even the page-in, leaving only the fixed command latency.
//! Misses insert the freshly produced prefix KV back into the trie,
//! making it visible to *every* replica, not just the sticky one.
//!
//! Accounting is backed by the paging layer: every node registers its
//! extent in a [`PageTable`] over the pool tier
//! ([`TierModel::from_system`]), and capacity pressure evicts leaf nodes
//! through the existing [`PlacementPolicy`] victim selection (LRU /
//! access-heat), so the byte ledger of the cache is exactly the page
//! table's resident ledger.
//!
//! [`FabricLatencies::read_latency`]: crate::fabric::FabricLatencies::read_latency
//! [`TierModel::from_system`]: crate::paging::TierModel::from_system

use crate::config::SystemConfig;
use crate::error::{FhError, Result};
use crate::fabric::FabricLatencies;
use crate::models::arch::ModelArch;
use crate::models::memory;
use crate::paging::{PageTable, PlacementPolicy, PolicyKind, TierModel, DEFAULT_PAGE_BYTES};
use crate::trace::TensorId;
use crate::traffic::rng::splitmix64;
use crate::units::{Bandwidth, Bytes, Seconds};
use std::collections::HashSet;

/// Synthetic tensor-id space for prefix-KV extents (disjoint from the
/// paging orchestrator's weight ids and its `1 << 40` KV stream ids —
/// this cache owns its own table, the offset just keeps debug output
/// unambiguous).
const PREFIX_KV_ID_BASE: u64 = 1 << 41;

/// Where a prefix chain's extents live among the TAB pool's physical
/// modules (DESIGN.md §Faults). Placement is invisible to healthy runs —
/// it only determines the *blast radius* of a module failure: how many
/// cached chains one dead module takes with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolPlacement {
    /// Chains round-robin across modules in insertion order — the
    /// even-spread baseline; a module failure loses ~1/modules of the
    /// chains regardless of popularity.
    Striped,
    /// A chain homes on `hash(first token) % modules` — content-addressed
    /// placement (what a consistent-hashed pool allocator does). Popular
    /// hash buckets concentrate: the hottest module carries ≥ the striped
    /// share, so its failure invalidates at least as many bytes.
    Hashed,
}

/// Knobs of the shared prefix cache ([`super::cluster::ClusterConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct PrefixCacheConfig {
    /// Fraction of the node's remote pool reserved for shared prefix KV,
    /// in (0, 1]. Ignored when `capacity` is set.
    pub pool_share: f64,
    /// Explicit capacity override (`serve --prefix-cache-gb`).
    pub capacity: Option<Bytes>,
    /// Victim selection under capacity pressure (leaf nodes only, so the
    /// trie never orphans children). [`PolicyKind::MinimalResidency`]
    /// degenerates to LRU here — a cache that drops entries after one
    /// use would never produce a hit.
    pub policy: PolicyKind,
    /// Longest indexed prefix per request, in tokens (bounds trie depth).
    pub max_tokens: usize,
    /// NMC gather: attention reads cached KV in-pool, eliding the page-in
    /// — the fetch charge collapses to the fixed TAB command latency.
    pub nmc_gather: bool,
    /// Physical TAB modules the reserved share spreads over (≥ 1). Only
    /// the fault layer observes module boundaries.
    pub modules: usize,
    /// Chain → module assignment (DESIGN.md §Faults).
    pub placement: PoolPlacement,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig {
            pool_share: 0.25,
            capacity: None,
            policy: PolicyKind::Lru,
            max_tokens: 1024,
            nmc_gather: false,
            modules: 8,
            placement: PoolPlacement::Striped,
        }
    }
}

/// Lifetime counters of the cache (conservation laws pinned by
/// `rust/tests/prefix_props.rs`).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixCacheStats {
    /// Admission-time probes.
    pub lookups: u64,
    /// Probes that matched ≥ 1 token.
    pub hits: u64,
    /// Tokens served from the pool across all hits.
    pub hit_tokens: u64,
    /// Prompt tokens probed across all lookups (hit-token denominator).
    pub probed_tokens: u64,
    /// Trie nodes (token extents) ever inserted.
    pub inserted_tokens: u64,
    /// Trie nodes evicted under capacity pressure.
    pub evicted_tokens: u64,
    /// High-water mark of pool bytes held.
    pub bytes_peak: Bytes,
}

/// Result of a longest-prefix probe.
#[derive(Debug, Clone, Copy)]
pub struct PrefixHit {
    /// Tokens whose KV is already in the pool (always < prompt length —
    /// at least the final prompt token must run through prefill to
    /// produce logits).
    pub tokens: usize,
    /// KV bytes those tokens occupy.
    pub bytes: Bytes,
    /// Replica that last produced/extended the deepest matched extent —
    /// its local pages are warm, so the router prefers it
    /// ([`super::router::Router::route_work_warm`]).
    pub replica: Option<usize>,
    /// Stall charged to the request's prefill step for fetching the
    /// cached KV out of the pool.
    pub fetch: Seconds,
    /// TAB module the matched chain is homed on — the fault layer
    /// revokes hits whose module dies before the request prefills
    /// (DESIGN.md §Faults).
    pub home: Option<usize>,
}

impl PrefixHit {
    pub const MISS: PrefixHit = PrefixHit {
        tokens: 0,
        bytes: Bytes::ZERO,
        replica: None,
        fetch: Seconds::ZERO,
        home: None,
    };
}

/// One trie node: the KV extent of one prompt token, reached through the
/// token chain from the root.
#[derive(Debug, Clone)]
struct Node {
    token: i32,
    parent: usize,
    /// (token, node index), sorted by token — deterministic traversal.
    children: Vec<(i32, usize)>,
    depth: usize,
    /// Replica that last inserted/extended through this node (warm-page
    /// probe for the router).
    last_replica: usize,
    /// TAB module the extent lives on. A whole chain shares its depth-1
    /// ancestor's home (extents of one prefix are written contiguously),
    /// so the blast radius of a module failure is chain-granular.
    home: usize,
}

/// Cluster-wide shared prefix-KV cache (one instance per
/// [`super::cluster::Cluster`]; every replica reads and writes it — the
/// TAB pool semantics).
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    /// Arena of trie nodes; slot 0 is the root sentinel. `None` = freed.
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// Live non-root nodes (== inserted − evicted).
    live: usize,
    /// Byte ledger over the pool tier: node slot → one-extent tensor.
    table: PageTable,
    policy: PlacementPolicy,
    capacity: Bytes,
    bytes_per_token: Bytes,
    lat: FabricLatencies,
    fabric_bw: Bandwidth,
    /// Monotone access counter; advanced once per node touch so victim
    /// ordering never ties (deterministic eviction).
    tick: u64,
    /// Live extents per TAB module — the fault layer's blast-radius
    /// ledger (`Σ module_extents == live`, pinned by the invariants).
    module_extents: Vec<u64>,
    /// Depth-1 chains ever created; drives striped round-robin homing.
    chains: u64,
    pub stats: PrefixCacheStats,
}

impl PrefixCache {
    /// Build the cache over `sys`'s pool tier for `model`'s KV geometry.
    pub fn new(cfg: PrefixCacheConfig, sys: &SystemConfig, model: &ModelArch) -> Result<Self> {
        if !sys.is_fenghuang() {
            return Err(FhError::Config(
                "the shared prefix cache lives in the TAB pool — shared-nothing \
                 fabrics have no pool to share KV through"
                    .into(),
            ));
        }
        if !(cfg.pool_share > 0.0 && cfg.pool_share <= 1.0) {
            return Err(FhError::Config(format!(
                "prefix-cache pool share must be in (0, 1], got {}",
                cfg.pool_share
            )));
        }
        if cfg.max_tokens == 0 {
            return Err(FhError::Config("prefix-cache max_tokens must be ≥ 1".into()));
        }
        if cfg.modules == 0 {
            return Err(FhError::Config("prefix-cache modules must be ≥ 1".into()));
        }
        let tiers = TierModel::from_system(sys);
        let pool = tiers.pool().capacity.ok_or_else(|| {
            FhError::Config("TAB node reports no remote pool capacity".into())
        })?;
        let capacity = match cfg.capacity {
            Some(c) => {
                if c.value() <= 0.0 {
                    return Err(FhError::Config("prefix-cache capacity must be > 0".into()));
                }
                c.min(pool)
            }
            None => pool * cfg.pool_share,
        };
        let bytes_per_token = memory::kv_cache_bytes(model, 1, 1);
        Ok(PrefixCache {
            cfg,
            nodes: vec![Some(Node {
                token: 0,
                parent: 0,
                children: Vec::new(),
                depth: 0,
                last_replica: 0,
                home: 0,
            })],
            free: Vec::new(),
            live: 0,
            module_extents: vec![0; cfg.modules],
            chains: 0,
            table: PageTable::new(DEFAULT_PAGE_BYTES),
            policy: PlacementPolicy { kind: cfg.policy, ..Default::default() },
            capacity,
            bytes_per_token,
            lat: sys.latencies,
            fabric_bw: sys.fabric_bw,
            tick: 0,
            stats: PrefixCacheStats::default(),
        })
    }

    /// Reserved pool capacity.
    pub fn capacity(&self) -> Bytes {
        self.capacity
    }

    /// Pool bytes currently held by cached extents.
    pub fn held_bytes(&self) -> Bytes {
        self.table.resident_bytes()
    }

    /// KV bytes of one cached token extent.
    pub fn bytes_per_token(&self) -> Bytes {
        self.bytes_per_token
    }

    /// Live cached token extents.
    pub fn entries(&self) -> usize {
        self.live
    }

    /// Whether hits gather KV in-pool (NMC) instead of staging it — the
    /// cluster's contention layer prices the two paths differently
    /// (DESIGN.md §Fabric-Contention).
    pub fn nmc_gather(&self) -> bool {
        self.cfg.nmc_gather
    }

    fn tid(slot: usize) -> TensorId {
        TensorId(PREFIX_KV_ID_BASE + slot as u64)
    }

    fn slot_of(id: TensorId) -> usize {
        (id.0 - PREFIX_KV_ID_BASE) as usize
    }

    fn node(&self, slot: usize) -> &Node {
        self.nodes[slot].as_ref().expect("live trie node")
    }

    fn child(&self, slot: usize, token: i32) -> Option<usize> {
        self.node(slot)
            .children
            .binary_search_by_key(&token, |&(t, _)| t)
            .ok()
            .map(|i| self.node(slot).children[i].1)
    }

    /// Longest-prefix probe for `prompt`. Touches the matched path (LRU /
    /// heat bookkeeping) and charges the fetch for the hit extent.
    pub fn lookup(&mut self, prompt: &[i32]) -> PrefixHit {
        self.stats.lookups += 1;
        // At least one prompt token must always prefill (logits for the
        // first generated token come from running it).
        let limit = prompt.len().saturating_sub(1).min(self.cfg.max_tokens);
        self.stats.probed_tokens += limit as u64;
        let mut cur = 0usize;
        let mut depth = 0usize;
        let mut replica = None;
        let mut home = None;
        while depth < limit {
            let Some(next) = self.child(cur, prompt[depth]) else { break };
            cur = next;
            depth += 1;
            replica = Some(self.node(cur).last_replica);
            home = Some(self.node(cur).home);
            self.tick += 1;
            self.table.touch(Self::tid(cur), self.tick);
        }
        if depth == 0 {
            return PrefixHit::MISS;
        }
        self.stats.hits += 1;
        self.stats.hit_tokens += depth as u64;
        let bytes = self.bytes_per_token * depth as f64;
        // NMC gather executes in-pool: the SMs stream KV directly from
        // the pool during attention, so only the command latency is
        // exposed. Without it the extent pages into local HBM first
        // (Eq 3.1: fixed latency + serialization).
        let fetch = if self.cfg.nmc_gather {
            self.lat.tab_read
        } else {
            self.lat.read_latency(bytes, self.fabric_bw)
        };
        PrefixHit { tokens: depth, bytes, replica, fetch, home }
    }

    /// Publish the prefix KV `replica` produced for `prompt`: extend the
    /// trie along the token chain (bounded by `max_tokens`), evicting
    /// under capacity pressure. Returns the number of token extents newly
    /// inserted. On TAB fabrics the KV pages are *produced into* the pool
    /// — publication itself is metadata-only and free.
    pub fn insert(&mut self, prompt: &[i32], replica: usize) -> usize {
        let chain = &prompt[..prompt.len().min(self.cfg.max_tokens)];
        let mut cur = 0usize;
        let mut matched = 0usize;
        for &tok in chain {
            let Some(next) = self.child(cur, tok) else { break };
            cur = next;
            matched += 1;
            self.tick += 1;
            let tick = self.tick;
            self.table.touch(Self::tid(cur), tick);
            self.nodes[cur].as_mut().expect("live trie node").last_replica = replica;
        }
        let mut inserted = 0usize;
        for &tok in &chain[matched..] {
            if !self.make_room(cur) {
                break;
            }
            let slot = match self.free.pop() {
                Some(s) => s,
                None => {
                    self.nodes.push(None);
                    self.nodes.len() - 1
                }
            };
            let depth = self.node(cur).depth + 1;
            // Chain-granular module homing: a new depth-1 node opens a
            // chain and picks its module by placement policy; deeper
            // extents inherit the chain's home.
            let home = if depth == 1 {
                let h = match self.cfg.placement {
                    PoolPlacement::Striped => (self.chains % self.cfg.modules as u64) as usize,
                    PoolPlacement::Hashed => {
                        (splitmix64(tok as u32 as u64) % self.cfg.modules as u64) as usize
                    }
                };
                self.chains += 1;
                h
            } else {
                self.node(cur).home
            };
            self.nodes[slot] = Some(Node {
                token: tok,
                parent: cur,
                children: Vec::new(),
                depth,
                last_replica: replica,
                home,
            });
            self.module_extents[home] += 1;
            let parent = self.nodes[cur].as_mut().expect("live trie node");
            let at = parent
                .children
                .binary_search_by_key(&tok, |&(t, _)| t)
                .expect_err("token was not a child");
            parent.children.insert(at, (tok, slot));
            self.tick += 1;
            self.table.register(Self::tid(slot), self.bytes_per_token);
            // Pool pages are authoritative (the TAB copy *is* the KV) —
            // staged clean, so eviction is a metadata drop.
            self.table.page_in(Self::tid(slot), self.tick, false);
            self.live += 1;
            inserted += 1;
            cur = slot;
        }
        self.stats.inserted_tokens += inserted as u64;
        self.stats.bytes_peak = self.stats.bytes_peak.max(self.table.resident_bytes());
        inserted
    }

    /// Make room for one more token extent: evict leaf extents (policy
    /// order) until it fits. `tip` is the node the insertion will extend —
    /// its root path is protected. Returns false when nothing evictable
    /// remains and the extent still does not fit.
    ///
    /// Cost note: when the cache is saturated, each pressured token pays
    /// an O(live) protect-set rebuild plus the policy's victim scan. This
    /// is deliberate: freeing one extent at a time keeps eviction at the
    /// policy's exact per-extent granularity (batching the whole incoming
    /// chain would let a long insert dip past cold leaves into hot ones),
    /// and the under-capacity fast path above stays O(1). At bench scale
    /// (≲ tens of thousands of live extents) the saturated path costs
    /// seconds per sweep cell; revisit with an incremental leaf set if a
    /// workload ever holds millions of extents under sustained pressure.
    fn make_room(&mut self, tip: usize) -> bool {
        loop {
            let over = self.held_bytes() + self.bytes_per_token - self.capacity;
            if over.value() <= 0.0 {
                return true;
            }
            // Internal nodes are structural: evicting one would orphan
            // its children, so only leaves are candidates. The insertion
            // path stays protected even where it is a leaf (`tip`).
            let mut protect: HashSet<TensorId> = HashSet::new();
            for (slot, n) in self.nodes.iter().enumerate() {
                if let Some(n) = n {
                    if slot != 0 && !n.children.is_empty() {
                        protect.insert(Self::tid(slot));
                    }
                }
            }
            let mut p = tip;
            while p != 0 {
                protect.insert(Self::tid(p));
                p = self.node(p).parent;
            }
            let victims = self.policy.victims(&self.table, over, &protect);
            if victims.is_empty() {
                return false;
            }
            for v in victims {
                self.remove_leaf(Self::slot_of(v));
            }
        }
    }

    /// Drop a leaf extent: detach from its parent and release its pool
    /// bytes (clean pages — no write-back; the pool copy was
    /// authoritative and is simply forgotten).
    fn remove_leaf(&mut self, slot: usize) {
        let node = self.nodes[slot].take().expect("live trie node");
        debug_assert!(node.children.is_empty(), "evicting an internal trie node");
        let parent = self.nodes[node.parent].as_mut().expect("live parent");
        if let Ok(i) = parent.children.binary_search_by_key(&node.token, |&(t, _)| t) {
            parent.children.remove(i);
        }
        self.table.remove(Self::tid(slot));
        self.free.push(slot);
        self.live -= 1;
        self.module_extents[node.home] -= 1;
        self.stats.evicted_tokens += 1;
    }

    /// Pool bytes homed on module `m`.
    pub fn module_bytes(&self, m: usize) -> Bytes {
        self.bytes_per_token * self.module_extents[m] as f64
    }

    /// Module holding the most live extents (lowest index on ties) — the
    /// `module@T:hot` fault target.
    pub fn hottest_module(&self) -> usize {
        let mut best = 0usize;
        for (m, &n) in self.module_extents.iter().enumerate() {
            if n > self.module_extents[best] {
                best = m;
            }
        }
        best
    }

    /// A TAB module dies: every extent homed on `m` — whole chains, by
    /// construction — is invalidated through the paging ledger and
    /// detached from the trie. Returns `(bytes, extents)` invalidated;
    /// the bytes are exactly `module_bytes(m)` before the call (pinned by
    /// `rust/tests/fault_props.rs`). Subsequent lookups miss these
    /// prefixes and re-publish them cold on whichever module the
    /// placement policy picks next.
    pub fn fail_module(&mut self, m: usize) -> (Bytes, u64) {
        let doomed = self.module_bytes(m);
        // Depth-1 chain roots homed on m, in slot order (deterministic).
        let roots: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter_map(|(slot, n)| {
                n.as_ref().filter(|n| n.depth == 1 && n.home == m).map(|_| slot)
            })
            .collect();
        let mut freed = 0u64;
        for root in roots {
            // Detach the chain from the trie root, then free its whole
            // subtree; children are unhooked wholesale, so this is the
            // one place extents die with children still attached.
            let token = self.node(root).token;
            let sentinel = self.nodes[0].as_mut().expect("root sentinel");
            if let Ok(i) = sentinel.children.binary_search_by_key(&token, |&(t, _)| t) {
                sentinel.children.remove(i);
            }
            let mut stack = vec![root];
            while let Some(slot) = stack.pop() {
                let node = self.nodes[slot].take().expect("live trie node");
                debug_assert_eq!(node.home, m, "chain homing must be uniform");
                stack.extend(node.children.iter().map(|&(_, c)| c));
                self.table.remove(Self::tid(slot));
                self.free.push(slot);
                self.live -= 1;
                freed += 1;
            }
        }
        debug_assert_eq!(freed, self.module_extents[m], "blast radius must match the ledger");
        self.module_extents[m] = 0;
        self.stats.evicted_tokens += freed;
        (doomed, freed)
    }

    /// Hit rate over lookups (0 when nothing was probed).
    pub fn hit_rate(&self) -> f64 {
        if self.stats.lookups == 0 {
            0.0
        } else {
            self.stats.hits as f64 / self.stats.lookups as f64
        }
    }

    /// Fraction of probed prompt tokens served from the pool.
    pub fn token_hit_rate(&self) -> f64 {
        if self.stats.probed_tokens == 0 {
            0.0
        } else {
            self.stats.hit_tokens as f64 / self.stats.probed_tokens as f64
        }
    }

    /// Structural + ledger invariants, checked by the property tests:
    /// parent/child consistency, sorted children, no orphans, exact byte
    /// accounting against the page-table ledger, capacity respected, and
    /// counter conservation. Returns a description of the first violation.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let mut live = 0usize;
        let mut per_module = vec![0u64; self.cfg.modules];
        for (slot, n) in self.nodes.iter().enumerate() {
            let Some(n) = n else { continue };
            if slot != 0 {
                live += 1;
                if n.home >= self.cfg.modules {
                    return Err(format!("node {slot} homed on phantom module {}", n.home));
                }
                per_module[n.home] += 1;
                let Some(parent) = self.nodes.get(n.parent).and_then(|p| p.as_ref()) else {
                    return Err(format!("node {slot} has a dead parent {}", n.parent));
                };
                if n.depth > 1 && n.home != parent.home {
                    return Err(format!(
                        "node {slot} home {} splits its chain (parent home {})",
                        n.home, parent.home
                    ));
                }
                if parent
                    .children
                    .binary_search_by_key(&n.token, |&(t, _)| t)
                    .ok()
                    .map(|i| parent.children[i].1)
                    != Some(slot)
                {
                    return Err(format!("node {slot} is orphaned from parent {}", n.parent));
                }
                if n.depth != parent.depth + 1 {
                    return Err(format!("node {slot} depth {} breaks the chain", n.depth));
                }
                let resident = self
                    .table
                    .entry(Self::tid(slot))
                    .map(|e| e.resident_bytes())
                    .unwrap_or(Bytes::ZERO);
                if (resident.value() - self.bytes_per_token.value()).abs()
                    > 1e-6 * self.bytes_per_token.value()
                {
                    return Err(format!(
                        "node {slot} holds {} B in the ledger, expected {} B",
                        resident.value(),
                        self.bytes_per_token.value()
                    ));
                }
            }
            for (i, &(t, c)) in n.children.iter().enumerate() {
                if i > 0 && n.children[i - 1].0 >= t {
                    return Err(format!("node {slot} children unsorted at {i}"));
                }
                let Some(child) = self.nodes.get(c).and_then(|p| p.as_ref()) else {
                    return Err(format!("node {slot} lists dead child {c}"));
                };
                if child.parent != slot {
                    return Err(format!("child {c} disowns parent {slot}"));
                }
            }
        }
        if live != self.live {
            return Err(format!("live counter {} vs walked {live}", self.live));
        }
        if per_module != self.module_extents {
            return Err(format!(
                "module ledger {:?} vs walked {per_module:?}",
                self.module_extents
            ));
        }
        let expect = self.bytes_per_token * live as f64;
        let held = self.held_bytes();
        if (held.value() - expect.value()).abs() > 1e-6 * expect.value().max(1.0) {
            return Err(format!(
                "ledger holds {} B but {live} extents should hold {} B",
                held.value(),
                expect.value()
            ));
        }
        if held.value() > self.capacity.value() * (1.0 + 1e-9) {
            return Err(format!(
                "held {} B exceeds capacity {} B",
                held.value(),
                self.capacity.value()
            ));
        }
        if self.stats.evicted_tokens > self.stats.inserted_tokens
            || self.stats.inserted_tokens - self.stats.evicted_tokens != live as u64
        {
            return Err(format!(
                "conservation broken: inserted {} − evicted {} ≠ live {live}",
                self.stats.inserted_tokens, self.stats.evicted_tokens
            ));
        }
        if self.stats.hits > self.stats.lookups || self.stats.hit_tokens > self.stats.probed_tokens
        {
            return Err("hit counters exceed their denominators".into());
        }
        Ok(())
    }
}

/// Aggregated cache observables for [`super::cluster::ClusterReport`].
#[derive(Debug, Clone, Copy)]
pub struct PrefixCacheReport {
    pub lookups: u64,
    pub hits: u64,
    pub hit_tokens: u64,
    pub inserted_tokens: u64,
    pub evicted_tokens: u64,
    /// Live token extents at end of run.
    pub entries: usize,
    pub pool_bytes_held: Bytes,
    pub pool_bytes_peak: Bytes,
    pub capacity: Bytes,
    pub hit_rate: f64,
    pub token_hit_rate: f64,
}

impl PrefixCache {
    pub fn report(&self) -> PrefixCacheReport {
        PrefixCacheReport {
            lookups: self.stats.lookups,
            hits: self.stats.hits,
            hit_tokens: self.stats.hit_tokens,
            inserted_tokens: self.stats.inserted_tokens,
            evicted_tokens: self.stats.evicted_tokens,
            entries: self.live,
            pool_bytes_held: self.held_bytes(),
            pool_bytes_peak: self.stats.bytes_peak,
            capacity: self.capacity,
            hit_rate: self.hit_rate(),
            token_hit_rate: self.token_hit_rate(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm};
    use crate::models::arch::gpt3_175b;
    use crate::units::Bandwidth;

    fn cache(cfg: PrefixCacheConfig) -> PrefixCache {
        PrefixCache::new(cfg, &fh4_15xm(Bandwidth::tbps(4.8)), &gpt3_175b()).unwrap()
    }

    #[test]
    fn shared_nothing_fabric_is_rejected() {
        let r = PrefixCache::new(PrefixCacheConfig::default(), &baseline8(), &gpt3_175b());
        assert!(r.is_err());
    }

    #[test]
    fn config_knobs_are_validated() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let m = gpt3_175b();
        let bad = PrefixCacheConfig { pool_share: 0.0, ..Default::default() };
        assert!(PrefixCache::new(bad, &sys, &m).is_err());
        let bad = PrefixCacheConfig { pool_share: 1.5, ..Default::default() };
        assert!(PrefixCache::new(bad, &sys, &m).is_err());
        let bad = PrefixCacheConfig { max_tokens: 0, ..Default::default() };
        assert!(PrefixCache::new(bad, &sys, &m).is_err());
        let bad = PrefixCacheConfig { capacity: Some(Bytes::ZERO), ..Default::default() };
        assert!(PrefixCache::new(bad, &sys, &m).is_err());
    }

    #[test]
    fn capacity_derives_from_the_pool_tier() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let pool = TierModel::from_system(&sys).pool().capacity.unwrap();
        let c = cache(PrefixCacheConfig { pool_share: 0.25, ..Default::default() });
        assert!((c.capacity().value() - (pool * 0.25).value()).abs() < 1e-6);
        // Explicit capacity wins, clamped to the pool.
        let c = cache(PrefixCacheConfig {
            capacity: Some(Bytes::gb(4.0)),
            ..Default::default()
        });
        assert_eq!(c.capacity(), Bytes::gb(4.0));
        let c = cache(PrefixCacheConfig {
            capacity: Some(pool * 3.0),
            ..Default::default()
        });
        assert_eq!(c.capacity(), pool);
    }

    #[test]
    fn longest_prefix_lookup_after_insert() {
        let mut c = cache(PrefixCacheConfig::default());
        let prompt: Vec<i32> = (1..=100).collect();
        assert_eq!(c.lookup(&prompt).tokens, 0, "cold cache misses");
        assert_eq!(c.insert(&prompt, 2), 100);
        // Full re-probe: every token but the mandatory last one hits.
        let hit = c.lookup(&prompt);
        assert_eq!(hit.tokens, 99);
        assert_eq!(hit.replica, Some(2));
        assert!(hit.fetch > Seconds::ZERO);
        assert_eq!(hit.bytes, c.bytes_per_token() * 99.0);
        // A diverging tail hits only the shared head.
        let mut fork = prompt.clone();
        fork[40] = 999;
        assert_eq!(c.lookup(&fork).tokens, 40);
        // Re-inserting the shared head adds only the new tail.
        assert_eq!(c.insert(&fork, 0), 60);
        assert_eq!(c.entries(), 160);
        c.check_invariants().unwrap();
    }

    #[test]
    fn lookup_never_returns_the_whole_prompt() {
        let mut c = cache(PrefixCacheConfig::default());
        let prompt = vec![5i32; 8];
        c.insert(&prompt, 0);
        assert_eq!(c.lookup(&prompt).tokens, 7, "one token always prefills");
        assert_eq!(c.lookup(&[5i32]).tokens, 0);
        assert_eq!(c.lookup(&[]).tokens, 0);
    }

    #[test]
    fn max_tokens_bounds_trie_depth() {
        let mut c = cache(PrefixCacheConfig { max_tokens: 10, ..Default::default() });
        let prompt: Vec<i32> = (1..=50).collect();
        assert_eq!(c.insert(&prompt, 0), 10);
        assert_eq!(c.lookup(&prompt).tokens, 10);
        c.check_invariants().unwrap();
    }

    #[test]
    fn nmc_gather_elides_the_page_in() {
        let mk = |nmc| {
            let mut c = cache(PrefixCacheConfig { nmc_gather: nmc, ..Default::default() });
            let prompt: Vec<i32> = (1..=200).collect();
            c.insert(&prompt, 0);
            c.lookup(&prompt).fetch
        };
        let staged = mk(false);
        let gathered = mk(true);
        assert_eq!(gathered, Seconds::ns(220.0), "NMC pays only the command latency");
        // 199 tokens × ~4.7 MB over 4.8 TB/s dwarfs 220 ns.
        assert!(staged > gathered * 100.0, "staged {staged:?} vs gathered {gathered:?}");
    }

    #[test]
    fn eviction_keeps_capacity_and_invariants() {
        // Capacity for ~20 gpt3 token extents.
        let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
        let mut c = cache(PrefixCacheConfig {
            capacity: Some(bpt * 20.0),
            ..Default::default()
        });
        for s in 0..8 {
            let prompt: Vec<i32> = (0..10).map(|i| s * 100 + i + 1).collect();
            c.insert(&prompt, (s % 3) as usize);
            c.check_invariants().unwrap();
        }
        assert!(c.held_bytes() <= c.capacity());
        assert!(c.stats.evicted_tokens > 0, "pressure must evict");
        assert_eq!(
            c.stats.inserted_tokens - c.stats.evicted_tokens,
            c.entries() as u64
        );
        // The most recently inserted chain survived whole (its path was
        // protected during its own insert).
        let last: Vec<i32> = (0..10).map(|i| 700 + i + 1).collect();
        assert_eq!(c.lookup(&last).tokens, 9);
        c.check_invariants().unwrap();
    }

    #[test]
    fn eviction_is_deterministic() {
        let run = || {
            let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
            let mut c = cache(PrefixCacheConfig {
                capacity: Some(bpt * 16.0),
                ..Default::default()
            });
            for s in 0..12 {
                let prompt: Vec<i32> = (0..6).map(|i| s * 37 + i + 1).collect();
                c.insert(&prompt, 0);
            }
            let mut survivors = Vec::new();
            for s in 0..12 {
                let prompt: Vec<i32> = (0..6).map(|i| s * 37 + i + 1).collect();
                survivors.push(c.lookup(&prompt).tokens);
            }
            survivors
        };
        assert_eq!(run(), run(), "victim selection must not depend on hash order");
    }

    #[test]
    fn striped_placement_round_robins_chains() {
        let mut c = cache(PrefixCacheConfig {
            modules: 4,
            placement: PoolPlacement::Striped,
            ..Default::default()
        });
        // 8 chains of 5 tokens with distinct first tokens → 2 chains
        // (10 extents) per module, exactly.
        for s in 0..8i32 {
            let prompt: Vec<i32> = (0..5).map(|i| s * 1000 + i + 1).collect();
            assert_eq!(c.insert(&prompt, 0), 5);
        }
        for m in 0..4 {
            assert_eq!(c.module_bytes(m), c.bytes_per_token() * 10.0);
        }
        assert_eq!(c.hottest_module(), 0, "even spread ties break to the lowest index");
        c.check_invariants().unwrap();
    }

    #[test]
    fn hashed_placement_is_content_addressed() {
        let mk = || {
            let mut c = cache(PrefixCacheConfig {
                modules: 4,
                placement: PoolPlacement::Hashed,
                ..Default::default()
            });
            for s in 0..16i32 {
                let prompt: Vec<i32> = (0..3).map(|i| s * 1000 + i + 1).collect();
                c.insert(&prompt, 0);
            }
            c
        };
        let a = mk();
        let b = mk();
        for m in 0..4 {
            assert_eq!(a.module_bytes(m), b.module_bytes(m), "hashing must be deterministic");
        }
        // A chain's hit reports the home its first token hashes to,
        // independent of insertion order.
        let mut c = mk();
        for s in 0..16i32 {
            let prompt: Vec<i32> = (0..3).map(|i| s * 1000 + i + 1).collect();
            let want = (splitmix64(prompt[0] as u32 as u64) % 4) as usize;
            assert_eq!(c.lookup(&prompt).home, Some(want));
        }
        a.check_invariants().unwrap();
    }

    #[test]
    fn fail_module_invalidates_exactly_its_ledger() {
        let mut c = cache(PrefixCacheConfig {
            modules: 3,
            placement: PoolPlacement::Striped,
            ..Default::default()
        });
        for s in 0..6i32 {
            let prompt: Vec<i32> = (0..8).map(|i| s * 1000 + i + 1).collect();
            c.insert(&prompt, 0);
        }
        let m = 1usize; // chains 1 and 4 homed here (striped)
        let doomed = c.module_bytes(m);
        let held = c.held_bytes();
        let (bytes, extents) = c.fail_module(m);
        assert_eq!(bytes, doomed);
        assert_eq!(extents, 16, "two 8-token chains die with the module");
        assert_eq!(c.module_bytes(m), Bytes::ZERO);
        assert!((c.held_bytes().value() - (held - bytes).value()).abs() < 1e-6);
        c.check_invariants().unwrap();
        for s in 0..6i32 {
            let prompt: Vec<i32> = (0..8).map(|i| s * 1000 + i + 1).collect();
            let hit = c.lookup(&prompt);
            if s as usize % 3 == m {
                assert_eq!(hit.tokens, 0, "chain {s} should have died with module {m}");
            } else {
                assert_eq!(hit.tokens, 7, "chain {s} must survive a foreign module failure");
            }
        }
        // A second failure of the same module is a no-op.
        assert_eq!(c.fail_module(m), (Bytes::ZERO, 0));
        // Re-publication lands the prefix cold on a fresh chain.
        let prompt: Vec<i32> = (0..8).map(|i| 1000 + i + 1).collect();
        assert_eq!(c.insert(&prompt, 0), 8);
        assert_eq!(c.lookup(&prompt).tokens, 7);
        c.check_invariants().unwrap();
    }

    #[test]
    fn tiny_capacity_truncates_instead_of_thrashing() {
        let bpt = memory::kv_cache_bytes(&gpt3_175b(), 1, 1);
        let mut c = cache(PrefixCacheConfig {
            capacity: Some(bpt * 3.0),
            ..Default::default()
        });
        let prompt: Vec<i32> = (1..=50).collect();
        let inserted = c.insert(&prompt, 0);
        assert_eq!(inserted, 3, "only what fits is published");
        assert_eq!(c.lookup(&prompt).tokens, 3);
        c.check_invariants().unwrap();
    }
}
