//! xPU and interconnect catalog (→ Fig 2.5, 2.7, 2.9).
//!
//! Datasheet numbers for the GPU generations the paper's trend figures
//! cover. FLOPs are *dense* (non-sparse) tensor-core rates. Where the paper
//! plots "peak advertised FLOPS" (which mixes precisions across
//! generations, e.g. FP4 for Blackwell) we carry both the FP16-dense rate
//! and the lowest-precision advertised dense rate.

use crate::units::{Bandwidth, Bytes, FlopRate};

/// One accelerator generation.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    pub year: u32,
    /// Dense FP16/BF16 tensor throughput.
    pub fp16_flops: FlopRate,
    /// Dense throughput at the lowest advertised precision (FP8/FP4).
    pub min_precision_flops: FlopRate,
    pub hbm_capacity: Bytes,
    pub hbm_bw: Bandwidth,
    /// Aggregate bidirectional inter-GPU link bandwidth per GPU.
    pub link_bw_bidir: Bandwidth,
    /// Link generation label (for reports).
    pub link_name: String,
}

impl GpuSpec {
    /// Per-direction link bandwidth (the number that bounds a ring step).
    pub fn link_bw_unidir(&self) -> Bandwidth {
        self.link_bw_bidir / 2.0
    }

    /// FLOPS per GB of HBM capacity (→ Fig 2.5).
    pub fn flops_per_gb(&self, advertised: bool) -> f64 {
        let f = if advertised { self.min_precision_flops } else { self.fp16_flops };
        f.value() / self.hbm_capacity.as_gb()
    }

    /// HBM bytes per FP16 FLOP (→ Fig 2.7).
    pub fn byte_per_flop(&self) -> f64 {
        self.hbm_bw.value() / self.fp16_flops.value()
    }

    /// FP16 FLOPS per Gbps of interconnect (→ Fig 2.9).
    pub fn flops_per_gbps(&self) -> f64 {
        self.fp16_flops.value() / (self.link_bw_bidir.value() * 8.0 / 1e9)
    }
}

fn spec(
    name: &str,
    year: u32,
    fp16_tflops: f64,
    min_prec_tflops: f64,
    cap_gb: f64,
    hbm_tbps: f64,
    link_gbps_bidir: f64,
    link_name: &str,
) -> GpuSpec {
    GpuSpec {
        name: name.into(),
        year,
        fp16_flops: FlopRate::tflops(fp16_tflops),
        min_precision_flops: FlopRate::tflops(min_prec_tflops),
        hbm_capacity: Bytes::gb(cap_gb),
        hbm_bw: Bandwidth::tbps(hbm_tbps),
        link_bw_bidir: Bandwidth::gbps(link_gbps_bidir),
        link_name: link_name.into(),
    }
}

pub fn v100() -> GpuSpec {
    spec("V100", 2017, 125.0, 125.0, 32.0, 0.9, 300.0, "NVLink2")
}
pub fn a100() -> GpuSpec {
    spec("A100", 2020, 312.0, 624.0, 80.0, 2.039, 600.0, "NVLink3")
}
pub fn h100() -> GpuSpec {
    spec("H100", 2022, 989.0, 1979.0, 80.0, 3.35, 900.0, "NVLink4")
}
pub fn h200() -> GpuSpec {
    spec("H200", 2023, 989.0, 1979.0, 141.0, 4.8, 900.0, "NVLink4")
}
pub fn b200() -> GpuSpec {
    spec("B200", 2024, 2250.0, 9000.0, 192.0, 8.0, 1800.0, "NVLink5")
}
pub fn gb200() -> GpuSpec {
    spec("GB200", 2024, 2500.0, 10000.0, 192.0, 8.0, 1800.0, "NVLink5")
}
pub fn gb300() -> GpuSpec {
    spec("GB300", 2025, 2500.0, 15000.0, 288.0, 8.0, 1800.0, "NVLink5")
}

/// The xPU generations plotted by Figs 2.5 / 2.7 / 2.9, chronological.
pub fn catalog() -> Vec<GpuSpec> {
    vec![v100(), a100(), h100(), h200(), b200(), gb200(), gb300()]
}

pub fn by_name(name: &str) -> Option<GpuSpec> {
    catalog().into_iter().find(|g| g.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn h200_datasheet_numbers() {
        // NVIDIA H200 datasheet: 141 GB HBM3e, 4.8 TB/s. (Paper Table 4.1/4.2.)
        let g = h200();
        assert_eq!(g.hbm_capacity.as_gb(), 141.0);
        assert_eq!(g.hbm_bw.as_tbps(), 4.8);
        assert_eq!(g.link_bw_bidir.as_gbps(), 900.0);
        assert_eq!(g.link_bw_unidir().as_gbps(), 450.0);
    }

    #[test]
    fn fig25_flops_per_gb_rises_steeply() {
        // §2.1.1: "FLOPs-per-GB-capacity ratio of GPUs has risen by
        // approximately 34× from the V100 to the GB200". With advertised
        // (lowest-precision) rates we land in the same decade; with
        // FP16-dense the trend is ~3×. Both directions must be upward.
        let v = v100();
        let gb = gb200();
        let adv = gb.flops_per_gb(true) / v.flops_per_gb(true);
        let fp16 = gb.flops_per_gb(false) / v.flops_per_gb(false);
        assert!(adv > 10.0, "advertised ratio {adv:.1}");
        assert!(fp16 > 2.5, "fp16 ratio {fp16:.1}");
    }

    #[test]
    fn fig27_byte_per_flop_declines() {
        let cat = catalog();
        let first = cat.first().unwrap().byte_per_flop();
        let last = cat.last().unwrap().byte_per_flop();
        assert!(last < first, "byte/FLOP must decline across generations");
    }

    #[test]
    fn fig29_flops_per_gbps_rises_about_2_5x_a100_to_gb300() {
        let r = gb300().flops_per_gbps() / a100().flops_per_gbps();
        assert!((2.0..3.5).contains(&r), "A100→GB300 FLOPs/Gbps ratio {r:.2}");
    }

    #[test]
    fn catalog_is_chronological() {
        let years: Vec<u32> = catalog().iter().map(|g| g.year).collect();
        let mut sorted = years.clone();
        sorted.sort();
        assert_eq!(years, sorted);
    }

    #[test]
    fn lookup() {
        assert!(by_name("h200").is_some());
        assert!(by_name("TPUv7").is_none());
    }
}
