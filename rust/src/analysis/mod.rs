//! Figure/table generators: text reports reproducing every artifact in
//! the paper's evaluation (see DESIGN.md §4 for the index).

pub mod csv;
pub mod trends;

pub use csv::render_csv;

use crate::config::{baseline8, fh4_15xm, fh4_20xm, fig41_bandwidth_sweep};
use crate::error::Result;
use crate::fabric::analysis::{allreduce_speedup_at, latency_floors, speedup, SpeedupConfig};
use crate::fabric::latency::{component_totals, READ_COMPONENTS, WRITE_COMPONENTS};
use crate::models::arch::{eval_models, trend_models};
use crate::models::{comm, flops, memory, mfu};
use crate::sim;
use crate::units::{Bandwidth, Bytes};
use std::fmt::Write as _;

/// Render a named artifact ("all" renders everything).
pub fn render(which: &str) -> Result<String> {
    let mut out = String::new();
    let all = which == "all";
    if all || which == "fig1" {
        out.push_str(&fig1_trends());
    }
    if all || which == "fig2-model" {
        out.push_str(&fig2_model_trends());
    }
    if all || which == "fig2-hw" {
        out.push_str(&fig2_hw_trends());
    }
    if all || which == "table31" {
        out.push_str(&table31());
    }
    if all || which == "speedup" {
        out.push_str(&speedup_report());
    }
    if all || which == "fig41" || which == "table43" {
        out.push_str(&fig41_and_table43()?);
    }
    if all || which == "chapter5" {
        out.push_str(&chapter5());
    }
    if out.is_empty() {
        return Err(crate::FhError::Config(format!(
            "unknown artifact '{which}' (try: all fig1 fig2-model fig2-hw table31 speedup fig41 table43 chapter5)"
        )));
    }
    Ok(out)
}

/// Fig 1.1 — AI users worldwide + SOTA model sizes over time.
pub fn fig1_trends() -> String {
    let mut s = String::from("== Figure 1.1: AI adoption and model-size scaling ==\n");
    s.push_str("year  users(M)   flagship model        params(B)\n");
    for (year, users, name, params) in trends::AI_TREND {
        let _ = writeln!(s, "{year}  {users:>8}   {name:<20} {params:>9.1}");
    }
    s.push('\n');
    s
}

/// Figs 2.1–2.4, 2.6, 2.8 — model-side trends.
pub fn fig2_model_trends() -> String {
    let mut s = String::new();
    s.push_str("== Figure 2.1: memory capacity requirement (batch 16, max seq) ==\n");
    s.push_str("model         params(GB)  kv@16(GB)   total(GB)\n");
    for m in trend_models() {
        let p = memory::param_bytes(&m);
        let kv = memory::kv_cache_bytes(&m, 16, m.max_seq);
        let _ = writeln!(
            s,
            "{:<12} {:>10.1} {:>10.1} {:>11.1}",
            m.name,
            p.as_gb(),
            kv.as_gb(),
            (p + kv).as_gb()
        );
    }

    s.push_str("\n== Figure 2.2: MFU vs batch size (decode GEMM) ==\nbatch  mfu\n");
    for (b, v) in mfu::fig22_mfu_vs_batch(12288) {
        let _ = writeln!(s, "{b:>5}  {v:.3}");
    }

    s.push_str("\n== Figure 2.3: FLOPs per generated token (1K KV) ==\n");
    for m in trend_models() {
        let f = flops::decode_flops_per_token(&m, 1024);
        let _ = writeln!(s, "{:<12} {:>10.1} GFLOP/token", m.name, f.as_gflop());
    }

    s.push_str("\n== Figure 2.4: model compute / memory-footprint ratio ==\n");
    for m in trend_models() {
        let r = flops::compute_per_memory_ratio(&m, 1024);
        let _ = writeln!(s, "{:<12} {:>8.2} FLOP per byte of weights", m.name, r);
    }

    s.push_str("\n== Figure 2.6: Byte-per-FLOP, prefill vs decode (vs GB200 HW line) ==\n");
    s.push_str("model         prefill      decode      decode/prefill\n");
    for m in trend_models() {
        let p = flops::prefill_byte_per_flop(&m, 4096);
        let d = flops::decode_byte_per_flop(&m, 1, 4096);
        let _ = writeln!(s, "{:<12} {p:>10.2e} {d:>11.2e} {:>10.0}×", m.name, d / p);
    }
    let gb200 = crate::hardware::gb200();
    let _ = writeln!(
        s,
        "GB200 hardware byte/FLOP: {:.2e}",
        gb200.hbm_bw.value() / gb200.fp16_flops.value()
    );

    s.push_str("\n== Figure 2.8: model FLOPs per communication byte (TP) ==\n");
    for m in trend_models() {
        let f = comm::flops_per_comm_byte(&m, 1024);
        let _ = writeln!(
            s,
            "{:<12} hidden {:>6}  {:>8.0} FLOP/byte",
            m.name, m.hidden, f
        );
    }
    s.push('\n');
    s
}

/// Figs 2.5, 2.7, 2.9 — hardware-side trends.
pub fn fig2_hw_trends() -> String {
    let mut s = String::new();
    s.push_str("== Figure 2.5: FLOPS per GB of HBM capacity ==\n");
    s.push_str("gpu     year  fp16(TF/GB)  advertised(TF/GB)\n");
    for g in crate::hardware::catalog() {
        let _ = writeln!(
            s,
            "{:<7} {}  {:>10.2} {:>15.2}",
            g.name,
            g.year,
            g.flops_per_gb(false) / 1e12,
            g.flops_per_gb(true) / 1e12
        );
    }
    let v = crate::hardware::v100();
    let gb = crate::hardware::gb200();
    let _ = writeln!(
        s,
        "V100→GB200 ratio: fp16 {:.1}×, advertised {:.1}× (paper: ≈34×)",
        gb.flops_per_gb(false) / v.flops_per_gb(false),
        gb.flops_per_gb(true) / v.flops_per_gb(true)
    );

    s.push_str("\n== Figure 2.7: HBM bytes per FP16 FLOP ==\n");
    for g in crate::hardware::catalog() {
        let _ = writeln!(s, "{:<7} {:>9.2e} B/FLOP", g.name, g.byte_per_flop());
    }

    s.push_str("\n== Figure 2.9: FP16 FLOPS per Gbps of interconnect ==\n");
    for g in crate::hardware::catalog() {
        let _ = writeln!(s, "{:<7} {:>10.1} GFLOP/s per Gbps", g.name, g.flops_per_gbps() / 1e9);
    }
    let a = crate::hardware::a100();
    let g3 = crate::hardware::gb300();
    let _ = writeln!(
        s,
        "A100→GB300 ratio: {:.2}× (paper: ≈2.5×)\n",
        g3.flops_per_gbps() / a.flops_per_gbps()
    );
    s
}

/// Table 3.1 — operation latency breakdown.
pub fn table31() -> String {
    let mut s = String::from("== Table 3.1: minimal operation latency (2 KB) ==\n");
    for c in READ_COMPONENTS {
        let _ = writeln!(s, "read   {:<55} {:>5.0} ns", c.label, c.ns);
    }
    for c in WRITE_COMPONENTS {
        let _ = writeln!(s, "write  {:<55} {:>5.0} ns", c.label, c.ns);
    }
    let (r, w) = component_totals();
    let _ = writeln!(s, "total read {:.0} ns | total write {:.0} ns | notification 40 ns\n", r.as_ns(), w.as_ns());
    s
}

/// §3.3.3 — speed-up decomposition + payload sweep.
pub fn speedup_report() -> String {
    let cfg = SpeedupConfig::default();
    let r = speedup(&cfg);
    let (ring_floor, tab_floor) = latency_floors(&cfg);
    let mut s = String::from("== §3.3.3: FengHuang vs NVLink AllReduce speed-up (N=8) ==\n");
    let _ = writeln!(s, "Enabler 1 (data movement): latency-bound {:.0}×, bandwidth-bound {:.2}×", r.enabler1_latency, r.enabler1_bandwidth);
    let _ = writeln!(
        s,
        "Enabler 2 (link): read {:.2}× / write {:.2}× latency, {:.2}× bandwidth",
        r.enabler2_latency_read, r.enabler2_latency_write, r.enabler2_bandwidth
    );
    let _ = writeln!(
        s,
        "Overall: latency-bound {:.0}× (paper: 70×), bandwidth-bound {:.2}× (paper: 15.56×)",
        r.overall_latency_bound, r.overall_bandwidth_bound
    );
    let _ = writeln!(
        s,
        "latency floors: ring {:.0} ns vs TAB {:.0} ns",
        ring_floor.as_ns(),
        tab_floor.as_ns()
    );
    s.push_str("payload sweep (simulated AllReduce):\n  size        speedup\n");
    for kib in [2.0, 16.0, 128.0, 1024.0, 8192.0, 65536.0, 524288.0, 4194304.0] {
        let sp = allreduce_speedup_at(Bytes::kib(kib), &cfg);
        let _ = writeln!(s, "  {:>8.0} KiB {sp:>7.1}×", kib);
    }
    s.push('\n');
    s
}

/// Fig 4.1 + Table 4.3 — workload performance and local-memory needs.
pub fn fig41_and_table43() -> Result<String> {
    let mut s = String::from(
        "== Figure 4.1: TTFT / TPOT / E2E — Baseline8 vs FH4 sweeps ==\n\
         (Q&A: prompt 4096 gen 1024; reasoning `Qwen3-R`: prompt 512 gen 16384; batch 8)\n",
    );
    let mut table43: Vec<(String, f64)> = Vec::new();
    for m in eval_models() {
        let base = sim::run_workload(&baseline8(), &m, 8, 4096, 1024)?;
        let _ = writeln!(
            s,
            "{:<8} {:<11} TTFT {:>8.1} ms | TPOT {:>7.2} ms | E2E {:>7.2} s",
            m.name,
            "Baseline8",
            base.ttft.as_ms(),
            base.tpot.as_ms(),
            base.e2e.value()
        );
        for sysf in [fh4_15xm as fn(Bandwidth) -> _, fh4_20xm as fn(Bandwidth) -> _] {
            for bw in fig41_bandwidth_sweep() {
                let r = sim::run_workload(&sysf(bw), &m, 8, 4096, 1024)?;
                let _ = writeln!(
                    s,
                    "{:<8} {:<11} TTFT {:>8.1} ms | TPOT {:>7.2} ms | E2E {:>7.2} s  @ {:.1} TB/s (vs base: TTFT {:+.1}%, TPOT {:+.1}%)",
                    m.name,
                    r.system,
                    r.ttft.as_ms(),
                    r.tpot.as_ms(),
                    r.e2e.value(),
                    bw.as_tbps(),
                    (r.ttft / base.ttft - 1.0) * 100.0,
                    (r.tpot / base.tpot - 1.0) * 100.0,
                );
                if (bw.as_tbps() - 4.8).abs() < 1e-9 && r.system.contains("1.5x") {
                    table43.push((m.name.clone(), r.peak_local.as_gb()));
                }
            }
        }
    }
    // Qwen3-R reasoning task.
    let qwen = crate::models::arch::qwen3_235b();
    let base = sim::run_workload(&baseline8(), &qwen, 8, 512, 16384)?;
    let _ = writeln!(
        s,
        "{:<8} {:<11} TTFT {:>8.1} ms | TPOT {:>7.2} ms | E2E {:>7.2} s",
        "Qwen3-R", "Baseline8", base.ttft.as_ms(), base.tpot.as_ms(), base.e2e.value()
    );
    for bw in fig41_bandwidth_sweep() {
        let r = sim::run_workload(&fh4_15xm(bw), &qwen, 8, 512, 16384)?;
        let _ = writeln!(
            s,
            "{:<8} {:<11} TTFT {:>8.1} ms | TPOT {:>7.2} ms | E2E {:>7.2} s  @ {:.1} TB/s (vs base E2E {:+.1}%)",
            "Qwen3-R",
            r.system,
            r.ttft.as_ms(),
            r.tpot.as_ms(),
            r.e2e.value(),
            bw.as_tbps(),
            (r.e2e / base.e2e - 1.0) * 100.0,
        );
        if (bw.as_tbps() - 4.8).abs() < 1e-9 {
            table43.push(("Qwen3-R".into(), r.peak_local.as_gb()));
        }
    }

    s.push_str("\n== Table 4.3: FH local-memory capacity requirement (per GPU) ==\n");
    s.push_str("model     ours(GB)  paper(GB)  vs 144 GB HBM\n");
    let paper = [("GPT-3", 10.0), ("Grok-1", 18.0), ("Qwen3", 20.0), ("Qwen3-R", 20.0)];
    for ((name, gb), (_, pgb)) in table43.iter().zip(paper) {
        let _ = writeln!(
            s,
            "{:<9} {:>7.2} {:>9.1} {:>10.1}% reduction",
            name,
            gb,
            pgb,
            (1.0 - gb / 144.0) * 100.0
        );
    }
    s.push('\n');
    Ok(s)
}

/// Chapter 5 — bandwidth-per-capacity roadmap arithmetic.
pub fn chapter5() -> String {
    let mut s = String::from("== Chapter 5: bandwidth-to-capacity ratios ==\n");
    // Classical 2029-30 projection: 500 GB @ 50 TB/s → 100 TB/s per TB.
    let classical = 50.0 / 0.5;
    // FengHuang two-tier: 20 GB local @ 10 TB/s → 500 TB/s per TB.
    let fh = 10.0 / 0.02;
    let _ = writeln!(s, "classical roadmap: {classical:.0} TB/s per TB");
    let _ = writeln!(s, "FengHuang local tier: {fh:.0} TB/s per TB ({:.0}× — paper: 5×)", fh / classical);
    let _ = writeln!(
        s,
        "TAB remote tier: up to 4096 TB capacity, 11.5–23 TB/s per-GPU links (4–8× roadmap)\n"
    );
    s
}
