//! CSV emitters for every figure — the machine-readable counterpart of
//! the text reports (downstream users plot these directly:
//! `fenghuang figures-csv <artifact> > fig.csv`).

use crate::config::{baseline8, fh4_15xm, fh4_20xm, fig41_bandwidth_sweep};
use crate::error::Result;
use crate::models::arch::{eval_models, trend_models};
use crate::models::{comm, flops, memory};
use crate::sim;
use crate::units::Bandwidth;
use std::fmt::Write as _;

/// Render a named artifact as CSV.
pub fn render_csv(which: &str) -> Result<String> {
    match which {
        "fig1" => Ok(fig1()),
        "fig2-model" => Ok(fig2_model()),
        "fig2-hw" => Ok(fig2_hw()),
        "fig41" => fig41(),
        "speedup" => Ok(speedup()),
        other => Err(crate::FhError::Config(format!(
            "unknown csv artifact '{other}' (fig1 fig2-model fig2-hw fig41 speedup)"
        ))),
    }
}

/// Assemble a CSV table from a header and pre-rendered rows — the shared
/// sink for data-carrying exports that cannot be a named artifact above
/// (e.g. the telemetry time-series of a live run,
/// `telemetry::export::timeseries_csv`).
pub fn table(header: &str, rows: &[String]) -> String {
    let mut s = String::with_capacity(header.len() + 1 + rows.iter().map(|r| r.len() + 1).sum::<usize>());
    let _ = writeln!(s, "{header}");
    for row in rows {
        let _ = writeln!(s, "{row}");
    }
    s
}

fn fig1() -> String {
    let rows: Vec<String> = super::trends::AI_TREND
        .iter()
        .map(|(year, users, name, params)| format!("{year},{users},{name},{params}"))
        .collect();
    table("year,users_millions,model,params_b", &rows)
}

fn fig2_model() -> String {
    let mut s = String::from(
        "model,year,hidden,params_gb,kv16_gb,decode_gflop_per_tok,flop_per_weight_byte,\
         prefill_byte_per_flop,decode_byte_per_flop,flops_per_comm_byte\n",
    );
    for m in trend_models() {
        let _ = writeln!(
            s,
            "{},{},{},{:.2},{:.2},{:.2},{:.4},{:.4e},{:.4e},{:.1}",
            m.name,
            m.year,
            m.hidden,
            memory::param_bytes(&m).as_gb(),
            memory::kv_cache_bytes(&m, 16, m.max_seq).as_gb(),
            flops::decode_flops_per_token(&m, 1024).as_gflop(),
            flops::compute_per_memory_ratio(&m, 1024),
            flops::prefill_byte_per_flop(&m, 4096),
            flops::decode_byte_per_flop(&m, 1, 4096),
            comm::flops_per_comm_byte(&m, 1024),
        );
    }
    s
}

fn fig2_hw() -> String {
    let mut s = String::from(
        "gpu,year,fp16_tflops,hbm_gb,hbm_tbps,link_gbps,flops_per_gb,byte_per_flop,flops_per_gbps\n",
    );
    for g in crate::hardware::catalog() {
        let _ = writeln!(
            s,
            "{},{},{:.0},{:.0},{:.2},{:.0},{:.3e},{:.3e},{:.3e}",
            g.name,
            g.year,
            g.fp16_flops.as_tflops(),
            g.hbm_capacity.as_gb(),
            g.hbm_bw.as_tbps(),
            g.link_bw_bidir.as_gbps(),
            g.flops_per_gb(false),
            g.byte_per_flop(),
            g.flops_per_gbps(),
        );
    }
    s
}

fn fig41() -> Result<String> {
    let mut s = String::from(
        "model,task,system,remote_tbps,ttft_ms,tpot_ms,e2e_s,peak_local_gb\n",
    );
    let mut emit = |m: &crate::models::ModelArch,
                    task: &str,
                    prompt: u64,
                    gen: u64|
     -> Result<()> {
        let base = sim::run_workload(&baseline8(), m, 8, prompt, gen)?;
        let _ = writeln!(
            s,
            "{},{task},Baseline8,,{:.2},{:.3},{:.3},{:.2}",
            m.name,
            base.ttft.as_ms(),
            base.tpot.as_ms(),
            base.e2e.value(),
            base.peak_local.as_gb()
        );
        for sysf in [fh4_15xm as fn(Bandwidth) -> _, fh4_20xm as fn(Bandwidth) -> _] {
            for bw in fig41_bandwidth_sweep() {
                let r = sim::run_workload(&sysf(bw), m, 8, prompt, gen)?;
                let _ = writeln!(
                    s,
                    "{},{task},{},{},{:.2},{:.3},{:.3},{:.2}",
                    m.name,
                    r.system,
                    bw.as_tbps(),
                    r.ttft.as_ms(),
                    r.tpot.as_ms(),
                    r.e2e.value(),
                    r.peak_local.as_gb()
                );
            }
        }
        Ok(())
    };
    for m in eval_models() {
        emit(&m, "qa", 4096, 1024)?;
    }
    emit(&crate::models::arch::qwen3_235b(), "reasoning", 512, 16384)?;
    Ok(s)
}

fn speedup() -> String {
    use crate::fabric::analysis::{allreduce_speedup_at, SpeedupConfig};
    use crate::units::Bytes;
    let cfg = SpeedupConfig::default();
    let mut s = String::from("payload_kib,allreduce_speedup\n");
    let mut kib = 2.0f64;
    while kib <= 4.0 * 1024.0 * 1024.0 {
        let _ = writeln!(s, "{kib},{:.3}", allreduce_speedup_at(Bytes::kib(kib), &cfg));
        kib *= 4.0;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_artifacts_emit_valid_csv() {
        for which in ["fig1", "fig2-model", "fig2-hw", "speedup"] {
            let csv = render_csv(which).unwrap();
            let mut lines = csv.lines();
            let header = lines.next().unwrap();
            let cols = header.split(',').count();
            assert!(cols >= 2, "{which}: header {header}");
            let mut rows = 0;
            for line in lines {
                assert_eq!(line.split(',').count(), cols, "{which}: ragged row {line}");
                rows += 1;
            }
            assert!(rows >= 5, "{which}: only {rows} rows");
        }
    }

    #[test]
    fn fig41_csv_covers_full_grid() {
        let csv = render_csv("fig41").unwrap();
        // 4 workloads × (1 baseline + 2 systems × 4 bandwidths) = 36 rows.
        assert_eq!(csv.lines().count() - 1, 36);
        assert!(csv.contains("Qwen3,reasoning"));
        assert!(csv.contains("FH4-2.0xM,6.4"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        assert!(render_csv("fig99").is_err());
    }

    #[test]
    fn table_helper_emits_header_plus_rows() {
        let t = table("a,b", &["1,2".to_string(), "3,4".to_string()]);
        assert_eq!(t, "a,b\n1,2\n3,4\n");
        assert_eq!(table("a,b", &[]), "a,b\n");
    }
}
