//! Fig 1.1 data series — AI users worldwide and flagship model sizes,
//! as cited by the paper ([1, 23] for users; [8, 7, 5, 9, 6] for models).

/// (year, AI tool users in millions, flagship model, parameters in B).
pub const AI_TREND: [(u32, u32, &str, f64); 6] = [
    (2019, 60, "GPT-2-XL", 1.5),
    (2020, 116, "GPT-3", 175.0),
    (2021, 148, "MT-NLG 530B", 530.0),
    (2022, 200, "PaLM / GLaM", 1200.0),
    (2023, 255, "GPT-4 (est.)", 1760.0),
    (2024, 314, "DeepSeek-V3 / Grok", 671.0),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn users_grow_threefold_2020_to_2024() {
        // §1: "116 million people in 2020 to 314 million people in 2024,
        // an almost threefold increase".
        let u2020 = AI_TREND.iter().find(|t| t.0 == 2020).unwrap().1;
        let u2024 = AI_TREND.iter().find(|t| t.0 == 2024).unwrap().1;
        assert_eq!(u2020, 116);
        assert_eq!(u2024, 314);
        let ratio = u2024 as f64 / u2020 as f64;
        assert!(ratio > 2.5 && ratio < 3.0);
    }

    #[test]
    fn gpt3_to_gpt4_is_about_10x() {
        // §1: 175B (2020) → ~1.8T (2023).
        let gpt3 = AI_TREND.iter().find(|t| t.0 == 2020).unwrap().3;
        let gpt4 = AI_TREND.iter().find(|t| t.0 == 2023).unwrap().3;
        assert!(gpt4 / gpt3 > 9.0);
    }

    #[test]
    fn years_monotone() {
        for w in AI_TREND.windows(2) {
            assert!(w[1].0 > w[0].0);
        }
    }
}
