//! Active tensor paging: the multi-tier memory orchestration subsystem
//! (DESIGN.md §Paging; → Table 4.3, EXPERIMENTS.md §Capacity-Sweep).
//!
//! Where `sim::prefetcher` models a *stateless* whole-tensor prefetch
//! policy, this layer is a real, stateful orchestrator:
//!
//! * [`page`] — page table: tensor ranges → fixed-size pages with
//!   per-page residency, dirty bits, and access heat;
//! * [`tiers`] — the GPU-local HBM → FengHuang remote pool hierarchy,
//!   with capacities/bandwidths drawn from `config`/`hardware`, plus the
//!   per-replica KV capacity-pressure model the cluster layer charges;
//! * [`policy`] — pluggable placement/eviction (minimal-residency
//!   default, LRU, access-heat) with weight pinning and a generalized
//!   lookahead prefetch window;
//! * [`migrate`] — batched page moves charged via the Table 3.1 fabric
//!   latencies and Eq 4.1 link efficiency;
//! * [`nmc`] — near-memory compute offload: write-accumulate reductions
//!   and embedding/KV gathers execute in-pool and skip page-in entirely.
//!
//! [`orchestrate`] walks an operator trace for a configurable number of
//! steps, maintains residency state across steps, derives each op's fetch
//! time from the *page-table state* (only missing pages move), and feeds
//! the result to the two-stream engine ([`crate::sim::engine::schedule`])
//! — so cache hits on later decode steps shrink the paging stream, and
//! small local budgets surface as exposed stalls instead of being assumed
//! away.

pub mod migrate;
pub mod nmc;
pub mod page;
pub mod policy;
pub mod tiers;

pub use migrate::{MigrationConfig, MigrationEngine, MigrationStats};
pub use nmc::{NmcConfig, NmcKind};
pub use page::{PageTable, DEFAULT_PAGE_BYTES};
pub use policy::{PlacementPolicy, PolicyKind};
pub use tiers::{KvPressure, Tier, TierModel, TierSpec};

use crate::config::{FabricKind, SystemConfig};
use crate::error::{FhError, Result};
use crate::fabric::contention::{ContentionConfig, ContentionMode, FabricClock, FabricReport};
use crate::models::arch::ModelArch;
use crate::sim::engine;
use crate::sim::exec::{op_time, op_time_kv_staged};
use crate::sim::memory::OccupancyTracker;
use crate::trace::{self, Phase, TensorId, Trace, TraceConfig};
use crate::units::{Bytes, Seconds};
use std::collections::{HashMap, HashSet};

/// Synthetic tensor-id space for paged KV streams (one per layer; trace
/// weight ids are small sequential integers and never collide).
const KV_ID_BASE: u64 = 1 << 40;

fn kv_tensor_id(layer: u32) -> TensorId {
    TensorId(KV_ID_BASE + layer as u64)
}

/// Orchestrator configuration.
#[derive(Debug, Clone, Copy)]
pub struct PagingConfig {
    /// Page size (default 2 MiB).
    pub page_bytes: Bytes,
    /// Local-tier budget for paged bytes. `None` = uncapped (the
    /// orchestrator reports the peak instead of enforcing it).
    pub local_budget: Option<Bytes>,
    /// Home capacity of the pool tier when a flash tier is configured
    /// (the capacity-ratio knob of the flash sweep). `None` = the
    /// system's full `remote_capacity`. Setting it without `sys.flash`
    /// is a config error — a 2-tier pool is deliberately uncapped, as in
    /// the pre-flash model.
    pub pool_budget: Option<Bytes>,
    pub policy: PlacementPolicy,
    pub migration: MigrationConfig,
    pub nmc: NmcConfig,
    /// Shared-fabric arbitration for the paging stream and the NMC
    /// command/gather path (DESIGN.md §Fabric-Contention). Off keeps the
    /// unloaded charges bit-identically.
    pub contention: ContentionConfig,
    /// Steps to co-simulate (≥ 2 exposes the steady state: later decode
    /// steps reuse whatever residency the budget allowed to survive).
    pub steps: usize,
}

impl Default for PagingConfig {
    fn default() -> Self {
        PagingConfig {
            page_bytes: DEFAULT_PAGE_BYTES,
            local_budget: None,
            pool_budget: None,
            policy: PlacementPolicy::default(),
            migration: MigrationConfig::default(),
            nmc: NmcConfig::default(),
            contention: ContentionConfig::default(),
            steps: 2,
        }
    }
}

/// Result of a multi-step paged simulation.
#[derive(Debug, Clone)]
pub struct PagedReport {
    pub system: String,
    pub model: String,
    pub phase: Phase,
    pub batch: u64,
    pub policy: PolicyKind,
    pub steps: usize,
    pub num_ops: usize,
    /// First-step wall time (cold: every page misses).
    pub cold_step: Seconds,
    /// Last-step wall time (steady state under the budget).
    pub steady_step: Seconds,
    /// Exposed prefetch stall of the last step.
    pub exposed: Seconds,
    /// Paging-stream busy time of the last step.
    pub paging_busy: Seconds,
    /// Peak local occupancy across all steps: staged pages (including
    /// lookahead staging overlap) + pinned pages + per-op scratch.
    pub peak_local: Bytes,
    /// Bytes pinned by the weight-pinning reservation.
    pub pinned: Bytes,
    /// Total registered (remote) working set.
    pub working_set: Bytes,
    /// End-of-run bytes homed on the pool tier (= working set in the
    /// 2-tier model).
    pub pool_homed: Bytes,
    /// End-of-run bytes homed on the flash tier (zero without flash).
    pub flash_homed: Bytes,
    /// Bytes permanently resident in HBM because neither backing tier
    /// had room at placement (pinned; zero without flash).
    pub local_homed: Bytes,
    /// Cumulative migration counters over all steps.
    pub migration: MigrationStats,
    /// Ops executed in-pool by NMC (cumulative).
    pub nmc_offloads: u64,
    /// Eviction events (cumulative).
    pub evictions: u64,
    /// Shared-fabric arbitration observables (None with contention off).
    pub fabric: Option<FabricReport>,
}

impl PagedReport {
    /// Fraction of the last step lost to exposed prefetch.
    pub fn exposure_frac(&self) -> f64 {
        if self.steady_step.value() == 0.0 {
            0.0
        } else {
            self.exposed / self.steady_step
        }
    }

    /// Local-capacity reduction vs a reference capacity (e.g. the
    /// Baseline8 144 GB HBM of Table 4.3).
    pub fn capacity_reduction_vs(&self, reference: Bytes) -> f64 {
        if reference.value() <= 0.0 {
            return 0.0;
        }
        (1.0 - self.peak_local / reference).max(0.0)
    }
}

/// Why a chunk of bytes left (or stayed in) local memory — drives the
/// occupancy-interval reconstruction after the schedule is known.
struct ResidencyEvent {
    bytes: Bytes,
    /// Op index whose fetch brought the bytes in this step (`None` =
    /// carried over from a previous step).
    fetched_at: Option<usize>,
    /// Op index at which the bytes were released (`None` = still resident
    /// at step end).
    released_at: Option<usize>,
    /// Released at the op's *end* (minimal-residency drop) rather than at
    /// its fetch (capacity-pressure eviction runs before the fetch).
    released_at_end: bool,
}

/// Run the paged simulation over `steps` repetitions of one trace.
pub fn orchestrate(sys: &SystemConfig, tr: &Trace, cfg: &PagingConfig) -> Result<PagedReport> {
    sys.validate()?;
    if sys.fabric != FabricKind::TabSharedMemory {
        return Err(FhError::Config(
            "active tensor paging requires a FengHuang (TAB) node — shared-nothing \
             baselines keep everything resident"
                .into(),
        ));
    }
    if cfg.steps == 0 {
        return Err(FhError::Config("paging needs steps ≥ 1".into()));
    }
    if let Some(b) = cfg.local_budget {
        if b.value() <= 0.0 {
            return Err(FhError::Config("local budget must be positive".into()));
        }
    }
    // The 3-tier hierarchy: pool homes are capped (pool_budget, else the
    // full remote capacity) only when a flash tier exists below them to
    // take the displaced bands. 2-tier configs keep the uncapped pool of
    // the pre-flash model and never enter any flash code path.
    let flash_cap = TierModel::from_system(sys).flash().and_then(|f| f.capacity);
    if let Some(pb) = cfg.pool_budget {
        if flash_cap.is_none() {
            return Err(FhError::Config(
                "pool_budget caps the pool's home capacity of the 3-tier hierarchy — \
                 configure a flash tier (sys.flash / --flash-gb) first"
                    .into(),
            ));
        }
        if pb.value() <= 0.0 {
            return Err(FhError::Config("pool budget must be positive".into()));
        }
    }
    let pool_cap = flash_cap.map(|_| cfg.pool_budget.unwrap_or(sys.remote_capacity));
    let pol = cfg.policy;
    let mut table = PageTable::new(cfg.page_bytes);
    let mut mig = MigrationEngine::new(sys, cfg.migration);
    if cfg.contention.mode != ContentionMode::Off {
        // Single-node paging: one port into the pool; the ledger still
        // windows the stream, so per-module hotspots and window-budget
        // exhaustion surface even without fleet neighbours.
        let clock = FabricClock::for_system(sys, cfg.contention.resolved(1))?;
        mig = mig.with_contention(clock, 0);
    }

    // Register every weight tensor up front (KV tensors register lazily —
    // they grow with context).
    for op in &tr.ops {
        for w in &op.weights {
            table.register(w.id, w.bytes);
        }
    }
    // Load-time heat-band placement (3-tier only). No access statistics
    // exist yet, so program order is the heat proxy — every op re-runs
    // each step, and earlier bands are re-touched first. The pool takes
    // the leading bands up to its cap, flash the stable remainder; what
    // fits in neither backing tier must live in HBM permanently (pinned;
    // its one-time load is charged on first fetch like any pinned
    // weight). Runtime re-touches then promote bands back up.
    let mut local_homed = Bytes::ZERO;
    if let (Some(pool_cap), Some(flash_cap)) = (pool_cap, flash_cap) {
        let mut pool_used = Bytes::ZERO;
        let mut flash_used = Bytes::ZERO;
        let mut placed: HashSet<TensorId> = HashSet::new();
        for op in &tr.ops {
            for w in &op.weights {
                if !placed.insert(w.id) {
                    continue;
                }
                if pool_used + w.bytes <= pool_cap {
                    pool_used += w.bytes;
                } else if flash_used + w.bytes <= flash_cap {
                    flash_used += w.bytes;
                    table.set_home(w.id, Tier::Flash);
                } else {
                    local_homed += table.pin(w.id);
                    table.set_home(w.id, Tier::LocalHbm);
                }
            }
        }
        if let Some(budget) = cfg.local_budget {
            if local_homed.value() > budget.value() * (1.0 + 1e-9) {
                return Err(FhError::LocalMemoryThrash {
                    op: format!("{}/placement", tr.model),
                    need_gb: local_homed.as_gb(),
                    cap_gb: budget.as_gb(),
                });
            }
        }
    }
    // Weight pinning: reserve up to pin_frac × budget, program order.
    let mut pinned = Bytes::ZERO;
    if pol.pin_frac > 0.0 {
        if let Some(budget) = cfg.local_budget {
            let reserve = budget * pol.pin_frac.clamp(0.0, 1.0);
            'pinning: for op in &tr.ops {
                for w in &op.weights {
                    if table.entry(w.id).is_some_and(|e| e.pinned) {
                        continue;
                    }
                    if pinned + w.bytes > reserve {
                        break 'pinning;
                    }
                    pinned += table.pin(w.id);
                }
            }
        }
    }

    let n = tr.ops.len();
    let mut now: u64 = 0;
    let mut cold_step = Seconds::ZERO;
    let mut steady_step = Seconds::ZERO;
    let mut exposed = Seconds::ZERO;
    let mut paging_busy = Seconds::ZERO;
    let mut peak_local = Bytes::ZERO;
    let mut nmc_offloads: u64 = 0;
    let mut evictions: u64 = 0;

    for step in 0..cfg.steps {
        let carry = table.resident_bytes();
        let mut fetch: Vec<Seconds> = Vec::with_capacity(n);
        let mut run: Vec<Seconds> = Vec::with_capacity(n);
        let mut scratch: Vec<Bytes> = Vec::with_capacity(n);
        // Live residency chunks fetched this step: tensor → event index.
        let mut open: HashMap<TensorId, usize> = HashMap::new();
        let mut events: Vec<ResidencyEvent> = Vec::new();
        // Write-backs queue on the serial paging stream ahead of the next
        // fetch.
        let mut writeback_debt = Seconds::ZERO;

        for (k, op) in tr.ops.iter().enumerate() {
            now += 1;
            let mut kv_staged = pol.stages_kv(op);
            let mut nmc_run: Option<Seconds> = None;
            if cfg.nmc.enabled {
                match nmc::eligible(op) {
                    Some(NmcKind::ReduceAccumulate) => {
                        nmc_run = Some(nmc::reduce_time_contended(op, sys, &mut mig));
                    }
                    Some(NmcKind::EmbeddingGather) => {
                        // NMC executes *in the pool*: a flash-homed
                        // table cannot be gathered in-memory — it falls
                        // through to the normal path and pages in like
                        // any dense weight (NMC never elides a
                        // flash-tier fetch).
                        let in_pool = op
                            .weights
                            .iter()
                            .all(|w| table.entry(w.id).map_or(true, |e| e.home != Tier::Flash));
                        if in_pool {
                            nmc_run = Some(nmc::gather_time_contended(op, sys, &mut mig));
                        }
                    }
                    Some(NmcKind::KvGather) => {
                        // Gathered pool-side: never staged, even under a
                        // page_kv policy. The gather still moves its
                        // bytes through the pool, so the contention
                        // ledger records them as overlapped load (no
                        // time charged — the stream runs under the op).
                        // A KV band demoted to flash is out of the
                        // gather engine's reach and stages normally.
                        let in_pool = table
                            .entry(kv_tensor_id(op.layer))
                            .map_or(true, |e| e.home != Tier::Flash);
                        if in_pool {
                            if kv_staged {
                                nmc_offloads += 1;
                            }
                            kv_staged = false;
                            mig.book_overlapped(op.kv_stream_bytes);
                        }
                    }
                    None => {}
                }
            }
            // Scratch excludes the KV stream in both modes: staged KV is
            // tracked by the page table (a ResidencyEvent), unstaged KV
            // streams remote-to-SM and never occupies local memory.
            scratch.push(op.scratch_bytes - op.kv_stream_bytes);
            if let Some(t) = nmc_run {
                nmc_offloads += 1;
                fetch.push(std::mem::take(&mut writeback_debt));
                run.push(t);
                continue;
            }
            if op.is_collective() {
                fetch.push(std::mem::take(&mut writeback_debt));
                run.push(op_time(op, sys));
                continue;
            }

            // What this op needs staged: weights, plus the KV stream when
            // the policy pages it (KV pages are dirty — decode appends).
            let mut needed: Vec<(TensorId, bool)> =
                op.weights.iter().map(|w| (w.id, false)).collect();
            if kv_staged {
                let kvid = kv_tensor_id(op.layer);
                table.register(kvid, op.kv_stream_bytes);
                needed.push((kvid, true));
                if let (Some(pool_cap), Some(flash_cap)) = (pool_cap, flash_cap) {
                    // KV growth can push the pool's homes past its cap:
                    // sink the coldest stable band to flash (charged on
                    // the serial paging stream like a write-back). Bands
                    // the current op needs are protected; a full flash
                    // tier simply leaves the pool over-committed.
                    let over = table.bytes_homed(Tier::RemotePool) - pool_cap;
                    if over.value() > 0.0 {
                        let protect: HashSet<TensorId> =
                            needed.iter().map(|(id, _)| *id).collect();
                        for victim in pol.demotion_victims(&table, over, &protect, None) {
                            let vbytes =
                                table.entry(victim).map_or(Bytes::ZERO, |e| e.bytes);
                            let room = flash_cap - table.bytes_homed(Tier::Flash);
                            if vbytes > room {
                                break;
                            }
                            let vb = table.set_home(victim, Tier::Flash);
                            writeback_debt += mig.demote(vb, table.pages_for(vb));
                        }
                    }
                }
            }
            let mut missing = Bytes::ZERO;
            for (id, _) in &needed {
                missing += table.missing_bytes(*id);
            }

            // Capacity: make room under the budget before fetching.
            if let Some(budget) = cfg.local_budget {
                let over = table.resident_bytes() + missing - budget;
                if over.value() > 0.0 {
                    let protect: HashSet<TensorId> =
                        needed.iter().map(|(id, _)| *id).collect();
                    for victim in pol.victims(&table, over, &protect) {
                        let fetched_at = open.remove(&victim).map(|i| {
                            events[i].released_at = Some(k);
                            events[i].released_at_end = false;
                            events[i].fetched_at
                        });
                        let ev = table.evict(victim);
                        evictions += 1;
                        if ev.dirty_bytes.value() > 0.0 {
                            let pages = table.pages_for(ev.dirty_bytes);
                            // Dirty pages write back to their home tier
                            // (flash-homed bands at the media rate).
                            writeback_debt += if table.home(victim) == Some(Tier::Flash) {
                                mig.write_back_flash(ev.dirty_bytes, pages)
                            } else {
                                mig.write_back(ev.dirty_bytes, pages)
                            };
                        }
                        if fetched_at.is_none() {
                            // Carried bytes from an earlier step release
                            // mid-step.
                            events.push(ResidencyEvent {
                                bytes: ev.bytes,
                                fetched_at: None,
                                released_at: Some(k),
                                released_at_end: false,
                            });
                        }
                    }
                    if (table.resident_bytes() + missing).value()
                        > budget.value() * (1.0 + 1e-9)
                    {
                        return Err(FhError::LocalMemoryThrash {
                            op: format!("{}/{}", tr.model, op.name()),
                            need_gb: (table.resident_bytes() + missing).as_gb(),
                            cap_gb: budget.as_gb(),
                        });
                    }
                }
            }

            // Fetch missing pages (batched), touch hits.
            let mut t_fetch = std::mem::take(&mut writeback_debt);
            if missing.value() > 0.0 {
                if let (Some(pool_cap), Some(flash_cap)) = (pool_cap, flash_cap) {
                    // Promotion on re-touch: a flash-homed tensor
                    // fetched *again* is climbing the heat bands — copy
                    // it back into the pool, displacing a strictly
                    // colder band (hysteresis: a uniformly-warm working
                    // set stays put instead of churning through the
                    // pool every step).
                    let protect: HashSet<TensorId> =
                        needed.iter().map(|(id, _)| *id).collect();
                    for (id, _) in &needed {
                        let (retouch, bytes, heat) = match table.entry(*id) {
                            Some(e) => (
                                e.home == Tier::Flash
                                    && e.heat > 0
                                    && e.bytes.value() > 0.0,
                                e.bytes,
                                e.heat,
                            ),
                            None => (false, Bytes::ZERO, 0),
                        };
                        if !retouch {
                            continue;
                        }
                        let over = table.bytes_homed(Tier::RemotePool) + bytes - pool_cap;
                        if over.value() <= 0.0 {
                            table.set_home(*id, Tier::RemotePool);
                            t_fetch += mig.promote(bytes, table.pages_for(bytes));
                            continue;
                        }
                        let victims =
                            pol.demotion_victims(&table, over, &protect, Some(heat));
                        let freed: Bytes = victims
                            .iter()
                            .map(|v| table.entry(*v).map_or(Bytes::ZERO, |e| e.bytes))
                            .sum();
                        // The promoted band leaves flash as the victims
                        // arrive, so flash room is checked net of it.
                        let flash_after =
                            table.bytes_homed(Tier::Flash) + freed - bytes;
                        if freed >= over
                            && flash_after.value() <= flash_cap.value() * (1.0 + 1e-9)
                        {
                            for victim in victims {
                                let vb = table.set_home(victim, Tier::Flash);
                                t_fetch += mig.demote(vb, table.pages_for(vb));
                            }
                            table.set_home(*id, Tier::RemotePool);
                            t_fetch += mig.promote(bytes, table.pages_for(bytes));
                        }
                        // else: no strictly colder band to displace —
                        // the tensor stays flash-homed for now.
                    }
                }
                let mut moved = Bytes::ZERO;
                let mut pages = 0u64;
                let mut moved_flash = Bytes::ZERO;
                let mut pages_flash = 0u64;
                for (id, dirty) in &needed {
                    let from_flash =
                        table.entry(*id).is_some_and(|e| e.home == Tier::Flash);
                    let (b, p) = table.page_in(*id, now, *dirty);
                    if b.value() > 0.0 {
                        open.insert(
                            *id,
                            events.len(),
                        );
                        events.push(ResidencyEvent {
                            bytes: b,
                            fetched_at: Some(k),
                            released_at: None,
                            released_at_end: false,
                        });
                    }
                    if from_flash {
                        moved_flash += b;
                        pages_flash += p;
                    } else {
                        moved += b;
                        pages += p;
                    }
                }
                t_fetch += mig.page_in(moved, pages);
                if moved_flash.value() > 0.0 {
                    t_fetch += mig.page_in_flash(moved_flash, pages_flash);
                }
            } else {
                for (id, _) in &needed {
                    table.touch(*id, now);
                }
            }
            fetch.push(t_fetch);
            run.push(if kv_staged { op_time_kv_staged(op, sys) } else { op_time(op, sys) });

            // Minimal residency: drop the working set as soon as the op
            // completes ("only the minimum required data are stored
            // locally").
            if pol.kind == PolicyKind::MinimalResidency {
                for (id, _) in &needed {
                    let idx = open.remove(id);
                    let ev = table.evict(*id);
                    if ev.bytes.value() > 0.0 {
                        evictions += 1;
                        if ev.dirty_bytes.value() > 0.0 {
                            let pages = table.pages_for(ev.dirty_bytes);
                            writeback_debt += if table.home(*id) == Some(Tier::Flash) {
                                mig.write_back_flash(ev.dirty_bytes, pages)
                            } else {
                                mig.write_back(ev.dirty_bytes, pages)
                            };
                        }
                        match idx {
                            Some(i) => {
                                events[i].released_at = Some(k);
                                events[i].released_at_end = true;
                            }
                            None => events.push(ResidencyEvent {
                                bytes: ev.bytes,
                                fetched_at: None,
                                released_at: Some(k),
                                released_at_end: true,
                            }),
                        }
                    }
                }
            }
        }

        // Two-stream schedule from the page-table-derived fetch times.
        let sched = engine::schedule(&fetch, &run, pol.window.max(1));
        let step_time = engine::makespan(&sched);
        let step_exposed = engine::total_exposed(&sched);
        let step_paging: Seconds = fetch.iter().copied().sum::<Seconds>() + writeback_debt;

        // Reconstruct the occupancy timeline now that op times are known.
        let mut occ = OccupancyTracker::new();
        let carried_released: Bytes = events
            .iter()
            .filter(|e| e.fetched_at.is_none())
            .map(|e| e.bytes)
            .sum();
        occ.pin(carry - carried_released.min(carry));
        for e in &events {
            let from = match e.fetched_at {
                Some(f) => sched[f].fetch_start,
                None => Seconds::ZERO,
            };
            let to = match e.released_at {
                Some(r) if e.released_at_end => sched[r].end,
                Some(r) => sched[r].fetch_start,
                None => step_time,
            };
            occ.add(from, to, e.bytes);
        }
        for (k, s) in scratch.iter().enumerate() {
            if s.value() > 0.0 {
                occ.add(sched[k].start, sched[k].end, *s);
            }
        }
        peak_local = peak_local.max(occ.peak());

        if step == 0 {
            cold_step = step_time;
        }
        steady_step = step_time;
        exposed = step_exposed;
        paging_busy = step_paging;
    }

    Ok(PagedReport {
        system: sys.name.clone(),
        model: tr.model.clone(),
        phase: tr.phase,
        batch: tr.batch,
        policy: pol.kind,
        steps: cfg.steps,
        num_ops: n,
        cold_step,
        steady_step,
        exposed,
        paging_busy,
        peak_local,
        pinned,
        working_set: table.registered_bytes(),
        pool_homed: table.bytes_homed(Tier::RemotePool),
        flash_homed: table.bytes_homed(Tier::Flash),
        local_homed,
        fabric: mig.fabric_report(),
        migration: mig.stats,
        nmc_offloads,
        evictions,
    })
}

/// Generate the trace for one phase and run the paged simulation.
pub fn simulate_paged(
    sys: &SystemConfig,
    model: &ModelArch,
    batch: u64,
    phase: Phase,
    cfg: &PagingConfig,
) -> Result<PagedReport> {
    let tr = trace::generate(&TraceConfig { model: model.clone(), tp: sys.tp(), batch, phase });
    orchestrate(sys, &tr, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm};
    use crate::models::arch::gpt3_175b;
    use crate::units::Bandwidth;

    fn sys() -> SystemConfig {
        fh4_15xm(Bandwidth::tbps(4.8))
    }

    fn decode_cfg() -> PagingConfig {
        PagingConfig { steps: 2, ..Default::default() }
    }

    fn decode_report(cfg: &PagingConfig) -> PagedReport {
        simulate_paged(&sys(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, cfg).unwrap()
    }

    #[test]
    fn baseline_fabric_is_rejected() {
        let r = simulate_paged(
            &baseline8(),
            &gpt3_175b(),
            8,
            Phase::Decode { kv_len: 128 },
            &PagingConfig::default(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn unlimited_lru_reaches_zero_fetch_steady_state() {
        let cfg = PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            ..decode_cfg()
        };
        let r = decode_report(&cfg);
        // Step 1 pages the full weight shard in; step 2 is all cache hits,
        // so the steady step loses the paging stream entirely.
        assert!(r.cold_step > r.steady_step, "cold {:?} steady {:?}", r.cold_step, r.steady_step);
        assert_eq!(r.exposed, Seconds::ZERO);
        assert_eq!(r.paging_busy, Seconds::ZERO);
        // All weights were moved exactly once.
        let ws = r.working_set.as_gb();
        assert!((r.migration.bytes_in.as_gb() - ws).abs() < 0.01 * ws);
        assert_eq!(r.evictions, 0);
    }

    #[test]
    fn minimal_residency_restreams_every_step() {
        let r = decode_report(&decode_cfg());
        // Both steps page the full working set (evicted after each use).
        let ws = r.working_set.as_gb();
        assert!(
            (r.migration.bytes_in.as_gb() - 2.0 * ws).abs() < 0.02 * ws,
            "paged {} GB vs 2×{} GB",
            r.migration.bytes_in.as_gb(),
            ws
        );
        assert!(r.evictions > 0);
        assert!(r.paging_busy > Seconds::ZERO);
        // Peak stays far below the working set: that is Table 4.3.
        assert!(r.peak_local.as_gb() < 0.4 * ws, "peak {} GB", r.peak_local.as_gb());
    }

    #[test]
    fn table43_band_minimal_residency_reduction() {
        // Acceptance: ≥ 90% local-capacity reduction vs the Baseline8
        // 144 GB HBM on at least one workload, with the steady step still
        // inside the performance envelope of the uncapped run.
        let r = decode_report(&decode_cfg());
        let reduction = r.capacity_reduction_vs(Bytes::gb(144.0));
        assert!(reduction >= 0.90, "reduction {:.3} (peak {} GB)", reduction, r.peak_local.as_gb());
        let uncapped = decode_report(&PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            ..decode_cfg()
        });
        let slowdown = r.steady_step / uncapped.steady_step;
        assert!(slowdown < 2.5, "paging slowdown {slowdown:.2}×");
    }

    #[test]
    fn tighter_budget_is_never_faster() {
        let mk = |budget_gb: f64| {
            let cfg = PagingConfig {
                local_budget: Some(Bytes::gb(budget_gb)),
                policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
                ..decode_cfg()
            };
            decode_report(&cfg).steady_step
        };
        let tight = mk(8.0);
        let loose = mk(64.0);
        let uncapped = decode_report(&PagingConfig {
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            ..decode_cfg()
        })
        .steady_step;
        assert!(tight >= loose - Seconds::ns(1.0), "tight {tight:?} loose {loose:?}");
        assert!(loose >= uncapped - Seconds::ns(1.0));
    }

    #[test]
    fn budget_is_enforced_on_paged_bytes() {
        let cfg = PagingConfig {
            local_budget: Some(Bytes::gb(12.0)),
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            ..decode_cfg()
        };
        let r = decode_report(&cfg);
        assert!(r.evictions > 0, "12 GB cannot hold the 87 GB shard");
        // Peak can exceed the paged-byte budget only by scratch +
        // lookahead staging, not by another working set.
        assert!(r.peak_local.as_gb() < 12.0 + 20.0, "peak {} GB", r.peak_local.as_gb());
    }

    #[test]
    fn infeasible_budget_reports_thrash() {
        let cfg = PagingConfig {
            local_budget: Some(Bytes::gb(0.2)),
            policy: PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() },
            steps: 1,
            ..Default::default()
        };
        let r = simulate_paged(&sys(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg);
        assert!(matches!(r, Err(FhError::LocalMemoryThrash { .. })), "got {r:?}");
    }

    #[test]
    fn pinning_reserves_and_survives_steps() {
        let cfg = PagingConfig {
            local_budget: Some(Bytes::gb(24.0)),
            policy: PlacementPolicy {
                kind: PolicyKind::MinimalResidency,
                pin_frac: 0.5,
                ..Default::default()
            },
            ..decode_cfg()
        };
        let r = decode_report(&cfg);
        assert!(r.pinned.as_gb() > 1.0, "pinned {} GB", r.pinned.as_gb());
        assert!(r.pinned.as_gb() <= 12.0 + 1e-9);
        // Pinned weights page in once and never re-stream: two minimal
        // residency steps move 2×working-set − pinned (± rounding).
        let ws = r.working_set.as_gb();
        assert!(
            r.migration.bytes_in.as_gb() <= 2.0 * ws - 0.9 * r.pinned.as_gb(),
            "paged {} GB, pinned {} GB",
            r.migration.bytes_in.as_gb(),
            r.pinned.as_gb()
        );
    }

    #[test]
    fn nmc_offloads_reduce_fetch_and_count_ops() {
        let base = decode_report(&decode_cfg());
        let nmc = decode_report(&PagingConfig {
            nmc: NmcConfig { enabled: true },
            ..decode_cfg()
        });
        assert!(nmc.nmc_offloads > 0);
        assert_eq!(base.nmc_offloads, 0);
        // In-pool reductions shave the collectives' read-back latency.
        assert!(nmc.steady_step <= base.steady_step + Seconds::ns(1.0));
    }

    #[test]
    fn paged_kv_stages_and_writes_back() {
        let cfg = PagingConfig {
            policy: PlacementPolicy { page_kv: true, ..Default::default() },
            ..decode_cfg()
        };
        let r = decode_report(&cfg);
        // KV pages are dirty → minimal residency writes them back.
        assert!(r.migration.writebacks > 0);
        assert!(r.migration.bytes_out.value() > 0.0);
    }

    #[test]
    fn fabric_contention_overlays_the_paging_stream() {
        let base = decode_report(&decode_cfg());
        assert!(base.fabric.is_none(), "contention defaults to off");
        let contended = decode_report(&PagingConfig {
            contention: ContentionConfig {
                mode: ContentionMode::Shared,
                ..Default::default()
            },
            ..decode_cfg()
        });
        let fr = contended.fabric.as_ref().expect("ledger attached");
        assert!(fr.transfers > 0, "page DMA must book through the ledger");
        assert!(fr.bytes.value() > 0.0);
        // A serial single-port stream sees arbitration overhead but no
        // self-queueing: never faster than the unloaded engine.
        assert!(
            contended.steady_step >= base.steady_step - Seconds::ns(1.0),
            "contended {:?} vs base {:?}",
            contended.steady_step,
            base.steady_step
        );
        // An explicit Off config is bit-identical to the default path.
        let off = decode_report(&PagingConfig {
            contention: ContentionConfig::default(),
            ..decode_cfg()
        });
        assert_eq!(off.cold_step, base.cold_step);
        assert_eq!(off.steady_step, base.steady_step);
        assert_eq!(off.migration.bytes_in.value(), base.migration.bytes_in.value());
    }

    #[test]
    fn flash_with_roomy_pool_is_bit_identical_to_two_tiers() {
        use crate::config::FlashConfig;
        let base = decode_report(&decode_cfg());
        let mut fsys = sys();
        fsys.flash = Some(FlashConfig::gb(2048.0));
        let r = simulate_paged(&fsys, &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &decode_cfg())
            .unwrap();
        // The 1152 GB pool homes the whole shard, so no band ever reaches
        // flash and every observable matches the 2-tier run bit for bit.
        assert_eq!(r.cold_step, base.cold_step);
        assert_eq!(r.steady_step, base.steady_step);
        assert_eq!(r.exposed, base.exposed);
        assert_eq!(r.paging_busy, base.paging_busy);
        assert_eq!(r.peak_local, base.peak_local);
        assert_eq!(r.migration.bytes_in, base.migration.bytes_in);
        assert_eq!(r.migration.time_in, base.migration.time_in);
        assert_eq!(r.migration.flash_pages_in, 0);
        assert_eq!(r.migration.demotions, 0);
        assert_eq!(r.flash_homed, Bytes::ZERO);
        assert_eq!(r.pool_homed, r.working_set);
        assert_eq!(r.local_homed, Bytes::ZERO);
    }

    #[test]
    fn capped_pool_homes_the_stable_band_on_flash() {
        use crate::config::FlashConfig;
        let mut fsys = sys();
        fsys.flash = Some(FlashConfig::gb(2048.0));
        let cfg = PagingConfig { pool_budget: Some(Bytes::gb(40.0)), ..decode_cfg() };
        let r =
            simulate_paged(&fsys, &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg).unwrap();
        // The gpt3/tp4 shard is ~87 GB: ~40 GB leads stay pool-homed, the
        // stable remainder lives on flash and pages in at the media rate.
        assert!(r.flash_homed.as_gb() > 10.0, "flash homed {} GB", r.flash_homed.as_gb());
        assert!(r.pool_homed.as_gb() <= 40.0 * (1.0 + 1e-9));
        assert_eq!(r.local_homed, Bytes::ZERO, "flash had room for the spill");
        assert!(r.migration.flash_bytes_in.value() > 0.0);
        assert!(r.migration.flash_pages_in > 0);
        // Conservation: every registered byte is homed on exactly one tier.
        let homed = r.pool_homed + r.flash_homed + r.local_homed;
        assert!(
            (homed.value() - r.working_set.value()).abs() < 1.0,
            "homed {} vs working set {}",
            homed.as_gb(),
            r.working_set.as_gb()
        );
        // Streaming part of each step from 1.6 TB/s flash instead of the
        // 4.8 TB/s pool can only slow the steady state down.
        let base = decode_report(&decode_cfg());
        assert!(
            r.steady_step >= base.steady_step - Seconds::ns(1.0),
            "flash {:?} vs pool {:?}",
            r.steady_step,
            base.steady_step
        );
    }

    #[test]
    fn pool_budget_requires_a_flash_tier() {
        let cfg = PagingConfig { pool_budget: Some(Bytes::gb(40.0)), ..decode_cfg() };
        let r = simulate_paged(&sys(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }, &cfg);
        assert!(matches!(r, Err(FhError::Config(_))), "got {r:?}");
    }

    #[test]
    fn prefill_single_step_works() {
        let cfg = PagingConfig { steps: 1, ..Default::default() };
        let r =
            simulate_paged(&sys(), &gpt3_175b(), 8, Phase::Prefill { prompt_len: 2048 }, &cfg)
                .unwrap();
        assert_eq!(r.cold_step, r.steady_step);
        assert!(r.cold_step.value() > 0.0);
        assert!(r.exposure_frac() < 0.35, "prefill exposure {:.3}", r.exposure_frac());
    }
}
