//! Placement / eviction policies of the paging orchestrator
//! (DESIGN.md §Paging).
//!
//! * [`PolicyKind::MinimalResidency`] — the paper's default: a tensor's
//!   pages are dropped the moment its consuming op completes ("only the
//!   minimum required data are stored locally").
//! * [`PolicyKind::Lru`] — keep pages until capacity pressure, evict the
//!   least-recently-used tensor first (classic cache; wins when the
//!   budget fits a useful fraction of the per-step working set).
//! * [`PolicyKind::Heat`] — evict the least-frequently-touched tensor
//!   first (access-heat; protects tensors reused across steps from
//!   one-shot streaming traffic).
//!
//! [`PlacementPolicy`] also carries the lookahead window and the KV
//! staging switch — a generalisation of the older
//! [`crate::sim::prefetcher::PrefetchPolicy`] (see
//! [`PlacementPolicy::from_prefetch`]), which keeps working for the
//! stateless whole-tensor path.

use super::page::PageTable;
use super::tiers::Tier;
use crate::sim::prefetcher::PrefetchPolicy;
use crate::trace::{Op, OpKind, TensorId};
use crate::units::Bytes;
use std::collections::HashSet;

/// Eviction strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PolicyKind {
    /// Paper default: evict as soon as the consuming op completes.
    #[default]
    MinimalResidency,
    /// Least-recently-used, evicted under capacity pressure only.
    Lru,
    /// Least-frequently-used (access heat), under capacity pressure only.
    Heat,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "minimal" | "minimal-residency" | "min" => Some(PolicyKind::MinimalResidency),
            "lru" => Some(PolicyKind::Lru),
            "heat" | "lfu" | "access-heat" => Some(PolicyKind::Heat),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::MinimalResidency => "minimal-residency",
            PolicyKind::Lru => "lru",
            PolicyKind::Heat => "access-heat",
        }
    }

    /// All policies, for sweeps.
    pub fn all() -> [PolicyKind; 3] {
        [PolicyKind::MinimalResidency, PolicyKind::Lru, PolicyKind::Heat]
    }
}

/// Full placement policy of the orchestrator.
#[derive(Debug, Clone, Copy)]
pub struct PlacementPolicy {
    pub kind: PolicyKind,
    /// Lookahead window w for the paging stream (generalises
    /// [`PrefetchPolicy::window`]; the paper evaluates one node ahead).
    pub window: usize,
    /// Stage the attention KV stream through local memory instead of
    /// direct SM-from-remote reads (ablation; default false per §3.1).
    pub page_kv: bool,
    /// Fraction of the local budget reserved for pinned weights: tensors
    /// are pinned in program order until the reservation fills. Pinned
    /// pages are fetched once and never evicted.
    pub pin_frac: f64,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        let p = PrefetchPolicy::default();
        PlacementPolicy { kind: PolicyKind::default(), window: p.window, page_kv: p.page_kv, pin_frac: 0.0 }
    }
}

impl PlacementPolicy {
    /// Bridge from the stateless prefetcher policy (subsumption: the same
    /// window/KV semantics, plus stateful residency on top).
    pub fn from_prefetch(p: &PrefetchPolicy) -> Self {
        PlacementPolicy { window: p.window, page_kv: p.page_kv, ..Default::default() }
    }

    /// Whether this op's KV stream is staged through the pager.
    pub fn stages_kv(&self, op: &Op) -> bool {
        self.page_kv
            && matches!(op.kind, OpKind::Attention)
            && op.kv_stream_bytes.value() > 0.0
    }

    /// Pick eviction victims freeing at least `need` bytes, best victim
    /// first. `protect` holds tensors the current op needs (never
    /// victims). Pinned and non-resident tensors are skipped.
    pub fn victims(
        &self,
        table: &PageTable,
        need: Bytes,
        protect: &HashSet<TensorId>,
    ) -> Vec<TensorId> {
        let mut cands: Vec<(TensorId, u64, u64, Bytes)> = table
            .iter()
            .filter(|(id, e)| {
                !e.pinned && e.resident_bytes().value() > 0.0 && !protect.contains(id)
            })
            .map(|(id, e)| (*id, e.last_use, e.heat, e.resident_bytes()))
            .collect();
        match self.kind {
            // Minimal residency evicts eagerly after use; when pressure
            // still arises (working sets bigger than budget), fall back to
            // coldest-first like LRU. The trailing TensorId breaks
            // last_use/heat ties: candidates come out of a HashMap whose
            // iteration order is seeded per process, so without it the
            // victim order of tied tensors (common — registered in the
            // same op batch) would differ run to run.
            PolicyKind::MinimalResidency | PolicyKind::Lru => {
                cands.sort_unstable_by_key(|c| (c.1, c.0));
            }
            PolicyKind::Heat => {
                cands.sort_unstable_by_key(|c| (c.2, c.1, c.0));
            }
        }
        let mut out = Vec::new();
        let mut freed = Bytes::ZERO;
        for (id, _, _, bytes) in cands {
            if freed >= need {
                break;
            }
            out.push(id);
            freed += bytes;
        }
        out
    }

    /// Pick pool→flash demotion victims freeing at least `need` bytes of
    /// pool-homed capacity, coldest heat band first (heat, then recency,
    /// then id — fully deterministic). Only pool-homed, unpinned,
    /// non-resident tensors outside `protect` qualify: demoting a tensor
    /// whose pages are staged in HBM would detach the local copy from
    /// its authoritative home mid-flight, and a tensor hot enough to be
    /// resident is by definition not in the stable band. When
    /// `below_heat` is set, only tensors *strictly colder* than that
    /// heat qualify — the hysteresis that keeps promotion from churning
    /// a uniformly-warm working set through the pool.
    pub fn demotion_victims(
        &self,
        table: &PageTable,
        need: Bytes,
        protect: &HashSet<TensorId>,
        below_heat: Option<u64>,
    ) -> Vec<TensorId> {
        let mut cands: Vec<(TensorId, u64, u64, Bytes)> = table
            .iter()
            .filter(|(id, e)| {
                e.home == Tier::RemotePool
                    && !e.pinned
                    && e.resident_bytes().value() <= 0.0
                    && e.bytes.value() > 0.0
                    && !protect.contains(id)
                    && below_heat.map_or(true, |h| e.heat < h)
            })
            .map(|(id, e)| (*id, e.heat, e.last_use, e.bytes))
            .collect();
        cands.sort_unstable_by_key(|c| (c.1, c.2, c.0));
        let mut out = Vec::new();
        let mut freed = Bytes::ZERO;
        for (id, _, _, bytes) in cands {
            if freed >= need {
                break;
            }
            out.push(id);
            freed += bytes;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Bytes;

    fn table_with(entries: &[(u64, f64, u64, u64)]) -> PageTable {
        // (id, bytes, last_use, extra_touches)
        let mut t = PageTable::new(Bytes::new(64.0));
        for &(id, bytes, last, touches) in entries {
            let id = TensorId(id);
            t.register(id, Bytes::new(bytes));
            t.page_in(id, last, false);
            for k in 0..touches {
                t.touch(id, last + k + 1);
            }
        }
        t
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for k in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(k.name()), Some(k));
        }
        assert_eq!(PolicyKind::parse("LRU"), Some(PolicyKind::Lru));
        assert_eq!(PolicyKind::parse("minimal"), Some(PolicyKind::MinimalResidency));
        assert!(PolicyKind::parse("belady").is_none());
    }

    #[test]
    fn lru_evicts_coldest_first() {
        let t = table_with(&[(1, 100.0, 5, 0), (2, 100.0, 1, 0), (3, 100.0, 9, 0)]);
        let p = PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() };
        let v = p.victims(&t, Bytes::new(150.0), &HashSet::new());
        assert_eq!(v, vec![TensorId(2), TensorId(1)]);
    }

    #[test]
    fn heat_evicts_least_touched_first() {
        // id 1 touched 4×, id 2 once, id 3 twice.
        let t = table_with(&[(1, 100.0, 1, 3), (2, 100.0, 8, 0), (3, 100.0, 2, 1)]);
        let p = PlacementPolicy { kind: PolicyKind::Heat, ..Default::default() };
        let v = p.victims(&t, Bytes::new(1.0), &HashSet::new());
        assert_eq!(v, vec![TensorId(2)]);
    }

    #[test]
    fn protected_and_pinned_are_never_victims() {
        let mut t = table_with(&[(1, 100.0, 1, 0), (2, 100.0, 2, 0), (3, 100.0, 3, 0)]);
        t.pin(TensorId(3));
        let protect: HashSet<TensorId> = [TensorId(1)].into_iter().collect();
        let p = PlacementPolicy { kind: PolicyKind::Lru, ..Default::default() };
        let v = p.victims(&t, Bytes::new(500.0), &protect);
        assert_eq!(v, vec![TensorId(2)], "only the unprotected unpinned tensor");
    }

    #[test]
    fn demotion_picks_the_coldest_band_deterministically() {
        // Non-resident pool-homed tensors, ordered (heat, last_use, id).
        let mut t = PageTable::new(Bytes::new(64.0));
        for id in 1u64..=4 {
            t.register(TensorId(id), Bytes::new(100.0));
        }
        t.touch(TensorId(1), 5);
        t.touch(TensorId(1), 6);
        t.touch(TensorId(4), 7);
        let p = PlacementPolicy::default();
        // Heat: id1=2, id4=1, id2=id3=0 — the 2/3 tie breaks by id.
        let v = p.demotion_victims(&t, Bytes::new(250.0), &HashSet::new(), None);
        assert_eq!(v, vec![TensorId(2), TensorId(3), TensorId(4)]);
        // Hysteresis: only tensors strictly colder than heat 1 qualify.
        let v = p.demotion_victims(&t, Bytes::new(500.0), &HashSet::new(), Some(1));
        assert_eq!(v, vec![TensorId(2), TensorId(3)]);
        // Resident, already-demoted, and protected tensors never qualify.
        t.page_in(TensorId(2), 1, false);
        t.set_home(TensorId(3), Tier::Flash);
        let protect: HashSet<TensorId> = [TensorId(4)].into_iter().collect();
        let v = p.demotion_victims(&t, Bytes::new(500.0), &protect, None);
        assert_eq!(v, vec![TensorId(1)]);
    }

    #[test]
    fn from_prefetch_preserves_window_and_kv() {
        let pf = PrefetchPolicy { window: 3, page_kv: true };
        let p = PlacementPolicy::from_prefetch(&pf);
        assert_eq!(p.window, 3);
        assert!(p.page_kv);
        assert_eq!(p.kind, PolicyKind::MinimalResidency);
    }
}
