//! Near-memory compute (NMC) offload: eligible ops execute *in the pool*
//! and skip page-in entirely (DESIGN.md §Paging).
//!
//! The paper's pool is active memory — the TAB already performs
//! write-accumulate reductions in-memory (§3.3.1, the functional
//! semantics live in [`crate::fabric::tab`]). This module generalises
//! that capability into an offload model:
//!
//! * **Write-accumulate reductions** (AllReduce / ReduceScatter): each
//!   GPU `write_accumulate`s its contribution and the pool reduces in
//!   place. The consumer-side read-back command of the ordinary TAB
//!   collective path is elided — the reduced tensor stays in the pool for
//!   the next consumer.
//! * **Embedding gather**: the embedding table never pages in; the pool
//!   gathers the addressed rows and streams only those to the GPU.
//! * **KV gather**: the attention KV stream is gathered pool-side, so
//!   even under a `page_kv` policy the KV pages skip the paging stream.
//!
//! Offload times are grounded on the same Table 3.1 latencies and Eq 4.1
//! link efficiency as every other remote access.

use crate::config::SystemConfig;
use crate::fabric::collectives::{tab_wire_bytes, Collective};
use crate::models::mfu;
use crate::trace::{Op, OpKind, OpName};
use crate::units::Seconds;

/// NMC knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct NmcConfig {
    pub enabled: bool,
}

/// Which in-pool execution an op maps to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NmcKind {
    /// In-pool write-accumulate reduction (AllReduce / ReduceScatter).
    ReduceAccumulate,
    /// Pool-side gather of embedding rows.
    EmbeddingGather,
    /// Pool-side gather of the attention KV stream.
    KvGather,
}

/// Whether `op` can execute in the pool, and how.
pub fn eligible(op: &Op) -> Option<NmcKind> {
    match op.kind {
        OpKind::Collective(Collective::AllReduce | Collective::ReduceScatter) => {
            Some(NmcKind::ReduceAccumulate)
        }
        OpKind::Memory if op.op == OpName::Embed => Some(NmcKind::EmbeddingGather),
        OpKind::Attention if op.kv_stream_bytes.value() > 0.0 => Some(NmcKind::KvGather),
        _ => None,
    }
}

/// In-pool reduction time: write-accumulate + completion notification +
/// the write stream; the read-back command of the ordinary collective
/// path (Eq 3.1 fixed part) is elided because the result stays in-pool.
pub fn reduce_time(op: &Op, sys: &SystemConfig) -> Seconds {
    let OpKind::Collective(c) = op.kind else {
        return Seconds::ZERO;
    };
    let fixed = sys.latencies.tab_write_accumulate + sys.latencies.notification_latency();
    fixed + tab_wire_bytes(c, op.comm_payload, sys.num_gpus).over(sys.fabric_bw)
}

/// Pool-side gather time: one read command, then only the gathered rows
/// stream to the GPU at Eq 4.1 efficiency. The gathered payload equals
/// the op's read traffic (the rows themselves); the *table* moves
/// nothing.
pub fn gather_time(op: &Op, sys: &SystemConfig) -> Seconds {
    sys.latencies.tab_read + mfu::transfer_time(op.read_bytes, sys.fabric_bw)
}

/// [`reduce_time`] with the reduction's wire traffic booked through the
/// shared-fabric ledger (DESIGN.md §Fabric-Contention): the accumulate
/// stream runs at whatever residual bandwidth the arbitration grants.
/// Falls back to the exact unloaded charge when `mig` carries no active
/// contention clock. Note the contended stream additionally pays the
/// Eq 4.1 message-size efficiency the ledger models; the unloaded path
/// keeps the paper's raw `bytes / bandwidth` term.
pub fn reduce_time_contended(
    op: &Op,
    sys: &SystemConfig,
    mig: &mut crate::paging::MigrationEngine,
) -> Seconds {
    let OpKind::Collective(c) = op.kind else {
        return Seconds::ZERO;
    };
    match mig.book_stream(tab_wire_bytes(c, op.comm_payload, sys.num_gpus)) {
        Some(stream) => {
            sys.latencies.tab_write_accumulate + sys.latencies.notification_latency() + stream
        }
        None => reduce_time(op, sys),
    }
}

/// [`gather_time`] with the gathered rows booked through the
/// shared-fabric ledger; unloaded charge when contention is off.
pub fn gather_time_contended(
    op: &Op,
    sys: &SystemConfig,
    mig: &mut crate::paging::MigrationEngine,
) -> Seconds {
    match mig.book_stream(op.read_bytes) {
        Some(stream) => sys.latencies.tab_read + stream,
        None => gather_time(op, sys),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::fabric::collectives::tab_collective_time;
    use crate::models::arch::gpt3_175b;
    use crate::trace::{generate, Phase, TraceConfig};
    use crate::units::Bandwidth;

    fn trace() -> crate::trace::Trace {
        generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 2048 },
        })
    }

    #[test]
    fn eligibility_covers_the_three_offload_classes() {
        let t = trace();
        let embed = t.ops.iter().find(|o| o.op == OpName::Embed).unwrap();
        assert_eq!(eligible(embed), Some(NmcKind::EmbeddingGather));
        let attn = t.ops.iter().find(|o| o.op == OpName::Attn).unwrap();
        assert_eq!(eligible(attn), Some(NmcKind::KvGather));
        let ar = t.ops.iter().find(|o| o.is_collective()).unwrap();
        assert_eq!(eligible(ar), Some(NmcKind::ReduceAccumulate));
        let qkv = t.ops.iter().find(|o| o.op == OpName::Qkv).unwrap();
        assert_eq!(eligible(qkv), None, "dense GEMMs stay on the GPU");
    }

    #[test]
    fn in_pool_reduction_beats_readback_path() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let t = trace();
        let ar = t.ops.iter().find(|o| o.is_collective()).unwrap();
        let OpKind::Collective(c) = ar.kind else { unreachable!() };
        let ordinary =
            tab_collective_time(c, ar.comm_payload, sys.num_gpus, sys.fabric_bw, &sys.latencies);
        let nmc = reduce_time(ar, &sys);
        // Eliding the read-back saves exactly the fixed read latency.
        let saved = ordinary - nmc;
        assert!((saved.as_ns() - 220.0).abs() < 1e-6, "saved {} ns", saved.as_ns());
    }

    #[test]
    fn contended_variants_fall_back_exactly_when_uncontended() {
        use crate::paging::{MigrationConfig, MigrationEngine};
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut plain = MigrationEngine::new(&sys, MigrationConfig::default());
        let t = trace();
        let ar = t.ops.iter().find(|o| o.is_collective()).unwrap();
        let embed = t.ops.iter().find(|o| o.op == OpName::Embed).unwrap();
        assert_eq!(reduce_time_contended(ar, &sys, &mut plain), reduce_time(ar, &sys));
        assert_eq!(gather_time_contended(embed, &sys, &mut plain), gather_time(embed, &sys));
        // With an active clock the stream pays Eq 4.1 shaping (and, under
        // load, queueing): never cheaper than the unloaded wire time.
        use crate::fabric::contention::{ContentionConfig, ContentionMode, FabricClock};
        let cfg = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() }
            .resolved(1);
        let clock = FabricClock::for_system(&sys, cfg).unwrap();
        let mut loaded = MigrationEngine::new(&sys, MigrationConfig::default())
            .with_contention(clock, 0);
        let contended = gather_time_contended(embed, &sys, &mut loaded);
        assert!(contended >= gather_time(embed, &sys) - Seconds::ns(1.0));
        assert!(loaded.fabric_report().unwrap().transfers == 1);
    }

    #[test]
    fn gather_streams_only_the_rows() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let t = trace();
        let embed = t.ops.iter().find(|o| o.op == OpName::Embed).unwrap();
        let g = gather_time(embed, &sys);
        assert!(g > Seconds::ZERO);
        // The gather must be dwarfed by a hypothetical table page-in: the
        // decode-step rows are a few hundred KB vs a multi-GB table.
        let table_pagein = mfu::transfer_time(crate::units::Bytes::gb(1.0), sys.fabric_bw);
        assert!(g < table_pagein, "gather {} vs table {}", g.as_us(), table_pagein.as_us());
    }
}
