//! Memory-tier model: GPU-local HBM → FengHuang remote pool, plus the
//! per-replica KV capacity-pressure model the cluster layer charges
//! (DESIGN.md §Paging).
//!
//! Capacities and bandwidths are drawn from the node's [`SystemConfig`]
//! (which in turn comes from the `hardware` catalog presets): the local
//! tier is the GPU HBM (`local_bw`, `local_capacity`), the remote tier is
//! the pool behind the TAB crossbar (`fabric_bw`, `remote_capacity`).

use crate::config::{FabricKind, SystemConfig};
use crate::fabric::FabricLatencies;
use crate::models::mfu;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Which tier a page lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// GPU-local HBM (the paging cache on FengHuang nodes).
    LocalHbm,
    /// The FengHuang remote pool behind the TAB.
    RemotePool,
}

/// One tier's capacity/bandwidth envelope.
#[derive(Debug, Clone)]
pub struct TierSpec {
    pub tier: Tier,
    pub name: String,
    /// `None` = uncapped (Table 4.1 "as much as needed").
    pub capacity: Option<Bytes>,
    pub bandwidth: Bandwidth,
}

/// The two-tier hierarchy of a FengHuang node.
#[derive(Debug, Clone)]
pub struct TierModel {
    pub local: TierSpec,
    pub remote: TierSpec,
}

impl TierModel {
    /// Derive the hierarchy from a node config (per-GPU view: the paging
    /// simulator models one GPU's shard of the working set).
    pub fn from_system(sys: &SystemConfig) -> Self {
        TierModel {
            local: TierSpec {
                tier: Tier::LocalHbm,
                name: format!("{}/local", sys.name),
                capacity: sys.local_capacity,
                bandwidth: sys.local_bw,
            },
            remote: TierSpec {
                tier: Tier::RemotePool,
                name: format!("{}/pool", sys.name),
                capacity: if sys.remote_capacity.value() > 0.0 {
                    Some(sys.remote_capacity)
                } else {
                    None
                },
                bandwidth: sys.fabric_bw,
            },
        }
    }

    /// Override the local budget (the Table 4.3 sweep knob).
    pub fn with_local_budget(mut self, budget: Option<Bytes>) -> Self {
        self.local.capacity = budget;
        self
    }
}

/// Per-replica KV capacity pressure (coordinator wiring of the paging
/// subsystem; EXPERIMENTS.md §Capacity-Sweep).
///
/// A serving replica holds the KV cache of every active sequence. Under a
/// finite local budget the overflow spills to the remote tier; each
/// decode step must then stream the spilled fraction of the KV it touches
/// back over the fabric — an added serial stall on top of the modelled
/// step time (conservative: no overlap with compute is assumed for the
/// spilled fraction).
#[derive(Debug, Clone)]
pub struct KvPressure {
    /// Per-replica local KV budget (aggregate across the node's GPUs).
    pub budget: Bytes,
    remote_bw: Bandwidth,
    lat: FabricLatencies,
    shared_pool: bool,
    /// High-water mark of bytes spilled to the remote tier.
    pub spilled_peak: Bytes,
    /// Total stall charged to decode steps.
    pub stall_total: Seconds,
    /// Decode steps that paid a paging stall.
    pub steps_stalled: u64,
}

impl KvPressure {
    pub fn new(budget: Bytes, sys: &SystemConfig) -> Self {
        KvPressure {
            budget,
            remote_bw: sys.fabric_bw,
            lat: sys.latencies,
            shared_pool: sys.fabric == FabricKind::TabSharedMemory,
            spilled_peak: Bytes::ZERO,
            stall_total: Seconds::ZERO,
            steps_stalled: 0,
        }
    }

    /// Bytes currently spilled for a resident KV footprint of `total`.
    pub fn spilled(&self, total: Bytes) -> Bytes {
        if total > self.budget {
            total - self.budget
        } else {
            Bytes::ZERO
        }
    }

    /// Stall charged to one decode step that touches `touched` bytes of a
    /// `total`-byte resident KV footprint. The spilled fraction of the
    /// touched bytes streams from the remote tier (Eq 4.1 link
    /// efficiency), behind one fixed command latency.
    pub fn step_stall(&mut self, total: Bytes, touched: Bytes) -> Seconds {
        let spill = self.spilled(total);
        self.spilled_peak = self.spilled_peak.max(spill);
        if spill.value() <= 0.0 || total.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let frac = (spill / total).min(1.0);
        let remote_touched = touched * frac;
        let fixed = if self.shared_pool { self.lat.tab_read } else { self.lat.nvlink_read };
        let stall = fixed + mfu::transfer_time(remote_touched, self.remote_bw);
        self.stall_total += stall;
        self.steps_stalled += 1;
        stall
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm};

    #[test]
    fn tier_model_mirrors_system_config() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let t = TierModel::from_system(&sys);
        assert_eq!(t.local.tier, Tier::LocalHbm);
        assert!(t.local.capacity.is_none(), "FH4 local is uncapped");
        assert_eq!(t.local.bandwidth, sys.local_bw);
        assert_eq!(t.remote.tier, Tier::RemotePool);
        assert_eq!(t.remote.capacity, Some(sys.remote_capacity));
        assert_eq!(t.remote.bandwidth, sys.fabric_bw);
        let capped = t.with_local_budget(Some(Bytes::gb(12.0)));
        assert_eq!(capped.local.capacity, Some(Bytes::gb(12.0)));

        let b = TierModel::from_system(&baseline8());
        assert_eq!(b.local.capacity, baseline8().local_capacity);
        assert!(b.remote.capacity.is_none(), "shared-nothing has no pool");
    }

    #[test]
    fn kv_pressure_is_free_under_budget() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv = KvPressure::new(Bytes::gb(10.0), &sys);
        let s = kv.step_stall(Bytes::gb(8.0), Bytes::gb(8.0));
        assert_eq!(s, Seconds::ZERO);
        assert_eq!(kv.steps_stalled, 0);
        assert_eq!(kv.spilled_peak, Bytes::ZERO);
    }

    #[test]
    fn kv_pressure_charges_spilled_fraction() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv = KvPressure::new(Bytes::gb(10.0), &sys);
        // 40 GB resident, 10 GB budget → 75% spilled; touching all 40 GB
        // streams 30 GB from the pool: ≥ 30 GB / 4.8 TB/s = 6.25 ms.
        let s = kv.step_stall(Bytes::gb(40.0), Bytes::gb(40.0));
        assert!(s.as_ms() > 6.0, "stall {} ms", s.as_ms());
        assert!(s.as_ms() < 20.0, "stall {} ms", s.as_ms());
        assert_eq!(kv.steps_stalled, 1);
        assert_eq!(kv.spilled_peak, Bytes::gb(30.0));
        assert_eq!(kv.stall_total, s);
        // More spill → more stall.
        let s2 = kv.step_stall(Bytes::gb(80.0), Bytes::gb(80.0));
        assert!(s2 > s);
    }
}
