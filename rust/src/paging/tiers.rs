//! Memory-tier model: GPU-local HBM → FengHuang remote pool → optional
//! high-bandwidth flash, plus the per-replica KV capacity-pressure model
//! the cluster layer charges (DESIGN.md §Paging, §Tiering).
//!
//! Capacities and bandwidths are drawn from the node's [`SystemConfig`]
//! (which in turn comes from the `hardware` catalog presets): the local
//! tier is the GPU HBM (`local_bw`, `local_capacity`), the second tier is
//! the pool behind the TAB crossbar (`fabric_bw`, `remote_capacity`), and
//! the optional third tier is the flash envelope (`sys.flash`). The model
//! is an ordered hierarchy — [`TierModel::tiers`] sorts fastest first —
//! and a 2-tier model (no flash) behaves bit-identically to the original
//! fixed local/remote pair.

use crate::config::{FabricKind, SystemConfig};
use crate::fabric::FabricLatencies;
use crate::models::mfu;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Which tier a page lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Tier {
    /// GPU-local HBM (the paging cache on FengHuang nodes).
    LocalHbm,
    /// The FengHuang remote pool behind the TAB.
    RemotePool,
    /// High-bandwidth flash behind the pool (Ma & Patterson HBF).
    Flash,
}

/// One tier's capacity/bandwidth envelope.
#[derive(Debug, Clone)]
pub struct TierSpec {
    pub tier: Tier,
    pub name: String,
    /// `None` = uncapped (Table 4.1 "as much as needed").
    pub capacity: Option<Bytes>,
    pub bandwidth: Bandwidth,
}

/// The ordered memory hierarchy of a FengHuang node, fastest tier first.
/// Two tiers (HBM, pool) always exist; flash is present only when the
/// system config carries a [`crate::config::FlashConfig`].
#[derive(Debug, Clone)]
pub struct TierModel {
    pub tiers: Vec<TierSpec>,
}

impl TierModel {
    /// Derive the hierarchy from a node config (per-GPU view: the paging
    /// simulator models one GPU's shard of the working set).
    pub fn from_system(sys: &SystemConfig) -> Self {
        let mut tiers = vec![
            TierSpec {
                tier: Tier::LocalHbm,
                name: format!("{}/local", sys.name),
                capacity: sys.local_capacity,
                bandwidth: sys.local_bw,
            },
            TierSpec {
                tier: Tier::RemotePool,
                name: format!("{}/pool", sys.name),
                capacity: if sys.remote_capacity.value() > 0.0 {
                    Some(sys.remote_capacity)
                } else {
                    None
                },
                bandwidth: sys.fabric_bw,
            },
        ];
        if let Some(f) = sys.flash {
            tiers.push(TierSpec {
                tier: Tier::Flash,
                name: format!("{}/flash", sys.name),
                capacity: Some(f.capacity),
                bandwidth: f.bandwidth,
            });
        }
        TierModel { tiers }
    }

    /// The GPU-local HBM tier (always tier 0).
    pub fn local(&self) -> &TierSpec {
        &self.tiers[0]
    }

    /// The TAB pool tier (always tier 1).
    pub fn pool(&self) -> &TierSpec {
        &self.tiers[1]
    }

    /// The flash tier, when the hierarchy has one.
    pub fn flash(&self) -> Option<&TierSpec> {
        self.tiers.get(2)
    }

    /// Override the local budget (the Table 4.3 sweep knob).
    pub fn with_local_budget(mut self, budget: Option<Bytes>) -> Self {
        self.tiers[0].capacity = budget;
        self
    }
}

/// Per-replica KV capacity pressure (coordinator wiring of the paging
/// subsystem; EXPERIMENTS.md §Capacity-Sweep).
///
/// A serving replica holds the KV cache of every active sequence. Under a
/// finite local budget the overflow spills down the hierarchy in order —
/// HBM → pool → flash — and each decode step must then stream the spilled
/// fraction of the KV it touches back over the fabric, the slice past the
/// pool's capacity at flash bandwidth (an added serial stall on top of
/// the modelled step time; conservative: no overlap with compute is
/// assumed for the spilled fraction). Without a flash tier the pool is
/// uncapped, as in the original 2-tier model.
#[derive(Debug, Clone)]
pub struct KvPressure {
    /// Per-replica local KV budget (aggregate across the node's GPUs).
    pub budget: Bytes,
    remote_bw: Bandwidth,
    lat: FabricLatencies,
    shared_pool: bool,
    /// Pool capacity beyond which spill lands on flash. `None` = legacy
    /// 2-tier model (uncapped pool, no flash configured).
    pool_cap: Option<Bytes>,
    flash_bw: Bandwidth,
    /// High-water mark of bytes spilled out of local HBM.
    pub spilled_peak: Bytes,
    /// High-water mark of spill past the pool cap (flash-tier bytes).
    pub flash_spilled_peak: Bytes,
    /// Total stall charged to decode steps.
    pub stall_total: Seconds,
    /// Decode steps that paid a paging stall.
    pub steps_stalled: u64,
}

impl KvPressure {
    pub fn new(budget: Bytes, sys: &SystemConfig) -> Self {
        let (pool_cap, flash_bw) = match sys.flash {
            Some(f) => (Some(sys.remote_capacity), f.bandwidth),
            None => (None, sys.fabric_bw),
        };
        KvPressure {
            budget,
            remote_bw: sys.fabric_bw,
            lat: sys.latencies,
            shared_pool: sys.fabric == FabricKind::TabSharedMemory,
            pool_cap,
            flash_bw,
            spilled_peak: Bytes::ZERO,
            flash_spilled_peak: Bytes::ZERO,
            stall_total: Seconds::ZERO,
            steps_stalled: 0,
        }
    }

    /// Bytes currently spilled for a resident KV footprint of `total`.
    pub fn spilled(&self, total: Bytes) -> Bytes {
        if total > self.budget {
            total - self.budget
        } else {
            Bytes::ZERO
        }
    }

    /// The slice of spill past the pool's capacity — served from flash.
    /// Zero in the 2-tier model, where the pool is uncapped.
    pub fn flash_spilled(&self, total: Bytes) -> Bytes {
        let spill = self.spilled(total);
        match self.pool_cap {
            Some(cap) if spill > cap => spill - cap,
            _ => Bytes::ZERO,
        }
    }

    /// Stall charged to one decode step that touches `touched` bytes of a
    /// `total`-byte resident KV footprint. The spilled fraction of the
    /// touched bytes streams from the backing tiers (Eq 4.1 link
    /// efficiency) behind one fixed command latency — pool bytes at
    /// fabric bandwidth, the slice past the pool cap at flash bandwidth.
    pub fn step_stall(&mut self, total: Bytes, touched: Bytes) -> Seconds {
        let spill = self.spilled(total);
        self.spilled_peak = self.spilled_peak.max(spill);
        let flash_spill = self.flash_spilled(total);
        self.flash_spilled_peak = self.flash_spilled_peak.max(flash_spill);
        if spill.value() <= 0.0 || total.value() <= 0.0 {
            return Seconds::ZERO;
        }
        if touched.value() <= 0.0 {
            // Nothing streamed this step: no command is issued, so there
            // is no fixed latency either. (The earlier model charged a
            // phantom tab_read/nvlink_read here and bumped
            // steps_stalled even though zero bytes moved.)
            return Seconds::ZERO;
        }
        let frac = (spill / total).min(1.0);
        let frac_flash = (flash_spill / total).min(frac);
        let frac_pool = frac - frac_flash;
        let fixed = if self.shared_pool { self.lat.tab_read } else { self.lat.nvlink_read };
        let mut stall = fixed + mfu::transfer_time(touched * frac_pool, self.remote_bw);
        if flash_spill.value() > 0.0 {
            stall += mfu::transfer_time(touched * frac_flash, self.flash_bw);
        }
        self.stall_total += stall;
        self.steps_stalled += 1;
        stall
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm, FlashConfig};

    #[test]
    fn tier_model_mirrors_system_config() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let t = TierModel::from_system(&sys);
        assert_eq!(t.tiers.len(), 2, "no flash configured → 2 tiers");
        assert_eq!(t.local().tier, Tier::LocalHbm);
        assert!(t.local().capacity.is_none(), "FH4 local is uncapped");
        assert_eq!(t.local().bandwidth, sys.local_bw);
        assert_eq!(t.pool().tier, Tier::RemotePool);
        assert_eq!(t.pool().capacity, Some(sys.remote_capacity));
        assert_eq!(t.pool().bandwidth, sys.fabric_bw);
        assert!(t.flash().is_none());
        let capped = t.with_local_budget(Some(Bytes::gb(12.0)));
        assert_eq!(capped.local().capacity, Some(Bytes::gb(12.0)));

        let b = TierModel::from_system(&baseline8());
        assert_eq!(b.local().capacity, baseline8().local_capacity);
        assert!(b.pool().capacity.is_none(), "shared-nothing has no pool");
    }

    #[test]
    fn flash_tier_appears_ordered_below_the_pool() {
        let flash = FlashConfig { capacity: Bytes::gb(1024.0), bandwidth: Bandwidth::tbps(1.6) };
        let sys = fh4_15xm(Bandwidth::tbps(4.8)).with_flash(flash);
        let t = TierModel::from_system(&sys);
        assert_eq!(t.tiers.len(), 3);
        let f = t.flash().expect("flash tier present");
        assert_eq!(f.tier, Tier::Flash);
        assert_eq!(f.name, "FH4-1.5xM/flash");
        assert_eq!(f.capacity, Some(flash.capacity));
        assert_eq!(f.bandwidth, flash.bandwidth);
        // The hierarchy stays ordered fastest-first.
        assert!(t.local().bandwidth > t.pool().bandwidth);
        assert!(t.pool().bandwidth > f.bandwidth);
    }

    #[test]
    fn kv_pressure_is_free_under_budget() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv = KvPressure::new(Bytes::gb(10.0), &sys);
        let s = kv.step_stall(Bytes::gb(8.0), Bytes::gb(8.0));
        assert_eq!(s, Seconds::ZERO);
        assert_eq!(kv.steps_stalled, 0);
        assert_eq!(kv.spilled_peak, Bytes::ZERO);
    }

    #[test]
    fn kv_pressure_charges_spilled_fraction() {
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv = KvPressure::new(Bytes::gb(10.0), &sys);
        // 40 GB resident, 10 GB budget → 75% spilled; touching all 40 GB
        // streams 30 GB from the pool: ≥ 30 GB / 4.8 TB/s = 6.25 ms.
        let s = kv.step_stall(Bytes::gb(40.0), Bytes::gb(40.0));
        assert!(s.as_ms() > 6.0, "stall {} ms", s.as_ms());
        assert!(s.as_ms() < 20.0, "stall {} ms", s.as_ms());
        assert_eq!(kv.steps_stalled, 1);
        assert_eq!(kv.spilled_peak, Bytes::gb(30.0));
        assert_eq!(kv.stall_total, s);
        // More spill → more stall.
        let s2 = kv.step_stall(Bytes::gb(80.0), Bytes::gb(80.0));
        assert!(s2 > s);
    }

    #[test]
    fn zero_touch_steps_charge_nothing() {
        // Regression: a decode step under spill that touches zero KV
        // bytes used to pay the full command latency and count as a
        // stalled step.
        let sys = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv = KvPressure::new(Bytes::gb(10.0), &sys);
        let s = kv.step_stall(Bytes::gb(40.0), Bytes::ZERO);
        assert_eq!(s, Seconds::ZERO);
        assert_eq!(kv.steps_stalled, 0);
        assert_eq!(kv.stall_total, Seconds::ZERO);
        // The spill high-water mark still advances — the footprint is
        // real even when this step streamed nothing.
        assert_eq!(kv.spilled_peak, Bytes::gb(30.0));
        // A positive-touch stall on the same footprint is unchanged by
        // the zero-touch guard: bitwise equal to a fresh instance that
        // never saw the zero-touch step.
        let s_after = kv.step_stall(Bytes::gb(40.0), Bytes::gb(40.0));
        let mut fresh = KvPressure::new(Bytes::gb(10.0), &sys);
        let s_fresh = fresh.step_stall(Bytes::gb(40.0), Bytes::gb(40.0));
        assert_eq!(s_after, s_fresh);
        assert_eq!(kv.steps_stalled, 1);
    }

    #[test]
    fn flash_tier_serves_spill_past_the_pool_cap() {
        // Pool capped at 20 GB, flash below it at a quarter of the
        // fabric rate: spilling 40 GB puts 20 GB on the pool and 20 GB
        // on flash, which must stall more than an uncapped pool would.
        let mut slow = fh4_15xm(Bandwidth::tbps(4.8));
        slow.remote_capacity = Bytes::gb(20.0);
        slow.flash =
            Some(FlashConfig { capacity: Bytes::gb(1024.0), bandwidth: Bandwidth::tbps(1.2) });
        let mut kv = KvPressure::new(Bytes::gb(10.0), &slow);
        assert_eq!(kv.flash_spilled(Bytes::gb(50.0)), Bytes::gb(20.0));
        let s_flash = kv.step_stall(Bytes::gb(50.0), Bytes::gb(50.0));
        assert_eq!(kv.flash_spilled_peak, Bytes::gb(20.0));

        let plain = fh4_15xm(Bandwidth::tbps(4.8));
        let mut kv2 = KvPressure::new(Bytes::gb(10.0), &plain);
        let s_pool = kv2.step_stall(Bytes::gb(50.0), Bytes::gb(50.0));
        assert!(
            s_flash > s_pool,
            "flash-backed spill {} ms vs uncapped pool {} ms",
            s_flash.as_ms(),
            s_pool.as_ms()
        );

        // A flash tier running at exactly fabric bandwidth costs the
        // same stream time up to the Eq 4.1 ramp of splitting one
        // message into two (never cheaper, and within a fraction of a
        // percent at GB-scale transfers).
        let mut same = fh4_15xm(Bandwidth::tbps(4.8));
        same.remote_capacity = Bytes::gb(20.0);
        same.flash =
            Some(FlashConfig { capacity: Bytes::gb(1024.0), bandwidth: Bandwidth::tbps(4.8) });
        let mut kv3 = KvPressure::new(Bytes::gb(10.0), &same);
        let s_same = kv3.step_stall(Bytes::gb(50.0), Bytes::gb(50.0));
        assert!(s_same >= s_pool);
        assert!(
            (s_same.value() - s_pool.value()) / s_pool.value() < 1e-2,
            "equal-bandwidth flash split {} ms vs pool {} ms",
            s_same.as_ms(),
            s_pool.as_ms()
        );
    }
}
