//! Page-migration engine: batches page moves between tiers and charges
//! them through the Table 3.1 fabric latencies (DESIGN.md §Paging).
//!
//! Page-ins coalesce contiguous pages into large DMA batches — one TAB
//! read command per batch (Eq 3.1 fixed part) plus the Eq 4.1
//! size-dependent serialization of the whole payload. Write-backs of
//! dirty pages (evicted KV) pay the Eq 3.2 write path symmetrically.
//!
//! With a contention clock attached ([`MigrationEngine::with_contention`],
//! DESIGN.md §Fabric-Contention), every DMA batch is additionally booked
//! into the shared-fabric bandwidth ledger: the serialization term runs at
//! the *residual* bandwidth the ledger grants, and exhausted windows show
//! up as queueing delay. Without a clock, the arithmetic is untouched —
//! bit-identical to the pre-contention engine.

use crate::config::SystemConfig;
use crate::fabric::contention::{FabricClock, FabricReport};
use crate::fabric::FabricLatencies;
use crate::models::mfu;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Migration knobs.
#[derive(Debug, Clone, Copy)]
pub struct MigrationConfig {
    /// Pages coalesced into one DMA batch (one fixed command latency per
    /// batch). 64 × 2 MiB = 128 MiB batches by default.
    pub batch_pages: u64,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig { batch_pages: 64 }
    }
}

/// Cumulative migration counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrationStats {
    pub pages_in: u64,
    pub pages_out: u64,
    pub bytes_in: Bytes,
    pub bytes_out: Bytes,
    /// DMA batches issued (page-in and write-back).
    pub batches: u64,
    /// Paging-stream time spent on page-ins.
    pub time_in: Seconds,
    /// Paging-stream time spent on dirty-page write-backs.
    pub time_out: Seconds,
    /// Eviction events that required a write-back.
    pub writebacks: u64,
    /// Page-ins served from the flash tier (also counted in
    /// `pages_in`/`bytes_in`; their time folds into `time_in`).
    pub flash_pages_in: u64,
    pub flash_bytes_in: Bytes,
    /// Pool→flash home demotions (heat-band placement; time in
    /// `time_out`).
    pub demotions: u64,
    pub demoted_bytes: Bytes,
    /// Flash→pool promotions on re-touch (time in `time_in`).
    pub promotions: u64,
    pub promoted_bytes: Bytes,
}

/// Charges page moves over the remote fabric.
pub struct MigrationEngine {
    cfg: MigrationConfig,
    bw: Bandwidth,
    /// Media rate of the flash tier (= `bw` when no flash is
    /// configured; only the flash-path methods read it).
    flash_bw: Bandwidth,
    lat: FabricLatencies,
    pub stats: MigrationStats,
    /// Shared-fabric arbitration (None = unloaded charges, the
    /// pre-contention engine).
    clock: Option<FabricClock>,
    /// Fabric port this engine's DMA issues from.
    port: usize,
    /// The paging stream is serial: each booking starts where the last
    /// one completed.
    cursor: Seconds,
    /// Booking counter (home-module key in hashed per-module mode).
    seq: u64,
}

impl MigrationEngine {
    pub fn new(sys: &SystemConfig, cfg: MigrationConfig) -> Self {
        MigrationEngine {
            cfg,
            bw: sys.fabric_bw,
            flash_bw: sys.flash.map(|f| f.bandwidth).unwrap_or(sys.fabric_bw),
            lat: sys.latencies,
            stats: MigrationStats::default(),
            clock: None,
            port: 0,
            cursor: Seconds::ZERO,
            seq: 0,
        }
    }

    /// Attach a contention clock: every DMA batch (and NMC stream) this
    /// engine charges is booked into the shared-fabric ledger from `port`.
    pub fn with_contention(mut self, clock: FabricClock, port: usize) -> Self {
        self.clock = Some(clock);
        self.port = port;
        self
    }

    pub fn contended(&self) -> bool {
        self.clock.is_some()
    }

    /// Book `bytes` on the ledger at the paging stream's cursor and
    /// return the congestion-adjusted duration (queueing + serialization
    /// at the residual bandwidth). `None` without a clock (or with an
    /// inert Off-mode one) — callers fall back to the unloaded charge,
    /// keeping Off bit-identical.
    pub fn book_stream(&mut self, bytes: Bytes) -> Option<Seconds> {
        let clock = self.clock.as_mut()?;
        if clock.mode() == crate::fabric::contention::ContentionMode::Off {
            return None;
        }
        self.seq += 1;
        let b = clock.book(self.cursor, bytes, self.port, self.seq);
        let d = b.completion - self.cursor;
        self.cursor = b.completion;
        Some(d)
    }

    /// Record an overlapped in-pool stream (the NMC KV gather) on the
    /// ledger: the bytes load the fabric for arbitration purposes, but
    /// the stream runs under the compute pass, so nothing is charged and
    /// the serial DMA cursor does not advance. No-op when uncontended.
    pub fn book_overlapped(&mut self, bytes: Bytes) {
        let Some(clock) = self.clock.as_mut() else { return };
        if clock.mode() == crate::fabric::contention::ContentionMode::Off {
            return;
        }
        self.seq += 1;
        clock.book(self.cursor, bytes, self.port, self.seq);
    }

    /// Ledger observables, when contention is on.
    pub fn fabric_report(&self) -> Option<FabricReport> {
        self.clock.as_ref().map(|c| c.report())
    }

    fn batches(&self, pages: u64) -> u64 {
        if pages == 0 {
            0
        } else {
            let bp = self.cfg.batch_pages.max(1);
            (pages + bp - 1) / bp
        }
    }

    /// Charge a batched page-in of `bytes` spanning `pages` pages.
    pub fn page_in(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let stream = match self.book_stream(bytes) {
            Some(d) => d,
            None => mfu::transfer_time(bytes, self.bw),
        };
        let t = self.lat.tab_read * batches as f64 + stream;
        self.stats.pages_in += pages;
        self.stats.bytes_in += bytes;
        self.stats.batches += batches;
        self.stats.time_in += t;
        t
    }

    /// Charge a batched page-in of `bytes` whose home is the *flash*
    /// tier: the same command structure as [`Self::page_in`] (flash sits
    /// behind the same TAB ports), but serialization is capped by the
    /// flash media rate. Under contention the bytes are booked into the
    /// fabric ledger like any transfer, and the stream takes the slower
    /// of the booked completion and the unloaded flash serialization.
    pub fn page_in_flash(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let media = mfu::transfer_time(bytes, self.flash_bw);
        let stream = match self.book_stream(bytes) {
            Some(d) => d.max(media),
            None => media,
        };
        let t = self.lat.tab_read * batches as f64 + stream;
        self.stats.pages_in += pages;
        self.stats.bytes_in += bytes;
        self.stats.flash_pages_in += pages;
        self.stats.flash_bytes_in += bytes;
        self.stats.batches += batches;
        self.stats.time_in += t;
        t
    }

    /// Charge a pool→flash demotion of `bytes` spanning `pages`
    /// (heat-band placement writing a stable band down-tier): the write
    /// path's fixed command latency per batch, serialization at the
    /// flash media rate, booked through the contention ledger like any
    /// other transfer.
    pub fn demote(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let media = mfu::transfer_time(bytes, self.flash_bw);
        let stream = match self.book_stream(bytes) {
            Some(d) => d.max(media),
            None => media,
        };
        let t = self.lat.tab_write * batches as f64 + stream;
        self.stats.demotions += 1;
        self.stats.demoted_bytes += bytes;
        self.stats.batches += batches;
        self.stats.time_out += t;
        t
    }

    /// Charge a flash→pool promotion of `bytes` spanning `pages` (a
    /// re-touched band climbing back above the stable band): read from
    /// the flash media, write into the pool.
    pub fn promote(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let media = mfu::transfer_time(bytes, self.flash_bw);
        let stream = match self.book_stream(bytes) {
            Some(d) => d.max(media),
            None => media,
        };
        let t = self.lat.tab_write * batches as f64 + stream;
        self.stats.promotions += 1;
        self.stats.promoted_bytes += bytes;
        self.stats.batches += batches;
        self.stats.time_in += t;
        t
    }

    /// Charge a write-back of `bytes` of dirty pages spanning `pages`.
    pub fn write_back(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let stream = match self.book_stream(bytes) {
            Some(d) => d,
            None => mfu::transfer_time(bytes, self.bw),
        };
        let t = self.lat.tab_write * batches as f64 + stream;
        self.stats.pages_out += pages;
        self.stats.bytes_out += bytes;
        self.stats.batches += batches;
        self.stats.time_out += t;
        self.stats.writebacks += 1;
        t
    }

    /// Charge a write-back of `bytes` of dirty pages whose home is the
    /// flash tier: the write command path, serialization capped by the
    /// flash media rate.
    pub fn write_back_flash(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let media = mfu::transfer_time(bytes, self.flash_bw);
        let stream = match self.book_stream(bytes) {
            Some(d) => d.max(media),
            None => media,
        };
        let t = self.lat.tab_write * batches as f64 + stream;
        self.stats.pages_out += pages;
        self.stats.bytes_out += bytes;
        self.stats.batches += batches;
        self.stats.time_out += t;
        self.stats.writebacks += 1;
        t
    }

    /// Total paging-stream busy time charged so far.
    pub fn busy(&self) -> Seconds {
        self.stats.time_in + self.stats.time_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::units::Bandwidth;

    fn engine() -> MigrationEngine {
        MigrationEngine::new(
            &fh4_15xm(Bandwidth::tbps(4.0)),
            MigrationConfig { batch_pages: 64 },
        )
    }

    #[test]
    fn page_in_charges_fixed_latency_per_batch() {
        let mut m = engine();
        // 65 pages → 2 batches → 2 × 220 ns of fixed read latency.
        let t = m.page_in(Bytes::mib(130.0), 65);
        let floor = 2.0 * 220.0; // ns
        assert!(t.as_ns() > floor, "t {} ns", t.as_ns());
        assert_eq!(m.stats.batches, 2);
        assert_eq!(m.stats.pages_in, 65);
        // Bulk transfer dominates: 130 MiB / 4 TB/s ≈ 34 µs plus eff loss.
        assert!(t.as_us() > 30.0 && t.as_us() < 60.0, "t {} µs", t.as_us());
    }

    #[test]
    fn empty_moves_are_free() {
        let mut m = engine();
        assert_eq!(m.page_in(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(m.write_back(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(m.stats.batches, 0);
        assert_eq!(m.busy(), Seconds::ZERO);
    }

    #[test]
    fn write_back_uses_write_path_and_counts() {
        let mut m = engine();
        let t = m.page_in(Bytes::mib(2.0), 1);
        let w = m.write_back(Bytes::mib(2.0), 1);
        // Same payload: the write path's fixed latency (90 ns) is smaller
        // than the read path's (220 ns).
        assert!(w < t, "write {} vs read {}", w.as_ns(), t.as_ns());
        assert_eq!(m.stats.writebacks, 1);
        assert_eq!(m.stats.pages_out, 1);
        assert_eq!(m.busy(), t + w);
    }

    #[test]
    fn off_clock_and_no_clock_are_bit_identical() {
        use crate::fabric::contention::{ContentionConfig, FabricClock};
        let sys = fh4_15xm(Bandwidth::tbps(4.0));
        let mut plain = MigrationEngine::new(&sys, MigrationConfig::default());
        let clock =
            FabricClock::for_system(&sys, ContentionConfig::default().resolved(1)).unwrap();
        let mut off = MigrationEngine::new(&sys, MigrationConfig::default())
            .with_contention(clock, 0);
        for (mib, pages) in [(130.0, 65), (2.0, 1), (512.0, 256)] {
            assert_eq!(plain.page_in(Bytes::mib(mib), pages), off.page_in(Bytes::mib(mib), pages));
            assert_eq!(
                plain.write_back(Bytes::mib(mib), pages),
                off.write_back(Bytes::mib(mib), pages)
            );
        }
        assert_eq!(plain.busy(), off.busy());
        assert!(off.contended() && !plain.contended());
    }

    #[test]
    fn contended_dma_pays_queueing_once_windows_fill() {
        use crate::fabric::contention::{ContentionConfig, ContentionMode, FabricClock};
        let sys = fh4_15xm(Bandwidth::tbps(4.0));
        let cfg = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() }
            .resolved(1);
        let clock = FabricClock::for_system(&sys, cfg).unwrap();
        let mut contended =
            MigrationEngine::new(&sys, MigrationConfig::default()).with_contention(clock, 0);
        let mut plain = MigrationEngine::new(&sys, MigrationConfig::default());
        // A serial stream of large DMAs: the single port's window budget
        // caps each batch, so the contended engine can never be faster,
        // and its ledger sees every byte.
        let mut t_c = Seconds::ZERO;
        let mut t_p = Seconds::ZERO;
        for _ in 0..4 {
            t_c += contended.page_in(Bytes::gib(1.0), 512);
            t_p += plain.page_in(Bytes::gib(1.0), 512);
        }
        assert!(t_c >= t_p - Seconds::ns(1.0), "contended {t_c:?} vs plain {t_p:?}");
        let fr = contended.fabric_report().expect("ledger attached");
        assert_eq!(fr.transfers, 4);
        assert!((fr.bytes.value() - 4.0 * Bytes::gib(1.0).value()).abs() < 1.0);
        assert!(plain.fabric_report().is_none());
    }

    #[test]
    fn overlapped_streams_load_the_ledger_without_a_time_charge() {
        use crate::fabric::contention::{ContentionConfig, ContentionMode, FabricClock};
        let sys = fh4_15xm(Bandwidth::tbps(4.0));
        let cfg = ContentionConfig { mode: ContentionMode::Shared, ..Default::default() }
            .resolved(1);
        let clock = FabricClock::for_system(&sys, cfg).unwrap();
        let mut m =
            MigrationEngine::new(&sys, MigrationConfig::default()).with_contention(clock, 0);
        m.book_overlapped(Bytes::gib(1.0));
        let fr = m.fabric_report().unwrap();
        assert_eq!(fr.transfers, 1, "overlapped bytes must appear as fabric load");
        assert!((fr.bytes.value() - Bytes::gib(1.0).value()).abs() < 1.0);
        assert_eq!(m.busy(), Seconds::ZERO, "no paging-stream time is charged");
        // Without a clock the call is a no-op.
        let mut plain = MigrationEngine::new(&sys, MigrationConfig::default());
        plain.book_overlapped(Bytes::gib(1.0));
        assert!(plain.fabric_report().is_none());
        assert_eq!(plain.busy(), Seconds::ZERO);
    }

    #[test]
    fn flash_paths_serialize_at_the_media_rate() {
        use crate::config::FlashConfig;
        // Without a flash tier the flash paths degrade to fabric rate:
        // bitwise the same charge as the pool read path.
        let mut m = engine();
        let pool_t = m.page_in(Bytes::mib(512.0), 256);
        let flash_t = m.page_in_flash(Bytes::mib(512.0), 256);
        assert_eq!(pool_t, flash_t);
        assert_eq!(m.stats.flash_pages_in, 256);
        assert_eq!(m.stats.pages_in, 512, "flash page-ins count in the total");

        // A 1 TB/s flash tier under a 4 TB/s fabric: ~4× the stream time.
        let mut sys = fh4_15xm(Bandwidth::tbps(4.0));
        sys.flash =
            Some(FlashConfig { capacity: Bytes::gb(1024.0), bandwidth: Bandwidth::tbps(1.0) });
        let mut f = MigrationEngine::new(&sys, MigrationConfig::default());
        let slow = f.page_in_flash(Bytes::mib(512.0), 256);
        assert!(
            slow > flash_t * 3.0 && slow < flash_t * 5.0,
            "flash {} µs vs fabric {} µs",
            slow.as_us(),
            flash_t.as_us()
        );
        // The pool path of the same engine is untouched by the flash bw.
        assert_eq!(f.page_in(Bytes::mib(512.0), 256), pool_t);

        // Demotion and promotion ride the write path (90 ns fixed vs
        // 220 ns) with the same media-rate serialization.
        let d = f.demote(Bytes::mib(512.0), 256);
        let p = f.promote(Bytes::mib(512.0), 256);
        assert_eq!(d, p);
        assert!(d < slow, "write fixed path below read fixed path");
        assert_eq!(f.stats.demotions, 1);
        assert_eq!(f.stats.demoted_bytes, Bytes::mib(512.0));
        assert_eq!(f.stats.promotions, 1);
        assert_eq!(f.stats.promoted_bytes, Bytes::mib(512.0));
        // Empty moves stay free on every path.
        assert_eq!(f.page_in_flash(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(f.demote(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(f.promote(Bytes::ZERO, 0), Seconds::ZERO);
    }

    #[test]
    fn batching_amortises_fixed_latency() {
        // Moving 256 pages as one call beats 256 single-page calls.
        let mut batched = engine();
        let t1 = batched.page_in(Bytes::mib(512.0), 256);
        let mut unbatched = engine();
        let mut t2 = Seconds::ZERO;
        for _ in 0..256 {
            t2 += unbatched.page_in(Bytes::mib(2.0), 1);
        }
        assert!(t1 < t2, "batched {} vs unbatched {}", t1.as_us(), t2.as_us());
    }
}
