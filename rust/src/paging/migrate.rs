//! Page-migration engine: batches page moves between tiers and charges
//! them through the Table 3.1 fabric latencies (DESIGN.md §Paging).
//!
//! Page-ins coalesce contiguous pages into large DMA batches — one TAB
//! read command per batch (Eq 3.1 fixed part) plus the Eq 4.1
//! size-dependent serialization of the whole payload. Write-backs of
//! dirty pages (evicted KV) pay the Eq 3.2 write path symmetrically.

use crate::config::SystemConfig;
use crate::fabric::FabricLatencies;
use crate::models::mfu;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Migration knobs.
#[derive(Debug, Clone, Copy)]
pub struct MigrationConfig {
    /// Pages coalesced into one DMA batch (one fixed command latency per
    /// batch). 64 × 2 MiB = 128 MiB batches by default.
    pub batch_pages: u64,
}

impl Default for MigrationConfig {
    fn default() -> Self {
        MigrationConfig { batch_pages: 64 }
    }
}

/// Cumulative migration counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct MigrationStats {
    pub pages_in: u64,
    pub pages_out: u64,
    pub bytes_in: Bytes,
    pub bytes_out: Bytes,
    /// DMA batches issued (page-in and write-back).
    pub batches: u64,
    /// Paging-stream time spent on page-ins.
    pub time_in: Seconds,
    /// Paging-stream time spent on dirty-page write-backs.
    pub time_out: Seconds,
    /// Eviction events that required a write-back.
    pub writebacks: u64,
}

/// Charges page moves over the remote fabric.
#[derive(Debug, Clone)]
pub struct MigrationEngine {
    cfg: MigrationConfig,
    bw: Bandwidth,
    lat: FabricLatencies,
    pub stats: MigrationStats,
}

impl MigrationEngine {
    pub fn new(sys: &SystemConfig, cfg: MigrationConfig) -> Self {
        MigrationEngine {
            cfg,
            bw: sys.fabric_bw,
            lat: sys.latencies,
            stats: MigrationStats::default(),
        }
    }

    fn batches(&self, pages: u64) -> u64 {
        if pages == 0 {
            0
        } else {
            let bp = self.cfg.batch_pages.max(1);
            (pages + bp - 1) / bp
        }
    }

    /// Charge a batched page-in of `bytes` spanning `pages` pages.
    pub fn page_in(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let t = self.lat.tab_read * batches as f64 + mfu::transfer_time(bytes, self.bw);
        self.stats.pages_in += pages;
        self.stats.bytes_in += bytes;
        self.stats.batches += batches;
        self.stats.time_in += t;
        t
    }

    /// Charge a write-back of `bytes` of dirty pages spanning `pages`.
    pub fn write_back(&mut self, bytes: Bytes, pages: u64) -> Seconds {
        if pages == 0 || bytes.value() <= 0.0 {
            return Seconds::ZERO;
        }
        let batches = self.batches(pages);
        let t = self.lat.tab_write * batches as f64 + mfu::transfer_time(bytes, self.bw);
        self.stats.pages_out += pages;
        self.stats.bytes_out += bytes;
        self.stats.batches += batches;
        self.stats.time_out += t;
        self.stats.writebacks += 1;
        t
    }

    /// Total paging-stream busy time charged so far.
    pub fn busy(&self) -> Seconds {
        self.stats.time_in + self.stats.time_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::fh4_15xm;
    use crate::units::Bandwidth;

    fn engine() -> MigrationEngine {
        MigrationEngine::new(
            &fh4_15xm(Bandwidth::tbps(4.0)),
            MigrationConfig { batch_pages: 64 },
        )
    }

    #[test]
    fn page_in_charges_fixed_latency_per_batch() {
        let mut m = engine();
        // 65 pages → 2 batches → 2 × 220 ns of fixed read latency.
        let t = m.page_in(Bytes::mib(130.0), 65);
        let floor = 2.0 * 220.0; // ns
        assert!(t.as_ns() > floor, "t {} ns", t.as_ns());
        assert_eq!(m.stats.batches, 2);
        assert_eq!(m.stats.pages_in, 65);
        // Bulk transfer dominates: 130 MiB / 4 TB/s ≈ 34 µs plus eff loss.
        assert!(t.as_us() > 30.0 && t.as_us() < 60.0, "t {} µs", t.as_us());
    }

    #[test]
    fn empty_moves_are_free() {
        let mut m = engine();
        assert_eq!(m.page_in(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(m.write_back(Bytes::ZERO, 0), Seconds::ZERO);
        assert_eq!(m.stats.batches, 0);
        assert_eq!(m.busy(), Seconds::ZERO);
    }

    #[test]
    fn write_back_uses_write_path_and_counts() {
        let mut m = engine();
        let t = m.page_in(Bytes::mib(2.0), 1);
        let w = m.write_back(Bytes::mib(2.0), 1);
        // Same payload: the write path's fixed latency (90 ns) is smaller
        // than the read path's (220 ns).
        assert!(w < t, "write {} vs read {}", w.as_ns(), t.as_ns());
        assert_eq!(m.stats.writebacks, 1);
        assert_eq!(m.stats.pages_out, 1);
        assert_eq!(m.busy(), t + w);
    }

    #[test]
    fn batching_amortises_fixed_latency() {
        // Moving 256 pages as one call beats 256 single-page calls.
        let mut batched = engine();
        let t1 = batched.page_in(Bytes::mib(512.0), 256);
        let mut unbatched = engine();
        let mut t2 = Seconds::ZERO;
        for _ in 0..256 {
            t2 += unbatched.page_in(Bytes::mib(2.0), 1);
        }
        assert!(t1 < t2, "batched {} vs unbatched {}", t1.as_us(), t2.as_us());
    }
}
