//! Page table: tensor ranges → fixed-size pages with per-page residency
//! (DESIGN.md §Paging).
//!
//! The orchestrator reasons about memory at *page* granularity: every
//! tensor the trace touches is split into fixed-size pages (default
//! 2 MiB — large enough to amortise the Table 3.1 command latencies,
//! small enough that partial working sets page independently). Each page
//! carries its residency tier, a dirty bit (remote copy stale; eviction
//! must write back), and access metadata (heat / last use) that the
//! eviction policies in [`super::policy`] consume.

use super::tiers::Tier;
use crate::trace::TensorId;
use crate::units::Bytes;
use std::collections::HashMap;

/// Default page size: 2 MiB.
pub const DEFAULT_PAGE_BYTES: Bytes = Bytes(2.0 * 1024.0 * 1024.0);

/// Residency state of one page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// Only the remote-pool copy exists.
    Remote,
    /// Staged in GPU-local memory (the remote copy remains authoritative
    /// unless the page is dirty).
    Local,
}

/// Per-page state.
#[derive(Debug, Clone, Copy)]
pub struct PageState {
    pub residency: Residency,
    /// Local copy modified (KV appends); eviction must write back.
    pub dirty: bool,
    pub bytes: Bytes,
}

/// All pages of one registered tensor, plus tensor-level access metadata
/// (every op touches a tensor's pages together, so heat/recency are
/// tracked once per tensor).
#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub pages: Vec<PageState>,
    pub bytes: Bytes,
    pub pinned: bool,
    /// Monotone access counter value at last touch.
    pub last_use: u64,
    /// Number of touches since registration.
    pub heat: u64,
    /// Backing tier holding the tensor's authoritative copy (DESIGN.md
    /// §Tiering). Pool by default; heat-band placement demotes stable
    /// bands to [`Tier::Flash`] and promotes them back on re-touch.
    /// [`Tier::LocalHbm`] marks tensors permanently resident because no
    /// backing tier had room. [`Residency::Remote`] pages live at this
    /// tier; in the 2-tier model the field never leaves `RemotePool`.
    pub home: Tier,
}

impl TensorEntry {
    pub fn resident_bytes(&self) -> Bytes {
        self.pages
            .iter()
            .filter(|p| p.residency == Residency::Local)
            .map(|p| p.bytes)
            .sum()
    }

    pub fn resident_pages(&self) -> u64 {
        self.pages.iter().filter(|p| p.residency == Residency::Local).count() as u64
    }
}

/// Result of an eviction: what left local memory.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Evicted {
    pub bytes: Bytes,
    pub dirty_bytes: Bytes,
    pub pages: u64,
}

/// The page table: tensor → pages, with aggregate residency accounting.
#[derive(Debug)]
pub struct PageTable {
    page_bytes: Bytes,
    tensors: HashMap<TensorId, TensorEntry>,
    resident: Bytes,
    peak_resident: Bytes,
    // Per-tier homed-byte ledgers, maintained incrementally so reads are
    // O(1) *and* deterministic — recomputing by HashMap iteration would
    // sum f64s in a per-process random order.
    homed_local: Bytes,
    homed_pool: Bytes,
    homed_flash: Bytes,
}

impl PageTable {
    pub fn new(page_bytes: Bytes) -> Self {
        assert!(page_bytes.value() > 0.0, "page size must be positive");
        PageTable {
            page_bytes,
            tensors: HashMap::new(),
            resident: Bytes::ZERO,
            peak_resident: Bytes::ZERO,
            homed_local: Bytes::ZERO,
            homed_pool: Bytes::ZERO,
            homed_flash: Bytes::ZERO,
        }
    }

    fn homed_counter(&mut self, tier: Tier) -> &mut Bytes {
        match tier {
            Tier::LocalHbm => &mut self.homed_local,
            Tier::RemotePool => &mut self.homed_pool,
            Tier::Flash => &mut self.homed_flash,
        }
    }

    pub fn page_bytes(&self) -> Bytes {
        self.page_bytes
    }

    /// Number of pages a tensor of `bytes` occupies at this page size.
    pub fn pages_for(&self, bytes: Bytes) -> u64 {
        (bytes.value() / self.page_bytes.value()).ceil().max(0.0) as u64
    }

    /// Register (or grow — KV tensors grow with context) a tensor. New
    /// pages start [`Residency::Remote`]. Shrinking is not supported;
    /// re-registering with fewer bytes is a no-op.
    pub fn register(&mut self, id: TensorId, bytes: Bytes) {
        let page = self.page_bytes;
        let mut resident_delta = Bytes::ZERO;
        let entry = self.tensors.entry(id).or_insert(TensorEntry {
            pages: Vec::new(),
            bytes: Bytes::ZERO,
            pinned: false,
            last_use: 0,
            heat: 0,
            home: Tier::RemotePool,
        });
        if bytes <= entry.bytes {
            return;
        }
        let bytes_before = entry.bytes;
        let want_pages = (bytes.value() / page.value()).ceil() as usize;
        // Re-size the (previously last, possibly partial) page up to full.
        if let Some(last) = entry.pages.last_mut() {
            if last.bytes < page {
                let grow = (page - last.bytes).min(bytes - entry.bytes);
                // Growing a resident page keeps it resident and counts the
                // grown bytes toward residency.
                if last.residency == Residency::Local {
                    resident_delta += grow;
                }
                last.bytes += grow;
            }
        }
        let covered: Bytes = entry.pages.iter().map(|p| p.bytes).sum();
        let mut remaining = bytes - covered;
        while entry.pages.len() < want_pages && remaining.value() > 0.0 {
            let b = remaining.min(page);
            entry.pages.push(PageState { residency: Residency::Remote, dirty: false, bytes: b });
            remaining = remaining - b;
        }
        entry.bytes = entry.pages.iter().map(|p| p.bytes).sum();
        let grown = entry.bytes - bytes_before;
        let home = entry.home;
        *self.homed_counter(home) += grown;
        self.resident += resident_delta;
        self.peak_resident = self.peak_resident.max(self.resident);
    }

    pub fn contains(&self, id: TensorId) -> bool {
        self.tensors.contains_key(&id)
    }

    pub fn entry(&self, id: TensorId) -> Option<&TensorEntry> {
        self.tensors.get(&id)
    }

    /// Bytes of `id` not currently staged locally.
    pub fn missing_bytes(&self, id: TensorId) -> Bytes {
        match self.tensors.get(&id) {
            Some(e) => e.bytes - e.resident_bytes(),
            None => Bytes::ZERO,
        }
    }

    /// Pages of `id` not currently staged locally.
    pub fn missing_pages(&self, id: TensorId) -> u64 {
        match self.tensors.get(&id) {
            Some(e) => {
                e.pages.iter().filter(|p| p.residency == Residency::Remote).count() as u64
            }
            None => 0,
        }
    }

    /// Stage every page of `id` locally; returns (bytes, pages) actually
    /// moved (already-resident pages move nothing). Marks the access.
    pub fn page_in(&mut self, id: TensorId, now: u64, dirty: bool) -> (Bytes, u64) {
        let Some(e) = self.tensors.get_mut(&id) else {
            return (Bytes::ZERO, 0);
        };
        let mut moved = Bytes::ZERO;
        let mut pages = 0u64;
        for p in e.pages.iter_mut() {
            if p.residency == Residency::Remote {
                p.residency = Residency::Local;
                moved += p.bytes;
                pages += 1;
            }
            if dirty {
                p.dirty = true;
            }
        }
        e.last_use = now;
        e.heat += 1;
        self.resident += moved;
        self.peak_resident = self.peak_resident.max(self.resident);
        (moved, pages)
    }

    /// Backing tier of `id`'s authoritative copy.
    pub fn home(&self, id: TensorId) -> Option<Tier> {
        self.tensors.get(&id).map(|e| e.home)
    }

    /// Re-home `id`'s authoritative copy on `tier` (demotion/promotion).
    /// Returns the tensor's bytes — the payload the migration engine
    /// charges for the move — or [`Bytes::ZERO`] when nothing changed
    /// (unknown tensor, or already homed there).
    pub fn set_home(&mut self, id: TensorId, tier: Tier) -> Bytes {
        let Some(e) = self.tensors.get_mut(&id) else {
            return Bytes::ZERO;
        };
        if e.home == tier {
            return Bytes::ZERO;
        }
        let (from, bytes) = (e.home, e.bytes);
        e.home = tier;
        let c = self.homed_counter(from);
        *c = *c - bytes;
        *self.homed_counter(tier) += bytes;
        bytes
    }

    /// Registered bytes whose authoritative copy lives on `tier` (O(1),
    /// maintained incrementally — deterministic across runs).
    pub fn bytes_homed(&self, tier: Tier) -> Bytes {
        match tier {
            Tier::LocalHbm => self.homed_local,
            Tier::RemotePool => self.homed_pool,
            Tier::Flash => self.homed_flash,
        }
    }

    /// Record an access without moving pages.
    pub fn touch(&mut self, id: TensorId, now: u64) {
        if let Some(e) = self.tensors.get_mut(&id) {
            e.last_use = now;
            e.heat += 1;
        }
    }

    /// Pin `id`: its pages may never be selected for eviction. Returns the
    /// tensor's size (pinned budget accounting).
    pub fn pin(&mut self, id: TensorId) -> Bytes {
        match self.tensors.get_mut(&id) {
            Some(e) => {
                e.pinned = true;
                e.bytes
            }
            None => Bytes::ZERO,
        }
    }

    /// Drop every local page of `id` (no-op on pinned tensors).
    pub fn evict(&mut self, id: TensorId) -> Evicted {
        let Some(e) = self.tensors.get_mut(&id) else {
            return Evicted::default();
        };
        if e.pinned {
            return Evicted::default();
        }
        let mut out = Evicted::default();
        for p in e.pages.iter_mut() {
            if p.residency == Residency::Local {
                out.bytes += p.bytes;
                out.pages += 1;
                if p.dirty {
                    out.dirty_bytes += p.bytes;
                    p.dirty = false;
                }
                p.residency = Residency::Remote;
            }
        }
        self.resident = self.resident - out.bytes;
        out
    }

    /// Evict *and unregister* `id`: drop its local pages and forget the
    /// tensor entirely (the shared prefix cache recycles extent slots
    /// this way — a plain [`Self::evict`] would leave dead entries
    /// accumulating in victim scans). No-op on pinned tensors.
    pub fn remove(&mut self, id: TensorId) -> Evicted {
        if self.tensors.get(&id).is_some_and(|e| e.pinned) {
            return Evicted::default();
        }
        let out = self.evict(id);
        if let Some(e) = self.tensors.remove(&id) {
            let c = self.homed_counter(e.home);
            *c = *c - e.bytes;
        }
        out
    }

    /// Iterate all tensors (policy victim scans).
    pub fn iter(&self) -> impl Iterator<Item = (&TensorId, &TensorEntry)> {
        self.tensors.iter()
    }

    pub fn resident_bytes(&self) -> Bytes {
        self.resident
    }

    pub fn peak_resident(&self) -> Bytes {
        self.peak_resident
    }

    /// Total bytes registered (the remote working set).
    pub fn registered_bytes(&self) -> Bytes {
        self.tensors.values().map(|e| e.bytes).sum()
    }

    pub fn num_tensors(&self) -> usize {
        self.tensors.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b(v: f64) -> Bytes {
        Bytes::new(v)
    }

    #[test]
    fn register_splits_into_pages_with_partial_tail() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(1), b(250.0));
        let e = t.entry(TensorId(1)).unwrap();
        assert_eq!(e.pages.len(), 3);
        assert_eq!(e.pages[0].bytes, b(100.0));
        assert_eq!(e.pages[2].bytes, b(50.0));
        assert_eq!(t.missing_bytes(TensorId(1)), b(250.0));
        assert_eq!(t.missing_pages(TensorId(1)), 3);
        assert_eq!(t.registered_bytes(), b(250.0));
    }

    #[test]
    fn page_in_moves_only_missing_pages() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(1), b(250.0));
        let (moved, pages) = t.page_in(TensorId(1), 1, false);
        assert_eq!(moved, b(250.0));
        assert_eq!(pages, 3);
        assert_eq!(t.resident_bytes(), b(250.0));
        // Second page-in is a pure cache hit.
        let (moved, pages) = t.page_in(TensorId(1), 2, false);
        assert_eq!(moved, Bytes::ZERO);
        assert_eq!(pages, 0);
        let e = t.entry(TensorId(1)).unwrap();
        assert_eq!(e.heat, 2);
        assert_eq!(e.last_use, 2);
    }

    #[test]
    fn evict_returns_dirty_bytes_and_frees_residency() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(7), b(150.0));
        t.page_in(TensorId(7), 1, true);
        let ev = t.evict(TensorId(7));
        assert_eq!(ev.bytes, b(150.0));
        assert_eq!(ev.dirty_bytes, b(150.0));
        assert_eq!(ev.pages, 2);
        assert_eq!(t.resident_bytes(), Bytes::ZERO);
        // Pages are clean after writeback; re-evicting is a no-op.
        assert_eq!(t.evict(TensorId(7)), Evicted::default());
        assert_eq!(t.peak_resident(), b(150.0));
    }

    #[test]
    fn pinned_tensors_refuse_eviction() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(3), b(100.0));
        assert_eq!(t.pin(TensorId(3)), b(100.0));
        t.page_in(TensorId(3), 1, false);
        assert_eq!(t.evict(TensorId(3)), Evicted::default());
        assert_eq!(t.resident_bytes(), b(100.0));
    }

    #[test]
    fn kv_growth_appends_pages_and_preserves_residency() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(9), b(120.0)); // pages: 100, 20
        t.page_in(TensorId(9), 1, true);
        assert_eq!(t.resident_bytes(), b(120.0));
        // Context grows: 120 → 260 bytes. The partial page fills to 100,
        // then a new 60-byte page appends (remote until next access).
        t.register(TensorId(9), b(260.0));
        let e = t.entry(TensorId(9)).unwrap();
        assert_eq!(e.bytes, b(260.0));
        assert_eq!(e.pages.len(), 3);
        // The grown part of the already-resident page counts as resident.
        assert_eq!(t.resident_bytes(), b(200.0));
        assert_eq!(t.missing_bytes(TensorId(9)), b(60.0));
    }

    #[test]
    fn remove_unregisters_and_frees_residency() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(4), b(250.0));
        t.page_in(TensorId(4), 1, true);
        let ev = t.remove(TensorId(4));
        assert_eq!(ev.bytes, b(250.0));
        assert_eq!(ev.dirty_bytes, b(250.0));
        assert!(!t.contains(TensorId(4)), "entry is gone, not just evicted");
        assert_eq!(t.resident_bytes(), Bytes::ZERO);
        assert_eq!(t.registered_bytes(), Bytes::ZERO);
        // Re-registering the same id starts from scratch.
        t.register(TensorId(4), b(50.0));
        assert_eq!(t.registered_bytes(), b(50.0));
        assert_eq!(t.missing_bytes(TensorId(4)), b(50.0));
        // Pinned tensors survive removal attempts.
        t.pin(TensorId(4));
        t.page_in(TensorId(4), 2, false);
        assert_eq!(t.remove(TensorId(4)), Evicted::default());
        assert!(t.contains(TensorId(4)));
        assert_eq!(t.resident_bytes(), b(50.0));
    }

    #[test]
    fn home_ledger_tracks_moves_growth_and_removal() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(1), b(250.0));
        t.register(TensorId(2), b(100.0));
        assert_eq!(t.home(TensorId(1)), Some(Tier::RemotePool), "pool by default");
        assert_eq!(t.bytes_homed(Tier::RemotePool), b(350.0));
        assert_eq!(t.bytes_homed(Tier::Flash), Bytes::ZERO);

        // Demotion moves the ledger and returns the payload.
        assert_eq!(t.set_home(TensorId(1), Tier::Flash), b(250.0));
        assert_eq!(t.bytes_homed(Tier::RemotePool), b(100.0));
        assert_eq!(t.bytes_homed(Tier::Flash), b(250.0));
        // Re-homing to the same tier is free — no phantom transfer.
        assert_eq!(t.set_home(TensorId(1), Tier::Flash), Bytes::ZERO);
        // Unknown tensors move nothing.
        assert_eq!(t.set_home(TensorId(99), Tier::Flash), Bytes::ZERO);

        // KV-style growth lands on the tensor's current home.
        t.register(TensorId(1), b(400.0));
        assert_eq!(t.bytes_homed(Tier::Flash), b(400.0));

        // Promotion back, then removal releases the ledger.
        assert_eq!(t.set_home(TensorId(1), Tier::RemotePool), b(400.0));
        t.remove(TensorId(1));
        assert_eq!(t.bytes_homed(Tier::RemotePool), b(100.0));

        // Local homing (no backing tier had room).
        t.set_home(TensorId(2), Tier::LocalHbm);
        assert_eq!(t.bytes_homed(Tier::LocalHbm), b(100.0));
        assert_eq!(t.bytes_homed(Tier::RemotePool), Bytes::ZERO);
    }

    #[test]
    fn shrinking_reregistration_is_noop() {
        let mut t = PageTable::new(b(100.0));
        t.register(TensorId(2), b(300.0));
        t.register(TensorId(2), b(100.0));
        assert_eq!(t.entry(TensorId(2)).unwrap().bytes, b(300.0));
    }
}
