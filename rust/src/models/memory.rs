//! Parameter-count and memory-footprint arithmetic (→ Fig 2.1, Fig 2.4).
//!
//! All quantities derive from [`ModelArch`]; cross-checked against the
//! published totals (175B / 314B / 235B / 671B) in the unit tests.

use super::arch::{Attention, FeedForward, ModelArch};
use crate::units::Bytes;

/// Attention parameters in one layer (Q, K, V, O projections).
///
/// MLA is approximated as: joint KV down-projection (hidden → rank+rope),
/// K/V up-projections (rank → q_dim each), plus full Q and O projections.
/// This slightly over-counts DeepSeek-V3's low-rank Q path (~0.5% of total).
pub fn attn_params_per_layer(m: &ModelArch) -> u64 {
    let h = m.hidden;
    let q = m.q_dim();
    match m.attention {
        Attention::Mha | Attention::Gqa { .. } => {
            let kv = m.kv_dim();
            h * q + 2 * h * kv + q * h
        }
        Attention::Mla { kv_lora_rank, rope_head_dim } => {
            let rank = kv_lora_rank as u64;
            let down = h * (rank + rope_head_dim as u64);
            let up = 2 * rank * q;
            h * q + down + up + q * h
        }
    }
}

/// Dense-FFN parameters for the given intermediate size.
fn dense_ffn_params(hidden: u64, intermediate: u64, gated: bool) -> u64 {
    let mats = if gated { 3 } else { 2 };
    mats * hidden * intermediate
}

/// FFN parameters in one *MoE* layer (all experts + router + shared).
pub fn moe_ffn_params_per_layer(m: &ModelArch) -> u64 {
    match m.ffn {
        FeedForward::Dense { .. } => 0,
        FeedForward::Moe {
            experts,
            expert_intermediate,
            shared_experts,
            shared_intermediate,
            gated,
            ..
        } => {
            let router = m.hidden * experts as u64;
            experts as u64 * dense_ffn_params(m.hidden, expert_intermediate, gated)
                + shared_experts as u64 * dense_ffn_params(m.hidden, shared_intermediate, gated)
                + router
        }
    }
}

/// FFN parameters in one layer with a *dense* FFN. For MoE models with a
/// dense prefix (DeepSeek-V3) the prefix FFN intermediate is approximated
/// as 4·hidden, gated (documented in DESIGN.md; <0.1% of total).
pub fn dense_ffn_params_per_layer(m: &ModelArch) -> u64 {
    match m.ffn {
        FeedForward::Dense { intermediate, gated } => {
            dense_ffn_params(m.hidden, intermediate, gated)
        }
        FeedForward::Moe { .. } => dense_ffn_params(m.hidden, 4 * m.hidden, true),
    }
}

/// Total parameter count (embeddings counted once — tied head).
pub fn param_count(m: &ModelArch) -> u64 {
    let attn = m.layers as u64 * attn_params_per_layer(m);
    let moe = m.moe_layers() as u64 * moe_ffn_params_per_layer(m);
    let dense = m.dense_ffn_layers() as u64 * dense_ffn_params_per_layer(m);
    let embed = m.vocab * m.hidden;
    attn + moe + dense + embed
}

/// Parameters touched when generating one token (MoE: only routed experts).
pub fn active_param_count(m: &ModelArch) -> u64 {
    let attn = m.layers as u64 * attn_params_per_layer(m);
    let dense = m.dense_ffn_layers() as u64 * dense_ffn_params_per_layer(m);
    let moe_active = match m.ffn {
        FeedForward::Dense { .. } => 0,
        FeedForward::Moe {
            top_k,
            expert_intermediate,
            shared_experts,
            shared_intermediate,
            gated,
            experts,
            ..
        } => {
            let router = m.hidden * experts as u64;
            m.moe_layers() as u64
                * (top_k as u64 * dense_ffn_params(m.hidden, expert_intermediate, gated)
                    + shared_experts as u64
                        * dense_ffn_params(m.hidden, shared_intermediate, gated)
                    + router)
        }
    };
    let embed = m.hidden; // one row of the embedding table
    attn + dense + moe_active + embed
}

/// Bytes of weight storage at the model's deployment precision.
pub fn param_bytes(m: &ModelArch) -> Bytes {
    Bytes::new(param_count(m) as f64 * m.weight_dtype.bytes())
}

/// KV-cache bytes *per token per layer*.
pub fn kv_bytes_per_token_per_layer(m: &ModelArch) -> Bytes {
    let elems = match m.attention {
        Attention::Mha | Attention::Gqa { .. } => 2 * m.kv_dim(),
        // MLA stores the joint compressed latent + RoPE key once (not 2×).
        Attention::Mla { kv_lora_rank, rope_head_dim } => {
            (kv_lora_rank + rope_head_dim) as u64
        }
    };
    Bytes::new(elems as f64 * m.kv_dtype.bytes())
}

/// KV-cache bytes for a full batch at the given per-request sequence length.
pub fn kv_cache_bytes(m: &ModelArch, batch: u64, seq_len: u64) -> Bytes {
    kv_bytes_per_token_per_layer(m) * (m.layers as u64 * batch * seq_len) as f64
}

/// Total inference memory requirement: weights + KV cache (→ Fig 2.1).
pub fn inference_memory(m: &ModelArch, batch: u64, seq_len: u64) -> Bytes {
    param_bytes(m) + kv_cache_bytes(m, batch, seq_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::*;

    fn close(actual: f64, expected: f64, tol_frac: f64) -> bool {
        (actual - expected).abs() <= expected * tol_frac
    }

    #[test]
    fn gpt3_has_175b_params() {
        let n = param_count(&gpt3_175b()) as f64;
        assert!(close(n, 175e9, 0.02), "gpt3 params {n:.3e}");
    }

    #[test]
    fn grok1_has_314b_params() {
        let n = param_count(&grok1()) as f64;
        assert!(close(n, 314e9, 0.03), "grok1 params {n:.3e}");
    }

    #[test]
    fn qwen3_has_235b_params() {
        let n = param_count(&qwen3_235b()) as f64;
        assert!(close(n, 235e9, 0.03), "qwen3 params {n:.3e}");
    }

    #[test]
    fn deepseek_has_671b_params() {
        let n = param_count(&deepseek_v3()) as f64;
        assert!(close(n, 671e9, 0.04), "dsv3 params {n:.3e}");
    }

    #[test]
    fn qwen3_active_is_22b() {
        // Qwen3-235B-A22B: ~22B active per token.
        let n = active_param_count(&qwen3_235b()) as f64;
        assert!(close(n, 22e9, 0.10), "qwen3 active {n:.3e}");
    }

    #[test]
    fn deepseek_active_is_37b() {
        let n = active_param_count(&deepseek_v3()) as f64;
        assert!(close(n, 37e9, 0.15), "dsv3 active {n:.3e}");
    }

    #[test]
    fn paper_claim_gpt3_fp16_storage() {
        // §2.1.1: "a 671B-parameter model in FP16 requiring over 1.34 TB".
        let mut ds = deepseek_v3();
        ds.weight_dtype = crate::units::Dtype::F16;
        assert!(param_bytes(&ds).as_gb() > 1340.0);
        // FP8 halves it.
        assert!(param_bytes(&deepseek_v3()).as_gb() < 700.0);
    }

    #[test]
    fn mla_compresses_kv_by_order_of_magnitude() {
        // §2.1.1: MLA reduces KV footprint up to ~10× vs conventional MHA.
        let ds = deepseek_v3();
        let mla = kv_bytes_per_token_per_layer(&ds).value();
        let mut mha = ds.clone();
        mha.attention = Attention::Mha;
        let full = kv_bytes_per_token_per_layer(&mha).value();
        let ratio = full / mla;
        assert!(ratio > 8.0, "MLA compression only {ratio:.1}×");
    }

    #[test]
    fn kv_scales_linearly_with_batch_and_seq() {
        let m = qwen3_235b();
        let base = kv_cache_bytes(&m, 1, 1024).value();
        assert_eq!(kv_cache_bytes(&m, 16, 1024).value(), base * 16.0);
        assert_eq!(kv_cache_bytes(&m, 1, 4096).value(), base * 4.0);
    }

    #[test]
    fn active_leq_total() {
        for m in trend_models() {
            assert!(
                active_param_count(&m) <= param_count(&m),
                "{}: active > total",
                m.name
            );
        }
    }

    #[test]
    fn deepseek_leaves_most_params_inactive() {
        // §2.1.2: "models such as DeepSeek-V3 leave up to 95% of parameters
        // inactive during inference".
        let m = deepseek_v3();
        let frac = active_param_count(&m) as f64 / param_count(&m) as f64;
        assert!(frac < 0.08, "active fraction {frac:.3}");
    }
}
