//! FLOP arithmetic for prefill and decode (→ Fig 2.3, 2.4, 2.6).
//!
//! Matmul convention: a GEMM of M×K by K×N costs 2·M·K·N FLOPs. Per-token
//! linear-layer cost is therefore 2 × (active parameters). Attention
//! score/value products add 4·q_dim·context FLOPs per layer per token.

use super::arch::ModelArch;
use super::memory::active_param_count;
use crate::units::Flops;

/// Attention (QKᵀ + AV) FLOPs for one token attending over `context` keys.
pub fn attn_flops_per_token(m: &ModelArch, context: u64) -> Flops {
    // 2 GEMMs (scores, values), each 2 · q_dim · context FLOPs, per layer.
    Flops::new(4.0 * m.q_dim() as f64 * context as f64 * m.layers as f64)
}

/// FLOPs to generate ONE token in decode with `kv_len` cached tokens.
pub fn decode_flops_per_token(m: &ModelArch, kv_len: u64) -> Flops {
    let linear = 2.0 * active_param_count(m) as f64;
    Flops::new(linear) + attn_flops_per_token(m, kv_len)
}

/// FLOPs for a full prefill over a prompt of `prompt_len` tokens
/// (single request; multiply by batch for a batched prefill).
///
/// Causal attention: token i attends to i keys, so the attention term sums
/// to prompt_len·(prompt_len+1)/2 contexts.
pub fn prefill_flops(m: &ModelArch, prompt_len: u64) -> Flops {
    let linear = 2.0 * active_param_count(m) as f64 * prompt_len as f64;
    let contexts = prompt_len as f64 * (prompt_len as f64 + 1.0) / 2.0;
    let attn = 4.0 * m.q_dim() as f64 * contexts * m.layers as f64;
    Flops::new(linear + attn)
}

/// Memory traffic (bytes) to generate one token in decode: every active
/// parameter is read once, plus the KV cache of `kv_len` tokens, per
/// `batch` tokens amortised (weights are read once per *step*, not per
/// token — the paper's Byte-per-FLOP figure 2.6 uses batch=1 semantics
/// unless stated).
pub fn decode_bytes_per_step(m: &ModelArch, batch: u64, kv_len: u64) -> f64 {
    // Weights: a batched decode step still reads each active weight once.
    // For MoE, different tokens may route to different experts; with batch
    // B and top-k routing over E experts the expected number of *distinct*
    // activated experts per layer is E·(1 − (1 − k/E)^B).
    let weights = distinct_active_param_count(m, batch) as f64 * m.weight_dtype.bytes();
    let kv = super::memory::kv_bytes_per_token_per_layer(m).value()
        * m.layers as f64
        * kv_len as f64
        * batch as f64;
    weights + kv
}

/// Active parameters counted with batch-aware expert de-duplication.
pub fn distinct_active_param_count(m: &ModelArch, batch: u64) -> u64 {
    use super::arch::FeedForward;
    match m.ffn {
        FeedForward::Dense { .. } => active_param_count(m),
        FeedForward::Moe {
            experts,
            top_k,
            expert_intermediate,
            shared_experts,
            shared_intermediate,
            gated,
        } => {
            let e = experts as f64;
            let k = top_k as f64;
            let b = batch as f64;
            let distinct = e * (1.0 - (1.0 - k / e).powf(b));
            let mats = if gated { 3.0 } else { 2.0 };
            let expert_params = mats * m.hidden as f64 * expert_intermediate as f64;
            let shared = shared_experts as f64
                * mats
                * m.hidden as f64
                * shared_intermediate as f64;
            let router = m.hidden as f64 * e;
            let moe = m.moe_layers() as f64 * (distinct * expert_params + shared + router);
            let attn = m.layers as u64 as f64 * super::memory::attn_params_per_layer(m) as f64;
            let dense = m.dense_ffn_layers() as f64
                * super::memory::dense_ffn_params_per_layer(m) as f64;
            (attn + dense + moe) as u64
        }
    }
}

/// Byte-per-FLOP ratio for a decode step (→ Fig 2.6 decode bars).
pub fn decode_byte_per_flop(m: &ModelArch, batch: u64, kv_len: u64) -> f64 {
    let bytes = decode_bytes_per_step(m, batch, kv_len);
    let flops = decode_flops_per_token(m, kv_len).value() * batch as f64;
    bytes / flops
}

/// Byte-per-FLOP ratio for prefill (→ Fig 2.6 prefill bars).
/// Weights are read once; activations/KV writes are second-order.
pub fn prefill_byte_per_flop(m: &ModelArch, prompt_len: u64) -> f64 {
    let bytes = super::memory::param_bytes(m).value();
    let flops = prefill_flops(m, prompt_len).value();
    bytes / flops
}

/// FLOPs-per-generated-token over model-memory-footprint ratio (→ Fig 2.4,
/// FLOP per byte of model storage; the paper reports this falling ~10×
/// from GPT-2 to DeepSeek-V3).
pub fn compute_per_memory_ratio(m: &ModelArch, kv_len: u64) -> f64 {
    decode_flops_per_token(m, kv_len).value() / super::memory::param_bytes(m).value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::*;

    #[test]
    fn decode_flops_approx_2x_active_params() {
        // With a short context, linear terms dominate: ≈ 2 · active.
        let m = gpt3_175b();
        let f = decode_flops_per_token(&m, 1).value();
        let expected = 2.0 * 175e9;
        assert!((f - expected).abs() / expected < 0.05, "f={f:.3e}");
    }

    #[test]
    fn moe_decode_flops_stay_flat_despite_param_growth() {
        // §2.1.1 Trend 2: FLOPs/token stabilise or decline after GPT-3.
        let dense = decode_flops_per_token(&gpt3_175b(), 1024).value();
        let qwen = decode_flops_per_token(&qwen3_235b(), 1024).value();
        let ds = decode_flops_per_token(&deepseek_v3(), 1024).value();
        assert!(qwen < dense, "qwen3 FLOPs/token should be below GPT-3");
        assert!(ds < dense, "deepseek FLOPs/token should be below GPT-3");
    }

    #[test]
    fn fig24_ratio_drops_order_of_magnitude_gpt2_to_dsv3() {
        let r_gpt2 = compute_per_memory_ratio(&gpt2(), 1024);
        let r_ds = compute_per_memory_ratio(&deepseek_v3(), 1024);
        let drop = r_gpt2 / r_ds;
        assert!(drop > 5.0, "compute/memory ratio drop only {drop:.1}×");
    }

    #[test]
    fn prefill_flops_scale_quadratically_in_attention_term() {
        let m = gpt2();
        let f1 = prefill_flops(&m, 1024).value();
        let f2 = prefill_flops(&m, 2048).value();
        // Strictly more than linear scaling.
        assert!(f2 > 2.0 * f1);
        assert!(f2 < 4.5 * f1);
    }

    #[test]
    fn decode_is_much_more_memory_bound_than_prefill() {
        // §2.1.2: Qwen3 decode Byte/FLOP ≈ 100× prefill.
        let m = qwen3_235b();
        let d = decode_byte_per_flop(&m, 1, 4096);
        let p = prefill_byte_per_flop(&m, 4096);
        let ratio = d / p;
        assert!(ratio > 50.0, "decode/prefill byte-per-flop ratio {ratio:.0}×");
    }

    #[test]
    fn distinct_experts_saturate_with_batch() {
        let m = qwen3_235b();
        let b1 = distinct_active_param_count(&m, 1);
        let b64 = distinct_active_param_count(&m, 64);
        let all = crate::models::memory::param_count(&m);
        assert!(b1 < b64, "more batch → more distinct experts");
        assert!(b64 < all, "never exceeds total");
        // Huge batches touch essentially every expert.
        let b4096 = distinct_active_param_count(&m, 4096) as f64;
        assert!(b4096 > 0.95 * (all - m.vocab * m.hidden) as f64);
    }

    #[test]
    fn grok_distinct_experts_small_batch() {
        // Grok-1: 8 experts top-2; batch 8 activates E(1-(1-1/4)^8) ≈ 7.2.
        let m = grok1();
        let d = distinct_active_param_count(&m, 8) as f64;
        let total = crate::models::memory::param_count(&m) as f64;
        assert!(d / total > 0.85, "grok batch-8 touches most weights: {}", d / total);
    }
}
