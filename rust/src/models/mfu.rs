//! Hardware-efficiency curves (→ Fig 2.2) and calibration knobs.
//!
//! The paper's simulator replays *measured* Nsight kernel timings, which
//! embed the real-world efficiency of small-batch tensor-parallel serving
//! (MFU well below peak, memory bandwidth utilisation dependent on shard
//! size, link efficiency dependent on message size). We replace those
//! traces with explicit, documented efficiency curves:
//!
//! * `mfu(tokens, shard_cols)` — Model FLOPs Utilisation of a GEMM with M =
//!   `tokens` rows and per-GPU output width `shard_cols`. Saturating in both
//!   axes; reproduces the Fig 2.2 "MFU rises with batch size" curve and the
//!   tensor-parallel sharding penalty (smaller per-GPU matrices utilise the
//!   MXU/tensor cores worse).
//! * `mem_eff(bytes)` — achieved fraction of peak DRAM bandwidth for a
//!   kernel streaming `bytes` from memory. Small shards pay fixed kernel
//!   and DRAM-page overheads; large streams approach `MEM_EFF_MAX`.
//! * `link_eff(bytes, bw)` — Eq 4.1's `Efficiency(Tensor Size)`: effective
//!   fraction of link bandwidth for a transfer, with a latency-dominated
//!   ramp ("larger tensor sizes achieve higher effective bandwidth and
//!   exhibit reduced latency dominance").
//!
//! Every constant here is a calibration knob listed in DESIGN.md §5.

use crate::units::{Bandwidth, Bytes, Seconds};

/// Peak achievable MFU for a well-shaped dense GEMM (FlashAttention-3 era).
pub const MFU_MAX: f64 = 0.65;
/// Tokens at which the batch axis reaches half of `MFU_MAX`.
pub const MFU_TOKENS_HALF: f64 = 64.0;
/// Per-GPU output-columns at which the shard axis reaches half saturation.
pub const MFU_COLS_HALF: f64 = 1536.0;

/// Peak achieved fraction of DRAM bandwidth for a streaming kernel.
pub const MEM_EFF_MAX: f64 = 0.82;
/// Stream size at which memory efficiency reaches half of max.
pub const MEM_EFF_HALF: Bytes = Bytes(96.0 * 1024.0 * 1024.0);

/// Peak link efficiency (fraction of line rate) for bulk transfers.
pub const LINK_EFF_MAX: f64 = 0.95;
/// Latency-equivalent ramp time of a link transfer (Eq 4.1 shaping).
pub const LINK_RAMP: Seconds = Seconds(5.0e-6);

/// Local-memory efficiency of FengHuang kernels. The FH local tier is a
/// *paging cache*: the Tensor Prefetcher stages each kernel's working set
/// contiguously, so kernel reads are long sequential streams ("local
/// memory … capacity and bandwidth are tuned to workload characteristics
/// for efficient caching and computation", §3.1) rather than the scattered
/// per-shard access of a conventional resident layout.
pub const FH_LOCAL_STREAM_EFF: f64 = 0.85;

/// Efficiency of direct SM reads of the KV stream from remote memory
/// (§3.1: remote tensors can be "accessed by the SMs through the caching
/// hierarchy" without staging in local memory). Bulk sequential stream on
/// a dedicated virtual channel.
pub const FH_KV_STREAM_EFF: f64 = 0.90;

/// Framework-level inefficiency multiplier applied to the *baseline*
/// (shared-nothing NVLink) system's kernel times. Represents the measured
/// overheads the paper's Nsight traces embed — kernel-launch gaps,
/// synchronization with NCCL streams, scheduler bubbles — which published
/// TP-8 small-batch serving measurements consistently show (30–45% MFU,
/// 40–55% MBU). FengHuang's execution model instead pays its overheads
/// explicitly through the prefetch/paging simulation, per the paper's own
/// methodology (§4.1.3). Calibration knob; see DESIGN.md §5 and the
/// EXPERIMENTS.md sensitivity ablation.
pub const BASELINE_FRAMEWORK_OVERHEAD: f64 = 1.45;

/// Model FLOPs Utilisation for a GEMM with `tokens` rows on a shard with
/// `shard_cols` output columns (→ Fig 2.2).
pub fn mfu(tokens: f64, shard_cols: f64) -> f64 {
    debug_assert!(tokens >= 0.0 && shard_cols >= 0.0);
    let batch_axis = tokens / (tokens + MFU_TOKENS_HALF);
    let shard_axis = shard_cols / (shard_cols + MFU_COLS_HALF);
    MFU_MAX * batch_axis * shard_axis
}

/// Achieved fraction of peak DRAM bandwidth for a kernel streaming `bytes`.
pub fn mem_eff(bytes: Bytes) -> f64 {
    debug_assert!(bytes.value() >= 0.0);
    MEM_EFF_MAX * bytes.value() / (bytes.value() + MEM_EFF_HALF.value())
}

/// Eq 4.1 link efficiency: fraction of `bw` achieved when moving `bytes`.
pub fn link_eff(bytes: Bytes, bw: Bandwidth) -> f64 {
    debug_assert!(bytes.value() >= 0.0);
    let ramp_bytes = bw.value() * LINK_RAMP.value();
    LINK_EFF_MAX * bytes.value() / (bytes.value() + ramp_bytes)
}

/// Effective transfer time under Eq 4.1:
/// `tensor_size / (bandwidth × Efficiency(tensor_size))`.
pub fn transfer_time(bytes: Bytes, bw: Bandwidth) -> Seconds {
    if bytes.value() <= 0.0 {
        return Seconds::ZERO;
    }
    let eff = link_eff(bytes, bw);
    Seconds(bytes.value() / (bw.value() * eff))
}

/// The Fig 2.2 series: MFU at the paper's plotted batch sizes for a decode
/// step (GEMM M = batch) on an unsharded model.
pub fn fig22_mfu_vs_batch(hidden: u64) -> Vec<(u64, f64)> {
    [1u64, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024]
        .iter()
        .map(|&b| (b, mfu(b as f64, hidden as f64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mfu_monotone_in_batch() {
        let series = fig22_mfu_vs_batch(12288);
        for w in series.windows(2) {
            assert!(w[1].1 > w[0].1, "MFU must rise with batch: {series:?}");
        }
    }

    #[test]
    fn mfu_small_batch_is_poor_large_batch_is_decent() {
        // Fig 2.2 shape: single-token decode MFU is a few percent; large
        // batches reach tens of percent.
        assert!(mfu(1.0, 12288.0) < 0.02);
        assert!(mfu(1024.0, 12288.0) > 0.5);
    }

    #[test]
    fn mfu_penalises_tensor_parallel_sharding() {
        let full = mfu(4096.0, 49152.0);
        let tp8 = mfu(4096.0, 49152.0 / 8.0);
        assert!(tp8 < full);
        assert!(tp8 > 0.5 * full, "penalty should be moderate, not cliff");
    }

    #[test]
    fn mem_eff_saturates() {
        assert!(mem_eff(Bytes::mib(1.0)) < 0.01);
        assert!(mem_eff(Bytes::gib(1.0)) > 0.7);
        assert!(mem_eff(Bytes::gib(64.0)) <= MEM_EFF_MAX);
    }

    #[test]
    fn link_eff_matches_eq41_shape() {
        let bw = Bandwidth::tbps(4.0);
        // 2 KB transfer: latency dominated.
        let small = link_eff(Bytes::kib(2.0), bw);
        // 1 GB transfer: near line rate.
        let large = link_eff(Bytes::gib(1.0), bw);
        assert!(small < 0.001, "small={small}");
        assert!(large > 0.9, "large={large}");
    }

    #[test]
    fn transfer_time_includes_ramp() {
        let bw = Bandwidth::tbps(4.0);
        let t = transfer_time(Bytes::gb(4.0), bw);
        // Ideal would be 1 ms; with eff ≤ 0.95 it must exceed 1.05 ms.
        assert!(t.as_ms() > 1.05 && t.as_ms() < 1.3, "t={}", t.as_ms());
        assert_eq!(transfer_time(Bytes::ZERO, bw), Seconds::ZERO);
    }

    #[test]
    fn transfer_time_monotone_in_size() {
        let bw = Bandwidth::tbps(4.0);
        let mut prev = Seconds::ZERO;
        for mb in [1.0, 4.0, 16.0, 64.0, 256.0, 1024.0] {
            let t = transfer_time(Bytes::mib(mb), bw);
            assert!(t > prev);
            prev = t;
        }
    }
}
