//! Analytical LLM model library.
//!
//! Architecture descriptors ([`arch`]), memory footprints ([`memory`]),
//! FLOP counts ([`flops`]), tensor-parallel communication volumes
//! ([`comm`]) and hardware-efficiency curves ([`mfu`]). Together these
//! replace the Nsight profiling traces used by the paper's simulator.

pub mod arch;
pub mod comm;
pub mod flops;
pub mod memory;
pub mod mfu;

pub use arch::{Attention, FeedForward, ModelArch};
