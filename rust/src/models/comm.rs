//! Tensor-parallel communication-volume arithmetic (→ Fig 2.8).
//!
//! With Megatron-style tensor parallelism each transformer layer performs
//! two AllReduces over the activations (after the attention output
//! projection and after the FFN down projection). MoE layers with expert
//! parallelism additionally exchange tokens via AllToAll; we fold that into
//! the same per-layer payload accounting used by the paper (volume is
//! driven by hidden size — §2.1.3).

use super::arch::ModelArch;
use crate::units::{Bytes, Dtype};

/// Activation precision on the wire (communication payloads).
pub const ACT_DTYPE: Dtype = Dtype::F16;

/// AllReduce payload bytes per token per layer (one direction, logical
/// tensor size — algorithm-dependent wire traffic is applied by `fabric`).
pub fn allreduce_payload_per_token_per_layer(m: &ModelArch) -> Bytes {
    Bytes::new(m.hidden as f64 * ACT_DTYPE.bytes())
}

/// Number of collective phases per layer (2 AllReduce for TP; MoE adds
/// 2 AllToAll phases for dispatch/combine).
pub fn collectives_per_layer(m: &ModelArch) -> u32 {
    if m.is_moe() {
        4
    } else {
        2
    }
}

/// Total logical communication payload for generating one token across all
/// layers (→ denominator of Fig 2.8).
pub fn comm_bytes_per_token(m: &ModelArch) -> Bytes {
    allreduce_payload_per_token_per_layer(m)
        * (collectives_per_layer(m) as f64 * m.layers as f64)
}

/// FLOPs executed per byte of inter-device communication (→ Fig 2.8).
pub fn flops_per_comm_byte(m: &ModelArch, kv_len: u64) -> f64 {
    let f = super::flops::decode_flops_per_token(m, kv_len).value();
    f / comm_bytes_per_token(m).value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::*;

    #[test]
    fn payload_tracks_hidden_size() {
        // §2.1.3: transfer volume is primarily determined by hidden size.
        let p_gpt2 = comm_bytes_per_token(&gpt2()).value() / gpt2().layers as f64;
        let p_ds = comm_bytes_per_token(&deepseek_v3()).value() / deepseek_v3().layers as f64;
        // DeepSeek hidden (7168) ≈ 9.3× GPT-2 (768); MoE doubles phases.
        let ratio = p_ds / p_gpt2;
        assert!(ratio > 15.0 && ratio < 22.0, "ratio={ratio:.1}");
    }

    #[test]
    fn moe_models_have_lower_flops_per_comm_byte() {
        // §2.1.3: "sparse MoE architectures in Qwen3 and DeepSeek-V3 yield
        // significantly lower FLOPs per transfer byte compared to Grok1".
        let grok = flops_per_comm_byte(&grok1(), 1024);
        let qwen = flops_per_comm_byte(&qwen3_235b(), 1024);
        let ds = flops_per_comm_byte(&deepseek_v3(), 1024);
        assert!(qwen < grok, "qwen {qwen:.0} !< grok {grok:.0}");
        assert!(ds < grok, "ds {ds:.0} !< grok {grok:.0}");
    }

    #[test]
    fn dense_models_use_two_collectives_per_layer() {
        assert_eq!(collectives_per_layer(&gpt3_175b()), 2);
        assert_eq!(collectives_per_layer(&qwen3_235b()), 4);
    }
}
