//! LLM architecture descriptors.
//!
//! These are the published architectures the paper evaluates (GPT-3 175B,
//! Grok-1, Qwen3-235B) plus the models used in the Chapter 2 trend figures
//! (GPT-2, DeepSeek-V3, and the historical scaling set of Fig 1.1).
//!
//! Every analytical quantity the simulator needs — parameter counts,
//! KV-cache footprints, FLOPs, communication volume — is derived from these
//! descriptors, replacing the Nsight profiling traces of the paper's own
//! simulator (see DESIGN.md §1).

use crate::units::Dtype;

/// Attention flavour — determines KV-cache size per token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Attention {
    /// Multi-head attention: KV heads == query heads.
    Mha,
    /// Grouped-query attention with the given number of KV heads.
    Gqa { kv_heads: u32 },
    /// Multi-head latent attention (DeepSeek): KV compressed to
    /// `kv_lora_rank` plus a decoupled RoPE key of `rope_head_dim`.
    Mla { kv_lora_rank: u32, rope_head_dim: u32 },
}

/// Feed-forward flavour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FeedForward {
    /// Dense MLP with the given intermediate size. `gated` adds the third
    /// (gate) projection used by SwiGLU-style blocks.
    Dense { intermediate: u64, gated: bool },
    /// Sparse mixture-of-experts.
    Moe {
        experts: u32,
        top_k: u32,
        /// Intermediate size of each routed expert.
        expert_intermediate: u64,
        /// Number of always-active shared experts (DeepSeek-V3 style).
        shared_experts: u32,
        /// Intermediate size of each shared expert.
        shared_intermediate: u64,
        gated: bool,
    },
}

/// A transformer architecture, sufficient to derive memory / compute /
/// communication requirements analytically.
#[derive(Debug, Clone)]
pub struct ModelArch {
    pub name: String,
    /// Release year — used by the Chapter 2 trend figures.
    pub year: u32,
    pub layers: u32,
    pub hidden: u64,
    pub heads: u32,
    pub head_dim: u64,
    pub attention: Attention,
    pub ffn: FeedForward,
    pub vocab: u64,
    /// Maximum supported sequence length.
    pub max_seq: u64,
    /// Weight precision used for inference deployments of this model.
    pub weight_dtype: Dtype,
    /// KV-cache precision.
    pub kv_dtype: Dtype,
    /// Layers at the start of the network that use a dense FFN even in MoE
    /// models (DeepSeek-V3 uses 3).
    pub dense_prefix_layers: u32,
}

impl ModelArch {
    /// Query projection output width (= heads * head_dim).
    pub fn q_dim(&self) -> u64 {
        self.heads as u64 * self.head_dim
    }

    /// KV projection output width per K or V.
    pub fn kv_dim(&self) -> u64 {
        match self.attention {
            Attention::Mha => self.q_dim(),
            Attention::Gqa { kv_heads } => kv_heads as u64 * self.head_dim,
            // MLA stores a joint compressed KV plus the RoPE key; the
            // projection width used for weight sizing is the compression
            // rank (the up-projections are accounted separately in
            // `attn_params_per_layer`).
            Attention::Mla { kv_lora_rank, rope_head_dim } => {
                (kv_lora_rank + rope_head_dim) as u64
            }
        }
    }

    /// Number of MoE layers (total minus the dense prefix).
    pub fn moe_layers(&self) -> u32 {
        match self.ffn {
            FeedForward::Dense { .. } => 0,
            FeedForward::Moe { .. } => self.layers - self.dense_prefix_layers,
        }
    }

    /// Number of layers with a dense FFN.
    pub fn dense_ffn_layers(&self) -> u32 {
        self.layers - self.moe_layers()
    }

    pub fn is_moe(&self) -> bool {
        matches!(self.ffn, FeedForward::Moe { .. })
    }
}

/// Builder-style construction for presets and tests.
pub struct ArchBuilder(ModelArch);

impl ArchBuilder {
    pub fn new(name: &str, year: u32) -> Self {
        ArchBuilder(ModelArch {
            name: name.to_string(),
            year,
            layers: 12,
            hidden: 768,
            heads: 12,
            head_dim: 64,
            attention: Attention::Mha,
            ffn: FeedForward::Dense { intermediate: 3072, gated: false },
            vocab: 50257,
            max_seq: 1024,
            weight_dtype: Dtype::F16,
            kv_dtype: Dtype::F16,
            dense_prefix_layers: 0,
        })
    }

    pub fn layers(mut self, v: u32) -> Self {
        self.0.layers = v;
        self
    }
    pub fn hidden(mut self, v: u64) -> Self {
        self.0.hidden = v;
        self
    }
    pub fn heads(mut self, v: u32) -> Self {
        self.0.heads = v;
        self
    }
    pub fn head_dim(mut self, v: u64) -> Self {
        self.0.head_dim = v;
        self
    }
    pub fn attention(mut self, v: Attention) -> Self {
        self.0.attention = v;
        self
    }
    pub fn ffn(mut self, v: FeedForward) -> Self {
        self.0.ffn = v;
        self
    }
    pub fn vocab(mut self, v: u64) -> Self {
        self.0.vocab = v;
        self
    }
    pub fn max_seq(mut self, v: u64) -> Self {
        self.0.max_seq = v;
        self
    }
    pub fn weight_dtype(mut self, v: Dtype) -> Self {
        self.0.weight_dtype = v;
        self
    }
    pub fn kv_dtype(mut self, v: Dtype) -> Self {
        self.0.kv_dtype = v;
        self
    }
    pub fn dense_prefix_layers(mut self, v: u32) -> Self {
        self.0.dense_prefix_layers = v;
        self
    }
    pub fn build(self) -> ModelArch {
        let a = self.0;
        assert!(a.layers > 0 && a.hidden > 0 && a.heads > 0, "degenerate arch {}", a.name);
        assert!(a.dense_prefix_layers <= a.layers, "dense prefix exceeds layer count");
        a
    }
}

// ---------------------------------------------------------------------------
// Presets — published architectures.
// ---------------------------------------------------------------------------

/// GPT-2 small (124M) — the 768-hidden entry of Fig 2.8.
pub fn gpt2() -> ModelArch {
    ArchBuilder::new("GPT-2", 2019)
        .layers(12)
        .hidden(768)
        .heads(12)
        .head_dim(64)
        .ffn(FeedForward::Dense { intermediate: 3072, gated: false })
        .vocab(50257)
        .max_seq(1024)
        .build()
}

/// GPT-2 XL (1.5B) — the headline GPT-2 size of Fig 1.1.
pub fn gpt2_xl() -> ModelArch {
    ArchBuilder::new("GPT-2-XL", 2019)
        .layers(48)
        .hidden(1600)
        .heads(25)
        .head_dim(64)
        .ffn(FeedForward::Dense { intermediate: 6400, gated: false })
        .vocab(50257)
        .max_seq(1024)
        .build()
}

/// GPT-3 175B (Brown et al. 2020) — dense transformer workload of §4.
pub fn gpt3_175b() -> ModelArch {
    ArchBuilder::new("GPT-3", 2020)
        .layers(96)
        .hidden(12288)
        .heads(96)
        .head_dim(128)
        .ffn(FeedForward::Dense { intermediate: 49152, gated: false })
        .vocab(50257)
        .max_seq(4096)
        .build()
}

/// Grok-1 (xAI, 314B total, 8 experts top-2) — MoE workload of §4.
/// Each expert is a replica of the original FFN (intermediate 32768).
pub fn grok1() -> ModelArch {
    ArchBuilder::new("Grok-1", 2024)
        .layers(64)
        .hidden(6144)
        .heads(48)
        .head_dim(128)
        .attention(Attention::Gqa { kv_heads: 8 })
        .ffn(FeedForward::Moe {
            experts: 8,
            top_k: 2,
            expert_intermediate: 32768,
            shared_experts: 0,
            shared_intermediate: 0,
            gated: true,
        })
        .vocab(131072)
        .max_seq(8192)
        .build()
}

/// Qwen3-235B-A22B (128 experts, top-8, fine-grained experts) — MoE
/// workload of §4 with 128K context for the reasoning task.
pub fn qwen3_235b() -> ModelArch {
    ArchBuilder::new("Qwen3", 2025)
        .layers(94)
        .hidden(4096)
        .heads(64)
        .head_dim(128)
        .attention(Attention::Gqa { kv_heads: 4 })
        .ffn(FeedForward::Moe {
            experts: 128,
            top_k: 8,
            expert_intermediate: 1536,
            shared_experts: 0,
            shared_intermediate: 0,
            gated: true,
        })
        .vocab(151936)
        .max_seq(131072)
        .build()
}

/// DeepSeek-V3 (671B total, 256 experts top-8 + 1 shared, MLA) — used by
/// the Chapter 2 trend figures. FP8 deployment precision.
pub fn deepseek_v3() -> ModelArch {
    ArchBuilder::new("DeepSeek-V3", 2024)
        .layers(61)
        .hidden(7168)
        .heads(128)
        .head_dim(128)
        .attention(Attention::Mla { kv_lora_rank: 512, rope_head_dim: 64 })
        .ffn(FeedForward::Moe {
            experts: 256,
            top_k: 8,
            expert_intermediate: 2048,
            shared_experts: 1,
            shared_intermediate: 2048,
            gated: true,
        })
        .vocab(129280)
        .max_seq(163840)
        .weight_dtype(Dtype::Fp8)
        .dense_prefix_layers(3)
        .build()
}

/// The five models of the Chapter 2 model-trend figures, in paper order.
pub fn trend_models() -> Vec<ModelArch> {
    vec![gpt2(), gpt3_175b(), grok1(), qwen3_235b(), deepseek_v3()]
}

/// The §4 evaluation workloads.
pub fn eval_models() -> Vec<ModelArch> {
    vec![gpt3_175b(), grok1(), qwen3_235b()]
}

/// Look a preset up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<ModelArch> {
    let n = name.to_ascii_lowercase();
    let m = match n.as_str() {
        "gpt2" | "gpt-2" => gpt2(),
        "gpt2-xl" | "gpt-2-xl" => gpt2_xl(),
        "gpt3" | "gpt-3" | "gpt3-175b" => gpt3_175b(),
        "grok1" | "grok-1" => grok1(),
        "qwen3" | "qwen3-235b" => qwen3_235b(),
        "deepseek" | "deepseek-v3" | "dsv3" => deepseek_v3(),
        _ => return None,
    };
    Some(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_published_hidden_sizes() {
        assert_eq!(gpt2().hidden, 768);
        assert_eq!(gpt3_175b().hidden, 12288);
        assert_eq!(grok1().hidden, 6144);
        assert_eq!(qwen3_235b().hidden, 4096);
        assert_eq!(deepseek_v3().hidden, 7168);
    }

    #[test]
    fn kv_dim_reflects_attention_flavour() {
        assert_eq!(gpt3_175b().kv_dim(), 96 * 128); // MHA
        assert_eq!(grok1().kv_dim(), 8 * 128); // GQA
        assert_eq!(deepseek_v3().kv_dim(), 512 + 64); // MLA
    }

    #[test]
    fn moe_layer_partition() {
        let ds = deepseek_v3();
        assert_eq!(ds.moe_layers(), 58);
        assert_eq!(ds.dense_ffn_layers(), 3);
        let g = gpt3_175b();
        assert_eq!(g.moe_layers(), 0);
        assert_eq!(g.dense_ffn_layers(), 96);
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("Qwen3").is_some());
        assert!(by_name("gpt-3").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn max_seq_matches_paper_claims() {
        // §2.1.1: Qwen3 128K, DeepSeek 160K, Grok-1 8K.
        assert_eq!(qwen3_235b().max_seq, 131072);
        assert_eq!(deepseek_v3().max_seq, 163840);
        assert_eq!(grok1().max_seq, 8192);
    }
}
