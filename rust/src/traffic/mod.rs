//! Open-loop traffic engine (DESIGN.md §Traffic; → EXPERIMENTS.md
//! §Traffic-Sweep).
//!
//! The paper's headline economic claim — up to 50 % fewer GPUs *while
//! maintaining end-user performance* (§4.4) — is a statement about
//! serving under live traffic with latency SLOs. This module supplies
//! the live traffic: a deterministic, zero-dependency workload engine
//! that turns a seed into an open-loop request stream the cluster
//! simulator can serve.
//!
//! * [`rng`] — seedable xorshift64* generator (no `rand` crate offline);
//! * [`arrival`] — arrival processes: Poisson, bursty (MMPP on-off),
//!   diurnal ramp, and replay-from-slice;
//! * [`mix`] — workload classes (chat, long-prompt RAG, agentic
//!   multi-turn with session-prefix reuse, offline batch) with per-class
//!   prompt/output length distributions and SLO posture;
//! * [`tenants`] — per-tenant stream composition for multi-tenant
//!   serving: one seed lane, mix, and SLO tier per tenant, merged into
//!   a single arrival-ordered workload (DESIGN.md §Multi-Tenant).
//!
//! [`generate`] composes the three: requests arrive per the pattern, are
//! classed per the mix weights, and carry per-request [`SloTarget`]s the
//! coordinator scores on completion (fleet SLO attainment + goodput;
//! `coordinator::metrics`). Everything downstream of the seed is
//! bit-for-bit reproducible — the property the golden regression tests
//! (`rust/tests/golden.rs`) pin.

pub mod arrival;
pub mod mix;
pub mod rng;
pub mod tenants;

pub use arrival::{arrival_times, ArrivalConfig, ArrivalPattern};
pub use mix::{ClassKind, ClassSpec, WorkloadMix};
pub use rng::XorShift;
pub use tenants::generate_tenant_workload;

use crate::coordinator::request::{Request, SloTarget, AFFINITY_PREFIX};
use crate::error::{FhError, Result};
use crate::units::Seconds;

/// Default base SLO: interactive chat at 2 s to first token, 80 ms per
/// output token (classes scale these; see [`ClassSpec::slo_for`]).
pub const DEFAULT_SLO_TTFT_MS: f64 = 2000.0;
pub const DEFAULT_SLO_TPOT_MS: f64 = 80.0;

/// Full traffic-engine configuration: one seed in, one workload out.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    pub arrivals: ArrivalConfig,
    pub mix: WorkloadMix,
    /// Number of requests to draw.
    pub requests: usize,
    pub seed: u64,
    /// Admissible prompt cap (the serving model's `max_seq`); class
    /// ranges are clamped to it so no request is dead on arrival.
    pub max_prompt: usize,
    /// Base per-request SLO; classes scale it ([`ClassSpec::slo_scale`]).
    /// `None` disables SLO tagging entirely.
    pub slo: Option<SloTarget>,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            arrivals: ArrivalConfig::default(),
            mix: WorkloadMix::of(ClassKind::Chat),
            requests: 64,
            seed: 42,
            max_prompt: 4096,
            slo: Some(SloTarget {
                ttft: Seconds::ms(DEFAULT_SLO_TTFT_MS),
                tpot: Seconds::ms(DEFAULT_SLO_TPOT_MS),
            }),
        }
    }
}

/// Affinity-prefix token for (marker, position): requests sharing a
/// marker share the whole prefix, hence the same
/// [`Request::affinity_key`]. The marker is mixed through a
/// splitmix64-style finaliser *per position* so distinct markers keep
/// distinct 32-token prefixes (a plain `marker % vocab` would alias
/// unrelated sessions once ids wrap the vocab size).
fn prefix_token(marker: u64, i: usize) -> i32 {
    let mut z = marker ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z % 509) as i32 + 1
}

/// Draw the full open-loop workload for `cfg`. Deterministic in the
/// seed; requests come out sorted by arrival time.
pub fn generate(cfg: &TrafficConfig) -> Result<Vec<Request>> {
    if cfg.max_prompt == 0 {
        return Err(FhError::Config("traffic max_prompt must be ≥ 1".into()));
    }
    if cfg.mix.classes.is_empty() {
        return Err(FhError::Config("traffic mix needs at least one class".into()));
    }
    let mut rng = XorShift::new(cfg.seed);
    let times = arrival_times(&cfg.arrivals, cfg.requests, &mut rng)?;
    let weights = cfg.mix.weights();
    // Per-class, per-session turn counters (agentic context growth).
    let mut turns: Vec<Vec<u64>> =
        cfg.mix.classes.iter().map(|c| vec![0u64; c.sessions]).collect();
    let mut out = Vec::with_capacity(cfg.requests);
    for (id, t) in times.into_iter().enumerate() {
        let ci = rng.pick_weighted(&weights);
        let class = &cfg.mix.classes[ci];
        // Session draw: pooled classes share prefixes, the rest get a
        // unique per-request marker (class-disambiguated so chat and
        // batch never alias).
        let (marker, turn) = if class.sessions > 0 {
            let s = rng.range(0, class.sessions as u64 - 1) as usize;
            let turn = turns[ci][s];
            turns[ci][s] += 1;
            (((ci as u64) << 32) | s as u64, turn)
        } else {
            (((ci as u64) << 32) | (1 << 20) | id as u64, 0)
        };
        let lo = class.prompt_lo.clamp(1, cfg.max_prompt);
        let hi = class.prompt_hi.clamp(lo, cfg.max_prompt);
        let grown = turn as usize * class.turn_growth;
        let plen = (rng.range(lo as u64, hi as u64) as usize + grown).min(cfg.max_prompt);
        let gen = rng.range(class.gen_lo as u64, class.gen_hi as u64).max(1) as usize;
        // Session classes share their *conversation head*: turn `t`
        // carries the affinity prefix plus everything accumulated by the
        // previous turns, all derived from (marker, position) — so a
        // later turn's prompt extends an earlier turn's token chain,
        // which is exactly what the shared prefix cache
        // (DESIGN.md §Prefix-Cache) indexes. Only the fresh per-turn
        // tail varies by request. One-shot classes keep the old shape:
        // a unique 32-token marker prefix, request-specific tail.
        let shared = if class.sessions > 0 {
            (AFFINITY_PREFIX + grown).min(plen)
        } else {
            plen.min(AFFINITY_PREFIX)
        };
        let mut prompt = Vec::with_capacity(plen);
        for i in 0..shared {
            prompt.push(prefix_token(marker, i));
        }
        for i in prompt.len()..plen {
            prompt.push(((id * 31 + i * 13) % 509) as i32 + 1);
        }
        out.push(Request {
            id: id as u64,
            prompt,
            max_new_tokens: gen,
            arrival: t,
            slo: class.slo_for(cfg.slo),
            ..Default::default()
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(mix: &str, requests: usize) -> TrafficConfig {
        TrafficConfig {
            mix: WorkloadMix::parse(mix).unwrap(),
            requests,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let a = generate(&cfg("chat+rag", 64)).unwrap();
        let b = generate(&cfg("chat+rag", 64)).unwrap();
        assert_eq!(a.len(), 64);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.slo.map(|s| s.ttft), y.slo.map(|s| s.ttft));
        }
        let mut c = cfg("chat+rag", 64);
        c.seed = 8;
        let c = generate(&c).unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt || x.arrival != y.arrival),
            "a different seed must change the workload"
        );
    }

    #[test]
    fn arrivals_sorted_and_prompts_admissible() {
        let mut c = cfg("chat+rag+agentic+batch", 200);
        c.max_prompt = 1024;
        let reqs = generate(&c).unwrap();
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        for r in &reqs {
            assert!(!r.prompt.is_empty());
            assert!(r.prompt.len() <= 1024, "prompt {} exceeds cap", r.prompt.len());
            assert!(r.max_new_tokens >= 1);
        }
    }

    #[test]
    fn rag_prompts_are_longer_and_slo_relaxed() {
        let reqs = generate(&cfg("rag", 32)).unwrap();
        for r in &reqs {
            assert!(r.prompt.len() >= 1536);
            let slo = r.slo.expect("rag carries an SLO");
            assert!((slo.ttft.as_ms() - 2.0 * DEFAULT_SLO_TTFT_MS).abs() < 1e-9);
        }
    }

    #[test]
    fn batch_requests_carry_no_slo() {
        let reqs = generate(&cfg("batch", 32)).unwrap();
        assert!(reqs.iter().all(|r| r.slo.is_none()));
    }

    #[test]
    fn agentic_sessions_share_affinity_keys_and_grow() {
        let reqs = generate(&cfg("agentic", 120)).unwrap();
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.affinity_key()).collect();
        keys.sort_unstable();
        keys.dedup();
        let pool = ClassSpec::preset(ClassKind::Agentic).sessions;
        assert!(
            keys.len() <= pool,
            "{} distinct keys from a {pool}-session pool",
            keys.len()
        );
        assert!(keys.len() >= 2, "several sessions should see traffic");
        // Later turns of a session carry more context than its first turn.
        let by_key = |k: u64| -> Vec<usize> {
            reqs.iter().filter(|r| r.affinity_key() == k).map(|r| r.prompt.len()).collect()
        };
        let busiest = keys
            .iter()
            .copied()
            .max_by_key(|&k| by_key(k).len())
            .unwrap();
        let lens = by_key(busiest);
        assert!(
            lens.last().unwrap() > lens.first().unwrap(),
            "context must grow across turns: {lens:?}"
        );
    }

    #[test]
    fn session_turns_extend_a_shared_conversation_head() {
        // Within one agentic session, turn t+1's prompt must share a
        // strictly longer prefix with turn t than the 32-token affinity
        // marker alone — the chain the shared prefix cache reuses.
        let reqs = generate(&cfg("agentic", 120)).unwrap();
        let keys: Vec<u64> = reqs.iter().map(|r| r.affinity_key()).collect();
        let mut best_growth = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            for (j, s) in reqs.iter().enumerate().skip(i + 1) {
                if keys[i] != keys[j] {
                    continue;
                }
                let common = r
                    .prompt
                    .iter()
                    .zip(&s.prompt)
                    .take_while(|(a, b)| a == b)
                    .count();
                best_growth = best_growth.max(common);
            }
        }
        assert!(
            best_growth > AFFINITY_PREFIX,
            "deep session turns must share more than the {AFFINITY_PREFIX}-token marker \
             (best shared prefix: {best_growth})"
        );
        // Requests of different sessions still diverge inside the marker.
        let distinct = reqs
            .iter()
            .zip(reqs.iter().skip(1))
            .any(|(a, b)| a.affinity_key() != b.affinity_key());
        assert!(distinct);
    }

    #[test]
    fn unique_prefixes_do_not_alias_across_many_requests() {
        // Regression: a `marker % vocab` prefix would collapse distinct
        // sessions onto 509 sticky keys once ids wrap the vocab.
        let reqs = generate(&cfg("chat+rag", 600)).unwrap();
        let mut keys: Vec<u64> = reqs.iter().map(|r| r.affinity_key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), 600, "every chat/rag request is its own session");
    }

    #[test]
    fn mixed_stream_draws_every_class() {
        let reqs = generate(&cfg("chat+batch", 200)).unwrap();
        let with_slo = reqs.iter().filter(|r| r.slo.is_some()).count();
        let without = reqs.len() - with_slo;
        assert!(with_slo > 20 && without > 20, "chat {with_slo} / batch {without}");
    }

    #[test]
    fn invalid_configs_error() {
        let mut c = cfg("chat", 8);
        c.max_prompt = 0;
        assert!(generate(&c).is_err());
        let mut c = cfg("chat", 8);
        c.mix.classes.clear();
        assert!(generate(&c).is_err());
        let mut c = cfg("chat", 8);
        c.arrivals.qps = -1.0;
        assert!(generate(&c).is_err());
    }

    #[test]
    fn empty_workload_is_fine() {
        assert!(generate(&cfg("chat", 0)).unwrap().is_empty());
    }
}
