//! Per-tenant traffic composition (DESIGN.md §Multi-Tenant).
//!
//! Each tenant of a [`TenantsConfig`] drives its own slice of the
//! open-loop stream: its own [`WorkloadMix`], its own seed lane, its
//! own SLO tier (the fleet base SLO scaled by `slo_scale`), and a
//! prompt cap clamped to *its* model's context window. The per-tenant
//! streams are merged into one arrival-ordered workload with the
//! owning tenant stamped on every request — the cluster's admission
//! arbiter keys on that field.

use crate::coordinator::request::{Request, SloTarget};
use crate::coordinator::tenancy::TenantsConfig;
use crate::error::Result;
use crate::traffic::{generate, TrafficConfig};

/// Tag a request id with its tenant lane so merged ids stay unique
/// (per-tenant generators all count from zero).
const TENANT_ID_SHIFT: u32 = 40;

/// The [`TrafficConfig`] one tenant's slice of the stream is drawn
/// from: `base` shapes arrivals/volume, the tenant shapes everything
/// workload-specific. Exposed for tests and benches that want a solo
/// baseline of a single tenant's traffic.
pub fn tenant_traffic(tenants: &TenantsConfig, base: &TrafficConfig, ti: usize) -> TrafficConfig {
    let t = &tenants.tenants[ti];
    let n = tenants.tenants.len();
    let share = base.requests / n + usize::from(ti < base.requests % n);
    TrafficConfig {
        mix: t.mix.clone(),
        requests: share,
        // Distinct seed lane per tenant: tenant B's draws never shift
        // tenant A's stream when B's share changes.
        seed: base.seed ^ (ti as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15),
        max_prompt: base.max_prompt.min(t.model.max_seq as usize),
        slo: base.slo.map(|s| SloTarget { ttft: s.ttft * t.slo_scale, tpot: s.tpot * t.slo_scale }),
        ..base.clone()
    }
}

/// Draw every tenant's stream and merge by arrival time (stable — ties
/// keep tenant-index order, so the merge is deterministic and the two
/// simulation cores see the identical sequence).
pub fn generate_tenant_workload(
    tenants: &TenantsConfig,
    base: &TrafficConfig,
) -> Result<Vec<Request>> {
    tenants.validate()?;
    let mut out = Vec::with_capacity(base.requests);
    for ti in 0..tenants.tenants.len() {
        let cfg = tenant_traffic(tenants, base, ti);
        for mut r in generate(&cfg)? {
            r.tenant = ti;
            r.id |= (ti as u64) << TENANT_ID_SHIFT;
            out.push(r);
        }
    }
    out.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::tenancy::{TenantConfig, TenantsConfig};
    use crate::models::arch::{gpt2, gpt2_xl};
    use crate::traffic::WorkloadMix;

    fn two_tenants() -> TenantsConfig {
        let mut a = TenantConfig::new("alpha", gpt2());
        a.mix = WorkloadMix::parse("chat").unwrap();
        let mut b = TenantConfig::new("beta", gpt2_xl());
        b.mix = WorkloadMix::parse("batch").unwrap();
        b.slo_scale = 4.0;
        TenantsConfig::new(vec![a, b])
    }

    #[test]
    fn workload_is_merged_sorted_and_stamped() {
        let tc = TrafficConfig { requests: 41, seed: 9, ..Default::default() };
        let reqs = generate_tenant_workload(&two_tenants(), &tc).unwrap();
        assert_eq!(reqs.len(), 41);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let a = reqs.iter().filter(|r| r.tenant == 0).count();
        let b = reqs.iter().filter(|r| r.tenant == 1).count();
        assert_eq!((a, b), (21, 20), "remainder goes to the earlier tenant");
        // Ids unique across the merge.
        let mut ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 41);
    }

    #[test]
    fn tenant_lanes_are_independent_and_deterministic() {
        let tenants = two_tenants();
        let tc = TrafficConfig { requests: 40, seed: 9, ..Default::default() };
        let x = generate_tenant_workload(&tenants, &tc).unwrap();
        let y = generate_tenant_workload(&tenants, &tc).unwrap();
        for (a, b) in x.iter().zip(&y) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.arrival, b.arrival);
        }
        // Tenant A's stream is untouched by B's mix changing.
        let mut other = two_tenants();
        other.tenants[1].mix = WorkloadMix::parse("rag").unwrap();
        let z = generate_tenant_workload(&other, &tc).unwrap();
        let lane = |reqs: &[Request]| -> Vec<(u64, usize)> {
            reqs.iter().filter(|r| r.tenant == 0).map(|r| (r.id, r.prompt.len())).collect()
        };
        assert_eq!(lane(&x), lane(&z));
    }

    #[test]
    fn slo_scale_and_context_clamp_apply() {
        let tenants = two_tenants();
        let tc = TrafficConfig { requests: 30, seed: 3, ..Default::default() };
        let a_cfg = tenant_traffic(&tenants, &tc, 0);
        assert!(a_cfg.max_prompt <= gpt2().max_seq as usize);
        let reqs = generate_tenant_workload(&tenants, &tc).unwrap();
        for r in reqs.iter().filter(|r| r.tenant == 0) {
            assert!(r.prompt.len() <= gpt2().max_seq as usize);
        }
        // Tenant with slo_scale would see scaled targets; batch carries
        // none, so pin the scale through the per-tenant config instead.
        let b_cfg = tenant_traffic(&tenants, &tc, 1);
        let base = tc.slo.unwrap();
        let scaled = b_cfg.slo.unwrap();
        assert!((scaled.ttft.value() - 4.0 * base.ttft.value()).abs() < 1e-12);
    }
}
