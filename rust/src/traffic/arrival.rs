//! Open-loop arrival processes (DESIGN.md §Traffic).
//!
//! The generator is *open-loop*: arrival timestamps are drawn from the
//! process alone and never react to serving latency, which is what makes
//! goodput-under-SLO a meaningful metric (a closed loop would throttle
//! itself out of the overload the SLO is supposed to expose). Patterns:
//!
//! * **poisson** — homogeneous Poisson at `qps` (exponential gaps);
//! * **bursty** — a two-state MMPP: an *on* state firing at `qps` and an
//!   *off* state at `burst_idle_frac · qps`, with exponentially
//!   distributed dwell times (flash-crowd shape);
//! * **diurnal** — non-homogeneous Poisson whose rate ramps
//!   sinusoidally from `diurnal_floor · qps` (trough, at t = 0) to `qps`
//!   (peak, half a period in) — the day/night curve the elastic
//!   autoscaler is measured against;
//! * **replay** — replay a recorded gap slice, cycled (trace-driven
//!   load; the CLI feeds it fixed `1/qps` gaps as the degenerate case).
//!
//! Non-homogeneous patterns use Lewis–Shedler thinning against the peak
//! rate, so every pattern is exact and fully determined by the seed.

use super::rng::XorShift;
use crate::error::{FhError, Result};
use crate::units::Seconds;

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalPattern {
    Poisson,
    Bursty,
    Diurnal,
    Replay,
}

impl ArrivalPattern {
    /// Parse a CLI pattern name.
    pub fn parse(s: &str) -> Option<ArrivalPattern> {
        match s.to_ascii_lowercase().as_str() {
            "poisson" => Some(ArrivalPattern::Poisson),
            "bursty" | "mmpp" | "onoff" => Some(ArrivalPattern::Bursty),
            "diurnal" | "ramp" => Some(ArrivalPattern::Diurnal),
            "replay" | "trace" => Some(ArrivalPattern::Replay),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ArrivalPattern::Poisson => "poisson",
            ArrivalPattern::Bursty => "bursty",
            ArrivalPattern::Diurnal => "diurnal",
            ArrivalPattern::Replay => "replay",
        }
    }

    /// The synthetic patterns (replay needs a recorded slice), for sweeps.
    pub fn synthetic() -> [ArrivalPattern; 3] {
        [ArrivalPattern::Poisson, ArrivalPattern::Bursty, ArrivalPattern::Diurnal]
    }
}

/// Arrival-process knobs. `qps` is the *peak* rate; non-homogeneous
/// patterns modulate below it.
#[derive(Debug, Clone)]
pub struct ArrivalConfig {
    pub pattern: ArrivalPattern,
    /// Peak arrival rate (requests per virtual second).
    pub qps: f64,
    /// Diurnal cycle length.
    pub diurnal_period: Seconds,
    /// Trough rate as a fraction of peak, in [0, 1].
    pub diurnal_floor: f64,
    /// Bursty: mean dwell in the on state.
    pub burst_on: Seconds,
    /// Bursty: mean dwell in the off state.
    pub burst_off: Seconds,
    /// Bursty: off-state rate as a fraction of peak, in [0, 1].
    pub burst_idle_frac: f64,
    /// Replay: recorded inter-arrival gaps, cycled.
    pub replay_gaps: Vec<Seconds>,
}

impl Default for ArrivalConfig {
    fn default() -> Self {
        ArrivalConfig {
            pattern: ArrivalPattern::Poisson,
            qps: 8.0,
            diurnal_period: Seconds::new(30.0),
            diurnal_floor: 0.1,
            burst_on: Seconds::new(2.0),
            burst_off: Seconds::new(6.0),
            burst_idle_frac: 0.05,
            replay_gaps: Vec::new(),
        }
    }
}

impl ArrivalConfig {
    fn validate(&self) -> Result<()> {
        if !(self.qps > 0.0) {
            return Err(FhError::Config(format!("qps must be > 0, got {}", self.qps)));
        }
        if !(0.0..=1.0).contains(&self.diurnal_floor) {
            return Err(FhError::Config(format!(
                "diurnal floor must be in [0, 1], got {}",
                self.diurnal_floor
            )));
        }
        if !(0.0..=1.0).contains(&self.burst_idle_frac) {
            return Err(FhError::Config(format!(
                "burst idle fraction must be in [0, 1], got {}",
                self.burst_idle_frac
            )));
        }
        if self.diurnal_period.value() <= 0.0
            || self.burst_on.value() <= 0.0
            || self.burst_off.value() <= 0.0
        {
            return Err(FhError::Config("arrival dwell/period knobs must be positive".into()));
        }
        if self.pattern == ArrivalPattern::Replay && self.replay_gaps.is_empty() {
            return Err(FhError::Config(
                "replay pattern needs a non-empty gap slice (replay_gaps)".into(),
            ));
        }
        Ok(())
    }

    /// Instantaneous rate at time `t` (the thinning intensity), as a
    /// fraction of peak. Homogeneous patterns are flat at 1.
    fn intensity_frac(&self, t: Seconds, burst_on_now: bool) -> f64 {
        match self.pattern {
            ArrivalPattern::Poisson | ArrivalPattern::Replay => 1.0,
            ArrivalPattern::Bursty => {
                if burst_on_now {
                    1.0
                } else {
                    self.burst_idle_frac
                }
            }
            ArrivalPattern::Diurnal => {
                let phase = t.value() / self.diurnal_period.value();
                let shape = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
                self.diurnal_floor + (1.0 - self.diurnal_floor) * shape
            }
        }
    }
}

/// Two-state dwell machine for the bursty pattern: tracks whether the
/// process is in the on state at a given (monotone) query time.
struct BurstState {
    on: bool,
    until: f64,
}

impl BurstState {
    fn at(&mut self, t: f64, cfg: &ArrivalConfig, rng: &mut XorShift) -> bool {
        while t >= self.until {
            self.on = !self.on;
            let mean = if self.on { cfg.burst_on.value() } else { cfg.burst_off.value() };
            self.until += rng.exp(mean);
        }
        self.on
    }
}

/// Draw `n` monotone arrival timestamps from the configured process.
pub fn arrival_times(cfg: &ArrivalConfig, n: usize, rng: &mut XorShift) -> Result<Vec<Seconds>> {
    cfg.validate()?;
    let mut out = Vec::with_capacity(n);
    if cfg.pattern == ArrivalPattern::Replay {
        let mut t = Seconds::ZERO;
        for i in 0..n {
            t += cfg.replay_gaps[i % cfg.replay_gaps.len()];
            out.push(t);
        }
        return Ok(out);
    }
    // Lewis–Shedler thinning against the peak rate: candidates from a
    // homogeneous Poisson at qps, accepted with probability λ(t)/qps.
    let mean_gap = 1.0 / cfg.qps;
    let mut burst = BurstState { on: false, until: 0.0 };
    let mut t = 0.0f64;
    while out.len() < n {
        t += rng.exp(mean_gap);
        let on = if cfg.pattern == ArrivalPattern::Bursty {
            burst.at(t, cfg, rng)
        } else {
            false
        };
        let frac = cfg.intensity_frac(Seconds::new(t), on);
        if rng.next_f64() < frac {
            out.push(Seconds::new(t));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn times(pattern: ArrivalPattern, qps: f64, n: usize, seed: u64) -> Vec<Seconds> {
        let cfg = ArrivalConfig { pattern, qps, ..Default::default() };
        arrival_times(&cfg, n, &mut XorShift::new(seed)).unwrap()
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in ArrivalPattern::synthetic() {
            assert_eq!(ArrivalPattern::parse(p.name()), Some(p));
        }
        assert_eq!(ArrivalPattern::parse("replay"), Some(ArrivalPattern::Replay));
        assert_eq!(ArrivalPattern::parse("MMPP"), Some(ArrivalPattern::Bursty));
        assert!(ArrivalPattern::parse("lunar").is_none());
    }

    #[test]
    fn arrivals_are_monotone_and_deterministic() {
        for p in ArrivalPattern::synthetic() {
            let a = times(p, 10.0, 200, 7);
            let b = times(p, 10.0, 200, 7);
            assert_eq!(a.len(), 200);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x, y, "{} must be seed-deterministic", p.name());
            }
            for w in a.windows(2) {
                assert!(w[1] >= w[0], "{} arrivals must be monotone", p.name());
            }
            let c = times(p, 10.0, 200, 8);
            assert_ne!(
                a.last().unwrap(),
                c.last().unwrap(),
                "{} must vary with the seed",
                p.name()
            );
        }
    }

    #[test]
    fn poisson_rate_converges_to_qps() {
        let a = times(ArrivalPattern::Poisson, 20.0, 4000, 3);
        let span = a.last().unwrap().value();
        let rate = 4000.0 / span;
        assert!((rate - 20.0).abs() < 1.5, "empirical rate {rate}");
    }

    #[test]
    fn diurnal_trough_is_sparser_than_peak() {
        // Rate at t≈0 is floor·qps; at period/2 it is qps. Count arrivals
        // in the first vs the middle tenth of one period.
        let cfg = ArrivalConfig {
            pattern: ArrivalPattern::Diurnal,
            qps: 50.0,
            diurnal_period: Seconds::new(40.0),
            diurnal_floor: 0.05,
            ..Default::default()
        };
        let a = arrival_times(&cfg, 1200, &mut XorShift::new(9)).unwrap();
        let count_in = |lo: f64, hi: f64| {
            a.iter().filter(|t| t.value() >= lo && t.value() < hi).count()
        };
        let trough = count_in(0.0, 4.0);
        let peak = count_in(18.0, 22.0);
        assert!(
            peak > 4 * trough.max(1),
            "peak window {peak} must dwarf trough window {trough}"
        );
    }

    #[test]
    fn bursty_mean_rate_sits_between_idle_and_peak() {
        let cfg = ArrivalConfig {
            pattern: ArrivalPattern::Bursty,
            qps: 40.0,
            burst_on: Seconds::new(1.0),
            burst_off: Seconds::new(3.0),
            burst_idle_frac: 0.05,
            ..Default::default()
        };
        let a = arrival_times(&cfg, 2000, &mut XorShift::new(4)).unwrap();
        let rate = 2000.0 / a.last().unwrap().value();
        // Duty cycle 25%: expected ≈ 40·(0.25 + 0.75·0.05) ≈ 11.5 qps.
        assert!(rate > 40.0 * 0.05 * 1.5, "rate {rate} stuck at idle");
        assert!(rate < 40.0 * 0.8, "rate {rate} never left the on state");
    }

    #[test]
    fn replay_cycles_the_gap_slice() {
        let cfg = ArrivalConfig {
            pattern: ArrivalPattern::Replay,
            replay_gaps: vec![Seconds::ms(10.0), Seconds::ms(30.0)],
            ..Default::default()
        };
        let a = arrival_times(&cfg, 4, &mut XorShift::new(1)).unwrap();
        assert!((a[0].as_ms() - 10.0).abs() < 1e-9);
        assert!((a[1].as_ms() - 40.0).abs() < 1e-9);
        assert!((a[3].as_ms() - 80.0).abs() < 1e-9);
        // Empty slice is a config error, not a hang.
        let bad = ArrivalConfig { pattern: ArrivalPattern::Replay, ..Default::default() };
        assert!(arrival_times(&bad, 4, &mut XorShift::new(1)).is_err());
    }

    #[test]
    fn invalid_knobs_are_rejected() {
        let bad = ArrivalConfig { qps: 0.0, ..Default::default() };
        assert!(arrival_times(&bad, 4, &mut XorShift::new(1)).is_err());
        let bad = ArrivalConfig { diurnal_floor: 1.5, ..Default::default() };
        assert!(arrival_times(&bad, 4, &mut XorShift::new(1)).is_err());
    }
}
