//! Workload classes and mixes (DESIGN.md §Traffic).
//!
//! Each class models one serving population with its own prompt/output
//! length distributions and SLO posture:
//!
//! * **chat** — interactive conversations: short-to-medium prompts,
//!   medium generations, strict TTFT/TPOT;
//! * **rag** — retrieval-augmented long-prompt queries: the prompt
//!   carries stuffed context, so the TTFT target is relaxed (2× base)
//!   while the decode target stays strict;
//! * **agentic** — multi-turn tool-use sessions drawn from a small
//!   session pool; requests of one session share the affinity prefix
//!   ([`crate::coordinator::request::AFFINITY_PREFIX`]) and the context
//!   grows every turn — the workload KV-affinity routing is built for;
//! * **batch** — offline/background generation with no latency SLO:
//!   it fills troughs, contributes throughput, and is excluded from
//!   goodput by construction.
//!
//! A [`WorkloadMix`] is a weighted set of classes; the CLI grammar is
//! `chat+rag` or `chat:3+batch:1` (weights default to 1).

use crate::coordinator::request::SloTarget;

/// The built-in workload populations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClassKind {
    Chat,
    Rag,
    Agentic,
    Batch,
}

impl ClassKind {
    pub fn parse(s: &str) -> Option<ClassKind> {
        match s.to_ascii_lowercase().as_str() {
            "chat" => Some(ClassKind::Chat),
            "rag" | "long-prompt" => Some(ClassKind::Rag),
            "agentic" | "agent" | "multi-turn" => Some(ClassKind::Agentic),
            "batch" | "offline" => Some(ClassKind::Batch),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ClassKind::Chat => "chat",
            ClassKind::Rag => "rag",
            ClassKind::Agentic => "agentic",
            ClassKind::Batch => "batch",
        }
    }

    pub fn all() -> [ClassKind; 4] {
        [ClassKind::Chat, ClassKind::Rag, ClassKind::Agentic, ClassKind::Batch]
    }
}

/// One class of a [`WorkloadMix`]: sampling ranges plus SLO posture.
#[derive(Debug, Clone)]
pub struct ClassSpec {
    pub kind: ClassKind,
    /// Relative share of arrivals routed to this class.
    pub weight: f64,
    /// Prompt length range (tokens, inclusive; clamped to the serving
    /// model's admissible prompt at generation time).
    pub prompt_lo: usize,
    pub prompt_hi: usize,
    /// Generation budget range (tokens, inclusive).
    pub gen_lo: usize,
    pub gen_hi: usize,
    /// TTFT/TPOT multipliers on the base [`SloTarget`]; `None` = no
    /// latency SLO (offline work, excluded from goodput).
    pub slo_scale: Option<(f64, f64)>,
    /// Session pool size: requests draw a session and share its affinity
    /// prefix. 0 = every request gets a unique prefix.
    pub sessions: usize,
    /// Context tokens appended per session turn (agentic growth).
    pub turn_growth: usize,
}

impl ClassSpec {
    /// The calibrated default spec for a class.
    pub fn preset(kind: ClassKind) -> ClassSpec {
        match kind {
            ClassKind::Chat => ClassSpec {
                kind,
                weight: 1.0,
                prompt_lo: 96,
                prompt_hi: 768,
                gen_lo: 48,
                gen_hi: 192,
                slo_scale: Some((1.0, 1.0)),
                sessions: 0,
                turn_growth: 0,
            },
            ClassKind::Rag => ClassSpec {
                kind,
                weight: 1.0,
                prompt_lo: 1536,
                prompt_hi: 3584,
                gen_lo: 64,
                gen_hi: 160,
                slo_scale: Some((2.0, 1.0)),
                sessions: 0,
                turn_growth: 0,
            },
            ClassKind::Agentic => ClassSpec {
                kind,
                weight: 1.0,
                prompt_lo: 128,
                prompt_hi: 512,
                gen_lo: 24,
                gen_hi: 96,
                slo_scale: Some((1.0, 1.5)),
                sessions: 8,
                turn_growth: 96,
            },
            ClassKind::Batch => ClassSpec {
                kind,
                weight: 1.0,
                prompt_lo: 256,
                prompt_hi: 2048,
                gen_lo: 128,
                gen_hi: 384,
                slo_scale: None,
                sessions: 0,
                turn_growth: 0,
            },
        }
    }

    /// This class's per-request SLO, scaled off the fleet base target.
    pub fn slo_for(&self, base: Option<SloTarget>) -> Option<SloTarget> {
        match (base, self.slo_scale) {
            (Some(b), Some((ft, fp))) => {
                Some(SloTarget { ttft: b.ttft * ft, tpot: b.tpot * fp })
            }
            _ => None,
        }
    }
}

/// A weighted set of workload classes.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    pub classes: Vec<ClassSpec>,
}

impl WorkloadMix {
    /// Single-class mix from a preset.
    pub fn of(kind: ClassKind) -> WorkloadMix {
        WorkloadMix { classes: vec![ClassSpec::preset(kind)] }
    }

    /// Parse the CLI mix grammar: `chat+rag`, `chat:3+batch:1`. Weights
    /// default to 1 and must be positive; duplicate classes are rejected.
    pub fn parse(s: &str) -> Option<WorkloadMix> {
        let mut classes: Vec<ClassSpec> = Vec::new();
        for part in s.split('+') {
            let part = part.trim();
            if part.is_empty() {
                return None;
            }
            let (name, weight) = match part.split_once(':') {
                Some((n, w)) => (n, w.parse::<f64>().ok()?),
                None => (part, 1.0),
            };
            if !(weight > 0.0) {
                return None;
            }
            let kind = ClassKind::parse(name)?;
            if classes.iter().any(|c| c.kind == kind) {
                return None;
            }
            let mut spec = ClassSpec::preset(kind);
            spec.weight = weight;
            classes.push(spec);
        }
        if classes.is_empty() {
            None
        } else {
            Some(WorkloadMix { classes })
        }
    }

    /// Canonical display name (`chat+rag`).
    pub fn name(&self) -> String {
        self.classes
            .iter()
            .map(|c| c.kind.name())
            .collect::<Vec<_>>()
            .join("+")
    }

    /// Per-class sampling weights.
    pub fn weights(&self) -> Vec<f64> {
        self.classes.iter().map(|c| c.weight).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::Seconds;

    #[test]
    fn class_names_roundtrip() {
        for k in ClassKind::all() {
            assert_eq!(ClassKind::parse(k.name()), Some(k));
        }
        assert_eq!(ClassKind::parse("OFFLINE"), Some(ClassKind::Batch));
        assert!(ClassKind::parse("cryptomining").is_none());
    }

    #[test]
    fn mix_grammar_parses_weights_and_rejects_garbage() {
        let m = WorkloadMix::parse("chat+rag").unwrap();
        assert_eq!(m.classes.len(), 2);
        assert_eq!(m.name(), "chat+rag");
        assert_eq!(m.weights(), vec![1.0, 1.0]);

        let m = WorkloadMix::parse("chat:3+batch:1").unwrap();
        assert_eq!(m.weights(), vec![3.0, 1.0]);

        assert!(WorkloadMix::parse("").is_none());
        assert!(WorkloadMix::parse("chat+chat").is_none(), "duplicates rejected");
        assert!(WorkloadMix::parse("chat:-1").is_none(), "weights must be positive");
        assert!(WorkloadMix::parse("chat:zero").is_none());
        assert!(WorkloadMix::parse("warez+chat").is_none());
    }

    #[test]
    fn presets_are_internally_consistent() {
        for k in ClassKind::all() {
            let c = ClassSpec::preset(k);
            assert!(c.prompt_lo >= 1 && c.prompt_lo <= c.prompt_hi, "{:?}", k);
            assert!(c.gen_lo >= 1 && c.gen_lo <= c.gen_hi, "{:?}", k);
            assert!(c.weight > 0.0);
        }
        assert!(ClassSpec::preset(ClassKind::Batch).slo_scale.is_none());
        assert!(ClassSpec::preset(ClassKind::Agentic).sessions > 0);
    }

    #[test]
    fn slo_scaling_applies_per_class() {
        let base = Some(SloTarget { ttft: Seconds::ms(1000.0), tpot: Seconds::ms(50.0) });
        let rag = ClassSpec::preset(ClassKind::Rag).slo_for(base).unwrap();
        assert!((rag.ttft.as_ms() - 2000.0).abs() < 1e-9, "RAG TTFT is relaxed 2x");
        assert!((rag.tpot.as_ms() - 50.0).abs() < 1e-9);
        assert!(ClassSpec::preset(ClassKind::Batch).slo_for(base).is_none());
        assert!(ClassSpec::preset(ClassKind::Chat).slo_for(None).is_none());
    }
}
