//! Seedable, dependency-free pseudo-random source for the traffic engine
//! (DESIGN.md §Traffic).
//!
//! The offline build has no `rand` crate, and the serving experiments
//! demand bit-for-bit reproducibility (`--seed` on the CLI, fixed seeds
//! in the golden tests), so the engine carries its own generator:
//! xorshift64* seeded through a splitmix64 scramble so that nearby seeds
//! (0, 1, 2, …) still produce decorrelated streams.

/// The splitmix64 finaliser: one avalanche round. The single definition
/// shared by the seed scramble below and the contention ledger's
/// home-module hash ([`crate::fabric::contention`]), so the magic
/// constants cannot drift between copies.
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xorshift64* generator. Cheap, deterministic, and good enough for
/// workload synthesis (this is not a cryptographic source).
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Seed the generator. Any seed is valid, including 0 (the scramble
    /// maps it away from the forbidden all-zero xorshift state).
    pub fn new(seed: u64) -> Self {
        // splitmix64 finaliser: decorrelates consecutive small seeds.
        let z = splitmix64(seed);
        XorShift { state: if z == 0 { 0x9E3779B97F4A7C15 } else { z } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi] (inclusive). `lo > hi` is a caller bug.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "range {lo}..={hi} is empty");
        let span = hi - lo + 1;
        // Modulo bias is irrelevant at simulation spans (≪ 2^64).
        lo + if span == 0 { self.next_u64() } else { self.next_u64() % span }
    }

    /// Exponential variate with the given mean (inverse-CDF sampling).
    /// The draw uses 1 − u ∈ (0, 1] so ln never sees zero.
    pub fn exp(&mut self, mean: f64) -> f64 {
        debug_assert!(mean > 0.0, "exponential mean must be positive");
        -mean * (1.0 - self.next_f64()).ln()
    }

    /// Index drawn proportionally to `weights` (all non-negative, at
    /// least one positive).
    pub fn pick_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_stream() {
        let mut a = XorShift::new(7);
        let mut b = XorShift::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn nearby_seeds_decorrelate() {
        let mut a = XorShift::new(0);
        let mut b = XorShift::new(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0, "seeds 0 and 1 must not share draws");
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f64_stays_in_unit_interval() {
        let mut r = XorShift::new(42);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut r = XorShift::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(5, 8);
            assert!((5..=8).contains(&v));
            seen_lo |= v == 5;
            seen_hi |= v == 8;
        }
        assert!(seen_lo && seen_hi, "both endpoints must be reachable");
        assert_eq!(r.range(9, 9), 9);
    }

    #[test]
    fn exponential_mean_converges() {
        let mut r = XorShift::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| r.exp(0.25)).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn weighted_pick_respects_weights() {
        let mut r = XorShift::new(5);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.pick_weighted(&[1.0, 2.0, 1.0])] += 1;
        }
        // Middle class has weight 1/2; the edges 1/4 each.
        assert!((counts[1] as f64 / 30_000.0 - 0.5).abs() < 0.03);
        assert!(counts[0] > 0 && counts[2] > 0);
        // A zero-weight class is never drawn.
        let mut r = XorShift::new(6);
        for _ in 0..1000 {
            assert_ne!(r.pick_weighted(&[1.0, 0.0]), 1);
        }
    }
}
