//! CLI flag parsing for the `fenghuang` binary — extracted into the
//! library so the per-subcommand whitelists, bare-switch handling, and
//! conflict rules are unit-testable (`cargo test` covers what a typo'd
//! flag does *before* a user hits it).
//!
//! Arg parsing is hand-rolled; the offline build environment has no clap
//! (DESIGN.md §1). Every subcommand validates its flag set: unknown
//! flags and out-of-range values fail with actionable messages instead
//! of silently falling back to defaults.

use crate::config::{baseline8, fh4_15xm, fh4_20xm, FlashConfig, SystemConfig};
use crate::coordinator::prefix_cache::PrefixCacheConfig;
use crate::coordinator::tenancy::{TenantArbitration, TenantsConfig};
use crate::error::{FhError, Result};
use crate::fabric::contention::{ContentionConfig, ContentionMode};
use crate::faults::FaultSchedule;
use crate::telemetry::TelemetryConfig;
use crate::units::{Bandwidth, Bytes, Seconds};
use std::collections::HashMap;

/// Flags understood by `fenghuang simulate`.
pub const SIMULATE_FLAGS: &[&str] = &["model", "system", "remote-tbps", "batch", "prompt", "gen"];

/// Flags understood by `fenghuang serve`.
pub const SERVE_FLAGS: &[&str] = &[
    "model",
    "requests",
    "max-batch",
    "replicas",
    "policy",
    "disaggregate",
    "sessions",
    "kv-budget-gb",
    "prefix-cache",
    "prefix-cache-gb",
    "qps",
    "pattern",
    "mix",
    "slo-ttft-ms",
    "slo-tpot-ms",
    "autoscale",
    "autoscale-min",
    "shed-tokens",
    "seed",
    "fabric-contention",
    "flash-gb",
    "flash-bw",
    "faults",
    "tenants",
    "tenant-mode",
    "admit-tokens",
    "telemetry",
    "telemetry-interval-ms",
    "trace-out",
    "timeseries-out",
];

/// Serve flags that may appear without a value (`--autoscale` ≡
/// `--autoscale on`, `--prefix-cache` ≡ `--prefix-cache on`,
/// `--fabric-contention` ≡ `--fabric-contention shared`,
/// `--telemetry` ≡ `--telemetry on`).
pub const SERVE_BARE: &[&str] = &["autoscale", "prefix-cache", "fabric-contention", "telemetry"];

/// Any of these flags routes `serve` through the open-loop traffic
/// engine instead of the legacy fixed-gap workload.
pub const TRAFFIC_FLAGS: &[&str] = &[
    "qps",
    "pattern",
    "mix",
    "slo-ttft-ms",
    "slo-tpot-ms",
    "autoscale",
    "autoscale-min",
    "shed-tokens",
    "seed",
    "telemetry",
    "telemetry-interval-ms",
    "trace-out",
    "timeseries-out",
];

/// Flags understood by `fenghuang page`.
pub const PAGE_FLAGS: &[&str] = &[
    "model",
    "system",
    "remote-tbps",
    "batch",
    "phase",
    "kv-len",
    "prompt",
    "local-gb",
    "policy",
    "window",
    "steps",
    "page-mib",
    "pin-frac",
    "page-kv",
    "nmc",
    "fabric-contention",
    "flash-gb",
    "flash-bw",
    "pool-gb",
];

/// Page flags that may appear without a value.
pub const PAGE_BARE: &[&str] = &["fabric-contention"];

pub fn cli_err(msg: String) -> FhError {
    FhError::Config(msg)
}

/// Parse `--key value` pairs after the subcommand, rejecting flags the
/// subcommand does not understand (a typo'd flag must not silently fall
/// back to a default). Flags listed in `bare` are switches: they may
/// stand alone (`--autoscale`), in which case they read as "on".
pub fn parse_flags(
    cmd: &str,
    args: &[String],
    allowed: &[&str],
    bare: &[&str],
) -> Result<HashMap<String, String>> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let k = &args[i];
        if !k.starts_with("--") {
            return Err(cli_err(format!("unexpected argument '{k}' (flags are --key value)")));
        }
        let key = k.trim_start_matches("--").to_string();
        if !allowed.contains(&key.as_str()) {
            let mut expected: Vec<String> =
                allowed.iter().map(|a| format!("--{a}")).collect();
            expected.sort();
            return Err(cli_err(format!(
                "unknown flag --{key} for '{cmd}' (expected one of: {})",
                expected.join(", ")
            )));
        }
        let next = args.get(i + 1);
        if bare.contains(&key.as_str()) && next.map_or(true, |v| v.starts_with("--")) {
            flags.insert(key, "on".to_string());
            i += 1;
            continue;
        }
        let v = next.ok_or_else(|| cli_err(format!("flag {k} needs a value")))?;
        flags.insert(key, v.clone());
        i += 2;
    }
    Ok(flags)
}

/// Typed flag lookup with a default.
pub fn flag<T: std::str::FromStr>(
    flags: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        Some(v) => v.parse().map_err(|e| cli_err(format!("--{key}: {e}"))),
        None => Ok(default),
    }
}

/// A flag that must parse to a value ≥ 1 (counts, sizes).
pub fn positive<T>(flags: &HashMap<String, String>, key: &str, default: T) -> Result<T>
where
    T: std::str::FromStr + PartialOrd + From<u8> + std::fmt::Display,
    T::Err: std::fmt::Display,
{
    let v = flag(flags, key, default)?;
    if v < T::from(1u8) {
        return Err(cli_err(format!("--{key} must be ≥ 1, got {v}")));
    }
    Ok(v)
}

/// An on/off switch flag (absent = off; bare = on via [`parse_flags`]).
pub fn switch(flags: &HashMap<String, String>, key: &str) -> Result<bool> {
    match flags.get(key).map(|s| s.to_ascii_lowercase()) {
        None => Ok(false),
        Some(v) => match v.as_str() {
            "on" | "true" | "1" | "yes" => Ok(true),
            "off" | "false" | "0" | "no" => Ok(false),
            other => Err(cli_err(format!("--{key} wants on|off, got '{other}'"))),
        },
    }
}

/// Resolve a `--system` preset name.
pub fn system_by_name(name: &str, remote_tbps: f64) -> Result<SystemConfig> {
    let bw = Bandwidth::tbps(remote_tbps);
    match name.to_ascii_lowercase().as_str() {
        "baseline8" => Ok(baseline8()),
        "fh4-1.5xm" | "fh4_15xm" => Ok(fh4_15xm(bw)),
        "fh4-2.0xm" | "fh4_20xm" => Ok(fh4_20xm(bw)),
        other => Err(cli_err(format!(
            "unknown system preset '{other}' (expected baseline8, fh4-1.5xm or fh4-2.0xm)"
        ))),
    }
}

/// Parse `--disaggregate P:D` (prefill:decode pool sizes).
pub fn parse_disaggregate(v: &str) -> Result<(usize, usize)> {
    let (p, d) = v
        .split_once(':')
        .ok_or_else(|| cli_err(format!("--disaggregate wants P:D, got '{v}'")))?;
    let p: usize = p.parse().map_err(|e| cli_err(format!("--disaggregate prefill: {e}")))?;
    let d: usize = d.parse().map_err(|e| cli_err(format!("--disaggregate decode: {e}")))?;
    if p == 0 || d == 0 {
        return Err(cli_err(format!(
            "--disaggregate pools must be non-empty, got {p}:{d}"
        )));
    }
    Ok((p, d))
}

/// Reject an explicit `--replicas` that contradicts `--disaggregate P:D`
/// (the pools define the fleet; a conflicting count must not be silently
/// ignored).
pub fn check_disaggregate_replicas(
    flags: &HashMap<String, String>,
    replicas: usize,
    (p, d): (usize, usize),
) -> Result<()> {
    if flags.contains_key("replicas") && p + d != replicas {
        return Err(cli_err(format!(
            "--replicas {replicas} conflicts with --disaggregate {p}:{d} \
             (the pools make a {}-replica fleet; drop --replicas or make them agree)",
            p + d
        )));
    }
    Ok(())
}

/// Build the shared prefix-cache config from `--prefix-cache [on|off]`
/// and `--prefix-cache-gb G` (DESIGN.md §Prefix-Cache). A bare
/// `--prefix-cache` enables the default pool share; `--prefix-cache-gb`
/// both enables the cache and pins its capacity; an explicit
/// `--prefix-cache off` alongside a capacity is a conflict.
pub fn parse_prefix_cache(flags: &HashMap<String, String>) -> Result<Option<PrefixCacheConfig>> {
    let explicit = flags.contains_key("prefix-cache");
    let on = switch(flags, "prefix-cache")?;
    let capacity = match flags.get("prefix-cache-gb") {
        Some(v) => {
            let gb: f64 =
                v.parse().map_err(|e| cli_err(format!("--prefix-cache-gb: {e}")))?;
            if gb <= 0.0 {
                return Err(cli_err(format!("--prefix-cache-gb must be > 0, got {gb}")));
            }
            if explicit && !on {
                return Err(cli_err(
                    "--prefix-cache-gb conflicts with --prefix-cache off".into(),
                ));
            }
            Some(Bytes::gb(gb))
        }
        None => None,
    };
    if !on && capacity.is_none() {
        return Ok(None);
    }
    Ok(Some(PrefixCacheConfig { capacity, ..Default::default() }))
}

/// Build the shared-fabric arbitration config from
/// `--fabric-contention [off|shared|per-module]`
/// (DESIGN.md §Fabric-Contention). A bare switch reads as `shared`; the
/// default is off — every fabric charge stays unloaded and bit-identical.
pub fn parse_fabric_contention(flags: &HashMap<String, String>) -> Result<ContentionConfig> {
    match flags.get("fabric-contention") {
        None => Ok(ContentionConfig::default()),
        Some(v) => {
            let mode = ContentionMode::parse(v).ok_or_else(|| {
                cli_err(format!(
                    "--fabric-contention wants off, shared or per-module, got '{v}'"
                ))
            })?;
            Ok(ContentionConfig { mode, ..Default::default() })
        }
    }
}

/// Build the high-bandwidth flash tier from `--flash-gb G` and
/// `--flash-bw TBPS` (DESIGN.md §Tiering). `--flash-gb` alone takes the
/// HBF default bandwidth ([`crate::config::DEFAULT_FLASH_TBPS`]);
/// `--flash-bw` without a capacity is a conflict — a bandwidth alone
/// does not define a tier.
pub fn parse_flash(flags: &HashMap<String, String>) -> Result<Option<FlashConfig>> {
    let gb = match flags.get("flash-gb") {
        Some(v) => {
            let gb: f64 = v.parse().map_err(|e| cli_err(format!("--flash-gb: {e}")))?;
            if gb <= 0.0 {
                return Err(cli_err(format!("--flash-gb must be > 0, got {gb}")));
            }
            Some(gb)
        }
        None => None,
    };
    let bw = match flags.get("flash-bw") {
        Some(v) => {
            let tbps: f64 = v.parse().map_err(|e| cli_err(format!("--flash-bw: {e}")))?;
            if tbps <= 0.0 {
                return Err(cli_err(format!("--flash-bw must be > 0 TB/s, got {tbps}")));
            }
            Some(tbps)
        }
        None => None,
    };
    match (gb, bw) {
        (Some(gb), Some(tbps)) => Ok(Some(FlashConfig {
            capacity: Bytes::gb(gb),
            bandwidth: Bandwidth::tbps(tbps),
        })),
        (Some(gb), None) => Ok(Some(FlashConfig::gb(gb))),
        (None, Some(_)) => Err(cli_err(
            "--flash-bw needs --flash-gb (a bandwidth alone does not define a flash tier)"
                .into(),
        )),
        (None, None) => Ok(None),
    }
}

/// Build the fault schedule from `--faults SPEC` (DESIGN.md §Faults),
/// validated against the fleet size. An absent flag is `None` — the
/// cluster's fault paths stay a strict bit-identical passthrough.
pub fn parse_faults(
    flags: &HashMap<String, String>,
    replicas: usize,
) -> Result<Option<FaultSchedule>> {
    match flags.get("faults") {
        None => Ok(None),
        Some(v) => Ok(Some(FaultSchedule::parse(v, replicas)?)),
    }
}

/// Build the multi-tenant config from `--tenants SPEC`, `--tenant-mode
/// wfq|fifo` and `--admit-tokens N` (DESIGN.md §Multi-Tenant). An absent
/// `--tenants` is `None` — the single-model serving paths stay a strict
/// bit-identical passthrough — and makes the companion flags conflicts
/// rather than silent no-ops.
pub fn parse_tenants(flags: &HashMap<String, String>) -> Result<Option<TenantsConfig>> {
    let Some(spec) = flags.get("tenants") else {
        for k in ["tenant-mode", "admit-tokens"] {
            if flags.contains_key(k) {
                return Err(cli_err(format!("--{k} needs --tenants")));
            }
        }
        return Ok(None);
    };
    let mut tc = TenantsConfig::parse(spec)?;
    if let Some(v) = flags.get("tenant-mode") {
        tc.arbitration = TenantArbitration::parse(v).ok_or_else(|| {
            cli_err(format!("--tenant-mode wants wfq or fifo, got '{v}'"))
        })?;
    }
    if let Some(v) = flags.get("admit-tokens") {
        let gate: u64 = v.parse().map_err(|e| cli_err(format!("--admit-tokens: {e}")))?;
        if gate == 0 {
            return Err(cli_err("--admit-tokens must be ≥ 1 token".into()));
        }
        tc.admit_tokens = Some(gate);
    }
    Ok(Some(tc))
}

/// Build the telemetry config from `--telemetry [on|off]`,
/// `--telemetry-interval-ms MS`, `--trace-out PATH` and
/// `--timeseries-out PATH` (DESIGN.md §Telemetry). An absent
/// `--telemetry` is `None` — the observability paths stay a strict
/// bit-identical passthrough — and makes the companion flags conflicts
/// rather than silent no-ops (an export path on a run that records
/// nothing must not produce an empty file).
pub fn parse_telemetry(flags: &HashMap<String, String>) -> Result<Option<TelemetryConfig>> {
    let explicit = flags.contains_key("telemetry");
    let on = switch(flags, "telemetry")?;
    if !on {
        for k in ["telemetry-interval-ms", "trace-out", "timeseries-out"] {
            if flags.contains_key(k) {
                return Err(cli_err(if explicit {
                    format!("--{k} conflicts with --telemetry off")
                } else {
                    format!("--{k} needs --telemetry")
                }));
            }
        }
        return Ok(None);
    }
    let mut tel = TelemetryConfig::default();
    if let Some(v) = flags.get("telemetry-interval-ms") {
        let ms: f64 =
            v.parse().map_err(|e| cli_err(format!("--telemetry-interval-ms: {e}")))?;
        tel.interval = Seconds::ms(ms);
    }
    tel.validate()?;
    Ok(Some(tel))
}

/// Reject active fabric contention on a shared-nothing system: there is
/// no shared TAB pool to arbitrate (the same rule `FabricClock` enforces,
/// surfaced at flag-validation time with the preset's name).
pub fn check_contention_fabric(sys: &SystemConfig, cfg: &ContentionConfig) -> Result<()> {
    if cfg.mode != ContentionMode::Off && !sys.is_fenghuang() {
        return Err(cli_err(format!(
            "--fabric-contention {} models the shared TAB pool, but system '{}' is \
             shared-nothing (pick a fh4 system or drop the flag)",
            cfg.mode.name(),
            sys.name
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn unknown_flags_fail_with_the_whitelist() {
        let e = parse_flags("serve", &args(&["--replica", "4"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("unknown flag --replica"), "{msg}");
        assert!(msg.contains("--replicas"), "message must list valid flags: {msg}");
        // Non-flag positional arguments are rejected too.
        let e = parse_flags("serve", &args(&["gpt3"]), SERVE_FLAGS, SERVE_BARE).unwrap_err();
        assert!(e.to_string().contains("unexpected argument"), "{e}");
        // A value-taking flag at the end of the line needs its value.
        let e = parse_flags("serve", &args(&["--model"]), SERVE_FLAGS, SERVE_BARE).unwrap_err();
        assert!(e.to_string().contains("needs a value"), "{e}");
    }

    #[test]
    fn bare_switches_read_as_on() {
        let f = parse_flags(
            "serve",
            &args(&["--autoscale", "--replicas", "4"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert_eq!(f.get("autoscale").map(String::as_str), Some("on"));
        assert_eq!(f.get("replicas").map(String::as_str), Some("4"));
        assert!(switch(&f, "autoscale").unwrap());
        // Trailing bare switch.
        let f = parse_flags("serve", &args(&["--prefix-cache"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert!(switch(&f, "prefix-cache").unwrap());
        // Explicit value still accepted.
        let f = parse_flags(
            "serve",
            &args(&["--prefix-cache", "off"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert!(!switch(&f, "prefix-cache").unwrap());
        // Garbage switch values are rejected.
        let f = parse_flags(
            "serve",
            &args(&["--autoscale", "sideways"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert!(switch(&f, "autoscale").is_err());
    }

    #[test]
    fn typed_and_positive_flags_validate() {
        let f = parse_flags("serve", &args(&["--requests", "12"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert_eq!(positive::<usize>(&f, "requests", 64).unwrap(), 12);
        assert_eq!(positive::<usize>(&f, "replicas", 3).unwrap(), 3, "default passes through");
        let f = parse_flags("serve", &args(&["--requests", "0"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert!(positive::<usize>(&f, "requests", 64).is_err());
        let f = parse_flags("serve", &args(&["--requests", "many"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert!(flag::<usize>(&f, "requests", 64).is_err());
    }

    #[test]
    fn disaggregate_parses_and_conflicts_with_replicas() {
        assert_eq!(parse_disaggregate("2:2").unwrap(), (2, 2));
        assert_eq!(parse_disaggregate("1:7").unwrap(), (1, 7));
        assert!(parse_disaggregate("4").is_err());
        assert!(parse_disaggregate("0:4").is_err());
        assert!(parse_disaggregate("2:0").is_err());
        assert!(parse_disaggregate("a:b").is_err());
        // Explicit but agreeing --replicas is fine; disagreeing is not;
        // absent --replicas never conflicts.
        let f = parse_flags(
            "serve",
            &args(&["--replicas", "4", "--disaggregate", "2:2"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert!(check_disaggregate_replicas(&f, 4, (2, 2)).is_ok());
        assert!(check_disaggregate_replicas(&f, 4, (3, 2)).is_err());
        let f = parse_flags("serve", &args(&["--disaggregate", "3:2"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert!(check_disaggregate_replicas(&f, 1, (3, 2)).is_ok());
    }

    #[test]
    fn prefix_cache_flags_build_the_config() {
        // Absent → no cache.
        let f = parse_flags("serve", &args(&[]), SERVE_FLAGS, SERVE_BARE).unwrap();
        assert!(parse_prefix_cache(&f).unwrap().is_none());
        // Bare switch → defaults (pool-share capacity).
        let f = parse_flags("serve", &args(&["--prefix-cache"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        let pc = parse_prefix_cache(&f).unwrap().unwrap();
        assert!(pc.capacity.is_none());
        assert!((pc.pool_share - PrefixCacheConfig::default().pool_share).abs() < 1e-12);
        // Explicit capacity implies the cache.
        let f = parse_flags(
            "serve",
            &args(&["--prefix-cache-gb", "32"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let pc = parse_prefix_cache(&f).unwrap().unwrap();
        assert_eq!(pc.capacity, Some(Bytes::gb(32.0)));
        // Explicit off keeps it off; off + capacity is a conflict.
        let f = parse_flags(
            "serve",
            &args(&["--prefix-cache", "off"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert!(parse_prefix_cache(&f).unwrap().is_none());
        let f = parse_flags(
            "serve",
            &args(&["--prefix-cache", "off", "--prefix-cache-gb", "8"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        assert!(parse_prefix_cache(&f).is_err());
        // Bad capacities are rejected.
        for bad in ["0", "-3", "plenty"] {
            let f = parse_flags(
                "serve",
                &args(&["--prefix-cache-gb", bad]),
                SERVE_FLAGS,
                SERVE_BARE,
            )
            .unwrap();
            assert!(parse_prefix_cache(&f).is_err(), "--prefix-cache-gb {bad} must fail");
        }
    }

    #[test]
    fn fabric_contention_flag_family_parses_and_conflicts() {
        // Absent → Off (the bit-identical default).
        let f = parse_flags("serve", &args(&[]), SERVE_FLAGS, SERVE_BARE).unwrap();
        assert_eq!(parse_fabric_contention(&f).unwrap().mode, ContentionMode::Off);
        // Bare switch defaults to shared arbitration.
        let f = parse_flags("serve", &args(&["--fabric-contention"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        assert_eq!(parse_fabric_contention(&f).unwrap().mode, ContentionMode::Shared);
        // Explicit modes.
        for (v, want) in [
            ("off", ContentionMode::Off),
            ("shared", ContentionMode::Shared),
            ("per-module", ContentionMode::PerModule),
        ] {
            let f = parse_flags(
                "serve",
                &args(&["--fabric-contention", v]),
                SERVE_FLAGS,
                SERVE_BARE,
            )
            .unwrap();
            assert_eq!(parse_fabric_contention(&f).unwrap().mode, want, "mode {v}");
        }
        // Unknown mode is rejected with the expected vocabulary.
        let f = parse_flags(
            "serve",
            &args(&["--fabric-contention", "turbo"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let e = parse_fabric_contention(&f).unwrap_err().to_string();
        assert!(e.contains("per-module"), "{e}");
        // The page subcommand takes the same family as a bare switch.
        let f = parse_flags(
            "page",
            &args(&["--fabric-contention", "--model", "gpt3"]),
            PAGE_FLAGS,
            PAGE_BARE,
        )
        .unwrap();
        let cfg = parse_fabric_contention(&f).unwrap();
        assert_eq!(cfg.mode, ContentionMode::Shared);
        // Active contention conflicts with shared-nothing systems; Off
        // and TAB systems pass.
        assert!(check_contention_fabric(&baseline8(), &cfg).is_err());
        let e = check_contention_fabric(&baseline8(), &cfg).unwrap_err().to_string();
        assert!(e.contains("Baseline8"), "{e}");
        check_contention_fabric(&fh4_15xm(Bandwidth::tbps(4.8)), &cfg).unwrap();
        check_contention_fabric(&baseline8(), &ContentionConfig::default()).unwrap();
    }

    #[test]
    fn system_presets_resolve_case_insensitively() {
        assert_eq!(system_by_name("baseline8", 4.8).unwrap().name, "Baseline8");
        assert_eq!(system_by_name("FH4-1.5xM", 4.8).unwrap().name, "FH4-1.5xM");
        assert_eq!(system_by_name("fh4_20xm", 6.4).unwrap().name, "FH4-2.0xM");
        assert!(system_by_name("tpu-pod", 4.8).is_err());
    }

    #[test]
    fn faults_flag_builds_the_schedule() {
        // Absent → None: the fault paths stay passthrough.
        let f = parse_flags("serve", &args(&[]), SERVE_FLAGS, SERVE_BARE).unwrap();
        assert!(parse_faults(&f, 4).unwrap().is_none());
        // An explicit schedule parses against the fleet size.
        let f = parse_flags(
            "serve",
            &args(&["--faults", "crash@0.5:r1:repair0.2,window=0.1"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let fs = parse_faults(&f, 4).unwrap().unwrap();
        assert_eq!(fs.events.len(), 1);
        // A crash target outside the fleet is rejected at parse time.
        assert!(parse_faults(&f, 1).is_err());
        // Garbage specs fail with the grammar vocabulary.
        let f = parse_flags("serve", &args(&["--faults", "meteor@1"]), SERVE_FLAGS, SERVE_BARE)
            .unwrap();
        let e = parse_faults(&f, 4).unwrap_err().to_string();
        assert!(e.contains("crash@"), "{e}");
    }

    #[test]
    fn whitelists_cover_the_documented_surface() {
        // The traffic selector flags must all be valid serve flags, and
        // every bare switch must be in the whitelist too — otherwise a
        // documented flag would be unreachable.
        for k in TRAFFIC_FLAGS {
            assert!(SERVE_FLAGS.contains(k), "--{k} missing from SERVE_FLAGS");
        }
        for k in SERVE_BARE {
            assert!(SERVE_FLAGS.contains(k), "--{k} missing from SERVE_FLAGS");
        }
        for k in PAGE_BARE {
            assert!(PAGE_FLAGS.contains(k), "--{k} missing from PAGE_FLAGS");
        }
        assert!(SERVE_FLAGS.contains(&"prefix-cache"));
        assert!(SERVE_FLAGS.contains(&"prefix-cache-gb"));
        assert!(SERVE_FLAGS.contains(&"fabric-contention"));
        assert!(SERVE_FLAGS.contains(&"faults"));
        assert!(PAGE_FLAGS.contains(&"fabric-contention"));
        // The flash-tier family is reachable from both subcommands; the
        // pool cap only makes sense where the paging orchestrator runs.
        for k in ["flash-gb", "flash-bw"] {
            assert!(SERVE_FLAGS.contains(&k), "--{k} missing from SERVE_FLAGS");
            assert!(PAGE_FLAGS.contains(&k), "--{k} missing from PAGE_FLAGS");
        }
        assert!(PAGE_FLAGS.contains(&"pool-gb"));
        assert!(!SERVE_FLAGS.contains(&"pool-gb"));
        // The multi-tenant family is serve-only.
        for k in ["tenants", "tenant-mode", "admit-tokens"] {
            assert!(SERVE_FLAGS.contains(&k), "--{k} missing from SERVE_FLAGS");
            assert!(!PAGE_FLAGS.contains(&k), "--{k} leaked into PAGE_FLAGS");
        }
        // The telemetry family is serve-only and rides the traffic engine.
        for k in ["telemetry", "telemetry-interval-ms", "trace-out", "timeseries-out"] {
            assert!(SERVE_FLAGS.contains(&k), "--{k} missing from SERVE_FLAGS");
            assert!(TRAFFIC_FLAGS.contains(&k), "--{k} missing from TRAFFIC_FLAGS");
            assert!(!PAGE_FLAGS.contains(&k), "--{k} leaked into PAGE_FLAGS");
        }
        assert!(SERVE_BARE.contains(&"telemetry"));
    }

    #[test]
    fn telemetry_flag_family_builds_the_config() {
        // Absent → None: the observability paths stay passthrough.
        let f = parse_flags("serve", &args(&[]), SERVE_FLAGS, SERVE_BARE).unwrap();
        assert!(parse_telemetry(&f).unwrap().is_none());
        // Bare switch → defaults.
        let f = parse_flags("serve", &args(&["--telemetry"]), SERVE_FLAGS, SERVE_BARE).unwrap();
        let tel = parse_telemetry(&f).unwrap().unwrap();
        assert_eq!(tel.interval, TelemetryConfig::default().interval);
        // Explicit interval override.
        let f = parse_flags(
            "serve",
            &args(&["--telemetry", "--telemetry-interval-ms", "25"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let tel = parse_telemetry(&f).unwrap().unwrap();
        assert_eq!(tel.interval, Seconds::ms(25.0));
        // Companion flags without --telemetry are conflicts, not no-ops;
        // so is an explicit off alongside them.
        for lone in [
            ["--telemetry-interval-ms", "50"],
            ["--trace-out", "t.json"],
            ["--timeseries-out", "t.csv"],
        ] {
            let f = parse_flags("serve", &args(&lone), SERVE_FLAGS, SERVE_BARE).unwrap();
            let e = parse_telemetry(&f).unwrap_err().to_string();
            assert!(e.contains("--telemetry"), "{e}");
        }
        let f = parse_flags(
            "serve",
            &args(&["--telemetry", "off", "--trace-out", "t.json"]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let e = parse_telemetry(&f).unwrap_err().to_string();
        assert!(e.contains("conflicts"), "{e}");
        // Non-positive and garbage intervals are rejected.
        for bad in ["0", "-10", "soon"] {
            let f = parse_flags(
                "serve",
                &args(&["--telemetry", "--telemetry-interval-ms", bad]),
                SERVE_FLAGS,
                SERVE_BARE,
            )
            .unwrap();
            assert!(parse_telemetry(&f).is_err(), "--telemetry-interval-ms {bad} must fail");
        }
    }

    #[test]
    fn tenants_flag_family_builds_the_config() {
        // Absent → None: single-model serving stays passthrough.
        let f = parse_flags("serve", &args(&[]), SERVE_FLAGS, SERVE_BARE).unwrap();
        assert!(parse_tenants(&f).unwrap().is_none());
        // A two-tenant spec with QoS knobs parses end to end.
        let f = parse_flags(
            "serve",
            &args(&[
                "--tenants",
                "alpha/gpt2/weight=3/mix=chat,beta/gpt2-xl/quota=500000/mix=batch",
                "--tenant-mode",
                "fifo",
                "--admit-tokens",
                "2048",
            ]),
            SERVE_FLAGS,
            SERVE_BARE,
        )
        .unwrap();
        let tc = parse_tenants(&f).unwrap().unwrap();
        assert_eq!(tc.tenants.len(), 2);
        assert_eq!(tc.tenants[0].name, "alpha");
        assert!((tc.tenants[0].weight - 3.0).abs() < 1e-12);
        assert_eq!(tc.tenants[1].quota_tokens, Some(500_000));
        assert_eq!(tc.arbitration, TenantArbitration::Fifo);
        assert_eq!(tc.admit_tokens, Some(2048));
        // Companion flags without --tenants are conflicts, not no-ops.
        for lone in [["--tenant-mode", "wfq"], ["--admit-tokens", "1024"]] {
            let f = parse_flags("serve", &args(&lone), SERVE_FLAGS, SERVE_BARE).unwrap();
            let e = parse_tenants(&f).unwrap_err().to_string();
            assert!(e.contains("--tenants"), "{e}");
        }
        // Bad values are rejected with the grammar vocabulary.
        for bad in [
            ["--tenants", "alpha/gpt2", "--tenant-mode", "strict"].as_slice(),
            ["--tenants", "alpha/gpt2", "--admit-tokens", "0"].as_slice(),
            ["--tenants", "alpha/no-such-model"].as_slice(),
            ["--tenants", "alpha/gpt2/weight=-1"].as_slice(),
        ] {
            let f = parse_flags("serve", &args(bad), SERVE_FLAGS, SERVE_BARE).unwrap();
            assert!(parse_tenants(&f).is_err(), "{bad:?} must fail");
        }
    }

    #[test]
    fn flash_flag_family_builds_the_tier() {
        use crate::config::DEFAULT_FLASH_TBPS;
        // Absent → None: the 2-tier model, bit-identically.
        let f = parse_flags("page", &args(&[]), PAGE_FLAGS, PAGE_BARE).unwrap();
        assert!(parse_flash(&f).unwrap().is_none());
        // Capacity alone takes the HBF default bandwidth.
        let f = parse_flags("page", &args(&["--flash-gb", "1024"]), PAGE_FLAGS, PAGE_BARE)
            .unwrap();
        let fc = parse_flash(&f).unwrap().unwrap();
        assert_eq!(fc.capacity, Bytes::gb(1024.0));
        assert_eq!(fc.bandwidth, Bandwidth::tbps(DEFAULT_FLASH_TBPS));
        // Both knobs together.
        let f = parse_flags(
            "page",
            &args(&["--flash-gb", "512", "--flash-bw", "0.8"]),
            PAGE_FLAGS,
            PAGE_BARE,
        )
        .unwrap();
        let fc = parse_flash(&f).unwrap().unwrap();
        assert_eq!(fc.capacity, Bytes::gb(512.0));
        assert_eq!(fc.bandwidth, Bandwidth::tbps(0.8));
        // Bandwidth without a capacity is a conflict, not a default.
        let f = parse_flags("page", &args(&["--flash-bw", "1.6"]), PAGE_FLAGS, PAGE_BARE)
            .unwrap();
        let e = parse_flash(&f).unwrap_err().to_string();
        assert!(e.contains("--flash-gb"), "{e}");
        // Non-positive and garbage values are rejected.
        for bad in [
            ["--flash-gb", "0"].as_slice(),
            ["--flash-gb", "-4"].as_slice(),
            ["--flash-gb", "64", "--flash-bw", "fast"].as_slice(),
            ["--flash-gb", "64", "--flash-bw", "-1"].as_slice(),
        ] {
            let f = parse_flags("page", &args(bad), PAGE_FLAGS, PAGE_BARE).unwrap();
            assert!(parse_flash(&f).is_err(), "{bad:?} must fail");
        }
    }
}
