//! Fault observables: what a faulted run reports (DESIGN.md §Faults).
//!
//! The recovery metrics are computed from a *completion trace* — one
//! [`CompletionEvent`] per finished request, recorded by both cluster
//! cores only when a schedule is active (healthy runs record nothing,
//! preserving the passthrough guarantee). The trace is cut into fixed
//! windows from the first fault instant; per-window SLO attainment
//! against the pre-fault baseline yields the dip, the recovery time and
//! the goodput lost.

use super::schedule::FaultSchedule;
use crate::units::{Bytes, Seconds};

/// One completed request, as the recovery report sees it. The
/// multi-tenant layer reuses the same trace (armed whenever tenants are
/// configured) to slice completions per tenant, so the event also
/// carries the owning tenant and the observed TTFT.
#[derive(Debug, Clone, Copy)]
pub struct CompletionEvent {
    /// Virtual completion time.
    pub at: Seconds,
    /// Tokens generated (the goodput contribution when the SLO held).
    pub tokens: u64,
    /// SLO verdict (`None` when the request carried no target).
    pub slo: Option<bool>,
    /// Owning tenant (0 on single-tenant fleets).
    pub tenant: usize,
    /// Time to first token, for per-tenant tail-latency reporting.
    pub ttft: Seconds,
}

/// Windowed-attainment recovery metrics ([`recovery_stats`]).
#[derive(Debug, Clone, Copy)]
pub struct RecoveryStats {
    /// SLO attainment over completions before the first fault (1.0
    /// when nothing with an SLO completed pre-fault).
    pub baseline_attainment: f64,
    /// Worst per-window attainment from the first fault on.
    pub dip_attainment: f64,
    /// `max(0, baseline − dip)` — the headline availability hit.
    pub slo_dip: f64,
    /// First fault → end of the last window whose attainment sat below
    /// `baseline − ε`. `Some(0)` when attainment never dipped; `None`
    /// when the run ended still dipped (see `recovered`).
    pub recovery_time: Option<Seconds>,
    /// Whether attainment returned within ε of the baseline before the
    /// run ended.
    pub recovered: bool,
    /// Σ over windows of `max(0, baseline_rate × span − slo-met
    /// tokens)`: goodput the pre-fault trajectory promised but the
    /// faulted fleet did not deliver.
    pub goodput_lost_tokens: f64,
}

/// Cut `completions` (time-sorted) into `window`-wide slices from the
/// first fault instant and score SLO attainment per slice against the
/// pre-fault baseline. `end` is the run's makespan; the last (possibly
/// partial) window is pro-rated in the goodput integral.
pub fn recovery_stats(
    completions: &[CompletionEvent],
    first_fault: Seconds,
    end: Seconds,
    window: Seconds,
    epsilon: f64,
) -> RecoveryStats {
    let ff = first_fault.value();
    let w = window.value();
    debug_assert!(w > 0.0, "fault report window must be positive");

    // Pre-fault baseline: attainment and the goodput rate to hold the
    // faulted windows against.
    let mut base_met = 0u64;
    let mut base_total = 0u64;
    let mut base_tokens = 0.0f64;
    for c in completions {
        if c.at.value() >= ff {
            break;
        }
        if let Some(met) = c.slo {
            base_total += 1;
            if met {
                base_met += 1;
                base_tokens += c.tokens as f64;
            }
        }
    }
    let baseline = if base_total == 0 { 1.0 } else { base_met as f64 / base_total as f64 };
    let base_rate = if ff > 0.0 { base_tokens / ff } else { 0.0 };

    let end_s = end.value().max(ff);
    let k0 = (ff / w).floor() as u64;
    let mut i = completions.partition_point(|c| c.at.value() < k0 as f64 * w);
    let mut dip = f64::INFINITY;
    let mut last_bad: Option<u64> = None;
    let mut last_data: Option<u64> = None;
    let mut goodput_lost = 0.0f64;
    let mut k = k0;
    loop {
        let wstart = k as f64 * w;
        let wend = wstart + w;
        let mut met = 0u64;
        let mut total = 0u64;
        let mut met_tokens = 0.0f64;
        while i < completions.len() && completions[i].at.value() < wend {
            if let Some(m) = completions[i].slo {
                total += 1;
                if m {
                    met += 1;
                    met_tokens += completions[i].tokens as f64;
                }
            }
            i += 1;
        }
        let span = (end_s.min(wend) - wstart).clamp(0.0, w);
        goodput_lost += (base_rate * span - met_tokens).max(0.0);
        if total > 0 {
            let att = met as f64 / total as f64;
            dip = dip.min(att);
            last_data = Some(k);
            if att < baseline - epsilon {
                last_bad = Some(k);
            }
        }
        k += 1;
        if k as f64 * w > end_s {
            break;
        }
    }
    if !dip.is_finite() {
        dip = baseline; // no post-fault data: nothing observable dipped
    }
    let (recovery_time, recovered) = match last_bad {
        None => (Some(Seconds::ZERO), true),
        Some(bad) => {
            if last_data.map(|d| d > bad).unwrap_or(false) {
                (Some(Seconds::new((bad + 1) as f64 * w - ff)), true)
            } else {
                (None, false) // the run ended inside the dip
            }
        }
    };
    RecoveryStats {
        baseline_attainment: baseline,
        dip_attainment: dip,
        slo_dip: (baseline - dip).max(0.0),
        recovery_time,
        recovered,
        goodput_lost_tokens: goodput_lost,
    }
}

/// Windowed SLO-attainment time-series over a whole run: cut
/// `completions` (time-sorted) into `window`-wide slices from t = 0 and
/// score attainment per slice. Returns `(window_start, attainment)`
/// rows. Windows with no SLO-carrying completions carry the previous
/// window's value forward (1.0 before any data), so the series is
/// plottable without gaps. Used by the telemetry report, which reuses
/// the fault-recovery completion trace
/// ([`crate::telemetry::TelemetryReport`]).
pub fn attainment_windows(
    completions: &[CompletionEvent],
    end: Seconds,
    window: Seconds,
) -> Vec<(Seconds, f64)> {
    let w = window.value();
    debug_assert!(w > 0.0, "attainment window must be positive");
    let end_s = end.value();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut carry = 1.0f64;
    let mut k = 0u64;
    while (k as f64) * w < end_s || k == 0 {
        let wstart = k as f64 * w;
        let wend = wstart + w;
        let mut met = 0u64;
        let mut total = 0u64;
        while i < completions.len() && completions[i].at.value() < wend {
            if let Some(m) = completions[i].slo {
                total += 1;
                if m {
                    met += 1;
                }
            }
            i += 1;
        }
        if total > 0 {
            carry = met as f64 / total as f64;
        }
        out.push((Seconds::new(wstart), carry));
        k += 1;
    }
    out
}

/// Fault observables of one cluster run
/// ([`crate::coordinator::cluster::ClusterReport`] `faults`).
#[derive(Debug, Clone)]
pub struct FaultReport {
    /// Replica crashes injected.
    pub crashes: u64,
    /// Crashed replicas that rejoined before the run ended.
    pub rejoins: u64,
    /// TAB module failures injected.
    pub module_failures: u64,
    /// Link-degradation intervals injected.
    pub link_degrades: u64,
    /// In-flight requests evacuated off crashed replicas and re-routed.
    pub requests_requeued: u64,
    /// Requests whose cached prefix was lost (crash evacuation or
    /// module failure) and must run full prefill again.
    pub requests_reprefilled: u64,
    /// Decode tokens thrown away by crashes (generated on the dead
    /// replica, regenerated after re-queue).
    pub tokens_lost: u64,
    /// Prefix-KV bytes invalidated by module failures — exactly the
    /// dead modules' ledger bytes (pinned by `rust/tests/fault_props.rs`).
    pub bytes_invalidated: Bytes,
    /// Prefix-KV token extents invalidated by module failures.
    pub extents_invalidated: u64,
    /// Instant of the first scheduled fault (`None` for an empty
    /// timeline).
    pub first_fault: Option<Seconds>,
    /// Pre-fault SLO attainment.
    pub baseline_attainment: f64,
    /// Worst windowed attainment from the first fault on.
    pub dip_attainment: f64,
    /// `baseline − dip`, clamped at 0.
    pub slo_dip: f64,
    /// First fault → attainment back within ε of baseline.
    pub recovery_time: Option<Seconds>,
    /// Whether the fleet got back within ε before the run ended.
    pub recovered: bool,
    /// Goodput the pre-fault trajectory promised but the faulted run
    /// did not deliver.
    pub goodput_lost_tokens: f64,
    /// Report window width used for the windowed metrics.
    pub window: Seconds,
}

impl FaultReport {
    /// All-zero report for a configured-but-empty schedule.
    pub fn empty(schedule: &FaultSchedule) -> FaultReport {
        FaultReport {
            crashes: 0,
            rejoins: 0,
            module_failures: 0,
            link_degrades: 0,
            requests_requeued: 0,
            requests_reprefilled: 0,
            tokens_lost: 0,
            bytes_invalidated: Bytes::ZERO,
            extents_invalidated: 0,
            first_fault: None,
            baseline_attainment: 1.0,
            dip_attainment: 1.0,
            slo_dip: 0.0,
            recovery_time: Some(Seconds::ZERO),
            recovered: true,
            goodput_lost_tokens: 0.0,
            window: schedule.window,
        }
    }

    /// One-line summary for [`crate::coordinator::cluster::ClusterReport`].
    pub fn summary_line(&self) -> String {
        format!(
            "faults: {} crash / {} module / {} degrade | requeued {} reprefilled {} \
             tokens lost {} | invalidated {:.1} MB ({} extents) | slo dip {:.1}% \
             recovery {} | goodput lost {:.0} tok",
            self.crashes,
            self.module_failures,
            self.link_degrades,
            self.requests_requeued,
            self.requests_reprefilled,
            self.tokens_lost,
            self.bytes_invalidated.value() / 1e6,
            self.extents_invalidated,
            100.0 * self.slo_dip,
            match self.recovery_time {
                Some(t) => format!("{:.0} ms", t.value() * 1e3),
                None => "not reached".to_string(),
            },
            self.goodput_lost_tokens,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, tokens: u64, slo: Option<bool>) -> CompletionEvent {
        CompletionEvent { at: Seconds::new(at), tokens, slo, tenant: 0, ttft: Seconds::ZERO }
    }

    #[test]
    fn healthy_trace_reports_no_dip() {
        let trace: Vec<CompletionEvent> =
            (0..40).map(|i| ev(0.05 * i as f64, 10, Some(true))).collect();
        let s = recovery_stats(&trace, Seconds::new(1.0), Seconds::new(2.0), Seconds::new(0.25), 0.05);
        assert_eq!(s.baseline_attainment, 1.0);
        assert_eq!(s.dip_attainment, 1.0);
        assert_eq!(s.slo_dip, 0.0);
        assert_eq!(s.recovery_time, Some(Seconds::ZERO));
        assert!(s.recovered);
        assert!(s.goodput_lost_tokens.abs() < 1e-9, "rate held: {}", s.goodput_lost_tokens);
    }

    #[test]
    fn dip_and_recovery_are_located() {
        // 1.0 attainment before the fault at t=1; zero attainment in
        // [1.0, 1.5); recovered from 1.5 on.
        let mut trace = Vec::new();
        for i in 0..20 {
            trace.push(ev(0.05 * i as f64, 10, Some(true)));
        }
        for i in 0..10 {
            trace.push(ev(1.0 + 0.05 * i as f64, 10, Some(false)));
        }
        for i in 0..10 {
            trace.push(ev(1.5 + 0.05 * i as f64, 10, Some(true)));
        }
        let s = recovery_stats(&trace, Seconds::new(1.0), Seconds::new(2.0), Seconds::new(0.25), 0.05);
        assert_eq!(s.baseline_attainment, 1.0);
        assert_eq!(s.dip_attainment, 0.0);
        assert_eq!(s.slo_dip, 1.0);
        assert!(s.recovered);
        // Bad windows are [1.0,1.25) and [1.25,1.5): recovery at 1.5.
        assert_eq!(s.recovery_time, Some(Seconds::new(0.5)));
        // Two dipped windows lost their whole goodput promise
        // (rate 200 tok/s × 0.5 s), the recovered windows kept it.
        assert!((s.goodput_lost_tokens - 100.0).abs() < 1e-6, "{}", s.goodput_lost_tokens);
    }

    #[test]
    fn run_ending_inside_the_dip_is_not_recovered() {
        let mut trace = Vec::new();
        for i in 0..20 {
            trace.push(ev(0.05 * i as f64, 10, Some(true)));
        }
        for i in 0..10 {
            trace.push(ev(1.0 + 0.05 * i as f64, 10, Some(false)));
        }
        let s = recovery_stats(&trace, Seconds::new(1.0), Seconds::new(1.5), Seconds::new(0.25), 0.05);
        assert!(!s.recovered);
        assert_eq!(s.recovery_time, None);
        assert!(s.slo_dip > 0.9);
    }

    #[test]
    fn no_slo_traffic_defaults_to_full_attainment() {
        let trace: Vec<CompletionEvent> = (0..10).map(|i| ev(0.1 * i as f64, 5, None)).collect();
        let s = recovery_stats(&trace, Seconds::new(0.5), Seconds::new(1.0), Seconds::new(0.25), 0.05);
        assert_eq!(s.baseline_attainment, 1.0);
        assert_eq!(s.dip_attainment, 1.0);
        assert!(s.recovered);
        assert_eq!(s.goodput_lost_tokens, 0.0, "no baseline rate without slo-met tokens");
    }

    #[test]
    fn deeper_dips_lose_more_goodput() {
        let base: Vec<CompletionEvent> = (0..20).map(|i| ev(0.05 * i as f64, 10, Some(true))).collect();
        let lost_for = |bad_windows: usize| {
            let mut trace = base.clone();
            for i in 0..(bad_windows * 5) {
                trace.push(ev(1.0 + 0.05 * i as f64, 10, Some(false)));
            }
            for i in 0..5 {
                trace.push(ev(1.0 + (bad_windows * 5) as f64 * 0.05 + 0.05 * i as f64, 10, Some(true)));
            }
            let end = trace.last().unwrap().at + Seconds::new(0.05);
            recovery_stats(&trace, Seconds::new(1.0), end, Seconds::new(0.25), 0.05)
        };
        let short = lost_for(1);
        let long = lost_for(3);
        assert!(long.goodput_lost_tokens > short.goodput_lost_tokens);
        assert!(long.recovery_time.unwrap() > short.recovery_time.unwrap());
        assert!(short.recovered && long.recovered);
    }

    #[test]
    fn attainment_windows_carry_forward_and_score() {
        // [0,0.25): 1.0; [0.25,0.5): empty → carries 1.0;
        // [0.5,0.75): 0.5; [0.75,1.0): empty → carries 0.5.
        let trace = vec![
            ev(0.1, 10, Some(true)),
            ev(0.2, 10, Some(true)),
            ev(0.55, 10, Some(true)),
            ev(0.6, 10, Some(false)),
        ];
        let rows = attainment_windows(&trace, Seconds::new(1.0), Seconds::new(0.25));
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0], (Seconds::new(0.0), 1.0));
        assert_eq!(rows[1], (Seconds::new(0.25), 1.0));
        assert_eq!(rows[2], (Seconds::new(0.5), 0.5));
        assert_eq!(rows[3], (Seconds::new(0.75), 0.5));
    }

    #[test]
    fn attainment_windows_empty_trace_is_all_ones() {
        let rows = attainment_windows(&[], Seconds::new(0.5), Seconds::new(0.2));
        assert_eq!(rows.len(), 3);
        assert!(rows.iter().all(|&(_, a)| a == 1.0));
        // Zero-length runs still yield one (degenerate) window.
        let rows = attainment_windows(&[], Seconds::ZERO, Seconds::new(0.2));
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn empty_report_is_all_zero() {
        let r = FaultReport::empty(&FaultSchedule::default());
        assert_eq!(r.crashes + r.module_failures + r.link_degrades, 0);
        assert_eq!(r.slo_dip, 0.0);
        assert!(r.recovered);
        assert!(r.summary_line().contains("0 crash"));
    }
}
