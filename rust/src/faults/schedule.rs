//! Fault schedules: the timed fault vocabulary and its CLI grammar
//! (DESIGN.md §Faults).
//!
//! A schedule is a list of explicit [`FaultSpec`]s. The `random:` spec
//! form materialises a seeded random process per fault class into the
//! same explicit list at parse time, so the cluster only ever sees a
//! concrete, reproducible timeline. [`FaultSchedule::timeline`] derives
//! the replica-rejoin events from crash repair times and returns the
//! whole set in stable time order — the exact injection sequence both
//! cluster cores replay.

use crate::error::{FhError, Result};
use crate::traffic::rng::{splitmix64, XorShift};
use crate::units::Seconds;

/// Which TAB module a [`FaultKind::ModuleFailure`] kills.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ModuleSel {
    /// A fixed module index (must be < the prefix cache's module count).
    Index(usize),
    /// The module holding the most cached bytes at fault time — the
    /// worst-case blast radius (lowest index wins ties).
    Hottest,
}

/// One fault class, with its recovery semantics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// `replica` dies: in-flight requests re-queue through the router,
    /// its local KV is lost (pool-resident prefixes survive), and it
    /// rejoins cold after `repair`.
    ReplicaCrash { replica: usize, repair: Seconds },
    /// `replica` comes back with cold caches. Derived from
    /// [`FaultKind::ReplicaCrash`] by [`FaultSchedule::timeline`] —
    /// never written explicitly.
    ReplicaRejoin { replica: usize },
    /// A TAB module dies: every prefix-KV extent homed on it is
    /// invalidated through the radix trie and the paging ledger.
    /// Permanent (re-warmed only by later traffic).
    ModuleFailure { module: ModuleSel },
    /// Per-port and per-module contention budgets scale by `factor`
    /// for `duration`, then recover.
    LinkDegrade { factor: f64, duration: Seconds },
}

/// One timed fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    pub at: Seconds,
    pub kind: FaultKind,
}

/// A deterministic fault schedule
/// ([`crate::coordinator::cluster::ClusterConfig`] `faults`). An empty
/// schedule is a strict passthrough — see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSchedule {
    /// Explicit faults (rejoins are derived, never listed here).
    pub events: Vec<FaultSpec>,
    /// SLO-attainment window width for the recovery report.
    pub window: Seconds,
    /// Recovery tolerance: attainment within `epsilon` of the pre-fault
    /// baseline counts as recovered.
    pub epsilon: f64,
}

/// Default report window (250 ms) — several decode rounds at paper
/// scale, so per-window attainment is not all-or-nothing.
pub const DEFAULT_FAULT_WINDOW: Seconds = Seconds(0.25);

/// Default recovery tolerance.
pub const DEFAULT_FAULT_EPSILON: f64 = 0.05;

/// Default crash repair time.
pub const DEFAULT_REPAIR: Seconds = Seconds(1.0);

impl Default for FaultSchedule {
    fn default() -> Self {
        FaultSchedule {
            events: Vec::new(),
            window: DEFAULT_FAULT_WINDOW,
            epsilon: DEFAULT_FAULT_EPSILON,
        }
    }
}

fn num(s: &str, what: &str) -> Result<f64> {
    s.parse::<f64>()
        .ok()
        .filter(|v| v.is_finite())
        .ok_or_else(|| FhError::Config(format!("--faults: {what} `{s}` is not a finite number")))
}

impl FaultSchedule {
    /// No faults scheduled (the passthrough case).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse a `--faults` spec: comma-separated items, each one of
    ///
    /// * `crash@T:rN[:repairX]` — replica N crashes at T seconds,
    ///   rejoins after X seconds (default 1.0);
    /// * `module@T:hot` / `module@T:mI` — TAB module failure at T,
    ///   hottest module or fixed index I;
    /// * `degrade@T:xF:dD` — link budgets scale by factor F for D
    ///   seconds starting at T;
    /// * `window=W` / `eps=E` — recovery-report knobs;
    /// * `random:seed=S:horizon=H[:crash=R][:module=R][:degrade=R][:repair=X]`
    ///   — seeded Poisson processes per fault class (rates R in
    ///   events/second over `[0, H)`), materialised immediately.
    ///
    /// `replicas` bounds the crash targets (random crashes draw from
    /// it; explicit `rN` is checked against it).
    pub fn parse(spec: &str, replicas: usize) -> Result<FaultSchedule> {
        let mut out = FaultSchedule::default();
        for raw in spec.split(',') {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(v) = item.strip_prefix("window=") {
                out.window = Seconds::new(num(v, "window")?);
            } else if let Some(v) = item.strip_prefix("eps=") {
                out.epsilon = num(v, "eps")?;
            } else if let Some(body) = item.strip_prefix("random:") {
                out.events.extend(parse_random(body, replicas)?);
            } else if let Some(body) = item.strip_prefix("crash@") {
                out.events.push(parse_crash(body, replicas)?);
            } else if let Some(body) = item.strip_prefix("module@") {
                out.events.push(parse_module(body)?);
            } else if let Some(body) = item.strip_prefix("degrade@") {
                out.events.push(parse_degrade(body)?);
            } else {
                return Err(FhError::Config(format!(
                    "--faults: unknown item `{item}` (expected crash@…, module@…, \
                     degrade@…, random:…, window=… or eps=…)"
                )));
            }
        }
        out.validate()?;
        Ok(out)
    }

    /// Context-free sanity checks (fleet-dependent checks — replica and
    /// module bounds against the actual cluster, prefix-cache and
    /// contention prerequisites — live in `Cluster::new`).
    pub fn validate(&self) -> Result<()> {
        if !(self.window.value() > 0.0) {
            return Err(FhError::Config("fault report window must be > 0".into()));
        }
        if !(self.epsilon >= 0.0 && self.epsilon.is_finite()) {
            return Err(FhError::Config("fault recovery epsilon must be ≥ 0".into()));
        }
        for e in &self.events {
            if e.at.value() < 0.0 {
                return Err(FhError::Config("fault times must be ≥ 0".into()));
            }
            match e.kind {
                FaultKind::ReplicaCrash { repair, .. } => {
                    if repair.value() < 0.0 {
                        return Err(FhError::Config("crash repair time must be ≥ 0".into()));
                    }
                }
                FaultKind::ReplicaRejoin { .. } => {
                    return Err(FhError::Config(
                        "rejoin events are derived from crashes, never scheduled directly"
                            .into(),
                    ));
                }
                FaultKind::ModuleFailure { .. } => {}
                FaultKind::LinkDegrade { factor, duration } => {
                    // The floor keeps degraded window budgets far above
                    // the ledger's byte epsilon, so bookings always make
                    // progress.
                    if !(factor >= 1e-6 && factor <= 1.0) {
                        return Err(FhError::Config(format!(
                            "degrade factor must be in [1e-6, 1], got {factor}"
                        )));
                    }
                    if !(duration.value() > 0.0) {
                        return Err(FhError::Config("degrade duration must be > 0".into()));
                    }
                }
            }
        }
        Ok(())
    }

    /// The concrete injection sequence: explicit events plus the
    /// rejoin derived from each crash (`at + repair`), in stable time
    /// order — at equal instants, explicit faults fire before derived
    /// rejoins, and earlier-listed events before later ones.
    pub fn timeline(&self) -> Vec<FaultSpec> {
        let mut all = self.events.clone();
        for e in &self.events {
            if let FaultKind::ReplicaCrash { replica, repair } = e.kind {
                all.push(FaultSpec {
                    at: e.at + repair,
                    kind: FaultKind::ReplicaRejoin { replica },
                });
            }
        }
        all.sort_by(|a, b| a.at.value().total_cmp(&b.at.value()));
        all
    }
}

fn parse_crash(body: &str, replicas: usize) -> Result<FaultSpec> {
    let mut parts = body.split(':');
    let at = Seconds::new(num(parts.next().unwrap_or(""), "crash time")?);
    let target = parts.next().ok_or_else(|| {
        FhError::Config(format!("--faults: crash@{body} needs a replica (`:rN`)"))
    })?;
    let replica = target
        .strip_prefix('r')
        .and_then(|v| v.parse::<usize>().ok())
        .ok_or_else(|| {
            FhError::Config(format!("--faults: crash target `{target}` is not `rN`"))
        })?;
    if replica >= replicas {
        return Err(FhError::Config(format!(
            "--faults: crash replica r{replica} out of range (fleet has {replicas})"
        )));
    }
    let repair = match parts.next() {
        Some(v) => {
            let x = v.strip_prefix("repair").ok_or_else(|| {
                FhError::Config(format!("--faults: crash option `{v}` is not `repairX`"))
            })?;
            Seconds::new(num(x, "repair time")?)
        }
        None => DEFAULT_REPAIR,
    };
    if let Some(extra) = parts.next() {
        return Err(FhError::Config(format!("--faults: crash has extra field `{extra}`")));
    }
    Ok(FaultSpec { at, kind: FaultKind::ReplicaCrash { replica, repair } })
}

fn parse_module(body: &str) -> Result<FaultSpec> {
    let mut parts = body.split(':');
    let at = Seconds::new(num(parts.next().unwrap_or(""), "module-failure time")?);
    let sel = parts.next().ok_or_else(|| {
        FhError::Config(format!("--faults: module@{body} needs a target (`:hot` or `:mI`)"))
    })?;
    let module = if sel == "hot" {
        ModuleSel::Hottest
    } else {
        let idx = sel.strip_prefix('m').and_then(|v| v.parse::<usize>().ok()).ok_or_else(
            || FhError::Config(format!("--faults: module target `{sel}` is not `hot` or `mI`")),
        )?;
        ModuleSel::Index(idx)
    };
    if let Some(extra) = parts.next() {
        return Err(FhError::Config(format!("--faults: module has extra field `{extra}`")));
    }
    Ok(FaultSpec { at, kind: FaultKind::ModuleFailure { module } })
}

fn parse_degrade(body: &str) -> Result<FaultSpec> {
    let mut parts = body.split(':');
    let at = Seconds::new(num(parts.next().unwrap_or(""), "degrade time")?);
    let mut factor = None;
    let mut duration = None;
    for p in parts {
        if let Some(v) = p.strip_prefix('x') {
            factor = Some(num(v, "degrade factor")?);
        } else if let Some(v) = p.strip_prefix('d') {
            duration = Some(Seconds::new(num(v, "degrade duration")?));
        } else {
            return Err(FhError::Config(format!(
                "--faults: degrade field `{p}` is not `xF` or `dD`"
            )));
        }
    }
    let factor = factor
        .ok_or_else(|| FhError::Config("--faults: degrade needs a factor (`:xF`)".into()))?;
    let duration = duration
        .ok_or_else(|| FhError::Config("--faults: degrade needs a duration (`:dD`)".into()))?;
    Ok(FaultSpec { at, kind: FaultKind::LinkDegrade { factor, duration } })
}

/// Materialise the `random:` spec: an independent seeded Poisson
/// process per fault class (exponential inter-fault gaps at the class
/// rate) over `[0, horizon)`. Classes draw from decorrelated
/// substreams of the one seed, in the fixed order crash → module →
/// degrade, so adding one class never perturbs another's timeline.
fn parse_random(body: &str, replicas: usize) -> Result<Vec<FaultSpec>> {
    let mut seed = None;
    let mut horizon = None;
    let mut crash_rate = 0.0f64;
    let mut module_rate = 0.0f64;
    let mut degrade_rate = 0.0f64;
    let mut repair = DEFAULT_REPAIR;
    for p in body.split(':') {
        if let Some(v) = p.strip_prefix("seed=") {
            seed = Some(v.parse::<u64>().map_err(|_| {
                FhError::Config(format!("--faults: random seed `{v}` is not an integer"))
            })?);
        } else if let Some(v) = p.strip_prefix("horizon=") {
            horizon = Some(num(v, "random horizon")?);
        } else if let Some(v) = p.strip_prefix("crash=") {
            crash_rate = num(v, "crash rate")?;
        } else if let Some(v) = p.strip_prefix("module=") {
            module_rate = num(v, "module rate")?;
        } else if let Some(v) = p.strip_prefix("degrade=") {
            degrade_rate = num(v, "degrade rate")?;
        } else if let Some(v) = p.strip_prefix("repair=") {
            repair = Seconds::new(num(v, "repair time")?);
        } else {
            return Err(FhError::Config(format!("--faults: unknown random field `{p}`")));
        }
    }
    let seed =
        seed.ok_or_else(|| FhError::Config("--faults: random needs `seed=S`".into()))?;
    let horizon = horizon
        .filter(|h| *h > 0.0)
        .ok_or_else(|| FhError::Config("--faults: random needs `horizon=H` > 0".into()))?;
    let mut out = Vec::new();
    for (class, rate) in
        [("crash", crash_rate), ("module", module_rate), ("degrade", degrade_rate)]
    {
        if rate <= 0.0 {
            continue;
        }
        let salt = class.bytes().fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
        let mut rng = XorShift::new(splitmix64(seed ^ salt));
        let mut t = rng.exp(1.0 / rate);
        while t < horizon {
            let at = Seconds::new(t);
            let kind = match class {
                "crash" => FaultKind::ReplicaCrash {
                    replica: (rng.next_u64() % replicas.max(1) as u64) as usize,
                    repair,
                },
                "module" => FaultKind::ModuleFailure { module: ModuleSel::Hottest },
                _ => FaultKind::LinkDegrade {
                    factor: 0.25 + 0.5 * rng.next_f64(),
                    duration: Seconds::new(horizon / 10.0),
                },
            };
            out.push(FaultSpec { at, kind });
            t += rng.exp(1.0 / rate);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_default_schedules_are_passthrough() {
        assert!(FaultSchedule::default().is_empty());
        assert!(FaultSchedule::default().timeline().is_empty());
        let s = FaultSchedule::parse("", 4).unwrap();
        assert!(s.is_empty());
        assert_eq!(s.window, DEFAULT_FAULT_WINDOW);
        assert_eq!(s.epsilon, DEFAULT_FAULT_EPSILON);
    }

    #[test]
    fn explicit_grammar_round_trips() {
        let s = FaultSchedule::parse(
            "crash@0.5:r1:repair0.2, module@1.0:hot, module@2:m3, degrade@0.1:x0.5:d0.3, \
             window=0.1, eps=0.02",
            4,
        )
        .unwrap();
        assert_eq!(s.events.len(), 4);
        assert_eq!(s.window, Seconds::new(0.1));
        assert_eq!(s.epsilon, 0.02);
        assert_eq!(
            s.events[0],
            FaultSpec {
                at: Seconds::new(0.5),
                kind: FaultKind::ReplicaCrash { replica: 1, repair: Seconds::new(0.2) },
            }
        );
        assert_eq!(s.events[1].kind, FaultKind::ModuleFailure { module: ModuleSel::Hottest });
        assert_eq!(s.events[2].kind, FaultKind::ModuleFailure { module: ModuleSel::Index(3) });
        assert_eq!(
            s.events[3].kind,
            FaultKind::LinkDegrade { factor: 0.5, duration: Seconds::new(0.3) }
        );
    }

    #[test]
    fn crash_defaults_and_bounds() {
        let s = FaultSchedule::parse("crash@1:r0", 2).unwrap();
        assert_eq!(
            s.events[0].kind,
            FaultKind::ReplicaCrash { replica: 0, repair: DEFAULT_REPAIR }
        );
        assert!(FaultSchedule::parse("crash@1:r2", 2).is_err(), "out-of-fleet replica");
        assert!(FaultSchedule::parse("crash@1", 2).is_err(), "missing replica");
        assert!(FaultSchedule::parse("crash@1:x0", 2).is_err(), "bad target");
        assert!(FaultSchedule::parse("crash@-1:r0", 2).is_err(), "negative time");
    }

    #[test]
    fn bad_specs_are_rejected() {
        for bad in [
            "explode@1:r0",
            "module@1",
            "module@1:q2",
            "degrade@1:x0.5",
            "degrade@1:d0.5",
            "degrade@1:x0:d1",
            "degrade@1:x2:d1",
            "degrade@1:x0.5:d0",
            "window=0",
            "eps=nan",
            "crash@nan:r0",
            "random:horizon=1",
            "random:seed=1",
            "random:seed=1:horizon=1:bogus=2",
        ] {
            assert!(FaultSchedule::parse(bad, 4).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn timeline_derives_rejoins_in_stable_time_order() {
        let s = FaultSchedule::parse("crash@1:r0:repair0.5, module@1.2:hot", 2).unwrap();
        let t = s.timeline();
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].at, Seconds::new(1.0));
        assert!(matches!(t[0].kind, FaultKind::ReplicaCrash { .. }));
        assert_eq!(t[1].at, Seconds::new(1.2));
        assert!(matches!(t[1].kind, FaultKind::ModuleFailure { .. }));
        assert_eq!(t[2].at, Seconds::new(1.5));
        assert_eq!(t[2].kind, FaultKind::ReplicaRejoin { replica: 0 });
        // Zero repair: the crash still precedes its own rejoin.
        let s = FaultSchedule::parse("crash@1:r0:repair0", 2).unwrap();
        let t = s.timeline();
        assert!(matches!(t[0].kind, FaultKind::ReplicaCrash { .. }));
        assert!(matches!(t[1].kind, FaultKind::ReplicaRejoin { .. }));
        assert_eq!(t[0].at, t[1].at);
    }

    #[test]
    fn random_process_is_seeded_and_bounded() {
        let spec = "random:seed=7:horizon=10:crash=0.5:module=0.3:degrade=0.2";
        let a = FaultSchedule::parse(spec, 4).unwrap();
        let b = FaultSchedule::parse(spec, 4).unwrap();
        assert_eq!(a, b, "same seed, same schedule");
        assert!(!a.is_empty(), "rates over a 10 s horizon should fire");
        for e in &a.events {
            assert!(e.at.value() >= 0.0 && e.at.value() < 10.0);
            if let FaultKind::ReplicaCrash { replica, repair } = e.kind {
                assert!(replica < 4);
                assert_eq!(repair, DEFAULT_REPAIR);
            }
            if let FaultKind::LinkDegrade { factor, duration } = e.kind {
                assert!((0.25..0.75).contains(&factor));
                assert_eq!(duration, Seconds::new(1.0));
            }
        }
        let c = FaultSchedule::parse("random:seed=8:horizon=10:crash=0.5", 4).unwrap();
        assert_ne!(a.events, c.events, "different seeds diverge");
        // Dropping a class never perturbs the surviving classes.
        let crash_only = FaultSchedule::parse("random:seed=7:horizon=10:crash=0.5", 4).unwrap();
        let crashes: Vec<_> = a
            .events
            .iter()
            .filter(|e| matches!(e.kind, FaultKind::ReplicaCrash { .. }))
            .cloned()
            .collect();
        assert_eq!(crash_only.events, crashes);
    }

    #[test]
    fn random_mixes_with_explicit_items() {
        let s =
            FaultSchedule::parse("crash@0.5:r0, random:seed=3:horizon=5:module=1.0", 2).unwrap();
        assert!(s.events.len() >= 2);
        assert!(matches!(s.events[0].kind, FaultKind::ReplicaCrash { .. }));
        let t = s.timeline();
        for w in t.windows(2) {
            assert!(w[0].at <= w[1].at, "timeline must be time-sorted");
        }
    }
}
