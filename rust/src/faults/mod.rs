//! Fault-injection and recovery subsystem (DESIGN.md §Faults).
//!
//! Every scenario the simulator expressed before this module was a
//! healthy fleet, yet the paper's cost and GPU-reduction claims assume
//! the disaggregated TAB pool stays up. Pooling concentrates blast
//! radius: one TAB module failure takes KV pages and cached prefixes
//! for *every* replica with it. This module provides the vocabulary to
//! ask whether the shared pool survives operations:
//!
//! * [`FaultSchedule`] — a deterministic, seeded list of timed faults
//!   (explicit `(time, fault)` entries and/or a seeded random process
//!   per fault class, materialised at parse time so the schedule the
//!   cluster sees is always a concrete timeline);
//! * [`FaultKind`] — the three fault classes with recovery semantics:
//!   **replica crash** (in-flight requests re-queued through the
//!   router, the replica's local KV lost — re-prefill vs
//!   re-fetch-from-pool depending on TAB residency — and the replica
//!   rejoins cold after a configurable repair time), **TAB module
//!   failure** (every prefix-KV extent homed on the dead module is
//!   invalidated through the radix trie and its paging ledger; striped
//!   vs hashed placement changes the blast radius), and **link
//!   degradation** (per-port / per-module contention budgets drop by a
//!   factor for a bounded interval);
//! * [`FaultReport`] — per-class counts, recovery time (first fault →
//!   SLO attainment back within ε of the pre-fault window), windowed
//!   SLO-attainment dip, goodput lost, requests re-queued /
//!   re-prefilled and bytes invalidated.
//!
//! **Passthrough guarantee.** Like [`ContentionMode::Off`], an absent
//! or empty schedule is a strict no-op: no fault events enter the
//! calendar, no completion traces are recorded, and no arithmetic runs
//! that could perturb a healthy run — no-fault runs stay bit-identical
//! with the subsystem compiled in (pinned by
//! `rust/tests/fault_props.rs` and the differential harness
//! `rust/tests/event_core_equiv.rs`).
//!
//! [`ContentionMode::Off`]: crate::fabric::contention::ContentionMode::Off

pub mod report;
pub mod schedule;

pub use report::{attainment_windows, recovery_stats, CompletionEvent, FaultReport, RecoveryStats};
pub use schedule::{FaultKind, FaultSchedule, FaultSpec, ModuleSel};
