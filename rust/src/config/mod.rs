//! System configuration — Tables 4.1 / 4.2 as data.
//!
//! A [`SystemConfig`] fully describes a node for the simulator: GPU count
//! and compute rate, local-memory tier, fabric kind (shared-nothing NVLink
//! vs TAB shared memory), remote-memory tier, and the fixed latencies.
//! Presets reproduce the paper's `Baseline8`, `FH4-1.5xM` and `FH4-2.0xM`
//! rows; configs round-trip through a flat `key = value` TOML subset
//! (parsed in-tree — the build environment has no serde/toml crates).

use crate::error::Result;
use crate::fabric::FabricLatencies;
use crate::hardware;
use crate::units::{Bandwidth, Bytes, FlopRate};
use std::path::Path;

/// Interconnect architecture of the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FabricKind {
    /// Shared-nothing scale-up: GPUs exchange data over NVLink rings.
    NvlinkRing,
    /// FengHuang: GPUs share a remote pool behind the TAB crossbar.
    TabSharedMemory,
}

/// A high-bandwidth-flash tier behind the TAB pool (Ma & Patterson,
/// PAPERS.md): ~10× HBM capacity at near-HBM bandwidth, sitting below
/// the pool in the HBM ↔ pool ↔ flash hierarchy (DESIGN.md §Tiering).
/// `None` on a [`SystemConfig`] means the legacy 2-tier model, which
/// stays bit-identical to the pre-flash simulator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FlashConfig {
    /// Flash capacity behind the pool.
    pub capacity: Bytes,
    /// Media streaming rate of the flash tier (the HBF design point is
    /// HBM-like, i.e. TB/s-class, not NVMe-class).
    pub bandwidth: Bandwidth,
}

/// Default flash media rate (TB/s) for `--flash-gb` without an explicit
/// `--flash-bw`: a third of the FH4 local HBM rate — "HBM-like", per the
/// Ma & Patterson HBF sketch, while still clearly slower than HBM.
pub const DEFAULT_FLASH_TBPS: f64 = 1.6;

impl FlashConfig {
    /// Flash tier of `capacity_gb` at the default HBF media rate.
    pub fn gb(capacity_gb: f64) -> Self {
        FlashConfig {
            capacity: Bytes::gb(capacity_gb),
            bandwidth: Bandwidth::tbps(DEFAULT_FLASH_TBPS),
        }
    }
}

/// One node configuration (a row of Tables 4.1 + 4.2).
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub name: String,
    /// Number of xPUs.
    pub num_gpus: usize,
    /// Dense FP16 compute per GPU *after* the paper's compute-improvement
    /// factor (FH GPUs are "1.33× H200").
    pub compute_per_gpu: FlopRate,
    /// Local HBM bandwidth per GPU.
    pub local_bw: Bandwidth,
    /// Local HBM capacity per GPU. `None` means "as much as needed"
    /// (Table 4.1) — the simulator then *reports* the peak requirement
    /// instead of enforcing a cap (→ Table 4.3).
    pub local_capacity: Option<Bytes>,
    pub fabric: FabricKind,
    /// NVLink: per-direction link bandwidth per GPU.
    /// TAB: crossbar bandwidth per GPU (the paper's 4.0–6.4 TB/s knob).
    pub fabric_bw: Bandwidth,
    /// Remote memory capacity behind the TAB (0 for shared-nothing).
    pub remote_capacity: Bytes,
    /// Optional third tier below the pool. Requires a TAB fabric — flash
    /// sits behind the same crossbar ports as the pool.
    pub flash: Option<FlashConfig>,
    pub latencies: FabricLatencies,
    /// Multiplier on compute time representing framework-level overheads
    /// (kernel-launch gaps, NCCL stream synchronisation, scheduler
    /// bubbles). The paper's Baseline8 numbers come from *measured* Nsight
    /// traces, which embed these overheads; its FengHuang numbers come
    /// from an analytic model that pays its costs explicitly through the
    /// prefetch simulation. We reproduce that asymmetry with an explicit,
    /// ablatable knob (DESIGN.md §5; `benches/ablations.rs` sweeps it).
    pub framework_overhead: f64,
}

impl SystemConfig {
    /// Aggregate compute across the node.
    pub fn total_compute(&self) -> FlopRate {
        self.compute_per_gpu * self.num_gpus as f64
    }

    /// Aggregate local-memory bandwidth.
    pub fn total_local_bw(&self) -> Bandwidth {
        self.local_bw * self.num_gpus as f64
    }

    /// Tensor-parallel degree used by the workloads (= GPU count).
    pub fn tp(&self) -> usize {
        self.num_gpus
    }

    pub fn is_fenghuang(&self) -> bool {
        self.fabric == FabricKind::TabSharedMemory
    }

    /// Attach a flash tier below the pool (builder style for presets and
    /// tests; validation still rejects flash on non-TAB systems).
    pub fn with_flash(mut self, flash: FlashConfig) -> Self {
        self.flash = Some(flash);
        self
    }

    /// Serialise to a flat `key = value` TOML subset.
    pub fn to_toml(&self) -> Result<String> {
        let cap = match self.local_capacity {
            Some(b) => format!("{}", b.as_gb()),
            None => "unlimited".to_string(),
        };
        let fabric = match self.fabric {
            FabricKind::NvlinkRing => "nvlink",
            FabricKind::TabSharedMemory => "tab",
        };
        // Flash keys are emitted only when the tier exists, so configs
        // written by the pre-flash format stay parseable and 2-tier
        // configs round-trip to the exact same bytes as before.
        let flash = match self.flash {
            Some(f) => format!(
                "flash_gb = {}\nflash_bw_tbps = {}\n",
                f.capacity.as_gb(),
                f.bandwidth.as_tbps()
            ),
            None => String::new(),
        };
        let l = &self.latencies;
        Ok(format!(
            "name = \"{}\"\n\
             num_gpus = {}\n\
             compute_tflops = {}\n\
             local_bw_tbps = {}\n\
             local_capacity_gb = \"{}\"\n\
             fabric = \"{}\"\n\
             fabric_bw_gbps = {}\n\
             remote_capacity_gb = {}\n\
             {}framework_overhead = {}\n\
             tab_read_ns = {}\n\
             tab_write_ns = {}\n\
             tab_writeacc_ns = {}\n\
             tab_notify_ns = {}\n\
             nvlink_read_ns = {}\n\
             nvlink_write_ns = {}\n",
            self.name,
            self.num_gpus,
            self.compute_per_gpu.as_tflops(),
            self.local_bw.as_tbps(),
            cap,
            fabric,
            self.fabric_bw.as_gbps(),
            self.remote_capacity.as_gb(),
            flash,
            self.framework_overhead,
            l.tab_read.as_ns(),
            l.tab_write.as_ns(),
            l.tab_write_accumulate.as_ns(),
            l.tab_notification.as_ns(),
            l.nvlink_read.as_ns(),
            l.nvlink_write.as_ns(),
        ))
    }

    /// Parse the flat `key = value` format emitted by [`Self::to_toml`].
    pub fn from_toml(s: &str) -> Result<Self> {
        let mut kv = std::collections::HashMap::new();
        for (lineno, line) in s.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                crate::FhError::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
        }
        let get = |k: &str| -> Result<String> {
            kv.get(k)
                .cloned()
                .ok_or_else(|| crate::FhError::Config(format!("missing key '{k}'")))
        };
        let num = |k: &str| -> Result<f64> {
            get(k)?.parse().map_err(|e| crate::FhError::Config(format!("{k}: {e}")))
        };
        let fabric = match get("fabric")?.as_str() {
            "nvlink" => FabricKind::NvlinkRing,
            "tab" => FabricKind::TabSharedMemory,
            other => {
                return Err(crate::FhError::Config(format!("unknown fabric '{other}'")));
            }
        };
        let cap_raw = get("local_capacity_gb")?;
        let local_capacity = if cap_raw == "unlimited" {
            None
        } else {
            Some(Bytes::gb(cap_raw.parse().map_err(|e| {
                crate::FhError::Config(format!("local_capacity_gb: {e}"))
            })?))
        };
        // Optional flash tier: both keys or neither (a bandwidth without
        // a capacity describes a tier that does not exist).
        let flash = match (kv.get("flash_gb"), kv.get("flash_bw_tbps")) {
            (None, None) => None,
            (Some(g), bw) => {
                let gb: f64 = g
                    .parse()
                    .map_err(|e| crate::FhError::Config(format!("flash_gb: {e}")))?;
                let tbps = match bw {
                    Some(b) => b
                        .parse()
                        .map_err(|e| crate::FhError::Config(format!("flash_bw_tbps: {e}")))?,
                    None => DEFAULT_FLASH_TBPS,
                };
                Some(FlashConfig { capacity: Bytes::gb(gb), bandwidth: Bandwidth::tbps(tbps) })
            }
            (None, Some(_)) => {
                return Err(crate::FhError::Config(
                    "flash_bw_tbps without flash_gb — give the tier a capacity".into(),
                ));
            }
        };
        use crate::units::Seconds;
        Ok(SystemConfig {
            name: get("name")?,
            num_gpus: num("num_gpus")? as usize,
            compute_per_gpu: FlopRate::tflops(num("compute_tflops")?),
            local_bw: Bandwidth::tbps(num("local_bw_tbps")?),
            local_capacity,
            fabric,
            fabric_bw: Bandwidth::gbps(num("fabric_bw_gbps")?),
            remote_capacity: Bytes::gb(num("remote_capacity_gb")?),
            flash,
            latencies: FabricLatencies {
                tab_read: Seconds::ns(num("tab_read_ns")?),
                tab_write: Seconds::ns(num("tab_write_ns")?),
                tab_write_accumulate: Seconds::ns(num("tab_writeacc_ns")?),
                tab_notification: Seconds::ns(num("tab_notify_ns")?),
                nvlink_read: Seconds::ns(num("nvlink_read_ns")?),
                nvlink_write: Seconds::ns(num("nvlink_write_ns")?),
            },
            framework_overhead: num("framework_overhead")?,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        Self::from_toml(&std::fs::read_to_string(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_toml()?)?;
        Ok(())
    }

    /// Validate physical consistency.
    pub fn validate(&self) -> Result<()> {
        if self.num_gpus == 0 {
            return Err(crate::FhError::Config("num_gpus must be ≥ 1".into()));
        }
        if self.compute_per_gpu.value() <= 0.0 || self.local_bw.value() <= 0.0 {
            return Err(crate::FhError::Config("compute/bandwidth must be positive".into()));
        }
        if self.fabric_bw.value() <= 0.0 {
            // A zero/negative fabric bandwidth turns every `bytes / bw`
            // charge downstream (collectives, paging DMA, prefix fetches,
            // the contention ledger) into NaN/inf latencies — reject it
            // at the config boundary instead.
            return Err(crate::FhError::Config(format!(
                "fabric bandwidth must be positive, got {} GB/s",
                self.fabric_bw.as_gbps()
            )));
        }
        if self.fabric == FabricKind::TabSharedMemory && self.remote_capacity.value() <= 0.0 {
            return Err(crate::FhError::Config(
                "FengHuang systems need remote memory capacity".into(),
            ));
        }
        if let Some(f) = self.flash {
            if self.fabric != FabricKind::TabSharedMemory {
                return Err(crate::FhError::Config(
                    "flash tier sits behind the TAB crossbar — shared-nothing \
                     systems have no pool to back it"
                        .into(),
                ));
            }
            if f.capacity.value() <= 0.0 || f.bandwidth.value() <= 0.0 {
                return Err(crate::FhError::Config(format!(
                    "flash tier needs positive capacity and bandwidth, got {} GB at {} TB/s",
                    f.capacity.as_gb(),
                    f.bandwidth.as_tbps()
                )));
            }
        }
        Ok(())
    }
}

/// Default TAB remote bandwidth (TB/s per GPU) used by the cluster and
/// serving presets — the paper's headline 4.8 TB/s operating point
/// (Fig 4.1 sweeps 4.0–6.4).
pub const DEFAULT_REMOTE_TBPS: f64 = 4.8;

/// `Baseline8`: 8×H200, NVLink 4.0 (450 GB/s per direction), 1152 GB HBM.
pub fn baseline8() -> SystemConfig {
    let h200 = hardware::h200();
    SystemConfig {
        name: "Baseline8".into(),
        num_gpus: 8,
        compute_per_gpu: h200.fp16_flops,
        local_bw: h200.hbm_bw,                      // 4.8 TB/s
        local_capacity: Some(h200.hbm_capacity),    // 144 GB per Table 4.1 ≈ 141 GB datasheet
        fabric: FabricKind::NvlinkRing,
        fabric_bw: h200.link_bw_unidir(),           // 450 GB/s
        remote_capacity: Bytes::ZERO,
        flash: None,
        latencies: FabricLatencies::default(),
        framework_overhead: 1.55,
    }
}

fn fh4(name: &str, local_speedup: f64, remote_bw: Bandwidth) -> SystemConfig {
    let h200 = hardware::h200();
    SystemConfig {
        name: name.into(),
        num_gpus: 4,
        compute_per_gpu: h200.fp16_flops * 1.33, // "1.33× H200"
        local_bw: h200.hbm_bw * local_speedup,
        local_capacity: None, // "as much as needed" — sim reports the peak
        fabric: FabricKind::TabSharedMemory,
        fabric_bw: remote_bw,
        remote_capacity: Bytes::gb(1152.0),
        flash: None,
        latencies: FabricLatencies::default(),
        framework_overhead: 1.0,
    }
}

/// `FH4-1.5xM`: 4×(1.33·H200), 7.2 TB/s local HBM, TAB remote memory.
pub fn fh4_15xm(remote_bw: Bandwidth) -> SystemConfig {
    fh4("FH4-1.5xM", 1.5, remote_bw)
}

/// `FH4-2.0xM`: 4×(1.33·H200), 9.6 TB/s local HBM, TAB remote memory.
pub fn fh4_20xm(remote_bw: Bandwidth) -> SystemConfig {
    fh4("FH4-2.0xM", 2.0, remote_bw)
}

/// The remote-bandwidth sweep of Fig 4.1 (TB/s per GPU).
pub fn fig41_bandwidth_sweep() -> Vec<Bandwidth> {
    [4.0, 4.8, 5.6, 6.4].iter().map(|&t| Bandwidth::tbps(t)).collect()
}

/// Cluster preset: a rack of `replicas` identical FH4-1.5xM nodes, each
/// with its own TAB pool at `remote_bw` (the unit the paper's "50% fewer
/// GPUs at rack scale" claim multiplies out from; DESIGN.md §6). An empty
/// rack is valid data here; `Cluster::new` is where zero replicas errors.
pub fn fh4_rack(replicas: usize, remote_bw: Bandwidth) -> Vec<SystemConfig> {
    (0..replicas)
        .map(|i| {
            let mut s = fh4_15xm(remote_bw);
            s.name = format!("FH4-1.5xM/r{i}");
            s
        })
        .collect()
}

/// Cluster preset: a rack of `replicas` Baseline8 nodes (the
/// shared-nothing comparison fleet).
pub fn baseline_rack(replicas: usize) -> Vec<SystemConfig> {
    (0..replicas)
        .map(|i| {
            let mut s = baseline8();
            s.name = format!("Baseline8/r{i}");
            s
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline8_matches_table_41_42() {
        let b = baseline8();
        assert_eq!(b.num_gpus, 8);
        assert_eq!(b.local_bw.as_tbps(), 4.8);
        assert_eq!(b.fabric_bw.as_gbps(), 450.0);
        assert_eq!(b.fabric, FabricKind::NvlinkRing);
        // "Total of 1152 GB of HBM operating at 38.4 TB/s" (§3.3.3).
        assert!((b.total_local_bw().as_tbps() - 38.4).abs() < 1e-9);
        let total_cap = b.local_capacity.unwrap() * b.num_gpus as f64;
        assert!((total_cap.as_gb() - 1128.0).abs() < 30.0, "≈1152 GB node");
    }

    #[test]
    fn fh4_matches_table_41_42() {
        let f = fh4_15xm(Bandwidth::tbps(4.0));
        assert_eq!(f.num_gpus, 4);
        assert!((f.local_bw.as_tbps() - 7.2).abs() < 1e-9);
        assert!((f.compute_per_gpu.as_tflops() - 989.0 * 1.33).abs() < 1e-6);
        assert!(f.local_capacity.is_none(), "as much as needed");
        assert_eq!(f.remote_capacity.as_gb(), 1152.0);
        let f2 = fh4_20xm(Bandwidth::tbps(6.4));
        assert!((f2.local_bw.as_tbps() - 9.6).abs() < 1e-9);
    }

    #[test]
    fn toml_roundtrip() {
        let b = baseline8();
        let s = b.to_toml().unwrap();
        let back = SystemConfig::from_toml(&s).unwrap();
        assert_eq!(back.name, "Baseline8");
        assert_eq!(back.num_gpus, 8);
        assert_eq!(back.fabric, FabricKind::NvlinkRing);
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut b = baseline8();
        b.num_gpus = 0;
        assert!(b.validate().is_err());
        let mut f = fh4_15xm(Bandwidth::tbps(4.0));
        f.remote_capacity = Bytes::ZERO;
        assert!(f.validate().is_err());
        assert!(baseline8().validate().is_ok());
    }

    #[test]
    fn validation_rejects_non_positive_bandwidths() {
        // Zero or negative fabric bandwidth would produce NaN/inf
        // latencies in every downstream `bytes / bw` charge.
        for bad_bw in [0.0, -4.8] {
            let mut f = fh4_15xm(Bandwidth::tbps(4.8));
            f.fabric_bw = Bandwidth::tbps(bad_bw);
            let e = f.validate().unwrap_err().to_string();
            assert!(e.contains("fabric bandwidth"), "{e}");
            let mut b = baseline8();
            b.fabric_bw = Bandwidth::gbps(bad_bw);
            assert!(b.validate().is_err());
        }
        // Local-memory bandwidth is equally guarded.
        let mut f = fh4_15xm(Bandwidth::tbps(4.8));
        f.local_bw = Bandwidth::ZERO;
        assert!(f.validate().is_err());
        let mut f = fh4_15xm(Bandwidth::tbps(4.8));
        f.local_bw = Bandwidth::tbps(-1.0);
        assert!(f.validate().is_err());
        // The positive presets all still pass.
        for sys in [baseline8(), fh4_15xm(Bandwidth::tbps(4.0)), fh4_20xm(Bandwidth::tbps(6.4))] {
            sys.validate().unwrap();
        }
    }

    #[test]
    fn flash_tier_round_trips_and_validates() {
        // 2-tier serialisation is byte-identical to the pre-flash format.
        let plain = fh4_15xm(Bandwidth::tbps(4.8));
        let toml = plain.to_toml().unwrap();
        assert!(!toml.contains("flash"), "no flash keys on a 2-tier config");
        assert!(SystemConfig::from_toml(&toml).unwrap().flash.is_none());

        // 3-tier round-trips exactly.
        let f = plain.clone().with_flash(FlashConfig {
            capacity: Bytes::gb(1024.0),
            bandwidth: Bandwidth::tbps(1.2),
        });
        f.validate().unwrap();
        let back = SystemConfig::from_toml(&f.to_toml().unwrap()).unwrap();
        assert_eq!(back.flash, f.flash);

        // flash_gb alone picks the default media rate.
        let toml2 = format!("{}flash_gb = 512\n", plain.to_toml().unwrap());
        let with_default = SystemConfig::from_toml(&toml2).unwrap().flash.unwrap();
        assert_eq!(with_default.capacity.as_gb(), 512.0);
        assert_eq!(with_default.bandwidth.as_tbps(), DEFAULT_FLASH_TBPS);

        // Bandwidth without capacity is a malformed tier.
        let toml3 = format!("{}flash_bw_tbps = 1.6\n", plain.to_toml().unwrap());
        assert!(SystemConfig::from_toml(&toml3).is_err());
    }

    #[test]
    fn flash_validation_rejects_bad_tiers() {
        // Flash behind a shared-nothing fabric has no pool to back it.
        let b = baseline8().with_flash(FlashConfig::gb(1024.0));
        assert!(b.validate().unwrap_err().to_string().contains("flash"));
        // Non-positive capacity or bandwidth is rejected like any tier.
        let mut f = fh4_15xm(Bandwidth::tbps(4.8)).with_flash(FlashConfig::gb(1024.0));
        f.validate().unwrap();
        f.flash = Some(FlashConfig { capacity: Bytes::ZERO, bandwidth: Bandwidth::tbps(1.6) });
        assert!(f.validate().is_err());
        f.flash = Some(FlashConfig { capacity: Bytes::gb(64.0), bandwidth: Bandwidth::ZERO });
        assert!(f.validate().is_err());
    }

    #[test]
    fn sweep_covers_paper_range() {
        let s = fig41_bandwidth_sweep();
        assert_eq!(s.first().unwrap().as_tbps(), 4.0);
        assert_eq!(s.last().unwrap().as_tbps(), 6.4);
    }

    #[test]
    fn rack_presets_name_replicas_distinctly() {
        let rack = fh4_rack(4, Bandwidth::tbps(4.8));
        assert_eq!(rack.len(), 4);
        assert_eq!(rack[0].name, "FH4-1.5xM/r0");
        assert_eq!(rack[3].name, "FH4-1.5xM/r3");
        for s in &rack {
            assert!(s.is_fenghuang());
            s.validate().unwrap();
        }
        let base = baseline_rack(2);
        assert_eq!(base[1].name, "Baseline8/r1");
        assert!(!base[0].is_fenghuang());
    }
}
