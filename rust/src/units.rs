//! Typed scalar units used throughout the simulator.
//!
//! Everything is `f64`-backed: the simulator works at nanosecond / byte /
//! FLOP granularity and the dynamic range (40 ns notification latencies up
//! to 10^16 FLOP prefill passes) fits comfortably in a double. Newtypes keep
//! bandwidths from being added to latencies by accident.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

macro_rules! unit {
    ($(#[$meta:meta])* $name:ident, $suffix:expr) => {
        $(#[$meta])*
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        pub struct $name(pub f64);

        impl $name {
            pub const ZERO: $name = $name(0.0);

            #[inline]
            pub fn new(v: f64) -> Self {
                debug_assert!(v.is_finite(), concat!(stringify!($name), " must be finite"));
                $name(v)
            }

            #[inline]
            pub fn value(self) -> f64 {
                self.0
            }

            #[inline]
            pub fn max(self, other: Self) -> Self {
                $name(self.0.max(other.0))
            }

            #[inline]
            pub fn min(self, other: Self) -> Self {
                $name(self.0.min(other.0))
            }
        }

        impl Add for $name {
            type Output = $name;
            #[inline]
            fn add(self, rhs: $name) -> $name {
                $name(self.0 + rhs.0)
            }
        }

        impl AddAssign for $name {
            #[inline]
            fn add_assign(&mut self, rhs: $name) {
                self.0 += rhs.0;
            }
        }

        impl Sub for $name {
            type Output = $name;
            #[inline]
            fn sub(self, rhs: $name) -> $name {
                $name(self.0 - rhs.0)
            }
        }

        impl Mul<f64> for $name {
            type Output = $name;
            #[inline]
            fn mul(self, rhs: f64) -> $name {
                $name(self.0 * rhs)
            }
        }

        impl Div<f64> for $name {
            type Output = $name;
            #[inline]
            fn div(self, rhs: f64) -> $name {
                $name(self.0 / rhs)
            }
        }

        /// Ratio of two quantities of the same unit is a plain scalar.
        impl Div for $name {
            type Output = f64;
            #[inline]
            fn div(self, rhs: $name) -> f64 {
                self.0 / rhs.0
            }
        }

        impl Sum for $name {
            fn sum<I: Iterator<Item = $name>>(iter: I) -> $name {
                $name(iter.map(|v| v.0).sum())
            }
        }

        impl fmt::Display for $name {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "{:.4}{}", self.0, $suffix)
            }
        }
    };
}

unit!(
    /// A quantity of bytes.
    Bytes,
    "B"
);
unit!(
    /// A duration in seconds (simulation clock).
    Seconds,
    "s"
);
unit!(
    /// A number of floating-point operations.
    Flops,
    "FLOP"
);
unit!(
    /// A memory / link bandwidth in bytes per second.
    Bandwidth,
    "B/s"
);
unit!(
    /// A compute throughput in FLOP per second.
    FlopRate,
    "FLOP/s"
);

impl Bytes {
    pub fn kib(v: f64) -> Self {
        Bytes(v * 1024.0)
    }
    pub fn mib(v: f64) -> Self {
        Bytes(v * 1024.0 * 1024.0)
    }
    pub fn gib(v: f64) -> Self {
        Bytes(v * 1024.0 * 1024.0 * 1024.0)
    }
    /// Decimal gigabytes — hardware datasheets (H200 "141 GB") use GB.
    pub fn gb(v: f64) -> Self {
        Bytes(v * 1e9)
    }
    pub fn tb(v: f64) -> Self {
        Bytes(v * 1e12)
    }
    pub fn as_gib(self) -> f64 {
        self.0 / (1024.0 * 1024.0 * 1024.0)
    }
    pub fn as_gb(self) -> f64 {
        self.0 / 1e9
    }
    /// Time to move this many bytes at `bw`.
    pub fn over(self, bw: Bandwidth) -> Seconds {
        debug_assert!(bw.0 > 0.0, "bandwidth must be positive");
        Seconds(self.0 / bw.0)
    }
}

impl Seconds {
    pub fn ns(v: f64) -> Self {
        Seconds(v * 1e-9)
    }
    pub fn us(v: f64) -> Self {
        Seconds(v * 1e-6)
    }
    pub fn ms(v: f64) -> Self {
        Seconds(v * 1e-3)
    }
    pub fn as_ns(self) -> f64 {
        self.0 * 1e9
    }
    pub fn as_us(self) -> f64 {
        self.0 * 1e6
    }
    pub fn as_ms(self) -> f64 {
        self.0 * 1e3
    }
}

impl Flops {
    pub fn giga(v: f64) -> Self {
        Flops(v * 1e9)
    }
    pub fn tera(v: f64) -> Self {
        Flops(v * 1e12)
    }
    pub fn as_gflop(self) -> f64 {
        self.0 / 1e9
    }
    pub fn as_tflop(self) -> f64 {
        self.0 / 1e12
    }
    /// Time to execute this many FLOPs at `rate`.
    pub fn over(self, rate: FlopRate) -> Seconds {
        debug_assert!(rate.0 > 0.0, "flop rate must be positive");
        Seconds(self.0 / rate.0)
    }
}

impl Bandwidth {
    pub fn gbps(v: f64) -> Self {
        Bandwidth(v * 1e9)
    }
    pub fn tbps(v: f64) -> Self {
        Bandwidth(v * 1e12)
    }
    pub fn as_gbps(self) -> f64 {
        self.0 / 1e9
    }
    pub fn as_tbps(self) -> f64 {
        self.0 / 1e12
    }
}

impl FlopRate {
    pub fn tflops(v: f64) -> Self {
        FlopRate(v * 1e12)
    }
    pub fn pflops(v: f64) -> Self {
        FlopRate(v * 1e15)
    }
    pub fn as_tflops(self) -> f64 {
        self.0 / 1e12
    }
}

/// Exact nearest-rank percentile over an ascending-sorted sample slice
/// (0 when empty). The single definition shared by the serving latency
/// stats (`coordinator::metrics::LatencyStat`) and the fabric
/// contention ledger (`fabric::contention`), so queue percentiles can
/// never drift from TTFT/TPOT percentiles in the same report.
pub fn percentile_nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize - 1;
    sorted[rank.min(sorted.len() - 1)]
}

/// Numeric precision of a tensor element.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    Bf16,
    F16,
    Fp8,
}

impl Dtype {
    pub fn bytes(self) -> f64 {
        match self {
            Dtype::F32 => 4.0,
            Dtype::Bf16 | Dtype::F16 => 2.0,
            Dtype::Fp8 => 1.0,
        }
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
            Dtype::F16 => "f16",
            Dtype::Fp8 => "fp8",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_constructors_roundtrip() {
        assert_eq!(Bytes::gib(1.0).value(), 1024.0 * 1024.0 * 1024.0);
        assert_eq!(Bytes::gb(1.0).value(), 1e9);
        assert!((Bytes::tb(1.5).as_gb() - 1500.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_matches_hand_calc() {
        // 4 GB over 4 TB/s = 1 ms
        let t = Bytes::gb(4.0).over(Bandwidth::tbps(4.0));
        assert!((t.as_ms() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn flop_time_matches_hand_calc() {
        // 989 TFLOP at 989 TFLOP/s = 1 s
        let t = Flops::tera(989.0).over(FlopRate::tflops(989.0));
        assert!((t.value() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn unit_ratio_is_scalar() {
        assert_eq!(Bytes::gb(8.0) / Bytes::gb(2.0), 4.0);
        assert_eq!(Seconds::ns(1000.0) / Seconds::ns(220.0), 1000.0 / 220.0);
    }

    #[test]
    fn seconds_conversions() {
        assert!((Seconds::ns(1500.0).as_us() - 1.5).abs() < 1e-12);
        assert!((Seconds::ms(2.0).as_ns() - 2e6).abs() < 1e-6);
    }

    #[test]
    fn dtype_widths() {
        assert_eq!(Dtype::F32.bytes(), 4.0);
        assert_eq!(Dtype::Bf16.bytes(), 2.0);
        assert_eq!(Dtype::Fp8.bytes(), 1.0);
    }

    #[test]
    fn nearest_rank_percentile_matches_hand_calc() {
        assert_eq!(percentile_nearest_rank(&[], 95.0), 0.0);
        assert_eq!(percentile_nearest_rank(&[17.0], 1.0), 17.0);
        assert_eq!(percentile_nearest_rank(&[17.0], 100.0), 17.0);
        let s = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile_nearest_rank(&s, 50.0), 30.0);
        assert_eq!(percentile_nearest_rank(&s, 100.0), 50.0);
        assert_eq!(percentile_nearest_rank(&s, 1.0), 10.0);
    }

    #[test]
    fn sum_and_ordering() {
        let total: Seconds = [Seconds::ns(40.0), Seconds::ns(50.0)].into_iter().sum();
        assert!((total.as_ns() - 90.0).abs() < 1e-9);
        assert!(Seconds::ns(90.0) < Seconds::ns(220.0));
        assert_eq!(Seconds::ns(90.0).max(Seconds::ns(220.0)), Seconds::ns(220.0));
    }
}
