//! Trace-driven system simulation (→ Fig 4.1, Table 4.3).
//!
//! Maps operator traces onto a [`SystemConfig`]:
//!
//! * **Baseline (shared-nothing)** — all weights and KV resident in local
//!   HBM; kernels run back-to-back at roofline × efficiency curves;
//!   collectives cost NVLink ring time (§3.3.3 formulas).
//! * **FengHuang** — weights and KV stream from remote memory through the
//!   Paging Stream ([`engine::schedule`], lookahead-1 by default);
//!   kernels read from local memory at the FH local tier's bandwidth;
//!   collectives cost TAB shared-memory time (write-accumulate +
//!   notification + read); peak local-memory occupancy is tracked for
//!   Table 4.3.
//!
//! Per-op time = `max(compute, memory)` roofline with the documented
//! efficiency curves of [`crate::models::mfu`].

use super::engine::{self, OpSchedule};
use super::memory::OccupancyTracker;
use super::prefetcher::PrefetchPolicy;
use crate::config::{FabricKind, SystemConfig};
use crate::error::Result;
use crate::fabric::{collectives, nvlink};
use crate::models::arch::ModelArch;
use crate::models::mfu;
use crate::trace::{self, Op, OpKind, Phase, Trace, TraceConfig};
use crate::units::{Bytes, Seconds};

/// Per-phase simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub system: String,
    pub model: String,
    pub phase: Phase,
    pub batch: u64,
    /// Wall-clock of the step (TTFT for prefill, TPOT for decode).
    pub total: Seconds,
    /// Time the regular stream spent computing (busy).
    pub compute_busy: Seconds,
    /// Time spent in collectives.
    pub comm_time: Seconds,
    /// Paging-stream busy time (zero on baseline).
    pub paging_busy: Seconds,
    /// Stall attributable to prefetch (waiting on the paging stream).
    pub exposed_prefetch: Seconds,
    /// Peak local-memory occupancy per GPU (→ Table 4.3 on FH systems).
    pub peak_local: Bytes,
    pub num_ops: usize,
}

impl SimReport {
    /// Fraction of the step lost to exposed prefetch.
    pub fn exposure_frac(&self) -> f64 {
        if self.total.value() == 0.0 {
            0.0
        } else {
            self.exposed_prefetch / self.total
        }
    }
}

/// Execution time of a *local* (non-collective) op on `sys`.
///
/// Baseline systems read everything (weights, KV, activations) from the
/// resident local HBM layout, at the shard-size-dependent efficiency of
/// [`mfu::mem_eff`]. FengHuang systems read their *staged* working set
/// from the local paging cache as long sequential streams
/// ([`mfu::FH_LOCAL_STREAM_EFF`]), while the attention KV stream is read
/// directly from remote memory by the SMs ([`mfu::FH_KV_STREAM_EFF`],
/// §3.1) on a virtual channel distinct from the paging stream.
fn local_op_time(op: &Op, sys: &SystemConfig) -> Seconds {
    local_op_time_with(op, sys, false)
}

/// As [`local_op_time`], but with `kv_staged = true` the attention KV
/// stream has been staged into local memory by the paging subsystem
/// (`crate::paging`), so it reads at local-tier bandwidth instead of the
/// remote KV virtual channel.
fn local_op_time_with(op: &Op, sys: &SystemConfig, kv_staged: bool) -> Seconds {
    let compute = if op.flops.value() > 0.0 {
        let eff = mfu::mfu(op.m_tokens, op.shard_cols.max(1.0));
        let rate = sys.compute_per_gpu * eff.max(1e-4);
        op.flops.over(rate) * sys.framework_overhead
    } else {
        Seconds::ZERO
    };
    let traffic = op.read_bytes + op.write_bytes;
    let memory = match sys.fabric {
        FabricKind::NvlinkRing => {
            if traffic.value() > 0.0 {
                let eff = mfu::mem_eff(traffic).max(1e-4);
                traffic.over(sys.local_bw * eff)
            } else {
                Seconds::ZERO
            }
        }
        FabricKind::TabSharedMemory => {
            let kv = if kv_staged { Bytes::ZERO } else { op.kv_stream_bytes };
            let local = traffic - kv;
            let kv_time = if kv.value() > 0.0 {
                kv.over(sys.fabric_bw * mfu::FH_KV_STREAM_EFF)
            } else {
                Seconds::ZERO
            };
            let local_time = if local.value() > 0.0 {
                local.over(sys.local_bw * mfu::FH_LOCAL_STREAM_EFF)
            } else {
                Seconds::ZERO
            };
            kv_time + local_time
        }
    };
    compute.max(memory)
}

/// Execution time of a collective op on `sys`.
fn collective_op_time(op: &Op, sys: &SystemConfig) -> Seconds {
    let OpKind::Collective(kind) = op.kind else {
        unreachable!("collective_op_time on non-collective")
    };
    match sys.fabric {
        FabricKind::NvlinkRing => nvlink::ring_collective_time(
            kind,
            op.comm_payload,
            sys.num_gpus,
            sys.fabric_bw,
            &sys.latencies,
        ),
        FabricKind::TabSharedMemory => collectives::tab_collective_time(
            kind,
            op.comm_payload,
            sys.num_gpus,
            sys.fabric_bw,
            &sys.latencies,
        ),
    }
}

pub(crate) fn op_time(op: &Op, sys: &SystemConfig) -> Seconds {
    if op.is_collective() {
        collective_op_time(op, sys)
    } else {
        local_op_time(op, sys)
    }
}

/// Per-op time with the KV stream staged locally by the pager
/// (`crate::paging` orchestrator, `page_kv` policies).
pub(crate) fn op_time_kv_staged(op: &Op, sys: &SystemConfig) -> Seconds {
    if op.is_collective() {
        collective_op_time(op, sys)
    } else {
        local_op_time_with(op, sys, true)
    }
}

/// Simulate one trace on a system.
pub fn simulate_trace(sys: &SystemConfig, tr: &Trace, policy: &PrefetchPolicy) -> SimReport {
    let run: Vec<Seconds> = tr.ops.iter().map(|o| op_time(o, sys)).collect();
    let comm_time: Seconds = tr
        .ops
        .iter()
        .zip(&run)
        .filter(|(o, _)| o.is_collective())
        .map(|(_, t)| *t)
        .sum();
    let compute_busy: Seconds = tr
        .ops
        .iter()
        .zip(&run)
        .filter(|(o, _)| !o.is_collective())
        .map(|(_, t)| *t)
        .sum();

    match sys.fabric {
        FabricKind::NvlinkRing => {
            // Shared-nothing: everything resident; serial op stream.
            let total: Seconds = run.iter().copied().sum();
            let mut occ = OccupancyTracker::new();
            occ.pin(tr.unique_weight_bytes());
            // KV cache stays resident too.
            let kv: Bytes = tr
                .ops
                .iter()
                .filter(|o| matches!(o.kind, OpKind::Attention))
                .map(|o| o.read_bytes)
                .sum();
            occ.pin(kv);
            SimReport {
                system: sys.name.clone(),
                model: tr.model.clone(),
                phase: tr.phase,
                batch: tr.batch,
                total,
                compute_busy,
                comm_time,
                paging_busy: Seconds::ZERO,
                exposed_prefetch: Seconds::ZERO,
                peak_local: occ.peak(),
                num_ops: tr.ops.len(),
            }
        }
        FabricKind::TabSharedMemory => {
            let fetch: Vec<Seconds> = tr
                .ops
                .iter()
                .map(|o| {
                    super::efficiency::prefetch_overhead(
                        policy.remote_bytes(o),
                        sys.fabric_bw,
                        &sys.latencies,
                    )
                })
                .collect();
            let sched = engine::schedule(&fetch, &run, policy.window);
            let total = engine::makespan(&sched);
            let exposed = engine::total_exposed(&sched);
            let paging_busy: Seconds = fetch.iter().copied().sum();
            let peak_local = fh_peak_local(tr, &sched, policy);
            SimReport {
                system: sys.name.clone(),
                model: tr.model.clone(),
                phase: tr.phase,
                batch: tr.batch,
                total,
                compute_busy,
                comm_time,
                paging_busy,
                exposed_prefetch: exposed,
                peak_local,
                num_ops: tr.ops.len(),
            }
        }
    }
}

/// Peak local occupancy on a FengHuang run: each op's prefetched working
/// set is resident from fetch-completion to op-completion; scratch lives
/// for the op's execution (→ Table 4.3).
fn fh_peak_local(tr: &Trace, sched: &[OpSchedule], policy: &PrefetchPolicy) -> Bytes {
    let mut occ = OccupancyTracker::new();
    for (op, os) in tr.ops.iter().zip(sched) {
        let remote = policy.remote_bytes(op);
        if remote.value() > 0.0 {
            occ.add(os.fetch_start, os.end, remote);
        }
        let local_scratch = policy.resident_bytes(op) - op.weight_bytes();
        if local_scratch.value() > 0.0 {
            occ.add(os.start, os.end, local_scratch);
        }
    }
    occ.peak()
}

/// Simulate one phase of a workload with the default prefetch policy.
pub fn simulate(
    sys: &SystemConfig,
    model: &ModelArch,
    batch: u64,
    phase: Phase,
) -> Result<SimReport> {
    simulate_with_policy(sys, model, batch, phase, &PrefetchPolicy::default())
}

/// Simulate one phase with an explicit prefetch policy (ablations).
pub fn simulate_with_policy(
    sys: &SystemConfig,
    model: &ModelArch,
    batch: u64,
    phase: Phase,
    policy: &PrefetchPolicy,
) -> Result<SimReport> {
    sys.validate()?;
    let tr = trace::generate(&TraceConfig { model: model.clone(), tp: sys.tp(), batch, phase });
    let report = simulate_trace(sys, &tr, policy);
    // Capacity check on capped systems.
    if let Some(cap) = sys.local_capacity {
        if report.peak_local > cap {
            return Err(crate::FhError::LocalMemoryThrash {
                op: format!("{}/{:?}", tr.model, tr.phase),
                need_gb: report.peak_local.as_gb(),
                cap_gb: cap.as_gb(),
            });
        }
    }
    Ok(report)
}

/// Full-workload result (one Fig 4.1 bar group).
#[derive(Debug, Clone)]
pub struct WorkloadReport {
    pub system: String,
    pub model: String,
    pub prompt_len: u64,
    pub gen_len: u64,
    pub batch: u64,
    /// Time to first token = one batched prefill pass.
    pub ttft: Seconds,
    /// Time per output token at mid-generation context.
    pub tpot: Seconds,
    /// End-to-end latency = TTFT + gen_len × TPOT.
    pub e2e: Seconds,
    /// Peak local memory over both phases (→ Table 4.3).
    pub peak_local: Bytes,
}

/// Run a (prompt, generation) workload — the paper's Q&A (4096, 1024) and
/// reasoning (512, 16384) tasks, batch 8.
pub fn run_workload(
    sys: &SystemConfig,
    model: &ModelArch,
    batch: u64,
    prompt_len: u64,
    gen_len: u64,
) -> Result<WorkloadReport> {
    let prefill = simulate(sys, model, batch, Phase::Prefill { prompt_len })?;
    // Representative decode step: mid-generation context length.
    let kv_mid = prompt_len + gen_len / 2;
    let decode = simulate(sys, model, batch, Phase::Decode { kv_len: kv_mid })?;
    let ttft = prefill.total;
    let tpot = decode.total;
    Ok(WorkloadReport {
        system: sys.name.clone(),
        model: model.name.clone(),
        prompt_len,
        gen_len,
        batch,
        ttft,
        tpot,
        e2e: ttft + tpot * gen_len as f64,
        peak_local: prefill.peak_local.max(decode.peak_local),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{baseline8, fh4_15xm, fh4_20xm};
    use crate::models::arch::{gpt3_175b, grok1, qwen3_235b};
    use crate::units::Bandwidth;

    #[test]
    fn baseline_gpt3_decode_in_plausible_range() {
        // H200×8 TP-8 GPT-3 decode at batch 8: published small-batch TP-8
        // serving lands in the 15–40 ms/token range.
        let r = simulate(&baseline8(), &gpt3_175b(), 8, Phase::Decode { kv_len: 4608 }).unwrap();
        let ms = r.total.as_ms();
        assert!((10.0..50.0).contains(&ms), "baseline GPT-3 TPOT {ms:.1} ms");
    }

    #[test]
    fn baseline_gpt3_prefill_in_plausible_range() {
        let r =
            simulate(&baseline8(), &gpt3_175b(), 8, Phase::Prefill { prompt_len: 4096 }).unwrap();
        let s = r.total.value();
        assert!((1.0..15.0).contains(&s), "baseline GPT-3 TTFT {s:.2} s");
    }

    #[test]
    fn fh_ttft_stable_across_remote_bandwidth() {
        // §4.2: "TTFT remains relatively stable as remote memory bandwidth
        // increases from 4.0 TB/s to 6.4 TB/s" — prefill hides prefetch.
        let m = gpt3_175b();
        let lo = simulate(
            &fh4_15xm(Bandwidth::tbps(4.0)),
            &m,
            8,
            Phase::Prefill { prompt_len: 4096 },
        )
        .unwrap();
        let hi = simulate(
            &fh4_15xm(Bandwidth::tbps(6.4)),
            &m,
            8,
            Phase::Prefill { prompt_len: 4096 },
        )
        .unwrap();
        let delta = (lo.total.value() - hi.total.value()).abs() / hi.total.value();
        assert!(delta < 0.05, "TTFT moved {delta:.3} with remote BW");
        assert!(lo.exposure_frac() < 0.10, "prefill exposure {:.3}", lo.exposure_frac());
    }

    #[test]
    fn fh_tpot_improves_with_remote_bandwidth() {
        // §4.2: TPOT falls as remote bandwidth rises 4.0 → 6.4 TB/s.
        // Grok-1 is the most remote-bandwidth-bound workload (large
        // experts), so it shows the clearest scaling.
        let m = grok1();
        let lo =
            simulate(&fh4_20xm(Bandwidth::tbps(4.0)), &m, 8, Phase::Decode { kv_len: 4608 })
                .unwrap();
        let hi =
            simulate(&fh4_20xm(Bandwidth::tbps(6.4)), &m, 8, Phase::Decode { kv_len: 4608 })
                .unwrap();
        assert!(hi.total < lo.total, "TPOT must fall with more remote BW");
        let gain = 1.0 - hi.total / lo.total;
        assert!(gain > 0.08, "TPOT gain {gain:.3} too small");
    }

    #[test]
    fn fh_ttft_beats_baseline_at_4tbps() {
        // §4.2: FH4-1.5×M outperforms Baseline8 TTFT for all three models
        // at 4.0 TB/s remote bandwidth.
        for m in [gpt3_175b(), grok1(), qwen3_235b()] {
            let base =
                simulate(&baseline8(), &m, 8, Phase::Prefill { prompt_len: 4096 }).unwrap();
            let fh = simulate(
                &fh4_15xm(Bandwidth::tbps(4.0)),
                &m,
                8,
                Phase::Prefill { prompt_len: 4096 },
            )
            .unwrap();
            assert!(
                fh.total < base.total,
                "{}: FH TTFT {:.2}s !< baseline {:.2}s",
                m.name,
                fh.total.value(),
                base.total.value()
            );
        }
    }

    #[test]
    fn table43_fh_local_memory_order_of_magnitude() {
        // Table 4.3: 10–20 GB local per workload — versus 144 GB HBM, a
        // ≥85% reduction. Our per-op granularity gives the same order.
        for (m, kv) in [(gpt3_175b(), 5120u64), (grok1(), 5120), (qwen3_235b(), 5120)] {
            let r = simulate(&fh4_15xm(Bandwidth::tbps(4.8)), &m, 8, Phase::Decode { kv_len: kv })
                .unwrap();
            let gb = r.peak_local.as_gb();
            assert!(gb > 0.3 && gb < 30.0, "{} peak local {gb:.1} GB", m.name);
            assert!(gb < 0.2 * 144.0, "{}: must be ≫ smaller than 144 GB HBM", m.name);
        }
    }

    #[test]
    fn grok_is_relatively_weakest_at_low_remote_bw() {
        // §4.2: "Grok-1 experiences a slight slowdown at 4.0 TB/s".
        // Check the *relative* ordering: Grok's FH/baseline TPOT ratio is
        // the worst of the three models at 4.0 TB/s.
        let ratio = |m: &crate::models::ModelArch| {
            let b = simulate(&baseline8(), m, 8, Phase::Decode { kv_len: 4608 }).unwrap();
            let f =
                simulate(&fh4_15xm(Bandwidth::tbps(4.0)), m, 8, Phase::Decode { kv_len: 4608 })
                    .unwrap();
            f.total / b.total
        };
        let g = ratio(&grok1());
        let q = ratio(&qwen3_235b());
        let d = ratio(&gpt3_175b());
        assert!(g > q.min(d) - 0.02, "grok ratio {g:.2} vs qwen {q:.2} / gpt3 {d:.2}");
    }

    #[test]
    fn e2e_workload_composes() {
        let r = run_workload(&baseline8(), &gpt3_175b(), 8, 4096, 1024).unwrap();
        assert!(r.e2e.value() > r.ttft.value());
        let expect = r.ttft.value() + 1024.0 * r.tpot.value();
        assert!((r.e2e.value() - expect).abs() < 1e-9);
    }

    #[test]
    fn invalid_config_is_rejected() {
        let mut sys = baseline8();
        sys.num_gpus = 0;
        assert!(simulate(&sys, &gpt3_175b(), 8, Phase::Decode { kv_len: 128 }).is_err());
    }
}
