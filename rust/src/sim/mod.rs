//! Discrete-event simulator of FengHuang and baseline nodes.
//!
//! * [`engine`] — two-stream (Regular + Paging) schedule computation;
//! * [`prefetcher`] — the Tensor Prefetcher policy (lookahead window,
//!   remote working sets, minimal-residency eviction);
//! * [`efficiency`] — Eq 4.1 prefetching-overhead model;
//! * [`memory`] — local-memory occupancy tracking (→ Table 4.3);
//! * [`exec`] — op timing, per-phase simulation, and full-workload
//!   TTFT / TPOT / E2E reports (→ Fig 4.1).

pub mod efficiency;
pub mod engine;
pub mod exec;
pub mod memory;
pub mod prefetcher;

pub use exec::{run_workload, simulate, simulate_trace, simulate_with_policy, SimReport, WorkloadReport};
pub use prefetcher::PrefetchPolicy;
