//! Eq 4.1 — prefetching overhead with size-dependent link efficiency.
//!
//! "To more accurately model prefetching overhead, we apply a scaling
//! coefficient to the theoretical remote memory bandwidth, similar to
//! empirical NVLink behavior. In particular, larger tensor sizes achieve
//! higher effective bandwidth and exhibit reduced latency dominance."
//!
//! The shaping curve lives in [`crate::models::mfu`]; this module gives it
//! the paper's Eq 4.1 name and adds the fixed TAB read latency (Table 3.1)
//! that bounds small transfers.

use crate::fabric::FabricLatencies;
use crate::models::mfu;
use crate::units::{Bandwidth, Bytes, Seconds};

/// Eq 4.1: `Tensor Size / (Remote Memory Bandwidth × Efficiency(Size))`,
/// plus the fixed TAB read latency for the initiating command.
pub fn prefetch_overhead(tensor: Bytes, remote_bw: Bandwidth, lat: &FabricLatencies) -> Seconds {
    if tensor.value() <= 0.0 {
        return Seconds::ZERO;
    }
    lat.tab_read + mfu::transfer_time(tensor, remote_bw)
}

/// Effective bandwidth achieved for a transfer of `tensor` (reported by
/// the ablation benches).
pub fn effective_bandwidth(tensor: Bytes, remote_bw: Bandwidth) -> Bandwidth {
    remote_bw * mfu::link_eff(tensor, remote_bw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn larger_tensors_get_higher_effective_bandwidth() {
        let bw = Bandwidth::tbps(4.0);
        let small = effective_bandwidth(Bytes::mib(1.0), bw);
        let large = effective_bandwidth(Bytes::gib(1.0), bw);
        assert!(large.value() > small.value() * 5.0);
        assert!(large.value() < bw.value(), "never exceeds line rate");
    }

    #[test]
    fn zero_tensor_is_free() {
        let lat = FabricLatencies::default();
        assert_eq!(prefetch_overhead(Bytes::ZERO, Bandwidth::tbps(4.0), &lat), Seconds::ZERO);
    }

    #[test]
    fn overhead_includes_fixed_read_latency() {
        let lat = FabricLatencies::default();
        let t = prefetch_overhead(Bytes::new(64.0), Bandwidth::tbps(4.0), &lat);
        assert!(t.as_ns() >= 220.0);
    }
}
