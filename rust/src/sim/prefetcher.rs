//! Tensor Prefetcher policy (§3.2).
//!
//! Decides *what* each op needs from FengHuang Remote Memory and *when* it
//! may be fetched (lookahead window). On a FengHuang system the remote
//! working set of an op is:
//!
//! * its weight tensors — weights live in remote memory and are paged into
//!   local memory just in time ("the model's weights and intermediate
//!   results that are not used immediately" reside remotely), and
//! * optionally, the attention KV stream. By default (matching §3.1) the
//!   KV cache is read *directly* from remote memory by the SMs through the
//!   caching hierarchy — at Table 4.3 local-memory budgets (10–20 GB) a
//!   long-context batch's KV cannot stay resident. Setting `page_kv`
//!   routes it through the paging stream instead (ablation).
//!
//! Eviction follows the paper's minimal-residency strategy: a tensor is
//! dropped as soon as its consuming op completes ("only the minimum
//! required data are stored locally").

use crate::trace::{Op, OpKind};
use crate::units::Bytes;

/// Prefetcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchPolicy {
    /// Lookahead window w (paper: 1).
    pub window: usize,
    /// Page KV-cache streams through local memory instead of direct
    /// SM-from-remote access (ablation; default false per §3.1).
    pub page_kv: bool,
}

impl Default for PrefetchPolicy {
    fn default() -> Self {
        // The paper evaluates lookahead-1 at Nsight dependency-graph
        // granularity, where a node is roughly one transformer layer's
        // kernel group; our synthetic traces split each layer into 7–9
        // finer ops, so w = 10 (≈ one layer ahead) reproduces the same
        // one-node-ahead overlap (benches/ablations.rs sweeps w).
        PrefetchPolicy { window: 10, page_kv: false }
    }
}

impl PrefetchPolicy {
    /// Bytes op `op` needs moved from remote memory before it can run.
    pub fn remote_bytes(&self, op: &Op) -> Bytes {
        let weights = op.weight_bytes();
        match op.kind {
            OpKind::Attention if self.page_kv => {
                // The attention scratch is dominated by the KV read; the
                // query/output activations are local (produced by the
                // previous op). KV read = read_bytes minus activation in.
                weights + op.read_bytes
            }
            _ => weights,
        }
    }

    /// Bytes resident in local memory while `op` executes (its working
    /// set: weights + scratch, minus any KV stream that flows directly
    /// from remote without staging).
    pub fn resident_bytes(&self, op: &Op) -> Bytes {
        if self.page_kv {
            op.working_set()
        } else {
            op.working_set() - op.kv_stream_bytes
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::gpt3_175b;
    use crate::trace::{generate, Phase, TraceConfig};

    #[test]
    fn gemm_remote_bytes_are_weights_only() {
        let t = generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 1024 },
        });
        let p = PrefetchPolicy::default();
        let qkv = t.ops.iter().find(|o| o.name() == "l0.qkv").unwrap();
        assert_eq!(p.remote_bytes(qkv).value(), qkv.weight_bytes().value());
    }

    #[test]
    fn attention_kv_is_direct_remote_by_default() {
        let t = generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 4096 },
        });
        let attn = t.ops.iter().find(|o| o.name() == "l0.attn").unwrap();
        // Default: KV flows directly from remote — not through the pager,
        // and not resident in local memory.
        let p = PrefetchPolicy::default();
        assert_eq!(p.remote_bytes(attn).value(), 0.0);
        assert!(p.resident_bytes(attn) < attn.working_set());
        // Ablation: page the KV stream through local memory.
        let paged = PrefetchPolicy { page_kv: true, ..Default::default() };
        assert!(paged.remote_bytes(attn).value() > 0.0);
        assert_eq!(paged.resident_bytes(attn).value(), attn.working_set().value());
    }

    #[test]
    fn collectives_need_no_prefetch() {
        let t = generate(&TraceConfig {
            model: gpt3_175b(),
            tp: 4,
            batch: 8,
            phase: Phase::Decode { kv_len: 1024 },
        });
        let p = PrefetchPolicy::default();
        let ar = t.ops.iter().find(|o| o.is_collective()).unwrap();
        assert_eq!(p.remote_bytes(ar).value(), 0.0);
    }
}
