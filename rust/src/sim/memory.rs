//! Local-memory occupancy tracking (→ Table 4.3).
//!
//! The paper reports "the required local memory capacity … determined by
//! the peak memory usage observed during execution on the FengHuang
//! system" under the lookahead-1 prefetch strategy. We track residency as
//! timed intervals — a tensor occupies local memory from the moment its
//! prefetch completes (or its producing op starts, for scratch) until the
//! consuming op finishes — and compute the exact peak by sweeping the
//! interval endpoints.

use crate::units::{Bytes, Seconds};

/// A residency interval: `bytes` live in local memory during [from, to).
#[derive(Debug, Clone, Copy)]
struct Interval {
    from: Seconds,
    to: Seconds,
    bytes: Bytes,
}

/// Accumulates residency intervals and reports the peak occupancy.
#[derive(Debug, Default)]
pub struct OccupancyTracker {
    intervals: Vec<Interval>,
    /// Bytes resident for the whole run (weights pinned across steps, …).
    pinned: Bytes,
}

impl OccupancyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin `bytes` for the entire run (baseline weights, KV cache).
    pub fn pin(&mut self, bytes: Bytes) {
        self.pinned += bytes;
    }

    /// Record `bytes` resident during `[from, to)`.
    pub fn add(&mut self, from: Seconds, to: Seconds, bytes: Bytes) {
        debug_assert!(to >= from, "inverted interval");
        if bytes.value() <= 0.0 || to <= from {
            return;
        }
        self.intervals.push(Interval { from, to, bytes });
    }

    /// Exact peak occupancy: sweep over interval endpoints.
    pub fn peak(&self) -> Bytes {
        if self.intervals.is_empty() {
            return self.pinned;
        }
        let mut events: Vec<(f64, f64)> = Vec::with_capacity(self.intervals.len() * 2);
        for iv in &self.intervals {
            events.push((iv.from.value(), iv.bytes.value()));
            events.push((iv.to.value(), -iv.bytes.value()));
        }
        // Sort by time; at equal times apply releases before acquisitions
        // (an op's working set replaces its predecessor's, it does not
        // stack with it instantaneously). Unstable sort: equal keys are
        // already disambiguated by the second component.
        events.sort_unstable_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.partial_cmp(&b.1).unwrap())
        });
        let mut current = 0.0;
        let mut peak = 0.0f64;
        for (_, delta) in events {
            current += delta;
            peak = peak.max(current);
        }
        Bytes::new(peak + self.pinned.value())
    }

    /// Time-weighted average occupancy (for reports).
    pub fn average(&self, span: Seconds) -> Bytes {
        if span.value() <= 0.0 {
            return self.pinned;
        }
        let weighted: f64 = self
            .intervals
            .iter()
            .map(|iv| iv.bytes.value() * (iv.to.value() - iv.from.value()))
            .sum();
        Bytes::new(weighted / span.value() + self.pinned.value())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: f64) -> Seconds {
        Seconds::new(v)
    }
    fn b(v: f64) -> Bytes {
        Bytes::new(v)
    }

    #[test]
    fn peak_of_overlapping_intervals() {
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(2.0), b(100.0));
        t.add(s(1.0), s(3.0), b(50.0)); // overlap in [1,2) → 150
        t.add(s(4.0), s(5.0), b(120.0));
        assert_eq!(t.peak().value(), 150.0);
    }

    #[test]
    fn back_to_back_intervals_do_not_stack() {
        // Release at t=1 applies before the acquisition at t=1.
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(1.0), b(100.0));
        t.add(s(1.0), s(2.0), b(100.0));
        assert_eq!(t.peak().value(), 100.0);
    }

    #[test]
    fn pinned_adds_to_everything() {
        let mut t = OccupancyTracker::new();
        t.pin(b(1000.0));
        t.add(s(0.0), s(1.0), b(10.0));
        assert_eq!(t.peak().value(), 1010.0);
        let empty = OccupancyTracker::new();
        assert_eq!(empty.peak().value(), 0.0);
    }

    #[test]
    fn zero_length_and_zero_byte_intervals_ignored() {
        let mut t = OccupancyTracker::new();
        t.add(s(1.0), s(1.0), b(500.0));
        t.add(s(0.0), s(2.0), b(0.0));
        assert_eq!(t.peak().value(), 0.0);
    }

    #[test]
    fn average_is_time_weighted() {
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(1.0), b(100.0));
        t.add(s(1.0), s(2.0), b(300.0));
        assert_eq!(t.average(s(2.0)).value(), 200.0);
    }

    #[test]
    fn zero_length_interval_between_real_ones_never_contributes() {
        // A zero-length interval at a live instant must not spike the
        // peak, nor shift the average.
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(2.0), b(100.0));
        t.add(s(1.0), s(1.0), b(1000.0));
        assert_eq!(t.peak().value(), 100.0);
        assert_eq!(t.average(s(2.0)).value(), 100.0);
    }

    #[test]
    fn pinned_only_run_reports_pin_for_peak_and_average() {
        // No intervals at all: both peak and average are exactly the
        // pinned footprint, for any span (including a zero span).
        let mut t = OccupancyTracker::new();
        t.pin(b(700.0));
        t.pin(b(300.0));
        assert_eq!(t.peak().value(), 1000.0);
        assert_eq!(t.average(s(5.0)).value(), 1000.0);
        assert_eq!(t.average(s(0.0)).value(), 1000.0);
    }

    #[test]
    fn overlapping_intervals_ending_at_identical_endpoints() {
        // Three intervals all releasing at t=3: the releases coincide
        // with an acquisition at t=3, which must apply first (negative
        // deltas sort before positive at equal timestamps).
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(3.0), b(100.0));
        t.add(s(1.0), s(3.0), b(50.0));
        t.add(s(2.0), s(3.0), b(25.0));
        t.add(s(3.0), s(4.0), b(120.0));
        // Peak is in [2,3): 100 + 50 + 25; the t=3 handover never stacks.
        assert_eq!(t.peak().value(), 175.0);
    }

    #[test]
    fn identical_intervals_stack_exactly() {
        // Two byte-identical intervals are distinct residents (two
        // tensors staged together), not a dedup target.
        let mut t = OccupancyTracker::new();
        t.add(s(1.0), s(2.0), b(40.0));
        t.add(s(1.0), s(2.0), b(40.0));
        assert_eq!(t.peak().value(), 80.0);
    }
}
