//! Local-memory occupancy tracking (→ Table 4.3).
//!
//! The paper reports "the required local memory capacity … determined by
//! the peak memory usage observed during execution on the FengHuang
//! system" under the lookahead-1 prefetch strategy. We track residency as
//! timed intervals — a tensor occupies local memory from the moment its
//! prefetch completes (or its producing op starts, for scratch) until the
//! consuming op finishes — and compute the exact peak by sweeping the
//! interval endpoints.

use crate::units::{Bytes, Seconds};

/// A residency interval: `bytes` live in local memory during [from, to).
#[derive(Debug, Clone, Copy)]
struct Interval {
    from: Seconds,
    to: Seconds,
    bytes: Bytes,
}

/// Accumulates residency intervals and reports the peak occupancy.
#[derive(Debug, Default)]
pub struct OccupancyTracker {
    intervals: Vec<Interval>,
    /// Bytes resident for the whole run (weights pinned across steps, …).
    pinned: Bytes,
}

impl OccupancyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pin `bytes` for the entire run (baseline weights, KV cache).
    pub fn pin(&mut self, bytes: Bytes) {
        self.pinned += bytes;
    }

    /// Record `bytes` resident during `[from, to)`.
    pub fn add(&mut self, from: Seconds, to: Seconds, bytes: Bytes) {
        debug_assert!(to >= from, "inverted interval");
        if bytes.value() <= 0.0 || to <= from {
            return;
        }
        self.intervals.push(Interval { from, to, bytes });
    }

    /// Exact peak occupancy: sweep over interval endpoints.
    pub fn peak(&self) -> Bytes {
        if self.intervals.is_empty() {
            return self.pinned;
        }
        let mut events: Vec<(f64, f64)> = Vec::with_capacity(self.intervals.len() * 2);
        for iv in &self.intervals {
            events.push((iv.from.value(), iv.bytes.value()));
            events.push((iv.to.value(), -iv.bytes.value()));
        }
        // Sort by time; at equal times apply releases before acquisitions
        // (an op's working set replaces its predecessor's, it does not
        // stack with it instantaneously). Unstable sort: equal keys are
        // already disambiguated by the second component.
        events.sort_unstable_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(a.1.partial_cmp(&b.1).unwrap())
        });
        let mut current = 0.0;
        let mut peak = 0.0f64;
        for (_, delta) in events {
            current += delta;
            peak = peak.max(current);
        }
        Bytes::new(peak + self.pinned.value())
    }

    /// Time-weighted average occupancy (for reports).
    pub fn average(&self, span: Seconds) -> Bytes {
        if span.value() <= 0.0 {
            return self.pinned;
        }
        let weighted: f64 = self
            .intervals
            .iter()
            .map(|iv| iv.bytes.value() * (iv.to.value() - iv.from.value()))
            .sum();
        Bytes::new(weighted / span.value() + self.pinned.value())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: f64) -> Seconds {
        Seconds::new(v)
    }
    fn b(v: f64) -> Bytes {
        Bytes::new(v)
    }

    #[test]
    fn peak_of_overlapping_intervals() {
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(2.0), b(100.0));
        t.add(s(1.0), s(3.0), b(50.0)); // overlap in [1,2) → 150
        t.add(s(4.0), s(5.0), b(120.0));
        assert_eq!(t.peak().value(), 150.0);
    }

    #[test]
    fn back_to_back_intervals_do_not_stack() {
        // Release at t=1 applies before the acquisition at t=1.
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(1.0), b(100.0));
        t.add(s(1.0), s(2.0), b(100.0));
        assert_eq!(t.peak().value(), 100.0);
    }

    #[test]
    fn pinned_adds_to_everything() {
        let mut t = OccupancyTracker::new();
        t.pin(b(1000.0));
        t.add(s(0.0), s(1.0), b(10.0));
        assert_eq!(t.peak().value(), 1010.0);
        let empty = OccupancyTracker::new();
        assert_eq!(empty.peak().value(), 0.0);
    }

    #[test]
    fn zero_length_and_zero_byte_intervals_ignored() {
        let mut t = OccupancyTracker::new();
        t.add(s(1.0), s(1.0), b(500.0));
        t.add(s(0.0), s(2.0), b(0.0));
        assert_eq!(t.peak().value(), 0.0);
    }

    #[test]
    fn average_is_time_weighted() {
        let mut t = OccupancyTracker::new();
        t.add(s(0.0), s(1.0), b(100.0));
        t.add(s(1.0), s(2.0), b(300.0));
        assert_eq!(t.average(s(2.0)).value(), 200.0);
    }
}
