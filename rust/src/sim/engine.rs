//! Two-stream timeline engine.
//!
//! The paper's execution model (§3.2): a **Regular Stream** executes
//! kernels in program order while a **Paging Stream** prefetches each op's
//! remote working set ahead of use (lookahead *w*; the paper evaluates
//! w = 1 — "each node initiates prefetching for its immediate successor").
//!
//! The schedule is the fixed-point of three constraints:
//!
//! 1. the paging stream is serial (one DMA at a time);
//! 2. the prefetch for op *k* may not be issued before op *k − w* has
//!    *started* computing (that is what a lookahead-w window means —
//!    the prefetcher only sees w ops ahead of the op currently entering
//!    execution);
//! 3. op *k* may not start before its prefetch completed and op *k − 1*
//!    finished.
//!
//! Because dependencies only point backwards, a single forward pass
//! computes the exact schedule in O(n).

use crate::units::Seconds;

/// Computed schedule for one op.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OpSchedule {
    /// When the paging stream began fetching this op's working set.
    pub fetch_start: Seconds,
    /// When the working set became resident.
    pub fetch_done: Seconds,
    /// When the regular stream began executing the op.
    pub start: Seconds,
    /// When the op finished.
    pub end: Seconds,
    /// Stall attributable to prefetch (op was ready to run but waited on
    /// the paging stream).
    pub exposed: Seconds,
}

/// Compute the two-stream schedule.
///
/// `fetch[k]` is the prefetch duration of op k's remote working set (zero
/// if nothing is remote); `run[k]` is the op's execution time once
/// resident; `window` is the lookahead w ≥ 1.
pub fn schedule(fetch: &[Seconds], run: &[Seconds], window: usize) -> Vec<OpSchedule> {
    assert_eq!(fetch.len(), run.len());
    assert!(window >= 1, "lookahead window must be ≥ 1");
    let n = fetch.len();
    let mut out = Vec::with_capacity(n);
    let mut paging_free = Seconds::ZERO;
    let mut compute_free = Seconds::ZERO;
    let mut starts: Vec<Seconds> = Vec::with_capacity(n);
    for k in 0..n {
        // Constraint 2: window gate.
        let gate = if k >= window { starts[k - window] } else { Seconds::ZERO };
        // Constraint 1: serial paging stream.
        let fetch_start = paging_free.max(gate);
        let fetch_done = fetch_start + fetch[k];
        paging_free = fetch_done;
        // Constraint 3: both predecessor-done and residency.
        let start = compute_free.max(fetch_done);
        let exposed = start - compute_free;
        let end = start + run[k];
        compute_free = end;
        starts.push(start);
        out.push(OpSchedule { fetch_start, fetch_done, start, end, exposed });
    }
    out
}

/// Total runtime of a schedule.
pub fn makespan(sched: &[OpSchedule]) -> Seconds {
    sched.last().map(|s| s.end).unwrap_or(Seconds::ZERO)
}

/// Total prefetch-exposed stall.
pub fn total_exposed(sched: &[OpSchedule]) -> Seconds {
    sched.iter().map(|s| s.exposed).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: f64) -> Seconds {
        Seconds::new(v)
    }

    #[test]
    fn fully_hidden_prefetch() {
        // Long compute, short fetches: makespan = fetch[0] + Σ run.
        let fetch = vec![s(1.0); 4];
        let run = vec![s(10.0); 4];
        let sched = schedule(&fetch, &run, 1);
        assert_eq!(makespan(&sched), s(1.0 + 40.0));
        // Only the first op's fetch is exposed (cold start).
        assert_eq!(total_exposed(&sched), s(1.0));
    }

    #[test]
    fn prefetch_bound_pipeline() {
        // Fetches dominate: makespan ≈ Σ fetch + last run.
        let fetch = vec![s(10.0); 4];
        let run = vec![s(1.0); 4];
        let sched = schedule(&fetch, &run, 1);
        assert_eq!(makespan(&sched), s(40.0 + 1.0));
        assert_eq!(total_exposed(&sched), s(40.0 - 3.0)); // run overlap hides 3
    }

    #[test]
    fn window_gate_limits_lookahead() {
        // With w=1, fetch k may not start before op k−1 starts. First op
        // starts at fetch[0]=10; so fetch[1] starts at 10, not 0.
        let fetch = vec![s(10.0), s(10.0)];
        let run = vec![s(1.0), s(1.0)];
        let sched = schedule(&fetch, &run, 1);
        assert_eq!(sched[1].fetch_start, s(10.0));
        assert_eq!(sched[1].start, s(20.0));
    }

    #[test]
    fn wider_window_reduces_makespan_when_fetches_vary() {
        // A large fetch late in the trace benefits from an earlier issue.
        let fetch = vec![s(0.0), s(1.0), s(1.0), s(30.0), s(0.0)];
        let run = vec![s(10.0), s(10.0), s(10.0), s(1.0), s(1.0)];
        let w1 = makespan(&schedule(&fetch, &run, 1));
        let w3 = makespan(&schedule(&fetch, &run, 3));
        assert!(w3 < w1, "w=3 {w3:?} should beat w=1 {w1:?}");
    }

    #[test]
    fn zero_fetch_ops_run_back_to_back() {
        let fetch = vec![Seconds::ZERO; 5];
        let run = vec![s(2.0); 5];
        let sched = schedule(&fetch, &run, 1);
        assert_eq!(makespan(&sched), s(10.0));
        assert_eq!(total_exposed(&sched), Seconds::ZERO);
        for (i, os) in sched.iter().enumerate() {
            assert_eq!(os.start, s(2.0 * i as f64));
        }
    }

    #[test]
    fn monotone_nonoverlapping_compute() {
        let fetch: Vec<_> = (0..20).map(|i| s((i % 3) as f64)).collect();
        let run: Vec<_> = (0..20).map(|i| s((i % 5) as f64 + 0.5)).collect();
        let sched = schedule(&fetch, &run, 2);
        for w in sched.windows(2) {
            assert!(w[1].start >= w[0].end, "regular stream must be serial");
        }
        for os in &sched {
            assert!(os.fetch_done <= os.start, "op must wait for residency");
        }
    }
}
