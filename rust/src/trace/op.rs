//! Operator-level trace records.
//!
//! The paper's simulator "constructs a dependency graph from profiling
//! traces" (§4.1.3). Our traces carry the same information Nsight would:
//! for every kernel, its FLOPs, its local-memory traffic, the weight
//! tensors it needs resident, and — for communication ops — the collective
//! kind and payload. Dependencies are the sequential program order of one
//! decoder step (SGLang executes layers in order; parallelism lives inside
//! ops, not between them).

use crate::fabric::Collective;
use crate::units::{Bytes, Flops};

/// Stable identity of a weight tensor (same across decode steps, so the
/// paging simulator can reason about residency and reuse).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u64);

/// A weight tensor an op needs resident in local memory before it runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WeightRef {
    pub id: TensorId,
    pub bytes: Bytes,
}

/// What an op does — drives the timing model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// Dense GEMM-like compute (projections, FFN, router, lm head).
    Gemm,
    /// Attention score/value kernels (streams KV cache).
    Attention,
    /// Expert FFN of a MoE layer (large weight working set).
    MoeExperts,
    /// Inter-GPU collective.
    Collective(Collective),
    /// Element-wise / norm / embedding — bandwidth-only.
    Memory,
}

/// Which operator within a layer (cheap, Copy — avoids per-op string
/// allocation on the simulator hot path; render with [`Op::name`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpName {
    Embed,
    Qkv,
    Attn,
    OProj,
    ArAttn,
    Router,
    A2aDispatch,
    Experts,
    A2aCombine,
    ArFfn,
    FfnUp,
    FfnDown,
    LmHead,
}

impl OpName {
    pub fn suffix(self) -> &'static str {
        match self {
            OpName::Embed => "embed",
            OpName::Qkv => "qkv",
            OpName::Attn => "attn",
            OpName::OProj => "o_proj",
            OpName::ArAttn => "ar_attn",
            OpName::Router => "router",
            OpName::A2aDispatch => "a2a_dispatch",
            OpName::Experts => "experts",
            OpName::A2aCombine => "a2a_combine",
            OpName::ArFfn => "ar_ffn",
            OpName::FfnUp => "ffn_up",
            OpName::FfnDown => "ffn_down",
            OpName::LmHead => "lm_head",
        }
    }
}

/// One operator in the trace.
#[derive(Debug, Clone)]
pub struct Op {
    pub op: OpName,
    pub layer: u32,
    pub kind: OpKind,
    /// FLOPs executed by this GPU (already divided by TP degree).
    pub flops: Flops,
    /// Bytes this GPU reads from local memory: weights + activations + KV.
    pub read_bytes: Bytes,
    /// Bytes written back to local memory (outputs, KV appends).
    pub write_bytes: Bytes,
    /// Weight tensors that must be resident before execution (per-GPU
    /// shard sizes). Empty for collectives / attention.
    pub weights: Vec<WeightRef>,
    /// GEMM M dimension (tokens) — drives the MFU batch axis.
    pub m_tokens: f64,
    /// Per-GPU GEMM output columns — drives the MFU shard axis.
    pub shard_cols: f64,
    /// Collective payload per GPU (logical tensor size), if a collective.
    pub comm_payload: Bytes,
    /// Non-weight working set (activations in + out + KV read) the op
    /// needs in local memory while running.
    pub scratch_bytes: Bytes,
    /// KV-cache stream bytes (attention ops). On FengHuang systems these
    /// are read *directly* from remote memory through the caching
    /// hierarchy (§3.1: tensors can be "accessed by the SMs through the
    /// caching hierarchy" without staging), on a separate virtual channel
    /// from the paging stream.
    pub kv_stream_bytes: Bytes,
}

impl Op {
    /// Human-readable name, e.g. `l3.qkv` (rendered on demand).
    pub fn name(&self) -> String {
        match self.op {
            OpName::Embed | OpName::LmHead => self.op.suffix().to_string(),
            _ => format!("l{}.{}", self.layer, self.op.suffix()),
        }
    }

    pub fn weight_bytes(&self) -> Bytes {
        self.weights.iter().map(|w| w.bytes).sum()
    }

    /// Total local-memory working set while this op runs.
    pub fn working_set(&self) -> Bytes {
        self.weight_bytes() + self.scratch_bytes
    }

    pub fn is_collective(&self) -> bool {
        matches!(self.kind, OpKind::Collective(_))
    }
}

/// Inference phase described by a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Process a prompt of `prompt_len` tokens per request.
    Prefill { prompt_len: u64 },
    /// Generate one token with `kv_len` tokens of context per request.
    Decode { kv_len: u64 },
}

/// A full single-step trace: one prefill pass or one decode step.
#[derive(Debug, Clone)]
pub struct Trace {
    pub model: String,
    pub phase: Phase,
    pub tp: usize,
    pub batch: u64,
    pub ops: Vec<Op>,
}

impl Trace {
    pub fn total_flops(&self) -> Flops {
        self.ops.iter().map(|o| o.flops).sum()
    }

    pub fn total_read_bytes(&self) -> Bytes {
        self.ops.iter().map(|o| o.read_bytes).sum()
    }

    /// Total unique weight bytes (each tensor counted once — decode steps
    /// revisit the same tensors).
    pub fn unique_weight_bytes(&self) -> Bytes {
        let mut seen = std::collections::HashSet::new();
        self.ops
            .iter()
            .flat_map(|o| o.weights.iter())
            .filter(|w| seen.insert(w.id))
            .map(|w| w.bytes)
            .sum()
    }

    pub fn num_collectives(&self) -> usize {
        self.ops.iter().filter(|o| o.is_collective()).count()
    }
}
