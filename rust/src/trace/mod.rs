//! Synthetic operator traces — the stand-in for the paper's Nsight
//! profiling traces (§4.1.3, and DESIGN.md §1 substitution table).

pub mod gen;
pub mod op;

pub use gen::{generate, TraceConfig};
pub use op::{Op, OpKind, OpName, Phase, TensorId, Trace, WeightRef};
