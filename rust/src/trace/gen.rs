//! Synthetic operator-trace generator.
//!
//! Produces the per-layer kernel sequence a tensor-parallel SGLang-style
//! engine executes for one prefill pass or one decode step, with the same
//! metadata the paper extracts from Nsight traces (§4.1.3): FLOPs, memory
//! traffic, weight working sets, collective payloads.
//!
//! Layer structure (Megatron-style TP over `tp` GPUs):
//!
//! ```text
//! embed → [ qkv_proj → attention → o_proj → AllReduce →
//!           (router → experts → AllReduce)  |  (ffn_up → ffn_down → AllReduce) ]×L
//!       → final_norm → lm_head
//! ```
//!
//! MoE layers route tokens to experts; with batch-level top-k routing the
//! expected number of *distinct* experts activated bounds the weight bytes
//! a decode step touches (see `models::flops::distinct_active_param_count`).

use super::op::{Op, OpKind, OpName, Phase, Trace, WeightRef};
use crate::fabric::Collective;
use crate::models::arch::{Attention, FeedForward, ModelArch};
use crate::models::comm::ACT_DTYPE;
use crate::units::{Bytes, Flops};

/// Trace-generation parameters.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub model: ModelArch,
    /// Tensor-parallel degree (8 for Baseline8, 4 for FH4).
    pub tp: usize,
    pub batch: u64,
    pub phase: Phase,
}

struct Gen<'a> {
    cfg: &'a TraceConfig,
    ops: Vec<Op>,
    next_id: u64,
}

impl<'a> Gen<'a> {
    fn tokens(&self) -> f64 {
        match self.cfg.phase {
            Phase::Prefill { prompt_len } => (self.cfg.batch * prompt_len) as f64,
            Phase::Decode { .. } => self.cfg.batch as f64,
        }
    }

    fn context(&self) -> f64 {
        match self.cfg.phase {
            Phase::Prefill { prompt_len } => prompt_len as f64 / 2.0, // causal average
            Phase::Decode { kv_len } => kv_len as f64,
        }
    }

    fn wdt(&self) -> f64 {
        self.cfg.model.weight_dtype.bytes()
    }

    fn adt(&self) -> f64 {
        ACT_DTYPE.bytes()
    }

    fn fresh_id(&mut self) -> super::op::TensorId {
        let id = self.next_id;
        self.next_id += 1;
        super::op::TensorId(id)
    }

    /// Emit a weight-bearing GEMM: `params` weights (full, pre-TP), output
    /// width `out_cols` (full). Activation in/out included in scratch.
    fn gemm(&mut self, name: OpName, layer: u32, kind: OpKind, params: f64, out_cols: f64) {
        let tp = self.cfg.tp as f64;
        let tokens = self.tokens();
        let w_bytes = params / tp * self.wdt();
        let act_in = tokens * self.cfg.model.hidden as f64 * self.adt();
        let act_out = tokens * out_cols / tp * self.adt();
        let id = self.fresh_id();
        self.ops.push(Op {
            op: name,
            layer,
            kind,
            flops: Flops::new(2.0 * tokens * params / tp),
            read_bytes: Bytes::new(w_bytes + act_in),
            write_bytes: Bytes::new(act_out),
            weights: vec![WeightRef { id, bytes: Bytes::new(w_bytes) }],
            m_tokens: tokens,
            shard_cols: out_cols / tp,
            comm_payload: Bytes::ZERO,
            scratch_bytes: Bytes::new(act_in + act_out),
            kv_stream_bytes: Bytes::ZERO,
        });
    }

    fn collective(&mut self, name: OpName, layer: u32, op: Collective, payload_elems: f64) {
        let payload = Bytes::new(payload_elems * self.adt());
        self.ops.push(Op {
            op: name,
            layer,
            kind: OpKind::Collective(op),
            flops: Flops::ZERO,
            read_bytes: Bytes::ZERO,
            write_bytes: Bytes::ZERO,
            weights: vec![],
            m_tokens: self.tokens(),
            shard_cols: 0.0,
            comm_payload: payload,
            scratch_bytes: payload,
            kv_stream_bytes: Bytes::ZERO,
        });
    }

    fn attention(&mut self, layer: u32) {
        let m = &self.cfg.model;
        let tp = self.cfg.tp as f64;
        let tokens = self.tokens();
        let ctx = self.context();
        // Score + value GEMMs, sharded by heads.
        let flops = 4.0 * m.q_dim() as f64 * ctx * tokens / tp;
        // KV stream: context × kv bytes per token per layer, per batch lane
        // for decode; for prefill KV is produced as it goes (count once).
        let kv_per_tok = crate::models::memory::kv_bytes_per_token_per_layer(m).value();
        let kv_read = match self.cfg.phase {
            Phase::Prefill { prompt_len } => {
                self.cfg.batch as f64 * prompt_len as f64 * kv_per_tok / tp
            }
            Phase::Decode { kv_len } => self.cfg.batch as f64 * kv_len as f64 * kv_per_tok / tp,
        };
        let act = tokens * m.q_dim() as f64 / tp * self.adt();
        let kv_write = tokens * kv_per_tok / tp;
        self.ops.push(Op {
            op: OpName::Attn,
            layer,
            kind: OpKind::Attention,
            flops: Flops::new(flops),
            read_bytes: Bytes::new(kv_read + act),
            write_bytes: Bytes::new(act + kv_write),
            weights: vec![],
            m_tokens: tokens,
            shard_cols: m.q_dim() as f64 / tp,
            comm_payload: Bytes::ZERO,
            scratch_bytes: Bytes::new(kv_read + 2.0 * act),
            kv_stream_bytes: Bytes::new(kv_read),
        });
    }

    /// Expected number of distinct experts activated in one step.
    fn distinct_experts(&self, experts: u32, top_k: u32) -> f64 {
        let e = experts as f64;
        let k = top_k as f64;
        let routed_tokens = self.tokens();
        e * (1.0 - (1.0 - k / e).powf(routed_tokens))
    }

    fn layer(&mut self, l: u32) {
        let m = self.cfg.model.clone();
        let h = m.hidden as f64;
        let tokens = self.tokens();

        // QKV projection.
        let (qkv_params, qkv_cols) = match m.attention {
            Attention::Mha | Attention::Gqa { .. } => {
                let cols = (m.q_dim() + 2 * m.kv_dim()) as f64;
                (h * cols, cols)
            }
            Attention::Mla { kv_lora_rank, rope_head_dim } => {
                let q = m.q_dim() as f64;
                let rank = kv_lora_rank as f64;
                let rope = rope_head_dim as f64;
                // q proj + joint kv down-proj + kv up-projs.
                let params = h * q + h * (rank + rope) + 2.0 * rank * q;
                (params, q + rank + rope)
            }
        };
        self.gemm(OpName::Qkv, l, OpKind::Gemm, qkv_params, qkv_cols);
        self.attention(l);
        self.gemm(OpName::OProj, l, OpKind::Gemm, m.q_dim() as f64 * h, h);
        self.collective(OpName::ArAttn, l, Collective::AllReduce, tokens * h);

        let is_moe_layer = m.is_moe() && l >= m.dense_prefix_layers;
        match m.ffn {
            FeedForward::Moe {
                experts,
                top_k,
                expert_intermediate,
                shared_experts,
                shared_intermediate,
                gated,
            } if is_moe_layer => {
                // Router.
                self.gemm(OpName::Router, l, OpKind::Gemm, h * experts as f64, experts as f64);
                // Token dispatch (AllToAll on expert-parallel systems; TP
                // systems fold this into the same payload accounting).
                self.collective(OpName::A2aDispatch, l, Collective::AllToAll, tokens * h);
                // Expert FFNs: weight working set = distinct experts.
                let mats = if gated { 3.0 } else { 2.0 };
                let distinct = self.distinct_experts(experts, top_k);
                let expert_params = mats * h * expert_intermediate as f64;
                let shared_params =
                    shared_experts as f64 * mats * h * shared_intermediate as f64;
                let tp = self.cfg.tp as f64;
                let w_bytes = (distinct * expert_params + shared_params) / tp * self.wdt();
                // FLOPs: every token runs top_k experts (+ shared).
                let flops = 2.0
                    * tokens
                    * (top_k as f64 * expert_params + shared_params)
                    / tp;
                let act = tokens * h * self.adt();
                let id = self.fresh_id();
                self.ops.push(Op {
                    op: OpName::Experts,
                    layer: l,
                    kind: OpKind::MoeExperts,
                    flops: Flops::new(flops),
                    read_bytes: Bytes::new(w_bytes + act),
                    write_bytes: Bytes::new(act),
                    weights: vec![WeightRef { id, bytes: Bytes::new(w_bytes) }],
                    m_tokens: tokens * top_k as f64 / distinct.max(1.0),
                    shard_cols: expert_intermediate as f64 / tp,
                    comm_payload: Bytes::ZERO,
                    scratch_bytes: Bytes::new(2.0 * act),
                    kv_stream_bytes: Bytes::ZERO,
                });
                self.collective(OpName::A2aCombine, l, Collective::AllToAll, tokens * h);
                self.collective(OpName::ArFfn, l, Collective::AllReduce, tokens * h);
            }
            _ => {
                // Dense FFN (or dense-prefix layer of a MoE model).
                let (inter, gated) = match m.ffn {
                    FeedForward::Dense { intermediate, gated } => (intermediate as f64, gated),
                    FeedForward::Moe { .. } => (4.0 * h, true),
                };
                let up_mats = if gated { 2.0 } else { 1.0 };
                self.gemm(OpName::FfnUp, l, OpKind::Gemm, up_mats * h * inter, inter);
                self.gemm(OpName::FfnDown, l, OpKind::Gemm, inter * h, h);
                self.collective(OpName::ArFfn, l, Collective::AllReduce, tokens * h);
            }
        }
    }

    fn run(mut self) -> Trace {
        let m = self.cfg.model.clone();
        let tokens = self.tokens();
        let h = m.hidden as f64;
        // Embedding lookup: bandwidth-only (gather of `tokens` rows).
        let embed_read = tokens * h * self.wdt();
        self.ops.push(Op {
            op: OpName::Embed,
            layer: 0,
            kind: OpKind::Memory,
            flops: Flops::ZERO,
            read_bytes: Bytes::new(embed_read),
            write_bytes: Bytes::new(tokens * h * self.adt()),
            weights: vec![],
            m_tokens: tokens,
            shard_cols: h,
            comm_payload: Bytes::ZERO,
            scratch_bytes: Bytes::new(tokens * h * self.adt()),
            kv_stream_bytes: Bytes::ZERO,
        });
        for l in 0..m.layers {
            self.layer(l);
        }
        // LM head: only the last position of each request produces logits.
        let logit_tokens = self.cfg.batch as f64;
        let tp = self.cfg.tp as f64;
        let head_params = m.vocab as f64 * h;
        let id = self.fresh_id();
        self.ops.push(Op {
            op: OpName::LmHead,
            layer: m.layers,
            kind: OpKind::Gemm,
            flops: Flops::new(2.0 * logit_tokens * head_params / tp),
            read_bytes: Bytes::new(head_params / tp * self.wdt()),
            write_bytes: Bytes::new(logit_tokens * m.vocab as f64 / tp * self.adt()),
            weights: vec![WeightRef {
                id,
                bytes: Bytes::new(head_params / tp * self.wdt()),
            }],
            m_tokens: logit_tokens,
            shard_cols: m.vocab as f64 / tp,
            comm_payload: Bytes::ZERO,
            scratch_bytes: Bytes::new(logit_tokens * m.vocab as f64 / tp * self.adt()),
            kv_stream_bytes: Bytes::ZERO,
        });
        Trace {
            model: m.name.clone(),
            phase: self.cfg.phase,
            tp: self.cfg.tp,
            batch: self.cfg.batch,
            ops: self.ops,
        }
    }
}

/// Generate the operator trace for one prefill pass or one decode step.
pub fn generate(cfg: &TraceConfig) -> Trace {
    assert!(cfg.tp >= 1, "tp must be ≥ 1");
    assert!(cfg.batch >= 1, "batch must be ≥ 1");
    Gen { cfg, ops: Vec::new(), next_id: 0 }.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch::*;
    use crate::units::Dtype;

    fn cfg(m: ModelArch, tp: usize, batch: u64, phase: Phase) -> TraceConfig {
        TraceConfig { model: m, tp, batch, phase }
    }

    #[test]
    fn dense_trace_has_expected_op_count() {
        // GPT-3: embed + 96 × (qkv, attn, o, AR, up, down, AR) + lm_head.
        let t = generate(&cfg(gpt3_175b(), 8, 8, Phase::Decode { kv_len: 1024 }));
        assert_eq!(t.ops.len(), 2 + 96 * 7);
        assert_eq!(t.num_collectives(), 96 * 2);
    }

    #[test]
    fn moe_trace_has_router_and_a2a() {
        let t = generate(&cfg(qwen3_235b(), 4, 8, Phase::Decode { kv_len: 1024 }));
        // Per layer: qkv, attn, o, AR, router, a2a, experts, a2a, AR = 9.
        assert_eq!(t.ops.len(), 2 + 94 * 9);
        // 2 AllReduce + 2 AllToAll per MoE layer (matches
        // models::comm::collectives_per_layer).
        assert_eq!(t.num_collectives(), 94 * 4);
    }

    #[test]
    fn decode_flops_match_analytical_model() {
        // The trace's total FLOPs (×tp, since each op is per-GPU) must be
        // close to models::flops::decode_flops_per_token × batch.
        let m = gpt3_175b();
        let batch = 8u64;
        let kv = 2048u64;
        let t = generate(&cfg(m.clone(), 8, batch, Phase::Decode { kv_len: kv }));
        let trace_flops = t.total_flops().value() * 8.0;
        let analytic =
            crate::models::flops::decode_flops_per_token(&m, kv).value() * batch as f64;
        let rel = (trace_flops - analytic).abs() / analytic;
        assert!(rel < 0.05, "trace {trace_flops:.3e} vs analytic {analytic:.3e} ({rel:.3})");
    }

    #[test]
    fn prefill_flops_match_analytical_model() {
        let m = qwen3_235b();
        let t = generate(&cfg(m.clone(), 4, 8, Phase::Prefill { prompt_len: 4096 }));
        let trace_flops = t.total_flops().value() * 4.0;
        let analytic = crate::models::flops::prefill_flops(&m, 4096).value() * 8.0;
        let rel = (trace_flops - analytic).abs() / analytic;
        assert!(rel < 0.08, "trace {trace_flops:.3e} vs analytic {analytic:.3e} ({rel:.3})");
    }

    #[test]
    fn unique_weight_bytes_close_to_param_shard() {
        // Dense model: every parameter appears exactly once in the trace;
        // unique weight bytes ≈ param_bytes / tp (embedding excluded — it
        // is gathered, not matmul'd; lm_head shares it).
        let m = gpt3_175b();
        let t = generate(&cfg(m.clone(), 8, 8, Phase::Decode { kv_len: 128 }));
        let total = crate::models::memory::param_bytes(&m).value() / 8.0;
        let traced = t.unique_weight_bytes().value();
        let rel = (traced - total).abs() / total;
        assert!(rel < 0.02, "traced {traced:.3e} vs shard {total:.3e}");
    }

    #[test]
    fn grok_expert_working_set_is_large() {
        // Grok-1 batch 8: E(1−(1−2/8)^8)·expert_params ≈ 7.2 experts of
        // 3·6144·32768 — the "large expert architecture" the paper blames
        // for the 4.0 TB/s slowdown.
        let t = generate(&cfg(grok1(), 4, 8, Phase::Decode { kv_len: 1024 }));
        let experts_op = t.ops.iter().find(|o| o.name() == "l0.experts").unwrap();
        let gb = experts_op.weight_bytes().as_gb();
        assert!(gb > 1.5 && gb < 3.0, "grok per-layer expert shard {gb:.2} GB");
    }

    #[test]
    fn decode_touches_fewer_expert_bytes_than_prefill() {
        let m = qwen3_235b();
        let d = generate(&cfg(m.clone(), 4, 8, Phase::Decode { kv_len: 1024 }));
        let p = generate(&cfg(m, 4, 8, Phase::Prefill { prompt_len: 4096 }));
        assert!(d.unique_weight_bytes() < p.unique_weight_bytes());
    }

    #[test]
    fn tensor_ids_are_unique_within_trace() {
        let t = generate(&cfg(deepseek_v3(), 4, 8, Phase::Decode { kv_len: 512 }));
        let ids: Vec<_> = t.ops.iter().flat_map(|o| o.weights.iter().map(|w| w.id)).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(ids.len(), dedup.len());
    }

    #[test]
    fn fp8_model_halves_weight_bytes() {
        let mut m = deepseek_v3();
        let t8 = generate(&cfg(m.clone(), 4, 8, Phase::Decode { kv_len: 512 }));
        m.weight_dtype = Dtype::F16;
        let t16 = generate(&cfg(m, 4, 8, Phase::Decode { kv_len: 512 }));
        let r = t16.unique_weight_bytes() / t8.unique_weight_bytes();
        assert!((r - 2.0).abs() < 0.05, "fp16/fp8 weight ratio {r:.3}");
    }
}
