//! Deterministic observability layer (DESIGN.md §Telemetry).
//!
//! Three pillars, driven identically by both cluster cores:
//!
//! * **Request span traces** — every completed request carries a causal
//!   lifecycle breakdown (queue wait → prefill compute → prefix fetch →
//!   swap stall → decode), recorded by the serving loops as pure copies
//!   of values the hot path already computed. The per-span conservation
//!   identity is *bitwise*: `prefill_done = queue_end + ((compute +
//!   fetch) + swap)` in exactly the association the serving loops used
//!   for `elapsed`, and `ttft = prefill_done − arrival` — so the span
//!   components provably sum to the measured TTFT
//!   (`rust/tests/telemetry_props.rs`).
//! * **Windowed time-series** — a [`TelemetrySampler`] pumped by the
//!   `TelemetryTick` event class / the stepping loop's merged tick,
//!   recording fleet gauges per interval (active replicas, queue depth,
//!   cumulative counters, pool bytes, fabric busy time).
//! * **A fleet stall-attribution ledger** — [`StallLedger`], embedded
//!   in `Metrics` and merged per replica and per tenant, totalling
//!   where every second of request latency went.
//!
//! **Passthrough proof obligation:** with telemetry off nothing here is
//! constructed, no tick is scheduled, and the serving loops take no
//! telemetry branch that touches an `f64` on the clock/metrics path —
//! so a telemetry-off run is bit-identical to the pre-telemetry
//! simulator. A telemetry-ON run leaves every *count* (completions,
//! tokens, SLO verdicts, shed/rejected) untouched — recording is pure
//! observation — though like autoscale ticks the sampling tick can
//! stretch an idle replica's clock to the tick instant, so makespans
//! may differ from the off run. Both pinned by
//! `rust/tests/telemetry_props.rs` and `benches/telemetry_overhead.rs`.

pub mod export;

use crate::error::{FhError, Result};
use crate::units::Seconds;

/// Telemetry knobs (`ClusterConfig::telemetry`; CLI `serve --telemetry
/// [--telemetry-interval-ms N]`). `None` = subsystem fully dormant.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryConfig {
    /// Sampling interval of the time-series tick (also the window width
    /// of the rolling-attainment curve).
    pub interval: Seconds,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { interval: Seconds::ms(100.0) }
    }
}

impl TelemetryConfig {
    pub fn validate(&self) -> Result<()> {
        if self.interval.value() <= 0.0 {
            return Err(FhError::Config(format!(
                "telemetry interval must be positive (got {} s)",
                self.interval.value()
            )));
        }
        Ok(())
    }
}

/// Where a span's lifecycle was observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Whole lifecycle on one replica (prefill + decode).
    Full,
    /// Prefill-only side of a disaggregated handoff: the span ends at
    /// the handoff instant (`finish = prefill_done`, `generated = 1`).
    PrefillHandoff,
    /// Decode side of a handoff: prefill components are zero (they were
    /// charged on the prefill replica), `ttft` is carried over, and
    /// `prefill_done` is reconstructed as `arrival + ttft`.
    DecodeInjected,
}

/// Prefill-step attribution captured by the serving loops when a batch
/// completes: pure copies of the values the hot path already computed,
/// in the exact shape needed to reconstruct the clock advance bitwise.
///
/// The serving loops advance their clock by `elapsed = compute + fetch
/// + swap` (left-to-right association — part of the bit-identity
/// contract); [`SpanStart::prefill_done`] replays that association.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpanStart {
    /// Replica clock just before the prefill batch ran (end of this
    /// request's queue wait).
    pub queue_end: Seconds,
    /// Batch prefill compute (shared by every request in the batch —
    /// TTFT semantics charge each request the whole batch cost).
    pub compute: Seconds,
    /// Batch prefix-cache fetch stall (serial, batch-summed).
    pub fetch: Seconds,
    /// Batch cold-start model-swap stall (serial, batch-summed).
    pub swap: Seconds,
}

impl SpanStart {
    /// Replica clock at prefill completion, reconstructed in the serving
    /// loops' exact f64 association.
    pub fn prefill_done(&self) -> Seconds {
        self.queue_end + ((self.compute + self.fetch) + self.swap)
    }
}

/// One completed request's lifecycle trace.
#[derive(Debug, Clone, Copy)]
pub struct RequestSpan {
    pub id: u64,
    /// Replica the span was observed on (stamped at report drain).
    pub replica: usize,
    pub tenant: usize,
    pub kind: SpanKind,
    pub arrival: Seconds,
    /// Clock at batch formation: `queue_end − arrival` is the admit
    /// queue wait.
    pub queue_end: Seconds,
    pub prefill_compute: Seconds,
    pub prefix_fetch: Seconds,
    pub swap_stall: Seconds,
    /// Clock at prefill completion (first token emitted).
    pub prefill_done: Seconds,
    /// Measured time-to-first-token, exactly as the metrics recorded it.
    pub ttft: Seconds,
    /// Clock at the last token (= `prefill_done` for `PrefillHandoff`).
    pub finish: Seconds,
    pub generated: u64,
}

impl RequestSpan {
    pub fn queue_wait(&self) -> Seconds {
        self.queue_end - self.arrival
    }

    pub fn decode_time(&self) -> Seconds {
        self.finish - self.prefill_done
    }

    /// Bitwise conservation: the span components reconstruct the
    /// measured TTFT exactly (no tolerance). Holds for every span whose
    /// prefill was observed in place; `DecodeInjected` spans carry
    /// their prefill attribution on the matching `PrefillHandoff` span.
    pub fn conserves_ttft(&self) -> bool {
        if self.kind == SpanKind::DecodeInjected {
            return true;
        }
        let start = SpanStart {
            queue_end: self.queue_end,
            compute: self.prefill_compute,
            fetch: self.prefix_fetch,
            swap: self.swap_stall,
        };
        let done = start.prefill_done();
        done.value().to_bits() == self.prefill_done.value().to_bits()
            && (done - self.arrival).value().to_bits() == self.ttft.value().to_bits()
    }
}

/// Fleet-level stall-attribution totals: where request latency went.
/// Lives in `Metrics` (merged per replica) and in `TenantReport`
/// (folded from the tenant's spans).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StallLedger {
    /// Spans folded in.
    pub spans: u64,
    /// Σ arrival → batch formation.
    pub queue_wait: Seconds,
    /// Σ prefill compute charged (batch cost per request — the TTFT
    /// convention).
    pub prefill_exec: Seconds,
    /// Σ prefix-cache fetch stalls charged.
    pub prefix_fetch: Seconds,
    /// Σ cold-start model-swap stalls charged.
    pub swap_stall: Seconds,
    /// Σ prefill completion → last token.
    pub decode: Seconds,
    /// Σ measured TTFT over the charged spans.
    pub ttft_total: Seconds,
    /// Σ measured end-to-end latency over finishing spans.
    pub e2e_total: Seconds,
}

impl StallLedger {
    pub fn is_zero(&self) -> bool {
        self.spans == 0
    }

    /// Fold one span in. Prefill attribution comes from `Full` and
    /// `PrefillHandoff` spans; decode/e2e from `Full` and
    /// `DecodeInjected` spans — so in a disaggregated fleet each phase
    /// is charged exactly once.
    pub fn charge(&mut self, s: &RequestSpan) {
        self.spans += 1;
        if s.kind != SpanKind::DecodeInjected {
            self.queue_wait += s.queue_wait();
            self.prefill_exec += s.prefill_compute;
            self.prefix_fetch += s.prefix_fetch;
            self.swap_stall += s.swap_stall;
            self.ttft_total += s.ttft;
        }
        if s.kind != SpanKind::PrefillHandoff {
            self.decode += s.decode_time();
            self.e2e_total += s.finish - s.arrival;
        }
    }

    pub fn merge(&mut self, other: &StallLedger) {
        self.spans += other.spans;
        self.queue_wait += other.queue_wait;
        self.prefill_exec += other.prefill_exec;
        self.prefix_fetch += other.prefix_fetch;
        self.swap_stall += other.swap_stall;
        self.decode += other.decode;
        self.ttft_total += other.ttft_total;
        self.e2e_total += other.e2e_total;
    }

    /// One human-readable attribution line, shared by the fleet summary
    /// (`Metrics::summary`) and the per-tenant summary
    /// (`TenantReport::summary_line`) so the formats can't drift.
    pub fn summary_line(&self) -> String {
        if self.is_zero() {
            return String::new();
        }
        let n = self.spans as f64;
        let opt = |label: &str, v: Seconds| {
            if v.value() > 0.0 {
                format!(" {label} {:.1}", v.as_ms() / n)
            } else {
                String::new()
            }
        };
        format!(
            "stalls ({} spans, ms/req): queue {:.1} prefill {:.1}{}{} decode {:.1} | \
             ttft mean {:.1} e2e mean {:.1}",
            self.spans,
            self.queue_wait.as_ms() / n,
            self.prefill_exec.as_ms() / n,
            opt("fetch", self.prefix_fetch),
            opt("swap", self.swap_stall),
            self.decode.as_ms() / n,
            self.ttft_total.as_ms() / n,
            self.e2e_total.as_ms() / n,
        )
    }
}

/// One fleet gauge snapshot, taken at a `TelemetryTick` by both cores
/// after advancing every replica to the tick instant (a global sync
/// point, so each field is bit-identical across cores — pinned by
/// `rust/tests/event_core_equiv.rs`).
#[derive(Debug, Clone, Copy)]
pub struct TelemetrySample {
    pub at: Seconds,
    /// Active (scaled-in, alive) replicas.
    pub active_replicas: usize,
    /// Router's outstanding routed work, in tokens.
    pub routed_tokens: u64,
    /// Σ queued + in-flight requests over the fleet.
    pub pending: u64,
    /// Cumulative completions so far.
    pub completed: u64,
    /// Cumulative tokens generated so far.
    pub tokens_generated: u64,
    /// Cumulative front-door sheds so far.
    pub shed: u64,
    /// Cumulative rejections so far.
    pub rejected: u64,
    /// Cumulative SLO-scored completions so far.
    pub slo_total: u64,
    /// Cumulative SLO-met completions so far.
    pub slo_met: u64,
    /// Prefix-cache bytes resident in the pool (0 with the cache off).
    pub pool_bytes: f64,
    /// Fabric busy seconds booked so far (0 with contention off).
    pub fabric_busy: Seconds,
}

/// The windowed time-series recorder (one per cluster run).
#[derive(Debug, Clone)]
pub struct TelemetrySampler {
    pub interval: Seconds,
    pub samples: Vec<TelemetrySample>,
}

impl TelemetrySampler {
    pub fn new(interval: Seconds) -> Self {
        TelemetrySampler { interval, samples: Vec::new() }
    }

    pub fn record(&mut self, s: TelemetrySample) {
        debug_assert!(
            self.samples.last().map_or(true, |p| p.at <= s.at),
            "telemetry samples must be recorded in time order"
        );
        self.samples.push(s);
    }
}

/// Telemetry slice of a finished run (`ClusterReport::telemetry`).
#[derive(Debug, Clone)]
pub struct TelemetryReport {
    pub interval: Seconds,
    /// Per-request lifecycle spans, in replica-index then completion
    /// order (deterministic across cores).
    pub spans: Vec<RequestSpan>,
    /// Interval gauges, in tick order.
    pub samples: Vec<TelemetrySample>,
    /// Rolling SLO attainment per interval-wide window, computed from
    /// the completion trace by the fault layer's window slicer
    /// (`faults::report::attainment_windows` — the same windows
    /// recovery accounting scores dips with). `(window start,
    /// attainment)`; empty windows carry the last value forward.
    pub attainment: Vec<(Seconds, f64)>,
    /// Fleet stall-attribution totals (also merged into
    /// `Metrics::ledger`).
    pub ledger: StallLedger,
}

impl TelemetryReport {
    /// One line for `ClusterReport::summary` (the ledger prints through
    /// the fleet metrics summary, not here).
    pub fn summary_line(&self) -> String {
        format!(
            "telemetry: {} spans | {} samples @ {:.0} ms{}",
            self.spans.len(),
            self.samples.len(),
            self.interval.as_ms(),
            match self.attainment.last() {
                Some((_, a)) if self.samples.iter().any(|s| s.slo_total > 0) =>
                    format!(" | rolling slo {:.1}%", 100.0 * a),
                _ => String::new(),
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_span() -> RequestSpan {
        let start = SpanStart {
            queue_end: Seconds::ms(10.0),
            compute: Seconds::ms(7.0),
            fetch: Seconds::ms(2.0),
            swap: Seconds::ms(1.0),
        };
        let arrival = Seconds::ms(4.0);
        let done = start.prefill_done();
        RequestSpan {
            id: 42,
            replica: 1,
            tenant: 0,
            kind: SpanKind::Full,
            arrival,
            queue_end: start.queue_end,
            prefill_compute: start.compute,
            prefix_fetch: start.fetch,
            swap_stall: start.swap,
            prefill_done: done,
            ttft: done - arrival,
            finish: done + Seconds::ms(30.0),
            generated: 16,
        }
    }

    #[test]
    fn config_validates_interval() {
        assert!(TelemetryConfig::default().validate().is_ok());
        assert!(TelemetryConfig { interval: Seconds::ZERO }.validate().is_err());
        assert!(TelemetryConfig { interval: Seconds::new(-1.0) }.validate().is_err());
    }

    #[test]
    fn span_conservation_is_bitwise() {
        let s = full_span();
        assert!(s.conserves_ttft());
        // Any drifted component breaks the identity.
        let mut bad = s;
        bad.prefill_compute += Seconds::new(1e-13);
        assert!(!bad.conserves_ttft());
        // An injected decode span carries no prefill attribution.
        let mut inj = s;
        inj.kind = SpanKind::DecodeInjected;
        inj.prefill_compute = Seconds::ZERO;
        assert!(inj.conserves_ttft());
    }

    #[test]
    fn ledger_charges_each_phase_once_across_a_handoff() {
        let s = full_span();
        let mut pre = s;
        pre.kind = SpanKind::PrefillHandoff;
        pre.finish = pre.prefill_done;
        pre.generated = 1;
        let mut inj = s;
        inj.kind = SpanKind::DecodeInjected;
        inj.prefill_compute = Seconds::ZERO;
        inj.prefix_fetch = Seconds::ZERO;
        inj.swap_stall = Seconds::ZERO;
        inj.queue_end = inj.arrival;
        inj.prefill_done = inj.arrival + inj.ttft;

        let mut whole = StallLedger::default();
        whole.charge(&s);
        let mut split = StallLedger::default();
        split.charge(&pre);
        split.charge(&inj);
        assert_eq!(split.spans, 2);
        assert_eq!(split.prefill_exec, whole.prefill_exec);
        assert_eq!(split.ttft_total, whole.ttft_total);
        assert!((split.decode.value() - whole.decode.value()).abs() < 1e-12);
        assert!(split.queue_wait == whole.queue_wait);
    }

    #[test]
    fn ledger_merge_adds_fields_and_summary_gates_segments() {
        let mut a = StallLedger::default();
        a.charge(&full_span());
        let mut b = StallLedger::default();
        b.charge(&full_span());
        let mut m = a;
        m.merge(&b);
        assert_eq!(m.spans, 2);
        assert_eq!(m.ttft_total, a.ttft_total + b.ttft_total);
        let line = m.summary_line();
        assert!(line.contains("queue") && line.contains("fetch") && line.contains("swap"), "{line}");
        // Zero fetch/swap segments disappear.
        let mut plain = full_span();
        plain.prefix_fetch = Seconds::ZERO;
        plain.swap_stall = Seconds::ZERO;
        let mut l = StallLedger::default();
        l.charge(&plain);
        let line = l.summary_line();
        assert!(!line.contains("fetch") && !line.contains("swap"), "{line}");
        assert_eq!(StallLedger::default().summary_line(), "");
    }

    #[test]
    fn sampler_records_in_order() {
        let mut s = TelemetrySampler::new(Seconds::ms(10.0));
        for k in 0..3u64 {
            s.record(TelemetrySample {
                at: Seconds::ms(10.0) * (k + 1) as f64,
                active_replicas: 2,
                routed_tokens: 100 * k,
                pending: k,
                completed: k,
                tokens_generated: 10 * k,
                shed: 0,
                rejected: 0,
                slo_total: k,
                slo_met: k,
                pool_bytes: 0.0,
                fabric_busy: Seconds::ZERO,
            });
        }
        assert_eq!(s.samples.len(), 3);
        assert!(s.samples.windows(2).all(|w| w[0].at <= w[1].at));
    }
}
