//! Telemetry exporters (DESIGN.md §Telemetry): Chrome trace-event JSON
//! (Perfetto / `chrome://tracing` loadable) and the time-series CSV.
//!
//! Trace schema: one process (`pid` 0, "fenghuang fleet") with one
//! thread track per replica. Each request span renders as up to three
//! `"X"` complete events on its replica's track — `queue`
//! (arrival → batch formation), `prefill` (batch formation → first
//! token, with the compute/fetch/swap attribution in `args`) and
//! `decode` (first → last token). Sampler gauges render as `"C"`
//! counter events. Timestamps are virtual-clock microseconds.

use super::{SpanKind, TelemetryReport};
use crate::analysis::csv;
use std::fmt::Write as _;

fn us(s: crate::units::Seconds) -> f64 {
    s.value() * 1e6
}

fn push_event(out: &mut String, body: &str, first: &mut bool) {
    if !*first {
        out.push_str(",\n");
    }
    *first = false;
    out.push_str("    ");
    out.push_str(body);
}

/// Render the report as Chrome trace-event JSON
/// (`serve --trace-out t.json`).
pub fn chrome_trace(report: &TelemetryReport) -> String {
    let mut out = String::from("{\"traceEvents\": [\n");
    let mut first = true;
    push_event(
        &mut out,
        "{\"ph\": \"M\", \"pid\": 0, \"name\": \"process_name\", \
         \"args\": {\"name\": \"fenghuang fleet\"}}",
        &mut first,
    );
    let mut replicas: Vec<usize> = report.spans.iter().map(|s| s.replica).collect();
    replicas.sort_unstable();
    replicas.dedup();
    for r in &replicas {
        push_event(
            &mut out,
            &format!(
                "{{\"ph\": \"M\", \"pid\": 0, \"tid\": {r}, \"name\": \"thread_name\", \
                 \"args\": {{\"name\": \"replica {r}\"}}}}"
            ),
            &mut first,
        );
    }
    for s in &report.spans {
        let tid = s.replica;
        if s.kind != SpanKind::DecodeInjected {
            let queue = us(s.queue_wait());
            if queue > 0.0 {
                push_event(
                    &mut out,
                    &format!(
                        "{{\"ph\": \"X\", \"pid\": 0, \"tid\": {tid}, \"name\": \"queue\", \
                         \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"req\": {}}}}}",
                        us(s.arrival),
                        queue,
                        s.id
                    ),
                    &mut first,
                );
            }
            push_event(
                &mut out,
                &format!(
                    "{{\"ph\": \"X\", \"pid\": 0, \"tid\": {tid}, \"name\": \"prefill\", \
                     \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"req\": {}, \
                     \"compute_ms\": {:.6}, \"fetch_ms\": {:.6}, \"swap_ms\": {:.6}, \
                     \"ttft_ms\": {:.6}, \"tenant\": {}}}}}",
                    us(s.queue_end),
                    us(s.prefill_done - s.queue_end),
                    s.id,
                    s.prefill_compute.as_ms(),
                    s.prefix_fetch.as_ms(),
                    s.swap_stall.as_ms(),
                    s.ttft.as_ms(),
                    s.tenant
                ),
                &mut first,
            );
        }
        let decode = us(s.decode_time());
        if s.kind != SpanKind::PrefillHandoff && decode > 0.0 {
            push_event(
                &mut out,
                &format!(
                    "{{\"ph\": \"X\", \"pid\": 0, \"tid\": {tid}, \"name\": \"decode\", \
                     \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"req\": {}, \
                     \"generated\": {}}}}}",
                    us(s.prefill_done),
                    decode,
                    s.id,
                    s.generated
                ),
                &mut first,
            );
        }
    }
    for sample in &report.samples {
        let ts = us(sample.at);
        for (name, v) in [
            ("pending", sample.pending as f64),
            ("routed_tokens", sample.routed_tokens as f64),
            ("active_replicas", sample.active_replicas as f64),
            ("pool_bytes", sample.pool_bytes),
        ] {
            push_event(
                &mut out,
                &format!(
                    "{{\"ph\": \"C\", \"pid\": 0, \"name\": \"{name}\", \"ts\": {ts:.3}, \
                     \"args\": {{\"{name}\": {v}}}}}"
                ),
                &mut first,
            );
        }
    }
    out.push_str("\n]}\n");
    out
}

/// Render the sampler series as CSV (`serve --timeseries-out t.csv`),
/// one row per tick; the rolling-attainment column joins the fault
/// layer's window series by index (both are `interval`-wide from t=0).
pub fn timeseries_csv(report: &TelemetryReport) -> String {
    let rows: Vec<String> = report
        .samples
        .iter()
        .enumerate()
        .map(|(k, s)| {
            let mut row = String::new();
            let _ = write!(
                row,
                "{:.6},{},{},{},{},{},{},{},{},{},{:.4},{:.0},{:.6}",
                s.at.value(),
                s.active_replicas,
                s.routed_tokens,
                s.pending,
                s.completed,
                s.tokens_generated,
                s.shed,
                s.rejected,
                s.slo_total,
                s.slo_met,
                report.attainment.get(k).map(|&(_, a)| a).unwrap_or(1.0),
                s.pool_bytes,
                s.fabric_busy.value(),
            );
            row
        })
        .collect();
    csv::table(
        "t_s,active_replicas,routed_tokens,pending,completed,tokens_generated,\
         shed,rejected,slo_total,slo_met,rolling_attainment,pool_bytes,fabric_busy_s",
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{RequestSpan, StallLedger, TelemetrySample, TelemetrySampler};
    use crate::units::Seconds;

    fn report() -> TelemetryReport {
        let mk = |id: u64, kind: SpanKind| {
            let arrival = Seconds::ms(id as f64);
            let queue_end = arrival + Seconds::ms(1.0);
            let done = queue_end + Seconds::ms(5.0);
            RequestSpan {
                id,
                replica: (id % 2) as usize,
                tenant: 0,
                kind,
                arrival,
                queue_end,
                prefill_compute: Seconds::ms(5.0),
                prefix_fetch: Seconds::ZERO,
                swap_stall: Seconds::ZERO,
                prefill_done: done,
                ttft: done - arrival,
                finish: if kind == SpanKind::PrefillHandoff { done } else { done + Seconds::ms(8.0) },
                generated: if kind == SpanKind::PrefillHandoff { 1 } else { 4 },
            }
        };
        let mut sampler = TelemetrySampler::new(Seconds::ms(10.0));
        for k in 1..=2u64 {
            sampler.record(TelemetrySample {
                at: Seconds::ms(10.0 * k as f64),
                active_replicas: 2,
                routed_tokens: 64 * k,
                pending: 3,
                completed: k,
                tokens_generated: 4 * k,
                shed: 0,
                rejected: 0,
                slo_total: k,
                slo_met: k,
                pool_bytes: 0.0,
                fabric_busy: Seconds::ZERO,
            });
        }
        TelemetryReport {
            interval: Seconds::ms(10.0),
            spans: vec![
                mk(0, SpanKind::Full),
                mk(1, SpanKind::PrefillHandoff),
                mk(2, SpanKind::DecodeInjected),
            ],
            samples: sampler.samples,
            attainment: vec![(Seconds::ZERO, 1.0), (Seconds::ms(10.0), 1.0)],
            ledger: StallLedger::default(),
        }
    }

    #[test]
    fn chrome_trace_has_balanced_structure_and_expected_tracks() {
        let t = chrome_trace(&report());
        assert!(t.starts_with("{\"traceEvents\": ["));
        assert!(t.trim_end().ends_with("]}"));
        assert_eq!(t.matches('{').count(), t.matches('}').count(), "unbalanced braces");
        assert_eq!(t.matches('[').count(), t.matches(']').count());
        assert!(t.contains("\"thread_name\""));
        assert!(t.contains("\"prefill\"") && t.contains("\"decode\"") && t.contains("\"queue\""));
        // The handoff span must not render a decode slice, the injected
        // span must not render a prefill slice.
        assert_eq!(t.matches("\"name\": \"prefill\"").count(), 2);
        assert_eq!(t.matches("\"name\": \"decode\"").count(), 2);
        assert!(t.contains("\"ph\": \"C\""), "counter tracks missing");
        // No trailing comma before the closing bracket.
        assert!(!t.contains(",\n]"));
    }

    #[test]
    fn timeseries_csv_is_rectangular() {
        let csv = timeseries_csv(&report());
        let mut lines = csv.lines();
        let cols = lines.next().unwrap().split(',').count();
        assert_eq!(cols, 13);
        let mut rows = 0;
        for l in lines {
            assert_eq!(l.split(',').count(), cols, "ragged: {l}");
            rows += 1;
        }
        assert_eq!(rows, 2);
        assert!(csv.contains("rolling_attainment"));
    }
}
