//! Statistical tenant-isolation test (DESIGN.md §Multi-Tenant): a batch
//! burst from tenant B must not wreck tenant A's tail latency when the
//! admission arbiter runs weighted fair queueing — and must visibly
//! wreck it under the FIFO "no isolation" baseline. Three runs on the
//! same fleet shape:
//!
//! * solo     — tenant A's traffic alone (baseline p99 TTFT);
//! * wfq      — A's traffic plus a simultaneous B burst, DRR arbitration;
//! * fifo     — the identical workload, global-arrival-order admission.
//!
//! The wall: `p99(wfq A) ≤ ISOLATION_FACTOR × p99(solo A)` while
//! `p99(fifo A) > ISOLATION_FACTOR × p99(solo A)` — FIFO's head-of-line
//! blocking parks A's requests behind B's backlog even though A's home
//! replica sits idle.

use fenghuang::coordinator::tenancy::{TenantArbitration, TenantsConfig};
use fenghuang::coordinator::{Cluster, ClusterConfig, Request};
use fenghuang::models::arch::gpt3_175b;
use fenghuang::units::Seconds;

/// How much of A's solo tail WFQ may give up before we call isolation
/// broken. Generous: WFQ leaves A's lane untouched (its home replica
/// never serves B), while FIFO's blocking inflates the tail by the
/// whole burst drain — well past this line.
const ISOLATION_FACTOR: f64 = 5.0;

/// Tenant A: steady interactive traffic, one request every 100 ms.
fn chat_requests() -> Vec<Request> {
    (0..20)
        .map(|i| Request {
            id: i,
            prompt: vec![(i % 509) as i32 + 1; 200],
            max_new_tokens: 40,
            arrival: Seconds::new(0.1 * i as f64),
            tenant: 0,
            ..Default::default()
        })
        .collect()
}

/// Tenant B: sixteen heavyweight batch requests dumped at t = 50 ms
/// (prompt + generation kept inside gpt2's 1024-token context).
fn burst_requests() -> Vec<Request> {
    (0..16)
        .map(|i| Request {
            id: (1 << 40) | i,
            prompt: vec![((i + 7) % 509) as i32 + 1; 600],
            max_new_tokens: 200,
            arrival: Seconds::new(0.05),
            tenant: 1,
            ..Default::default()
        })
        .collect()
}

fn merged_workload() -> Vec<Request> {
    let mut reqs = chat_requests();
    reqs.extend(burst_requests());
    reqs.sort_by(|x, y| x.arrival.partial_cmp(&y.arrival).expect("finite arrivals"));
    reqs
}

fn tenants(mode: TenantArbitration) -> TenantsConfig {
    let mut tc = TenantsConfig::parse("alpha/gpt2,beta/gpt2").expect("spec");
    tc.arbitration = mode;
    tc.admit_tokens = Some(1500);
    tc
}

/// Run on the event core and return tenant A's p99 TTFT in ms.
fn a_p99(mode: TenantArbitration, reqs: Vec<Request>) -> f64 {
    let cfg = ClusterConfig { tenants: Some(tenants(mode)), ..Default::default() };
    let mut cluster = Cluster::fh4(2, &gpt3_175b(), cfg).expect("cluster");
    let report = cluster.run(reqs).expect("run");
    let ts = report.tenants.as_ref().expect("tenant reports");
    assert!(ts[0].completed > 0, "tenant A must complete work");
    ts[0].ttft.percentile_ms(99.0)
}

#[test]
fn wfq_shields_tenant_a_from_a_neighbour_burst_and_fifo_does_not() {
    let solo = a_p99(TenantArbitration::Wfq, chat_requests());
    let wfq = a_p99(TenantArbitration::Wfq, merged_workload());
    let fifo = a_p99(TenantArbitration::Fifo, merged_workload());
    assert!(solo > 0.0, "solo baseline must be a real latency, got {solo} ms");
    assert!(
        wfq < fifo,
        "WFQ must strictly beat FIFO on tenant A's tail under a B burst: \
         wfq p99 {wfq:.3} ms vs fifo p99 {fifo:.3} ms"
    );
    assert!(
        wfq <= ISOLATION_FACTOR * solo,
        "isolation broken: under WFQ a neighbour burst moved tenant A's p99 TTFT \
         from {solo:.3} ms (solo) to {wfq:.3} ms — over {ISOLATION_FACTOR}×"
    );
    assert!(
        fifo > ISOLATION_FACTOR * solo,
        "the FIFO baseline was expected to visibly break isolation \
         (p99 {fifo:.3} ms vs solo {solo:.3} ms) — if this now holds, the \
         burst is no longer binding and the scenario needs retuning"
    );
}

#[test]
fn per_tenant_tails_are_separated_in_the_report() {
    // Sanity on the same scenario: the report's per-tenant TTFT stats
    // are really split by tenant — B's burst tail is far heavier than
    // A's under WFQ, and the fleet stat mixes both.
    let cfg = ClusterConfig {
        tenants: Some(tenants(TenantArbitration::Wfq)),
        ..Default::default()
    };
    let mut cluster = Cluster::fh4(2, &gpt3_175b(), cfg).expect("cluster");
    let report = cluster.run(merged_workload()).expect("run");
    let ts = report.tenants.as_ref().expect("tenant reports");
    assert_eq!(ts.len(), 2);
    assert_eq!(ts[0].completed, 20);
    assert_eq!(ts[1].completed, 16);
    let a99 = ts[0].ttft.percentile_ms(99.0);
    let b99 = ts[1].ttft.percentile_ms(99.0);
    assert!(
        b99 > a99,
        "the bursting batch tenant must own the heavier tail: A {a99:.3} ms, B {b99:.3} ms"
    );
    let fleet99 = report.fleet.ttft.percentile_ms(99.0);
    assert!(fleet99 >= a99, "fleet tail can't undercut its best tenant");
}
