//! Integration tests over the PJRT runtime + artifact bundle.
//!
//! These need `make artifacts` to have run; they skip (with a loud
//! message) if the bundle is missing so `cargo test` stays usable in a
//! fresh checkout.

use fenghuang::coordinator::tp::{verify_against_full_model, PjrtBackend, TpPipeline};
use fenghuang::runtime::artifacts::Bundle;
use fenghuang::runtime::{literal_f32, to_vec_f32, Runtime};

fn bundle_or_skip() -> Option<Bundle> {
    let dir = Bundle::default_dir();
    if !dir.join("model_fwd.hlo.txt").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Bundle::load(&dir).expect("bundle loads"))
}

#[test]
fn bundle_loads_and_indexes_tensors() {
    let Some(b) = bundle_or_skip() else { return };
    assert_eq!(b.meta.tp, 4);
    assert_eq!(b.meta.hidden, 256);
    let embed = b.tensor("embed").unwrap();
    assert_eq!(embed.len(), b.meta.vocab * b.meta.hidden);
    assert!(b.tensor("nonexistent").is_err());
    // Every manifest tensor is addressable.
    for name in b.tensor_names() {
        assert!(b.tensor(name).is_ok(), "{name}");
    }
}

#[test]
fn pjrt_executes_writeacc_kernel() {
    let Some(b) = bundle_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&b.hlo_path("writeacc")).unwrap();
    let n = b.meta.tp;
    let lanes = b.meta.writeacc_lanes;
    let data: Vec<f32> = (0..n * lanes).map(|i| (i % 13) as f32).collect();
    let input = literal_f32(&data, &[n as i64, lanes as i64]).unwrap();
    let out = exe.run(&[input]).unwrap();
    let sum = to_vec_f32(&out[0]).unwrap();
    assert_eq!(sum.len(), lanes);
    for (j, v) in sum.iter().enumerate().take(100) {
        let expect: f32 = (0..n).map(|i| ((i * lanes + j) % 13) as f32).sum();
        assert_eq!(*v, expect, "lane {j}");
    }
}

#[test]
fn pjrt_executes_attention_kernel_with_softmax_property() {
    let Some(b) = bundle_or_skip() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load_hlo(&b.hlo_path("attention")).unwrap();
    let (h, s, d) = (b.meta.heads, b.meta.seq, b.meta.hidden / b.meta.heads);
    let n = h * s * d;
    let q: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) * 0.1).collect();
    let k: Vec<f32> = (0..n).map(|i| ((i % 5) as f32 - 2.0) * 0.1).collect();
    let v: Vec<f32> = (0..n).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    let dims = [1i64, h as i64, s as i64, d as i64];
    let out = exe
        .run(&[
            literal_f32(&q, &dims).unwrap(),
            literal_f32(&k, &dims).unwrap(),
            literal_f32(&v, &dims).unwrap(),
        ])
        .unwrap();
    let o = to_vec_f32(&out[0]).unwrap();
    assert_eq!(o.len(), n);
    // Attention output is a convex combination of V rows.
    let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
    let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
    for &x in &o {
        assert!(x <= vmax + 1e-4 && x >= vmin - 1e-4, "{x} outside [{vmin}, {vmax}]");
    }
}

#[test]
fn full_model_forward_is_deterministic_and_causal() {
    let Some(b) = bundle_or_skip() else { return };
    let backend = PjrtBackend::new(&b.dir).unwrap();
    let meta = backend.meta.clone();
    let tokens: Vec<Vec<i32>> = (0..meta.batch)
        .map(|bi| (0..meta.seq).map(|si| ((bi * 31 + si * 3) % meta.vocab) as i32).collect())
        .collect();
    let a = backend.forward(&tokens).unwrap();
    let bb = backend.forward(&tokens).unwrap();
    assert_eq!(a, bb, "same input → same logits");
    // Causality: perturb the LAST token; logits at position 0 must not move.
    let mut t2 = tokens.clone();
    t2[0][meta.seq - 1] = (t2[0][meta.seq - 1] + 1) % meta.vocab as i32;
    let c = backend.forward(&t2).unwrap();
    let v = meta.vocab;
    for j in 0..v {
        assert!((a[j] - c[j]).abs() < 1e-5, "position 0 logit {j} moved");
    }
    // …and the last position must move.
    let s = meta.seq;
    let last = (s - 1) * v;
    let moved = (0..v).any(|j| (a[last + j] - c[last + j]).abs() > 1e-4);
    assert!(moved, "perturbing last token must change its logits");
}

#[test]
fn tp_pipeline_matches_full_model_through_tab_pool() {
    // The end-to-end composition check (also exercised by
    // examples/serve_e2e.rs): 4 PJRT workers + write-accumulate == one
    // full executable.
    let Some(b) = bundle_or_skip() else { return };
    let mut tp = TpPipeline::new(&b.dir).unwrap();
    let full = PjrtBackend::new(&b.dir).unwrap();
    let meta = tp.meta.clone();
    let tokens: Vec<Vec<i32>> = (0..meta.batch)
        .map(|bi| (0..meta.seq).map(|si| ((bi * 7 + si) % meta.vocab) as i32).collect())
        .collect();
    let max_diff = verify_against_full_model(&mut tp, &full, &tokens).unwrap();
    assert!(max_diff < 1e-2, "TP-over-TAB diverged: {max_diff}");
    let stats = tp.pool_stats();
    assert_eq!(stats.accumulates as usize, meta.layers * 2 * meta.tp);
    assert!(stats.notifications as usize >= meta.layers * 2 * meta.tp);
}

#[test]
fn serving_loop_over_pjrt_completes_with_real_tokens() {
    let Some(b) = bundle_or_skip() else { return };
    use fenghuang::coordinator::{Batcher, Request, Scheduler};
    use fenghuang::units::Seconds;
    let backend = PjrtBackend::new(&b.dir).unwrap();
    let meta = backend.meta.clone();
    let batcher = Batcher::new(meta.batch, 64, meta.seq - 4);
    let mut sched = Scheduler::new(backend, batcher);
    let reqs: Vec<Request> = (0..6)
        .map(|id| Request {
            id,
            prompt: (0..20).map(|i| ((id as usize + i) % meta.vocab) as i32).collect(),
            max_new_tokens: 3,
            arrival: Seconds::ZERO,
            ..Default::default()
        })
        .collect();
    sched.submit_all(reqs);
    sched.run_to_completion().unwrap();
    assert_eq!(sched.metrics.completed, 6);
    for r in &sched.responses {
        assert_eq!(r.tokens.len(), 23);
        // Generated tokens must be valid vocab entries.
        for &t in &r.tokens[20..] {
            assert!((0..meta.vocab as i32).contains(&t));
        }
        assert!(r.ttft.value() > 0.0 && r.total >= r.ttft);
    }
}
